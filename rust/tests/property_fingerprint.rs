//! Property suite for the serve cache's structural fingerprints
//! (ISSUE 6 satellite), over the seeded random-kernel generator:
//!
//! * **soundness of sharing** — kernels that are structurally identical
//!   (`structural_diff ≡ None`: a pretty-print → parse round-trip) or
//!   differ *only in names* (renamed kernel + renamed iterators) map to
//!   the same exact and warm keys;
//! * **separation** — deterministic structural mutations move the keys:
//!   flipping the dtype or shrinking a loop bound changes the exact key
//!   while warm-matching (the warm-start regime), and duplicating a
//!   statement changes both keys.
//!
//! `FUZZ_KERNELS` / `FUZZ_SMOKE=1` bound the corpus like the frontend
//! fuzz suite; failures panic with the seed and the `.knl` text.

use nlp_dse::frontend::{self, GenConfig};
use nlp_dse::ir::Kernel;
use nlp_dse::serve::fingerprint;
use nlp_dse::util::env_usize;

fn fuzz_n() -> usize {
    let n = if std::env::var("FUZZ_SMOKE").as_deref() == Ok("1") {
        env_usize("FUZZ_KERNELS", 16)
    } else {
        env_usize("FUZZ_KERNELS", 100)
    };
    n.max(1)
}

const BASE_SEED: u64 = 0xF1F0_2026;

fn seeds(label: &str) -> Vec<u64> {
    let n = fuzz_n() as u64;
    let base: u64 = std::env::var("FUZZ_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(BASE_SEED)
        .min(u64::MAX - n);
    eprintln!("[fuzz:{label}] {n} kernels, seeds {base}..={}", base + n - 1);
    (base..base + n).collect()
}

fn fail(seed: u64, k: &Kernel, msg: &str) -> ! {
    panic!(
        "\n=== fingerprint property failure ===\n\
         seed: {seed}\n\
         replay: FUZZ_SEED={seed} FUZZ_KERNELS=1 cargo test --test property_fingerprint\n\
         {msg}\n\
         --- offending kernel (.knl) ---\n{}",
        frontend::pretty::print(k)
    )
}

fn reparse(seed: u64, k: &Kernel, text: &str, what: &str) -> Kernel {
    frontend::parse_kernel(text, "<mutated>").unwrap_or_else(|e| {
        fail(seed, k, &format!("{what}: mutated text failed to reparse:\n{e}\n--- mutated ---\n{text}"))
    })
}

/// Rename every loop iterator `l<N>` to `q<N>_r`. Generator names are
/// systematic (`l0`, `l1`, …), so replacing longest-first and mapping
/// into an `l`-free namespace can never corrupt another identifier.
fn rename_iterators(k: &Kernel, text: &str) -> String {
    let mut names: Vec<String> = (0..k.n_loops())
        .map(|i| k.loop_name(nlp_dse::ir::LoopId(i as u32)).to_string())
        .collect();
    names.sort_by_key(|n| std::cmp::Reverse(n.len()));
    let mut out = text.to_string();
    for n in &names {
        out = out.replace(n.as_str(), &format!("q{}_r", &n[1..]));
    }
    out
}

#[test]
fn prop_roundtrips_and_renames_share_the_key() {
    for seed in seeds("fp-sound") {
        let k = frontend::generate(&GenConfig::sampled(seed));
        let fp = fingerprint(&k);
        let text = frontend::pretty::print(&k);

        // structural_diff ≡ None ⇒ same key
        let k2 = reparse(seed, &k, &text, "roundtrip");
        if let Some(d) = k.structural_diff(&k2) {
            fail(seed, &k, &format!("round-trip diverged: {d}"));
        }
        if fingerprint(&k2) != fp {
            fail(seed, &k, "round-trip changed the fingerprint");
        }

        // renamed kernel + renamed iterators: names differ, keys don't
        let renamed = rename_iterators(&k, &text).replace(
            &format!("\"{}\"", k.name),
            "\"renamed-elsewhere\"",
        );
        let k3 = reparse(seed, &k, &renamed, "rename");
        if k.structural_diff(&k3).is_none() {
            fail(seed, &k, "rename produced no structural_diff (names should differ)");
        }
        if fingerprint(&k3) != fp {
            fail(seed, &k, "renaming identifiers changed the fingerprint");
        }
    }
}

#[test]
fn prop_structural_mutations_move_the_key() {
    for seed in seeds("fp-separate") {
        let k = frontend::generate(&GenConfig::sampled(seed));
        let fp = fingerprint(&k);
        let text = frontend::pretty::print(&k);

        // dtype flip: a different solve problem (exact splits), same
        // nest shape (warm matches)
        let flipped = if text.contains("\" f32\n") {
            text.replacen("\" f32\n", "\" f64\n", 1)
        } else {
            text.replacen("\" f64\n", "\" f32\n", 1)
        };
        let kd = reparse(seed, &k, &flipped, "dtype flip");
        let fpd = fingerprint(&kd);
        if fpd.exact == fp.exact {
            fail(seed, &k, "dtype flip did not change the exact key");
        }
        if fpd.warm != fp.warm {
            fail(seed, &k, "dtype flip changed the warm key (must be warm-invariant)");
        }

        // shrink the first constant top-level loop bound: new sizes,
        // same shape — the warm-start resubmission regime
        if let Some(shrunk) = shrink_first_bound(&text) {
            let ks = reparse(seed, &k, &shrunk, "bound shrink");
            let fps = fingerprint(&ks);
            if fps.exact == fp.exact {
                fail(seed, &k, "bound shrink did not change the exact key");
            }
            if fps.warm != fp.warm {
                fail(seed, &k, "bound shrink changed the warm key (sizes are warm-invariant)");
            }
        }

        // duplicate a statement: a different nest entirely — both split
        let dup = duplicate_last_stmt(&text)
            .unwrap_or_else(|| fail(seed, &k, "no stmt line found to duplicate"));
        let kx = reparse(seed, &k, &dup, "stmt duplication");
        let fpx = fingerprint(&kx);
        if fpx.exact == fp.exact || fpx.warm == fp.warm {
            fail(seed, &k, "statement duplication left a key unchanged");
        }
    }
}

/// Replace the first `for <it> in 0 .. <C> {` whose upper bound is a
/// constant > 1 with `C - 1`. Returns `None` when no loop qualifies
/// (e.g. every trip count is 1 or bounds are triangular).
fn shrink_first_bound(text: &str) -> Option<String> {
    for line in text.lines() {
        let t = line.trim_start();
        let Some(rest) = t.strip_prefix("for ") else { continue };
        let Some((_, after)) = rest.split_once(" .. ") else { continue };
        let Some(ub) = after.strip_suffix(" {") else { continue };
        if let Ok(c) = ub.trim().parse::<u64>() {
            if c > 1 {
                let old = format!(" .. {c} {{");
                let new = format!(" .. {} {{", c - 1);
                return Some(text.replacen(&old, &new, 1));
            }
        }
    }
    None
}

/// Duplicate the last `stmt <name> …;` line under a fresh name, right
/// after the original (same loop body, so the tree stays well-formed).
fn duplicate_last_stmt(text: &str) -> Option<String> {
    let lines: Vec<&str> = text.lines().collect();
    let idx = lines
        .iter()
        .rposition(|l| l.trim_start().starts_with("stmt "))?;
    let line = lines[idx];
    let name = line.trim_start().strip_prefix("stmt ")?.split_whitespace().next()?;
    let name = name.trim_end_matches(';');
    let dup = line.replacen(&format!("stmt {name}"), &format!("stmt {name}_dup"), 1);
    let mut out: Vec<String> = lines.iter().map(|s| s.to_string()).collect();
    out.insert(idx + 1, dup);
    Some(out.join("\n") + "\n")
}
