//! Parallel/serial parity for the NLP solver, over **all 24 benchmark
//! kernels + CNN** (PolyBench at Small, CNN at its single Medium size)
//! and both parallelism modes.
//!
//! The solver's contract (see `nlp::solver`'s module docs for the
//! construction): `solve_jobs(.., jobs = N)` is **bit-identical** to
//! `solve_jobs(.., jobs = 1)` — same top-k design fingerprints in the
//! same order, bit-equal objectives, bit-equal proven lower bound, same
//! `optimal` flag — for every worker-team size. The work distribution
//! (bound-ascending deal + work stealing), the shared incumbent guard,
//! and the sharded menu cache may change *what gets pruned when*, but
//! never the deterministic reduction — and the stealing protocol must
//! schedule every pipeline configuration exactly once.

use nlp_dse::benchmarks::{self, Size};
use nlp_dse::hls::Device;
use nlp_dse::ir::{ArrayDir, DType, KernelBuilder, OpKind};
use nlp_dse::nlp::{self, NlpProblem, RustFeatureEvaluator, SolveResult, SymbolicEvaluator};
use nlp_dse::poly::Analysis;

fn kernel_size(name: &str) -> Size {
    if name == "cnn" {
        Size::Medium // cnn has a single problem size (Sec 7.1)
    } else {
        Size::Small
    }
}

/// Solver budget far above any Small-kernel solve time: the anytime
/// escapes (mid-run timeout, per-config node-cap exhaustion) are the one
/// documented source of nondeterminism, so parity is asserted on
/// completed searches — Small/CNN searches sit orders of magnitude under
/// both budgets (the `serial.optimal` guard below would trip loudly if
/// that ever changed).
const BUDGET_S: f64 = 300.0;
const TOPK: usize = 4;

fn assert_bit_identical(ctx: &str, serial: &SolveResult, par: &SolveResult) {
    assert_eq!(serial.optimal, par.optimal, "{ctx}: optimal flag");
    assert_eq!(
        serial.lower_bound.to_bits(),
        par.lower_bound.to_bits(),
        "{ctx}: lower bound {} vs {}",
        serial.lower_bound,
        par.lower_bound
    );
    assert_eq!(
        serial.designs.len(),
        par.designs.len(),
        "{ctx}: top-k size"
    );
    for (i, ((d1, o1), (d2, o2))) in serial.designs.iter().zip(&par.designs).enumerate() {
        assert_eq!(
            d1.fingerprint(),
            d2.fingerprint(),
            "{ctx}: design #{i} diverged"
        );
        assert_eq!(
            o1.to_bits(),
            o2.to_bits(),
            "{ctx}: objective #{i} {o1} vs {o2}"
        );
    }
}

#[test]
fn prop_parallel_solver_bit_identical_to_serial_on_all_kernels() {
    let dev = Device::u200();
    for name in benchmarks::ALL {
        let k = benchmarks::build(name, kernel_size(name), DType::F32).unwrap();
        let a = Analysis::new(&k);
        for fine in [false, true] {
            let p = NlpProblem::new(&k, &a, &dev, 512, fine);
            let serial = nlp::solve_jobs(&p, BUDGET_S, TOPK, &SymbolicEvaluator, 1);
            assert!(
                serial.optimal,
                "{name} fine={fine}: serial run must complete within the budget \
                 (parity is only guaranteed without timeouts)"
            );
            for jobs in [2, 4] {
                let par = nlp::solve_jobs(&p, BUDGET_S, TOPK, &SymbolicEvaluator, jobs);
                assert_eq!(par.jobs, jobs);
                assert_bit_identical(&format!("{name} fine={fine} jobs={jobs}"), &serial, &par);
            }
        }
    }
}

#[test]
fn parity_holds_for_the_rust_feature_evaluator_too() {
    // the evaluator choice is orthogonal to the reduction; spot-check the
    // slower reference evaluator on a representative trio
    let dev = Device::u200();
    for name in ["gemm", "2mm", "seidel-2d"] {
        let k = benchmarks::build(name, Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let p = NlpProblem::new(&k, &a, &dev, 256, false);
        let serial = nlp::solve_jobs(&p, BUDGET_S, TOPK, &RustFeatureEvaluator, 1);
        let par = nlp::solve_jobs(&p, BUDGET_S, TOPK, &RustFeatureEvaluator, 8);
        assert_bit_identical(&format!("{name} rust-eval"), &serial, &par);
    }
}

#[test]
fn serial_runs_are_fully_deterministic_including_stats() {
    // jobs = 1 twice: not just the reduction but every counter must
    // repeat (the parallel path only guarantees the reduction)
    let dev = Device::u200();
    let k = benchmarks::build("2mm", Size::Small, DType::F32).unwrap();
    let a = Analysis::new(&k);
    let p = NlpProblem::new(&k, &a, &dev, 512, false);
    let r1 = nlp::solve_jobs(&p, BUDGET_S, TOPK, &SymbolicEvaluator, 1);
    let r2 = nlp::solve_jobs(&p, BUDGET_S, TOPK, &SymbolicEvaluator, 1);
    assert_bit_identical("2mm serial-repeat", &r1, &r2);
    assert_eq!(r1.stats.nodes, r2.stats.nodes);
    assert_eq!(r1.stats.leaves, r2.stats.leaves);
    assert_eq!(r1.stats.pruned_bound, r2.stats.pruned_bound);
    assert_eq!(r1.stats.pruned_relaxation, r2.stats.pruned_relaxation);
    assert_eq!(r1.stats.pruned_partition, r2.stats.pruned_partition);
    assert_eq!(r1.stats.infeasible, r2.stats.infeasible);
    assert_eq!(r1.stats.candidates_scored, r2.stats.candidates_scored);
    assert_eq!(r1.stats.configs, r2.stats.configs);
    assert_eq!(r1.stats.truncated_menus, r2.stats.truncated_menus);
}

#[test]
fn work_stealing_schedules_every_config_exactly_once() {
    // the per-worker deques + steal-half protocol must neither drop nor
    // duplicate a pipeline configuration: `stats.configs` (summed over
    // the team) equals the space's config count for every team size —
    // and a completed search stays optimal, so nothing was silently
    // skipped. jobs=1 never consults other queues: zero steals, zero
    // recorded idle time.
    let dev = Device::u200();
    for name in ["gemm", "2mm", "bicg"] {
        let k = benchmarks::build(name, kernel_size(name), DType::F32).unwrap();
        let a = Analysis::new(&k);
        let p = NlpProblem::new(&k, &a, &dev, 512, false);
        let n_configs = p.space.pipeline_configs.len() as u64;
        for jobs in [1usize, 2, 4, 8] {
            let r = nlp::solve_jobs(&p, BUDGET_S, TOPK, &SymbolicEvaluator, jobs);
            assert!(r.optimal, "{name} jobs={jobs}: must complete in budget");
            assert_eq!(
                r.stats.configs, n_configs,
                "{name} jobs={jobs}: every config exactly once"
            );
            if jobs == 1 {
                assert_eq!(r.stats.steals, 0, "{name}: serial path never steals");
                assert_eq!(
                    r.stats.queue_idle_s, 0.0,
                    "{name}: serial path records no queue idle time"
                );
            }
        }
    }
}

/// A divisor-rich 4-deep accumulation: `s += A[i][j] * B[k][l]` makes all
/// four loops Add-reductions (the write index involves none of them, like
/// gemm's k), so the `{pipeline i}` configuration leaves four free
/// 24-divisor menus — the pipelined loop plus three under-pipe
/// tree-reduction loops — whose product 24⁴ ≈ 332k complete assignments
/// is past the solver's runaway-product guard.
fn runaway_menu_kernel() -> nlp_dse::Kernel {
    let mut kb = KernelBuilder::new("menu-bomb", DType::F32);
    let a = kb.array("A", &[360, 360], ArrayDir::In);
    let b = kb.array("B", &[360, 360], ArrayDir::In);
    let s = kb.array("s", &[1], ArrayDir::InOut);
    kb.for_const("i", 0, 360, |kb, i| {
        kb.for_const("j", 0, 360, |kb, j| {
            kb.for_const("k", 0, 360, |kb, kk| {
                kb.for_const("l", 0, 360, |kb, l| {
                    kb.stmt(
                        "S0",
                        vec![kb.at(s, &[kb.c(0)])],
                        vec![
                            kb.at(s, &[kb.c(0)]),
                            kb.at(a, &[kb.v(i), kb.v(j)]),
                            kb.at(b, &[kb.v(kk), kb.v(l)]),
                        ],
                        &[(OpKind::Mul, 1), (OpKind::Add, 1)],
                    );
                });
            });
        });
    });
    kb.finish()
}

#[test]
fn truncated_menus_are_recorded_and_stay_deterministic() {
    let k = runaway_menu_kernel();
    let a = Analysis::new(&k);
    let dev = Device::u200();
    let p = NlpProblem::new(&k, &a, &dev, u64::MAX, false);
    let serial = nlp::solve_jobs(&p, BUDGET_S, TOPK, &SymbolicEvaluator, 1);
    // the guard must fire *visibly* (the old code broke mid-extension and
    // silently truncated the last loop's menu asymmetrically)
    assert!(
        serial.stats.truncated_menus > 0,
        "runaway product must be recorded: {:?}",
        serial.stats
    );
    assert!(serial.best().is_some(), "truncation must not empty the search");
    // the lexicographic-prefix menu is part of the deterministic contract
    let par = nlp::solve_jobs(&p, BUDGET_S, TOPK, &SymbolicEvaluator, 4);
    assert_bit_identical("menu-bomb", &serial, &par);
}
