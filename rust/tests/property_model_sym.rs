//! Model/NLP parity and partial-bound soundness for the symbolic
//! bound-model IR (`model::sym`), over **all 24 benchmark kernels + CNN**
//! (PolyBench at Small, CNN at its single Medium size).
//!
//! Invariants:
//! 1. **Eval parity** — `BoundModel::compile()` evaluation equals
//!    `model::evaluate` on every complete design (resources exactly,
//!    latency to 1e-9 relative).
//! 2. **Violation parity** — the lowered shared constraints reproduce the
//!    exact `Violation` sequence of the legacy hand-written
//!    `NlpProblem::check` walk.
//! 3. **Partial-bound admissibility** — `BoundModel::lower_bound` on a
//!    (possibly empty) partial configuration never exceeds the model
//!    value of any complete design in the enumerated subspace.

use nlp_dse::benchmarks::{self, Size};
use nlp_dse::hls::Device;
use nlp_dse::ir::{DType, Kernel, LoopId};
use nlp_dse::model::{self, sym};
use nlp_dse::nlp::NlpProblem;
use nlp_dse::poly::Analysis;
use nlp_dse::pragma::{space, Design, Space};
use nlp_dse::util::proptest::Prop;
use nlp_dse::util::rng::Rng;

fn kernel_size(name: &str) -> Size {
    if name == "cnn" {
        Size::Medium // cnn has a single problem size (Sec 7.1)
    } else {
        Size::Small
    }
}

/// Draw a random *legal* design: pipeline antichain, divisor UFs, divisor
/// tiles (tiles exercise the Eq 12 select paths of the symbolic model).
fn random_design(rng: &mut Rng, k: &Kernel, a: &Analysis, s: &Space) -> Design {
    let cfg = s
        .pipeline_configs
        .get(rng.range(0, s.pipeline_configs.len() as u64) as usize)
        .unwrap()
        .clone();
    let ufs: Vec<u64> = (0..k.n_loops())
        .map(|i| {
            let menu = s.ufs(LoopId(i as u32), a, 1024);
            if menu.is_empty() {
                1
            } else {
                menu[rng.range(0, menu.len() as u64) as usize]
            }
        })
        .collect();
    let tiles: Vec<u64> = (0..k.n_loops())
        .map(|i| {
            let tc = &a.tcs[i];
            if tc.is_constant() && tc.max > 0 && rng.chance(0.3) {
                let divs = nlp_dse::util::divisors(tc.max);
                divs[rng.range(0, divs.len() as u64) as usize]
            } else {
                1
            }
        })
        .collect();
    space::materialize(
        k,
        a,
        &cfg,
        &|l| ufs[l.0 as usize],
        &|l| tiles[l.0 as usize],
    )
}

#[test]
fn prop_compiled_evaluation_equals_recursive_model() {
    let dev = Device::u200();
    for name in benchmarks::ALL {
        let k = benchmarks::build(name, kernel_size(name), DType::F32).unwrap();
        let a = Analysis::new(&k);
        let s = Space::new(&k, &a);
        let bm = sym::BoundModel::build(&k, &a, &dev);
        let cm = bm.compile();
        let mut scratch = cm.scratch();
        Prop::new(32).check(
            &format!("sym-eval-parity/{name}"),
            |rng| random_design(rng, &k, &a, &s),
            |d| {
                let sym_r = cm.evaluate(d, &mut scratch);
                let ref_r = model::evaluate(&k, &a, &dev, d);
                let rel = (sym_r.total_cycles - ref_r.total_cycles).abs()
                    / ref_r.total_cycles.max(1.0);
                if rel > 1e-9 {
                    return Err(format!(
                        "latency {} vs {} for {}",
                        sym_r.total_cycles,
                        ref_r.total_cycles,
                        d.fingerprint()
                    ));
                }
                if sym_r.dsp != ref_r.dsp {
                    return Err(format!("dsp {} vs {}", sym_r.dsp, ref_r.dsp));
                }
                if sym_r.onchip_bytes != ref_r.onchip_bytes {
                    return Err(format!(
                        "onchip {} vs {}",
                        sym_r.onchip_bytes, ref_r.onchip_bytes
                    ));
                }
                if sym_r.max_partitioning != ref_r.max_partitioning {
                    return Err(format!(
                        "partitioning {} vs {}",
                        sym_r.max_partitioning, ref_r.max_partitioning
                    ));
                }
                if sym_r.feasible != ref_r.feasible {
                    return Err(format!(
                        "feasible {} vs {}",
                        sym_r.feasible, ref_r.feasible
                    ));
                }
                Ok(())
            },
        );
    }
}

#[test]
fn prop_lowered_constraints_equal_legacy_violations() {
    let dev = Device::u200();
    for name in benchmarks::ALL {
        let k = benchmarks::build(name, kernel_size(name), DType::F32).unwrap();
        let a = Analysis::new(&k);
        let s = Space::new(&k, &a);
        for cap in [8u64, 64, 512, u64::MAX] {
            let p = NlpProblem::new(&k, &a, &dev, cap, false);
            Prop::new(16).check(
                &format!("violation-parity/{name}/cap{cap}"),
                |rng| {
                    let mut d = random_design(rng, &k, &a, &s);
                    // also exercise illegal UFs (non-divisors, above the
                    // dependence cap) so the Eq 6/8 constraints fire
                    if rng.chance(0.4) {
                        let li = rng.range(0, k.n_loops() as u64) as usize;
                        d.pragmas[li].uf = rng.range(1, 2 * a.tcs[li].max.max(2));
                    }
                    d
                },
                |d| {
                    let shared = p.check(d);
                    let legacy = p.check_legacy(d);
                    if shared != legacy {
                        return Err(format!(
                            "shared {shared:?} != legacy {legacy:?} for {}",
                            d.fingerprint()
                        ));
                    }
                    let o = p.objective(d);
                    let r = p.objective_reference(d);
                    if (o - r).abs() / r.max(1.0) > 1e-9 {
                        return Err(format!("objective {o} vs reference {r}"));
                    }
                    Ok(())
                },
            );
        }
    }
}

#[test]
fn prop_soa_batch_evaluation_is_bit_identical_to_scalar() {
    // the solver's hot path scores candidates through the SoA lane
    // kernel; `jobs=N ≡ jobs=1` (and warm-cache replay) holds only if
    // every lane reproduces the scalar tape walk bit-for-bit — so the
    // comparison here is `to_bits`, not a tolerance
    let dev = Device::u200();
    for name in benchmarks::ALL {
        let k = benchmarks::build(name, kernel_size(name), DType::F32).unwrap();
        let a = Analysis::new(&k);
        let s = Space::new(&k, &a);
        let bm = sym::BoundModel::build(&k, &a, &dev);
        let cm = bm.compile();
        let mut scalar = cm.scratch();
        let mut soa = cm.soa_scratch();
        let mut out = Vec::new();
        Prop::new(16).check(
            &format!("soa-bit-identity/{name}"),
            |rng| {
                // odd sizes on purpose: 0 (empty batch), sub-lane, exact
                // multiples, and ragged remainders all take different
                // padding paths
                let len = rng.range(0, 21) as usize;
                (0..len)
                    .map(|_| random_design(rng, &k, &a, &s))
                    .collect::<Vec<Design>>()
            },
            |batch| {
                cm.evaluate_batch_soa_in(batch, &mut soa, &mut out);
                if out.len() != batch.len() {
                    return Err(format!("{} results for {} designs", out.len(), batch.len()));
                }
                for (i, (d, got)) in batch.iter().zip(&out).enumerate() {
                    let want = cm.evaluate(d, &mut scalar);
                    let fields = [
                        ("comp_cycles", want.comp_cycles, got.comp_cycles),
                        ("comm_cycles", want.comm_cycles, got.comm_cycles),
                        ("total_cycles", want.total_cycles, got.total_cycles),
                        ("dsp", want.dsp, got.dsp),
                        ("onchip_bytes", want.onchip_bytes, got.onchip_bytes),
                    ];
                    for (fname, w, g) in fields {
                        if w.to_bits() != g.to_bits() {
                            return Err(format!(
                                "lane {i} of {}: {fname} {g} != scalar {w} ({})",
                                batch.len(),
                                d.fingerprint()
                            ));
                        }
                    }
                    if want.max_partitioning != got.max_partitioning
                        || want.feasible != got.feasible
                    {
                        return Err(format!("lane {i}: discrete fields diverge"));
                    }
                }
                Ok(())
            },
        );
    }
}

#[test]
fn prop_batched_interval_bounds_are_bit_identical_to_scalar() {
    // the dispatcher's bound-ascending deal sorts on these values, so
    // the laned interval pass must agree with `lower_bound` exactly —
    // any drift would reorder the deal and change steal patterns
    let dev = Device::u200();
    for name in benchmarks::ALL {
        let k = benchmarks::build(name, kernel_size(name), DType::F32).unwrap();
        let a = Analysis::new(&k);
        let bm = sym::BoundModel::build(&k, &a, &dev);
        Prop::new(16).check(
            &format!("laned-bound-bit-identity/{name}"),
            |rng| {
                let len = rng.range(0, 19) as usize;
                (0..len)
                    .map(|_| {
                        let mut p = sym::PartialDesign::free(k.n_loops());
                        if rng.chance(0.5) {
                            p = p.with_uf_cap([1, 4, 16, 64, 512][rng.range(0, 5) as usize]);
                        }
                        for i in 0..k.n_loops() {
                            let l = LoopId(i as u32);
                            if rng.chance(0.2) {
                                p.assign_pipeline(l, rng.chance(0.5));
                            }
                            if rng.chance(0.2) {
                                p.assign_tile(l, 1);
                            }
                        }
                        p
                    })
                    .collect::<Vec<sym::PartialDesign>>()
            },
            |partials| {
                let batch = bm.lower_bound_batch(partials);
                if batch.len() != partials.len() {
                    return Err(format!(
                        "{} bounds for {} partials",
                        batch.len(),
                        partials.len()
                    ));
                }
                for (i, (p, &got)) in partials.iter().zip(&batch).enumerate() {
                    let want = bm.lower_bound(p);
                    if want.to_bits() != got.to_bits() {
                        return Err(format!("partial {i}: laned {got} != scalar {want}"));
                    }
                }
                Ok(())
            },
        );
    }
}

/// Enumerate a bounded sub-space of valid designs the way the solver's
/// brute-force comparison does: every pipeline config × an odometer over
/// the capped UF menus.
fn enumerate_designs(k: &Kernel, a: &Analysis, s: &Space, cap: u64, limit: usize) -> Vec<Design> {
    let mut out = Vec::new();
    let loops: Vec<LoopId> = (0..k.n_loops()).map(|i| LoopId(i as u32)).collect();
    for cfg in &s.pipeline_configs {
        let menus: Vec<Vec<u64>> = loops
            .iter()
            .map(|&l| {
                let m = s.ufs(l, a, cap);
                if m.is_empty() {
                    vec![1] // non-unrollable loop: UF pinned at 1
                } else {
                    m
                }
            })
            .collect();
        let mut idx = vec![0usize; menus.len()];
        'odometer: loop {
            let d = space::materialize(
                k,
                a,
                cfg,
                &|l| menus[l.0 as usize][idx[l.0 as usize]],
                &|_| 1,
            );
            out.push(d);
            if out.len() >= limit {
                return out;
            }
            let mut c = 0;
            loop {
                if c == menus.len() {
                    break 'odometer; // this config exhausted; next one
                }
                idx[c] += 1;
                if idx[c] < menus[c].len() {
                    break;
                }
                idx[c] = 0;
                c += 1;
            }
        }
    }
    out
}

#[test]
fn partial_bound_is_admissible_over_enumerated_subspace() {
    let dev = Device::u200();
    for name in ["gemm", "bicg", "atax"] {
        let k = benchmarks::build(name, Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let s = Space::new(&k, &a);
        let bm = sym::BoundModel::build(&k, &a, &dev);
        let free = sym::PartialDesign::free(k.n_loops());
        let lb = bm.lower_bound(&free);
        assert!(lb.is_finite() && lb > 0.0, "{name}: lb {lb}");
        let designs = enumerate_designs(&k, &a, &s, 64, 20_000);
        assert!(!designs.is_empty(), "{name}");
        for d in &designs {
            let r = model::evaluate(&k, &a, &dev, d);
            assert!(
                lb <= r.total_cycles * (1.0 + 1e-9),
                "{name}: empty-partial bound {lb} beats design {} ({})",
                r.total_cycles,
                d.fingerprint()
            );
        }
    }
}

#[test]
fn config_partial_bound_is_admissible_per_pipeline_config() {
    // fixing the pipeline antichain must still floor every design that
    // uses exactly that antichain
    let dev = Device::u200();
    let k = benchmarks::build("gemm", Size::Small, DType::F32).unwrap();
    let a = Analysis::new(&k);
    let s = Space::new(&k, &a);
    let bm = sym::BoundModel::build(&k, &a, &dev);
    for cfg in &s.pipeline_configs {
        let mut partial = sym::PartialDesign::free(k.n_loops());
        for i in 0..k.n_loops() {
            let l = LoopId(i as u32);
            partial.assign_pipeline(l, cfg.pipelined.contains(&l));
            partial.assign_tile(l, 1);
        }
        let lb = bm.lower_bound(&partial);
        let menus: Vec<Vec<u64>> = (0..k.n_loops())
            .map(|i| s.ufs(LoopId(i as u32), &a, 64))
            .collect();
        let mut rng = Rng::new(nlp_dse::util::rng::hash64(&format!("{cfg:?}")));
        for _ in 0..200 {
            let d = space::materialize(
                &k,
                &a,
                cfg,
                &|l| {
                    let m = &menus[l.0 as usize];
                    m[(rng.next_u64() % m.len() as u64) as usize]
                },
                &|_| 1,
            );
            let r = model::evaluate(&k, &a, &dev, &d);
            assert!(
                lb <= r.total_cycles * (1.0 + 1e-9),
                "cfg {:?}: bound {lb} beats {}",
                cfg.pipelined,
                r.total_cycles
            );
        }
    }
}

#[test]
fn interval_tightens_monotonically_with_assignments() {
    // pinning pragmas can only shrink the objective interval
    let dev = Device::u200();
    let k = benchmarks::build("2mm", Size::Small, DType::F32).unwrap();
    let a = Analysis::new(&k);
    let bm = sym::BoundModel::build(&k, &a, &dev);
    let free = sym::PartialDesign::free(k.n_loops());
    let iv_free = bm.objective_interval(&free);
    let mut partial = free.clone();
    for i in 0..k.n_loops() {
        partial.assign_pipeline(LoopId(i as u32), false);
        partial.assign_tile(LoopId(i as u32), 1);
        let iv = bm.objective_interval(&partial);
        assert!(
            iv.lo >= iv_free.lo - 1e-9 && iv.hi <= iv_free.hi + 1e-9,
            "step {i}: [{}, {}] escapes [{}, {}]",
            iv.lo,
            iv.hi,
            iv_free.lo,
            iv_free.hi
        );
    }
}
