//! Generative property suite for the direction/distance vector layer
//! of `poly/deps` — the legality substrate every `transform/` rewrite
//! is certified against. The targeted unit cases (anti/output deps,
//! distance-2 recurrences, triangular bounds, transposed `Any`s) live
//! next to the implementation in `src/poly/deps.rs`; this suite checks
//! the *structural invariants* that must hold on every kernel the
//! generator can produce:
//!
//! 1. a vector's entries span exactly the statement pair's shared nest,
//!    outermost first (the order every transform legality scan relies
//!    on);
//! 2. normalization: no vector leads with a negative constant distance
//!    (`src` is always the side executing first);
//! 3. a self-dependence is never the all-`=` vector (a statement
//!    instance does not depend on itself);
//! 4. the vector list is duplicate-free, and `vectors_between` finds
//!    every vector under its own endpoints;
//! 5. every vector's endpoints are marked dependent in the statement
//!    dependence matrix the `C` operator consumes.
//!
//! Failures panic with the reproducing seed and the offending `.knl`
//! text, mirroring `property_frontend_fuzz`.

use nlp_dse::frontend::{self, GenConfig};
use nlp_dse::ir::Kernel;
use nlp_dse::poly::deps::analyze;
use nlp_dse::poly::DirComp;
use nlp_dse::util::env_usize;

fn fuzz_n() -> usize {
    let n = if std::env::var("FUZZ_SMOKE").as_deref() == Ok("1") {
        env_usize("FUZZ_KERNELS", 16)
    } else {
        env_usize("FUZZ_KERNELS", 100)
    };
    n.max(1)
}

const BASE_SEED: u64 = 0xDE55_2026;

fn seeds(label: &str) -> Vec<u64> {
    let n = fuzz_n() as u64;
    let base: u64 = std::env::var("FUZZ_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(BASE_SEED)
        .min(u64::MAX - n);
    eprintln!("[fuzz:{label}] {n} kernels, seeds {base}..={}", base + n - 1);
    (base..base + n).collect()
}

fn fail(seed: u64, k: &Kernel, msg: &str) -> ! {
    panic!(
        "\n=== generative deps failure ===\n\
         seed: {seed}\n\
         replay: FUZZ_SEED={seed} FUZZ_KERNELS=1 cargo test --test property_deps\n\
         {msg}\n\
         --- offending kernel (.knl) ---\n{}",
        frontend::pretty::print(k)
    )
}

#[test]
fn prop_dir_vectors_span_shared_nests_and_normalize() {
    for seed in seeds("dir-vectors") {
        let k = frontend::generate(&GenConfig::sampled(seed));
        let da = analyze(&k);
        for (i, v) in da.dir_vectors.iter().enumerate() {
            // (1) entries = the pair's shared nest, outermost first.
            // Shared loops are common ancestors, so both statements see
            // them in the same root-to-leaf order.
            let src_nest = &k.stmt_meta(v.src).nest;
            let dst_nest = &k.stmt_meta(v.dst).nest;
            let shared: Vec<_> = src_nest
                .iter()
                .filter(|l| dst_nest.contains(l))
                .copied()
                .collect();
            let spanned: Vec<_> = v.entries.iter().map(|&(l, _)| l).collect();
            if spanned != shared {
                fail(
                    seed,
                    &k,
                    &format!("vector {v:?} spans {spanned:?}, shared nest is {shared:?}"),
                );
            }
            // (2) lexicographically non-negative: the leading non-`=`
            // component is never a negative constant
            let lead = v.entries.iter().find(|(_, c)| !c.is_eq());
            if let Some(&(_, DirComp::Dist(d))) = lead {
                if d <= 0 {
                    fail(seed, &k, &format!("lex-negative vector {v:?}"));
                }
            }
            // (3) a self-dependence must be carried by something
            if v.src == v.dst && v.loop_independent() {
                fail(seed, &k, &format!("all-`=` self-dependence {v:?}"));
            }
            // (4) duplicate-free, and findable under its endpoints
            if da.dir_vectors[i + 1..].contains(v) {
                fail(seed, &k, &format!("duplicate vector {v:?}"));
            }
            if !da.vectors_between(v.src, v.dst).any(|x| x == v) {
                fail(
                    seed,
                    &k,
                    &format!("vectors_between({:?}, {:?}) misses {v:?}", v.src, v.dst),
                );
            }
            // (5) endpoints agree with the statement dependence matrix
            if !da.stmts_dependent(v.src, v.dst) {
                fail(
                    seed,
                    &k,
                    &format!("vector {v:?} between statements the matrix calls independent"),
                );
            }
        }
    }
}

#[test]
fn prop_carrier_is_the_outermost_non_eq_level() {
    for seed in seeds("carriers") {
        let k = frontend::generate(&GenConfig::sampled(seed));
        let da = analyze(&k);
        for v in &da.dir_vectors {
            match v.carrier() {
                None => {
                    if !v.loop_independent() {
                        fail(seed, &k, &format!("carrier-less non-independent {v:?}"));
                    }
                }
                Some(c) => {
                    // everything outside (above) the carrier is `=`
                    for &(l, comp) in &v.entries {
                        if l == c {
                            if comp.is_eq() {
                                fail(seed, &k, &format!("`=` carrier in {v:?}"));
                            }
                            break;
                        }
                        if !comp.is_eq() {
                            fail(
                                seed,
                                &k,
                                &format!("non-`=` level above the carrier in {v:?}"),
                            );
                        }
                    }
                }
            }
        }
    }
}
