//! System-mode integration suite (ISSUE 9 acceptance):
//!
//! * the epsilon-grid archive is **merge-order invariant** — archiving
//!   any partition of a point set, unioning, and re-archiving yields
//!   bit-identical fronts regardless of the partition or order (the
//!   property `nlp::front`'s module docs argue; this suite proves it on
//!   seeded random sets);
//! * per-kernel front extraction is **bit-reproducible across `jobs ∈
//!   {1, 2, 4, 8}`** — same designs, bit-equal metrics;
//! * the budget allocation **matches the brute-force oracle** on
//!   ≤ 3-kernel × ≤ 8-point instances built from *real* solver fronts,
//!   and every returned allocation is budget-feasible.

use nlp_dse::benchmarks::{self, Size};
use nlp_dse::hls::Device;
use nlp_dse::ir::DType;
use nlp_dse::nlp::front::{archive, canonical_cmp};
use nlp_dse::nlp::{self, FrontConfig, FrontPoint, NlpProblem, SymbolicEvaluator};
use nlp_dse::poly::Analysis;
use nlp_dse::pragma::Design;
use nlp_dse::system::{allocate, allocate_brute, solve_system, KernelFront, SystemConfig};
use nlp_dse::util::rng::Rng;

const BUDGET_S: f64 = 300.0;

fn assert_fronts_bit_identical(ctx: &str, a: &[FrontPoint], b: &[FrontPoint]) {
    assert_eq!(a.len(), b.len(), "{ctx}: front size");
    for (i, (p, q)) in a.iter().zip(b).enumerate() {
        assert_eq!(p.design, q.design, "{ctx}: design #{i}");
        assert_eq!(p.latency.to_bits(), q.latency.to_bits(), "{ctx}: latency #{i}");
        assert_eq!(p.risk.to_bits(), q.risk.to_bits(), "{ctx}: risk #{i}");
        assert_eq!(p.dsp.to_bits(), q.dsp.to_bits(), "{ctx}: dsp #{i}");
        assert_eq!(
            p.onchip_bytes.to_bits(),
            q.onchip_bytes.to_bits(),
            "{ctx}: onchip #{i}"
        );
        assert_eq!(p.lut.to_bits(), q.lut.to_bits(), "{ctx}: lut #{i}");
    }
}

/// Random front points over a tiny design payload (the archive never
/// looks inside the design; metrics drive everything).
fn random_points(k: &nlp_dse::Kernel, n: usize, rng: &mut Rng) -> Vec<FrontPoint> {
    (0..n)
        .map(|_| {
            let mut span = |lo: f64, hi: f64| lo + (rng.next_u64() % 256) as f64 / 256.0 * (hi - lo);
            FrontPoint {
                design: Design::empty(k),
                latency: span(1e2, 1e5),
                risk: span(0.0, 1.0),
                dsp: span(8.0, 2048.0),
                onchip_bytes: span(1e3, 2e6),
                lut: span(1e3, 5e5),
            }
        })
        .collect()
}

#[test]
fn prop_archive_is_invariant_under_arbitrary_merge_partitions() {
    let k = benchmarks::kernel_gemm(4, 4, 4, DType::F32);
    for seed in 0..40u64 {
        let mut rng = Rng::new(0xF407 + seed);
        let n = 8 + (rng.next_u64() % 48) as usize;
        let epsilon = [0.0, 0.02, 0.1][(rng.next_u64() % 3) as usize];
        let points = random_points(&k, n, &mut rng);
        let whole = archive(points.clone(), epsilon);
        // split into 1..=4 random chunks, archive each, merge in a
        // rotated order, re-archive: must be bit-identical to the
        // single-shot archive of the full set
        let chunks = 1 + (rng.next_u64() % 4) as usize;
        let mut parts: Vec<Vec<FrontPoint>> = vec![Vec::new(); chunks];
        for p in points {
            let c = (rng.next_u64() % chunks as u64) as usize;
            parts[c].push(p);
        }
        let rot = (rng.next_u64() % chunks as u64) as usize;
        parts.rotate_left(rot);
        let mut merged = Vec::new();
        for part in parts {
            merged.extend(archive(part, epsilon));
        }
        let remerged = archive(merged, epsilon);
        assert_fronts_bit_identical(
            &format!("seed {seed} eps {epsilon} chunks {chunks} rot {rot}"),
            &whole,
            &remerged,
        );
        // the archive is canonically sorted and duplicate-free
        for w in whole.windows(2) {
            assert_eq!(
                canonical_cmp(&w[0], &w[1]),
                std::cmp::Ordering::Less,
                "seed {seed}: canonical order must be strict"
            );
        }
    }
}

#[test]
fn front_extraction_is_bit_reproducible_across_jobs() {
    let dev = Device::u200();
    let fc = FrontConfig {
        epsilon: 0.05,
        max_points: 8,
    };
    for name in ["gemm", "bicg"] {
        let k = benchmarks::lookup(name, Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let p = NlpProblem::new(&k, &a, &dev, 64, false);
        let base = nlp::solve_front(&p, BUDGET_S, &fc, &SymbolicEvaluator, 1);
        assert!(base.optimal, "{name}: front solve must complete in budget");
        assert!(!base.points.is_empty(), "{name}: front must be non-empty");
        assert!(base.points.len() <= fc.max_points);
        for jobs in [2usize, 4, 8] {
            let r = nlp::solve_front(&p, BUDGET_S, &fc, &SymbolicEvaluator, jobs);
            assert_eq!(r.jobs, jobs);
            assert_eq!(
                r.stats.configs, base.stats.configs,
                "{name} jobs={jobs}: exhaustive accounting"
            );
            assert_fronts_bit_identical(&format!("{name} jobs={jobs}"), &base.points, &r.points);
        }
    }
}

/// Shrink a real solver front to at most `cap` points so brute force
/// stays cheap; keeps canonical order and the gflops pairing.
fn truncated(mut kf: KernelFront, cap: usize) -> KernelFront {
    kf.front.truncate(cap);
    kf.gflops.truncate(cap);
    kf
}

#[test]
fn allocation_matches_brute_force_on_real_fronts() {
    let dev = Device::u200();
    let cfg = SystemConfig {
        front: FrontConfig {
            epsilon: 0.02,
            max_points: 8,
        },
        cap: 64,
        timeout_s: BUDGET_S,
        jobs: 1,
    };
    let names = ["gemm", "bicg", "atax"];
    let kernels: Vec<(String, nlp_dse::Kernel)> = names
        .iter()
        .map(|n| {
            (
                n.to_string(),
                benchmarks::lookup(n, Size::Small, DType::F32).unwrap(),
            )
        })
        .collect();
    let out = solve_system(&kernels, &dev, &cfg, &SymbolicEvaluator);
    assert_eq!(out.kernels.len(), 3);
    for kf in &out.kernels {
        assert!(kf.optimal, "{}: per-kernel solve must complete", kf.name);
        assert!(!kf.front.is_empty() && kf.front.len() <= 8, "{}", kf.name);
    }

    // cross-check b&b against the oracle on every subset of the three
    // real fronts, at the full budget and at artificially tight ones
    let tight = {
        let mut d = dev.clone();
        d.dsp_total /= 8;
        d.onchip_bytes /= 8;
        d.lut_total /= 8;
        d
    };
    for mask in 1u32..8 {
        let subset: Vec<KernelFront> = out
            .kernels
            .iter()
            .enumerate()
            .filter(|(i, _)| mask & (1 << i) != 0)
            .map(|(_, kf)| truncated(kf.clone(), 8))
            .collect();
        for d in [&dev, &tight] {
            let bb = allocate(&subset, d);
            let bf = allocate_brute(&subset, d);
            let ctx = format!("mask {mask} dev {}", d.dsp_total);
            match (&bb.best, &bf.best) {
                (None, None) => {}
                (Some(b), Some(f)) => {
                    assert_eq!(b.choice, f.choice, "{ctx}: choice");
                    assert_eq!(b.gflops.to_bits(), f.gflops.to_bits(), "{ctx}: gflops");
                    assert!(b.dsp <= d.dsp_total as f64, "{ctx}: dsp budget");
                    assert!(b.onchip_bytes <= d.onchip_bytes as f64, "{ctx}: onchip");
                    assert!(b.lut <= d.lut_total as f64, "{ctx}: lut budget");
                }
                (bb, bf) => panic!("{ctx}: feasibility diverged ({bb:?} vs {bf:?})"),
            }
            assert!(
                bb.nodes <= bf.nodes.max(1) * (subset.len() as u64 + 1),
                "{ctx}: b&b explored more than brute force"
            );
        }
    }
}
