//! End-to-end tests of the DSE-as-a-service daemon over real TCP
//! sockets (ISSUE 6 satellite): solve/bound/emit round-trips, inline
//! parse errors keeping the caret diagnostic inside the JSON error
//! payload, concurrent clients, the shutdown drain guarantee (a solve
//! in flight when another client requests shutdown still delivers its
//! result), and the acceptance criterion — a repeated
//! structurally-identical solve is answered from the cache
//! bit-identically with `cache: "hit"`, and `stats` reports a nonzero
//! hit rate. The transform satellite rides here too: the same kernel
//! with and without `"transform"` gets distinct exact cache keys
//! (spaced fingerprints), both replay bit-identically, and the per-op
//! `hit`/`warm`/`miss` counters land in the `stats` payload.
//!
//! Each test spawns its own daemon on an ephemeral port
//! (`127.0.0.1:0`), so the suite is parallel-safe and needs no free
//! well-known port.

use nlp_dse::serve::{spawn, ServeConfig, ServerHandle};
use nlp_dse::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

fn daemon() -> ServerHandle {
    spawn(
        "127.0.0.1:0",
        ServeConfig {
            jobs: 1,
            cache_entries: 16,
        },
        2,
    )
    .expect("spawn daemon")
}

/// One connection, one request line; collect events until the terminal
/// `result`/`error` line arrives. Progress lines ride along in order.
fn request(h: &ServerHandle, line: &str) -> Vec<Json> {
    let mut s = TcpStream::connect(h.addr()).expect("connect");
    writeln!(s, "{line}").unwrap();
    read_events(&mut BufReader::new(s), 1)
}

/// Read events until `terminals` result/error lines have arrived.
fn read_events(r: &mut impl BufRead, terminals: usize) -> Vec<Json> {
    let mut out = Vec::new();
    let mut seen = 0usize;
    let mut buf = String::new();
    while seen < terminals {
        buf.clear();
        if r.read_line(&mut buf).expect("read") == 0 {
            panic!("connection closed after {seen}/{terminals} terminal events: {out:?}");
        }
        let j = Json::parse(buf.trim()).unwrap_or_else(|e| panic!("bad line `{buf}`: {e}"));
        if matches!(
            j.get("event").and_then(|x| x.as_str()),
            Some("result") | Some("error")
        ) {
            seen += 1;
        }
        out.push(j);
    }
    out
}

fn terminal(events: &[Json]) -> &Json {
    events.last().expect("at least one event")
}

#[test]
fn solve_bound_and_emit_round_trip() {
    let h = daemon();

    let ev = request(&h, r#"{"op":"solve","kernel":"gemm","size":"S","cap":16,"id":1}"#);
    let r = terminal(&ev);
    assert_eq!(r.get("event").and_then(|x| x.as_str()), Some("result"));
    assert_eq!(r.get("id").and_then(|x| x.as_u64()), Some(1));
    assert_eq!(r.get("cache").and_then(|x| x.as_str()), Some("miss"));
    let data = r.get("data").unwrap();
    assert_eq!(data.get("optimal").and_then(|x| x.as_bool()), Some(true));
    assert!(!data.get("designs").and_then(|x| x.as_arr()).unwrap().is_empty());
    // the miss emitted a progress line before the result
    assert!(ev
        .iter()
        .any(|e| e.get("event").and_then(|x| x.as_str()) == Some("progress")));

    let ev = request(
        &h,
        r#"{"op":"bound","kernel":"gemm","size":"S","assign":{"i":4},"pipeline":["j1"],"id":2}"#,
    );
    let r = terminal(&ev);
    assert_eq!(r.get("event").and_then(|x| x.as_str()), Some("result"));
    let data = r.get("data").unwrap();
    assert!(data.get("lower_bound_cycles").and_then(|x| x.as_f64()).unwrap() > 0.0);
    assert!(data.get("free_slots").and_then(|x| x.as_u64()).unwrap() > 0);

    let ev = request(
        &h,
        r#"{"op":"emit","kernel":"gemm","size":"S","assign":{"k":8},"pipeline":["j1"],"id":3}"#,
    );
    let r = terminal(&ev);
    assert_eq!(r.get("event").and_then(|x| x.as_str()), Some("result"));
    let code = r.get("data").unwrap().get("code").and_then(|x| x.as_str()).unwrap();
    assert!(code.contains("#pragma ACCEL"), "{code}");
    assert!(code.contains("void kernel_gemm("), "{code}");

    h.shutdown();
    h.join();
}

#[test]
fn malformed_inline_kernel_reports_the_caret_snippet_in_json() {
    let h = daemon();
    // line 4 of the inline text references an unknown identifier; the
    // frontend's rendered caret diagnostic must survive into the error
    // payload (the `\n`s below are JSON escapes inside the request line)
    let bad = "kernel \\\"b\\\" f32\\narray a[4] out\\nfor i in 0 .. 4 {\\n  stmt s writes a[zz];\\n}\\n";
    let ev = request(
        &h,
        &format!(r#"{{"op":"solve","knl":"{bad}","id":"e1"}}"#),
    );
    let r = terminal(&ev);
    assert_eq!(r.get("event").and_then(|x| x.as_str()), Some("error"));
    assert_eq!(r.get("id").and_then(|x| x.as_str()), Some("e1"));
    let msg = r.get("message").and_then(|x| x.as_str()).unwrap();
    assert!(msg.contains("parsing inline kernel"), "{msg}");
    let diag = r.get("diagnostic").and_then(|x| x.as_str()).expect("diagnostic field");
    assert!(diag.contains("<request>:4:"), "{diag}");
    assert!(diag.contains("stmt s writes a[zz];"), "{diag}");
    assert!(diag.contains('^'), "{diag}");
    h.shutdown();
    h.join();
}

#[test]
fn concurrent_clients_each_get_their_answers() {
    let h = daemon();
    let addr = h.addr();
    let kernels = ["gemm", "atax", "bicg", "mvt"];
    let mut threads = Vec::new();
    for (i, name) in kernels.iter().enumerate() {
        let name = name.to_string();
        threads.push(std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).expect("connect");
            writeln!(
                s,
                r#"{{"op":"solve","kernel":"{name}","size":"S","cap":8,"id":{i}}}"#
            )
            .unwrap();
            let ev = read_events(&mut BufReader::new(s), 1);
            let r = ev.last().unwrap().clone();
            assert_eq!(r.get("event").and_then(|x| x.as_str()), Some("result"), "{name}");
            assert_eq!(r.get("id").and_then(|x| x.as_u64()), Some(i as u64), "{name}");
            r.get("data").unwrap().get("kernel").and_then(|x| x.as_str()).unwrap().to_string()
        }));
    }
    let answered: Vec<String> = threads.into_iter().map(|t| t.join().unwrap()).collect();
    for name in kernels {
        assert!(answered.iter().any(|a| a == name), "{name} missing: {answered:?}");
    }
    h.shutdown();
    h.join();
}

#[test]
fn shutdown_drains_in_flight_solves_before_exit() {
    let h = daemon();
    // client A starts a cold solve and waits for its progress line, so
    // the job is provably running on the worker pool...
    let mut a = TcpStream::connect(h.addr()).expect("connect");
    writeln!(a, r#"{{"op":"solve","kernel":"2mm","size":"S","cap":16,"id":"A"}}"#).unwrap();
    let mut ra = BufReader::new(a.try_clone().unwrap());
    let mut buf = String::new();
    loop {
        buf.clear();
        assert!(ra.read_line(&mut buf).expect("read") > 0, "daemon closed before progress");
        let j = Json::parse(buf.trim()).unwrap();
        if j.get("event").and_then(|x| x.as_str()) == Some("progress") {
            break;
        }
    }
    // ...while client B shuts the daemon down
    let ev = request(&h, r#"{"op":"shutdown","id":"B"}"#);
    assert_eq!(terminal(&ev).get("event").and_then(|x| x.as_str()), Some("result"));
    // the drain guarantee: A's solve completes and its result arrives
    // even though A's connection outlives the accept loop
    let ev = read_events(&mut ra, 1);
    let r = terminal(&ev);
    assert_eq!(r.get("event").and_then(|x| x.as_str()), Some("result"));
    assert_eq!(r.get("id").and_then(|x| x.as_str()), Some("A"));
    h.join();
}

#[test]
fn transform_dse_partitions_the_cache_and_replays_bit_identically() {
    let h = daemon();
    let plain = r#"{"op":"dse","kernel":"mvt","size":"S","jobs":1,"id":20}"#;
    let with_t = r#"{"op":"dse","kernel":"mvt","size":"S","jobs":1,"transform":true,"max_variants":2,"id":21}"#;
    // the plain exploration runs cold, then replays from the cache
    let p1 = request(&h, plain);
    let p2 = request(&h, plain);
    assert_eq!(terminal(&p1).get("cache").and_then(|x| x.as_str()), Some("miss"));
    assert_eq!(terminal(&p2).get("cache").and_then(|x| x.as_str()), Some("hit"));
    assert_eq!(
        terminal(&p1).get("data").unwrap().to_line(),
        terminal(&p2).get("data").unwrap().to_line(),
        "dse replay must be bit-identical"
    );
    // the same kernel with `transform` has a distinct exact cache key
    // (spaced fingerprint): it must run cold, not replay the plain run
    let t1 = request(&h, with_t);
    assert_eq!(terminal(&t1).get("cache").and_then(|x| x.as_str()), Some("miss"));
    let d = terminal(&t1).get("data").unwrap();
    assert_eq!(d.get("engine").and_then(|x| x.as_str()), Some("transform"));
    assert!(!d.get("variants").and_then(|x| x.as_arr()).unwrap().is_empty());
    let t2 = request(&h, with_t);
    assert_eq!(terminal(&t2).get("cache").and_then(|x| x.as_str()), Some("hit"));
    assert_eq!(
        terminal(&t1).get("data").unwrap().to_line(),
        terminal(&t2).get("data").unwrap().to_line(),
        "transform replay must be bit-identical"
    );
    // the new per-op hit/warm/miss counters see all four requests
    let ev = request(&h, r#"{"op":"stats","id":22}"#);
    let data = terminal(&ev).get("data").unwrap().clone();
    let dse = data.get("ops").unwrap().get("dse").expect("dse op stats");
    let c = dse.get("cache").expect("per-op cache counters");
    assert_eq!(c.get("hit").and_then(|x| x.as_u64()), Some(2));
    assert_eq!(c.get("miss").and_then(|x| x.as_u64()), Some(2));
    assert_eq!(c.get("warm").and_then(|x| x.as_u64()), Some(0));
    // both spaces live side by side in the replay map
    let entries = data.get("cache").unwrap().get("entries").unwrap();
    assert_eq!(entries.get("dses").and_then(|x| x.as_u64()), Some(2));
    h.shutdown();
    h.join();
}

#[test]
fn repeated_solve_hits_the_cache_bit_identically_and_stats_sees_it() {
    let h = daemon();
    // two identical solves: the second must replay the first from the
    // solve cache, not recompute
    let req = r#"{"op":"solve","kernel":"gemm","size":"S","cap":16,"id":10}"#;
    let first = request(&h, req);
    let second = request(&h, req);
    let r1 = terminal(&first);
    let r2 = terminal(&second);
    assert_eq!(r1.get("cache").and_then(|x| x.as_str()), Some("miss"));
    assert_eq!(r2.get("cache").and_then(|x| x.as_str()), Some("hit"));
    assert_eq!(
        r1.get("data").unwrap().to_line(),
        r2.get("data").unwrap().to_line(),
        "cache replay must be bit-identical"
    );
    // a cache hit answers without a progress (solving) line
    assert_eq!(second.len(), 1, "{second:?}");

    let ev = request(&h, r#"{"op":"stats","id":11}"#);
    let data = terminal(&ev).get("data").unwrap().clone();
    let cache = data.get("cache").unwrap();
    assert_eq!(cache.get("hits").and_then(|x| x.as_u64()), Some(1));
    assert!(
        cache.get("hit_rate").and_then(|x| x.as_f64()).unwrap() > 0.0,
        "nonzero hit rate required: {data:?}"
    );
    let solve_ops = data.get("ops").unwrap().get("solve").unwrap();
    assert_eq!(solve_ops.get("count").and_then(|x| x.as_u64()), Some(2));

    // `emit --design_from solve` reuses the cached solve and says so
    let ev = request(
        &h,
        r#"{"op":"emit","kernel":"gemm","size":"S","cap":16,"design_from":"solve","id":12}"#,
    );
    let r = terminal(&ev);
    assert_eq!(r.get("event").and_then(|x| x.as_str()), Some("result"));
    assert_eq!(r.get("cache").and_then(|x| x.as_str()), Some("hit"));

    // the `shutdown` op answers, then the daemon exits on its own
    let ev = request(&h, r#"{"op":"shutdown","id":13}"#);
    assert_eq!(
        terminal(&ev).get("event").and_then(|x| x.as_str()),
        Some("result")
    );
    let addr = h.addr();
    h.join();
    assert!(TcpStream::connect(addr).is_err(), "listener must be gone");
}
