//! Property-based tests over the DESIGN.md §6 invariants, using the
//! in-repo mini property-testing driver (`util::proptest`).

use nlp_dse::benchmarks::{self, Size};
use nlp_dse::hls::{Device, HlsOracle};
use nlp_dse::ir::{DType, Kernel, LoopId};
use nlp_dse::model;
use nlp_dse::nlp::{self, NlpProblem, RustFeatureEvaluator};
use nlp_dse::poly::Analysis;
use nlp_dse::pragma::{space, Design, Space};
use nlp_dse::util::proptest::Prop;
use nlp_dse::util::rng::Rng;

const KERNELS: [&str; 8] = [
    "gemm", "2mm", "bicg", "atax", "mvt", "gesummv", "syrk", "doitgen",
];

/// Draw a random *legal* design (pipeline antichain + divisor UFs).
fn random_design(rng: &mut Rng, k: &Kernel, a: &Analysis, s: &Space) -> Design {
    let cfg = s
        .pipeline_configs
        .get(rng.range(0, s.pipeline_configs.len() as u64) as usize)
        .unwrap()
        .clone();
    let drawn: Vec<u64> = (0..k.n_loops())
        .map(|i| {
            let menu = s.ufs(LoopId(i as u32), a, 1024);
            if menu.is_empty() {
                1
            } else {
                menu[rng.range(0, menu.len() as u64) as usize]
            }
        })
        .collect();
    space::materialize(k, a, &cfg, &|l| drawn[l.0 as usize], &|_| 1)
}

#[test]
fn prop_lower_bound_vs_oracle() {
    // Invariant 1: model LB ≤ oracle latency for every valid non-flatten
    // synthesis, across random legal designs.
    let dev = Device::u200();
    let oracle = HlsOracle::new(dev.clone());
    for name in KERNELS {
        let k = benchmarks::build(name, Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let s = Space::new(&k, &a);
        Prop::new(48).check(
            &format!("lb-vs-oracle/{name}"),
            |rng| random_design(rng, &k, &a, &s).fingerprint(),
            |fp| {
                // regenerate from fingerprint-compatible draw: use the same
                // rng seed path by drawing again; simpler: rebuild design
                // from a fresh rng seeded by the fingerprint hash
                let mut rng = Rng::new(nlp_dse::util::rng::hash64(fp));
                let d = random_design(&mut rng, &k, &a, &s);
                let lb = model::evaluate(&k, &a, &dev, &d);
                let rep = oracle.synth(&k, &a, &d);
                if !rep.valid || rep.flattened {
                    return Ok(());
                }
                if rep.cycles >= lb.total_cycles * 0.999 {
                    Ok(())
                } else {
                    Err(format!(
                        "measured {} < bound {} for {}",
                        rep.cycles,
                        lb.total_cycles,
                        d.fingerprint()
                    ))
                }
            },
        );
    }
}

#[test]
fn prop_feature_encoding_under_precise() {
    // Invariant 3 (one side): encoded formula ≤ precise model.
    let dev = Device::u200();
    for name in KERNELS {
        let k = benchmarks::build(name, Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let s = Space::new(&k, &a);
        Prop::new(48).check(
            &format!("features-le-precise/{name}"),
            |rng| random_design(rng, &k, &a, &s),
            |d| {
                let Some(f) = model::encode_design(&k, &a, &dev, d) else {
                    return Ok(());
                };
                let (lat, _) = model::eval_features(&f);
                let precise = model::evaluate(&k, &a, &dev, d).total_cycles;
                if lat <= precise * 1.02 + 1.0 {
                    Ok(())
                } else {
                    Err(format!("features {lat} > precise {precise}"))
                }
            },
        );
    }
}

#[test]
fn prop_oracle_determinism() {
    // Invariant 6: identical designs → identical reports.
    let dev = Device::u200();
    let oracle = HlsOracle::new(dev.clone());
    for name in ["gemm", "2mm"] {
        let k = benchmarks::build(name, Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let s = Space::new(&k, &a);
        Prop::new(24).check(
            &format!("oracle-deterministic/{name}"),
            |rng| random_design(rng, &k, &a, &s),
            |d| {
                let r1 = oracle.synth(&k, &a, d);
                let r2 = oracle.synth(&k, &a, d);
                if r1.cycles == r2.cycles
                    && r1.synth_minutes == r2.synth_minutes
                    && r1.valid == r2.valid
                {
                    Ok(())
                } else {
                    Err("non-deterministic report".into())
                }
            },
        );
    }
}

#[test]
fn prop_solver_never_beats_relaxation() {
    // the solver's returned objective can never be below its own proven
    // lower bound (anytime-soundness)
    let dev = Device::u200();
    Prop::new(12).check(
        "solver-anytime-sound",
        |rng| {
            let name = *rng.choose(&KERNELS);
            let cap = *rng.choose(&[8u64, 64, 256, 1024]);
            (name, cap)
        },
        |&(name, cap)| {
            let k = benchmarks::build(name, Size::Small, DType::F32).unwrap();
            let a = Analysis::new(&k);
            let p = NlpProblem::new(&k, &a, &dev, cap, false);
            let r = nlp::solve(&p, 10.0, 1, &RustFeatureEvaluator);
            match r.best() {
                Some((_, obj)) => {
                    if *obj >= r.lower_bound - 1.0 {
                        Ok(())
                    } else {
                        Err(format!("obj {obj} < proven lb {}", r.lower_bound))
                    }
                }
                None => Ok(()),
            }
        },
    );
}

#[test]
fn prop_pruning_safety() {
    // Invariant 5: any design whose LB exceeds a measured latency is
    // really never better when force-synthesized.
    let dev = Device::u200();
    let oracle = HlsOracle::new(dev.clone());
    for name in ["gemm", "bicg"] {
        let k = benchmarks::build(name, Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let s = Space::new(&k, &a);
        // a reference measurement
        let mut dref = Design::empty(&k);
        for i in 0..k.n_loops() {
            if k.loop_meta(LoopId(i as u32)).innermost {
                dref.get_mut(LoopId(i as u32)).pipeline = true;
                break;
            }
        }
        let ref_rep = oracle.synth(&k, &a, &dref);
        assert!(ref_rep.valid);
        Prop::new(48).check(
            &format!("pruning-safe/{name}"),
            |rng| random_design(rng, &k, &a, &s),
            |d| {
                let lb = model::evaluate(&k, &a, &dev, d).total_cycles;
                if lb < ref_rep.cycles {
                    return Ok(()); // not pruned
                }
                let rep = oracle.synth(&k, &a, d);
                if !rep.valid || rep.flattened {
                    return Ok(());
                }
                if rep.cycles >= ref_rep.cycles * 0.999 {
                    Ok(())
                } else {
                    Err(format!(
                        "pruned design measured {} beats reference {}",
                        rep.cycles, ref_rep.cycles
                    ))
                }
            },
        );
    }
}

#[test]
fn prop_partitioning_merge_monotone() {
    // partitioning grows monotonically with UFs (the solver's pruning
    // assumption)
    for name in KERNELS {
        let k = benchmarks::build(name, Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let s = Space::new(&k, &a);
        Prop::new(32).check(
            &format!("partition-monotone/{name}"),
            |rng| {
                let d = random_design(rng, &k, &a, &s);
                let li = rng.range(0, k.n_loops() as u64) as usize;
                (d, li)
            },
            |(d, li)| {
                let base = d.max_partitioning(&k);
                let mut d2 = d.clone();
                let tc = &a.tcs[*li];
                if !tc.is_constant() {
                    return Ok(());
                }
                d2.pragmas[*li].uf = tc.max.max(1);
                let grown = d2.max_partitioning(&k);
                if grown >= base {
                    Ok(())
                } else {
                    Err(format!("partitioning shrank {base} -> {grown}"))
                }
            },
        );
    }
}
