//! Cross-module model integration: analytical model ↔ Merlin ↔ HLS oracle
//! over the whole benchmark suite.

use nlp_dse::benchmarks::{self, Size};
use nlp_dse::hls::{Device, HlsOracle};
use nlp_dse::ir::{DType, LoopId};
use nlp_dse::model;
use nlp_dse::poly::Analysis;
use nlp_dse::pragma::{Design, Space};

fn sizes_for(name: &str) -> Vec<Size> {
    if name == "cnn" {
        vec![Size::Medium]
    } else {
        vec![Size::Small, Size::Medium]
    }
}

#[test]
fn lower_bound_holds_for_empty_designs_full_suite() {
    let dev = Device::u200();
    let oracle = HlsOracle::new(dev.clone());
    for name in benchmarks::ALL {
        for size in sizes_for(name) {
            let k = benchmarks::build(name, size, DType::F32).unwrap();
            let a = Analysis::new(&k);
            let d = Design::empty(&k);
            let lb = model::evaluate(&k, &a, &dev, &d);
            let rep = oracle.synth(&k, &a, &d);
            assert!(rep.valid, "{name}-{size:?}: empty design must synthesize");
            assert!(
                rep.flattened || rep.cycles >= lb.total_cycles * 0.999,
                "{name}-{size:?}: measured {} < bound {}",
                rep.cycles,
                lb.total_cycles
            );
        }
    }
}

#[test]
fn lower_bound_holds_for_pipelined_designs_full_suite() {
    let dev = Device::u200();
    let oracle = HlsOracle::new(dev.clone());
    for name in benchmarks::ALL {
        let k = benchmarks::build(name, Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        // pipeline every innermost loop with a modest unroll
        for i in 0..k.n_loops() {
            let l = LoopId(i as u32);
            if !k.loop_meta(l).innermost {
                continue;
            }
            let mut d = Design::empty(&k);
            d.get_mut(l).pipeline = true;
            let tc = &a.tcs[i];
            if tc.is_constant() && tc.max % 2 == 0 && !a.deps.per_loop[i].serializing {
                d.get_mut(l).uf = 2;
            }
            let lb = model::evaluate(&k, &a, &dev, &d);
            let rep = oracle.synth(&k, &a, &d);
            if !rep.valid || rep.flattened {
                continue;
            }
            assert!(
                rep.cycles >= lb.total_cycles * 0.999,
                "{name} L{i}: measured {} < bound {}",
                rep.cycles,
                lb.total_cycles
            );
        }
    }
}

#[test]
fn model_monotone_in_fine_grained_unroll() {
    // more fine-grained parallelism on the pipelined innermost loop never
    // raises the bound
    let dev = Device::u200();
    for name in ["gemm", "bicg", "gesummv", "mvt", "doitgen"] {
        let k = benchmarks::build(name, Size::Medium, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let space = Space::new(&k, &a);
        for i in 0..k.n_loops() {
            let l = LoopId(i as u32);
            if !k.loop_meta(l).innermost {
                continue;
            }
            let mut prev = f64::INFINITY;
            for uf in space.ufs(l, &a, u64::MAX) {
                let mut d = Design::empty(&k);
                d.get_mut(l).pipeline = true;
                d.get_mut(l).uf = uf;
                let r = model::evaluate(&k, &a, &dev, &d);
                assert!(
                    r.comp_cycles <= prev * 1.0001,
                    "{name} L{i} uf={uf}: {} > {prev}",
                    r.comp_cycles
                );
                prev = r.comp_cycles;
            }
        }
    }
}

#[test]
fn feature_encoding_stays_lower_bound_suite_wide() {
    // encoded-formula evaluation ≤ precise model (documented
    // under-approximation), across the suite and several designs
    let dev = Device::u200();
    for name in benchmarks::ALL {
        let k = benchmarks::build(name, Size::Small, DType::F32)
            .or_else(|| benchmarks::build(name, Size::Medium, DType::F32))
            .unwrap();
        let a = Analysis::new(&k);
        let mut designs = vec![Design::empty(&k)];
        for i in 0..k.n_loops() {
            if k.loop_meta(LoopId(i as u32)).innermost {
                let mut d = Design::empty(&k);
                d.get_mut(LoopId(i as u32)).pipeline = true;
                designs.push(d);
            }
        }
        for d in &designs {
            let Some(f) = model::encode_design(&k, &a, &dev, d) else {
                continue;
            };
            let (lat, _) = model::eval_features(&f);
            let precise = model::evaluate(&k, &a, &dev, d).total_cycles;
            assert!(
                lat <= precise * 1.02 + 1.0,
                "{name}: features {lat} > precise {precise}"
            );
        }
    }
}

#[test]
fn dsp_accounting_consistent_between_paths() {
    let dev = Device::u200();
    for name in ["gemm", "2mm", "syrk"] {
        let k = benchmarks::build(name, Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        for i in 0..k.n_loops() {
            let l = LoopId(i as u32);
            if !k.loop_meta(l).innermost {
                continue;
            }
            let mut d = Design::empty(&k);
            d.get_mut(l).pipeline = true;
            d.get_mut(l).uf = a.tcs[i].max.max(1);
            let precise = model::evaluate(&k, &a, &dev, &d);
            if let Some(f) = model::encode_design(&k, &a, &dev, &d) {
                let (_, dsp) = model::eval_features(&f);
                assert!(
                    dsp <= precise.dsp * 1.01 + 1.0,
                    "{name} L{i}: feature dsp {dsp} > precise {}",
                    precise.dsp
                );
            }
        }
    }
}

#[test]
fn gramschmidt_triangular_latency_sane() {
    // triangular loops must use TC_avg, not TC_max: total iterations of
    // the j-loop body ≈ N²/2, not N²
    let dev = Device::u200();
    let k = benchmarks::build("gramschmidt", Size::Small, DType::F32).unwrap();
    let a = Analysis::new(&k);
    let d = Design::empty(&k);
    let r = model::evaluate(&k, &a, &dev, &d);
    // N=80, M=60: full-rectangular accounting would give ≥ N*N*M = 384k
    // pipeline starts on S5 alone; the triangular average halves it
    assert!(r.comp_cycles < 80.0 * 80.0 * 60.0 * 4.0, "{}", r.comp_cycles);
    assert!(r.comp_cycles > 80.0 * 40.0 * 60.0 * 0.5, "{}", r.comp_cycles);
}
