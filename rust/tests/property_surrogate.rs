//! Differential-fuzz gate for the learned surrogate (ISSUE 10): the
//! four committed properties, over the seeded random-kernel generator
//! where they are statements about *every* kernel, not just PolyBench:
//!
//! * **(a) reproducible training** — two trainings from one seed are
//!   bit-identical (same weights, same canonical JSON, same content
//!   hash), and the artifact survives a save/load round trip exactly;
//! * **(b) committed rank floor** — held-out Spearman rank correlation
//!   between predicted and exact ln-latency exceeds [`SPEARMAN_FLOOR`],
//!   on the training corpus's holdout split *and* on designs drawn from
//!   freshly generated kernels the fit never saw;
//! * **(c) exact-scored incumbents** — whatever the rank cut does, the
//!   engine's reported best is re-scored by the exact compiled model
//!   (matching `model::evaluate` to 1e-9 relative), is feasible, and is
//!   floored by the admissible bound;
//! * **(d) cut-free bit-identity** — `verify_fraction = 1.0` reproduces
//!   the exact `nlpdse` ladder step for step: same fingerprints, same
//!   measurements, same best.
//!
//! `FUZZ_KERNELS` / `FUZZ_SMOKE=1` / `FUZZ_SEED` bound the corpus like
//! the frontend fuzz suite; failures panic with the seed and the `.knl`
//! text.

use nlp_dse::dse::{run_nlp_dse, DseConfig};
use nlp_dse::engine::{Engine, ExploreCtx, Exploration};
use nlp_dse::frontend::{self, GenConfig};
use nlp_dse::hls::Device;
use nlp_dse::ir::{Kernel, LoopId};
use nlp_dse::model;
use nlp_dse::nlp::RustFeatureEvaluator;
use nlp_dse::poly::Analysis;
use nlp_dse::pragma::{space, Design, Space};
use nlp_dse::surrogate::{
    spearman, train, SurrogateConfig, SurrogateEngine, SurrogateModel, TrainConfig,
};
use nlp_dse::util::env_usize;
use nlp_dse::util::rng::Rng;

/// The committed floor for property (b). The dominant pooled feature is
/// the admissible bound-model floor — empirically within [0.2, 1.02]× of
/// the exact score — so held-out *ordering* is structural, and a fit
/// that drops below this floor has broken featurization or training,
/// not bad luck.
const SPEARMAN_FLOOR: f64 = 0.7;

fn fuzz_n() -> usize {
    // each kernel runs whole (short) DSE ladders in (c)/(d), so the
    // defaults sit below the frontend suite's
    let n = if std::env::var("FUZZ_SMOKE").as_deref() == Ok("1") {
        env_usize("FUZZ_KERNELS", 8)
    } else {
        env_usize("FUZZ_KERNELS", 40)
    };
    n.max(1)
}

const BASE_SEED: u64 = 0x5a10_2026;

fn seeds(label: &str) -> Vec<u64> {
    let n = fuzz_n() as u64;
    let base: u64 = std::env::var("FUZZ_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(BASE_SEED)
        .min(u64::MAX - n);
    eprintln!("[fuzz:{label}] {n} kernels, seeds {base}..={}", base + n - 1);
    (base..base + n).collect()
}

fn fail(seed: u64, k: &Kernel, msg: &str) -> ! {
    panic!(
        "\n=== surrogate property failure ===\n\
         seed: {seed}\n\
         replay: FUZZ_SEED={seed} FUZZ_KERNELS=1 cargo test --test property_surrogate\n\
         {msg}\n\
         --- offending kernel (.knl) ---\n{}",
        frontend::pretty::print(k)
    )
}

/// Tiny deterministic training corpus — big enough to pin the dominant
/// latency feature, small enough for the fuzz loop.
fn tiny_train(seed: u64) -> TrainConfig {
    TrainConfig {
        seed,
        kernels: 3,
        designs: 8,
        ..TrainConfig::default()
    }
}

/// Short ladder for the per-kernel DSE properties: ∞ → 64 → 1 exercises
/// the rung transition and the final exhaustive rung without paying for
/// the full 11-rung production ladder on every fuzz kernel.
fn fuzz_dse_config() -> DseConfig {
    DseConfig {
        ladder: vec![u64::MAX, 64, 1],
        ..DseConfig::default()
    }
}

/// Deterministic random designs for `k`, the corpus/`random`-engine
/// sampling idiom (pragma-free baseline always included).
fn sample_designs(k: &Kernel, a: &Analysis, dev: &Device, seed: u64, n: usize) -> Vec<Design> {
    let sp = Space::new(k, a);
    let mut rng = Rng::new(seed).derive("fresh-designs");
    let mut designs = vec![Design::empty(k)];
    for _ in 0..n {
        let pcfg = &sp.pipeline_configs[rng.range(0, sp.pipeline_configs.len() as u64) as usize];
        let drawn: Vec<u64> = (0..k.n_loops())
            .map(|i| {
                let menu = sp.ufs(LoopId(i as u32), a, dev.max_array_partition);
                if menu.is_empty() {
                    1
                } else {
                    menu[rng.range(0, menu.len() as u64) as usize]
                }
            })
            .collect();
        designs.push(space::materialize(k, a, pcfg, &|l: LoopId| drawn[l.0 as usize], &|_| 1));
    }
    designs
}

fn explore_surrogate(
    k: &Kernel,
    a: &Analysis,
    dev: &Device,
    model: &SurrogateModel,
    frac: f64,
) -> Exploration {
    let ctx = ExploreCtx {
        kernel: k,
        analysis: a,
        device: dev,
        evaluator: &RustFeatureEvaluator,
        bound: None,
    };
    let cfg = SurrogateConfig {
        model: Some(model.clone()),
        verify_fraction: frac,
        ..SurrogateConfig::default()
    };
    SurrogateEngine::new(cfg, fuzz_dse_config()).explore(&ctx)
}

// --- (a) training is bit-reproducible -----------------------------------

#[test]
fn prop_training_is_bit_reproducible_and_round_trips() {
    let dir = std::env::temp_dir().join("nlp_dse_property_surrogate");
    std::fs::create_dir_all(&dir).unwrap();
    for (i, seed) in seeds("sur-train").into_iter().enumerate().take(4) {
        let cfg = tiny_train(seed);
        let (t1, t2) = (train(&cfg), train(&cfg));
        assert_eq!(t1.model, t2.model, "seed {seed}: weights diverged");
        assert_eq!(
            t1.model.to_json().to_line(),
            t2.model.to_json().to_line(),
            "seed {seed}: canonical JSON diverged"
        );
        assert_eq!(
            t1.model.content_hash(),
            t2.model.content_hash(),
            "seed {seed}: content hash diverged"
        );
        assert_eq!(
            t1.holdout_spearman.to_bits(),
            t2.holdout_spearman.to_bits(),
            "seed {seed}: holdout score diverged"
        );
        // the artifact round trip is exact: same model, same hash
        let path = dir.join(format!("prop_roundtrip_{i}.json"));
        t1.model.save(&path).unwrap();
        let back = SurrogateModel::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back, t1.model, "seed {seed}: save/load changed the model");
        assert_eq!(
            back.content_hash(),
            t1.model.content_hash(),
            "seed {seed}: save/load changed the content hash"
        );
    }
}

// --- (b) held-out rank correlation exceeds the committed floor ----------

#[test]
fn prop_holdout_spearman_exceeds_the_committed_floor() {
    // the holdout split of the corpus the fit trained on…
    let t = train(&TrainConfig::micro());
    assert!(t.n_holdout >= 2, "degenerate holdout split");
    assert!(
        t.holdout_spearman > SPEARMAN_FLOOR,
        "holdout spearman {} <= floor {SPEARMAN_FLOOR}",
        t.holdout_spearman
    );

    // …and designs on freshly generated kernels the fit never saw,
    // pooled so one degenerate kernel (constant latency across its
    // designs) cannot zero the metric
    let dev = Device::u200();
    let mut preds: Vec<f64> = Vec::new();
    let mut exacts: Vec<f64> = Vec::new();
    let mut unrankable = 0usize;
    for seed in seeds("sur-rank") {
        let k = frontend::generate(&GenConfig::sampled(seed));
        let a = Analysis::new(&k);
        for d in sample_designs(&k, &a, &dev, seed, 12) {
            match t.model.predict(&k, &a, &dev, &d) {
                Some(p) => {
                    if !p.is_finite() {
                        fail(seed, &k, &format!("non-finite prediction {p}"));
                    }
                    preds.push(p);
                    exacts.push((1.0 + model::evaluate(&k, &a, &dev, &d).total_cycles).ln());
                }
                None => unrankable += 1,
            }
        }
    }
    let rho = spearman(&preds, &exacts);
    eprintln!(
        "[fuzz:sur-rank] pooled spearman {rho:.4} over {} fresh samples ({unrankable} unrankable)",
        preds.len()
    );
    assert!(preds.len() >= 2, "every fresh kernel was unrankable");
    assert!(rho > SPEARMAN_FLOOR, "fresh-kernel spearman {rho} <= floor {SPEARMAN_FLOOR}");
}

// --- (c) the reported best is exact-scored and feasible ------------------

#[test]
fn prop_reported_best_is_exact_scored_and_feasible() {
    let dev = Device::u200();
    let model = train(&TrainConfig::micro()).model;
    for seed in seeds("sur-exact") {
        let k = frontend::generate(&GenConfig::sampled(seed));
        let a = Analysis::new(&k);
        let out = explore_surrogate(&k, &a, &dev, &model, 0.35);
        assert_eq!(out.engine, "surrogate");
        let so = out.as_surrogate().expect("surrogate detail");
        let Some((d, _)) = &out.best else {
            if so.exact_cycles.is_some() || so.exact_feasible || so.exact_lower_bound.is_finite() {
                fail(seed, &k, "no best design, but exact re-verification fields are set");
            }
            continue;
        };
        let exact = match so.exact_cycles {
            Some(c) if c.is_finite() && c > 0.0 => c,
            other => fail(seed, &k, &format!("best not exact-scored: {other:?}")),
        };
        if !so.exact_feasible {
            fail(seed, &k, "reported best re-verifies infeasible");
        }
        if so.exact_lower_bound > exact * (1.0 + 1e-9) {
            fail(
                seed,
                &k,
                &format!("bound {} beats exact {exact}", so.exact_lower_bound),
            );
        }
        // differential: the engine's exact score is the reference model's
        let r = model::evaluate(&k, &a, &dev, d);
        let rel = (exact - r.total_cycles).abs() / r.total_cycles.max(1.0);
        if rel > 1e-9 {
            fail(
                seed,
                &k,
                &format!(
                    "exact_cycles {exact} != model::evaluate {} (rel {rel:e})",
                    r.total_cycles
                ),
            );
        }
        if !r.feasible {
            fail(seed, &k, "reference model calls the reported best infeasible");
        }
    }
}

// --- (d) verify_fraction = 1.0 is bit-identical to the exact ladder -----

#[test]
fn prop_verify_fraction_one_is_bit_identical_to_the_exact_ladder() {
    let dev = Device::u200();
    let model = train(&TrainConfig::micro()).model;
    let cfg = fuzz_dse_config();
    for seed in seeds("sur-ident") {
        let k = frontend::generate(&GenConfig::sampled(seed));
        let a = Analysis::new(&k);
        let exact = run_nlp_dse(&k, &a, &dev, &cfg, &RustFeatureEvaluator);
        let sur = explore_surrogate(&k, &a, &dev, &model, 1.0);
        let so = sur.as_surrogate().expect("surrogate detail");
        if so.rank_skipped != 0 {
            fail(seed, &k, &format!("cut-free run skipped {} candidates", so.rank_skipped));
        }
        if exact.best_gflops.to_bits() != sur.best_gflops.to_bits() {
            fail(
                seed,
                &k,
                &format!("best diverged: {} vs {}", exact.best_gflops, sur.best_gflops),
            );
        }
        if exact.trace.len() != so.outcome.trace.len() {
            fail(
                seed,
                &k,
                &format!("trace length {} vs {}", exact.trace.len(), so.outcome.trace.len()),
            );
        }
        for (s1, s2) in exact.trace.iter().zip(&so.outcome.trace) {
            if s1.fingerprint != s2.fingerprint || s1.measured != s2.measured {
                fail(
                    seed,
                    &k,
                    &format!(
                        "step {} diverged: ({}, {:?}) vs ({}, {:?})",
                        s1.step, s1.fingerprint, s1.measured, s2.fingerprint, s2.measured
                    ),
                );
            }
        }
        match (&exact.best, &sur.best) {
            (None, None) => {}
            (Some((d1, c1)), Some((d2, c2))) => {
                if d1.fingerprint() != d2.fingerprint() || c1.to_bits() != c2.to_bits() {
                    fail(seed, &k, "best design/latency diverged");
                }
            }
            _ => fail(seed, &k, "one ladder found a best, the other did not"),
        }
    }
}
