//! Round-trip coverage of the `.knl` frontend over the **entire seed
//! corpus**: all 24 PolyBench kernels + CNN, at every problem size and
//! both precisions, satisfy
//!
//! ```text
//! parse(pretty(k))  ≡  k        (structural identity)
//! pretty(parse(pretty(k)))  ==  pretty(k)   (printing is stable)
//! ```
//!
//! which proves the DSL spans the program class the paper evaluates —
//! the hand-built Rust corpus is a strict subset of what the textual
//! frontend accepts.

use nlp_dse::benchmarks::{self, Size};
use nlp_dse::frontend::{parse_kernel, pretty};
use nlp_dse::ir::DType;
use nlp_dse::poly::Analysis;

fn corpus() -> impl Iterator<Item = (&'static str, Size)> {
    benchmarks::ALL.into_iter().flat_map(|name| {
        let sizes: &'static [Size] = if name == "cnn" {
            &[Size::Medium] // cnn has a single problem size (Sec 7.1)
        } else {
            &[Size::Small, Size::Medium, Size::Large]
        };
        sizes.iter().map(move |&s| (name, s))
    })
}

#[test]
fn all_seed_kernels_roundtrip_structurally() {
    for (name, size) in corpus() {
        for dtype in [DType::F32, DType::F64] {
            let k = benchmarks::build(name, size, dtype).unwrap();
            let text = pretty::print(&k);
            let k2 = parse_kernel(&text, &format!("{name}.knl")).unwrap_or_else(|e| {
                panic!("{name}/{size:?}/{}: reparse failed:\n{e}\n--- .knl ---\n{text}", dtype.name())
            });
            if let Some(diff) = k.structural_diff(&k2) {
                panic!(
                    "{name}/{size:?}/{}: round-trip diverged: {diff}\n--- .knl ---\n{text}",
                    dtype.name()
                );
            }
        }
    }
}

#[test]
fn printing_is_stable_across_corpus() {
    for (name, size) in corpus() {
        let k = benchmarks::build(name, size, DType::F32).unwrap();
        let t1 = pretty::print(&k);
        let t2 = pretty::print(&parse_kernel(&t1, "<rt>").unwrap());
        assert_eq!(t1, t2, "{name}/{size:?}: pretty not a fixed point of parse∘pretty");
    }
}

#[test]
fn transformed_kernels_roundtrip_structurally() {
    use nlp_dse::serve::fingerprint::fingerprint;
    use nlp_dse::transform::{enumerate, TransformConfig};
    // every legal variant of a representative PolyBench slice stays
    // inside the DSL's program class: parse(pretty(apply(rw, k))) is
    // structurally identical to apply(rw, k), so `emit` of a winning
    // variant and a daemon round-trip of its text agree on the kernel
    let cfg = TransformConfig {
        max_variants: 8,
        max_depth: 1,
        max_perm_loops: 3,
    };
    for name in ["gemm", "2mm", "bicg", "atax", "mvt", "gesummv"] {
        let k = benchmarks::build(name, Size::Small, DType::F32).unwrap();
        let variants = enumerate(&k, &cfg);
        assert!(!variants.is_empty(), "{name}: at least the original");
        for v in &variants {
            let chain = v.trace_strings().join(" ; ");
            let text = pretty::print(&v.kernel);
            let k2 = parse_kernel(&text, "<transformed>").unwrap_or_else(|e| {
                panic!("{name} [{chain}]: reparse failed:\n{e}\n--- .knl ---\n{text}")
            });
            if let Some(diff) = v.kernel.structural_diff(&k2) {
                panic!("{name} [{chain}]: round-trip diverged: {diff}\n--- .knl ---\n{text}");
            }
            // the round-trip maps to the same cache line too: variant
            // dedup and daemon caching agree on what "same kernel" means
            assert_eq!(fingerprint(&v.kernel), fingerprint(&k2), "{name} [{chain}]");
        }
    }
}

#[test]
fn roundtrip_preserves_the_static_analyses() {
    // structural identity should make this redundant; assert it anyway
    // on a representative slice so an equality bug in structural_diff
    // cannot silently let analysis-relevant drift through
    for name in ["2mm", "cnn", "lu", "trmm", "heat-3d", "durbin", "gramschmidt"] {
        let size = if name == "cnn" { Size::Medium } else { Size::Small };
        let k = benchmarks::build(name, size, DType::F32).unwrap();
        let k2 = parse_kernel(&pretty::print(&k), "<rt>").unwrap();
        let a = Analysis::new(&k);
        let a2 = Analysis::new(&k2);
        assert_eq!(a.deps.nd(), a2.deps.nd(), "{name}: dependence count");
        assert_eq!(a.total_footprint, a2.total_footprint, "{name}: footprint");
        assert!(
            (a.total_flops - a2.total_flops).abs() < 1e-9,
            "{name}: flops {} vs {}",
            a.total_flops,
            a2.total_flops
        );
        for (i, (t, t2)) in a.tcs.iter().zip(&a2.tcs).enumerate() {
            assert_eq!((t.min, t.max), (t2.min, t2.max), "{name}: L{i} trip count");
        }
    }
}
