//! The unified engine API: registry dispatch, `Exploration`
//! normalization from every legacy outcome type, and the `Explorer`
//! facade end-to-end over every registered engine.

use nlp_dse::baselines::{run_autodse, run_harp, AutoDseConfig, HarpConfig};
use nlp_dse::benchmarks::{self, Size};
use nlp_dse::dse::{run_nlp_dse, DseConfig};
use nlp_dse::engine::{
    Engine, EngineTuning, Evaluator, Exploration, ExploreCtx, Explorer, Registry, StepStatus,
};
use nlp_dse::hls::{Device, HlsOracle};
use nlp_dse::ir::DType;
use nlp_dse::nlp::RustFeatureEvaluator;
use nlp_dse::poly::Analysis;
use nlp_dse::pragma::Design;

fn substrate(name: &str, size: Size) -> (nlp_dse::Kernel, Analysis, Device) {
    let k = benchmarks::build(name, size, DType::F32).unwrap();
    let a = Analysis::new(&k);
    (k, a, Device::u200())
}

// --- registry ----------------------------------------------------------

#[test]
fn registry_lists_and_resolves_builtin_engines() {
    let r = Registry::builtin();
    assert_eq!(
        r.names(),
        vec!["autodse", "harp", "nlpdse", "random", "surrogate"]
    );
    for n in r.names() {
        let e = r.create(&n, &EngineTuning::default()).unwrap();
        assert_eq!(e.name(), n);
    }
}

#[test]
fn registry_unknown_engine_error_names_alternatives() {
    let err = Registry::builtin()
        .create("gradient-descent", &EngineTuning::default())
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("unknown engine `gradient-descent`"), "{msg}");
    for n in ["nlpdse", "autodse", "harp", "random", "surrogate"] {
        assert!(msg.contains(n), "{msg} should list {n}");
    }
}

// --- Exploration normalization -----------------------------------------

#[test]
fn normalizes_nlpdse_outcome() {
    let (k, a, dev) = substrate("gemm", Size::Small);
    let o = run_nlp_dse(&k, &a, &dev, &DseConfig::default(), &RustFeatureEvaluator);
    let ex: Exploration = o.clone().into();
    assert_eq!(ex.engine, "nlpdse");
    assert_eq!(ex.kernel, o.kernel);
    assert_eq!(ex.best_gflops, o.best_gflops);
    assert_eq!(ex.first_synth_gflops, o.first_synth_gflops);
    assert_eq!(ex.wall_minutes, o.dse_minutes);
    assert_eq!(ex.synth_calls, o.designs_explored);
    assert_eq!(ex.synth_timeouts, o.designs_timeout);
    assert_eq!(ex.trace.len(), o.trace.len());
    assert_eq!(
        ex.pruned as usize,
        o.trace.iter().filter(|s| s.pruned).count()
    );
    // the proven floor is the smallest finite subspace lower bound
    let floor = ex.lower_bound.expect("nlpdse proves a floor");
    assert!(floor > 0.0 && floor.is_finite());
    // detail survives for the report generators
    let back = ex.as_nlpdse().expect("detail preserved");
    assert_eq!(back.steps_to_best, o.steps_to_best);
    assert!(ex.as_autodse().is_none() && ex.as_harp().is_none());
    // normalized trace agrees with the legacy step records
    for (ns, ls) in ex.trace.iter().zip(o.trace.iter()) {
        assert_eq!(ns.step, ls.step);
        assert_eq!(ns.measured, ls.measured);
        assert_eq!(ns.status == StepStatus::Dedup, ls.dedup);
        assert_eq!(ns.status == StepStatus::Pruned, ls.pruned && !ls.dedup);
    }
}

#[test]
fn normalizes_autodse_outcome() {
    let (k, a, dev) = substrate("bicg", Size::Small);
    let o = run_autodse(&k, &a, &dev, &AutoDseConfig::default());
    let ex: Exploration = o.clone().into();
    assert_eq!(ex.engine, "autodse");
    assert_eq!(ex.best_gflops, o.best_gflops);
    assert_eq!(ex.wall_minutes, o.dse_minutes);
    assert_eq!(ex.synth_calls, o.designs_explored);
    assert_eq!(ex.synth_timeouts, o.designs_timeout);
    assert_eq!(ex.rejected, o.early_rejected);
    assert!(ex.lower_bound.is_none(), "autodse has no bounding model");
    assert_eq!(
        ex.as_autodse().unwrap().designs_synthesized,
        o.designs_synthesized
    );
}

#[test]
fn normalizes_harp_outcome() {
    let (k, a, dev) = substrate("mvt", Size::Small);
    let cfg = HarpConfig {
        sweep_configs: 2_000,
        ..HarpConfig::default()
    };
    let o = run_harp(&k, &a, &dev, &cfg);
    let ex: Exploration = o.clone().into();
    assert_eq!(ex.engine, "harp");
    assert_eq!(ex.best_gflops, o.best_gflops);
    assert_eq!(ex.wall_minutes, o.dse_minutes);
    assert_eq!(ex.synth_calls, o.designs_synthesized);
    assert!(ex.lower_bound.is_none());
    assert_eq!(ex.as_harp().unwrap().configs_scored, o.configs_scored);
}

// --- Explorer facade end-to-end ----------------------------------------

fn quick_tuning() -> EngineTuning {
    EngineTuning {
        harp: HarpConfig {
            sweep_configs: 2_000,
            ..HarpConfig::default()
        },
        random: nlp_dse::engine::RandomConfig {
            samples: 1_000,
            synth_budget: 16,
            ..Default::default()
        },
        ..EngineTuning::default()
    }
}

#[test]
fn explorer_runs_every_registered_engine_end_to_end() {
    let explorer = Explorer::kernel("gemm", Size::Small)
        .unwrap()
        .evaluator(Evaluator::rust())
        .tuning(quick_tuning());
    for name in explorer.engine_names() {
        let ex = explorer.run_engine(&name).unwrap_or_else(|e| {
            panic!("engine {name} failed: {e:#}");
        });
        assert_eq!(ex.engine, name);
        assert_eq!(ex.kernel, "gemm");
        assert!(ex.best.is_some(), "{name} found no design");
        assert!(ex.best_gflops > 0.0, "{name}");
        assert!(ex.synth_calls >= 1, "{name}");
        assert!(ex.wall_minutes > 0.0, "{name}");
        // every engine's summary renders without a kernel in hand
        assert!(ex.summary().contains(&format!("engine `{name}`")));
    }
}

#[test]
fn every_builtin_engine_best_revalidates_under_the_exact_model() {
    let (k, a, dev) = substrate("gemm", Size::Small);
    let explorer = Explorer::kernel("gemm", Size::Small)
        .unwrap()
        .evaluator(Evaluator::rust())
        .tuning(quick_tuning());
    let oracle = HlsOracle::new(dev.clone());
    for name in Registry::builtin().names() {
        let ex = explorer.run_engine(&name).unwrap();
        let (d, cycles) = ex
            .best
            .as_ref()
            .unwrap_or_else(|| panic!("{name}: no best design"));
        // the exact analytic model can score every engine's best…
        let r = nlp_dse::model::evaluate(&k, &a, &dev, d);
        assert!(
            r.total_cycles.is_finite() && r.total_cycles > 0.0,
            "{name}: exact model cannot score the best design"
        );
        // …and the measurement oracle reproduces the recorded latency
        let rep = oracle.synth(&k, &a, d);
        assert!(rep.valid, "{name}: best design does not re-synthesize valid");
        assert_eq!(rep.cycles, *cycles, "{name}: recorded latency is not the oracle's");
        // engines that carry a bounding model (nlpdse, surrogate) prove
        // full feasibility of the *requested* pragmas, not just of what
        // Merlin realized
        if ex.lower_bound.is_some() {
            assert!(r.feasible, "{name}: bounded engine returned an infeasible best");
        }
    }
}

#[test]
fn surrogate_never_loses_to_random_at_equal_synth_budget() {
    // the acceptance criterion: at the same number of synthesis calls,
    // the rank-cut ladder's (exact-scored) best is never worse than
    // random search's
    let sur = Explorer::kernel("gemm", Size::Small)
        .unwrap()
        .evaluator(Evaluator::rust())
        .run_engine("surrogate")
        .unwrap();
    assert!(sur.best.is_some(), "surrogate found no design");
    let so = sur.as_surrogate().expect("surrogate detail");
    assert!(so.exact_feasible, "surrogate best must re-verify feasible");
    let budget = sur.synth_calls.max(1);
    let rand = Explorer::kernel("gemm", Size::Small)
        .unwrap()
        .evaluator(Evaluator::rust())
        .random_config(nlp_dse::engine::RandomConfig {
            samples: 5_000,
            synth_budget: budget,
            ..Default::default()
        })
        .engine("random")
        .unwrap()
        .run()
        .unwrap();
    assert!(rand.synth_calls <= budget, "random overspent its budget");
    assert!(
        sur.best_gflops >= rand.best_gflops,
        "surrogate {} < random {} at equal budget {budget}",
        sur.best_gflops,
        rand.best_gflops
    );
}

#[test]
fn explorer_selected_engine_and_builder_chain() {
    // the issue's canonical one-liner shape
    let outcome = Explorer::kernel("atax", Size::Small)
        .unwrap()
        .device(Device::u200())
        .evaluator(Evaluator::rust())
        .engine("random")
        .unwrap()
        .random_config(nlp_dse::engine::RandomConfig {
            samples: 500,
            synth_budget: 8,
            ..Default::default()
        })
        .run()
        .unwrap();
    assert_eq!(outcome.engine, "random");
    assert!(outcome.synth_calls <= 8);
    assert!(outcome.best.is_some());
}

#[test]
fn explorer_is_deterministic_per_engine() {
    for engine in ["autodse", "random"] {
        let run = || {
            Explorer::kernel("bicg", Size::Small)
                .unwrap()
                .evaluator(Evaluator::rust())
                .tuning(quick_tuning())
                .run_engine(engine)
                .unwrap()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.best_gflops, b.best_gflops, "{engine}");
        assert_eq!(a.synth_calls, b.synth_calls, "{engine}");
    }
}

// --- third-party engine: registered, zero CLI/coordinator edits ---------

struct BestOfOne;

impl Engine for BestOfOne {
    fn name(&self) -> &str {
        "best-of-one"
    }

    fn explore(&self, ctx: &ExploreCtx<'_>) -> Exploration {
        let oracle = HlsOracle::new(ctx.device.clone());
        let d = Design::empty(ctx.kernel);
        let rep = oracle.synth(ctx.kernel, ctx.analysis, &d);
        let gfs = rep.gflops(ctx.analysis, ctx.device);
        Exploration {
            engine: "best-of-one".into(),
            kernel: ctx.kernel.name.clone(),
            best: rep.valid.then(|| (d, rep.cycles)),
            best_gflops: gfs,
            first_synth_gflops: gfs,
            best_dsp_pct: 0.0,
            lower_bound: None,
            wall_minutes: rep.synth_minutes,
            synth_calls: 1,
            synth_timeouts: 0,
            pruned: 0,
            rejected: 0,
            trace: Vec::new(),
            detail: nlp_dse::engine::EngineDetail::Generic,
        }
    }
}

#[test]
fn custom_engine_registers_into_the_facade() {
    fn factory(_t: &EngineTuning) -> Box<dyn Engine> {
        Box::new(BestOfOne)
    }
    let outcome = Explorer::kernel("gemm", Size::Small)
        .unwrap()
        .evaluator(Evaluator::rust())
        .register("best-of-one", factory)
        .engine("best-of-one")
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(outcome.engine, "best-of-one");
    assert_eq!(outcome.synth_calls, 1);
    assert!(outcome.best.is_some());
}

// --- CLI dispatches through the registry --------------------------------

#[test]
fn cli_dse_dispatches_any_registered_engine() {
    let out = std::env::temp_dir().join("nlpdse-engine-cli.txt");
    nlp_dse::cli::run(&[
        "dse",
        "--kernel",
        "bicg",
        "--size",
        "S",
        "--engine",
        "random",
        "--out",
        out.to_str().unwrap(),
    ])
    .unwrap();
    let text = std::fs::read_to_string(&out).unwrap();
    assert!(text.contains("engine `random` on bicg"), "{text}");
    assert!(text.contains("best design"), "{text}");
}

#[test]
fn cli_rejects_unknown_engine_with_the_registry_list() {
    let err = nlp_dse::cli::run(&[
        "dse", "--kernel", "gemm", "--size", "S", "--engine", "nope",
    ])
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("unknown engine `nope`"), "{msg}");
    assert!(msg.contains("random"), "{msg}");
}
