//! NaN-robustness of the NLP solver (ISSUE 9 bugfix acceptance).
//!
//! A mispredicting learned evaluator (or a model bug) can hand the
//! solver `NaN` latencies. The old ordering used
//! `partial_cmp(..).unwrap()`, which panicked on the first NaN — and a
//! worker panic poisoned the shared queue/incumbent locks, cascading
//! into opaque `PoisonError` panics on every other worker. The fix:
//!
//! * every ordering site uses [`f64::total_cmp`], under which NaN ranks
//!   *after* `+inf` — a NaN-scored design loses to every real design
//!   and can never displace a finite incumbent;
//! * lock acquisitions recover the guard from a poisoned mutex
//!   (`unwrap_or_else(|p| p.into_inner())`), and `solve_jobs` re-raises
//!   the *first* worker panic with its original payload instead of a
//!   `PoisonError` cascade.
//!
//! The suites drive generated kernels (seeded, bit-replayable) through
//! an evaluator that deterministically NaN-poisons a slice of designs,
//! asserting the solve completes, schedules every pipeline
//! configuration exactly once, and stays bit-identical across worker
//! team sizes.

use nlp_dse::benchmarks::{self, Size};
use nlp_dse::frontend::{self, GenConfig};
use nlp_dse::hls::Device;
use nlp_dse::ir::DType;
use nlp_dse::nlp::{self, BatchEvaluator, NlpProblem, SolveResult, SymbolicEvaluator};
use nlp_dse::poly::Analysis;
use nlp_dse::pragma::Design;

const BUDGET_S: f64 = 300.0;
const TOPK: usize = 4;

/// Wraps the symbolic evaluator and replaces the latency of a
/// deterministic subset of designs with NaN: a design is poisoned when
/// the byte-sum of its fingerprint is `0 (mod modulus)` — `modulus = 1`
/// poisons everything, larger values poison a pseudo-random slice, and
/// the rule is a pure function of the design so serial and parallel
/// runs see identical poison.
struct NanEvaluator {
    modulus: u64,
}

fn poisoned(d: &Design, modulus: u64) -> bool {
    let sum: u64 = d.fingerprint().bytes().map(u64::from).sum();
    sum % modulus == 0
}

impl BatchEvaluator for NanEvaluator {
    fn eval_batch(&self, p: &NlpProblem, designs: &[Design]) -> Vec<(f64, f64)> {
        SymbolicEvaluator
            .eval_batch(p, designs)
            .into_iter()
            .zip(designs)
            .map(|((lat, dsp), d)| {
                if poisoned(d, self.modulus) {
                    (f64::NAN, dsp)
                } else {
                    (lat, dsp)
                }
            })
            .collect()
    }
}

fn assert_bit_identical(ctx: &str, serial: &SolveResult, par: &SolveResult) {
    assert_eq!(serial.optimal, par.optimal, "{ctx}: optimal flag");
    assert_eq!(
        serial.lower_bound.to_bits(),
        par.lower_bound.to_bits(),
        "{ctx}: lower bound"
    );
    assert_eq!(serial.designs.len(), par.designs.len(), "{ctx}: top-k size");
    for (i, ((d1, o1), (d2, o2))) in serial.designs.iter().zip(&par.designs).enumerate() {
        assert_eq!(d1.fingerprint(), d2.fingerprint(), "{ctx}: design #{i}");
        // to_bits compares NaN payloads too: both sides inject the same
        // constant NaN, so even poisoned entries must agree exactly
        assert_eq!(o1.to_bits(), o2.to_bits(), "{ctx}: objective #{i}");
    }
}

#[test]
fn prop_nan_evaluator_never_panics_and_keeps_parallel_parity() {
    let dev = Device::u200();
    for seed in 0..6u64 {
        let k = frontend::generate(&GenConfig::with_seed(seed));
        let a = Analysis::new(&k);
        let p = NlpProblem::new(&k, &a, &dev, 64, false);
        let n_configs = p.space.pipeline_configs.len() as u64;
        for modulus in [1u64, 3] {
            let eval = NanEvaluator { modulus };
            let ctx = format!("gen seed {seed} modulus {modulus}");
            let serial = nlp::solve_jobs(&p, BUDGET_S, TOPK, &eval, 1);
            assert!(serial.optimal, "{ctx}: must complete in budget");
            assert_eq!(
                serial.stats.configs, n_configs,
                "{ctx}: every pipeline configuration exactly once"
            );
            // NaN ranks last: any finite-objective design must sort
            // before every NaN one in the returned top-k
            let first_nan = serial.designs.iter().position(|(_, o)| o.is_nan());
            if let Some(i) = first_nan {
                assert!(
                    serial.designs[i..].iter().all(|(_, o)| o.is_nan()),
                    "{ctx}: NaN designs must form a suffix of the top-k"
                );
            }
            if modulus == 1 {
                assert!(
                    serial.designs.iter().all(|(_, o)| o.is_nan()),
                    "{ctx}: all-NaN evaluator can only yield NaN-scored designs"
                );
            }
            let par = nlp::solve_jobs(&p, BUDGET_S, TOPK, &eval, 4);
            assert_eq!(par.stats.configs, n_configs, "{ctx}: parallel accounting");
            assert_bit_identical(&ctx, &serial, &par);
        }
    }
}

#[test]
fn nan_poison_on_a_registry_kernel_cannot_displace_finite_incumbents() {
    // gemm with a partial poison: the solve must still find a finite
    // best design, identical to what the unpoisoned evaluator finds
    // among the surviving (non-poisoned) candidates — in particular the
    // best finite objective can never be NaN.
    let dev = Device::u200();
    let k = benchmarks::lookup("gemm", Size::Small, DType::F32).unwrap();
    let a = Analysis::new(&k);
    let p = NlpProblem::new(&k, &a, &dev, 64, false);
    let r = nlp::solve_jobs(&p, BUDGET_S, TOPK, &NanEvaluator { modulus: 3 }, 2);
    assert!(r.optimal);
    let (_, best) = r.best().expect("a finite design must survive");
    assert!(
        best.is_finite(),
        "the top design must be finite, got {best}"
    );
}

/// An evaluator whose panic message must survive the worker team: the
/// fix re-raises the first worker panic with its original payload, so
/// the caller sees `evaluator exploded`, not a `PoisonError`.
struct PanickingEvaluator;

impl BatchEvaluator for PanickingEvaluator {
    fn eval_batch(&self, _p: &NlpProblem, _designs: &[Design]) -> Vec<(f64, f64)> {
        panic!("evaluator exploded");
    }
}

#[test]
fn worker_panics_propagate_the_original_payload_not_a_poison_error() {
    let dev = Device::u200();
    let k = benchmarks::lookup("gemm", Size::Small, DType::F32).unwrap();
    let a = Analysis::new(&k);
    let p = NlpProblem::new(&k, &a, &dev, 16, false);
    for jobs in [1usize, 2] {
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            nlp::solve_jobs(&p, BUDGET_S, TOPK, &PanickingEvaluator, jobs)
        }))
        .expect_err("a panicking evaluator must abort the solve");
        let msg = err
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| err.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(
            msg.contains("evaluator exploded"),
            "jobs={jobs}: the original panic payload must propagate, got `{msg}`"
        );
    }
}
