//! Golden-file snapshot tests for the pragma-annotated C emitter over
//! the **entire registry corpus**: all 24 registered kernels (23
//! PolyBench + CNN), Merlin dialect, plus Vitis and realized-mode
//! snapshots on representative kernels.
//!
//! Protocol (documented in GUIDE.md):
//!
//! * snapshots live in `rust/tests/golden/codegen/*.c`;
//! * a **missing** snapshot is blessed on first run (written + reported)
//!   — the offline environment has no other way to mint the bytes —
//!   and compared byte-exactly on every run after;
//! * `UPDATE_GOLDEN=1 cargo test --test codegen_golden` refreshes every
//!   snapshot after an intentional emitter change; commit the diff.
//!
//! Blessing never skips the structural gate: every emission (fresh or
//! compared) must pass `codegen::lint` — balanced delimiters, one
//! `for (` per IR loop, statement coverage, pragma attachment — so a
//! broken emitter cannot bless broken snapshots.

use nlp_dse::benchmarks::{self, Size};
use nlp_dse::codegen::{self, EmitConfig};
use nlp_dse::hls::Device;
use nlp_dse::ir::{DType, Kernel, LoopId};
use nlp_dse::poly::Analysis;
use nlp_dse::pragma::Design;
use std::path::PathBuf;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/codegen")
}

/// Compare `content` against the named snapshot, blessing it when
/// absent or when `UPDATE_GOLDEN=1`.
fn check_golden(file: &str, content: &str) {
    let path = golden_dir().join(file);
    let update = std::env::var("UPDATE_GOLDEN").as_deref() == Ok("1");
    if update || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, content).unwrap();
        eprintln!("[golden] blessed {}", path.display());
        return;
    }
    let want = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        want,
        content,
        "golden mismatch for {file}; run UPDATE_GOLDEN=1 cargo test --test codegen_golden \
         and commit the refreshed snapshot if the change is intentional"
    );
}

/// The deterministic showcase design the snapshots use: pipeline every
/// innermost loop (with a modest divisor unroll), tile the nest roots.
/// Pure function of the kernel + analysis — no solver in the loop, so
/// snapshots only churn when the *emitter* changes.
fn showcase(k: &Kernel, a: &Analysis) -> Design {
    let mut d = Design::empty(k);
    for i in 0..k.n_loops() {
        let l = LoopId(i as u32);
        let meta = k.loop_meta(l);
        let tc = &a.tcs[i];
        if meta.innermost {
            d.get_mut(l).pipeline = true;
            if tc.is_constant() && tc.max > 1 {
                let uf = nlp_dse::util::divisors(tc.max)
                    .into_iter()
                    .filter(|&x| x <= 8)
                    .max()
                    .unwrap_or(1);
                d.get_mut(l).uf = uf;
            }
        } else if meta.parent.is_none() && tc.is_constant() && tc.max > 1 {
            let t = nlp_dse::util::divisors(tc.max)
                .into_iter()
                .filter(|&x| x <= 4)
                .max()
                .unwrap_or(1);
            d.get_mut(l).tile = t;
        }
    }
    d
}

fn setup(name: &str) -> (Kernel, Analysis, Device) {
    let size = if name == "cnn" { Size::Medium } else { Size::Small };
    let k = benchmarks::build(name, size, DType::F32).unwrap();
    let a = Analysis::new(&k);
    (k, a, Device::u200())
}

#[test]
fn golden_merlin_every_registry_kernel() {
    for name in benchmarks::ALL {
        let (k, a, dev) = setup(name);
        let d = showcase(&k, &a);
        let code = codegen::emit(&k, &a, &dev, &d, &EmitConfig::merlin());
        codegen::lint(&k, &code).unwrap_or_else(|e| panic!("{name}: {e}\n{code}"));
        check_golden(&format!("{name}.merlin.c"), &code);
    }
}

#[test]
fn golden_vitis_representatives() {
    for name in ["gemm", "2mm", "cnn", "lu", "jacobi-2d"] {
        let (k, a, dev) = setup(name);
        let d = showcase(&k, &a);
        let code = codegen::emit(&k, &a, &dev, &d, &EmitConfig::vitis());
        codegen::lint(&k, &code).unwrap_or_else(|e| panic!("{name}: {e}\n{code}"));
        check_golden(&format!("{name}.vitis.c"), &code);
    }
}

#[test]
fn golden_realized_representatives() {
    // realized snapshots pin the §7.5 behaviour: what simulated Merlin
    // accepts is deterministic per (kernel, design), so the emitted
    // refusal comments are stable snapshot material
    for name in ["gemm", "2mm", "gemver"] {
        let (k, a, dev) = setup(name);
        let d = showcase(&k, &a);
        let code = codegen::emit(&k, &a, &dev, &d, &EmitConfig::merlin().realized());
        codegen::lint(&k, &code).unwrap_or_else(|e| panic!("{name}: {e}\n{code}"));
        check_golden(&format!("{name}.merlin.realized.c"), &code);
    }
}

#[test]
fn emission_is_deterministic() {
    for name in ["gemm", "cnn", "durbin"] {
        let (k, a, dev) = setup(name);
        let d = showcase(&k, &a);
        for cfg in [EmitConfig::merlin(), EmitConfig::vitis(), EmitConfig::merlin().realized()] {
            let one = codegen::emit(&k, &a, &dev, &d, &cfg);
            let two = codegen::emit(&k, &a, &dev, &d, &cfg);
            assert_eq!(one, two, "{name}");
        }
    }
}

#[test]
fn realized_pragmas_match_the_realized_design_corpus_wide() {
    // acceptance invariant: the --realized output differs from the
    // requested output exactly where simulated Merlin rejects a pragma
    let pragmas = |code: &str| -> Vec<String> {
        code.lines()
            .map(str::trim_start)
            .filter(|l| l.starts_with("#pragma"))
            .map(str::to_string)
            .collect()
    };
    for name in benchmarks::ALL {
        let (k, a, dev) = setup(name);
        let d = showcase(&k, &a);
        let outcome = nlp_dse::merlin::apply(&k, &a, &dev, &d);
        let requested = codegen::emit(&k, &a, &dev, &d, &EmitConfig::merlin());
        let realized = codegen::emit(&k, &a, &dev, &d, &EmitConfig::merlin().realized());
        let of_realized = codegen::emit(&k, &a, &dev, &outcome.realized, &EmitConfig::merlin());
        assert_eq!(pragmas(&realized), pragmas(&of_realized), "{name}");
        if outcome.realized == d {
            assert_eq!(pragmas(&realized), pragmas(&requested), "{name}");
            assert!(!realized.contains("// not applied:"), "{name}");
        } else {
            assert_ne!(pragmas(&realized), pragmas(&requested), "{name}");
            assert!(realized.contains("// not applied:"), "{name}");
        }
    }
}
