//! End-to-end runtime integration: the AOT artifact (jax → HLO text →
//! PJRT) must reproduce the Rust feature evaluation to 1e-6 relative —
//! the cross-language ABI contract of DESIGN.md §3.
//!
//! Requires `make artifacts`; tests are skipped (pass vacuously, with a
//! note) when the artifacts have not been built.

use nlp_dse::benchmarks::{self, Size};
use nlp_dse::hls::Device;
use nlp_dse::ir::{DType, LoopId};
use nlp_dse::model;
use nlp_dse::nlp::{BatchEvaluator, NlpProblem, RustFeatureEvaluator};
use nlp_dse::poly::Analysis;
use nlp_dse::pragma::Design;
use nlp_dse::runtime::{default_artifact_dir, XlaEvaluator};

fn evaluator() -> Option<XlaEvaluator> {
    match XlaEvaluator::load(&default_artifact_dir()) {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("[skip] artifacts unavailable: {err:#}");
            None
        }
    }
}

#[test]
fn artifact_matches_rust_reference_across_designs() {
    let Some(eval) = evaluator() else { return };
    for name in ["gemm", "2mm", "bicg", "atax", "gesummv", "mvt", "doitgen"] {
        let k = benchmarks::build(name, Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let dev = Device::u200();
        // a spread of designs: empty, pipelined, unrolled
        let mut designs = vec![Design::empty(&k)];
        for i in 0..k.n_loops() {
            if k.loop_meta(LoopId(i as u32)).innermost {
                let mut d = Design::empty(&k);
                d.get_mut(LoopId(i as u32)).pipeline = true;
                designs.push(d.clone());
                if a.tcs[i].is_constant() && a.tcs[i].max % 2 == 0 {
                    d.get_mut(LoopId(i as u32)).uf = 2;
                    designs.push(d);
                }
            }
        }
        let feats: Vec<_> = designs
            .iter()
            .filter_map(|d| model::encode_design(&k, &a, &dev, d))
            .collect();
        assert!(!feats.is_empty(), "{name}");
        let got = eval.eval_features(&feats).expect("execute artifact");
        for (f, (lat_x, dsp_x)) in feats.iter().zip(&got) {
            let (lat_r, dsp_r) = model::eval_features(f);
            let rel = (lat_x - lat_r).abs() / lat_r.abs().max(1.0);
            assert!(rel < 1e-6, "{name}: artifact {lat_x} vs rust {lat_r}");
            let rel_d = (dsp_x - dsp_r).abs() / dsp_r.abs().max(1.0);
            assert!(rel_d < 1e-6, "{name}: dsp {dsp_x} vs {dsp_r}");
        }
    }
}

#[test]
fn artifact_batching_pads_correctly() {
    let Some(eval) = evaluator() else { return };
    let k = benchmarks::build("gemm", Size::Small, DType::F32).unwrap();
    let a = Analysis::new(&k);
    let dev = Device::u200();
    let f = model::encode_design(&k, &a, &dev, &Design::empty(&k)).unwrap();
    // 1 design, then a batch bigger than the artifact batch (forces 2 execs)
    let one = eval.eval_features(&[f.clone()]).unwrap();
    let many = eval.eval_features(&vec![f; eval.batch + 3]).unwrap();
    assert_eq!(many.len(), eval.batch + 3);
    for v in &many {
        assert_eq!(v.0, one[0].0);
        assert_eq!(v.1, one[0].1);
    }
}

#[test]
fn xla_and_rust_evaluators_agree_in_solver_use() {
    let Some(eval) = evaluator() else { return };
    let k = benchmarks::build("bicg", Size::Small, DType::F32).unwrap();
    let a = Analysis::new(&k);
    let dev = Device::u200();
    let p = NlpProblem::new(&k, &a, &dev, 256, false);
    let mut designs = vec![Design::empty(&k)];
    let mut d = Design::empty(&k);
    d.get_mut(LoopId(2)).pipeline = true;
    designs.push(d);
    let via_xla = eval.eval_batch(&p, &designs);
    let via_rust = RustFeatureEvaluator.eval_batch(&p, &designs);
    for (x, r) in via_xla.iter().zip(&via_rust) {
        assert!((x.0 - r.0).abs() / r.0.max(1.0) < 1e-6, "{x:?} vs {r:?}");
    }
}

#[test]
fn full_nlp_solve_through_xla_path() {
    let Some(eval) = evaluator() else { return };
    let k = benchmarks::build("gemm", Size::Small, DType::F32).unwrap();
    let a = Analysis::new(&k);
    let dev = Device::u200();
    let p = NlpProblem::new(&k, &a, &dev, 256, false);
    let via_xla = nlp_dse::nlp::solve(&p, 60.0, 1, &eval);
    let via_rust = nlp_dse::nlp::solve(&p, 60.0, 1, &RustFeatureEvaluator);
    let bx = via_xla.best().expect("xla best").1;
    let br = via_rust.best().expect("rust best").1;
    assert!(
        (bx - br).abs() / br < 1e-9,
        "solver optima must agree: xla {bx} vs rust {br}"
    );
    assert!(eval.executions() > 0, "XLA path must actually execute");
}
