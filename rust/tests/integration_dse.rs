//! Full Algorithm-1 DSE integration over several kernels + the campaign
//! coordinator.

use nlp_dse::benchmarks::{self, Size};
use nlp_dse::coordinator::{engine_names, run_campaign, CampaignConfig};
use nlp_dse::dse::{run_nlp_dse, DseConfig};
use nlp_dse::hls::{Device, HlsOracle};
use nlp_dse::ir::DType;
use nlp_dse::nlp::RustFeatureEvaluator;
use nlp_dse::poly::Analysis;
use nlp_dse::pragma::Design;

fn dse(name: &str, size: Size) -> (nlp_dse::dse::DseOutcome, Analysis, Device) {
    let k = benchmarks::build(name, size, DType::F32).unwrap();
    let a = Analysis::new(&k);
    let dev = Device::u200();
    let o = run_nlp_dse(&k, &a, &dev, &DseConfig::default(), &RustFeatureEvaluator);
    (o, a, dev)
}

#[test]
fn nlpdse_beats_original_across_suite_medium() {
    let dev = Device::u200();
    for name in ["2mm", "gemm", "atax", "bicg", "mvt", "gesummv", "doitgen"] {
        let k = benchmarks::build(name, Size::Medium, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let orig = HlsOracle::new(dev.clone())
            .synth(&k, &a, &Design::empty(&k))
            .gflops(&a, &dev);
        let (o, ..) = dse(name, Size::Medium);
        assert!(
            o.best_gflops >= orig,
            "{name}: NLP-DSE {} < original {orig}",
            o.best_gflops
        );
        assert!(o.designs_explored >= 1, "{name}");
        assert!(o.dse_minutes > 0.0, "{name}");
    }
}

#[test]
fn trace_lower_bounds_ascend_within_ladder() {
    // along the descending partitioning ladder, the per-subspace optima
    // (lower bounds) must be non-decreasing for a fixed parallelism mode
    let (o, ..) = dse("gemm", Size::Medium);
    let mut last_coarse = 0.0f64;
    let mut last_fine = 0.0f64;
    for s in o.trace.iter().filter(|s| s.lower_bound.is_finite()) {
        let slot = if s.fine_only { &mut last_fine } else { &mut last_coarse };
        assert!(
            s.lower_bound >= *slot * 0.999,
            "step {}: LB {} regressed below {}",
            s.step,
            s.lower_bound,
            slot
        );
        *slot = s.lower_bound;
    }
}

#[test]
fn best_design_matches_trace_best() {
    let (o, a, dev) = dse("2mm", Size::Medium);
    let best_trace = o
        .trace
        .iter()
        .filter(|s| s.valid)
        .map(|s| s.gflops)
        .fold(0.0f64, f64::max);
    assert!((o.best_gflops - best_trace).abs() < 1e-9);
    // and the recorded best design re-synthesizes to the same number
    let k = benchmarks::build("2mm", Size::Medium, DType::F32).unwrap();
    let (bd, cycles) = o.best.unwrap();
    let rep = HlsOracle::new(dev.clone()).synth(&k, &a, &bd);
    assert_eq!(rep.cycles, cycles);
}

#[test]
fn fs_design_is_first_valid_in_trace() {
    let (o, ..) = dse("gramschmidt", Size::Medium);
    let first_valid = o.trace.iter().find(|s| s.valid).map(|s| s.gflops);
    assert_eq!(first_valid, Some(o.first_synth_gflops));
}

#[test]
fn campaign_full_row_consistency() {
    let mut cfg = CampaignConfig::quick();
    cfg.kernels = vec![
        ("gemm".into(), Size::Small),
        ("bicg".into(), Size::Small),
    ];
    cfg.engines = engine_names(&["nlpdse", "autodse", "harp"]);
    cfg.tuning.harp.sweep_configs = 2_000;
    let r = run_campaign(&cfg);
    assert_eq!(r.rows.len(), 2);
    for row in &r.rows {
        assert!(row.space_size > 1.0, "{}", row.name);
        assert!(row.nl >= 2);
        assert!(row.original_gflops > 0.0);
        let n = row.nlpdse().unwrap();
        assert!(n.best_gflops >= row.original_gflops * 0.999);
        assert!(n.first_synth_gflops <= n.best_gflops * 1.0001);
    }
}

#[test]
fn harp_ladder_config_runs() {
    let k = benchmarks::build("gemver", Size::Small, DType::F64).unwrap();
    let a = Analysis::new(&k);
    let dev = Device::u200();
    let cfg = DseConfig {
        ladder: DseConfig::harp_ladder(),
        ..DseConfig::default()
    };
    let o = run_nlp_dse(&k, &a, &dev, &cfg, &RustFeatureEvaluator);
    assert!(o.best_gflops > 0.0);
    // 750 is part of the HARP ladder
    assert!(o.trace.iter().any(|s| s.cap == 750));
}

#[test]
fn dse_handles_fully_serial_kernel() {
    // seidel-2d has no legal parallelism: the DSE must still terminate
    // with a valid (pipelined-only) design
    let (o, ..) = dse("seidel-2d", Size::Small);
    assert!(o.best.is_some(), "seidel must still produce a design");
    assert!(o.best_gflops > 0.0);
}

#[test]
fn figure6_narrative_for_2mm() {
    // the paper's Section 8 walk: dedup steps exist (same configs found at
    // neighbouring rungs), and the best design arrives within ~10 steps
    let (o, ..) = dse("2mm", Size::Medium);
    assert!(o.trace.iter().any(|s| s.dedup), "expected dedup steps");
    assert!(o.steps_to_best <= 12, "steps_to_best {}", o.steps_to_best);
    assert!(o.steps_to_terminate <= 22);
}
