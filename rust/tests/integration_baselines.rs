//! Baseline engines vs NLP-DSE: the comparative *shapes* the paper claims.

use nlp_dse::baselines::{run_autodse, run_harp, AutoDseConfig, HarpConfig};
use nlp_dse::benchmarks::{self, Size};
use nlp_dse::dse::{run_nlp_dse, DseConfig};
use nlp_dse::hls::Device;
use nlp_dse::ir::DType;
use nlp_dse::nlp::RustFeatureEvaluator;
use nlp_dse::poly::Analysis;
use nlp_dse::util::stats::mean;

#[test]
fn nlpdse_faster_than_autodse_on_motivation_trio() {
    let dev = Device::u200();
    let mut time_ratios = Vec::new();
    for (name, size) in [
        ("2mm", Size::Medium),
        ("gemm", Size::Medium),
        ("gramschmidt", Size::Large),
    ] {
        let k = benchmarks::build(name, size, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let n = run_nlp_dse(&k, &a, &dev, &DseConfig::default(), &RustFeatureEvaluator);
        let auto = run_autodse(&k, &a, &dev, &AutoDseConfig::default());
        assert!(
            n.dse_minutes < auto.dse_minutes,
            "{name}: NLP-DSE {} min !< AutoDSE {} min",
            n.dse_minutes,
            auto.dse_minutes
        );
        time_ratios.push(auto.dse_minutes / n.dse_minutes);
        // QoR within a band: our AutoDSE baseline hill-climbs on measured
        // values and is stronger than the published tool (EXPERIMENTS.md
        // §Divergences); the reproduction target is the time advantage at
        // near-parity QoR
        assert!(
            n.best_gflops >= auto.best_gflops * 0.5,
            "{name}: NLP-DSE {} ≪ AutoDSE {}",
            n.best_gflops,
            auto.best_gflops
        );
    }
    assert!(
        mean(&time_ratios) > 1.5,
        "mean DSE-time improvement {:.2} too small",
        mean(&time_ratios)
    );
}

#[test]
fn autodse_explores_much_more_than_nlpdse() {
    // Table 5 shape: AutoDSE's DE is an order of magnitude above NLP-DSE's
    let dev = Device::u200();
    let k = benchmarks::build("atax", Size::Medium, DType::F32).unwrap();
    let a = Analysis::new(&k);
    let n = run_nlp_dse(&k, &a, &dev, &DseConfig::default(), &RustFeatureEvaluator);
    let auto = run_autodse(&k, &a, &dev, &AutoDseConfig::default());
    assert!(
        auto.designs_explored as f64 >= 2.0 * n.designs_explored as f64,
        "AutoDSE DE {} vs NLP-DSE DE {}",
        auto.designs_explored,
        n.designs_explored
    );
    assert!(auto.early_rejected > 0, "AutoDSE must hit Merlin rejections");
}

#[test]
fn harp_comparable_time_comparable_qor() {
    // Table 9 shape: NLP-DSE ≥ ~HARP on most kernels, similar DSE time
    let dev = Device::u200();
    let mut wins = 0;
    let mut total = 0;
    for name in ["gemm", "bicg", "mvt", "gesummv", "atax"] {
        let k = benchmarks::build(name, Size::Small, DType::F64).unwrap();
        let a = Analysis::new(&k);
        let n = run_nlp_dse(
            &k,
            &a,
            &dev,
            &DseConfig {
                ladder: DseConfig::harp_ladder(),
                ..DseConfig::default()
            },
            &RustFeatureEvaluator,
        );
        let h = run_harp(
            &k,
            &a,
            &dev,
            &HarpConfig {
                sweep_configs: 10_000,
                ..HarpConfig::default()
            },
        );
        total += 1;
        if n.best_gflops >= h.best_gflops * 0.9 {
            wins += 1;
        }
    }
    assert!(
        wins * 10 >= total * 6,
        "NLP-DSE should match-or-beat HARP on most kernels ({wins}/{total})"
    );
}

#[test]
fn engines_deterministic_cross_run() {
    let dev = Device::u200();
    let k = benchmarks::build("syrk", Size::Small, DType::F32).unwrap();
    let a = Analysis::new(&k);
    let a1 = run_autodse(&k, &a, &dev, &AutoDseConfig::default());
    let a2 = run_autodse(&k, &a, &dev, &AutoDseConfig::default());
    assert_eq!(a1.best_gflops, a2.best_gflops);
    assert_eq!(a1.designs_explored, a2.designs_explored);
    let h1 = run_harp(&k, &a, &dev, &HarpConfig { sweep_configs: 3_000, ..Default::default() });
    let h2 = run_harp(&k, &a, &dev, &HarpConfig { sweep_configs: 3_000, ..Default::default() });
    assert_eq!(h1.best_gflops, h2.best_gflops);
}
