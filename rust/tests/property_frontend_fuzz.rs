//! Generative differential suite: the fuzz extension of the PR 2/3
//! parity tests beyond the fixed corpus. For N seeded random kernels
//! (default **N = 100 per mode**; `FUZZ_KERNELS` overrides, and
//! `FUZZ_SMOKE=1` bounds it for the ci.sh smoke re-run):
//!
//! 1. the three redundant evaluators are mutual oracles —
//!    `CompiledModel::evaluate` ≡ `model::evaluate` ≡ the legacy
//!    formulation walk (`check_legacy` / `objective_reference`) on
//!    random valid designs, and the SoA lane kernel
//!    (`evaluate_batch_soa`) reproduces the scalar tape walk
//!    bit-for-bit over ragged random batches;
//! 2. `solve_jobs(jobs = 4)` is bit-identical to `jobs = 1`, in both
//!    coarse and fine parallelism modes;
//! 3. `BoundModel::lower_bound` is **refinement-monotone**: pinning
//!    additional loops of a partial design never decreases the bound
//!    (the soundness condition behind `--prune-bound`), and stays
//!    admissible against the completion it is refined towards;
//! 4. every generated kernel round-trips through pretty-print → parse;
//! 5. every generated kernel emits lintable pragma-annotated C in both
//!    dialects, and the realized emission's pragma set is exactly the
//!    requested emission of the design Merlin realizes — differing from
//!    the requested emission precisely at refused pragmas.
//!
//! Seeds are logged on entry and every failure panics with the
//! reproducing seed **and the offending `.knl` text**, so any case
//! replays with `FUZZ_SEED=<seed> FUZZ_KERNELS=1`.
//!
//! The `prop_transform_*` suites extend the harness to the pre-pragma
//! loop-transformation layer: every variant the bounded enumerator
//! produces from a generated kernel must carry a machine-checkable
//! legality certificate that replays (`verify_trace`), round-trip
//! through the frontend, and evaluate with the redundant evaluators in
//! agreement; and `dse --transform` must never return a worse
//! objective than the no-transform baseline, bit-reproducibly.
//! `TRANSFORM_FUZZ=1` widens these suites to the full `FUZZ_KERNELS`
//! count (they default smaller — enumeration multiplies the per-seed
//! cost); transform failures additionally print the rewrite trace.

use nlp_dse::codegen::{self, Dialect, EmitConfig};
use nlp_dse::frontend::{self, GenConfig};
use nlp_dse::hls::Device;
use nlp_dse::ir::{Kernel, LoopId};
use nlp_dse::model::{self, sym};
use nlp_dse::nlp::{self, NlpProblem, SolveResult, SymbolicEvaluator};
use nlp_dse::poly::Analysis;
use nlp_dse::pragma::{space, Design, Space};
use nlp_dse::util::{env_usize, rng::Rng};

/// Kernels per suite. The acceptance floor is 100; the CI smoke step
/// re-runs the suites bounded (like `BENCH_SMOKE` for the benches).
fn fuzz_n() -> usize {
    let n = if std::env::var("FUZZ_SMOKE").as_deref() == Ok("1") {
        env_usize("FUZZ_KERNELS", 16)
    } else {
        env_usize("FUZZ_KERNELS", 100)
    };
    n.max(1)
}

const BASE_SEED: u64 = 0xF052_2026;

/// The seed list for one suite, logged for replay.
fn seeds(label: &str) -> Vec<u64> {
    let n = fuzz_n() as u64;
    let base: u64 = std::env::var("FUZZ_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(BASE_SEED)
        .min(u64::MAX - n); // keep the seed range addition-safe
    eprintln!("[fuzz:{label}] {n} kernels, seeds {base}..={}", base + n - 1);
    (base..base + n).collect()
}

/// Panic with everything needed to reproduce: the seed and the kernel
/// as `.knl` text.
fn fail(seed: u64, k: &Kernel, msg: &str) -> ! {
    panic!(
        "\n=== generative fuzz failure ===\n\
         seed: {seed}\n\
         replay: FUZZ_SEED={seed} FUZZ_KERNELS=1 cargo test --test property_frontend_fuzz\n\
         {msg}\n\
         --- offending kernel (.knl) ---\n{}",
        frontend::pretty::print(k)
    )
}

/// Draw a random *legal* design: pipeline antichain from the space,
/// divisor UFs under the Eq 8 caps, occasional divisor tiles — the same
/// shape as the PR 2 parity suite's generator, over arbitrary kernels.
fn random_design(rng: &mut Rng, k: &Kernel, a: &Analysis, s: &Space) -> Design {
    let cfg = s
        .pipeline_configs
        .get(rng.range(0, s.pipeline_configs.len() as u64) as usize)
        .unwrap()
        .clone();
    let ufs: Vec<u64> = (0..k.n_loops())
        .map(|i| {
            let menu = s.ufs(LoopId(i as u32), a, 1024);
            if menu.is_empty() {
                1
            } else {
                menu[rng.range(0, menu.len() as u64) as usize]
            }
        })
        .collect();
    let tiles: Vec<u64> = (0..k.n_loops())
        .map(|i| {
            let tc = &a.tcs[i];
            if tc.is_constant() && tc.max > 0 && rng.chance(0.3) {
                let divs = nlp_dse::util::divisors(tc.max);
                divs[rng.range(0, divs.len() as u64) as usize]
            } else {
                1
            }
        })
        .collect();
    space::materialize(k, a, &cfg, &|l| ufs[l.0 as usize], &|l| tiles[l.0 as usize])
}

#[test]
fn prop_generated_corpus_roundtrips_and_analyzes() {
    for seed in seeds("roundtrip") {
        let k = frontend::generate(&GenConfig::sampled(seed));
        let text = frontend::pretty::print(&k);
        let k2 = match frontend::parse_kernel(&text, "<fuzz>") {
            Ok(k2) => k2,
            Err(e) => fail(seed, &k, &format!("generated kernel failed to reparse:\n{e}")),
        };
        if let Some(diff) = k.structural_diff(&k2) {
            fail(seed, &k, &format!("round-trip diverged: {diff}"));
        }
        // the full static stack must hold on every generated kernel
        let a = Analysis::new(&k);
        let s = Space::new(&k, &a);
        if s.pipeline_configs.is_empty() || s.size() < 1.0 {
            fail(seed, &k, "degenerate design space");
        }
    }
}

#[test]
fn prop_three_evaluators_agree_on_generated_kernels() {
    let dev = Device::u200();
    for seed in seeds("evaluators") {
        let k = frontend::generate(&GenConfig::sampled(seed));
        let a = Analysis::new(&k);
        let s = Space::new(&k, &a);
        let p = NlpProblem::new(&k, &a, &dev, 64, false);
        let mut scratch = p.scratch();
        let mut rng = Rng::new(seed).derive("designs");
        for case in 0..8 {
            let d = random_design(&mut rng, &k, &a, &s);
            let ctx = |what: &str| format!("case {case}, design {}: {what}", d.fingerprint());
            // compiled symbolic tape vs the reference recursion
            let sym_r = p.compiled.evaluate(&d, &mut scratch);
            let ref_r = model::evaluate(&k, &a, &dev, &d);
            let rel = (sym_r.total_cycles - ref_r.total_cycles).abs()
                / ref_r.total_cycles.max(1.0);
            if rel > 1e-9 {
                fail(
                    seed,
                    &k,
                    &ctx(&format!(
                        "latency {} (compiled) vs {} (recursive)",
                        sym_r.total_cycles, ref_r.total_cycles
                    )),
                );
            }
            if sym_r.dsp != ref_r.dsp
                || sym_r.onchip_bytes != ref_r.onchip_bytes
                || sym_r.max_partitioning != ref_r.max_partitioning
                || sym_r.feasible != ref_r.feasible
            {
                fail(
                    seed,
                    &k,
                    &ctx(&format!(
                        "resources diverged: dsp {}/{} onchip {}/{} part {}/{} feas {}/{}",
                        sym_r.dsp,
                        ref_r.dsp,
                        sym_r.onchip_bytes,
                        ref_r.onchip_bytes,
                        sym_r.max_partitioning,
                        ref_r.max_partitioning,
                        sym_r.feasible,
                        ref_r.feasible
                    )),
                );
            }
            // shared-constraint walk vs the legacy hand-written walk
            let o = p.objective(&d);
            let r = p.objective_reference(&d);
            if (o - r).abs() / r.max(1.0) > 1e-9 {
                fail(seed, &k, &ctx(&format!("objective {o} vs legacy reference {r}")));
            }
            let shared = p.check(&d);
            let legacy = p.check_legacy(&d);
            if shared != legacy {
                fail(
                    seed,
                    &k,
                    &ctx(&format!("violations {shared:?} vs legacy {legacy:?}")),
                );
            }
        }
    }
}

#[test]
fn prop_soa_batch_bit_identical_on_generated_kernels() {
    // the SoA lane kernel is the solver's scoring hot path; the fixed
    // benchmark corpus covers it in property_model_sym, this suite
    // covers it over arbitrary generated kernels — ragged batch sizes
    // on purpose so the last-lane padding path runs every seed
    let dev = Device::u200();
    for seed in seeds("soa-batch") {
        let k = frontend::generate(&GenConfig::sampled(seed));
        let a = Analysis::new(&k);
        let s = Space::new(&k, &a);
        let bm = sym::BoundModel::build(&k, &a, &dev);
        let cm = bm.compile();
        let mut scalar = cm.scratch();
        let mut soa = cm.soa_scratch();
        let mut out = Vec::new();
        let mut rng = Rng::new(seed).derive("soa-batches");
        for case in 0..4 {
            let len = rng.range(0, 21) as usize;
            let batch: Vec<Design> = (0..len).map(|_| random_design(&mut rng, &k, &a, &s)).collect();
            cm.evaluate_batch_soa_in(&batch, &mut soa, &mut out);
            if out.len() != batch.len() {
                fail(
                    seed,
                    &k,
                    &format!("case {case}: {} results for {} designs", out.len(), batch.len()),
                );
            }
            for (i, (d, got)) in batch.iter().zip(&out).enumerate() {
                let want = cm.evaluate(d, &mut scalar);
                if want.total_cycles.to_bits() != got.total_cycles.to_bits()
                    || want.comp_cycles.to_bits() != got.comp_cycles.to_bits()
                    || want.comm_cycles.to_bits() != got.comm_cycles.to_bits()
                    || want.dsp.to_bits() != got.dsp.to_bits()
                    || want.onchip_bytes.to_bits() != got.onchip_bytes.to_bits()
                    || want.max_partitioning != got.max_partitioning
                    || want.feasible != got.feasible
                {
                    fail(
                        seed,
                        &k,
                        &format!(
                            "case {case}, lane {i}/{}: SoA diverged from scalar on {}: \
                             {} vs {} cycles, dsp {}/{}, feasible {}/{}",
                            batch.len(),
                            d.fingerprint(),
                            got.total_cycles,
                            want.total_cycles,
                            got.dsp,
                            want.dsp,
                            got.feasible,
                            want.feasible
                        ),
                    );
                }
            }
        }
    }
}

fn diff_results(serial: &SolveResult, par: &SolveResult) -> Option<String> {
    if serial.optimal != par.optimal {
        return Some(format!("optimal {} vs {}", serial.optimal, par.optimal));
    }
    if serial.lower_bound.to_bits() != par.lower_bound.to_bits() {
        return Some(format!(
            "lower bound {} vs {}",
            serial.lower_bound, par.lower_bound
        ));
    }
    if serial.designs.len() != par.designs.len() {
        return Some(format!(
            "top-k {} vs {}",
            serial.designs.len(),
            par.designs.len()
        ));
    }
    for (i, ((d1, o1), (d2, o2))) in serial.designs.iter().zip(&par.designs).enumerate() {
        if d1.fingerprint() != d2.fingerprint() {
            return Some(format!(
                "design #{i}: {} vs {}",
                d1.fingerprint(),
                d2.fingerprint()
            ));
        }
        if o1.to_bits() != o2.to_bits() {
            return Some(format!("objective #{i}: {o1} vs {o2}"));
        }
    }
    None
}

#[test]
fn prop_parallel_solver_bit_identical_on_generated_kernels() {
    let dev = Device::u200();
    for seed in seeds("solver-parity") {
        // keep the per-kernel solve tiny: the suite runs hundreds of
        // (kernel × mode × jobs) searches
        let mut cfg = GenConfig::sampled(seed);
        cfg.max_trip = cfg.max_trip.min(16);
        cfg.depth = cfg.depth.min(2);
        let k = frontend::generate(&cfg);
        let a = Analysis::new(&k);
        for fine in [false, true] {
            let p = NlpProblem::new(&k, &a, &dev, 16, fine);
            let serial = nlp::solve_jobs(&p, 120.0, 3, &SymbolicEvaluator, 1);
            if !serial.optimal {
                fail(
                    seed,
                    &k,
                    &format!("fine={fine}: serial solve did not complete within budget"),
                );
            }
            let par = nlp::solve_jobs(&p, 120.0, 3, &SymbolicEvaluator, 4);
            if let Some(diff) = diff_results(&serial, &par) {
                fail(seed, &k, &format!("fine={fine}, jobs=4 diverged: {diff}"));
            }
        }
    }
}

#[test]
fn prop_lower_bound_monotone_under_refinement() {
    let dev = Device::u200();
    for seed in seeds("bound-monotone") {
        let k = frontend::generate(&GenConfig::sampled(seed));
        let a = Analysis::new(&k);
        let s = Space::new(&k, &a);
        let bm = sym::BoundModel::build(&k, &a, &dev);
        let mut rng = Rng::new(seed).derive("refinement");
        for case in 0..4 {
            // refine the free partial towards a random legal completion,
            // one loop at a time in random order
            let d = random_design(&mut rng, &k, &a, &s);
            let target = model::evaluate(&k, &a, &dev, &d).total_cycles;
            let mut partial = sym::PartialDesign::free(k.n_loops());
            let mut prev = bm.lower_bound(&partial);
            let mut order: Vec<usize> = (0..k.n_loops()).collect();
            rng.shuffle(&mut order);
            for (step, &i) in order.iter().enumerate() {
                let l = LoopId(i as u32);
                partial.assign_uf(l, d.pragmas[i].uf);
                partial.assign_tile(l, d.pragmas[i].tile);
                partial.assign_pipeline(l, d.pragmas[i].pipeline);
                let lb = bm.lower_bound(&partial);
                if lb < prev - prev.abs() * 1e-9 - 1e-9 {
                    fail(
                        seed,
                        &k,
                        &format!(
                            "case {case}: bound DECREASED at step {step} (pinning L{i}): \
                             {prev} -> {lb} (design {})",
                            d.fingerprint()
                        ),
                    );
                }
                if lb > target * (1.0 + 1e-9) {
                    fail(
                        seed,
                        &k,
                        &format!(
                            "case {case}: bound {lb} beats its own completion {target} \
                             at step {step} (design {}) — inadmissible",
                            d.fingerprint()
                        ),
                    );
                }
                prev = lb;
            }
        }
    }
}

/// Kernels per transform suite: enumeration multiplies the per-seed
/// cost, so the default is smaller than `fuzz_n`; `TRANSFORM_FUZZ=1`
/// (the ci.sh smoke step, or a manual deep run) widens to the full
/// count.
fn transform_fuzz_n() -> usize {
    if std::env::var("TRANSFORM_FUZZ").as_deref() == Ok("1") {
        fuzz_n()
    } else {
        fuzz_n().min(12)
    }
}

fn transform_seeds(label: &str) -> Vec<u64> {
    let n = transform_fuzz_n() as u64;
    let base: u64 = std::env::var("FUZZ_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(BASE_SEED)
        .min(u64::MAX - n);
    eprintln!("[fuzz:{label}] {n} kernels, seeds {base}..={}", base + n - 1);
    (base..base + n).collect()
}

/// `fail`, plus the rewrite chain that produced the offending variant.
fn fail_variant(seed: u64, k: &Kernel, trace: &[String], msg: &str) -> ! {
    let chain = if trace.is_empty() {
        "(original)".to_string()
    } else {
        trace.join(" ; ")
    };
    fail(seed, k, &format!("variant [{chain}]: {msg}"))
}

/// Deterministic enumeration bounds for the fuzz suites — small enough
/// that (variants × evaluations) stays tractable across the corpus,
/// and identical on replay (satellite: seed-reproducible transforms).
fn fuzz_tcfg() -> nlp_dse::transform::TransformConfig {
    nlp_dse::transform::TransformConfig {
        max_variants: 6,
        max_depth: 1,
        max_perm_loops: 3,
    }
}

#[test]
fn prop_transform_variants_certified_roundtrip_and_evaluate() {
    use nlp_dse::transform::{enumerate, verify_trace};
    let dev = Device::u200();
    for seed in transform_seeds("transform-legality") {
        let mut cfg = GenConfig::sampled(seed);
        cfg.max_trip = cfg.max_trip.min(16);
        let k = frontend::generate(&cfg);
        let variants = enumerate(&k, &fuzz_tcfg());
        if variants.is_empty() || !variants[0].is_original() {
            fail(seed, &k, "enumeration must lead with the original variant");
        }
        for v in &variants {
            let trace = v.trace_strings();
            // every admitted rewrite's certificate replays from scratch
            if let Err(e) = verify_trace(&k, v) {
                fail_variant(seed, &k, &trace, &format!("certificate replay failed: {e}"));
            }
            // transformed kernels stay inside the DSL's program class
            let text = frontend::pretty::print(&v.kernel);
            match frontend::parse_kernel(&text, "<fuzz-transform>") {
                Ok(k2) => {
                    if let Some(diff) = v.kernel.structural_diff(&k2) {
                        fail_variant(seed, &k, &trace, &format!("round-trip diverged: {diff}"));
                    }
                }
                Err(e) => fail_variant(seed, &k, &trace, &format!("reparse failed:\n{e}")),
            }
            // and the full evaluation stack holds on each of them: the
            // space is non-degenerate and the redundant evaluators agree
            let a = Analysis::new(&v.kernel);
            let s = Space::new(&v.kernel, &a);
            if s.pipeline_configs.is_empty() || s.size() < 1.0 {
                fail_variant(seed, &k, &trace, "degenerate design space");
            }
            let p = NlpProblem::new(&v.kernel, &a, &dev, 64, false);
            let mut scratch = p.scratch();
            let mut rng = Rng::new(seed).derive("transform-designs");
            for case in 0..2 {
                let d = random_design(&mut rng, &v.kernel, &a, &s);
                let sym_r = p.compiled.evaluate(&d, &mut scratch);
                let ref_r = model::evaluate(&v.kernel, &a, &dev, &d);
                let rel = (sym_r.total_cycles - ref_r.total_cycles).abs()
                    / ref_r.total_cycles.max(1.0);
                if rel > 1e-9 || sym_r.feasible != ref_r.feasible {
                    fail_variant(
                        seed,
                        &k,
                        &trace,
                        &format!(
                            "case {case}: evaluators diverged on design {}: \
                             {} vs {} cycles, feasible {}/{}",
                            d.fingerprint(),
                            sym_r.total_cycles,
                            ref_r.total_cycles,
                            sym_r.feasible,
                            ref_r.feasible
                        ),
                    );
                }
                if p.check(&d) != p.check_legacy(&d) {
                    fail_variant(
                        seed,
                        &k,
                        &trace,
                        &format!("case {case}: constraint walks disagree on {}", d.fingerprint()),
                    );
                }
            }
        }
    }
}

#[test]
fn prop_transform_dse_never_worse_and_reproducible() {
    use nlp_dse::transform::run_transform_dse;
    let dev = Device::u200();
    let dse_cfg = nlp_dse::dse::DseConfig {
        jobs: 1,
        ..Default::default()
    };
    let tcfg = fuzz_tcfg();
    // PolyBench slice + generated corpus (the replayed `gen` kernels)
    let mut kernels: Vec<(u64, Kernel)> = vec![
        (0, nlp_dse::benchmarks::build("mvt", nlp_dse::benchmarks::Size::Small, nlp_dse::ir::DType::F32).unwrap()),
        (0, nlp_dse::benchmarks::build("atax", nlp_dse::benchmarks::Size::Small, nlp_dse::ir::DType::F32).unwrap()),
    ];
    for seed in transform_seeds("transform-dse").into_iter().take(4) {
        let mut cfg = GenConfig::sampled(seed);
        cfg.max_trip = cfg.max_trip.min(16);
        cfg.depth = cfg.depth.min(2);
        kernels.push((seed, frontend::generate(&cfg)));
    }
    for (seed, k) in &kernels {
        let o = run_transform_dse(k, &dev, &dse_cfg, &tcfg, &SymbolicEvaluator);
        let baseline = &o.records[0];
        if baseline.index != 0 || baseline.pruned {
            fail(*seed, k, "variant 0 (the original) must always run unpruned");
        }
        // never worse than the no-transform baseline
        if let (Some(base), Some((_, best))) = (baseline.cycles, &o.outcome.best) {
            if *best > base * (1.0 + 1e-12) {
                fail(
                    *seed,
                    k,
                    &format!(
                        "winner [{:?}] measured {best} cycles, worse than the \
                         no-transform baseline {base}",
                        o.winning_trace()
                    ),
                );
            }
        }
        // the winner's trace replays and its certificates verify
        if let Err(e) = nlp_dse::transform::verify_trace(k, &o.variant) {
            fail(*seed, k, &format!("winning trace failed verification: {e}"));
        }
        // bit-reproducible: the same knobs replay to the same outcome
        let o2 = run_transform_dse(k, &dev, &dse_cfg, &tcfg, &SymbolicEvaluator);
        let same = o.winner == o2.winner
            && o.records.len() == o2.records.len()
            && o.outcome.best.as_ref().map(|(_, c)| c.to_bits())
                == o2.outcome.best.as_ref().map(|(_, c)| c.to_bits());
        if !same {
            fail(
                *seed,
                k,
                &format!(
                    "transform DSE not reproducible: winner {} vs {}, \
                     {} vs {} records",
                    o.winner,
                    o2.winner,
                    o.records.len(),
                    o2.records.len()
                ),
            );
        }
    }
}

#[test]
fn prop_emission_lints_and_realized_diffs_only_at_rejects() {
    let dev = Device::u200();
    let pragma_lines = |code: &str| -> Vec<String> {
        code.lines()
            .map(str::trim_start)
            .filter(|l| l.starts_with("#pragma"))
            .map(str::to_string)
            .collect()
    };
    for seed in seeds("emission") {
        let k = frontend::generate(&GenConfig::sampled(seed));
        let a = Analysis::new(&k);
        let s = Space::new(&k, &a);
        let mut rng = Rng::new(seed).derive("emit-designs");
        for case in 0..4 {
            let d = random_design(&mut rng, &k, &a, &s);
            for dialect in [Dialect::Merlin, Dialect::Vitis] {
                let cfg = EmitConfig {
                    dialect,
                    realized: false,
                };
                let code = codegen::emit(&k, &a, &dev, &d, &cfg);
                if let Err(e) = codegen::lint(&k, &code) {
                    fail(
                        seed,
                        &k,
                        &format!(
                            "case {case} ({}, requested): lint failed: {e}\n--- C ---\n{code}",
                            dialect.name()
                        ),
                    );
                }
                let real_cfg = EmitConfig {
                    dialect,
                    realized: true,
                };
                let realized = codegen::emit(&k, &a, &dev, &d, &real_cfg);
                if let Err(e) = codegen::lint(&k, &realized) {
                    fail(
                        seed,
                        &k,
                        &format!(
                            "case {case} ({}, realized): lint failed: {e}\n--- C ---\n{realized}",
                            dialect.name()
                        ),
                    );
                }
                // the realized emission's pragma set is the requested
                // emission of the design Merlin actually implements
                let outcome = nlp_dse::merlin::apply(&k, &a, &dev, &d);
                let of_realized = codegen::emit(&k, &a, &dev, &outcome.realized, &cfg);
                if pragma_lines(&realized) != pragma_lines(&of_realized) {
                    fail(
                        seed,
                        &k,
                        &format!(
                            "case {case} ({}): realized pragma set diverged from the \
                             realized design's own emission (design {})",
                            dialect.name(),
                            d.fingerprint()
                        ),
                    );
                }
                let code_p = pragma_lines(&code);
                let real_p = pragma_lines(&realized);
                let refused = outcome.realized != d;
                if refused && real_p == code_p && dialect == Dialect::Merlin {
                    fail(
                        seed,
                        &k,
                        &format!(
                            "case {case}: merlin refused pragmas (design {}) but the \
                             realized emission's pragma set did not change",
                            d.fingerprint()
                        ),
                    );
                }
                if !refused && real_p != code_p {
                    fail(
                        seed,
                        &k,
                        &format!(
                            "case {case} ({}): nothing was refused (design {}) but the \
                             realized pragma set changed",
                            dialect.name(),
                            d.fingerprint()
                        ),
                    );
                }
            }
        }
    }
}
