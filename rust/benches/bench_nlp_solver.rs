//! NLP solver end-to-end benchmark: one solve per kernel × partitioning
//! rung. These times stand in for the paper's BARON columns (Table 7) and
//! dominate the serial phase of Algorithm 1.

use nlp_dse::benchmarks::{self, Size};
use nlp_dse::hls::Device;
use nlp_dse::ir::DType;
use nlp_dse::nlp::{self, NlpProblem, RustFeatureEvaluator};
use nlp_dse::poly::Analysis;
use nlp_dse::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("nlp_solver");
    let dev = Device::u200();
    for (name, size) in [
        ("gemm", Size::Medium),
        ("2mm", Size::Medium),
        ("2mm", Size::Large),
        ("3mm", Size::Medium),
        ("gemver", Size::Medium),
        ("atax", Size::Large),
    ] {
        let k = benchmarks::build(name, size, DType::F32).unwrap();
        let a = Analysis::new(&k);
        for cap in [u64::MAX, 512, 64] {
            let p = NlpProblem::new(&k, &a, &dev, cap, false);
            let tag = if cap == u64::MAX {
                "inf".to_string()
            } else {
                cap.to_string()
            };
            b.bench(&format!("solve/{name}-{}/cap={tag}", size.tag()), || {
                black_box(nlp::solve(&p, 30.0, 1, &RustFeatureEvaluator));
            });
        }
    }
    b.finish();
}
