//! NLP solver end-to-end benchmark: one solve per kernel × partitioning
//! rung × worker count. These times stand in for the paper's BARON
//! columns (Table 7) and dominate the serial phase of Algorithm 1.
//!
//! Beyond the per-case timing harness, every case reports **nodes/s and
//! configs/s** (the search-orchestration throughput the parallel solver
//! targets) and the run writes a repo-root `BENCH_solver.json`:
//!
//! ```text
//! { "<kernel>-<size>/cap=<c>/jobs=<n>":
//!     { "wall_s", "nodes", "nodes_per_s", "configs", "configs_per_s",
//!       "threads", "steals", "queue_idle_s", "speedup_vs_jobs1" }, ... }
//! ```
//!
//! The scaling rows (3mm-M at 1/2/4/8 threads) are the EXPERIMENTS.md
//! scaling table; `steals` and `queue_idle_s` expose the work-stealing
//! scheduler's balance (steals stay rare when the bound-ascending deal
//! is even; idle time is what stealing failed to hide). `BENCH_SMOKE=1`
//! shrinks the matrix to the smallest kernel and {1, 2} threads — the
//! ci.sh bench-smoke step, so the bench (and its JSON emission) can't
//! rot. When `BENCH_BASELINE` names a prior `BENCH_solver.json`, the
//! run ends with a regression gate: any tag whose fresh configs/s falls
//! more than `BENCH_TOLERANCE` percent (default 20) below the baseline
//! row exits non-zero.

use nlp_dse::benchmarks::{self, Size};
use nlp_dse::hls::Device;
use nlp_dse::ir::DType;
use nlp_dse::nlp::{self, NlpProblem, RustFeatureEvaluator, SolveResult};
use nlp_dse::poly::Analysis;
use nlp_dse::util::bench::{black_box, Bench};
use nlp_dse::util::json::Json;

struct Case {
    tag: String,
    wall_s: f64,
    nodes: u64,
    configs: u64,
    threads: usize,
    steals: u64,
    queue_idle_s: f64,
    speedup_vs_jobs1: Option<f64>,
}

fn record(cases: &mut Vec<Case>, tag: &str, r: &SolveResult, baseline_wall: Option<f64>) {
    println!(
        "    {tag}: {:.1} knodes/s, {:.1} configs/s ({} nodes, {} configs, {} steal(s), {:.4}s idle, {:.3}s)",
        r.stats.nodes as f64 / r.solve_time_s.max(1e-9) / 1e3,
        r.stats.configs as f64 / r.solve_time_s.max(1e-9),
        r.stats.nodes,
        r.stats.configs,
        r.stats.steals,
        r.stats.queue_idle_s,
        r.solve_time_s
    );
    cases.push(Case {
        tag: tag.to_string(),
        wall_s: r.solve_time_s,
        nodes: r.stats.nodes,
        configs: r.stats.configs,
        threads: r.jobs,
        steals: r.stats.steals,
        queue_idle_s: r.stats.queue_idle_s,
        speedup_vs_jobs1: baseline_wall.map(|b| b / r.solve_time_s.max(1e-9)),
    });
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let mut b = Bench::new("nlp_solver");
    let dev = Device::u200();
    let mut cases: Vec<Case> = Vec::new();

    let matrix: Vec<(&str, Size)> = if smoke {
        vec![("gemm", Size::Small)]
    } else {
        vec![
            ("gemm", Size::Medium),
            ("2mm", Size::Medium),
            ("2mm", Size::Large),
            ("3mm", Size::Medium),
            ("gemver", Size::Medium),
            ("atax", Size::Large),
        ]
    };
    let caps: &[u64] = if smoke { &[u64::MAX] } else { &[u64::MAX, 512, 64] };

    for (name, size) in &matrix {
        let k = benchmarks::build(name, *size, DType::F32).unwrap();
        let a = Analysis::new(&k);
        for &cap in caps {
            let p = NlpProblem::new(&k, &a, &dev, cap, false);
            let tag = if cap == u64::MAX {
                "inf".to_string()
            } else {
                cap.to_string()
            };
            // capture the last timed iteration's result for the JSON row
            // instead of paying one extra un-timed solve (the
            // bench_tables pattern)
            let mut last = None;
            b.bench(&format!("solve/{name}-{}/cap={tag}", size.tag()), || {
                last = Some(black_box(nlp::solve(&p, 30.0, 1, &RustFeatureEvaluator)));
            });
            let r = last.expect("bench ran at least once");
            record(
                &mut cases,
                &format!("{name}-{}/cap={tag}/jobs=1", size.tag()),
                &r,
                None,
            );
        }
    }

    // ---- scaling: the parallel worker team on one Medium kernel --------
    // (3mm-M, the EXPERIMENTS.md scaling table; parity with jobs=1 is
    // property-tested, so this only measures wall clock)
    let (scale_kernel, scale_size) = if smoke {
        ("gemm", Size::Small)
    } else {
        ("3mm", Size::Medium)
    };
    let jobs_ladder: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4, 8] };
    let k = benchmarks::build(scale_kernel, scale_size, DType::F32).unwrap();
    let a = Analysis::new(&k);
    let p = NlpProblem::new(&k, &a, &dev, u64::MAX, false);
    // the matrix loop already benched and recorded the jobs=1 case for
    // this kernel (same tag) — reuse its wall as the speedup denominator
    // instead of paying another full solve
    let baseline_tag = format!("{scale_kernel}-{}/cap=inf/jobs=1", scale_size.tag());
    let baseline_wall: Option<f64> = cases
        .iter()
        .find(|c| c.tag == baseline_tag)
        .map(|c| c.wall_s);
    for &jobs in jobs_ladder {
        if jobs == 1 {
            continue; // already covered by the matrix loop
        }
        let mut last = None;
        b.bench(
            &format!("solve/{scale_kernel}-{}/jobs={jobs}", scale_size.tag()),
            || {
                last = Some(black_box(nlp::solve_jobs(
                    &p,
                    30.0,
                    1,
                    &RustFeatureEvaluator,
                    jobs,
                )));
            },
        );
        let r = last.expect("bench ran at least once");
        record(
            &mut cases,
            &format!(
                "{scale_kernel}-{}/cap=inf/jobs={jobs}",
                scale_size.tag()
            ),
            &r,
            baseline_wall,
        );
    }

    // ---- repo-root BENCH_solver.json ------------------------------------
    // cargo runs bench binaries with cwd = the package dir (rust/), so
    // anchor on the manifest to land the file at the workspace root
    let mut out = Json::obj();
    for c in &cases {
        let mut row = Json::obj();
        row.set("wall_s", c.wall_s)
            .set("nodes", c.nodes)
            .set("nodes_per_s", c.nodes as f64 / c.wall_s.max(1e-9))
            .set("configs", c.configs)
            .set("configs_per_s", c.configs as f64 / c.wall_s.max(1e-9))
            .set("threads", c.threads)
            .set("steals", c.steals)
            .set("queue_idle_s", c.queue_idle_s);
        if let Some(s) = c.speedup_vs_jobs1 {
            row.set("speedup_vs_jobs1", s);
        }
        out.set(&c.tag, row);
    }
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_solver.json");
    std::fs::write(&path, out.to_string_pretty()).expect("write BENCH_solver.json");
    println!("wrote {} ({} rows)", path.display(), cases.len());
    b.finish();

    // ---- regression gate (the ci.sh bench smoke) -----------------------
    // BENCH_BASELINE names the committed BENCH_solver.json, stashed by
    // ci.sh before this run overwrote it. Rows are matched by tag; a
    // fresh configs/s more than BENCH_TOLERANCE percent (default 20)
    // below the baseline fails the run. Tags on only one side (new
    // kernels, changed matrices) are skipped — the gate guards
    // throughput, not matrix shape.
    if let Ok(baseline_path) = std::env::var("BENCH_BASELINE") {
        let tol: f64 = std::env::var("BENCH_TOLERANCE")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(20.0);
        let text = std::fs::read_to_string(&baseline_path)
            .unwrap_or_else(|e| panic!("read baseline {baseline_path}: {e}"));
        let base = Json::parse(&text)
            .unwrap_or_else(|e| panic!("parse baseline {baseline_path}: {e}"));
        let mut compared = 0u32;
        let mut regressed = 0u32;
        for c in &cases {
            let was = base
                .get(&c.tag)
                .and_then(|row| row.get("configs_per_s"))
                .and_then(|v| v.as_f64());
            let Some(was) = was else { continue };
            if was <= 0.0 {
                continue;
            }
            compared += 1;
            let now = c.configs as f64 / c.wall_s.max(1e-9);
            if now < was * (1.0 - tol / 100.0) {
                regressed += 1;
                eprintln!(
                    "REGRESSION {}: {now:.1} configs/s vs baseline {was:.1} (> {tol}% below)",
                    c.tag
                );
            }
        }
        println!(
            "regression gate: {compared} row(s) compared against {baseline_path} (tolerance {tol}%)"
        );
        if regressed > 0 {
            eprintln!("{regressed} bench row(s) regressed past the {tol}% tolerance");
            std::process::exit(1);
        }
    }
}
