//! Surrogate-engine benchmarks (ISSUE 10): what training costs, how
//! much faster ranking is than exact scoring, and what the rank cut
//! buys on a whole ladder.
//!
//! Cases:
//!
//! * `train/...` — closed-form ridge training end to end (corpus
//!   sampling + labeling + fit), throughput in labeled samples/s;
//! * `predict/...` vs `exact-score/...` — ranking a solver-wave-sized
//!   design set with the surrogate against scoring it with the exact
//!   compiled model: the per-candidate speedup the rank cut monetizes;
//! * `exact-ladder/...` vs `rank-cut/...` — the `surrogate` engine at
//!   `verify_fraction = 1.0` (bit-identical to the `nlpdse` ladder) and
//!   at `0.35`: the end-to-end wall-clock difference.
//!
//! `BENCH_SMOKE=1` shrinks the matrix to mvt-S and the tiny corpus (the
//! ci.sh bench-smoke loop), keeping the bench compiling and honest.

use nlp_dse::benchmarks::{self, Size};
use nlp_dse::engine::{Evaluator, Explorer};
use nlp_dse::hls::Device;
use nlp_dse::ir::{DType, LoopId};
use nlp_dse::model::BoundModel;
use nlp_dse::poly::Analysis;
use nlp_dse::pragma::{space, Design, Space};
use nlp_dse::surrogate::{sample_corpus, train, SurrogateConfig, TrainConfig};
use nlp_dse::util::bench::{black_box, Bench};
use nlp_dse::util::rng::Rng;

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let mut b = Bench::new("surrogate");

    // --- training throughput -------------------------------------------
    let tcfg = if smoke {
        TrainConfig {
            kernels: 2,
            designs: 6,
            ..TrainConfig::default()
        }
    } else {
        TrainConfig::micro()
    };
    let n_samples = sample_corpus(&tcfg).xs.len() as f64;
    b.bench_with_items(
        &format!("train/k={} d={}", tcfg.kernels, tcfg.designs),
        n_samples,
        || {
            black_box(train(&tcfg).model.content_hash());
        },
    );

    // --- rank vs exact scoring over one solver-wave-sized set ----------
    let model = train(&tcfg).model;
    let k = benchmarks::build("gemm", Size::Small, DType::F32).unwrap();
    let a = Analysis::new(&k);
    let dev = Device::u200();
    let sp = Space::new(&k, &a);
    let mut rng = Rng::new(7);
    let wave = if smoke { 64 } else { 256 };
    let designs: Vec<Design> = (0..wave)
        .map(|_| {
            let pcfg =
                &sp.pipeline_configs[rng.range(0, sp.pipeline_configs.len() as u64) as usize];
            let drawn: Vec<u64> = (0..k.n_loops())
                .map(|i| {
                    let menu = sp.ufs(LoopId(i as u32), &a, dev.max_array_partition);
                    if menu.is_empty() {
                        1
                    } else {
                        menu[rng.range(0, menu.len() as u64) as usize]
                    }
                })
                .collect();
            space::materialize(&k, &a, pcfg, &|l: LoopId| drawn[l.0 as usize], &|_| 1)
        })
        .collect();

    b.bench_with_items(&format!("predict/gemm-S x{wave}"), wave as f64, || {
        let mut acc = 0.0;
        for d in &designs {
            acc += model.predict(&k, &a, &dev, d).unwrap_or(0.0);
        }
        black_box(acc);
    });

    let bound = BoundModel::build(&k, &a, &dev);
    let compiled = bound.compile();
    let mut scratch = compiled.scratch();
    b.bench_with_items(&format!("exact-score/gemm-S x{wave}"), wave as f64, || {
        let mut acc = 0.0;
        for d in &designs {
            acc += compiled.evaluate(d, &mut scratch).total_cycles;
        }
        black_box(acc);
    });

    // --- whole-ladder wall clock: exact vs rank-cut ---------------------
    let dse_names: &[&str] = if smoke { &["mvt"] } else { &["mvt", "gemm"] };
    for name in dse_names {
        for (case, frac) in [("exact-ladder", 1.0), ("rank-cut", 0.35)] {
            let sur = SurrogateConfig {
                model: Some(model.clone()),
                verify_fraction: frac,
                ..SurrogateConfig::default()
            };
            b.bench(&format!("{case}/{name}-S"), || {
                let out = Explorer::kernel(name, Size::Small)
                    .unwrap()
                    .evaluator(Evaluator::sym())
                    .jobs(1)
                    .surrogate_config(sur.clone())
                    .engine("surrogate")
                    .unwrap()
                    .run()
                    .unwrap();
                black_box(out.best_gflops);
            });
        }
    }

    b.finish();
}
