//! Model-evaluation hot path: precise recursive model vs feature encoding
//! vs encoded-formula evaluation vs the compiled symbolic tape, per
//! kernel. These are the L3 costs the NLP solver pays per candidate — the
//! target of the §Perf pass.
//!
//! The headline comparison for the symbolic bound-model IR is
//! `evaluate/*` (legacy recursion, one design) against `sym_eval/*`
//! (compiled tape, one design), `sym_eval_batch64/*` (AoS: the scalar
//! tape per design, shared scratch), and `sym_eval_batch64_soa/*` (the
//! node-major SoA lane kernel, same 64-design batch) — the acceptance
//! bars are sym_eval ≤ evaluate and batch64_soa ≤ batch64 per design.
//! `sym_eval_soa_sweep/*/n={1,8,64,512}` shows where lane-width padding
//! stops dominating (n=1 pays 7 dead lanes; by n≥8 every lane is
//! live). `sym_build/*` and `sym_compile/*` are the once-per-kernel
//! setup costs; `sym_lower_bound/*` is the interval pass the DSE's
//! partial-config pruning pays per rung.

use nlp_dse::benchmarks::{self, Size};
use nlp_dse::hls::Device;
use nlp_dse::ir::{DType, LoopId};
use nlp_dse::model::{self, sym};
use nlp_dse::poly::Analysis;
use nlp_dse::pragma::Design;
use nlp_dse::util::bench::{black_box, Bench};

fn main() {
    // BENCH_SMOKE=1 (the ci.sh bench-smoke step): one Small kernel only
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let mut b = Bench::new("model_eval");
    let dev = Device::u200();
    let kernels: &[&str] = if smoke {
        &["gemm"]
    } else {
        &["gemm", "2mm", "gemver", "heat-3d", "cnn"]
    };
    let size = if smoke { Size::Small } else { Size::Medium };
    for &name in kernels {
        let k = benchmarks::build(name, size, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let d = Design::empty(&k);
        b.bench(&format!("analysis/{name}"), || {
            black_box(Analysis::new(&k));
        });
        b.bench(&format!("evaluate/{name}"), || {
            black_box(model::evaluate(&k, &a, &dev, &d));
        });
        b.bench(&format!("encode/{name}"), || {
            black_box(model::encode_design(&k, &a, &dev, &d));
        });
        let f = model::encode_design(&k, &a, &dev, &d).unwrap();
        b.bench(&format!("eval_features/{name}"), || {
            black_box(model::eval_features(&f));
        });

        // --- the symbolic bound-model consumers --------------------------
        b.bench(&format!("sym_build/{name}"), || {
            black_box(sym::BoundModel::build(&k, &a, &dev));
        });
        let bm = sym::BoundModel::build(&k, &a, &dev);
        b.bench(&format!("sym_compile/{name}"), || {
            black_box(bm.compile());
        });
        let cm = bm.compile();
        let mut scratch = cm.scratch();
        b.bench(&format!("sym_eval/{name}"), || {
            black_box(cm.evaluate(&d, &mut scratch));
        });
        // a batch with varied unrolls, the solver's bulk-scoring shape:
        // AoS (design-major scalar walks) vs SoA (node-major lanes) at
        // the headline size 64, then a sweep over batch sizes to show
        // where the lane kernel starts paying for its setup
        let batch: Vec<Design> = (0..512u64)
            .map(|i| {
                let mut dd = Design::empty(&k);
                dd.get_mut(LoopId(0)).uf = 1 + (i % 4);
                dd
            })
            .collect();
        b.bench_with_items(&format!("sym_eval_batch64/{name}"), 64.0, || {
            black_box(cm.evaluate_batch(&batch[..64]));
        });
        let mut soa = cm.soa_scratch();
        let mut out = Vec::new();
        b.bench_with_items(&format!("sym_eval_batch64_soa/{name}"), 64.0, || {
            cm.evaluate_batch_soa_in(&batch[..64], &mut soa, &mut out);
            black_box(&out);
        });
        for n in [1usize, 8, 64, 512] {
            b.bench_with_items(&format!("sym_eval_soa_sweep/{name}/n={n}"), n as f64, || {
                cm.evaluate_batch_soa_in(&batch[..n], &mut soa, &mut out);
                black_box(&out);
            });
        }
        let free = sym::PartialDesign::free(k.n_loops());
        b.bench(&format!("sym_lower_bound/{name}"), || {
            black_box(bm.lower_bound(&free));
        });
    }
    b.finish();
}
