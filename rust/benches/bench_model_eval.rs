//! Model-evaluation hot path: precise recursive model vs feature encoding
//! vs encoded-formula evaluation, per kernel. These are the L3 costs the
//! NLP solver pays per candidate — the target of the §Perf pass.

use nlp_dse::benchmarks::{self, Size};
use nlp_dse::hls::Device;
use nlp_dse::ir::DType;
use nlp_dse::model;
use nlp_dse::poly::Analysis;
use nlp_dse::pragma::Design;
use nlp_dse::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("model_eval");
    let dev = Device::u200();
    for name in ["gemm", "2mm", "gemver", "heat-3d", "cnn"] {
        let k = benchmarks::build(name, Size::Medium, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let d = Design::empty(&k);
        b.bench(&format!("analysis/{name}"), || {
            black_box(Analysis::new(&k));
        });
        b.bench(&format!("evaluate/{name}"), || {
            black_box(model::evaluate(&k, &a, &dev, &d));
        });
        b.bench(&format!("encode/{name}"), || {
            black_box(model::encode_design(&k, &a, &dev, &d));
        });
        let f = model::encode_design(&k, &a, &dev, &d).unwrap();
        b.bench(&format!("eval_features/{name}"), || {
            black_box(model::eval_features(&f));
        });
    }
    b.finish();
}
