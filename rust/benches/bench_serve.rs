//! Serve-path benchmarks (ISSUE 6): what the warm cache actually buys.
//!
//! All cases drive [`nlp_dse::serve::handle_line`] in-process — the
//! daemon minus the socket — so the numbers isolate dispatch + cache +
//! solve, not TCP. Cases:
//!
//! * `fingerprint/<kernel>` — the per-request key derivation (two hash
//!   walks); this is the cache's fixed overhead on every solve;
//! * `parse+dispatch/stats` — protocol floor: parse a request line,
//!   run the cheapest op, serialize the response;
//! * `solve-miss/<kernel>` — cold solve including bound-model build
//!   (fresh state each iteration, nothing reusable);
//! * `solve-hit/<kernel>` — the same request against a primed cache:
//!   the bit-identical replay path the ISSUE's acceptance names.
//!
//! `BENCH_SMOKE=1` shrinks the matrix to gemm-S (the ci.sh bench-smoke
//! loop), keeping the bench compiling and honest.

use nlp_dse::benchmarks::{self, Size};
use nlp_dse::ir::DType;
use nlp_dse::serve::{fingerprint, handle_line, ServeConfig, ServeState};
use nlp_dse::util::bench::{black_box, Bench};

fn state() -> ServeState {
    ServeState::new(ServeConfig {
        jobs: 1,
        cache_entries: 16,
    })
}

/// Run one request line, discarding events (the sink is what the TCP
/// writer would be).
fn drive(state: &ServeState, line: &str) {
    let mut sink = |l: &str| {
        black_box(l.len());
    };
    handle_line(state, line, &mut sink);
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let mut b = Bench::new("serve");

    let kernels: &[(&str, &str)] = if smoke {
        &[("gemm", "S")]
    } else {
        &[("gemm", "S"), ("atax", "S"), ("bicg", "S")]
    };

    for (name, size) in kernels {
        let k = benchmarks::lookup(name, Size::parse(size).unwrap(), DType::F32).unwrap();
        b.bench(&format!("fingerprint/{name}-{size}"), || {
            black_box(fingerprint(&k));
        });
    }

    {
        let st = state();
        b.bench("parse+dispatch/stats", || {
            drive(&st, r#"{"op":"stats"}"#);
        });
    }

    for (name, size) in kernels {
        let req = format!(r#"{{"op":"solve","kernel":"{name}","size":"{size}","cap":16}}"#);
        // cold path: a fresh daemon state per iteration — model build +
        // full branch-and-bound every time
        b.bench(&format!("solve-miss/{name}-{size}"), || {
            let st = state();
            drive(&st, &req);
        });
        // hot path: primed cache, every iteration replays the stored
        // result (lookup + reserialization only)
        let st = state();
        drive(&st, &req);
        b.bench(&format!("solve-hit/{name}-{size}"), || {
            drive(&st, &req);
        });
    }

    b.finish();
}
