//! Emission throughput of the pragma-annotated C backend: per-kernel
//! latency in both dialects (+ realized mode, which folds in a full
//! simulated-Merlin run) and whole-corpus kernels/s — the cost of
//! dumping every campaign row's best design (`campaign --emit-dir`).

use nlp_dse::benchmarks::{self, Size};
use nlp_dse::codegen::{self, EmitConfig};
use nlp_dse::hls::Device;
use nlp_dse::ir::{DType, Kernel, LoopId};
use nlp_dse::poly::Analysis;
use nlp_dse::pragma::Design;
use nlp_dse::util::bench::{black_box, Bench};

/// The golden suite's deterministic showcase design (same construction
/// as `tests/codegen_golden.rs`): pipeline + unroll innermost loops,
/// tile nest roots — so the throughput numbers describe the snapshot
/// corpus.
fn showcase(k: &Kernel, a: &Analysis) -> Design {
    let mut d = Design::empty(k);
    for i in 0..k.n_loops() {
        let l = LoopId(i as u32);
        let meta = k.loop_meta(l);
        let tc = &a.tcs[i];
        if meta.innermost {
            d.get_mut(l).pipeline = true;
            if tc.is_constant() && tc.max > 1 {
                d.get_mut(l).uf = nlp_dse::util::divisors(tc.max)
                    .into_iter()
                    .filter(|&x| x <= 8)
                    .max()
                    .unwrap_or(1);
            }
        } else if meta.parent.is_none() && tc.is_constant() && tc.max > 1 {
            d.get_mut(l).tile = nlp_dse::util::divisors(tc.max)
                .into_iter()
                .filter(|&x| x <= 4)
                .max()
                .unwrap_or(1);
        }
    }
    d
}

fn main() {
    // BENCH_SMOKE=1 (the ci.sh bench-smoke step): one Small kernel only
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let mut b = Bench::new("codegen");
    let dev = Device::u200();

    let matrix: Vec<(&str, Size)> = if smoke {
        vec![("gemm", Size::Small)]
    } else {
        vec![
            ("gemm", Size::Medium),
            ("2mm", Size::Medium),
            ("cnn", Size::Medium),
            ("heat-3d", Size::Medium),
        ]
    };
    for (name, size) in matrix {
        let k = benchmarks::build(name, size, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let d = showcase(&k, &a);
        b.bench(&format!("emit/merlin/{name}-{}", size.tag()), || {
            black_box(codegen::emit(&k, &a, &dev, &d, &EmitConfig::merlin()));
        });
        b.bench(&format!("emit/vitis/{name}-{}", size.tag()), || {
            black_box(codegen::emit(&k, &a, &dev, &d, &EmitConfig::vitis()));
        });
        b.bench(&format!("emit/realized/{name}-{}", size.tag()), || {
            black_box(codegen::emit(&k, &a, &dev, &d, &EmitConfig::merlin().realized()));
        });
        b.bench(&format!("lint/{name}-{}", size.tag()), || {
            let code = codegen::emit(&k, &a, &dev, &d, &EmitConfig::merlin());
            black_box(codegen::lint(&k, &code).unwrap());
        });
    }

    // whole-corpus throughput: kernels/s for a campaign-wide dump
    let corpus: Vec<(Kernel, Analysis, Design)> = benchmarks::ALL
        .iter()
        .map(|name| {
            let size = if *name == "cnn" { Size::Medium } else { Size::Small };
            let k = benchmarks::build(name, size, DType::F32).unwrap();
            let a = Analysis::new(&k);
            let d = showcase(&k, &a);
            (k, a, d)
        })
        .collect();
    b.bench_with_items("emit_corpus/merlin/S", corpus.len() as f64, || {
        for (k, a, d) in &corpus {
            black_box(codegen::emit(k, a, &dev, d, &EmitConfig::merlin()));
        }
    });
    b.finish();
}
