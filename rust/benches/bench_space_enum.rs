//! Design-space machinery: space construction, size counting, pipeline
//! config enumeration, divisor menus — the L3 enumeration costs inside
//! `nest_candidates`.

use nlp_dse::benchmarks::{self, Size};
use nlp_dse::ir::DType;
use nlp_dse::poly::Analysis;
use nlp_dse::pragma::Space;
use nlp_dse::util::bench::{black_box, Bench};
use nlp_dse::util::divisors;

fn main() {
    // BENCH_SMOKE=1 (the ci.sh bench-smoke step): one Small kernel only
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let mut b = Bench::new("space_enum");
    let matrix: Vec<(&str, Size)> = if smoke {
        vec![("2mm", Size::Small)]
    } else {
        vec![
            ("2mm", Size::Medium),
            ("3mm", Size::Large),
            ("gemver", Size::Large),
            ("cnn", Size::Medium),
        ]
    };
    for (name, size) in matrix {
        let k = benchmarks::build(name, size, DType::F32).unwrap();
        let a = Analysis::new(&k);
        b.bench(&format!("space_new/{name}-{}", size.tag()), || {
            black_box(Space::new(&k, &a));
        });
        let s = Space::new(&k, &a);
        b.bench(&format!("space_size/{name}-{}", size.tag()), || {
            black_box(s.size());
        });
    }
    b.bench("divisors/2100", || {
        black_box(divisors(2100));
    });
    b.bench("divisors/2800", || {
        black_box(divisors(2800));
    });
    b.finish();
}
