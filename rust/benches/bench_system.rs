//! System-mode benchmarks (ISSUE 9): front extraction and allocation.
//!
//! * `front/archive/<n>` — epsilon-grid archive throughput over `n`
//!   synthetic points (sort + box collapse + dominance filter); the
//!   solver calls this once per finished solve, on every incumbent;
//! * `front/reduce/<n>` — archive plus the canonical-prefix truncation
//!   (what [`nlp_dse::nlp::solve_front`] actually runs);
//! * `alloc/bnb/<k>x<p>` — branch-and-bound budget allocation over `k`
//!   synthetic kernel fronts of `p` points each (the per-iteration
//!   node count is printed once, so nodes/s falls out of the rate);
//! * `system/gemm+bicg-S` — the end-to-end system mode on two small
//!   registry kernels: per-kernel exhaustive front solves plus the
//!   allocation, the CLI `system` command minus rendering.
//!
//! `BENCH_SMOKE=1` shrinks the matrix (the ci.sh bench-smoke loop),
//! keeping the bench compiling and honest.

use nlp_dse::benchmarks::{self, Size};
use nlp_dse::hls::Device;
use nlp_dse::ir::{DType, Kernel};
use nlp_dse::nlp::front::{archive, reduce};
use nlp_dse::nlp::{FrontConfig, FrontPoint, SymbolicEvaluator};
use nlp_dse::pragma::Design;
use nlp_dse::system::{allocate, solve_system, KernelFront, SystemConfig};
use nlp_dse::util::bench::{black_box, Bench};
use nlp_dse::util::rng::Rng;

/// `n` synthetic front points with metrics spread over realistic
/// ranges; the design payload is an empty design for a tiny kernel
/// (archive/allocation never look inside it).
fn points(k: &Kernel, n: usize, seed: u64) -> Vec<FrontPoint> {
    let mut rng = Rng::new(seed);
    let mut span = |lo: f64, hi: f64| lo + (rng.next_u64() % 1024) as f64 / 1024.0 * (hi - lo);
    (0..n)
        .map(|_| FrontPoint {
            design: Design::empty(k),
            latency: span(1e3, 1e6),
            risk: span(0.0, 1.0),
            dsp: span(16.0, 4096.0),
            onchip_bytes: span(1e3, 4e6),
            lut: span(1e3, 8e5),
        })
        .collect()
}

/// A synthetic kernel front for the allocation benches: `p` points with
/// anti-correlated throughput/area (the shape that makes b&b work).
fn synth_front(k: &Kernel, name: &str, p: usize, seed: u64) -> KernelFront {
    let front = points(k, p, seed);
    let gflops = front.iter().map(|pt| 1e12 / pt.latency).collect();
    KernelFront {
        name: name.to_string(),
        front,
        gflops,
        lower_bound: 0.0,
        optimal: true,
        solve_time_s: 0.0,
        configs: 0,
    }
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let mut b = Bench::new("system");
    let k = benchmarks::kernel_gemm(4, 4, 4, DType::F32);
    let dev = Device::u200();

    let sizes: &[usize] = if smoke { &[64] } else { &[64, 512] };
    for &n in sizes {
        let pts = points(&k, n, 7 + n as u64);
        b.bench(&format!("front/archive/{n}"), || {
            black_box(archive(pts.clone(), 0.02).len());
        });
        let fc = FrontConfig {
            epsilon: 0.02,
            max_points: 16,
        };
        b.bench(&format!("front/reduce/{n}"), || {
            black_box(reduce(pts.clone(), &fc).len());
        });
    }

    let shapes: &[(usize, usize)] = if smoke { &[(2, 8)] } else { &[(3, 8), (4, 16)] };
    for &(nk, np) in shapes {
        let fronts: Vec<KernelFront> = (0..nk)
            .map(|i| synth_front(&k, &format!("k{i}"), np, 31 * (i as u64 + 1)))
            .collect();
        let nodes = allocate(&fronts, &dev).nodes;
        println!("# alloc/bnb/{nk}x{np}: {nodes} node(s) per iteration");
        b.bench(&format!("alloc/bnb/{nk}x{np}"), || {
            black_box(allocate(&fronts, &dev).nodes);
        });
    }

    {
        let kernels = vec![
            (
                "gemm".to_string(),
                benchmarks::lookup("gemm", Size::parse("S").unwrap(), DType::F32).unwrap(),
            ),
            (
                "bicg".to_string(),
                benchmarks::lookup("bicg", Size::parse("S").unwrap(), DType::F32).unwrap(),
            ),
        ];
        let cfg = SystemConfig {
            front: FrontConfig {
                epsilon: 0.05,
                max_points: 8,
            },
            cap: 16,
            timeout_s: 30.0,
            jobs: 1,
        };
        b.bench("system/gemm+bicg-S", || {
            let out = solve_system(&kernels, &dev, &cfg, &SymbolicEvaluator);
            black_box(out.alloc.nodes);
        });
    }

    b.finish();
}
