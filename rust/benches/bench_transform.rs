//! Transform-layer benchmarks (ISSUE 7): what the `(variant × pragma)`
//! mode costs on top of a plain DSE run.
//!
//! Cases:
//!
//! * `deps/<kernel>` — the dependence analysis (direction/distance
//!   vectors included) every enumeration step re-runs on its frontier
//!   kernel; this is the legality substrate's unit cost;
//! * `enumerate/<kernel>` — bounded variant enumeration: candidate
//!   generation, per-candidate legality certification, rebuild, and
//!   fingerprint dedup;
//! * `verify/<kernel>` — certificate replay (`verify_trace`) over every
//!   enumerated variant: the machine-check a consumer pays to trust a
//!   winning trace;
//! * `transform-dse/<kernel>` — the full `(variant × pragma)` search at
//!   Small size with the symbolic evaluator and `jobs=1`: enumeration +
//!   per-variant lower-bound pruning + the NLP ladder per survivor.
//!
//! `BENCH_SMOKE=1` shrinks the matrix to mvt-S (the ci.sh bench-smoke
//! loop), keeping the bench compiling and honest.

use nlp_dse::benchmarks::{self, Size};
use nlp_dse::dse::DseConfig;
use nlp_dse::hls::Device;
use nlp_dse::ir::DType;
use nlp_dse::nlp::SymbolicEvaluator;
use nlp_dse::poly::deps::analyze;
use nlp_dse::transform::{enumerate, run_transform_dse, verify_trace, TransformConfig};
use nlp_dse::util::bench::{black_box, Bench};

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let mut b = Bench::new("transform");

    let kernels: &[&str] = if smoke {
        &["mvt"]
    } else {
        &["mvt", "atax", "gemm", "2mm"]
    };
    let cfg = TransformConfig {
        max_variants: 8,
        max_depth: 1,
        max_perm_loops: 3,
    };

    for name in kernels {
        let k = benchmarks::build(name, Size::Small, DType::F32).unwrap();
        b.bench(&format!("deps/{name}-S"), || {
            black_box(analyze(&k).dir_vectors.len());
        });
        b.bench(&format!("enumerate/{name}-S"), || {
            black_box(enumerate(&k, &cfg).len());
        });
        let variants = enumerate(&k, &cfg);
        b.bench(&format!("verify/{name}-S"), || {
            for v in &variants {
                verify_trace(&k, v).expect("enumerated trace verifies");
            }
            black_box(variants.len());
        });
    }

    // the end-to-end mode: bounded variant space, serial solver — the
    // simulated DSE clock makes this deterministic, so iteration times
    // measure real work, not search noise
    let dse_kernels: &[&str] = if smoke { &["mvt"] } else { &["mvt", "atax"] };
    let dev = Device::u200();
    let dse_cfg = DseConfig {
        jobs: 1,
        ..Default::default()
    };
    for name in dse_kernels {
        let k = benchmarks::build(name, Size::Small, DType::F32).unwrap();
        b.bench(&format!("transform-dse/{name}-S"), || {
            let o = run_transform_dse(&k, &dev, &dse_cfg, &cfg, &SymbolicEvaluator);
            black_box(o.records.len());
        });
    }

    b.finish();
}
