//! One benchmark per paper table/figure: times the full regeneration of
//! each experiment on the quick scope (the paper scope is exercised by the
//! `dse_campaign` example / `table --scope paper` CLI).
//!
//! Covers: Tables 1, 2, 3, 5, 6, 7, 8, 9 and Figures 2–6.
//! `BENCH_SMOKE=1` (the ci.sh bench-smoke step) shrinks both campaigns
//! to single Small kernels so the bench exercises every code path in
//! seconds.

use nlp_dse::benchmarks::Size;
use nlp_dse::coordinator::{engine_names, run_campaign, CampaignConfig};
use nlp_dse::report;
use nlp_dse::util::bench::{black_box, Bench};

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let mut b = Bench::new("tables_and_figures");

    // shared quick campaigns (the expensive part, measured once each)
    let mut cfg = CampaignConfig::quick();
    cfg.kernels = if smoke {
        vec![("gemm".into(), Size::Small), ("2mm".into(), Size::Small)]
    } else {
        vec![
            ("2mm".into(), Size::Medium),
            ("gemm".into(), Size::Medium),
            ("gramschmidt".into(), Size::Large),
            ("bicg".into(), Size::Medium),
        ]
    };
    cfg.engines = engine_names(&["nlpdse", "autodse"]);
    let mut auto_result = None;
    b.bench("campaign/quick-autodse(4 kernels)", || {
        auto_result = Some(black_box(run_campaign(&cfg)));
    });
    let auto_result = auto_result.unwrap();

    let mut hcfg = CampaignConfig::quick();
    hcfg.kernels = if smoke {
        vec![("gemm".into(), Size::Small)]
    } else {
        vec![
            ("gemm".into(), Size::Small),
            ("bicg".into(), Size::Small),
            ("mvt".into(), Size::Small),
        ]
    };
    hcfg.dtype = nlp_dse::ir::DType::F64;
    hcfg.engines = engine_names(&["nlpdse", "harp"]);
    hcfg.tuning.harp.sweep_configs = if smoke { 1_000 } else { 5_000 };
    let mut harp_result = None;
    b.bench("campaign/quick-harp(3 kernels)", || {
        harp_result = Some(black_box(run_campaign(&hcfg)));
    });
    let harp_result = harp_result.unwrap();

    // table renderers over the campaign rows
    b.bench("table1/original-vs-autodse", || {
        black_box(report::table1(&auto_result).render());
    });
    b.bench("table2/space-extent", || {
        black_box(report::table2(&auto_result).render());
    });
    b.bench("table3/nlpdse-vs-autodse", || {
        black_box(report::table3(&auto_result).render());
    });
    b.bench("table5/full-comparison", || {
        black_box(report::table5(&auto_result).render());
    });
    b.bench("table6/dse-steps", || {
        black_box(report::table6(&auto_result).render());
    });
    b.bench("table7/solver-scalability", || {
        black_box(report::table7(&auto_result).render());
    });
    b.bench("table8/problem-sizes", || {
        black_box(report::table8().render());
    });
    b.bench("table9/nlpdse-vs-harp", || {
        black_box(report::table9(&harp_result).render());
    });
    b.bench("figure2/large-series", || {
        black_box(report::figure2_3(&auto_result, Size::Large));
    });
    b.bench("figure3/medium-series", || {
        black_box(report::figure2_3(&auto_result, Size::Medium));
    });
    b.bench("figure4/harp-series", || {
        black_box(report::figure4(&harp_result));
    });
    b.bench("figure5/lb-accuracy-scatter", || {
        black_box(report::figure5(&auto_result));
    });
    b.bench("figure6/2mm-steps", || {
        black_box(report::figure6(&auto_result, "2mm", Size::Medium));
    });
    b.finish();
}
