//! Runtime batch evaluation: the XLA artifact vs the in-process Rust
//! evaluator at increasing batch sizes. This quantifies the L1/L2 hot path
//! and the PJRT invocation overhead (§Perf).
//!
//! Skipped (with a note) when `make artifacts` has not run.

use nlp_dse::benchmarks::{self, Size};
use nlp_dse::hls::Device;
use nlp_dse::ir::DType;
use nlp_dse::model;
use nlp_dse::poly::Analysis;
use nlp_dse::pragma::Design;
use nlp_dse::runtime::{default_artifact_dir, XlaEvaluator};
use nlp_dse::util::bench::{black_box, Bench};

fn main() {
    let eval = match XlaEvaluator::load(&default_artifact_dir()) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("[skip] bench_runtime_batch: {e:#}");
            return;
        }
    };
    let mut b = Bench::new("runtime_batch");
    let k = benchmarks::build("2mm", Size::Medium, DType::F32).unwrap();
    let a = Analysis::new(&k);
    let dev = Device::u200();
    let f = model::encode_design(&k, &a, &dev, &Design::empty(&k)).unwrap();

    for n in [1usize, 64, 512, 2048] {
        let batch: Vec<_> = (0..n).map(|_| f.clone()).collect();
        b.bench_with_items(&format!("xla/eval_features/n={n}"), n as f64, || {
            black_box(eval.eval_features(&batch).unwrap());
        });
        b.bench_with_items(&format!("rust/eval_features/n={n}"), n as f64, || {
            for x in &batch {
                black_box(model::eval_features(x));
            }
        });
    }
    b.finish();
}
