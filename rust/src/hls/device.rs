//! Target device model: AMD/Xilinx Alveo U200 at 250 MHz (Section 7.1),
//! with Vitis 2021.1-style floating-point operator costs.
//!
//! The paper's *feasibility* model uses **DSP and BRAM only** (Section 4.2
//! restrictions) and that is still what gates a design. Since the system
//! campaign mode the table also carries per-operator **LUT** costs so the
//! Pareto fronts can report a LUT axis — advisory for multi-kernel budget
//! allocation, never part of single-kernel feasibility.

use crate::ir::{DType, OpKind};

/// Per-operation implementation cost.
#[derive(Clone, Copy, Debug)]
pub struct OpCosts {
    /// Iteration latency in cycles (`LO(op) >= 1`, Theorem 4.4).
    pub latency: u64,
    /// DSP slices per instantiated unit.
    pub dsp: u64,
    /// LUTs per instantiated unit (advisory: reported on Pareto fronts
    /// and budgeted by the `system` allocator, never gating feasibility).
    pub lut: u64,
}

/// One FPGA target: frequency, resource budgets, transfer widths.
#[derive(Clone, Debug)]
pub struct Device {
    /// Device name tag.
    pub name: &'static str,
    /// Kernel clock frequency, Hz.
    pub freq_hz: f64,
    /// DSP slices available.
    pub dsp_total: u64,
    /// LUTs available (system-mode budget axis).
    pub lut_total: u64,
    /// On-chip memory (BRAM + URAM) in bytes usable for data caching.
    pub onchip_bytes: u64,
    /// BRAM18K blocks (partitioning granularity accounting).
    pub bram18k: u64,
    /// Max off-chip burst width in bits (Merlin packing, Theorem 4.13).
    pub max_burst_bits: u64,
    /// Vitis per-array partition limit (Section 6).
    pub max_array_partition: u64,
}

impl Device {
    /// The evaluation target (Section 7.1).
    pub fn u200() -> Device {
        Device {
            name: "xilinx-u200",
            freq_hz: 250e6,
            dsp_total: 6840,
            lut_total: 1_182_000,
            onchip_bytes: 35 * 1024 * 1024,
            bram18k: 4320,
            max_burst_bits: 512,
            max_array_partition: 1024,
        }
    }

    /// Operator cost table per dtype (typical Vitis 2021.x fp operators at
    /// 250 MHz; `fdiv`/`fsqrt` are LUT-based, hence 0 DSP — consistent with
    /// the paper's DSP-only feasibility model — and correspondingly
    /// LUT-heavy in the advisory LUT column).
    pub fn op_costs(&self, dtype: DType, op: OpKind) -> OpCosts {
        match (dtype, op) {
            (DType::F32, OpKind::Add) | (DType::F32, OpKind::Sub) => OpCosts {
                latency: 4,
                dsp: 2,
                lut: 200,
            },
            (DType::F32, OpKind::Mul) => OpCosts {
                latency: 3,
                dsp: 3,
                lut: 100,
            },
            (DType::F32, OpKind::Div) => OpCosts {
                latency: 12,
                dsp: 0,
                lut: 800,
            },
            (DType::F64, OpKind::Add) | (DType::F64, OpKind::Sub) => OpCosts {
                latency: 5,
                dsp: 3,
                lut: 400,
            },
            (DType::F64, OpKind::Mul) => OpCosts {
                latency: 6,
                dsp: 11,
                lut: 300,
            },
            (DType::F64, OpKind::Div) => OpCosts {
                latency: 30,
                dsp: 0,
                lut: 3200,
            },
        }
    }

    /// Off-chip transfer throughput: elements per cycle at full burst.
    pub fn elems_per_cycle(&self, dtype: DType) -> f64 {
        self.max_burst_bits as f64 / dtype.bits() as f64
    }

    /// Cycles to transfer `bytes` at the max burst width (lower bound,
    /// Theorem 4.13: `footprint / max_burst_size`).
    pub fn transfer_cycles(&self, bytes: u64) -> f64 {
        bytes as f64 / (self.max_burst_bits as f64 / 8.0)
    }

    /// Merlin's default per-array on-chip working tile: arrays larger than
    /// this are strip-mined/streamed rather than cached whole (the `tile`
    /// pragma controls the granularity). Bounds both the Eq 12 usage model
    /// and the oracle's BRAM accounting.
    pub fn working_tile_bytes(&self) -> u64 {
        2 * 1024 * 1024
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u200_constants() {
        let d = Device::u200();
        assert_eq!(d.dsp_total, 6840);
        assert_eq!(d.lut_total, 1_182_000);
        assert_eq!(d.max_burst_bits, 512);
        assert_eq!(d.max_array_partition, 1024);
        assert!(d.freq_hz == 250e6);
    }

    #[test]
    fn op_costs_positive_latency() {
        let d = Device::u200();
        for dt in [DType::F32, DType::F64] {
            for op in OpKind::ALL {
                let c = d.op_costs(dt, op);
                assert!(c.latency >= 1, "LO(op) >= 1 required by Theorem 4.4");
                assert!(c.lut >= 1, "every operator consumes some LUTs");
                // DSP-free (LUT-implemented) operators must be LUT-expensive
                if c.dsp == 0 {
                    assert!(c.lut >= 800);
                }
            }
        }
    }

    #[test]
    fn transfer_is_512bit_packed() {
        let d = Device::u200();
        // paper §4.2.8: N×M f32 matrix costs N*M/16 cycles
        let n = 1900u64;
        let m = 2100u64;
        let bytes = n * m * 4;
        assert!((d.transfer_cycles(bytes) - (n * m) as f64 / 16.0).abs() < 1e-6);
        assert_eq!(d.elems_per_cycle(DType::F32), 16.0);
        assert_eq!(d.elems_per_cycle(DType::F64), 8.0);
    }
}
