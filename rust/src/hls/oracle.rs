//! The HLS measurement oracle: simulated Vitis 2021.1 synthesis.
//!
//! Given a Merlin-realized design it produces the quantities every DSE in
//! the paper consumes: post-synthesis latency (the "HLS report" number),
//! DSP/BRAM usage, achieved II, and the **synthesis wall-time** — the
//! resource the paper's Tables actually budget (20 h DSE timeouts, 3 h
//! per-synthesis timeouts, `DT` columns).
//!
//! Construction guarantees (tested in `property_invariants.rs`):
//!
//! * **Lower-bound invariant** (Theorem B.21): measured latency ≥ the
//!   model's lower bound for the *requested* design — except when Vitis
//!   auto-applies `loop_flatten` (the paper's one documented violation,
//!   Fig 5's red point). Pessimism enters through realized (not optimal)
//!   transfers, achieved II, scheduling overhead ≥ 1, and refused pragmas.
//! * **Determinism**: identical (kernel, design) → identical report.
//! * **Synthesis-time growth**: wall time grows with replication and
//!   partitioning — reproducing why over-parallelized probes burn the DSE
//!   budget (Section 2.3 "Over Parallelization").

use crate::hls::Device;
use crate::ir::Kernel;
use crate::merlin::{self, MerlinOutcome};
use crate::model;
use crate::poly::Analysis;
use crate::pragma::Design;
use crate::util::rng::hash64;

/// Synthesis options (the paper's evaluation setup).
#[derive(Clone, Debug)]
pub struct SynthOptions {
    /// Per-synthesis timeout in minutes (180 in Section 7.2).
    pub hls_timeout_min: f64,
}

impl Default for SynthOptions {
    fn default() -> Self {
        SynthOptions {
            hls_timeout_min: 180.0,
        }
    }
}

/// One synthesis report.
#[derive(Clone, Debug)]
pub struct HlsReport {
    /// Measured kernel latency in cycles (valid only when `valid`).
    pub cycles: f64,
    /// DSP slices used.
    pub dsp: u64,
    /// BRAM18K blocks used.
    pub bram18k: u64,
    /// Worst achieved pipeline II.
    pub achieved_ii: f64,
    /// Simulated synthesis wall-clock minutes (capped at the timeout).
    pub synth_minutes: f64,
    /// Synthesis hit the per-design timeout → no usable result.
    pub timeout: bool,
    /// Resources fit on the device and Merlin accepted the design.
    pub valid: bool,
    /// Merlin refused the design outright (AutoDSE "early reject" — cheap).
    pub early_reject: bool,
    /// All requested pragmas were applied as given.
    pub pragmas_applied: bool,
    /// Vitis auto-applied loop_flatten (lower-bound exception).
    pub flattened: bool,
    /// The full Merlin outcome behind this report.
    pub merlin: MerlinOutcome,
}

impl HlsReport {
    /// Measured throughput; 0 for invalid/timed-out designs.
    pub fn gflops(&self, analysis: &Analysis, device: &Device) -> f64 {
        if !self.valid || self.timeout {
            return 0.0;
        }
        analysis.gflops(self.cycles, device.freq_hz)
    }
}

/// The oracle. Stateless; all variation is hash-derived from
/// (kernel, dtype, design fingerprint).
pub struct HlsOracle {
    /// Target device tables.
    pub device: Device,
    /// Synthesis options (timeout).
    pub options: SynthOptions,
}

impl HlsOracle {
    /// Oracle over `device` with default options.
    pub fn new(device: Device) -> HlsOracle {
        HlsOracle {
            device,
            options: SynthOptions::default(),
        }
    }

    fn jitter(&self, k: &Kernel, d: &Design, key: &str, lo: f64, hi: f64) -> f64 {
        let h = hash64(&format!(
            "{}/{}/{}/{}",
            k.name,
            k.dtype.name(),
            d.fingerprint(),
            key
        ));
        lo + (h % 10_000) as f64 / 10_000.0 * (hi - lo)
    }

    /// Synthesize one design.
    pub fn synth(&self, k: &Kernel, a: &Analysis, d: &Design) -> HlsReport {
        let dev = &self.device;
        let m = merlin::apply(k, a, dev, d);

        if m.early_reject {
            // Merlin refuses before HLS: costs a few Merlin-compile minutes
            let minutes = self.jitter(k, d, "merlin", 2.0, 8.0);
            return HlsReport {
                cycles: f64::INFINITY,
                dsp: 0,
                bram18k: 0,
                achieved_ii: 0.0,
                synth_minutes: minutes,
                timeout: false,
                valid: false,
                early_reject: true,
                pragmas_applied: false,
                flattened: false,
                merlin: m,
            };
        }

        // ---- measured latency ------------------------------------------------
        // the realized design's model latency, with realized transfers,
        // achieved II, and scheduling overhead ≥ 1
        let realized_model = model::evaluate(k, a, dev, &m.realized);
        let sched_overhead = self.jitter(k, d, "sched", 1.05, 1.35);
        let mut comp = realized_model.comp_cycles * sched_overhead * m.ii_penalty;
        let mut comm = m.comm_cycles;
        let mut flattened = m.flattened;
        if flattened {
            // loop_flatten merges the pipeline with the loop above it:
            // fewer pipeline drains → slightly *below* the model bound
            // (Fig 5's documented exception)
            comp = realized_model.comp_cycles * 0.88;
            comm = realized_model.comm_cycles;
        }
        // flatten only manifests as a bound violation when it actually
        // undercuts the pessimistic path
        if flattened && comp + comm >= realized_model.total_cycles {
            flattened = false;
        }
        let cycles = comp + comm;

        // ---- resources --------------------------------------------------------
        let dsp_over = self.jitter(k, d, "dsp", 1.0, 1.3);
        let dsp = (realized_model.dsp * dsp_over).round() as u64;
        let bram = self.bram_usage(k, a, &m.realized);
        let fits = dsp <= dev.dsp_total && bram <= dev.bram18k * 2; // URAM headroom

        // ---- synthesis wall time ----------------------------------------------
        // wall time follows the *requested* design: Vitis grinds through
        // scheduling/partitioning the huge netlist before Merlin's fallback
        // materializes — this is exactly how over-parallelized AutoDSE
        // probes burn the budget (Section 2.3)
        let par_product: f64 = d.pragmas.iter().map(|p| p.uf.max(1) as f64).product();
        let partition = d.max_partitioning(k) as f64;
        let fp_mb = a.total_footprint as f64 / (1024.0 * 1024.0);
        let base = 4.0
            + 0.9 * k.n_loops() as f64
            + 3.0 * (1.0 + par_product).log2()
            + 0.075 * partition
            + 0.35 * fp_mb.min(60.0);
        let synth_minutes_raw = base * self.jitter(k, d, "synth", 0.85, 1.35);
        let timeout = synth_minutes_raw > self.options.hls_timeout_min;
        let synth_minutes = synth_minutes_raw.min(self.options.hls_timeout_min);

        HlsReport {
            cycles,
            dsp,
            bram18k: bram,
            achieved_ii: realized_model.worst_ii * m.ii_penalty,
            synth_minutes,
            timeout,
            valid: fits && !timeout,
            early_reject: false,
            pragmas_applied: m.pragmas_applied(d) && m.ii_penalty == 1.0,
            flattened,
            merlin: m,
        }
    }

    /// BRAM18K accounting: each partition of a cached array occupies at
    /// least one block; big arrays need `footprint / 2 KB` blocks. This is
    /// what makes high partitioning factors blow the memory budget for
    /// large problem sizes (Section 7.3's 2mm/3mm discussion).
    fn bram_usage(&self, k: &Kernel, a: &Analysis, d: &Design) -> u64 {
        let mut total = 0u64;
        for arr in &k.arrays {
            let fp = arr.footprint_bytes(k.dtype) as f64;
            // Merlin caches a bounded working tile per array (tiling to
            // fit), so the caching contribution is capped; partitioning
            // multiplies the block count (each partition needs ≥ 1 block)
            let cached = fp.min(self.device.working_tile_bytes() as f64);
            let part = d.partitioning(k, arr.id);
            let blocks = (cached / 2048.0).ceil() as u64;
            total += blocks.max(part);
        }
        let _ = a;
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{self, Size};
    use crate::ir::{DType, LoopId};

    fn setup(name: &str, size: Size) -> (Kernel, Analysis, HlsOracle) {
        let k = benchmarks::build(name, size, DType::F32).unwrap();
        let a = Analysis::new(&k);
        (k, a, HlsOracle::new(Device::u200()))
    }

    #[test]
    fn report_deterministic() {
        let (k, a, o) = setup("gemm", Size::Medium);
        let mut d = Design::empty(&k);
        d.get_mut(LoopId(3)).pipeline = true;
        d.get_mut(LoopId(3)).uf = 20;
        let r1 = o.synth(&k, &a, &d);
        let r2 = o.synth(&k, &a, &d);
        assert_eq!(r1.cycles, r2.cycles);
        assert_eq!(r1.synth_minutes, r2.synth_minutes);
    }

    #[test]
    fn lower_bound_invariant_holds() {
        let (k, a, o) = setup("gemm", Size::Medium);
        let dev = Device::u200();
        for uf in [1u64, 2, 4, 10, 20] {
            let mut d = Design::empty(&k);
            d.get_mut(LoopId(3)).pipeline = true;
            d.get_mut(LoopId(3)).uf = uf;
            let rep = o.synth(&k, &a, &d);
            if !rep.valid || rep.flattened {
                continue;
            }
            let lb = crate::model::evaluate(&k, &a, &dev, &d);
            assert!(
                rep.cycles >= lb.total_cycles * 0.999,
                "uf={uf}: measured {} < bound {}",
                rep.cycles,
                lb.total_cycles
            );
        }
    }

    #[test]
    fn synthesis_time_grows_with_parallelism() {
        let (k, a, o) = setup("gemm", Size::Medium);
        let d_small = {
            let mut d = Design::empty(&k);
            d.get_mut(LoopId(3)).pipeline = true;
            d.get_mut(LoopId(3)).uf = 2;
            d
        };
        let d_big = {
            let mut d = Design::empty(&k);
            d.get_mut(LoopId(3)).pipeline = true;
            d.get_mut(LoopId(3)).uf = 220;
            d.get_mut(LoopId(1)).uf = 220; // j0 innermost: fine-grained
            d
        };
        let r_small = o.synth(&k, &a, &d_small);
        let r_big = o.synth(&k, &a, &d_big);
        assert!(
            r_big.synth_minutes > r_small.synth_minutes * 1.5,
            "{} vs {}",
            r_big.synth_minutes,
            r_small.synth_minutes
        );
    }

    #[test]
    fn early_reject_is_cheap() {
        let (k, a, o) = setup("seidel-2d", Size::Medium);
        let mut d = Design::empty(&k);
        d.get_mut(LoopId(1)).uf = 2;
        let r = o.synth(&k, &a, &d);
        assert!(r.early_reject);
        assert!(!r.valid);
        assert!(r.synth_minutes < 10.0);
    }

    #[test]
    fn original_designs_are_slow() {
        // "Original" rows of Table 1: ~0.1 GF/s territory
        let (k, a, o) = setup("2mm", Size::Medium);
        let d = Design::empty(&k);
        let r = o.synth(&k, &a, &d);
        assert!(r.valid);
        let gfs = r.gflops(&a, &Device::u200());
        assert!(
            (0.005..2.0).contains(&gfs),
            "original 2mm-M should be well under 2 GF/s, got {gfs}"
        );
    }

    #[test]
    fn gflops_zero_for_invalid() {
        let (k, a, o) = setup("seidel-2d", Size::Medium);
        let mut d = Design::empty(&k);
        d.get_mut(LoopId(1)).uf = 2;
        let r = o.synth(&k, &a, &d);
        assert_eq!(r.gflops(&a, &Device::u200()), 0.0);
    }
}
