//! Simulated Vitis HLS toolchain + target device.
//!
//! * [`device`] — the Alveo U200 @ 250 MHz resource/latency tables.
//! * [`oracle`] — the measurement oracle: given a Merlin-realized design,
//!   produce the post-synthesis latency, DSP/BRAM usage, achieved II, and
//!   the synthesis wall-time (which drives the DSE time budget and the
//!   180-minute HLS timeouts the paper's Tables count).

pub mod device;
pub mod oracle;

pub use device::{Device, OpCosts};
pub use oracle::{HlsOracle, HlsReport, SynthOptions};

