//! The daemon's wire protocol: newline-framed JSON, one value per line.
//!
//! A client writes one request object per line and reads response
//! *events* until a terminal one arrives for that request:
//!
//! ```text
//! → {"op":"solve","kernel":"gemm","size":"S","cap":16,"id":1}
//! ← {"event":"progress","id":1,"op":"solve","msg":"model built"}
//! ← {"event":"result","id":1,"op":"solve","cache":"miss","data":{...}}
//! ```
//!
//! * every request: `op` (required) ∈ `solve | dse | system | bound |
//!   emit | gen | stats | shutdown`, plus an optional `id` echoed
//!   verbatim on every event the request produces (clients multiplexing
//!   one connection correlate by it);
//! * kernel-carrying ops take either `kernel` (registry benchmark name)
//!   or `knl` (inline `.knl` source text), with optional `size`
//!   (`S|M|L`) and `dtype` (`f32|f64`) — the same resolution as the
//!   CLI; the multi-kernel `system` op instead takes `kernels` (a list
//!   of benchmark names sharing one `size`/`dtype`);
//! * terminal events are `result` (with `data`, and on cache-eligible
//!   ops a `cache: "hit" | "warm" | "miss"` attribution) and `error`
//!   (with `message`, and — when the failure is a `.knl` parse error —
//!   `diagnostic` holding the full rendered caret snippet, newlines
//!   JSON-escaped).
//!
//! Everything here is transport-agnostic string-to-string plumbing; the
//! TCP loop lives in [`super::server`], dispatch in [`super::session`].

use crate::util::json::Json;

/// One parsed request line. Op-specific options stay in `body` and are
/// read through the typed accessors (which reject wrong JSON types
/// instead of silently ignoring them).
#[derive(Debug)]
pub struct Request {
    /// Client correlation id, echoed verbatim (any JSON scalar).
    pub id: Option<Json>,
    /// The operation name.
    pub op: String,
    body: Json,
}

/// Parse one request line. `Err` is a human-readable message for an
/// `error` event (malformed JSON, missing `op`, non-object payload).
pub fn parse_request(line: &str) -> Result<Request, String> {
    let v = Json::parse(line).map_err(|e| format!("bad request JSON: {e}"))?;
    if !matches!(v, Json::Obj(_)) {
        return Err("request must be a JSON object".into());
    }
    let op = match v.get("op").and_then(|o| o.as_str()) {
        Some(s) => s.to_string(),
        None => return Err("request needs a string \"op\" field".into()),
    };
    let id = v.get("id").cloned().filter(|j| !matches!(j, Json::Null));
    Ok(Request { id, op, body: v })
}

impl Request {
    /// String option, `Err` when present but not a string.
    pub fn str_opt(&self, key: &str) -> Result<Option<String>, String> {
        match self.body.get(key) {
            None | Some(Json::Null) => Ok(None),
            Some(j) => j
                .as_str()
                .map(|s| Some(s.to_string()))
                .ok_or_else(|| format!("\"{key}\" must be a string")),
        }
    }

    /// Non-negative integer option, `Err` on fractions/negatives/strings.
    pub fn u64_opt(&self, key: &str) -> Result<Option<u64>, String> {
        match self.body.get(key) {
            None | Some(Json::Null) => Ok(None),
            Some(j) => j
                .as_u64()
                .map(Some)
                .ok_or_else(|| format!("\"{key}\" must be a non-negative integer")),
        }
    }

    /// Float option.
    pub fn f64_opt(&self, key: &str) -> Result<Option<f64>, String> {
        match self.body.get(key) {
            None | Some(Json::Null) => Ok(None),
            Some(j) => j
                .as_f64()
                .map(Some)
                .ok_or_else(|| format!("\"{key}\" must be a number")),
        }
    }

    /// Boolean option.
    pub fn bool_opt(&self, key: &str) -> Result<Option<bool>, String> {
        match self.body.get(key) {
            None | Some(Json::Null) => Ok(None),
            Some(j) => j
                .as_bool()
                .map(Some)
                .ok_or_else(|| format!("\"{key}\" must be a boolean")),
        }
    }

    /// `loop → n` assignment option: accepts a JSON object
    /// (`{"i": 4, "k": 8}`) or the CLI's string form (`"i=4,k=8"`).
    pub fn assign_opt(&self, key: &str) -> Result<Vec<(String, u64)>, String> {
        match self.body.get(key) {
            None | Some(Json::Null) => Ok(Vec::new()),
            Some(Json::Obj(m)) => {
                let mut out = Vec::new();
                for (l, v) in m {
                    let n = v
                        .as_u64()
                        .ok_or_else(|| format!("\"{key}\".{l} must be a non-negative integer"))?;
                    out.push((l.clone(), n));
                }
                Ok(out)
            }
            Some(Json::Str(s)) => {
                let mut out = Vec::new();
                for pair in s.split(',').filter(|p| !p.is_empty()) {
                    let (l, n) = pair
                        .split_once('=')
                        .ok_or_else(|| format!("bad \"{key}\" entry `{pair}` (want loop=n)"))?;
                    let n: u64 = n
                        .trim()
                        .parse()
                        .map_err(|_| format!("bad \"{key}\" entry `{pair}` (want loop=n)"))?;
                    out.push((l.trim().to_string(), n));
                }
                Ok(out)
            }
            Some(_) => Err(format!("\"{key}\" must be an object or \"loop=n,...\" string")),
        }
    }

    /// Loop-list option: a JSON array of strings or the CLI's
    /// comma-separated string form.
    pub fn list_opt(&self, key: &str) -> Result<Vec<String>, String> {
        match self.body.get(key) {
            None | Some(Json::Null) => Ok(Vec::new()),
            Some(Json::Arr(items)) => items
                .iter()
                .map(|j| {
                    j.as_str()
                        .map(|s| s.to_string())
                        .ok_or_else(|| format!("\"{key}\" entries must be strings"))
                })
                .collect(),
            Some(Json::Str(s)) => Ok(s
                .split(',')
                .map(|t| t.trim().to_string())
                .filter(|t| !t.is_empty())
                .collect()),
            Some(_) => Err(format!("\"{key}\" must be an array or comma string")),
        }
    }
}

fn base(event: &str, id: &Option<Json>, op: Option<&str>) -> Json {
    let mut o = Json::obj();
    o.set("event", event);
    if let Some(id) = id {
        o.set("id", id.clone());
    }
    if let Some(op) = op {
        o.set("op", op);
    }
    o
}

/// A `progress` event line (no trailing newline; the transport frames).
pub fn progress_line(id: &Option<Json>, op: &str, msg: &str) -> String {
    let mut o = base("progress", id, Some(op));
    o.set("msg", msg);
    o.to_line()
}

/// A terminal `result` event line. `cache` carries the per-request
/// attribution on cache-eligible ops (`hit`/`warm`/`miss`) and is
/// omitted elsewhere.
pub fn result_line(id: &Option<Json>, op: &str, cache: Option<&str>, data: Json) -> String {
    let mut o = base("result", id, Some(op));
    if let Some(c) = cache {
        o.set("cache", c);
    }
    o.set("data", data);
    o.to_line()
}

/// A terminal `error` event line. `diagnostic` carries the frontend's
/// rendered caret snippet when the failure was a `.knl` parse error.
pub fn error_line(id: &Option<Json>, message: &str, diagnostic: Option<&str>) -> String {
    let mut o = base("error", id, None);
    o.set("message", message);
    if let Some(d) = diagnostic {
        o.set("diagnostic", d);
    }
    o.to_line()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_minimal_request() {
        let r = parse_request(r#"{"op":"solve","kernel":"gemm","id":7}"#).unwrap();
        assert_eq!(r.op, "solve");
        assert_eq!(r.id.as_ref().and_then(|j| j.as_u64()), Some(7));
        assert_eq!(r.str_opt("kernel").unwrap().as_deref(), Some("gemm"));
        assert_eq!(r.str_opt("knl").unwrap(), None);
    }

    #[test]
    fn rejects_malformed_lines_with_a_reason() {
        assert!(parse_request("not json").unwrap_err().contains("bad request JSON"));
        assert!(parse_request("[1,2]").unwrap_err().contains("JSON object"));
        assert!(parse_request(r#"{"kernel":"gemm"}"#).unwrap_err().contains("\"op\""));
        assert!(parse_request(r#"{"op":5}"#).unwrap_err().contains("\"op\""));
    }

    #[test]
    fn typed_accessors_reject_wrong_types() {
        let r = parse_request(r#"{"op":"solve","cap":"big","fine":1}"#).unwrap();
        assert!(r.u64_opt("cap").is_err());
        assert!(r.bool_opt("fine").is_err());
        assert_eq!(r.u64_opt("topk").unwrap(), None);
    }

    #[test]
    fn assign_and_list_accept_both_forms() {
        let r = parse_request(r#"{"op":"bound","assign":{"i":4,"k":8},"pipeline":["j1"]}"#)
            .unwrap();
        assert_eq!(
            r.assign_opt("assign").unwrap(),
            vec![("i".into(), 4), ("k".into(), 8)]
        );
        assert_eq!(r.list_opt("pipeline").unwrap(), vec!["j1".to_string()]);
        let r = parse_request(r#"{"op":"bound","assign":"i=4, k=8","pipeline":"j1,i"}"#).unwrap();
        assert_eq!(
            r.assign_opt("assign").unwrap(),
            vec![("i".into(), 4), ("k".into(), 8)]
        );
        assert_eq!(r.list_opt("pipeline").unwrap().len(), 2);
        assert!(r.assign_opt("missing").unwrap().is_empty());
    }

    #[test]
    fn event_lines_are_single_line_json() {
        let id = Some(Json::from("a1"));
        let p = progress_line(&id, "solve", "queued");
        assert!(!p.contains('\n'));
        let v = Json::parse(&p).unwrap();
        assert_eq!(v.get("event").and_then(|j| j.as_str()), Some("progress"));
        assert_eq!(v.get("id").and_then(|j| j.as_str()), Some("a1"));
        let e = error_line(&None, "boom", Some("error: x\n  --> <r>:1:2"));
        let v = Json::parse(&e).unwrap();
        assert!(v.get("diagnostic").and_then(|j| j.as_str()).unwrap().contains("-->"));
        assert!(!e.contains('\n'), "newlines must be escaped: {e}");
    }
}
