//! The daemon's warm cache: fingerprint-keyed LRU over built bound
//! models, compiled tapes, completed `SolveResult`s, and completed
//! `dse` / `system` responses.
//!
//! Five maps, one eviction budget (`--cache-entries`):
//!
//! * **solve cache** — [`SolveKey`] → `Arc<SolveResult>`. Only results
//!   with `optimal == true` are admitted: a completed solve is a pure
//!   function of (kernel structure, space restrictions, device,
//!   evaluator) — the key — while an anytime (timed-out) result also
//!   depends on the timeout and scheduling, so caching it would break
//!   the coherence argument (DESIGN.md §11). `jobs` is deliberately
//!   *not* part of the key: the solver's deterministic reduction makes
//!   every worker count bit-identical.
//! * **model cache** — `(exact fingerprint, device)` →
//!   `Arc<BoundModel>` + `Arc<CompiledModel>`. The symbolic build and
//!   tape compilation depend only on (kernel, device), so even a solve
//!   *miss* with different space options reuses them via
//!   [`NlpProblem::with_model`].
//! * **warm index** — [`WarmKey`] (warm fingerprint + device +
//!   evaluator + cap + fine — the *same space restrictions* as the
//!   solve key, with only the exact structural hash relaxed to the
//!   shape hash) → the design list of the most recent solve of a
//!   same-shaped kernel in the same restricted space. On a solve miss
//!   whose shape warm-matches, these designs seed
//!   [`crate::nlp::solve_jobs_seeded`] (re-verified there; see its
//!   soundness note) and the response reports `cache: "warm"`. The
//!   space restrictions are part of the key because a seed carried
//!   across rungs (say cap=512 → cap=8) can be feasible yet
//!   unreachable by the restricted candidate menus, and
//!   `solve_jobs_seeded` documents that such a seed may *improve* the
//!   top-k — which would make warm answers depend on daemon history.
//! * **dse replay cache** — [`DseKey`] → the rendered response
//!   payload. The key's kernel fingerprint is *spaced*: `dse
//!   --transform` mixes its enumeration bounds into the hash so
//!   variant-space results cache-partition correctly (the same kernel
//!   ± `--transform` never shares a line).
//! * **system replay cache** — [`SystemKey`] → the rendered response
//!   payload. The `system` op canonicalizes its kernel list (sorted by
//!   exact fingerprint, then name) *before* solving, so a completed run
//!   is a pure function of the sorted per-kernel fingerprints plus the
//!   device and the front/allocation knobs — two requests naming the
//!   same kernels in different orders share one cache line and replay
//!   bit-identically (each response row carries its kernel name, so
//!   canonical order loses nothing).
//!
//! Even within one warm key, a seeded solve is not *proven* equal to
//! the cold solve (the menus are derived from trip counts, which the
//! warm key deliberately ignores), so [`WarmCache::insert_solve`]
//! refuses to admit warm-seeded results into the exact replay cache:
//! every replayed entry comes from an unseeded solve and is therefore
//! a pure function of its [`SolveKey`] (DESIGN.md §11).
//!
//! The cache is plain data (no interior locking): the serve session
//! wraps it in one mutex, held only around lookups/inserts — never
//! across a solve.
//!
//! [`NlpProblem::with_model`]: crate::nlp::NlpProblem::with_model

use crate::model::sym::{BoundModel, CompiledModel};
use crate::nlp::SolveResult;
use crate::pragma::Design;
use crate::util::json::Json;
use std::collections::HashMap;
use std::sync::Arc;

/// Full cache-hit key: everything a completed [`SolveResult`] depends
/// on. `jobs` and the timeout are excluded by construction (see module
/// docs).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct SolveKey {
    /// Name-blind exact structural fingerprint of the kernel
    /// (structure + bounds + dtype).
    pub kernel_fp: u64,
    /// Target device name (op costs and budgets).
    pub device: String,
    /// Evaluator tag (`rust` / `sym` / `xla` — distinct scoring fronts
    /// can rank candidate menus differently).
    pub evaluator: String,
    /// `MAX_PARTITIONING` sub-space rung.
    pub cap: u64,
    /// Eq 9 fine-grained-only restriction.
    pub fine: bool,
    /// Requested top-k width.
    pub topk: usize,
}

impl SolveKey {
    /// The warm-index key this solve reads and writes: identical space
    /// restrictions, with the exact structural hash relaxed to the
    /// shape-only `warm_fp`.
    pub fn warm_key(&self, warm_fp: u64) -> WarmKey {
        WarmKey {
            warm_fp,
            device: self.device.clone(),
            evaluator: self.evaluator.clone(),
            cap: self.cap,
            fine: self.fine,
        }
    }
}

/// Warm-index key: same nest shape, same device, and the same space
/// restrictions (evaluator/cap/fine) as the solves it seeds. `topk` is
/// excluded: seeds are re-verified incumbents, and how many the donor
/// solve kept does not change what any of them mean.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct WarmKey {
    /// Shape-only structural fingerprint (sizes/precision relaxed).
    pub warm_fp: u64,
    /// Target device name.
    pub device: String,
    /// Evaluator tag.
    pub evaluator: String,
    /// `MAX_PARTITIONING` sub-space rung.
    pub cap: u64,
    /// Eq 9 fine-grained-only restriction.
    pub fine: bool,
}

/// Replay key for a completed `dse` request. A finished exploration is
/// a pure function of (kernel structure, search space, device,
/// evaluator, engine, bound-pruning switch): the DSE clock is
/// simulated and every engine's schedule is deterministic, so the
/// rendered response replays bit-identically. The kernel fingerprint
/// is *spaced* ([`fingerprint_spaced`]) — `dse --transform` mixes its
/// enumeration bounds into the hash, so the same kernel with and
/// without `--transform` (or with different bounds) occupies distinct
/// cache lines. `jobs` is excluded for the same reason as in
/// [`SolveKey`].
///
/// [`fingerprint_spaced`]: super::fingerprint::fingerprint_spaced
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct DseKey {
    /// Spaced exact structural fingerprint of the kernel.
    pub kernel_fp: u64,
    /// Target device name.
    pub device: String,
    /// Evaluator tag.
    pub evaluator: String,
    /// Engine registry name, or `transform` for the variant search.
    pub engine: String,
    /// Lower-bound pruning switch (changes the explored schedule).
    pub prune_bound: bool,
}

/// Replay key for a completed `system` request: the canonicalized
/// (fingerprint-sorted) kernel list, the device, and every knob the
/// fronts or the allocation depend on. `epsilon` is keyed by its f64
/// bit pattern — replay requires the *exact* band, and bit equality is
/// the only equality that guarantees bit-identical archives. `jobs` is
/// excluded as everywhere else (deterministic reduction).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct SystemKey {
    /// Per-kernel exact structural fingerprints, sorted ascending.
    pub kernel_fps: Vec<u64>,
    /// Target device name.
    pub device: String,
    /// Evaluator tag.
    pub evaluator: String,
    /// Epsilon-dominance band as raw f64 bits.
    pub epsilon_bits: u64,
    /// Front truncation cap.
    pub max_points: usize,
    /// `MAX_PARTITIONING` sub-space rung of every per-kernel solve.
    pub cap: u64,
}

/// Model-cache key: the symbolic build depends only on (kernel, device).
type ModelKey = (u64, String);

struct SolveEntry {
    result: Arc<SolveResult>,
    last_used: u64,
}

struct ModelEntry {
    bound: Arc<BoundModel>,
    compiled: Arc<CompiledModel>,
    last_used: u64,
}

/// Cumulative cache counters (monotone; the `stats` op snapshots them).
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    /// Replay-cache hits (bit-identical replay), solve and dse alike.
    pub hits: u64,
    /// Replay-cache misses with no warm seed either.
    pub misses: u64,
    /// Solve-cache misses answered with warm-started solves.
    pub warm: u64,
    /// Model-cache hits (bound model + tape reused).
    pub model_hits: u64,
    /// Entries dropped by LRU eviction (all three maps).
    pub evictions: u64,
}

impl CacheStats {
    /// Solve-cache hit rate over all attributed requests.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses + self.warm;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// The daemon's warm cache (see module docs).
pub struct WarmCache {
    capacity: usize,
    tick: u64,
    solves: HashMap<SolveKey, SolveEntry>,
    models: HashMap<ModelKey, ModelEntry>,
    warm: HashMap<WarmKey, (Vec<Design>, u64)>,
    dses: HashMap<DseKey, (Arc<Json>, u64)>,
    systems: HashMap<SystemKey, (Arc<Json>, u64)>,
    /// Cumulative counters.
    pub stats: CacheStats,
}

impl WarmCache {
    /// Cache bounded at `capacity` entries per map (`--cache-entries`;
    /// a capacity of 0 disables caching entirely).
    pub fn new(capacity: usize) -> WarmCache {
        WarmCache {
            capacity,
            tick: 0,
            solves: HashMap::new(),
            models: HashMap::new(),
            warm: HashMap::new(),
            dses: HashMap::new(),
            systems: HashMap::new(),
            stats: CacheStats::default(),
        }
    }

    fn bump(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// Exact-key lookup. A hit returns the stored result verbatim
    /// (`Arc` clone — bit-identical to the solve that populated it) and
    /// refreshes its LRU stamp.
    pub fn lookup_solve(&mut self, key: &SolveKey) -> Option<Arc<SolveResult>> {
        let tick = self.bump();
        match self.solves.get_mut(key) {
            Some(e) => {
                e.last_used = tick;
                self.stats.hits += 1;
                Some(e.result.clone())
            }
            None => None,
        }
    }

    /// Warm-index lookup (does not count as a hit by itself — the
    /// caller attributes `warm` vs `miss` when the solve dispatches).
    pub fn warm_seeds(&self, key: &WarmKey) -> Option<Vec<Design>> {
        self.warm.get(key).map(|(d, _)| d.clone())
    }

    /// Spaced-key lookup for a completed `dse` response. A hit returns
    /// the stored payload verbatim (bit-identical replay) and refreshes
    /// its LRU stamp.
    pub fn lookup_dse(&mut self, key: &DseKey) -> Option<Arc<Json>> {
        let tick = self.bump();
        match self.dses.get_mut(key) {
            Some((data, t)) => {
                *t = tick;
                self.stats.hits += 1;
                Some(data.clone())
            }
            None => None,
        }
    }

    /// Admit a completed `dse` response for replay (simulated clocks
    /// make every run a pure function of its [`DseKey`]).
    pub fn insert_dse(&mut self, key: DseKey, data: Arc<Json>) {
        if self.capacity == 0 {
            return;
        }
        let tick = self.bump();
        self.dses.insert(key, (data, tick));
        if self.dses.len() > self.capacity {
            evict_min(&mut self.dses, |(_, t)| *t);
            self.stats.evictions += 1;
        }
    }

    /// Lookup for a completed `system` response. A hit returns the
    /// stored payload verbatim (bit-identical replay) and refreshes its
    /// LRU stamp.
    pub fn lookup_system(&mut self, key: &SystemKey) -> Option<Arc<Json>> {
        let tick = self.bump();
        match self.systems.get_mut(key) {
            Some((data, t)) => {
                *t = tick;
                self.stats.hits += 1;
                Some(data.clone())
            }
            None => None,
        }
    }

    /// Admit a completed `system` response for replay (exhaustive
    /// fronts + deterministic allocation make the run a pure function
    /// of its [`SystemKey`]; runs with any timed-out per-kernel solve
    /// must NOT be admitted — the caller checks `optimal` per kernel).
    pub fn insert_system(&mut self, key: SystemKey, data: Arc<Json>) {
        if self.capacity == 0 {
            return;
        }
        let tick = self.bump();
        self.systems.insert(key, (data, tick));
        if self.systems.len() > self.capacity {
            evict_min(&mut self.systems, |(_, t)| *t);
            self.stats.evictions += 1;
        }
    }

    /// Count one dispatched solve or exploration as warm-started or a
    /// cold miss.
    pub fn note_dispatch(&mut self, warm_started: bool) {
        if warm_started {
            self.stats.warm += 1;
        } else {
            self.stats.misses += 1;
        }
    }

    /// Admit a completed solve. Two classes of result never reach the
    /// exact replay cache, because neither is a pure function of the
    /// [`SolveKey`]:
    ///
    /// * non-optimal (anytime) results — they depend on the time
    ///   budget;
    /// * warm-`seeded` results — a seed the restricted candidate menus
    ///   cannot reach may have improved the top-k beyond what a cold
    ///   solve of this key returns (`solve_jobs_seeded`'s documented
    ///   escape), so replaying one would make identical requests
    ///   answer differently depending on daemon history.
    ///
    /// Both still refresh the warm index: their designs are legitimate
    /// seeds (re-verified at use), just not replayable answers.
    pub fn insert_solve(
        &mut self,
        key: SolveKey,
        warm_fp: u64,
        result: &Arc<SolveResult>,
        seeded: bool,
    ) {
        if self.capacity == 0 {
            return;
        }
        let tick = self.bump();
        let designs: Vec<Design> = result.designs.iter().map(|(d, _)| d.clone()).collect();
        if !designs.is_empty() {
            self.warm.insert(key.warm_key(warm_fp), (designs, tick));
            if self.warm.len() > self.capacity {
                evict_min(&mut self.warm, |(_, t)| *t);
                self.stats.evictions += 1;
            }
        }
        if result.optimal && !seeded {
            self.solves.insert(
                key,
                SolveEntry {
                    result: result.clone(),
                    last_used: tick,
                },
            );
            if self.solves.len() > self.capacity {
                evict_min(&mut self.solves, |e| e.last_used);
                self.stats.evictions += 1;
            }
        }
    }

    /// Shared bound model + compiled tape for `(kernel fingerprint,
    /// device)`, if cached.
    pub fn lookup_model(
        &mut self,
        fp: u64,
        device: &str,
    ) -> Option<(Arc<BoundModel>, Arc<CompiledModel>)> {
        let tick = self.bump();
        match self.models.get_mut(&(fp, device.to_string())) {
            Some(e) => {
                e.last_used = tick;
                self.stats.model_hits += 1;
                Some((e.bound.clone(), e.compiled.clone()))
            }
            None => None,
        }
    }

    /// Admit a freshly built model pair.
    pub fn insert_model(
        &mut self,
        fp: u64,
        device: &str,
        bound: Arc<BoundModel>,
        compiled: Arc<CompiledModel>,
    ) {
        if self.capacity == 0 {
            return;
        }
        let tick = self.bump();
        self.models.insert(
            (fp, device.to_string()),
            ModelEntry {
                bound,
                compiled,
                last_used: tick,
            },
        );
        if self.models.len() > self.capacity {
            evict_min(&mut self.models, |e| e.last_used);
            self.stats.evictions += 1;
        }
    }

    /// Live entry counts `(solves, models, warm, dses, systems)` for
    /// the `stats` op.
    pub fn sizes(&self) -> (usize, usize, usize, usize, usize) {
        (
            self.solves.len(),
            self.models.len(),
            self.warm.len(),
            self.dses.len(),
            self.systems.len(),
        )
    }
}

/// Drop the least-recently-used entry (O(n) scan — the cache is bounded
/// by `--cache-entries`, far below where a heap would matter).
fn evict_min<K: Clone + Eq + std::hash::Hash, V>(
    map: &mut HashMap<K, V>,
    stamp: impl Fn(&V) -> u64,
) {
    if let Some(k) = map
        .iter()
        .min_by_key(|(_, v)| stamp(v))
        .map(|(k, _)| k.clone())
    {
        map.remove(&k);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nlp::SolverStats;

    fn result(optimal: bool) -> Arc<SolveResult> {
        Arc::new(SolveResult {
            designs: vec![(Design { pragmas: vec![] }, 42.0)],
            lower_bound: 42.0,
            optimal,
            solve_time_s: 0.1,
            cpu_time_s: 0.1,
            jobs: 1,
            stats: SolverStats::default(),
        })
    }

    fn key(fp: u64) -> SolveKey {
        SolveKey {
            kernel_fp: fp,
            device: "xilinx-u200".into(),
            evaluator: "rust".into(),
            cap: 512,
            fine: false,
            topk: 3,
        }
    }

    #[test]
    fn hit_returns_the_same_arc_and_counts() {
        let mut c = WarmCache::new(4);
        assert!(c.lookup_solve(&key(1)).is_none());
        let r = result(true);
        c.insert_solve(key(1), 10, &r, false);
        let hit = c.lookup_solve(&key(1)).expect("hit");
        assert!(Arc::ptr_eq(&hit, &r), "bit-identical replay is the same Arc");
        assert_eq!(c.stats.hits, 1);
        // a different rung is a different key
        let mut k2 = key(1);
        k2.cap = 8;
        assert!(c.lookup_solve(&k2).is_none());
    }

    #[test]
    fn non_optimal_results_feed_warm_index_only() {
        let mut c = WarmCache::new(4);
        c.insert_solve(key(2), 20, &result(false), false);
        assert!(c.lookup_solve(&key(2)).is_none(), "anytime result not cached");
        assert!(c.warm_seeds(&key(2).warm_key(20)).is_some(), "but seeds survive");
        let mut other_dev = key(2).warm_key(20);
        other_dev.device = "other-device".into();
        assert!(c.warm_seeds(&other_dev).is_none());
    }

    #[test]
    fn warm_seeded_results_feed_warm_index_only() {
        let mut c = WarmCache::new(4);
        // an optimal but warm-seeded solve: its top-k may contain a
        // menu-unreachable seed, so it must never be replayed verbatim
        c.insert_solve(key(3), 30, &result(true), true);
        assert!(c.lookup_solve(&key(3)).is_none(), "seeded result not replayable");
        assert!(c.warm_seeds(&key(3).warm_key(30)).is_some(), "but seeds survive");
    }

    #[test]
    fn warm_index_is_partitioned_by_space_and_evaluator() {
        let mut c = WarmCache::new(8);
        c.insert_solve(key(4), 40, &result(true), false);
        let base = key(4).warm_key(40);
        assert!(c.warm_seeds(&base).is_some());
        // a different rung / restriction / evaluator must not donate
        // seeds: cross-space seeds can be menu-unreachable and change
        // the seeded solve's answer
        let mut rung = base.clone();
        rung.cap = 8;
        assert!(c.warm_seeds(&rung).is_none());
        let mut fine = base.clone();
        fine.fine = true;
        assert!(c.warm_seeds(&fine).is_none());
        let mut eval = base;
        eval.evaluator = "sym".into();
        assert!(c.warm_seeds(&eval).is_none());
    }

    #[test]
    fn lru_evicts_the_oldest() {
        let mut c = WarmCache::new(2);
        c.insert_solve(key(1), 1, &result(true), false);
        c.insert_solve(key(2), 2, &result(true), false);
        assert!(c.lookup_solve(&key(1)).is_some()); // refresh 1
        c.insert_solve(key(3), 3, &result(true), false); // evicts 2
        assert!(c.lookup_solve(&key(1)).is_some());
        assert!(c.lookup_solve(&key(2)).is_none());
        assert!(c.lookup_solve(&key(3)).is_some());
        assert!(c.stats.evictions > 0);
    }

    #[test]
    fn zero_capacity_disables_caching() {
        let mut c = WarmCache::new(0);
        c.insert_solve(key(1), 1, &result(true), false);
        assert!(c.lookup_solve(&key(1)).is_none());
        c.insert_dse(dse_key(1, "nlpdse"), Arc::new(Json::obj()));
        assert!(c.lookup_dse(&dse_key(1, "nlpdse")).is_none());
        c.insert_system(system_key(&[1, 2]), Arc::new(Json::obj()));
        assert!(c.lookup_system(&system_key(&[1, 2])).is_none());
        assert_eq!(c.sizes(), (0, 0, 0, 0, 0));
    }

    fn system_key(fps: &[u64]) -> SystemKey {
        SystemKey {
            kernel_fps: fps.to_vec(),
            device: "xilinx-u200".into(),
            evaluator: "sym".into(),
            epsilon_bits: 0.02f64.to_bits(),
            max_points: 16,
            cap: 512,
        }
    }

    #[test]
    fn system_replay_is_partitioned_by_kernels_and_knobs() {
        let mut c = WarmCache::new(4);
        let mut payload = Json::obj();
        payload.set("gflops", 2.5);
        let arc = Arc::new(payload);
        c.insert_system(system_key(&[1, 2]), arc.clone());
        let hit = c.lookup_system(&system_key(&[1, 2])).expect("hit");
        assert!(Arc::ptr_eq(&hit, &arc), "replay is the stored payload");
        // a different kernel multiset, epsilon, cap, or point budget is
        // a different line
        assert!(c.lookup_system(&system_key(&[1, 3])).is_none());
        assert!(c.lookup_system(&system_key(&[1])).is_none());
        let mut eps = system_key(&[1, 2]);
        eps.epsilon_bits = 0.05f64.to_bits();
        assert!(c.lookup_system(&eps).is_none());
        let mut cap = system_key(&[1, 2]);
        cap.cap = 8;
        assert!(c.lookup_system(&cap).is_none());
        let mut mp = system_key(&[1, 2]);
        mp.max_points = 4;
        assert!(c.lookup_system(&mp).is_none());
    }

    fn dse_key(fp: u64, engine: &str) -> DseKey {
        DseKey {
            kernel_fp: fp,
            device: "xilinx-u200".into(),
            evaluator: "rust".into(),
            engine: engine.into(),
            prune_bound: false,
        }
    }

    #[test]
    fn dse_replay_is_partitioned_by_key_fields() {
        let mut c = WarmCache::new(4);
        assert!(c.lookup_dse(&dse_key(1, "nlpdse")).is_none());
        let mut payload = Json::obj();
        payload.set("best_gflops", 1.5);
        let arc = Arc::new(payload);
        c.insert_dse(dse_key(1, "nlpdse"), arc.clone());
        let hit = c.lookup_dse(&dse_key(1, "nlpdse")).expect("hit");
        assert!(Arc::ptr_eq(&hit, &arc), "replay is the stored payload");
        assert_eq!(c.stats.hits, 1);
        // spaced fingerprints and engines partition the map
        assert!(c.lookup_dse(&dse_key(2, "nlpdse")).is_none());
        assert!(c.lookup_dse(&dse_key(1, "transform")).is_none());
        let mut pruned = dse_key(1, "nlpdse");
        pruned.prune_bound = true;
        assert!(c.lookup_dse(&pruned).is_none());
    }

    #[test]
    fn hit_rate_counts_all_attributed_requests() {
        let mut c = WarmCache::new(4);
        c.note_dispatch(false);
        c.note_dispatch(true);
        c.insert_solve(key(1), 1, &result(true), false);
        let _ = c.lookup_solve(&key(1));
        assert!((c.stats.hit_rate() - 1.0 / 3.0).abs() < 1e-12);
    }
}
