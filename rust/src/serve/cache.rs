//! The daemon's warm cache: fingerprint-keyed LRU over built bound
//! models, compiled tapes, and completed `SolveResult`s.
//!
//! Three maps, one eviction budget (`--cache-entries`):
//!
//! * **solve cache** — [`SolveKey`] → `Arc<SolveResult>`. Only results
//!   with `optimal == true` are admitted: a completed solve is a pure
//!   function of (kernel structure, space restrictions, device,
//!   evaluator) — the key — while an anytime (timed-out) result also
//!   depends on the timeout and scheduling, so caching it would break
//!   the coherence argument (DESIGN.md §11). `jobs` is deliberately
//!   *not* part of the key: the solver's deterministic reduction makes
//!   every worker count bit-identical.
//! * **model cache** — `(exact fingerprint, device)` →
//!   `Arc<BoundModel>` + `Arc<CompiledModel>`. The symbolic build and
//!   tape compilation depend only on (kernel, device), so even a solve
//!   *miss* with different space options reuses them via
//!   [`NlpProblem::with_model`].
//! * **warm index** — `(warm fingerprint, device)` → the design list of
//!   the most recent completed solve of any same-shaped kernel. On a
//!   solve miss whose shape warm-matches, these designs seed
//!   [`crate::nlp::solve_jobs_seeded`] (re-verified there; see its
//!   soundness note) and the response reports `cache: "warm"`.
//!
//! The cache is plain data (no interior locking): the serve session
//! wraps it in one mutex, held only around lookups/inserts — never
//! across a solve.
//!
//! [`NlpProblem::with_model`]: crate::nlp::NlpProblem::with_model

use crate::model::sym::{BoundModel, CompiledModel};
use crate::nlp::SolveResult;
use crate::pragma::Design;
use std::collections::HashMap;
use std::sync::Arc;

/// Full cache-hit key: everything a completed [`SolveResult`] depends
/// on. `jobs` and the timeout are excluded by construction (see module
/// docs).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct SolveKey {
    /// Name-blind exact structural fingerprint of the kernel
    /// (structure + bounds + dtype).
    pub kernel_fp: u64,
    /// Target device name (op costs and budgets).
    pub device: String,
    /// Evaluator tag (`rust` / `sym` / `xla` — distinct scoring fronts
    /// can rank candidate menus differently).
    pub evaluator: String,
    /// `MAX_PARTITIONING` sub-space rung.
    pub cap: u64,
    /// Eq 9 fine-grained-only restriction.
    pub fine: bool,
    /// Requested top-k width.
    pub topk: usize,
}

/// Model-cache key: the symbolic build depends only on (kernel, device).
type ModelKey = (u64, String);
/// Warm-index key: same nest shape on the same device.
type WarmKey = (u64, String);

struct SolveEntry {
    result: Arc<SolveResult>,
    last_used: u64,
}

struct ModelEntry {
    bound: Arc<BoundModel>,
    compiled: Arc<CompiledModel>,
    last_used: u64,
}

/// Cumulative cache counters (monotone; the `stats` op snapshots them).
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    /// Solve-cache hits (bit-identical replay).
    pub hits: u64,
    /// Solve-cache misses with no warm seed either.
    pub misses: u64,
    /// Solve-cache misses answered with warm-started solves.
    pub warm: u64,
    /// Model-cache hits (bound model + tape reused).
    pub model_hits: u64,
    /// Entries dropped by LRU eviction (all three maps).
    pub evictions: u64,
}

impl CacheStats {
    /// Solve-cache hit rate over all attributed requests.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses + self.warm;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// The daemon's warm cache (see module docs).
pub struct WarmCache {
    capacity: usize,
    tick: u64,
    solves: HashMap<SolveKey, SolveEntry>,
    models: HashMap<ModelKey, ModelEntry>,
    warm: HashMap<WarmKey, (Vec<Design>, u64)>,
    /// Cumulative counters.
    pub stats: CacheStats,
}

impl WarmCache {
    /// Cache bounded at `capacity` entries per map (`--cache-entries`;
    /// a capacity of 0 disables caching entirely).
    pub fn new(capacity: usize) -> WarmCache {
        WarmCache {
            capacity,
            tick: 0,
            solves: HashMap::new(),
            models: HashMap::new(),
            warm: HashMap::new(),
            stats: CacheStats::default(),
        }
    }

    fn bump(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// Exact-key lookup. A hit returns the stored result verbatim
    /// (`Arc` clone — bit-identical to the solve that populated it) and
    /// refreshes its LRU stamp.
    pub fn lookup_solve(&mut self, key: &SolveKey) -> Option<Arc<SolveResult>> {
        let tick = self.bump();
        match self.solves.get_mut(key) {
            Some(e) => {
                e.last_used = tick;
                self.stats.hits += 1;
                Some(e.result.clone())
            }
            None => None,
        }
    }

    /// Warm-index lookup (does not count as a hit by itself — the
    /// caller attributes `warm` vs `miss` when the solve dispatches).
    pub fn warm_seeds(&self, warm_fp: u64, device: &str) -> Option<Vec<Design>> {
        self.warm
            .get(&(warm_fp, device.to_string()))
            .map(|(d, _)| d.clone())
    }

    /// Count one dispatched solve as warm-started or a cold miss.
    pub fn note_dispatch(&mut self, warm_started: bool) {
        if warm_started {
            self.stats.warm += 1;
        } else {
            self.stats.misses += 1;
        }
    }

    /// Admit a completed solve. Non-optimal (anytime) results are
    /// rejected — they are not pure functions of the key — but their
    /// designs still refresh the warm index (a partial incumbent is a
    /// legitimate seed; seeds are re-verified at use).
    pub fn insert_solve(&mut self, key: SolveKey, warm_fp: u64, result: &Arc<SolveResult>) {
        if self.capacity == 0 {
            return;
        }
        let tick = self.bump();
        let designs: Vec<Design> = result.designs.iter().map(|(d, _)| d.clone()).collect();
        if !designs.is_empty() {
            self.warm
                .insert((warm_fp, key.device.clone()), (designs, tick));
            if self.warm.len() > self.capacity {
                evict_min(&mut self.warm, |(_, t)| *t);
                self.stats.evictions += 1;
            }
        }
        if result.optimal {
            self.solves.insert(
                key,
                SolveEntry {
                    result: result.clone(),
                    last_used: tick,
                },
            );
            if self.solves.len() > self.capacity {
                evict_min(&mut self.solves, |e| e.last_used);
                self.stats.evictions += 1;
            }
        }
    }

    /// Shared bound model + compiled tape for `(kernel fingerprint,
    /// device)`, if cached.
    pub fn lookup_model(
        &mut self,
        fp: u64,
        device: &str,
    ) -> Option<(Arc<BoundModel>, Arc<CompiledModel>)> {
        let tick = self.bump();
        match self.models.get_mut(&(fp, device.to_string())) {
            Some(e) => {
                e.last_used = tick;
                self.stats.model_hits += 1;
                Some((e.bound.clone(), e.compiled.clone()))
            }
            None => None,
        }
    }

    /// Admit a freshly built model pair.
    pub fn insert_model(
        &mut self,
        fp: u64,
        device: &str,
        bound: Arc<BoundModel>,
        compiled: Arc<CompiledModel>,
    ) {
        if self.capacity == 0 {
            return;
        }
        let tick = self.bump();
        self.models.insert(
            (fp, device.to_string()),
            ModelEntry {
                bound,
                compiled,
                last_used: tick,
            },
        );
        if self.models.len() > self.capacity {
            evict_min(&mut self.models, |e| e.last_used);
            self.stats.evictions += 1;
        }
    }

    /// Live entry counts `(solves, models, warm)` for the `stats` op.
    pub fn sizes(&self) -> (usize, usize, usize) {
        (self.solves.len(), self.models.len(), self.warm.len())
    }
}

/// Drop the least-recently-used entry (O(n) scan — the cache is bounded
/// by `--cache-entries`, far below where a heap would matter).
fn evict_min<K: Clone + Eq + std::hash::Hash, V>(
    map: &mut HashMap<K, V>,
    stamp: impl Fn(&V) -> u64,
) {
    if let Some(k) = map
        .iter()
        .min_by_key(|(_, v)| stamp(v))
        .map(|(k, _)| k.clone())
    {
        map.remove(&k);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nlp::SolverStats;

    fn result(optimal: bool) -> Arc<SolveResult> {
        Arc::new(SolveResult {
            designs: vec![(Design { pragmas: vec![] }, 42.0)],
            lower_bound: 42.0,
            optimal,
            solve_time_s: 0.1,
            cpu_time_s: 0.1,
            jobs: 1,
            stats: SolverStats::default(),
        })
    }

    fn key(fp: u64) -> SolveKey {
        SolveKey {
            kernel_fp: fp,
            device: "xilinx-u200".into(),
            evaluator: "rust".into(),
            cap: 512,
            fine: false,
            topk: 3,
        }
    }

    #[test]
    fn hit_returns_the_same_arc_and_counts() {
        let mut c = WarmCache::new(4);
        assert!(c.lookup_solve(&key(1)).is_none());
        let r = result(true);
        c.insert_solve(key(1), 10, &r);
        let hit = c.lookup_solve(&key(1)).expect("hit");
        assert!(Arc::ptr_eq(&hit, &r), "bit-identical replay is the same Arc");
        assert_eq!(c.stats.hits, 1);
        // a different rung is a different key
        let mut k2 = key(1);
        k2.cap = 8;
        assert!(c.lookup_solve(&k2).is_none());
    }

    #[test]
    fn non_optimal_results_feed_warm_index_only() {
        let mut c = WarmCache::new(4);
        c.insert_solve(key(2), 20, &result(false));
        assert!(c.lookup_solve(&key(2)).is_none(), "anytime result not cached");
        assert!(c.warm_seeds(20, "xilinx-u200").is_some(), "but seeds survive");
        assert!(c.warm_seeds(20, "other-device").is_none());
    }

    #[test]
    fn lru_evicts_the_oldest() {
        let mut c = WarmCache::new(2);
        c.insert_solve(key(1), 1, &result(true));
        c.insert_solve(key(2), 2, &result(true));
        assert!(c.lookup_solve(&key(1)).is_some()); // refresh 1
        c.insert_solve(key(3), 3, &result(true)); // evicts 2
        assert!(c.lookup_solve(&key(1)).is_some());
        assert!(c.lookup_solve(&key(2)).is_none());
        assert!(c.lookup_solve(&key(3)).is_some());
        assert!(c.stats.evictions > 0);
    }

    #[test]
    fn zero_capacity_disables_caching() {
        let mut c = WarmCache::new(0);
        c.insert_solve(key(1), 1, &result(true));
        assert!(c.lookup_solve(&key(1)).is_none());
        assert_eq!(c.sizes(), (0, 0, 0));
    }

    #[test]
    fn hit_rate_counts_all_attributed_requests() {
        let mut c = WarmCache::new(4);
        c.note_dispatch(false);
        c.note_dispatch(true);
        c.insert_solve(key(1), 1, &result(true));
        let _ = c.lookup_solve(&key(1));
        assert!((c.stats.hit_rate() - 1.0 / 3.0).abs() < 1e-12);
    }
}
