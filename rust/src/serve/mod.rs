//! DSE-as-a-service: a long-running daemon answering solve/DSE/system/
//! bound/emit/gen requests over newline-framed JSON, with a
//! fingerprint-keyed warm cache (`nlp-dse serve --addr HOST:PORT`).
//!
//! The paper's tool runs one kernel per invocation and rebuilds
//! everything — polyhedral analysis, the symbolic bound model, the
//! compiled tape — from scratch each time. In the iterative workflows
//! the evaluation section describes (resubmitting a kernel after a
//! source tweak, sweeping problem sizes, regenerating pragmas per
//! dialect), most of that work is identical across invocations. This
//! module keeps it hot:
//!
//! * [`fingerprint`](mod@fingerprint) — name-blind structural kernel hashes: `exact`
//!   (same value ⇒ same solve outcome) and `warm` (same nest shape
//!   modulo sizes/precision);
//! * [`cache`] — one LRU budget over completed `SolveResult`s (replayed
//!   bit-identically on `cache: "hit"`), built bound models + tapes, a
//!   warm index whose designs seed
//!   [`solve_jobs_seeded`](crate::nlp::solve_jobs_seeded) for
//!   `cache: "warm"` requests, and replay maps for completed `dse` and
//!   multi-kernel `system` runs;
//! * [`protocol`] — the line-JSON request/event grammar (documented in
//!   full in `docs/DESIGN.md` §11);
//! * [`session`] — transport-agnostic dispatch: the whole daemon minus
//!   the socket, driven directly by the test suites;
//! * [`server`] — the TCP accept loop over the coordinator's bounded
//!   [`ThreadPool`](crate::coordinator::pool::ThreadPool), with clean
//!   SIGTERM/`shutdown`-op termination.
//!
//! No new dependencies: `std::net`, the in-repo JSON codec, and the
//! existing worker pool. A session with `nc` works verbatim:
//!
//! ```text
//! $ nlp-dse serve --addr 127.0.0.1:4517 &
//! $ printf '%s\n' '{"op":"solve","kernel":"gemm","size":"S","cap":16}' \
//!     | nc 127.0.0.1 4517
//! {"event":"progress","op":"solve","msg":"model built | 0 warm seed(s) | solving jobs=8"}
//! {"event":"result","op":"solve","cache":"miss","data":{...}}
//! ```

pub mod cache;
pub mod fingerprint;
pub mod protocol;
pub mod server;
pub mod session;

pub use cache::{CacheStats, DseKey, SolveKey, SystemKey, WarmCache, WarmKey};
pub use fingerprint::{fingerprint, fingerprint_spaced, Fingerprint};
pub use server::{install_signal_handlers, spawn, ServerHandle};
pub use session::{handle_line, Control, ServeConfig, ServeState};
