//! Name-blind structural kernel fingerprints — the cache key derivation.
//!
//! Two 64-bit hashes per kernel, both computed in one positional
//! pre-order walk over the finalized tree (the same canonical form
//! [`Kernel::structural_diff`] compares, minus every name):
//!
//! * **exact** — everything the solve outcome depends on: dtype, array
//!   extents/directions, the loop tree shape, affine bounds, statement
//!   accesses (array id + index expressions), op multisets and chains.
//!   Two kernels with equal exact fingerprints produce bit-identical
//!   `SolveResult`s for the same (device, space, evaluator) — the full
//!   cache-hit key.
//! * **warm** — the shape alone: extents, bound constants, and dtype are
//!   dropped, keeping the tree, the bound/index *coefficient* structure,
//!   array directions, and op structure. Two kernels with equal warm
//!   fingerprints are "the same nest at new sizes/precision" — the
//!   resubmission regime the ISSUE's warm-start targets, where a cached
//!   incumbent re-verifies as a seed but the solve must still run.
//!
//! Names are deliberately excluded everywhere (kernel, iterators,
//! statements, arrays): a pretty-printed round-trip or a renamed-iterator
//! copy of a kernel is the *same* problem, and must hit the same cache
//! line. Ids do participate — they are dense creation-order indices, so
//! after finalization they encode tree positions, not spellings.
//!
//! `DefaultHasher` is documented to hash identically across instances and
//! processes (the solver's `design_key` already relies on this), so the
//! fingerprints are stable across daemon restarts.

use crate::ir::{AffineExpr, Kernel, Node};
use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};

/// The pair of structural hashes of one kernel (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Fingerprint {
    /// Full structural hash: same value ⇒ same solve outcome (given the
    /// same space/device/evaluator).
    pub exact: u64,
    /// Shape-only hash: same value ⇒ same nest modulo sizes/precision
    /// (the warm-start index).
    pub warm: u64,
}

/// Compute both fingerprints of a kernel in one tree walk.
pub fn fingerprint(k: &Kernel) -> Fingerprint {
    Fingerprint {
        exact: hash_kernel(k, true),
        warm: hash_kernel(k, false),
    }
}

/// [`fingerprint`] within a named search space.
///
/// Ops whose answer depends on more than the kernel structure — `dse
/// --transform` explores an enumerated variant space whose extent is
/// set by the request's enumeration bounds — mix a `space`
/// discriminator into *both* hashes, so results computed over
/// different spaces never share a cache line: the same kernel with and
/// without `--transform` (or with different bounds) gets distinct
/// exact keys, and a warm seed from one space cannot leak into
/// another. The empty space is the plain structural [`fingerprint`].
pub fn fingerprint_spaced(k: &Kernel, space: &str) -> Fingerprint {
    let base = fingerprint(k);
    if space.is_empty() {
        return base;
    }
    let mix = |seed: u64| {
        let mut h = DefaultHasher::new();
        seed.hash(&mut h);
        space.hash(&mut h);
        h.finish()
    };
    Fingerprint {
        exact: mix(base.exact),
        warm: mix(base.warm),
    }
}

fn hash_kernel(k: &Kernel, exact: bool) -> u64 {
    let mut h = DefaultHasher::new();
    if exact {
        k.dtype.bits().hash(&mut h);
    }
    k.arrays.len().hash(&mut h);
    for a in &k.arrays {
        // positional: id order is declaration order on both sides
        a.id.0.hash(&mut h);
        if exact {
            a.dims.hash(&mut h);
        } else {
            // shape only: dimensionality, not extents
            a.dims.len().hash(&mut h);
        }
        a.dir.word().hash(&mut h);
    }
    k.roots.len().hash(&mut h);
    for r in &k.roots {
        hash_node(k, r, exact, &mut h);
    }
    h.finish()
}

fn hash_node(k: &Kernel, n: &Node, exact: bool, h: &mut DefaultHasher) {
    match n {
        Node::Loop(l) => {
            0u8.hash(h);
            l.id.0.hash(h);
            hash_expr(&l.lb, exact, h);
            hash_expr(&l.ub, exact, h);
            l.body.len().hash(h);
            for c in &l.body {
                hash_node(k, c, exact, h);
            }
        }
        Node::Stmt(s) => {
            1u8.hash(h);
            s.id.0.hash(h);
            for (accs, tag) in [(&s.writes, 2u8), (&s.reads, 3u8)] {
                tag.hash(h);
                accs.len().hash(h);
                for a in accs {
                    a.array.0.hash(h);
                    a.indices.len().hash(h);
                    for idx in &a.indices {
                        // index constants are structural (A[i+1] vs A[i]),
                        // not sizes — hash them in both modes
                        idx.hash(h);
                    }
                }
            }
            s.ops.hash(h);
            s.chain.hash(h);
        }
    }
}

/// Bound expressions: the warm hash keeps the iterator/coefficient
/// structure (which loops a bound depends on, triangularity) but drops
/// the constant — that is where problem sizes live.
fn hash_expr(e: &AffineExpr, exact: bool, h: &mut DefaultHasher) {
    e.terms.hash(h);
    if exact {
        e.constant.hash(h);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{self, Size};
    use crate::ir::DType;

    #[test]
    fn fingerprint_is_deterministic_and_name_blind() {
        let k1 = benchmarks::kernel_gemm(60, 70, 80, DType::F32);
        let k2 = benchmarks::kernel_gemm(60, 70, 80, DType::F32);
        assert_eq!(fingerprint(&k1), fingerprint(&k2));

        // a pretty-printed round-trip is structurally identical and must
        // map to the same key (the ISSUE's soundness direction)
        let text = crate::frontend::pretty::print(&k1);
        let k3 = crate::frontend::parse_kernel(&text, "<test>").unwrap();
        assert_eq!(k1.structural_diff(&k3), None);
        assert_eq!(fingerprint(&k1), fingerprint(&k3));

        // renaming the kernel and every identifier changes no fingerprint
        let renamed = text
            .replace("gemm", "zzz")
            .replace("for i ", "for ii ")
            .replace("[i]", "[ii]");
        let k4 = crate::frontend::parse_kernel(&renamed, "<test>").unwrap();
        assert!(k1.structural_diff(&k4).is_some(), "names differ");
        assert_eq!(fingerprint(&k1), fingerprint(&k4), "fingerprints must not");
    }

    #[test]
    fn sizes_and_dtype_split_exact_but_not_warm() {
        let small = benchmarks::build("gemm", Size::Small, DType::F32).unwrap();
        let medium = benchmarks::build("gemm", Size::Medium, DType::F32).unwrap();
        let f64v = benchmarks::build("gemm", Size::Small, DType::F64).unwrap();
        let (fs, fm, f6) = (fingerprint(&small), fingerprint(&medium), fingerprint(&f64v));
        assert_ne!(fs.exact, fm.exact, "sizes change the exact key");
        assert_ne!(fs.exact, f6.exact, "precision changes the exact key");
        assert_eq!(fs.warm, fm.warm, "same nest shape warm-matches");
        assert_eq!(fs.warm, f6.warm, "precision is warm-invariant");
    }

    #[test]
    fn spaced_fingerprints_partition_by_space_string() {
        let k = benchmarks::kernel_gemm(60, 70, 80, DType::F32);
        let base = fingerprint(&k);
        assert_eq!(fingerprint_spaced(&k, ""), base, "empty space is the plain key");
        let t1 = fingerprint_spaced(&k, "transform variants=24 depth=2 perm=4");
        let t2 = fingerprint_spaced(&k, "transform variants=8 depth=1 perm=4");
        assert_ne!(t1.exact, base.exact, "± transform must split the exact key");
        assert_ne!(t1.warm, base.warm, "warm seeds must not cross spaces");
        assert_ne!(t1.exact, t2.exact, "different bounds are different spaces");
        // deterministic: same kernel + same space → same key
        assert_eq!(
            t1,
            fingerprint_spaced(&k, "transform variants=24 depth=2 perm=4")
        );
    }

    #[test]
    fn different_kernels_have_different_keys() {
        let names = ["gemm", "2mm", "bicg", "atax", "mvt", "gesummv"];
        let fps: Vec<u64> = names
            .iter()
            .map(|n| fingerprint(&benchmarks::build(n, Size::Small, DType::F32).unwrap()).exact)
            .collect();
        for i in 0..fps.len() {
            for j in i + 1..fps.len() {
                assert_ne!(fps[i], fps[j], "{} vs {}", names[i], names[j]);
            }
        }
    }
}
