//! The TCP front of the daemon: accept loop, per-connection readers, and
//! the bounded worker pool that actually runs requests.
//!
//! Layout (no async runtime — std networking plus the coordinator's
//! [`ThreadPool`]):
//!
//! * the **accept thread** polls a non-blocking listener (~25 ms) so it
//!   can notice the shutdown latch between connections;
//! * each connection gets a cheap **reader thread** that frames lines
//!   and enqueues one pool job per request through a `Weak` pool handle
//!   (the accept thread stays the pool's only owner, so shutdown can
//!   always drain) — concurrency across clients is bounded by the pool
//!   (`--threads`), not by connection count;
//! * responses go back through a per-connection mutexed writer, so
//!   concurrent jobs of one pipelining client interleave whole lines,
//!   never bytes (clients correlate by `id`);
//! * shutdown latches via the `shutdown` op, [`ServerHandle::shutdown`],
//!   or SIGTERM/SIGINT when [`install_signal_handlers`] was called (the
//!   CLI does; in-process tests don't touch process signals). The accept
//!   thread then drains the pool and returns.

use super::session::{self, ServeConfig, ServeState};
use crate::coordinator::pool::ThreadPool;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::{Arc, Mutex, Weak};
use std::thread;
use std::time::Duration;

/// Accept-loop poll interval: the latency bound on noticing shutdown.
const POLL: Duration = Duration::from_millis(25);

/// A running daemon: its bound address, shared state, and the accept
/// thread to join.
pub struct ServerHandle {
    addr: SocketAddr,
    state: Arc<ServeState>,
    accept: Option<thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// The actually-bound address (resolves `:0` ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The daemon's shared state (tests and the smoke harness poke the
    /// cache/stats through this).
    pub fn state(&self) -> &Arc<ServeState> {
        &self.state
    }

    /// Latch shutdown; the accept loop exits within one poll interval.
    pub fn shutdown(&self) {
        self.state.request_shutdown();
    }

    /// Wait for the accept loop to drain in-flight work and exit.
    pub fn join(mut self) {
        if let Some(t) = self.accept.take() {
            let _ = t.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.state.request_shutdown();
        if let Some(t) = self.accept.take() {
            let _ = t.join();
        }
    }
}

/// Bind `addr` (e.g. `127.0.0.1:4517`; port `0` picks an ephemeral one)
/// and serve on a pool of `threads` workers until shutdown latches.
pub fn spawn(addr: &str, cfg: ServeConfig, threads: usize) -> Result<ServerHandle> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let bound = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let state = Arc::new(ServeState::new(cfg));
    let accept_state = state.clone();
    let threads = threads.max(1);
    let accept = thread::Builder::new()
        .name("nlpdse-serve-accept".into())
        .spawn(move || accept_loop(listener, accept_state, threads))
        .context("spawning accept thread")?;
    Ok(ServerHandle {
        addr: bound,
        state,
        accept: Some(accept),
    })
}

fn accept_loop(listener: TcpListener, state: Arc<ServeState>, threads: usize) {
    // The accept thread is the pool's *only* strong owner: readers get a
    // Weak they upgrade just long enough to enqueue (`execute` is one
    // channel send). That keeps the drain-then-exit guarantee honest —
    // if readers held Arc clones, a reader blocked on an open client
    // socket would keep the pool alive past loop exit and in-flight
    // solves would be killed when main returns.
    let mut pool = Arc::new(Mutex::new(ThreadPool::new(threads)));
    let mut readers = Vec::new();
    while !state.shutdown_requested() && !term_signalled() {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let state = state.clone();
                let pool = Arc::downgrade(&pool);
                let r = thread::Builder::new()
                    .name("nlpdse-serve-conn".into())
                    .spawn(move || serve_connection(state, pool, stream));
                if let Ok(r) = r {
                    readers.push(r);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => thread::sleep(POLL),
            Err(_) => thread::sleep(POLL),
        }
        readers.retain(|r| !r.is_finished());
    }
    if term_signalled() {
        state.request_shutdown();
    }
    // reclaim sole ownership (readers only hold transient upgrades
    // across an enqueue, so this converges in microseconds), then drain:
    // ThreadPool::join closes the queue and runs every request already
    // accepted — clients awaiting long solves still get their results
    let pool = loop {
        match Arc::try_unwrap(pool) {
            Ok(m) => break m.into_inner().unwrap_or_else(|e| e.into_inner()),
            Err(p) => {
                pool = p;
                thread::sleep(Duration::from_millis(1));
            }
        }
    };
    pool.join();
    // give lingering readers a short grace period; ones still blocked on
    // an open client socket are left detached (their next upgrade fails,
    // so they exit without touching the drained pool)
    let deadline = std::time::Instant::now() + Duration::from_millis(250);
    while std::time::Instant::now() < deadline && readers.iter().any(|r| !r.is_finished()) {
        thread::sleep(Duration::from_millis(10));
    }
    for r in readers {
        if r.is_finished() {
            let _ = r.join();
        }
    }
}

fn serve_connection(state: Arc<ServeState>, pool: Weak<Mutex<ThreadPool>>, stream: TcpStream) {
    // accepted sockets can inherit the listener's non-blocking mode
    let _ = stream.set_nonblocking(false);
    let writer = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if state.shutdown_requested() {
            break;
        }
        if line.trim().is_empty() {
            continue;
        }
        // the accept loop dropped the pool: it is drained/draining, so
        // stop reading rather than enqueue work that can never run
        let Some(pool) = pool.upgrade() else { break };
        state.queue_enter();
        let state = state.clone();
        let writer = writer.clone();
        let job = move || {
            let mut emit = |l: &str| {
                let mut w = writer.lock().unwrap();
                let _ = writeln!(w, "{l}");
                let _ = w.flush();
            };
            // a Shutdown control already latched the shared state; the
            // accept loop notices within one poll interval
            let _ = session::handle_line(&state, &line, &mut emit);
            state.queue_exit();
        };
        pool.lock().unwrap().execute(job);
    }
}

#[cfg(unix)]
mod sig {
    //! SIGTERM/SIGINT latch without the libc crate: the two symbols we
    //! need (`signal(2)` and the handler ABI) are declared directly.

    use std::sync::atomic::{AtomicBool, Ordering};

    pub static TERM: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_term(_signum: i32) {
        // async-signal-safe: one atomic store
        TERM.store(true, Ordering::SeqCst);
    }

    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }

    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;

    pub fn install() {
        unsafe {
            signal(SIGTERM, on_term as usize);
            signal(SIGINT, on_term as usize);
        }
    }
}

/// Route SIGTERM/SIGINT into a clean daemon shutdown (the accept loop
/// polls the latch). The CLI `serve` command calls this; in-process
/// embedders (tests) should not, as it replaces process-wide handlers.
/// No-op on non-unix targets.
pub fn install_signal_handlers() {
    #[cfg(unix)]
    sig::install();
}

fn term_signalled() -> bool {
    #[cfg(unix)]
    {
        sig::TERM.load(std::sync::atomic::Ordering::SeqCst)
    }
    #[cfg(not(unix))]
    {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn send(addr: SocketAddr, lines: &[&str], expect: usize) -> Vec<Json> {
        let mut s = TcpStream::connect(addr).expect("connect");
        for l in lines {
            writeln!(s, "{l}").unwrap();
        }
        let mut out = Vec::new();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut buf = String::new();
        while out.len() < expect {
            buf.clear();
            if r.read_line(&mut buf).unwrap() == 0 {
                break;
            }
            out.push(Json::parse(buf.trim()).unwrap_or_else(|e| panic!("`{buf}`: {e}")));
        }
        out
    }

    #[test]
    fn tcp_roundtrip_and_clean_shutdown() {
        let h = spawn(
            "127.0.0.1:0",
            ServeConfig {
                jobs: 1,
                cache_entries: 8,
            },
            2,
        )
        .unwrap();
        let addr = h.addr();
        let events = send(addr, &[r#"{"op":"stats","id":1}"#], 1);
        assert_eq!(events[0].get("event").and_then(|j| j.as_str()), Some("result"));
        assert_eq!(events[0].get("id").and_then(|j| j.as_u64()), Some(1));
        // `shutdown` answers, then the daemon exits on its own
        let events = send(addr, &[r#"{"op":"shutdown","id":2}"#], 1);
        assert_eq!(events[0].get("event").and_then(|j| j.as_str()), Some("result"));
        h.join();
        assert!(TcpStream::connect(addr).is_err(), "listener must be gone");
    }
}
