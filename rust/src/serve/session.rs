//! Transport-agnostic request dispatch: one line in, event lines out.
//!
//! [`handle_line`] is the whole daemon minus the socket: it parses a
//! request, lowers the kernel spec exactly like the CLI
//! (registry name via [`benchmarks::lookup`], inline `.knl` text via
//! [`frontend::parse_kernel`]), consults the [`WarmCache`], runs the op,
//! and hands every response line to the caller's `emit` closure. The TCP
//! server ([`super::server`]) feeds it socket lines; the test suites feed
//! it strings directly — both exercise the identical code path.
//!
//! Solve requests get the full cache treatment (DESIGN.md §11):
//!
//! 1. exact fingerprint + (device, evaluator, cap, fine, topk) hits the
//!    solve cache → the stored result is replayed bit-identically,
//!    `cache: "hit"`;
//! 2. on a miss, the bound model + compiled tape are reused from the
//!    model cache when any same-fingerprint kernel built them before;
//! 3. the warm index is consulted for a same-shape (warm fingerprint,
//!    same device/evaluator/cap/fine) prior solve; its designs seed
//!    [`nlp::solve_jobs_seeded`] and the response reports
//!    `cache: "warm"`, else `"miss"`. Warm-seeded results refresh the
//!    warm index but are *not* admitted to the exact solve cache — a
//!    menu-unreachable seed may improve the top-k, so only unseeded
//!    solves are pure functions of their key (DESIGN.md §11).
//!
//! `emit --design_from solve` routes through the same path, so repeated
//! emissions of a cached kernel are instant and attributed.
//!
//! `dse` requests replay through their own spaced-fingerprint cache
//! ([`DseKey`]): the simulated DSE clock makes every completed
//! exploration a pure function of its key, and `dse` with
//! `"transform": true` mixes the variant-enumeration bounds into the
//! fingerprint so the same kernel ± transform occupies distinct cache
//! lines. A first-time transform `dse` is additionally warm-seeded from
//! the untransformed kernel's default-sub-space solve incumbents
//! (plain warm fingerprint, cap = `MAX`, coarse): every variant's
//! ladder starts from the re-verified seeds
//! ([`run_transform_dse_seeded`]), the response reports
//! `cache: "warm"`, and — exactly like warm solves — the seeded
//! payload is *not* admitted to the replay cache, keeping replay lines
//! history-independent. A `dse` with engine `surrogate` mixes the
//! ranking artifact's content hash and the verify fraction into the
//! space string the same way, so a retrained model (or a different
//! cut) starts cold instead of replaying a stale exploration.
//!
//! `system` requests replay through [`SystemKey`]: the kernel list is
//! canonicalized (sorted by exact fingerprint, then name) *before*
//! solving, so order-permuted requests share one cache line and replay
//! bit-identically, and only runs whose every per-kernel solve
//! completed (`optimal`) are admitted — an anytime (timed-out) front is
//! not a pure function of the key. Every op's `hit`/`warm`/`miss` attribution is also counted
//! per op (the `stats` payload's per-op `cache` object) — the global
//! [`CacheStats`](super::cache::CacheStats) counters alone cannot say
//! *which* op's traffic warmed or missed.

use super::cache::{DseKey, SolveKey, SystemKey, WarmCache, WarmKey};
use super::fingerprint::{fingerprint, fingerprint_spaced};
use super::protocol::{self, Request};
use crate::benchmarks::{self, Size};
use crate::engine::{Evaluator, Explorer};
use crate::frontend;
use crate::hls::Device;
use crate::ir::{DType, Kernel, LoopId};
use crate::model::sym::{BoundModel, PartialDesign};
use crate::nlp::{self, BatchEvaluator, NlpProblem, SolveResult};
use crate::poly::Analysis;
use crate::pragma::Design;
use crate::transform::{run_transform_dse_seeded, TransformConfig, TransformOutcome};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Daemon-wide knobs (CLI: `serve --jobs N --cache-entries K`).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Default NLP-solver worker-team size per request (a request's own
    /// `jobs` field overrides; results are bit-identical either way).
    pub jobs: usize,
    /// LRU capacity of each cache map; 0 disables caching.
    pub cache_entries: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            jobs: nlp::default_jobs(),
            cache_entries: 64,
        }
    }
}

/// Number of log₂ latency buckets tracked per op (bucket *i* counts
/// requests that took `[2^i, 2^(i+1))` milliseconds; the last bucket is
/// open-ended).
pub const LAT_BUCKETS: usize = 16;

#[derive(Clone, Copy, Default)]
struct OpRecord {
    count: u64,
    errors: u64,
    /// Requests answered from a replay cache (`cache: "hit"`).
    hit: u64,
    /// Requests solved with warm-start seeds (`cache: "warm"`).
    warm: u64,
    /// Requests computed cold (`cache: "miss"`).
    miss: u64,
    lat: [u64; LAT_BUCKETS],
}

/// Shared daemon state: config, warm cache, per-op counters, queue
/// depth, and the shutdown latch. One instance per daemon, shared by
/// every connection.
pub struct ServeState {
    cfg: ServeConfig,
    cache: Mutex<WarmCache>,
    ops: Mutex<BTreeMap<String, OpRecord>>,
    queue_depth: AtomicUsize,
    shutdown: AtomicBool,
    started: Instant,
}

impl ServeState {
    /// Fresh daemon state.
    pub fn new(cfg: ServeConfig) -> ServeState {
        ServeState {
            cache: Mutex::new(WarmCache::new(cfg.cache_entries)),
            cfg,
            ops: Mutex::new(BTreeMap::new()),
            queue_depth: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            started: Instant::now(),
        }
    }

    /// Latch the shutdown flag (idempotent; `shutdown` op or SIGTERM).
    pub fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }

    /// Whether shutdown has been requested.
    pub fn shutdown_requested(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// A request entered the work queue (server accounting).
    pub fn queue_enter(&self) {
        self.queue_depth.fetch_add(1, Ordering::SeqCst);
    }

    /// A request left the work queue.
    pub fn queue_exit(&self) {
        self.queue_depth.fetch_sub(1, Ordering::SeqCst);
    }

    fn record(&self, op: &str, elapsed: Duration, ok: bool, cache: Option<&str>) {
        let ms = elapsed.as_millis() as u64;
        let idx = (u64::BITS - ms.clamp(1, 1 << (LAT_BUCKETS - 1)).leading_zeros() - 1) as usize;
        let mut ops = self.ops.lock().unwrap();
        let rec = ops.entry(op.to_string()).or_default();
        rec.count += 1;
        if !ok {
            rec.errors += 1;
        }
        match cache {
            Some("hit") => rec.hit += 1,
            Some("warm") => rec.warm += 1,
            Some("miss") => rec.miss += 1,
            _ => {}
        }
        rec.lat[idx] += 1;
    }
}

/// What the connection loop should do after a handled line.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Control {
    /// Keep reading requests.
    Continue,
    /// The client asked the daemon to stop: close and shut down.
    Shutdown,
}

/// A failed request: a one-line message, plus the frontend's rendered
/// caret diagnostic when the failure was a `.knl` parse error.
struct Fail {
    msg: String,
    diagnostic: Option<String>,
}

impl From<String> for Fail {
    fn from(msg: String) -> Fail {
        Fail {
            msg,
            diagnostic: None,
        }
    }
}

impl From<anyhow::Error> for Fail {
    fn from(e: anyhow::Error) -> Fail {
        Fail {
            msg: format!("{e:#}"),
            diagnostic: None,
        }
    }
}

/// Handle one request line, emitting zero or more progress lines and
/// exactly one terminal line through `emit` (blank input emits nothing).
/// Every line is a complete JSON object without trailing newline.
pub fn handle_line(state: &ServeState, line: &str, emit: &mut dyn FnMut(&str)) -> Control {
    let line = line.trim();
    if line.is_empty() {
        return Control::Continue;
    }
    let req = match protocol::parse_request(line) {
        Ok(r) => r,
        Err(msg) => {
            emit(&protocol::error_line(&None, &msg, None));
            return Control::Continue;
        }
    };
    let t0 = Instant::now();
    let out = dispatch(state, &req, emit);
    let ok = out.is_ok();
    let cache_tag = match &out {
        Ok((tag, _)) => *tag,
        Err(_) => None,
    };
    state.record(&req.op, t0.elapsed(), ok, cache_tag);
    match out {
        Ok((cache, data)) => emit(&protocol::result_line(&req.id, &req.op, cache, data)),
        Err(f) => emit(&protocol::error_line(&req.id, &f.msg, f.diagnostic.as_deref())),
    }
    if ok && req.op == "shutdown" {
        state.request_shutdown();
        Control::Shutdown
    } else {
        Control::Continue
    }
}

fn dispatch(
    state: &ServeState,
    req: &Request,
    emit: &mut dyn FnMut(&str),
) -> Result<(Option<&'static str>, Json), Fail> {
    match req.op.as_str() {
        "solve" => op_solve(state, req, emit),
        "dse" => op_dse(state, req, emit),
        "system" => op_system(state, req, emit),
        "bound" => op_bound(req),
        "emit" => op_emit(state, req, emit),
        "gen" => op_gen(req),
        "stats" => Ok((None, op_stats(state))),
        "shutdown" => {
            let mut data = Json::obj();
            data.set("stopping", true);
            Ok((None, data))
        }
        other => Err(format!(
            "unknown op `{other}` (want solve|dse|system|bound|emit|gen|stats|shutdown)"
        )
        .into()),
    }
}

/// Kernel resolution, mirroring the CLI: inline `knl` source text wins
/// (sizes live in the text), else `kernel` names a registry benchmark at
/// `size`/`dtype`.
fn resolve_kernel(req: &Request) -> Result<Kernel, Fail> {
    if let Some(text) = req.str_opt("knl")? {
        return frontend::parse_kernel(&text, "<request>").map_err(|e| Fail {
            msg: format!("parsing inline kernel: {}", e.msg),
            diagnostic: Some(e.to_string()),
        });
    }
    let name = req.str_opt("kernel")?.ok_or_else(|| {
        String::from("request needs \"kernel\" (benchmark name) or \"knl\" (inline .knl source)")
    })?;
    let size = match req.str_opt("size")? {
        None => Size::Medium,
        Some(s) => Size::parse(&s).ok_or_else(|| format!("bad \"size\" `{s}` (want S|M|L)"))?,
    };
    let dtype = match req.str_opt("dtype")? {
        None => DType::F32,
        Some(s) => {
            DType::from_name(&s).ok_or_else(|| format!("bad \"dtype\" `{s}` (want f32|f64)"))?
        }
    };
    Ok(benchmarks::lookup(&name, size, dtype)?)
}

fn resolve_loop(k: &Kernel, tok: &str) -> Result<LoopId, Fail> {
    for i in 0..k.n_loops() {
        let l = LoopId(i as u32);
        if k.loop_name(l) == tok || format!("L{i}") == tok || i.to_string() == tok {
            return Ok(l);
        }
    }
    Err(format!(
        "unknown loop `{tok}` (loops: {})",
        (0..k.n_loops())
            .map(|i| k.loop_name(LoopId(i as u32)).to_string())
            .collect::<Vec<_>>()
            .join(", ")
    )
    .into())
}

fn evaluator_tag(req: &Request) -> Result<String, Fail> {
    let tag = req.str_opt("evaluator")?.unwrap_or_else(|| "rust".into());
    match tag.as_str() {
        "rust" | "sym" => Ok(tag),
        other => Err(format!("bad \"evaluator\" `{other}` (want rust|sym)").into()),
    }
}

fn solver_evaluator(tag: &str) -> Box<dyn BatchEvaluator> {
    match tag {
        "sym" => Box::new(nlp::SymbolicEvaluator),
        _ => Box::new(nlp::RustFeatureEvaluator),
    }
}

/// The cached solve pipeline shared by `solve` and `emit --design_from
/// solve`: exact-key replay, model reuse, warm-start seeding (module
/// docs spell out the order).
fn run_solve(
    state: &ServeState,
    req: &Request,
    emit: &mut dyn FnMut(&str),
    k: &Kernel,
    a: &Analysis,
    dev: &Device,
) -> Result<(&'static str, Arc<SolveResult>), Fail> {
    let cap = req.u64_opt("cap")?.unwrap_or(u64::MAX);
    let fine = req.bool_opt("fine")?.unwrap_or(false);
    let topk = req.u64_opt("topk")?.unwrap_or(3).clamp(1, 64) as usize;
    let jobs = match req.u64_opt("jobs")? {
        Some(0) => return Err(String::from("\"jobs\" must be >= 1 (1 = serial path)").into()),
        Some(n) => n as usize,
        None => state.cfg.jobs,
    };
    let timeout_s = req.f64_opt("timeout_s")?.unwrap_or(30.0);
    let eval_tag = evaluator_tag(req)?;

    let fp = fingerprint(k);
    let key = SolveKey {
        kernel_fp: fp.exact,
        device: dev.name.to_string(),
        evaluator: eval_tag.clone(),
        cap,
        fine,
        topk,
    };
    if let Some(hit) = state.cache.lock().unwrap().lookup_solve(&key) {
        return Ok(("hit", hit));
    }

    // miss: reuse (or build and admit) the bound model + compiled tape
    let cached_model = state.cache.lock().unwrap().lookup_model(fp.exact, dev.name);
    let model_cached = cached_model.is_some();
    let (bound, compiled) = match cached_model {
        Some(pair) => pair,
        None => {
            let bound = Arc::new(BoundModel::build(k, a, dev));
            let compiled = Arc::new(bound.compile());
            state.cache.lock().unwrap().insert_model(
                fp.exact,
                dev.name,
                bound.clone(),
                compiled.clone(),
            );
            (bound, compiled)
        }
    };
    // seeds only cross size/precision changes, never space restrictions
    // or evaluators: the warm key repeats every SolveKey field except
    // the exact structural hash
    let warm_key = key.warm_key(fp.warm);
    let seeds = {
        let mut cache = state.cache.lock().unwrap();
        let seeds = cache.warm_seeds(&warm_key).unwrap_or_default();
        cache.note_dispatch(!seeds.is_empty());
        seeds
    };
    emit(&protocol::progress_line(
        &req.id,
        &req.op,
        &format!(
            "model {} | {} warm seed(s) | solving jobs={jobs}",
            if model_cached { "cached" } else { "built" },
            seeds.len()
        ),
    ));

    let problem = NlpProblem::with_model(k, a, dev, cap, fine, bound, compiled);
    let eval = solver_evaluator(&eval_tag);
    let result = Arc::new(nlp::solve_jobs_seeded(
        &problem,
        timeout_s,
        topk,
        eval.as_ref(),
        jobs,
        &seeds,
    ));
    let seeded = !seeds.is_empty();
    let tag = if seeded { "warm" } else { "miss" };
    state
        .cache
        .lock()
        .unwrap()
        .insert_solve(key, fp.warm, &result, seeded);
    Ok((tag, result))
}

fn design_json(k: &Kernel, d: &Design) -> Json {
    let mut pragmas = Json::Arr(vec![]);
    for (i, p) in d.pragmas.iter().enumerate() {
        let mut o = Json::obj();
        o.set("loop", k.loop_name(LoopId(i as u32)))
            .set("uf", p.uf)
            .set("tile", p.tile)
            .set("pipeline", p.pipeline);
        pragmas.push(o);
    }
    pragmas
}

fn solve_json(k: &Kernel, a: &Analysis, dev: &Device, r: &SolveResult) -> Json {
    let mut designs = Json::Arr(vec![]);
    for (d, obj) in &r.designs {
        let mut o = Json::obj();
        o.set("objective_cycles", *obj)
            .set("gflops", a.gflops(*obj, dev.freq_hz))
            .set("pragmas", design_json(k, d));
        designs.push(o);
    }
    let mut data = Json::obj();
    data.set("kernel", k.name.as_str())
        .set("lower_bound", r.lower_bound)
        .set("optimal", r.optimal)
        .set("solve_time_s", r.solve_time_s)
        .set("jobs", r.jobs)
        .set("nodes", r.stats.nodes)
        .set("scored", r.stats.candidates_scored)
        .set("designs", designs);
    data
}

fn op_solve(
    state: &ServeState,
    req: &Request,
    emit: &mut dyn FnMut(&str),
) -> Result<(Option<&'static str>, Json), Fail> {
    let k = resolve_kernel(req)?;
    let a = Analysis::new(&k);
    let dev = Device::u200();
    let (tag, r) = run_solve(state, req, emit, &k, &a, &dev)?;
    Ok((Some(tag), solve_json(&k, &a, &dev, &r)))
}

/// The `(variant × pragma)` enumeration bounds of a `dse` request with
/// `"transform": true`.
fn transform_config(req: &Request) -> Result<TransformConfig, Fail> {
    let mut tcfg = TransformConfig::default();
    if let Some(v) = req.u64_opt("max_variants")? {
        if v == 0 {
            return Err(String::from("\"max_variants\" must be >= 1").into());
        }
        tcfg.max_variants = v as usize;
    }
    if let Some(v) = req.u64_opt("max_depth")? {
        tcfg.max_depth = v as usize;
    }
    if let Some(v) = req.u64_opt("max_perm_loops")? {
        tcfg.max_perm_loops = v as usize;
    }
    Ok(tcfg)
}

/// Render a `(variant × pragma)` search as the `dse` response payload:
/// per-variant fates, the winning rewrite chain, and the winner's best
/// design (pragmas are named against the *winning* kernel's loops).
fn transform_dse_json(o: &TransformOutcome, dev: &Device) -> Json {
    let wk = &o.variant.kernel;
    let a = Analysis::new(wk);
    let trace_json = |trace: &[String]| {
        let mut t = Json::Arr(vec![]);
        for s in trace {
            t.push(Json::from(s.as_str()));
        }
        t
    };
    let mut variants = Json::Arr(vec![]);
    for r in &o.records {
        let mut v = Json::obj();
        v.set("index", r.index)
            .set("trace", trace_json(&r.trace))
            .set("lower_bound", r.lower_bound)
            .set("pruned", r.pruned);
        if let Some(c) = r.cycles {
            v.set("cycles", c);
        }
        if let Some(g) = r.gflops {
            v.set("gflops", g);
        }
        variants.push(v);
    }
    let mut data = Json::obj();
    data.set("kernel", o.kernel.as_str())
        .set("engine", "transform")
        .set("space", o.config.describe())
        .set("variants", variants)
        .set("variants_pruned", o.pruned)
        .set("winner", o.winner)
        .set("winner_trace", trace_json(&o.winning_trace()))
        .set("best_gflops", o.outcome.best_gflops);
    match &o.outcome.best {
        Some((d, cycles)) => {
            data.set("best_cycles", *cycles)
                .set("gflops", a.gflops(*cycles, dev.freq_hz))
                .set("best_pragmas", design_json(wk, d));
        }
        None => {
            data.set("best_pragmas", Json::Null);
        }
    }
    data
}

fn op_dse(
    state: &ServeState,
    req: &Request,
    emit: &mut dyn FnMut(&str),
) -> Result<(Option<&'static str>, Json), Fail> {
    let k = resolve_kernel(req)?;
    let eval_tag = evaluator_tag(req)?;
    let jobs = match req.u64_opt("jobs")? {
        Some(0) => return Err(String::from("\"jobs\" must be >= 1").into()),
        Some(n) => n as usize,
        None => state.cfg.jobs,
    };
    let dse_cfg = crate::dse::DseConfig {
        prune_bound: req.bool_opt("prune_bound")?.unwrap_or(false),
        jobs,
        ..Default::default()
    };
    let transform = req.bool_opt("transform")?.unwrap_or(false);
    let tcfg = transform_config(req)?;
    let engine = if transform {
        "transform".to_string()
    } else {
        req.str_opt("engine")?.unwrap_or_else(|| "nlpdse".into())
    };
    let dev = Device::u200();

    // surrogate knobs: the artifact is loaded (and schema-checked) here,
    // and its content hash joins the spaced fingerprint below, so a
    // retrained model can never replay a stale exploration
    let model_file = req.str_opt("model_file")?;
    let verify_fraction = req.f64_opt("verify_fraction")?;
    if engine != "surrogate" && (model_file.is_some() || verify_fraction.is_some()) {
        return Err(String::from(
            "\"model_file\"/\"verify_fraction\" apply to engine `surrogate` only",
        )
        .into());
    }
    let mut surrogate_cfg = crate::surrogate::SurrogateConfig::default();
    let surrogate_space = if engine == "surrogate" {
        if let Some(f) = verify_fraction {
            if !(0.0..=1.0).contains(&f) {
                return Err(String::from(
                    "\"verify_fraction\" must be in [0, 1] (1.0 = the exact ladder)",
                )
                .into());
            }
            surrogate_cfg.verify_fraction = f;
        }
        // no artifact supplied: resolve the engine's deterministic
        // self-trained micro model here too, so the cache key always
        // names the exact model that ranked the candidates
        let model = match &model_file {
            Some(p) => crate::surrogate::SurrogateModel::load(std::path::Path::new(p))?,
            None => crate::surrogate::train(&surrogate_cfg.train).model,
        };
        let space = format!(
            "surrogate {:016x} vf={}",
            model.content_hash(),
            surrogate_cfg.verify_fraction
        );
        surrogate_cfg.model = Some(model);
        space
    } else {
        String::new()
    };

    // replay lookup: the spaced fingerprint partitions variant spaces
    // and surrogate artifacts, so the same kernel ± `transform` (or with
    // different enumeration bounds / a retrained model) never shares a
    // cache line
    let space = if transform {
        format!("transform {}", tcfg.describe())
    } else {
        surrogate_space
    };
    let fp = fingerprint_spaced(&k, &space);
    let key = DseKey {
        kernel_fp: fp.exact,
        device: dev.name.to_string(),
        evaluator: eval_tag.clone(),
        engine: engine.clone(),
        prune_bound: dse_cfg.prune_bound,
    };
    if let Some(hit) = state.cache.lock().unwrap().lookup_dse(&key) {
        return Ok((Some("hit"), (*hit).clone()));
    }

    emit(&protocol::progress_line(
        &req.id,
        &req.op,
        &format!("exploring with engine `{engine}`"),
    ));
    let (tag, data) = if transform {
        let eval = solver_evaluator(&eval_tag);
        // transform-aware warm seeding: the original kernel's cached
        // default-sub-space incumbents (`solve` at cap=MAX, coarse)
        // seed every variant's ladder. This deliberately crosses the
        // space boundary the spaced fingerprint enforces for *replay* —
        // it is sound here because each variant's solver re-verifies
        // every seed against its own model, and the seeded payload
        // below never enters the replay caches (history-independence:
        // a later identical request recomputes, bit-equal either way).
        let wkey = WarmKey {
            warm_fp: fingerprint(&k).warm,
            device: dev.name.to_string(),
            evaluator: eval_tag.clone(),
            cap: u64::MAX,
            fine: false,
        };
        let seeds = state
            .cache
            .lock()
            .unwrap()
            .warm_seeds(&wkey)
            .unwrap_or_default();
        if !seeds.is_empty() {
            emit(&protocol::progress_line(
                &req.id,
                &req.op,
                &format!("{} warm seed(s) from the untransformed kernel", seeds.len()),
            ));
        }
        let o = run_transform_dse_seeded(&k, &dev, &dse_cfg, &tcfg, eval.as_ref(), &seeds);
        let tag = if seeds.is_empty() { "miss" } else { "warm" };
        (tag, transform_dse_json(&o, &dev))
    } else {
        let eval = match eval_tag.as_str() {
            "sym" => Evaluator::sym(),
            _ => Evaluator::rust(),
        };
        let explorer = Explorer::custom(k)
            .evaluator(eval)
            .dse_config(dse_cfg)
            .surrogate_config(surrogate_cfg)
            .engine(&engine)?;
        let o = explorer.run()?;
        let k = explorer.kernel_ref();
        let mut data = Json::obj();
        data.set("kernel", o.kernel.as_str())
            .set("engine", o.engine.as_str())
            .set("best_gflops", o.best_gflops)
            .set("wall_minutes", o.wall_minutes)
            .set("synth_calls", o.synth_calls)
            .set("summary", o.summary().as_str());
        if let Some(lb) = o.lower_bound {
            data.set("lower_bound_cycles", lb);
        }
        match &o.best {
            Some((d, cycles)) => {
                data.set("best_cycles", *cycles)
                    .set("best_pragmas", design_json(k, d));
            }
            None => {
                data.set("best_pragmas", Json::Null);
            }
        }
        ("miss", data)
    };
    let mut cache = state.cache.lock().unwrap();
    cache.note_dispatch(tag == "warm");
    // seeded runs are kept out of the replay cache: replay lines must
    // be independent of what the warm cache happened to hold
    if tag != "warm" {
        cache.insert_dse(key, Arc::new(data.clone()));
    }
    drop(cache);
    Ok((Some(tag), data))
}

/// Render a system outcome as the `system` response payload:
/// per-kernel fronts (the allocator's chosen point flagged per row) and
/// the budget allocation totals against the device budgets. `kernels`
/// is the same canonical-order list the solve ran over, so
/// `kernels[i].1` names the loops of `out.kernels[i]`'s designs.
fn system_json(
    kernels: &[(String, Kernel)],
    out: &crate::system::SystemOutcome,
    dev: &Device,
) -> Json {
    let choice = out.alloc.best.as_ref().map(|b| b.choice.as_slice());
    let mut ks = Json::Arr(vec![]);
    for (i, kf) in out.kernels.iter().enumerate() {
        let chosen = choice.map(|c| c[i]);
        let mut front = Json::Arr(vec![]);
        for (j, p) in kf.front.iter().enumerate() {
            let mut o = Json::obj();
            o.set("latency_cycles", p.latency)
                .set("gflops", kf.gflops[j])
                .set("dsp", p.dsp)
                .set("onchip_bytes", p.onchip_bytes)
                .set("lut", p.lut)
                .set("chosen", chosen == Some(j))
                .set("pragmas", design_json(&kernels[i].1, &p.design));
            front.push(o);
        }
        let mut o = Json::obj();
        o.set("kernel", kf.name.as_str())
            .set("optimal", kf.optimal)
            .set("lower_bound", kf.lower_bound)
            .set("configs", kf.configs)
            .set("front", front);
        ks.push(o);
    }
    let mut alloc = Json::obj();
    match &out.alloc.best {
        Some(b) => {
            let mut ch = Json::Arr(vec![]);
            for &c in &b.choice {
                ch.push(c);
            }
            alloc
                .set("feasible", true)
                .set("choice", ch)
                .set("gflops", b.gflops)
                .set("dsp", b.dsp)
                .set("onchip_bytes", b.onchip_bytes)
                .set("lut", b.lut);
        }
        None => {
            alloc.set("feasible", false);
        }
    }
    alloc.set("nodes", out.alloc.nodes);
    let mut budget = Json::obj();
    budget
        .set("dsp", dev.dsp_total)
        .set("onchip_bytes", dev.onchip_bytes)
        .set("lut", dev.lut_total);
    let mut data = Json::obj();
    data.set("device", dev.name)
        .set("kernels", ks)
        .set("allocation", alloc)
        .set("budget", budget);
    data
}

/// `system`: per-kernel epsilon-dominance fronts plus the device-budget
/// allocation (DESIGN.md §14). `"kernels"` names registry benchmarks at
/// a shared `size`/`dtype` — the daemon takes no file paths, so inline
/// sources stay with the single-kernel ops. The kernel list is
/// canonicalized (sorted by exact fingerprint, then name) before the
/// replay lookup *and* the solve, so order-permuted requests share one
/// cache line and one payload.
fn op_system(
    state: &ServeState,
    req: &Request,
    emit: &mut dyn FnMut(&str),
) -> Result<(Option<&'static str>, Json), Fail> {
    let names = req.list_opt("kernels")?;
    if names.is_empty() {
        return Err(String::from(
            "request needs \"kernels\" (a list of benchmark names)",
        )
        .into());
    }
    let size = match req.str_opt("size")? {
        None => Size::Medium,
        Some(s) => Size::parse(&s).ok_or_else(|| format!("bad \"size\" `{s}` (want S|M|L)"))?,
    };
    let dtype = match req.str_opt("dtype")? {
        None => DType::F32,
        Some(s) => {
            DType::from_name(&s).ok_or_else(|| format!("bad \"dtype\" `{s}` (want f32|f64)"))?
        }
    };
    let epsilon = req.f64_opt("epsilon")?.unwrap_or(0.02);
    if !(0.0..1.0).contains(&epsilon) {
        return Err(format!("\"epsilon\" must be in [0, 1), got {epsilon}").into());
    }
    let max_points = req.u64_opt("max_points")?.unwrap_or(16).max(1) as usize;
    let cap = req.u64_opt("cap")?.unwrap_or(u64::MAX);
    let timeout_s = req.f64_opt("timeout_s")?.unwrap_or(30.0);
    let jobs = match req.u64_opt("jobs")? {
        Some(0) => return Err(String::from("\"jobs\" must be >= 1").into()),
        Some(n) => n as usize,
        None => state.cfg.jobs,
    };
    let eval_tag = evaluator_tag(req)?;
    let dev = Device::u200();

    let mut kernels: Vec<(u64, String, Kernel)> = Vec::with_capacity(names.len());
    for name in &names {
        let k = benchmarks::lookup(name, size, dtype)?;
        kernels.push((fingerprint(&k).exact, name.clone(), k));
    }
    kernels.sort_by(|a, b| (a.0, a.1.as_str()).cmp(&(b.0, b.1.as_str())));
    let key = SystemKey {
        kernel_fps: kernels.iter().map(|(fp, _, _)| *fp).collect(),
        device: dev.name.to_string(),
        evaluator: eval_tag.clone(),
        epsilon_bits: epsilon.to_bits(),
        max_points,
        cap,
    };
    if let Some(hit) = state.cache.lock().unwrap().lookup_system(&key) {
        return Ok((Some("hit"), (*hit).clone()));
    }

    emit(&protocol::progress_line(
        &req.id,
        &req.op,
        &format!("extracting {} front(s) | jobs={jobs}", kernels.len()),
    ));
    let cfg = crate::system::SystemConfig {
        front: nlp::FrontConfig {
            epsilon,
            max_points,
        },
        cap,
        timeout_s,
        jobs,
    };
    let list: Vec<(String, Kernel)> = kernels.into_iter().map(|(_, n, k)| (n, k)).collect();
    let eval = solver_evaluator(&eval_tag);
    let out = crate::system::solve_system(&list, &dev, &cfg, eval.as_ref());
    let data = system_json(&list, &out, &dev);
    let mut cache = state.cache.lock().unwrap();
    cache.note_dispatch(false);
    // anytime (timed-out) per-kernel fronts are not pure functions of
    // the key; only fully enumerated runs enter the replay cache
    if out.kernels.iter().all(|kf| kf.optimal) {
        cache.insert_system(key, Arc::new(data.clone()));
    }
    drop(cache);
    Ok((Some("miss"), data))
}

fn op_bound(req: &Request) -> Result<(Option<&'static str>, Json), Fail> {
    let k = resolve_kernel(req)?;
    let ex = Explorer::custom(k);
    let k = ex.kernel_ref();
    let mut partial = PartialDesign::free(k.n_loops());
    if let Some(cap) = req.u64_opt("cap")? {
        partial = partial.with_uf_cap(cap);
    }
    for (l, uf) in req.assign_opt("assign")? {
        partial.assign_uf(resolve_loop(k, &l)?, uf);
    }
    for tok in req.list_opt("pipeline")? {
        partial.assign_pipeline(resolve_loop(k, &tok)?, true);
    }
    let lb = ex.lower_bound(&partial);
    let mut data = Json::obj();
    data.set("kernel", k.name.as_str())
        .set("lower_bound_cycles", lb)
        .set("gflops_ceiling", ex.analysis().gflops(lb, ex.device_ref().freq_hz))
        .set("free_slots", partial.free_slots());
    Ok((None, data))
}

fn op_emit(
    state: &ServeState,
    req: &Request,
    emit: &mut dyn FnMut(&str),
) -> Result<(Option<&'static str>, Json), Fail> {
    let k = resolve_kernel(req)?;
    let a = Analysis::new(&k);
    let dev = Device::u200();
    let dialect = match req.str_opt("dialect")? {
        None => crate::codegen::Dialect::Merlin,
        Some(s) => crate::codegen::Dialect::parse(&s)
            .ok_or_else(|| format!("bad \"dialect\" `{s}` (want merlin|vitis)"))?,
    };
    let realized = req.bool_opt("realized")?.unwrap_or(false);

    let assigns = req.assign_opt("assign")?;
    let tiles = req.assign_opt("tile")?;
    let pipes = req.list_opt("pipeline")?;
    let manual = !assigns.is_empty() || !tiles.is_empty() || !pipes.is_empty();
    let from = req.str_opt("design_from")?;
    if manual && from.is_some() {
        return Err(String::from(
            "\"design_from\" conflicts with \"assign\"/\"pipeline\"/\"tile\" \
             (pick one design source)",
        )
        .into());
    }

    let (cache, design) = if manual {
        let mut d = Design::empty(&k);
        for (l, uf) in assigns {
            d.get_mut(resolve_loop(&k, &l)?).uf = uf;
        }
        for (l, t) in tiles {
            d.get_mut(resolve_loop(&k, &l)?).tile = t;
        }
        for tok in pipes {
            d.get_mut(resolve_loop(&k, &tok)?).pipeline = true;
        }
        (None, d)
    } else {
        match from.as_deref().unwrap_or("solve") {
            "empty" => (None, Design::empty(&k)),
            "solve" => {
                let (tag, r) = run_solve(state, req, emit, &k, &a, &dev)?;
                let d = r.best().map(|(d, _)| d.clone()).ok_or_else(|| {
                    format!(
                        "solver found no feasible design for `{}` (try a larger \"cap\")",
                        k.name
                    )
                })?;
                (Some(tag), d)
            }
            other => {
                return Err(format!(
                    "bad \"design_from\" `{other}` (want solve|empty, \
                     or use \"assign\"/\"pipeline\"/\"tile\")"
                )
                .into())
            }
        }
    };

    let code = crate::codegen::emit(
        &k,
        &a,
        &dev,
        &design,
        &crate::codegen::EmitConfig { dialect, realized },
    );
    let mut data = Json::obj();
    data.set("kernel", k.name.as_str())
        .set("dialect", dialect.name())
        .set("pragmas", design_json(&k, &design))
        .set("code", code);
    Ok((cache, data))
}

/// Per-request corpus cap: `gen` returns kernels inline, so a runaway
/// `count` would balloon one response line.
const MAX_GEN_COUNT: u64 = 32;

fn op_gen(req: &Request) -> Result<(Option<&'static str>, Json), Fail> {
    let seed = req.u64_opt("seed")?.unwrap_or(0);
    let count = req.u64_opt("count")?.unwrap_or(1);
    if count == 0 || count > MAX_GEN_COUNT {
        return Err(format!("\"count\" must be 1..={MAX_GEN_COUNT}").into());
    }
    if seed.checked_add(count - 1).is_none() {
        return Err(format!("\"seed\" {seed} + \"count\" {count} overflows the seed range").into());
    }
    let sampled = req.bool_opt("sampled")?.unwrap_or(false);
    let mut kernels = Json::Arr(vec![]);
    for i in 0..count {
        let s = seed + i;
        let mut cfg = if sampled {
            frontend::GenConfig::sampled(s)
        } else {
            frontend::GenConfig::with_seed(s)
        };
        if let Some(v) = req.u64_opt("depth")? {
            cfg.depth = v as usize;
        }
        if let Some(v) = req.u64_opt("width")? {
            cfg.width = v as usize;
        }
        if let Some(v) = req.u64_opt("nests")? {
            cfg.nests = v as usize;
        }
        if let Some(v) = req.u64_opt("arrays")? {
            cfg.arrays = v as usize;
        }
        if let Some(v) = req.u64_opt("max_trip")? {
            cfg.max_trip = v;
        }
        if let Some(s) = req.str_opt("dtype")? {
            cfg.dtype = DType::from_name(&s)
                .ok_or_else(|| format!("bad \"dtype\" `{s}` (want f32|f64)"))?;
        }
        let k = frontend::generate(&cfg);
        let mut o = Json::obj();
        o.set("seed", s)
            .set("name", k.name.as_str())
            .set("loops", k.n_loops())
            .set("stmts", k.n_stmts())
            .set("knl", frontend::pretty::print(&k));
        kernels.push(o);
    }
    let mut data = Json::obj();
    data.set("count", count).set("kernels", kernels);
    Ok((None, data))
}

fn op_stats(state: &ServeState) -> Json {
    let mut data = Json::obj();
    data.set("uptime_s", state.started.elapsed().as_secs_f64())
        .set("queue_depth", state.queue_depth.load(Ordering::SeqCst))
        .set("jobs", state.cfg.jobs)
        .set("cache_entries", state.cfg.cache_entries);

    let cache = state.cache.lock().unwrap();
    let s = cache.stats;
    let (solves, models, warm, dses, systems) = cache.sizes();
    drop(cache);
    let mut cj = Json::obj();
    cj.set("hits", s.hits)
        .set("misses", s.misses)
        .set("warm", s.warm)
        .set("model_hits", s.model_hits)
        .set("evictions", s.evictions)
        .set("hit_rate", s.hit_rate());
    let mut entries = Json::obj();
    entries
        .set("solves", solves)
        .set("models", models)
        .set("warm", warm)
        .set("dses", dses)
        .set("systems", systems);
    cj.set("entries", entries);
    data.set("cache", cj);

    let ops = state.ops.lock().unwrap();
    let mut oj = Json::obj();
    for (op, rec) in ops.iter() {
        let mut cache_counts = Json::obj();
        cache_counts
            .set("hit", rec.hit)
            .set("warm", rec.warm)
            .set("miss", rec.miss);
        let mut r = Json::obj();
        r.set("count", rec.count)
            .set("errors", rec.errors)
            .set("cache", cache_counts)
            .set(
                "latency_ms_log2",
                rec.lat.iter().copied().collect::<Vec<u64>>(),
            );
        oj.set(op.as_str(), r);
    }
    data.set("ops", oj);
    data
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Collect every emitted line for one request.
    fn call(state: &ServeState, line: &str) -> (Vec<Json>, Control) {
        let mut out = Vec::new();
        let ctl = handle_line(state, line, &mut |l| {
            out.push(Json::parse(l).unwrap_or_else(|e| panic!("bad line `{l}`: {e}")))
        });
        (out, ctl)
    }

    fn terminal(lines: &[Json]) -> &Json {
        lines.last().expect("at least one line")
    }

    #[test]
    fn solve_hits_the_cache_on_the_second_identical_request() {
        let state = ServeState::new(ServeConfig {
            jobs: 1,
            cache_entries: 8,
        });
        let req = r#"{"op":"solve","kernel":"gemm","size":"S","cap":16,"id":1}"#;
        let (first, _) = call(&state, req);
        let r1 = terminal(&first);
        assert_eq!(r1.get("event").and_then(|j| j.as_str()), Some("result"));
        assert_eq!(r1.get("cache").and_then(|j| j.as_str()), Some("miss"));
        let (second, _) = call(&state, req);
        let r2 = terminal(&second);
        assert_eq!(r2.get("cache").and_then(|j| j.as_str()), Some("hit"));
        assert_eq!(
            r1.get("data").unwrap().to_line(),
            r2.get("data").unwrap().to_line(),
            "cache replay must be bit-identical"
        );
    }

    #[test]
    fn warm_solves_stay_in_their_space_and_are_never_replayed() {
        let state = ServeState::new(ServeConfig {
            jobs: 1,
            cache_entries: 8,
        });
        let cache = |lines: &[Json]| {
            terminal(lines)
                .get("cache")
                .and_then(|j| j.as_str())
                .map(str::to_string)
        };
        let (first, _) = call(
            &state,
            r#"{"op":"solve","kernel":"gemm","size":"S","cap":8,"id":1}"#,
        );
        assert_eq!(cache(&first).as_deref(), Some("miss"));
        // same nest shape at a new size, same space restrictions → warm
        let m_req = r#"{"op":"solve","kernel":"gemm","size":"M","cap":8,"id":2}"#;
        let (second, _) = call(&state, m_req);
        assert_eq!(cache(&second).as_deref(), Some("warm"));
        // a warm-seeded result is not a pure function of the exact key,
        // so the repeat must re-solve (warm again), never replay "hit" —
        // and the deterministic solver makes the answers agree anyway
        let (third, _) = call(&state, m_req);
        assert_eq!(cache(&third).as_deref(), Some("warm"));
        let answer = |lines: &[Json]| terminal(lines).get("data").unwrap().get("designs").unwrap().to_line();
        assert_eq!(answer(&second), answer(&third));
        // a different rung never donates seeds: cross-rung seeds can be
        // menu-unreachable, so cap 4 starts cold
        let (other, _) = call(
            &state,
            r#"{"op":"solve","kernel":"gemm","size":"M","cap":4,"id":3}"#,
        );
        assert_eq!(cache(&other).as_deref(), Some("miss"));
        // attribution reached the stats counters too
        let s = state.cache.lock().unwrap().stats;
        assert_eq!((s.misses, s.warm, s.hits), (2, 2, 0));
    }

    #[test]
    fn inline_parse_errors_carry_the_caret_diagnostic() {
        let state = ServeState::new(ServeConfig::default());
        let bad = "kernel \\\"b\\\" f32\\narray a[4] out\\nfor i in 0 .. 4 {\\n  stmt s writes a[zz];\\n}\\n";
        let (lines, _) = call(
            &state,
            &format!(r#"{{"op":"solve","knl":"{bad}","id":"x"}}"#),
        );
        let e = terminal(&lines);
        assert_eq!(e.get("event").and_then(|j| j.as_str()), Some("error"));
        assert_eq!(e.get("id").and_then(|j| j.as_str()), Some("x"));
        let diag = e.get("diagnostic").and_then(|j| j.as_str()).expect("diagnostic");
        assert!(diag.contains("<request>:4:"), "{diag}");
        assert!(diag.contains('^'), "{diag}");
    }

    #[test]
    fn unknown_ops_and_bad_lines_stay_structured() {
        let state = ServeState::new(ServeConfig::default());
        let (lines, ctl) = call(&state, r#"{"op":"frobnicate"}"#);
        assert_eq!(ctl, Control::Continue);
        let msg = terminal(&lines).get("message").and_then(|j| j.as_str()).unwrap();
        assert!(msg.contains("unknown op"), "{msg}");
        let (lines, _) = call(&state, "}{ not json");
        let msg = terminal(&lines).get("message").and_then(|j| j.as_str()).unwrap();
        assert!(msg.contains("bad request JSON"), "{msg}");
        // blank lines are keepalive noise, not errors
        let (lines, _) = call(&state, "   ");
        assert!(lines.is_empty());
    }

    #[test]
    fn stats_reports_ops_cache_and_histograms() {
        let state = ServeState::new(ServeConfig {
            jobs: 1,
            cache_entries: 8,
        });
        let req = r#"{"op":"solve","kernel":"atax","size":"S","cap":8}"#;
        call(&state, req);
        call(&state, req);
        let (lines, _) = call(&state, r#"{"op":"stats"}"#);
        let data = terminal(&lines).get("data").unwrap().clone();
        assert_eq!(
            data.get("cache").unwrap().get("hits").and_then(|j| j.as_u64()),
            Some(1)
        );
        assert!(
            data.get("cache").unwrap().get("hit_rate").and_then(|j| j.as_f64()).unwrap() > 0.0
        );
        let solve = data.get("ops").unwrap().get("solve").expect("solve op stats");
        assert_eq!(solve.get("count").and_then(|j| j.as_u64()), Some(2));
        // per-op attribution: the eponymous miss then hit, no warms
        let per_op = solve.get("cache").expect("per-op cache counters");
        assert_eq!(per_op.get("miss").and_then(|j| j.as_u64()), Some(1));
        assert_eq!(per_op.get("hit").and_then(|j| j.as_u64()), Some(1));
        assert_eq!(per_op.get("warm").and_then(|j| j.as_u64()), Some(0));
        let histo = solve.get("latency_ms_log2").and_then(|j| j.as_arr()).unwrap();
        assert_eq!(histo.len(), LAT_BUCKETS);
        let total: u64 = histo.iter().filter_map(|j| j.as_u64()).sum();
        assert_eq!(total, 2);
    }

    #[test]
    fn dse_replays_and_partitions_by_transform_space() {
        let state = ServeState::new(ServeConfig {
            jobs: 1,
            cache_entries: 8,
        });
        let cache = |lines: &[Json]| {
            terminal(lines)
                .get("cache")
                .and_then(|j| j.as_str())
                .map(str::to_string)
        };
        let plain = r#"{"op":"dse","kernel":"mvt","size":"S","jobs":1,"id":1}"#;
        let (first, _) = call(&state, plain);
        assert_eq!(cache(&first).as_deref(), Some("miss"));
        let (second, _) = call(&state, plain);
        assert_eq!(cache(&second).as_deref(), Some("hit"));
        assert_eq!(
            terminal(&first).get("data").unwrap().to_line(),
            terminal(&second).get("data").unwrap().to_line(),
            "dse replay must be bit-identical"
        );
        // the same kernel with `transform` explores a different space:
        // the spaced fingerprint gives it a distinct exact key, so it
        // starts cold — then replays from its own line
        let t = r#"{"op":"dse","kernel":"mvt","size":"S","jobs":1,"transform":true,"max_variants":2,"id":2}"#;
        let (third, _) = call(&state, t);
        assert_eq!(cache(&third).as_deref(), Some("miss"));
        let data = terminal(&third).get("data").unwrap();
        assert_eq!(data.get("engine").and_then(|j| j.as_str()), Some("transform"));
        assert!(!data.get("variants").and_then(|j| j.as_arr()).unwrap().is_empty());
        let (fourth, _) = call(&state, t);
        assert_eq!(cache(&fourth).as_deref(), Some("hit"));
        assert_eq!(
            terminal(&third).get("data").unwrap().to_line(),
            terminal(&fourth).get("data").unwrap().to_line(),
            "transform replay must be bit-identical"
        );
        // per-op attribution saw all four: 2 cold, 2 replayed
        let (lines, _) = call(&state, r#"{"op":"stats"}"#);
        let data = terminal(&lines).get("data").unwrap().clone();
        let dse = data.get("ops").unwrap().get("dse").expect("dse op stats");
        let per_op = dse.get("cache").unwrap();
        assert_eq!(per_op.get("hit").and_then(|j| j.as_u64()), Some(2));
        assert_eq!(per_op.get("miss").and_then(|j| j.as_u64()), Some(2));
        assert_eq!(per_op.get("warm").and_then(|j| j.as_u64()), Some(0));
        // both spaces live side by side in the replay map
        let entries = data.get("cache").unwrap().get("entries").unwrap();
        assert_eq!(entries.get("dses").and_then(|j| j.as_u64()), Some(2));
    }

    #[test]
    fn surrogate_dse_mixes_the_artifact_hash_into_the_cache_key() {
        let state = ServeState::new(ServeConfig {
            jobs: 1,
            cache_entries: 8,
        });
        let cache = |lines: &[Json]| {
            terminal(lines)
                .get("cache")
                .and_then(|j| j.as_str())
                .map(str::to_string)
        };
        let dir = std::env::temp_dir().join("nlp_dse_serve_surrogate_test");
        std::fs::create_dir_all(&dir).unwrap();
        let tiny = crate::surrogate::TrainConfig {
            kernels: 2,
            designs: 6,
            ..crate::surrogate::TrainConfig::default()
        };
        let m1 = dir.join("m1.json");
        crate::surrogate::train(&tiny).model.save(&m1).unwrap();
        let m2 = dir.join("m2.json");
        let retrained = crate::surrogate::TrainConfig { seed: tiny.seed + 1, ..tiny.clone() };
        crate::surrogate::train(&retrained).model.save(&m2).unwrap();
        let req = |model: &std::path::Path, id: u32| {
            format!(
                r#"{{"op":"dse","kernel":"mvt","size":"S","jobs":1,"engine":"surrogate","model_file":"{}","verify_fraction":0.5,"id":{id}}}"#,
                model.display()
            )
        };
        let (first, _) = call(&state, &req(&m1, 1));
        assert_eq!(cache(&first).as_deref(), Some("miss"));
        let data = terminal(&first).get("data").unwrap();
        assert_eq!(data.get("engine").and_then(|j| j.as_str()), Some("surrogate"));
        assert!(data.get("best_pragmas").unwrap().as_arr().is_some(), "needs a best design");
        // identical artifact → replay, bit-identical
        let (second, _) = call(&state, &req(&m1, 2));
        assert_eq!(cache(&second).as_deref(), Some("hit"));
        assert_eq!(
            terminal(&first).get("data").unwrap().to_line(),
            terminal(&second).get("data").unwrap().to_line(),
            "surrogate replay must be bit-identical"
        );
        // a retrained artifact changes the content hash: its request
        // must start cold, never replay the stale model's exploration
        let (third, _) = call(&state, &req(&m2, 3));
        assert_eq!(cache(&third).as_deref(), Some("miss"));
        // surrogate knobs on other engines are an error, not ignored
        let (lines, _) = call(
            &state,
            r#"{"op":"dse","kernel":"mvt","size":"S","jobs":1,"verify_fraction":0.5,"id":4}"#,
        );
        let e = terminal(&lines);
        assert_eq!(e.get("event").and_then(|j| j.as_str()), Some("error"));
        let msg = e.get("message").and_then(|j| j.as_str()).unwrap();
        assert!(msg.contains("surrogate"), "{msg}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn transform_dse_warm_seeds_from_the_untransformed_solve() {
        let state = ServeState::new(ServeConfig {
            jobs: 1,
            cache_entries: 8,
        });
        let cache = |lines: &[Json]| {
            terminal(lines)
                .get("cache")
                .and_then(|j| j.as_str())
                .map(str::to_string)
        };
        // a default-sub-space solve (cap=MAX, coarse) of the plain
        // kernel donates its top-k into the warm cache
        let (solve, _) = call(
            &state,
            r#"{"op":"solve","kernel":"mvt","size":"S","id":1}"#,
        );
        assert_eq!(cache(&solve).as_deref(), Some("miss"));
        // the first transform dse finds those seeds: warm, not miss
        let t = r#"{"op":"dse","kernel":"mvt","size":"S","jobs":1,"transform":true,"max_variants":2,"id":2}"#;
        let (first, _) = call(&state, t);
        assert_eq!(cache(&first).as_deref(), Some("warm"));
        let data = terminal(&first).get("data").unwrap();
        assert_eq!(data.get("engine").and_then(|j| j.as_str()), Some("transform"));
        assert!(!data.get("variants").and_then(|j| j.as_arr()).unwrap().is_empty());
        // seeded payloads never enter the replay cache: the repeat must
        // re-run warm (not "hit"), and determinism — same seeds, same
        // solver — makes the answers bit-identical anyway
        let (second, _) = call(&state, t);
        assert_eq!(cache(&second).as_deref(), Some("warm"));
        assert_eq!(
            terminal(&first).get("data").unwrap().to_line(),
            terminal(&second).get("data").unwrap().to_line(),
            "same seeds must reproduce the same payload"
        );
        // attribution: one solve miss, two dse warms, zero dse replays
        let (lines, _) = call(&state, r#"{"op":"stats"}"#);
        let stats = terminal(&lines).get("data").unwrap().clone();
        let dse = stats.get("ops").unwrap().get("dse").expect("dse op stats");
        let per_op = dse.get("cache").unwrap();
        assert_eq!(per_op.get("warm").and_then(|j| j.as_u64()), Some(2));
        assert_eq!(per_op.get("hit").and_then(|j| j.as_u64()), Some(0));
        assert_eq!(per_op.get("miss").and_then(|j| j.as_u64()), Some(0));
        let entries = stats.get("cache").unwrap().get("entries").unwrap();
        assert_eq!(
            entries.get("dses").and_then(|j| j.as_u64()),
            Some(0),
            "seeded transform runs must stay out of the replay map"
        );
    }

    #[test]
    fn system_replays_order_invariantly_and_partitions_by_epsilon() {
        let state = ServeState::new(ServeConfig {
            jobs: 1,
            cache_entries: 8,
        });
        let cache = |lines: &[Json]| {
            terminal(lines)
                .get("cache")
                .and_then(|j| j.as_str())
                .map(str::to_string)
        };
        let a = r#"{"op":"system","kernels":["gemm","bicg"],"size":"S","cap":16,"epsilon":0.05,"max_points":4,"id":1}"#;
        let (first, _) = call(&state, a);
        assert_eq!(cache(&first).as_deref(), Some("miss"));
        // the payload carries a feasible allocation with one chosen
        // point per kernel, within the device budgets
        let data = terminal(&first).get("data").unwrap();
        let alloc = data.get("allocation").unwrap();
        assert_eq!(alloc.get("feasible").and_then(|j| j.as_bool()), Some(true));
        let choice = alloc.get("choice").and_then(|j| j.as_arr()).unwrap();
        assert_eq!(choice.len(), 2);
        let ks = data.get("kernels").and_then(|j| j.as_arr()).unwrap();
        assert_eq!(ks.len(), 2);
        for k in ks {
            let front = k.get("front").and_then(|j| j.as_arr()).unwrap();
            assert!(!front.is_empty() && front.len() <= 4);
            let chosen: usize = front
                .iter()
                .filter(|p| p.get("chosen").and_then(|j| j.as_bool()) == Some(true))
                .count();
            assert_eq!(chosen, 1, "exactly one chosen point per kernel");
        }
        // a permuted kernel list canonicalizes to the same key: replay,
        // bit-identical payload
        let b = r#"{"op":"system","kernels":["bicg","gemm"],"size":"S","cap":16,"epsilon":0.05,"max_points":4,"id":2}"#;
        let (second, _) = call(&state, b);
        assert_eq!(cache(&second).as_deref(), Some("hit"));
        assert_eq!(
            terminal(&first).get("data").unwrap().to_line(),
            terminal(&second).get("data").unwrap().to_line(),
            "order-permuted system replay must be bit-identical"
        );
        // a different epsilon is a different front: its own cache line
        let c = r#"{"op":"system","kernels":["gemm","bicg"],"size":"S","cap":16,"epsilon":0.1,"max_points":4,"id":3}"#;
        let (third, _) = call(&state, c);
        assert_eq!(cache(&third).as_deref(), Some("miss"));
        // both knob settings live side by side in the replay map
        let (lines, _) = call(&state, r#"{"op":"stats"}"#);
        let stats = terminal(&lines).get("data").unwrap().clone();
        let entries = stats.get("cache").unwrap().get("entries").unwrap();
        assert_eq!(entries.get("systems").and_then(|j| j.as_u64()), Some(2));
        let per_op = stats
            .get("ops")
            .unwrap()
            .get("system")
            .expect("system op stats")
            .get("cache")
            .unwrap();
        assert_eq!(per_op.get("miss").and_then(|j| j.as_u64()), Some(2));
        assert_eq!(per_op.get("hit").and_then(|j| j.as_u64()), Some(1));
    }

    #[test]
    fn shutdown_op_latches_and_reports() {
        let state = ServeState::new(ServeConfig::default());
        assert!(!state.shutdown_requested());
        let (lines, ctl) = call(&state, r#"{"op":"shutdown","id":9}"#);
        assert_eq!(ctl, Control::Shutdown);
        assert!(state.shutdown_requested());
        let r = terminal(&lines);
        assert_eq!(r.get("event").and_then(|j| j.as_str()), Some("result"));
        assert_eq!(r.get("id").and_then(|j| j.as_u64()), Some(9));
    }
}
