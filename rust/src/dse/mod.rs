//! NLP-DSE — the paper's design-space exploration (Algorithm 1).
//!
//! * [`clock`] — simulated wall-clock: synthesis jobs scheduled on N
//!   parallel workers (8 for NLP-DSE, 4×2 for AutoDSE), serial phases for
//!   solver invocations. All `T (min)` columns in the tables are makespans
//!   of this clock.
//! * [`nlpdse`] — Algorithm 1: sweep the max-array-partitioning ladder ×
//!   {coarse+fine, fine} parallelism, solve the NLP per sub-space, prune by
//!   lower bound, synthesize unseen candidates, terminate when the proven
//!   lower bound exceeds the best measured latency.

pub mod clock;
pub mod nlpdse;

pub use clock::SimClock;
pub use nlpdse::{
    run_nlp_dse, run_nlp_dse_seeded, run_nlp_dse_with_bound, run_nlp_dse_with_bound_seeded,
    DseConfig, DseOutcome, StepRecord,
};
