//! Algorithm 1: the NLP-driven design-space exploration.
//!
//! ```text
//! for max_array_partitioning in {∞, 2048, 1024, 512, 256, 128, 64, 32, 16, 8, 1}:
//!   for parallelism in {coarse+fine, fine}:
//!     nlp ← formulate(kernel, cap, parallelism)
//!     (config, lower_bound) ← SOLVE(nlp, timeout_NLP)
//!     if lower_bound < min_lat:
//!       if config unseen: hls_lat, valid ← MERLIN+HLS(config, timeout_HLS)
//!       if valid: min_lat ← min(min_lat, hls_lat)
//! ```
//!
//! The descending ladder seeds the search at the *lowest theoretical
//! latency* (maximum parallelism) — the paper's deliberate inversion of
//! AutoDSE's incremental strategy (Section 6). Termination: once the
//! sub-space lower bound exceeds the best measured latency, no remaining
//! configuration can win (the Theorem B.21 pruning guarantee).

use super::clock::SimClock;
use crate::hls::{Device, HlsOracle, HlsReport};
use crate::ir::Kernel;
use crate::nlp::{self, BatchEvaluator, NlpProblem};
use crate::poly::Analysis;
use crate::pragma::Design;
use std::collections::BTreeSet;

/// Campaign parameters (Section 7.2 defaults).
#[derive(Clone, Debug)]
pub struct DseConfig {
    /// The max-array-partitioning ladder; `u64::MAX` encodes ∞.
    pub ladder: Vec<u64>,
    /// HLS synthesis timeout, minutes.
    pub hls_timeout_min: f64,
    /// NLP solver budget, seconds (paper: 30 minutes of BARON).
    pub nlp_timeout_s: f64,
    /// Parallel synthesis workers (paper: 8 threads).
    pub workers: usize,
    /// Overall DSE budget, minutes (paper: 600, soft).
    pub dse_timeout_min: f64,
    /// Prune whole ladder rungs by the symbolic bound model's
    /// achievable-latency lower bound (`BoundModel::lower_bound` on the
    /// rung's partial configuration) before running the NLP solver — the
    /// paper's partial-configuration pruning use case
    /// (`dse --prune-bound`).
    pub prune_bound: bool,
    /// NLP-solver worker threads (`--jobs`). Defaults to every core the
    /// host exposes; `1` is the exact serial path. Searches that complete
    /// within budget return bit-identical results for every value (the
    /// solver's deterministic reduction), so this is purely a wall-clock
    /// knob; only a timed-out anytime result may differ.
    pub jobs: usize,
}

impl Default for DseConfig {
    fn default() -> Self {
        DseConfig {
            ladder: vec![u64::MAX, 2048, 1024, 512, 256, 128, 64, 32, 16, 8, 1],
            hls_timeout_min: 180.0,
            nlp_timeout_s: 30.0,
            workers: 8,
            dse_timeout_min: 600.0,
            prune_bound: false,
            jobs: nlp::default_jobs(),
        }
    }
}

impl DseConfig {
    /// The HARP-comparison ladder (Section 7.2.2).
    pub fn harp_ladder() -> Vec<u64> {
        vec![u64::MAX, 1024, 750, 512, 256, 128, 64, 32, 16, 8, 1]
    }
}

/// One DSE step (drives Fig 6 and the Fig 5 scatter).
#[derive(Clone, Debug)]
pub struct StepRecord {
    /// 1-based exploration step index.
    pub step: u32,
    /// Max-partitioning ladder rung the candidate came from.
    pub cap: u64,
    /// Candidate came from the fine-grained-only sub-space (Eq 9).
    pub fine_only: bool,
    /// NLP lower bound for the sub-space optimum.
    pub lower_bound: f64,
    /// Measured HLS latency (None: pruned / dedup / timeout / reject).
    pub measured: Option<f64>,
    /// Measured throughput (0 when invalid/timeout).
    pub gflops: f64,
    /// Synthesis produced a usable design.
    pub valid: bool,
    /// Synthesis hit its wall-clock timeout.
    pub timeout: bool,
    /// Merlin applied every requested pragma as given.
    pub pragmas_applied: bool,
    /// Vitis auto-applied `loop_flatten` (Fig 5 exception).
    pub flattened: bool,
    /// Skipped before synthesis by the lower-bound screen.
    pub pruned: bool,
    /// Identical configuration already synthesized; result reused.
    pub dedup: bool,
    /// Stable design fingerprint (dedup/oracle key).
    pub fingerprint: String,
}

/// What one NLP-DSE (Algorithm 1) run produced.
#[derive(Clone, Debug)]
pub struct DseOutcome {
    /// Kernel the exploration ran on.
    pub kernel: String,
    /// Best valid design and its measured latency, cycles.
    pub best: Option<(Design, f64)>,
    /// Best measured throughput.
    pub best_gflops: f64,
    /// NLP-DSE-FS: throughput of the first synthesizable design.
    pub first_synth_gflops: f64,
    /// DSE wall time (simulated), minutes.
    pub dse_minutes: f64,
    /// DE column: designs sent to synthesis.
    pub designs_explored: u32,
    /// DT column: synthesis timeouts.
    pub designs_timeout: u32,
    /// 1-based step index of the best-QoR design (Table 6 left).
    pub steps_to_best: u32,
    /// Step at which the LB-termination fired (Table 6 right).
    pub steps_to_terminate: u32,
    /// Peak DSP utilization % of the best design (Table 3).
    pub best_dsp_pct: f64,
    /// Per-step record of the whole exploration.
    pub trace: Vec<StepRecord>,
    /// Total NLP solve seconds (Table 7 ingredients).
    pub nlp_solve_s: Vec<f64>,
    /// NLP solves that hit their time budget.
    pub nlp_timeouts: u32,
}

/// Run Algorithm 1 on one kernel. Builds the kernel's symbolic bound
/// model once and shares it across every ladder rung (and the
/// `--prune-bound` path); use [`run_nlp_dse_with_bound`] to supply an
/// already-built model (e.g. `ExploreCtx::bound`).
pub fn run_nlp_dse(
    k: &Kernel,
    a: &Analysis,
    dev: &Device,
    cfg: &DseConfig,
    evaluator: &dyn BatchEvaluator,
) -> DseOutcome {
    run_nlp_dse_seeded(k, a, dev, cfg, evaluator, &[])
}

/// [`run_nlp_dse`] warm-started from candidate incumbent designs: every
/// ladder rung's solve is seeded (`solve_jobs_seeded`), so a cached
/// incumbent from an earlier run of the *same* kernel — or from the
/// un-transformed original of a loop-transformed variant — gives each
/// sub-space an admissible upper bound from step one. Soundness is the
/// solver's: seeds are re-verified per problem (foreign-shape or
/// infeasible seeds are dropped), so a completed seeded ladder returns
/// the cold ladder's designs.
pub fn run_nlp_dse_seeded(
    k: &Kernel,
    a: &Analysis,
    dev: &Device,
    cfg: &DseConfig,
    evaluator: &dyn BatchEvaluator,
    seeds: &[Design],
) -> DseOutcome {
    let bound = std::sync::Arc::new(crate::model::sym::BoundModel::build(k, a, dev));
    let compiled = std::sync::Arc::new(bound.compile());
    run_ladder(k, a, dev, cfg, evaluator, bound, compiled, seeds)
}

/// [`run_nlp_dse`] over a caller-owned bound model (one clone, not one
/// build per run).
pub fn run_nlp_dse_with_bound(
    k: &Kernel,
    a: &Analysis,
    dev: &Device,
    cfg: &DseConfig,
    evaluator: &dyn BatchEvaluator,
    bound: &crate::model::sym::BoundModel,
) -> DseOutcome {
    run_nlp_dse_with_bound_seeded(k, a, dev, cfg, evaluator, bound, &[])
}

/// [`run_nlp_dse_with_bound`] with warm seeds (see
/// [`run_nlp_dse_seeded`]).
#[allow(clippy::too_many_arguments)]
pub fn run_nlp_dse_with_bound_seeded(
    k: &Kernel,
    a: &Analysis,
    dev: &Device,
    cfg: &DseConfig,
    evaluator: &dyn BatchEvaluator,
    bound: &crate::model::sym::BoundModel,
    seeds: &[Design],
) -> DseOutcome {
    let bound = std::sync::Arc::new(bound.clone());
    let compiled = std::sync::Arc::new(bound.compile());
    run_ladder(k, a, dev, cfg, evaluator, bound, compiled, seeds)
}

/// Per-solve candidate screen: given one sub-space solve's ascending
/// `(design, lower_bound)` list, return a keep-mask — `true` entries are
/// synthesized exactly as in the plain ladder, `false` entries are
/// recorded as pruned steps and **not** synthesized (and not inserted
/// into the dedup set, so a later rung may still re-propose and
/// synthesize the same configuration). An all-`true` mask reproduces
/// the unfiltered ladder bit-identically by construction — the property
/// the surrogate engine's verify-fraction-1.0 differential test pins.
pub(crate) type RungFilter<'a> = dyn Fn(&[(Design, f64)]) -> Vec<bool> + 'a;

fn run_ladder(
    k: &Kernel,
    a: &Analysis,
    dev: &Device,
    cfg: &DseConfig,
    evaluator: &dyn BatchEvaluator,
    bound: std::sync::Arc<crate::model::sym::BoundModel>,
    compiled: std::sync::Arc<crate::model::sym::CompiledModel>,
    seeds: &[Design],
) -> DseOutcome {
    run_ladder_filtered(k, a, dev, cfg, evaluator, bound, compiled, seeds, None)
}

/// [`run_ladder`] with an optional per-solve candidate screen — the
/// shared substrate of the exact ladder and the surrogate engine's
/// ranked exploration (`surrogate/`). Crate-internal: external callers
/// go through the `run_nlp_dse*` wrappers or the engine registry.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_ladder_filtered(
    k: &Kernel,
    a: &Analysis,
    dev: &Device,
    cfg: &DseConfig,
    evaluator: &dyn BatchEvaluator,
    bound: std::sync::Arc<crate::model::sym::BoundModel>,
    compiled: std::sync::Arc<crate::model::sym::CompiledModel>,
    seeds: &[Design],
    filter: Option<&RungFilter<'_>>,
) -> DseOutcome {
    let oracle = HlsOracle {
        device: dev.clone(),
        options: crate::hls::SynthOptions {
            hls_timeout_min: cfg.hls_timeout_min,
        },
    };
    let mut clock = SimClock::new(cfg.workers);
    let mut trace: Vec<StepRecord> = Vec::new();
    let mut seen: BTreeSet<String> = BTreeSet::new();
    let mut min_lat = f64::INFINITY;
    let mut best: Option<(Design, f64)> = None;
    let mut best_report: Option<HlsReport> = None;
    let mut first_synth_gflops = 0.0f64;
    let mut designs_explored = 0;
    let mut designs_timeout = 0;
    let mut steps_to_best = 0;
    let mut steps_to_terminate = 0;
    let mut nlp_solve_s = Vec::new();
    let mut nlp_timeouts = 0;
    let mut step = 0u32;

    // loops whose coarse replication Merlin refused — learned during the
    // run (Section 7.5: the DSE detects pragmas that were not applied and
    // restricts the subsequent subspaces)
    let mut coarse_banned: std::collections::BTreeSet<u32> = Default::default();

    // each rung's partial-configuration bound is a pure function of its
    // cap, so with `--prune-bound` all of them are computed up front in
    // one laned interval sweep (LANE_WIDTH rungs per tape pass) instead
    // of a scalar pass per rung — bit-identical values, so every pruning
    // decision below is unchanged
    let rung_lbs: Vec<f64> = if cfg.prune_bound {
        let partials: Vec<crate::model::sym::PartialDesign> = cfg
            .ladder
            .iter()
            .map(|&cap| crate::model::sym::PartialDesign::free(k.n_loops()).with_uf_cap(cap))
            .collect();
        bound.lower_bound_batch(&partials)
    } else {
        Vec::new()
    };

    'outer: for (rung, &cap) in cfg.ladder.iter().enumerate() {
        for fine_only in [false, true] {
            if clock.makespan() > cfg.dse_timeout_min {
                break 'outer;
            }
            step += 1;

            // Theorem B.21 over *partial* configurations (`--prune-bound`):
            // every design of this rung keeps UF ≤ cap on array-indexing
            // loops, so the interval bound of that partial design floors
            // the whole rung. The bound is monotone as the cap descends
            // (domains only shrink), so the first rung it kills terminates
            // the whole ladder — same semantics as the solver-LB
            // termination below, minus the NLP solve.
            if cfg.prune_bound && min_lat.is_finite() {
                let rung_lb = rung_lbs[rung];
                if rung_lb >= min_lat {
                    steps_to_terminate = step;
                    trace.push(StepRecord {
                        step,
                        cap,
                        fine_only,
                        lower_bound: rung_lb,
                        measured: None,
                        gflops: 0.0,
                        valid: false,
                        timeout: false,
                        pragmas_applied: false,
                        flattened: false,
                        pruned: true,
                        dedup: false,
                        fingerprint: String::new(),
                    });
                    break 'outer;
                }
            }
            // a sub-space may be re-solved (bounded) after Merlin refusals
            // teach the DSE which coarse pragmas are unavailable
            let mut retry_rounds = 0;
            'retry: loop {
            let mut problem = NlpProblem::with_model(
                k,
                a,
                dev,
                cap,
                fine_only,
                bound.clone(),
                compiled.clone(),
            );
            problem.coarse_banned = coarse_banned.clone();
            // top-k per sub-space: the paper runs up to 8 designs per
            // iteration in parallel; when the LB-optimal configuration is
            // realized poorly by Merlin, the runners-up still get a shot
            let sol = nlp::solve_jobs_seeded(
                &problem,
                cfg.nlp_timeout_s,
                cfg.workers,
                evaluator,
                cfg.jobs,
                seeds,
            );
            nlp_solve_s.push(sol.solve_time_s);
            if !sol.optimal {
                nlp_timeouts += 1;
            }
            // the solver blocks synthesis of this wave; charge its
            // *measured busy time* (idle workers bill nothing) divided
            // across the simulated machine's solver cores — capped by the
            // configs actually processed, since parallelism beyond that
            // cannot exist — so the DSE-minutes column stays honest
            // whether the solve ran serial or parallel
            let cfgs = sol.stats.configs.max(1) as usize;
            clock.solve_phase(
                sol.cpu_time_s / 60.0,
                cfg.jobs.min(cfg.workers).max(1).min(cfgs),
            );

            let Some((_, _)) = sol.best() else {
                trace.push(StepRecord {
                    step,
                    cap,
                    fine_only,
                    lower_bound: sol.lower_bound,
                    measured: None,
                    gflops: 0.0,
                    valid: false,
                    timeout: false,
                    pragmas_applied: false,
                    flattened: false,
                    pruned: true,
                    dedup: false,
                    fingerprint: String::new(),
                });
                break 'retry;
            };

            // Theorem B.21 pruning: a sub-space whose *lower bound* beats
            // nothing can be skipped entirely; once this happens on the
            // descending ladder the search can stop
            let best_lb = sol.best().map(|b| b.1).unwrap_or(f64::INFINITY);
            if best_lb >= min_lat {
                steps_to_terminate = step;
                trace.push(StepRecord {
                    step,
                    cap,
                    fine_only,
                    lower_bound: best_lb,
                    measured: None,
                    gflops: 0.0,
                    valid: false,
                    timeout: false,
                    pragmas_applied: false,
                    flattened: false,
                    pruned: true,
                    dedup: false,
                    fingerprint: String::new(),
                });
                break 'outer;
            }

            // the optional screen sees the whole solve at once (rank
            // context); a short mask keeps the unlisted tail
            let keep: Vec<bool> = match filter {
                Some(f) => {
                    let mut m = f(&sol.designs);
                    m.resize(sol.designs.len(), true);
                    m
                }
                None => Vec::new(),
            };
            let bans_before = coarse_banned.len();
            for (idx, (design, lb)) in sol.designs.iter().enumerate() {
                let lb = *lb;
                if lb >= min_lat {
                    break; // runners-up are sorted ascending
                }
                let fp = design.fingerprint();
                if !keep.is_empty() && !keep[idx] {
                    // screened out before synthesis (e.g. surrogate rank
                    // cut): recorded like a lower-bound prune, but kept
                    // out of `seen` so a later sub-space may still
                    // synthesize this configuration
                    trace.push(StepRecord {
                        step,
                        cap,
                        fine_only,
                        lower_bound: lb,
                        measured: None,
                        gflops: 0.0,
                        valid: false,
                        timeout: false,
                        pragmas_applied: false,
                        flattened: false,
                        pruned: true,
                        dedup: false,
                        fingerprint: fp,
                    });
                    continue;
                }
                if !seen.insert(fp.clone()) {
                    // identical configuration already synthesized (Fig 6's
                    // red steps): reuse the result, no synthesis cost
                    trace.push(StepRecord {
                        step,
                        cap,
                        fine_only,
                        lower_bound: lb,
                        measured: None,
                        gflops: 0.0,
                        valid: false,
                        timeout: false,
                        pragmas_applied: false,
                        flattened: false,
                        pruned: false,
                        dedup: true,
                        fingerprint: fp,
                    });
                    continue;
                }

                let rep = oracle.synth(k, a, design);
                clock.submit(rep.synth_minutes);
                designs_explored += 1;
                if rep.timeout {
                    designs_timeout += 1;
                }
                // learn which coarse pragmas Merlin refused: restrict the
                // remaining subspaces so later solves stop proposing them
                for (i, (req, real)) in design
                    .pragmas
                    .iter()
                    .zip(rep.merlin.realized.pragmas.iter())
                    .enumerate()
                {
                    if req.uf > real.uf {
                        coarse_banned.insert(i as u32);
                    }
                }
                let gfs = rep.gflops(a, dev);
                if rep.valid && first_synth_gflops == 0.0 {
                    first_synth_gflops = gfs;
                }
                if rep.valid && rep.cycles < min_lat {
                    min_lat = rep.cycles;
                    best = Some((design.clone(), rep.cycles));
                    best_report = Some(rep.clone());
                    steps_to_best = step;
                }
                trace.push(StepRecord {
                    step,
                    cap,
                    fine_only,
                    lower_bound: lb,
                    measured: if rep.valid { Some(rep.cycles) } else { None },
                    gflops: gfs,
                    valid: rep.valid,
                    timeout: rep.timeout,
                    pragmas_applied: rep.pragmas_applied,
                    flattened: rep.flattened,
                    pruned: false,
                    dedup: false,
                    fingerprint: fp,
                });
            }
            // Merlin refused coarse pragmas this wave: re-solve the same
            // sub-space with the restriction (the paper's restricted
            // subspace exploration), bounded to two extra rounds
            if coarse_banned.len() > bans_before && retry_rounds < 2 {
                retry_rounds += 1;
                continue 'retry;
            }
            break 'retry;
            } // 'retry
        }
    }
    if steps_to_terminate == 0 {
        steps_to_terminate = step;
    }

    let best_gflops = best
        .as_ref()
        .map(|(_, cyc)| a.gflops(*cyc, dev.freq_hz))
        .unwrap_or(0.0);
    let best_dsp_pct = best_report
        .map(|r| r.dsp as f64 / dev.dsp_total as f64 * 100.0)
        .unwrap_or(0.0);

    DseOutcome {
        kernel: k.name.clone(),
        best,
        best_gflops,
        first_synth_gflops,
        dse_minutes: clock.makespan(),
        designs_explored,
        designs_timeout,
        steps_to_best,
        steps_to_terminate,
        best_dsp_pct,
        trace,
        nlp_solve_s,
        nlp_timeouts,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{self, Size};
    use crate::ir::DType;
    use crate::nlp::solver::RustFeatureEvaluator;

    fn run(name: &str, size: Size) -> (DseOutcome, Analysis, Device) {
        let k = benchmarks::build(name, size, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let out = run_nlp_dse(&k, &a, &dev, &DseConfig::default(), &RustFeatureEvaluator);
        (out, a, dev)
    }

    #[test]
    fn finds_good_design_for_gemm() {
        let (out, a, dev) = run("gemm", Size::Small);
        assert!(out.best.is_some());
        assert!(out.best_gflops > 0.5, "gemm-S {}", out.best_gflops);
        assert!(out.designs_explored >= 1);
        assert!(out.dse_minutes > 0.0);
        // the empty design is much slower
        let k = benchmarks::build("gemm", Size::Small, DType::F32).unwrap();
        let oracle = HlsOracle::new(dev.clone());
        let orig = oracle.synth(&k, &a, &Design::empty(&k));
        assert!(out.best_gflops > orig.gflops(&a, &dev) * 3.0);
    }

    #[test]
    fn pruning_keeps_best_safe() {
        // every pruned step's LB must exceed the final best latency
        let (out, _a, _dev) = run("bicg", Size::Small);
        let best_cycles = out.best.as_ref().unwrap().1;
        for s in out.trace.iter().filter(|s| s.pruned && s.lower_bound.is_finite()) {
            assert!(
                s.lower_bound >= best_cycles * 0.999,
                "step {} pruned with LB {} < best {}",
                s.step,
                s.lower_bound,
                best_cycles
            );
        }
    }

    #[test]
    fn deterministic_trace() {
        let (o1, ..) = run("atax", Size::Small);
        let (o2, ..) = run("atax", Size::Small);
        assert_eq!(o1.designs_explored, o2.designs_explored);
        assert_eq!(o1.best_gflops, o2.best_gflops);
        assert_eq!(o1.trace.len(), o2.trace.len());
    }

    #[test]
    fn dse_outcome_invariant_under_solver_jobs() {
        // the solver's deterministic reduction makes the whole ladder —
        // every synthesized design, dedup and termination step — identical
        // whether the NLP solves run on 1 thread or many
        let k = benchmarks::build("gemm", Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let serial = DseConfig {
            jobs: 1,
            ..DseConfig::default()
        };
        let parallel = DseConfig {
            jobs: 4,
            ..DseConfig::default()
        };
        let o1 = run_nlp_dse(&k, &a, &dev, &serial, &RustFeatureEvaluator);
        let o4 = run_nlp_dse(&k, &a, &dev, &parallel, &RustFeatureEvaluator);
        assert_eq!(o1.best_gflops, o4.best_gflops);
        assert_eq!(o1.designs_explored, o4.designs_explored);
        assert_eq!(o1.steps_to_best, o4.steps_to_best);
        assert_eq!(o1.steps_to_terminate, o4.steps_to_terminate);
        assert_eq!(o1.trace.len(), o4.trace.len());
        for (s1, s4) in o1.trace.iter().zip(&o4.trace) {
            assert_eq!(s1.fingerprint, s4.fingerprint, "step {}", s1.step);
        }
    }

    #[test]
    fn prune_bound_keeps_result_and_skips_solves() {
        // the rung-level partial-configuration bound must never change the
        // best design (Theorem B.21 admissibility), only skip work
        let k = benchmarks::build("gemm", Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let base = run_nlp_dse(&k, &a, &dev, &DseConfig::default(), &RustFeatureEvaluator);
        let pruned_cfg = DseConfig {
            prune_bound: true,
            ..DseConfig::default()
        };
        let pruned = run_nlp_dse(&k, &a, &dev, &pruned_cfg, &RustFeatureEvaluator);
        assert_eq!(base.best_gflops, pruned.best_gflops);
        assert!(pruned.nlp_solve_s.len() <= base.nlp_solve_s.len());
        // every rung pruned this way carries an admissible bound
        let best_cycles = pruned.best.as_ref().unwrap().1;
        for s in pruned.trace.iter().filter(|s| s.pruned && s.lower_bound.is_finite()) {
            assert!(s.lower_bound >= best_cycles * 0.999);
        }
    }

    #[test]
    fn seeded_ladder_matches_cold_best() {
        // warm seeds are admissible upper bounds: seeding the whole ladder
        // with the cold run's own winners must reproduce the cold best
        // (a seed can prune work but never displace a better design)
        let k = benchmarks::build("gemm", Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let cfg = DseConfig::default();
        let cold = run_nlp_dse(&k, &a, &dev, &cfg, &RustFeatureEvaluator);
        let seeds: Vec<Design> = cold.best.iter().map(|(d, _)| d.clone()).collect();
        assert!(!seeds.is_empty());
        let warm = run_nlp_dse_seeded(&k, &a, &dev, &cfg, &RustFeatureEvaluator, &seeds);
        assert_eq!(cold.best_gflops, warm.best_gflops);
        assert_eq!(
            cold.best.as_ref().map(|(d, _)| d.fingerprint()),
            warm.best.as_ref().map(|(d, _)| d.fingerprint())
        );
        // a seed from a different kernel is either shape-dropped or
        // re-verified into an ordinary (here: hopeless) incumbent — the
        // winning design is untouched either way
        let k8 = benchmarks::build("bicg", Size::Small, DType::F32).unwrap();
        let alien = Design::empty(&k8);
        let warm2 = run_nlp_dse_seeded(&k, &a, &dev, &cfg, &RustFeatureEvaluator, &[alien]);
        assert_eq!(cold.best_gflops, warm2.best_gflops);
    }

    #[test]
    fn steps_accounting_consistent() {
        let (out, ..) = run("gemm", Size::Small);
        assert!(out.steps_to_best <= out.steps_to_terminate);
        assert!(out.steps_to_terminate as usize <= out.trace.len() + 1);
        assert!(out.first_synth_gflops > 0.0);
        assert!(out.first_synth_gflops <= out.best_gflops * 1.0001);
    }
}
