//! Simulated wall-clock for DSE campaigns.
//!
//! Synthesis jobs (minutes each, from the HLS oracle's synthesis-time
//! model) are scheduled greedily onto `n` workers; solver invocations are
//! serial phases that advance the frontier. The campaign's `T` is the
//! makespan.

/// Simulated wall-clock over `workers` parallel synthesis slots.
#[derive(Clone, Debug)]
pub struct SimClock {
    /// Per-worker next-free time, minutes.
    free: Vec<f64>,
    /// Time already consumed by serial phases.
    serial_base: f64,
}

impl SimClock {
    /// A clock with `workers` parallel slots, all free at t = 0.
    pub fn new(workers: usize) -> SimClock {
        assert!(workers > 0);
        SimClock {
            free: vec![0.0; workers],
            serial_base: 0.0,
        }
    }

    /// Schedule a parallel job of `minutes`; returns its completion time.
    /// (`total_cmp`: a NaN free-time — impossible unless a caller billed
    /// NaN minutes — ranks last instead of panicking the scheduler.)
    pub fn submit(&mut self, minutes: f64) -> f64 {
        let (idx, _) = self
            .free
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .unwrap();
        let start = self.free[idx].max(self.serial_base);
        let done = start + minutes.max(0.0);
        self.free[idx] = done;
        done
    }

    /// A serial phase (e.g. a single-threaded NLP solve): all workers wait
    /// for the current makespan, then the phase runs alone on one core.
    pub fn serial(&mut self, minutes: f64) {
        let m = self.makespan();
        self.serial_base = m + minutes.max(0.0);
    }

    /// An NLP-solve phase that blocks synthesis but runs on several cores:
    /// `cpu_minutes` of *measured busy time* (summed over the solver's
    /// workers — idle queue-waiting threads bill nothing), re-divided
    /// across the `sim_jobs` cores the simulated machine gives the
    /// solver. A serial solve (busy ≈ wall) on the simulated 8-way box is
    /// charged `minutes / 8`; a solve that already used the simulated
    /// core count is charged ≈ its wall time. Keeps the simulated
    /// DSE-minutes column honest instead of assuming the solver owns one
    /// core (the old `serial` accounting) or extrapolating wall × jobs
    /// (which would let idle workers inflate the bill).
    pub fn solve_phase(&mut self, cpu_minutes: f64, sim_jobs: usize) {
        self.serial(cpu_minutes.max(0.0) / sim_jobs.max(1) as f64);
    }

    /// Current makespan in minutes.
    pub fn makespan(&self) -> f64 {
        self.free
            .iter()
            .cloned()
            .fold(self.serial_base, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_jobs_overlap() {
        let mut c = SimClock::new(4);
        for _ in 0..4 {
            c.submit(10.0);
        }
        assert_eq!(c.makespan(), 10.0);
        c.submit(5.0);
        assert_eq!(c.makespan(), 15.0);
    }

    #[test]
    fn serial_phases_block() {
        let mut c = SimClock::new(2);
        c.submit(10.0);
        c.serial(3.0);
        assert_eq!(c.makespan(), 13.0);
        let done = c.submit(1.0);
        assert_eq!(done, 14.0);
    }

    #[test]
    fn solve_phase_divides_busy_time_across_sim_cores() {
        // 10 busy minutes on an 8-way simulated solver → 1.25 min
        let mut c = SimClock::new(8);
        c.solve_phase(10.0, 8);
        assert!((c.makespan() - 1.25).abs() < 1e-12);
    }

    #[test]
    fn solve_phase_with_one_sim_core_matches_serial() {
        let mut s = SimClock::new(8);
        s.serial(10.0);
        let mut p = SimClock::new(8);
        p.solve_phase(10.0, 1);
        assert_eq!(s.makespan(), p.makespan());
    }

    #[test]
    fn single_worker_serializes() {
        let mut c = SimClock::new(1);
        c.submit(5.0);
        c.submit(5.0);
        assert_eq!(c.makespan(), 10.0);
    }
}
