//! Regeneration of every table and figure in the paper's evaluation
//! (DESIGN.md §5 maps each to its module and CLI entry point).

pub mod figures;
pub mod tables;

pub use figures::{figure2_3, figure4, figure5, figure6};
pub use tables::{
    emitted_index, serve_stats, system_allocation, system_fronts, table1, table2, table3, table5,
    table6, table7, table8, table9, EmittedRow,
};
