//! Figure data series — emitted as TSV (x-axis, series columns), the same
//! rows the paper plots.

use crate::coordinator::CampaignResult;
use crate::benchmarks::Size;

/// Figures 2 (Large) and 3 (Medium): per-kernel GF/s and DSE time for
/// NLP-DSE vs AutoDSE.
pub fn figure2_3(r: &CampaignResult, size: Size) -> String {
    let mut out = String::from("kernel\tnlpdse_gfs\tautodse_gfs\tnlpdse_T_min\tautodse_T_min\n");
    for row in r.rows.iter().filter(|x| x.size == size) {
        let n = row.nlpdse();
        let a = row.autodse();
        out.push_str(&format!(
            "{}\t{:.3}\t{:.3}\t{:.1}\t{:.1}\n",
            row.name,
            n.map(|x| x.best_gflops).unwrap_or(0.0),
            a.map(|x| x.best_gflops).unwrap_or(0.0),
            n.map(|x| x.dse_minutes).unwrap_or(0.0),
            a.map(|x| x.dse_minutes).unwrap_or(0.0),
        ));
    }
    out
}

/// Figure 4: NLP-DSE vs HARP throughput (S+M).
pub fn figure4(r: &CampaignResult) -> String {
    let mut out = String::from("kernel\tsize\tnlpdse_gfs\tharp_gfs\n");
    for row in &r.rows {
        out.push_str(&format!(
            "{}\t{}\t{:.3}\t{:.3}\n",
            row.name,
            row.size.tag(),
            row.nlpdse().map(|x| x.best_gflops).unwrap_or(0.0),
            row.harp().map(|x| x.best_gflops).unwrap_or(0.0),
        ));
    }
    out
}

/// Figure 5: predicted lower bound vs measured latency for every
/// synthesized design. Column `applied` distinguishes the 5a (all) vs 5b
/// (pragmas applied) filters; `flattened` marks the red LB-exception.
pub fn figure5(r: &CampaignResult) -> String {
    let mut rows: Vec<(f64, f64, bool, bool, String)> = Vec::new();
    for row in &r.rows {
        if let Some(n) = row.nlpdse() {
            for s in &n.trace {
                if let Some(meas) = s.measured {
                    rows.push((
                        meas,
                        s.lower_bound,
                        s.pragmas_applied,
                        s.flattened,
                        format!("{}-{}", row.name, row.size.tag()),
                    ));
                }
            }
        }
    }
    rows.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut out =
        String::from("rank\tmeasured_cycles\tpredicted_lb_cycles\tapplied\tflattened\tdesign\n");
    for (i, (meas, lb, applied, flat, tag)) in rows.iter().enumerate() {
        out.push_str(&format!(
            "{}\t{:.0}\t{:.0}\t{}\t{}\t{}\n",
            i, meas, lb, applied, flat, tag
        ));
    }
    out
}

/// Figure 6: throughput achieved at each NLP-DSE step for one kernel.
pub fn figure6(r: &CampaignResult, kernel: &str, size: Size) -> String {
    let mut out = String::from("step\tcap\tfine\tlb_cycles\tgflops\tstatus\n");
    if let Some(row) = r
        .rows
        .iter()
        .find(|x| x.name == kernel && x.size == size)
    {
        if let Some(n) = row.nlpdse() {
            for s in &n.trace {
                let status = if s.dedup {
                    "dedup"
                } else if s.pruned {
                    "pruned"
                } else if s.timeout {
                    "timeout"
                } else if s.valid {
                    "ok"
                } else {
                    "invalid"
                };
                out.push_str(&format!(
                    "{}\t{}\t{}\t{:.0}\t{:.3}\t{}\n",
                    s.step,
                    if s.cap == u64::MAX {
                        "inf".to_string()
                    } else {
                        s.cap.to_string()
                    },
                    s.fine_only,
                    s.lower_bound,
                    s.gflops,
                    status
                ));
            }
        }
    }
    out
}
