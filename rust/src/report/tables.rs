//! Table renderers — one per paper table, consuming campaign rows.

use crate::benchmarks::{self, Size};
use crate::coordinator::{CampaignResult, KernelRow};
use crate::ir::DType;
use crate::poly::Analysis;
use crate::util::stats::{geomean, mean};
use crate::util::table::{f2, i0, ratio, TextTable};
use crate::util::sci;

fn find<'a>(r: &'a CampaignResult, name: &str, size: Size) -> Option<&'a KernelRow> {
    r.rows.iter().find(|x| x.name == name && x.size == size)
}

/// The motivation trio used by Tables 1–3 (Section 2.2: 2mm-M, gemm-M,
/// gramschmidt-L).
pub const MOTIVATION: [(&str, Size); 3] = [
    ("2mm", Size::Medium),
    ("gemm", Size::Medium),
    ("gramschmidt", Size::Large),
];

/// Table 1: Original vs AutoDSE throughput.
pub fn table1(r: &CampaignResult) -> TextTable {
    let mut t = TextTable::new(
        "Table 1 — throughput (GF/s) of Merlin without pragmas vs AutoDSE",
        &["", "2mm GF/s", "2mm T(min)", "gemm GF/s", "gemm T(min)", "gramsch GF/s", "gramsch T(min)"],
    );
    let rows: Vec<&KernelRow> = MOTIVATION
        .iter()
        .filter_map(|(n, s)| find(r, n, *s))
        .collect();
    if rows.len() != 3 {
        t.row(vec!["(missing campaign rows)".into(); 7]);
        return t;
    }
    let orig: Vec<String> = rows.iter().flat_map(|x| [f2(x.original_gflops), "N/A".into()]).collect();
    let mut line = vec!["Original".to_string()];
    line.extend(orig);
    t.row(line);
    let auto: Vec<String> = rows
        .iter()
        .flat_map(|x| {
            let a = x.autodse();
            [
                f2(a.map(|a| a.best_gflops).unwrap_or(0.0)),
                i0(a.map(|a| a.dse_minutes).unwrap_or(0.0)),
            ]
        })
        .collect();
    let mut line = vec!["AutoDSE".to_string()];
    line.extend(auto);
    t.row(line);
    let imp: Vec<String> = rows
        .iter()
        .flat_map(|x| {
            let a = x.autodse().map(|a| a.best_gflops).unwrap_or(0.0);
            [ratio(a / x.original_gflops.max(1e-9)), "".into()]
        })
        .collect();
    let mut line = vec!["Improvement".to_string()];
    line.extend(imp);
    t.row(line);
    t
}

/// Table 2: space sizes and AutoDSE exploration extent.
pub fn table2(r: &CampaignResult) -> TextTable {
    let mut t = TextTable::new(
        "Table 2 — design space vs AutoDSE exploration extent",
        &["", "2mm", "gemm", "gramsch."],
    );
    let rows: Vec<Option<&KernelRow>> = MOTIVATION.iter().map(|(n, s)| find(r, n, *s)).collect();
    let get = |f: &dyn Fn(&KernelRow) -> String| -> Vec<String> {
        rows.iter()
            .map(|x| x.map(f).unwrap_or_else(|| "-".into()))
            .collect()
    };
    let mut line = vec!["Nb. valid designs (Space)".to_string()];
    line.extend(get(&|x| sci(x.space_size)));
    t.row(line);
    let mut line = vec!["Nb. design synthesized (AutoDSE)".to_string()];
    line.extend(get(&|x| {
        x.autodse()
            .map(|a| a.designs_synthesized.to_string())
            .unwrap_or_default()
    }));
    t.row(line);
    let mut line = vec!["Nb. design pruned/ER (AutoDSE)".to_string()];
    line.extend(get(&|x| {
        x.autodse()
            .map(|a| a.early_rejected.to_string())
            .unwrap_or_default()
    }));
    t.row(line);
    let mut line = vec!["Nb. design timeout (AutoDSE)".to_string()];
    line.extend(get(&|x| {
        x.autodse()
            .map(|a| a.designs_timeout.to_string())
            .unwrap_or_default()
    }));
    t.row(line);
    let mut line = vec!["Nb. design explored (AutoDSE)".to_string()];
    line.extend(get(&|x| {
        x.autodse()
            .map(|a| a.designs_explored.to_string())
            .unwrap_or_default()
    }));
    t.row(line);
    t
}

/// Table 3: NLP-DSE vs NLP-DSE-FS vs AutoDSE (GF/s, T, DSP%).
pub fn table3(r: &CampaignResult) -> TextTable {
    let mut t = TextTable::new(
        "Table 3 — NLP-DSE vs first-synthesizable vs AutoDSE",
        &[
            "", "2mm GF/s", "T", "DSP%", "gemm GF/s", "T", "DSP%", "gramsch GF/s", "T", "DSP%",
        ],
    );
    let rows: Vec<Option<&KernelRow>> = MOTIVATION.iter().map(|(n, s)| find(r, n, *s)).collect();
    let triple = |f: &dyn Fn(&KernelRow) -> [String; 3]| -> Vec<String> {
        rows.iter()
            .flat_map(|x| x.map(f).unwrap_or_else(|| ["-".into(), "-".into(), "-".into()]))
            .collect()
    };
    let mut line = vec!["Original".to_string()];
    line.extend(triple(&|x| [f2(x.original_gflops), "N/A".into(), "0".into()]));
    t.row(line);
    let mut line = vec!["AutoDSE".to_string()];
    line.extend(triple(&|x| {
        let a = x.autodse();
        [
            f2(a.map(|a| a.best_gflops).unwrap_or(0.0)),
            i0(a.map(|a| a.dse_minutes).unwrap_or(0.0)),
            i0(a.map(|a| a.best_dsp_pct).unwrap_or(0.0)),
        ]
    }));
    t.row(line);
    let mut line = vec!["NLP-DSE-FS".to_string()];
    line.extend(triple(&|x| {
        let n = x.nlpdse();
        [
            f2(n.map(|n| n.first_synth_gflops).unwrap_or(0.0)),
            "N/A".into(),
            "".into(),
        ]
    }));
    t.row(line);
    let mut line = vec!["NLP-DSE".to_string()];
    line.extend(triple(&|x| {
        let n = x.nlpdse();
        [
            f2(n.map(|n| n.best_gflops).unwrap_or(0.0)),
            i0(n.map(|n| n.dse_minutes).unwrap_or(0.0)),
            i0(n.map(|n| n.best_dsp_pct).unwrap_or(0.0)),
        ]
    }));
    t.row(line);
    let mut line = vec!["Imp. vs AutoDSE".to_string()];
    line.extend(triple(&|x| {
        let n = x.nlpdse().map(|n| n.best_gflops).unwrap_or(0.0);
        let nt = x.nlpdse().map(|n| n.dse_minutes).unwrap_or(0.0);
        let a = x.autodse().map(|a| a.best_gflops).unwrap_or(0.0);
        let at = x.autodse().map(|a| a.dse_minutes).unwrap_or(0.0);
        [
            ratio(n / a.max(1e-9)),
            ratio(at / nt.max(1e-9)),
            "".into(),
        ]
    }));
    t.row(line);
    t
}

/// Table 5: the full NLP-DSE vs AutoDSE comparison.
pub fn table5(r: &CampaignResult) -> TextTable {
    let mut t = TextTable::new(
        "Table 5 — NLP-DSE (with first-synthesizable) vs AutoDSE, all kernels",
        &[
            "Kernel", "NL", "ND", "S", "Space", "FS GF/s", "GF/s", "T", "DE", "DT",
            "A-GF/s", "A-T", "A-DE", "A-DT", "A-ER", "Imp-T", "Imp-GF/s",
        ],
    );
    let mut imp_t = Vec::new();
    let mut imp_g = Vec::new();
    let mut n_gfs = Vec::new();
    let mut a_gfs = Vec::new();
    let mut n_t = Vec::new();
    let mut a_t = Vec::new();
    for row in &r.rows {
        let n = row.nlpdse();
        let a = row.autodse();
        let (ng, nt) = (
            n.map(|x| x.best_gflops).unwrap_or(0.0),
            n.map(|x| x.dse_minutes).unwrap_or(0.0),
        );
        let (ag, at) = (
            a.map(|x| x.best_gflops).unwrap_or(0.0),
            a.map(|x| x.dse_minutes).unwrap_or(0.0),
        );
        if ag > 0.0 && nt > 0.0 {
            imp_t.push(at / nt);
            imp_g.push(ng / ag);
        }
        n_gfs.push(ng);
        a_gfs.push(ag);
        n_t.push(nt);
        a_t.push(at);
        t.row(vec![
            row.name.clone(),
            row.nl.to_string(),
            row.nd.to_string(),
            row.size.tag().to_string(),
            sci(row.space_size),
            f2(n.map(|x| x.first_synth_gflops).unwrap_or(0.0)),
            f2(ng),
            i0(nt),
            n.map(|x| x.designs_explored.to_string()).unwrap_or_default(),
            n.map(|x| x.designs_timeout.to_string()).unwrap_or_default(),
            f2(ag),
            i0(at),
            a.map(|x| x.designs_explored.to_string()).unwrap_or_default(),
            a.map(|x| x.designs_timeout.to_string()).unwrap_or_default(),
            a.map(|x| x.early_rejected.to_string()).unwrap_or_default(),
            ratio(at / nt.max(1e-9)),
            ratio(ng / ag.max(1e-9)),
        ]);
    }
    t.sep();
    t.row(vec![
        "Average".into(),
        "".into(),
        "".into(),
        "".into(),
        "".into(),
        "".into(),
        f2(mean(&n_gfs)),
        i0(mean(&n_t)),
        "".into(),
        "".into(),
        f2(mean(&a_gfs)),
        i0(mean(&a_t)),
        "".into(),
        "".into(),
        "".into(),
        ratio(mean(&imp_t)),
        ratio(mean(&imp_g)),
    ]);
    t.row(vec![
        "Geo. Mean".into(),
        "".into(),
        "".into(),
        "".into(),
        "".into(),
        "".into(),
        f2(geomean(&n_gfs)),
        i0(geomean(&n_t)),
        "".into(),
        "".into(),
        f2(geomean(&a_gfs)),
        i0(geomean(&a_t)),
        "".into(),
        "".into(),
        "".into(),
        ratio(geomean(&imp_t)),
        ratio(geomean(&imp_g)),
    ]);
    t
}

/// Table 6: DSE steps to best QoR / to LB-termination.
pub fn table6(r: &CampaignResult) -> TextTable {
    let mut t = TextTable::new(
        "Table 6 — DSE steps to best QoR and to lower-bound termination",
        &["Kernel", "S", "steps to best", "steps to LB>HLS"],
    );
    for row in &r.rows {
        let Some(n) = row.nlpdse() else { continue };
        t.row(vec![
            row.name.clone(),
            row.size.tag().to_string(),
            n.steps_to_best.to_string(),
            n.steps_to_terminate.to_string(),
        ]);
    }
    let bests: Vec<f64> = r
        .rows
        .iter()
        .filter_map(|x| x.nlpdse().map(|n| n.steps_to_best as f64))
        .collect();
    let terms: Vec<f64> = r
        .rows
        .iter()
        .filter_map(|x| x.nlpdse().map(|n| n.steps_to_terminate as f64))
        .collect();
    t.sep();
    t.row(vec![
        "Average".into(),
        "".into(),
        f2(mean(&bests)),
        f2(mean(&terms)),
    ]);
    t
}

/// Table 7: NLP solver scalability.
pub fn table7(r: &CampaignResult) -> TextTable {
    let mut t = TextTable::new(
        "Table 7 — NLP solver scalability (per problem size)",
        &["Size", "ND T/O", "ND NT/O", "Avg Time (s)", "Avg Time NT/O (s)"],
    );
    for size in [Size::Medium, Size::Large, Size::Small] {
        let mut times = Vec::new();
        let mut nto_times = Vec::new();
        let mut tos = 0u32;
        for row in r.rows.iter().filter(|x| x.size == size) {
            if let Some(n) = row.nlpdse() {
                tos += n.nlp_timeouts;
                times.extend(n.nlp_solve_s.iter().copied());
                // per-solve timeout attribution is aggregate here
                nto_times.extend(n.nlp_solve_s.iter().copied());
            }
        }
        if times.is_empty() {
            continue;
        }
        t.row(vec![
            format!("{size:?}"),
            tos.to_string(),
            (times.len() as u32 - tos).to_string(),
            format!("{:.3}", mean(&times)),
            format!("{:.3}", mean(&nto_times)),
        ]);
    }
    // all-sizes row
    let mut all = Vec::new();
    let mut tos = 0;
    for row in &r.rows {
        if let Some(n) = row.nlpdse() {
            tos += n.nlp_timeouts;
            all.extend(n.nlp_solve_s.iter().copied());
        }
    }
    if !all.is_empty() {
        t.sep();
        t.row(vec![
            "All".into(),
            tos.to_string(),
            (all.len() as u32 - tos).to_string(),
            format!("{:.3}", mean(&all)),
            format!("{:.3}", mean(&all)),
        ]);
    }
    t
}

/// Table 8: problem sizes (static, from the registry).
pub fn table8() -> TextTable {
    let mut t = TextTable::new(
        "Table 8 — problem sizes and footprints",
        &["Kernel", "NL", "fp S (kB)", "fp M (kB)", "fp L (kB)", "flops M"],
    );
    for name in benchmarks::ALL {
        let mut cells = vec![name.to_string()];
        let mut nl = 0;
        let mut fps = Vec::new();
        let mut flops_m = 0f64;
        for size in [Size::Small, Size::Medium, Size::Large] {
            if name == "cnn" && size != Size::Medium {
                fps.push("-".to_string());
                continue;
            }
            let k = benchmarks::build(name, size, DType::F32).unwrap();
            let a = Analysis::new(&k);
            nl = k.n_loops();
            fps.push(format!("{:.0}", a.total_footprint as f64 / 1024.0));
            if size == Size::Medium {
                flops_m = a.total_flops;
            }
        }
        cells.push(nl.to_string());
        cells.extend(fps);
        cells.push(sci(flops_m));
        t.row(cells);
    }
    t
}

/// Table 9: NLP-DSE vs HARP (S+M, f64).
pub fn table9(r: &CampaignResult) -> TextTable {
    let mut t = TextTable::new(
        "Table 9 — NLP-DSE vs HARP throughput (GF/s, f64)",
        &["Kernel", "S", "GF/s NLP-DSE", "GF/s HARP", "Perf. Improvement"],
    );
    let mut imps = Vec::new();
    for row in &r.rows {
        let n = row.nlpdse().map(|x| x.best_gflops).unwrap_or(0.0);
        let h = row.harp().map(|x| x.best_gflops).unwrap_or(0.0);
        if h > 0.0 {
            imps.push(n / h);
        }
        t.row(vec![
            row.name.clone(),
            row.size.tag().to_string(),
            f2(n),
            f2(h),
            f2(n / h.max(1e-9)),
        ]);
    }
    t.sep();
    t.row(vec![
        "Average".into(),
        "".into(),
        "".into(),
        "".into(),
        f2(mean(&imps)),
    ]);
    t.row(vec![
        "Geo. Mean".into(),
        "".into(),
        "".into(),
        "".into(),
        f2(geomean(&imps)),
    ]);
    t
}

/// One emitted-artifact index entry: the annotated C file written for a
/// campaign row × engine best design (`campaign --emit-dir`).
#[derive(Clone, Debug)]
pub struct EmittedRow {
    /// Kernel name of the campaign row.
    pub kernel: String,
    /// Problem-size tag (`S`/`M`/`L`).
    pub size: String,
    /// Engine whose best design was emitted.
    pub engine: String,
    /// Best measured throughput of that design.
    pub gflops: f64,
    /// Path of the emitted `.c` file.
    pub path: String,
}

/// Index table linking each campaign row to its emitted pragma-annotated
/// C artifact (the paper's actual deliverable — Section 7's generated
/// designs, regenerable from any campaign).
pub fn emitted_index(rows: &[EmittedRow]) -> TextTable {
    let mut t = TextTable::new(
        "Emitted designs — pragma-annotated HLS C per campaign row",
        &["Kernel", "S", "Engine", "GF/s", "File"],
    );
    if rows.is_empty() {
        let mut cells = vec!["(no valid designs to emit)".to_string()];
        cells.extend(std::iter::repeat(String::new()).take(4));
        t.row(cells);
        return t;
    }
    for r in rows {
        t.row(vec![
            r.kernel.clone(),
            r.size.clone(),
            r.engine.clone(),
            f2(r.gflops),
            r.path.clone(),
        ]);
    }
    t
}

/// Daemon observability: render one `stats`-op payload (the `data`
/// object the [`crate::serve`] daemon returns) as a table — cache
/// effectiveness first, then one row per op with its request count,
/// errors, and log2-bucket latency histogram (`~ms:count` pairs, the
/// lower bucket edge; zero buckets elided). The CLI prints this on
/// clean daemon shutdown.
pub fn serve_stats(data: &crate::util::json::Json) -> TextTable {
    use crate::util::json::Json;
    let mut t = TextTable::new(
        "Serve stats — fingerprint cache and per-op latency",
        &["", "Count", "Errors", "Latency histogram"],
    );
    let u = |j: Option<&Json>| j.and_then(|x| x.as_u64()).unwrap_or(0);
    let cache = data.get("cache");
    let g = |k: &str| u(cache.and_then(|c| c.get(k)));
    let hit_rate = cache
        .and_then(|c| c.get("hit_rate"))
        .and_then(|x| x.as_f64())
        .unwrap_or(0.0);
    t.row(vec![
        format!("cache (hit rate {:.0}%)", hit_rate * 100.0),
        format!(
            "{} hit / {} warm / {} miss",
            g("hits"),
            g("warm"),
            g("misses")
        ),
        String::new(),
        format!("models reused {}, evicted {}", g("model_hits"), g("evictions")),
    ]);
    if let Some(Json::Obj(ops)) = data.get("ops") {
        for (op, rec) in ops {
            let lat = rec
                .get("latency_ms_log2")
                .and_then(|x| x.as_arr())
                .map(|buckets| {
                    buckets
                        .iter()
                        .enumerate()
                        .filter_map(|(i, b)| {
                            let n = b.as_u64().unwrap_or(0);
                            (n > 0).then(|| format!("~{}ms:{n}", 1u64 << i))
                        })
                        .collect::<Vec<_>>()
                        .join(" ")
                })
                .unwrap_or_default();
            // per-op cache attribution (ops that never touch a cache —
            // gen, stats — show the bare count)
            let per_op = rec.get("cache");
            let oc = |k: &str| u(per_op.and_then(|c| c.get(k)));
            let (h, w, m) = (oc("hit"), oc("warm"), oc("miss"));
            let count = if h + w + m > 0 {
                format!("{} ({h} hit / {w} warm / {m} miss)", u(rec.get("count")))
            } else {
                u(rec.get("count")).to_string()
            };
            t.row(vec![
                format!("op {op}"),
                count,
                u(rec.get("errors")).to_string(),
                lat,
            ]);
        }
    }
    t.row(vec![
        "uptime / queue".into(),
        format!(
            "{:.0}s",
            data.get("uptime_s").and_then(|x| x.as_f64()).unwrap_or(0.0)
        ),
        String::new(),
        format!("queue depth {}", u(data.get("queue_depth"))),
    ]);
    t
}

/// System mode: every extracted front point, one row each, grouped by
/// kernel in input order — the `*` column marks the point the budget
/// allocator chose. Latency is the solver's verified objective; DSP /
/// on-chip / LUT are the model's Eq 11/12 usage estimates.
pub fn system_fronts(out: &crate::system::SystemOutcome) -> TextTable {
    let mut t = TextTable::new(
        "System fronts — epsilon-dominance Pareto points per kernel",
        &["Kernel", "Pt", "*", "Cycles", "GF/s", "DSP", "Onchip B", "LUT", "Optimal"],
    );
    let chosen = out.alloc.best.as_ref().map(|b| b.choice.clone());
    for (ki, kf) in out.kernels.iter().enumerate() {
        if kf.front.is_empty() {
            t.row(vec![
                kf.name.clone(),
                "-".into(),
                "".into(),
                "(empty front)".into(),
                String::new(),
                String::new(),
                String::new(),
                String::new(),
                kf.optimal.to_string(),
            ]);
            continue;
        }
        for (pi, p) in kf.front.iter().enumerate() {
            let mark = match &chosen {
                Some(c) if c[ki] == pi => "*",
                _ => "",
            };
            t.row(vec![
                if pi == 0 { kf.name.clone() } else { String::new() },
                pi.to_string(),
                mark.into(),
                i0(p.latency),
                f2(kf.gflops[pi]),
                i0(p.dsp),
                i0(p.onchip_bytes),
                i0(p.lut),
                if pi == 0 {
                    kf.optimal.to_string()
                } else {
                    String::new()
                },
            ]);
        }
    }
    t
}

/// System mode: the budget allocation — per-kernel chosen point, the
/// summed usage, and the device budget with per-axis headroom.
pub fn system_allocation(
    out: &crate::system::SystemOutcome,
    dev: &crate::hls::Device,
) -> TextTable {
    let mut t = TextTable::new(
        "System allocation — one front point per kernel under the device budget",
        &["Kernel", "Pt", "GF/s", "DSP", "Onchip B", "LUT"],
    );
    let Some(best) = &out.alloc.best else {
        t.row(vec![
            format!(
                "(infeasible: no combination fits {} — {} nodes searched)",
                dev.name, out.alloc.nodes
            ),
            String::new(),
            String::new(),
            String::new(),
            String::new(),
            String::new(),
        ]);
        return t;
    };
    for (kf, &pi) in out.kernels.iter().zip(&best.choice) {
        let p = &kf.front[pi];
        t.row(vec![
            kf.name.clone(),
            pi.to_string(),
            f2(kf.gflops[pi]),
            i0(p.dsp),
            i0(p.onchip_bytes),
            i0(p.lut),
        ]);
    }
    t.row(vec![
        "total".into(),
        String::new(),
        f2(best.gflops),
        i0(best.dsp),
        i0(best.onchip_bytes),
        i0(best.lut),
    ]);
    t.row(vec![
        format!("budget ({})", dev.name),
        String::new(),
        String::new(),
        i0(dev.dsp_total as f64),
        i0(dev.onchip_bytes as f64),
        i0(dev.lut_total as f64),
    ]);
    t
}

#[cfg(test)]
mod system_tables_tests {
    use super::*;
    use crate::system::{AllocOutcome, Allocation, KernelFront, SystemOutcome};

    fn outcome(with_alloc: bool) -> SystemOutcome {
        let k = crate::benchmarks::kernel_gemm(4, 4, 4, DType::F32);
        let kf = KernelFront {
            name: "gemm".into(),
            front: vec![crate::nlp::FrontPoint {
                design: crate::pragma::Design::empty(&k),
                latency: 1000.0,
                risk: 0.0,
                dsp: 40.0,
                onchip_bytes: 512.0,
                lut: 900.0,
            }],
            gflops: vec![1.25],
            lower_bound: 900.0,
            optimal: true,
            solve_time_s: 0.1,
            configs: 4,
        };
        let best = with_alloc.then(|| Allocation {
            choice: vec![0],
            gflops: 1.25,
            dsp: 40.0,
            onchip_bytes: 512.0,
            lut: 900.0,
        });
        SystemOutcome {
            kernels: vec![kf],
            alloc: AllocOutcome { best, nodes: 2 },
            solve_time_s: 0.1,
        }
    }

    #[test]
    fn fronts_table_marks_the_chosen_point() {
        let r = system_fronts(&outcome(true)).render();
        assert!(r.contains("gemm"), "{r}");
        assert!(r.contains('*'), "{r}");
        let a = system_allocation(&outcome(true), &crate::hls::Device::u200()).render();
        assert!(a.contains("total"), "{a}");
        assert!(a.contains("budget"), "{a}");
    }

    #[test]
    fn infeasible_allocation_renders_a_diagnostic_row() {
        let a = system_allocation(&outcome(false), &crate::hls::Device::u200()).render();
        assert!(a.contains("infeasible"), "{a}");
        assert!(a.contains("2 nodes"), "{a}");
    }
}

#[cfg(test)]
mod serve_stats_tests {
    use super::*;

    #[test]
    fn serve_stats_renders_cache_and_op_rows() {
        let data = crate::util::json::Json::parse(
            r#"{"uptime_s":12.5,"queue_depth":1,
                "cache":{"hits":3,"misses":2,"warm":1,"model_hits":2,
                         "evictions":0,"hit_rate":0.5,
                         "entries":{"solves":2,"models":2,"warm":2}},
                "ops":{"gen":{"count":2,"errors":0,
                              "cache":{"hit":0,"warm":0,"miss":0},
                              "latency_ms_log2":[2,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0]},
                       "solve":{"count":6,"errors":1,
                                "cache":{"hit":3,"warm":1,"miss":2},
                                "latency_ms_log2":[0,2,0,4,0,0,0,0,0,0,0,0,0,0,0,0]}}}"#,
        )
        .unwrap();
        let out = serve_stats(&data).render();
        assert!(out.contains("hit rate 50%"), "{out}");
        assert!(out.contains("op solve"), "{out}");
        // per-op attribution rides in the count column
        assert!(out.contains("6 (3 hit / 1 warm / 2 miss)"), "{out}");
        // ops with no cache traffic keep the bare count
        assert!(out.contains("op gen"), "{out}");
        assert!(!out.contains("2 (0 hit"), "{out}");
        assert!(out.contains("~2ms:2"), "{out}");
        assert!(out.contains("~8ms:4"), "{out}");
        assert!(out.contains("queue depth 1"), "{out}");
    }
}

#[cfg(test)]
mod emitted_tests {
    use super::*;

    #[test]
    fn emitted_index_renders_rows_and_empty_note() {
        let rows = vec![EmittedRow {
            kernel: "gemm".into(),
            size: "M".into(),
            engine: "nlpdse".into(),
            gflops: 12.5,
            path: "out/gemm-M-nlpdse.merlin.c".into(),
        }];
        let t = emitted_index(&rows).render();
        assert!(t.contains("gemm"), "{t}");
        assert!(t.contains("out/gemm-M-nlpdse.merlin.c"), "{t}");
        let empty = emitted_index(&[]).render();
        assert!(empty.contains("no valid designs"), "{empty}");
    }
}
