//! Closed-form ridge regression (normal equations + Gauss–Jordan).
//!
//! The feature dimension is tiny ([`PHI_DIM`](super::PHI_DIM) ≈ 14), so
//! `(XᵀX + λI) w = Xᵀy` solved densely is exact, allocation-light, and —
//! unlike any iterative fit — bit-reproducible across runs and
//! platforms, which is what the differential-fuzz gate pins. λ > 0 makes
//! the system symmetric positive definite, so the elimination below
//! never needs a singularity fallback.

/// A fitted standardization + weight vector.
#[derive(Clone, Debug, PartialEq)]
pub struct RidgeFit {
    /// Weights over the standardized features (bias column included).
    pub weights: Vec<f64>,
    /// Per-feature training mean (bias column: 0).
    pub mean: Vec<f64>,
    /// Per-feature training standard deviation (bias and constant
    /// columns: 1, so they pass through unscaled).
    pub std: Vec<f64>,
}

impl RidgeFit {
    /// Predict one target from a raw (unstandardized) feature vector.
    pub fn predict(&self, x: &[f64]) -> f64 {
        assert_eq!(x.len(), self.weights.len(), "ridge: dim mismatch");
        let mut y = 0.0;
        for j in 0..x.len() {
            y += self.weights[j] * (x[j] - self.mean[j]) / self.std[j];
        }
        y
    }
}

/// Fit `y ≈ φ·w` by standardized ridge regression. `xs` is row-major
/// (one feature vector per sample); column 0 is assumed to be the bias
/// and is left unstandardized. `lambda` is clamped to a positive floor
/// so the normal-equation matrix is always invertible. An empty sample
/// set yields the all-zero fit (predicts 0 everywhere) rather than
/// panicking — a degenerate corpus must not take the engine down.
pub fn fit_ridge(xs: &[Vec<f64>], y: &[f64], lambda: f64) -> RidgeFit {
    assert_eq!(xs.len(), y.len(), "ridge: sample/target mismatch");
    let dim = xs.first().map(|r| r.len()).unwrap_or(0);
    if xs.is_empty() || dim == 0 {
        return RidgeFit { weights: vec![0.0; dim], mean: vec![0.0; dim], std: vec![1.0; dim] };
    }
    let n = xs.len() as f64;
    let lambda = lambda.max(1e-9);

    // column standardization (bias column 0 passes through)
    let mut mean = vec![0.0; dim];
    let mut std = vec![1.0; dim];
    for j in 1..dim {
        let m = xs.iter().map(|r| r[j]).sum::<f64>() / n;
        let var = xs.iter().map(|r| (r[j] - m) * (r[j] - m)).sum::<f64>() / n;
        mean[j] = m;
        std[j] = if var.sqrt() > 1e-12 { var.sqrt() } else { 1.0 };
    }

    // normal equations over the standardized design matrix
    let mut ata = vec![vec![0.0; dim]; dim];
    let mut aty = vec![0.0; dim];
    let mut row = vec![0.0; dim];
    for (r, &t) in xs.iter().zip(y) {
        for j in 0..dim {
            row[j] = (r[j] - mean[j]) / std[j];
        }
        for i in 0..dim {
            aty[i] += row[i] * t;
            for j in i..dim {
                ata[i][j] += row[i] * row[j];
            }
        }
    }
    for i in 0..dim {
        for j in 0..i {
            ata[i][j] = ata[j][i]; // symmetrize the upper-triangle pass
        }
        ata[i][i] += lambda;
    }

    // Gauss–Jordan with partial pivoting on [ata | aty]
    let mut w = aty;
    let mut m = ata;
    for col in 0..dim {
        let piv = (col..dim)
            .max_by(|&i, &j| {
                m[i][col]
                    .abs()
                    .partial_cmp(&m[j][col].abs())
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .unwrap();
        m.swap(col, piv);
        w.swap(col, piv);
        let p = m[col][col];
        debug_assert!(p.abs() > 0.0, "ridge matrix is SPD, pivot cannot vanish");
        for j in col..dim {
            m[col][j] /= p;
        }
        w[col] /= p;
        for i in 0..dim {
            if i == col {
                continue;
            }
            let f = m[i][col];
            if f == 0.0 {
                continue;
            }
            for j in col..dim {
                m[i][j] -= f * m[col][j];
            }
            w[i] -= f * w[col];
        }
    }

    RidgeFit { weights: w, mean, std }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_a_linear_law() {
        // y = 3 + 2 x1 - x2, exactly representable: tiny lambda recovers it
        let xs: Vec<Vec<f64>> = (0..20)
            .map(|i| {
                let x1 = i as f64;
                let x2 = (i * i % 7) as f64;
                vec![1.0, x1, x2]
            })
            .collect();
        let y: Vec<f64> = xs.iter().map(|r| 3.0 + 2.0 * r[1] - r[2]).collect();
        let fit = fit_ridge(&xs, &y, 1e-9);
        for (r, &t) in xs.iter().zip(&y) {
            assert!((fit.predict(r) - t).abs() < 1e-6, "{} vs {t}", fit.predict(r));
        }
    }

    #[test]
    fn deterministic_bit_for_bit() {
        let xs: Vec<Vec<f64>> = (0..50)
            .map(|i| vec![1.0, (i as f64).sin() * 10.0, (i as f64 * 0.7).cos()])
            .collect();
        let y: Vec<f64> = xs.iter().map(|r| r[1] * 0.5 - r[2] * 2.0 + 1.0).collect();
        let f1 = fit_ridge(&xs, &y, 1e-3);
        let f2 = fit_ridge(&xs, &y, 1e-3);
        assert_eq!(f1, f2, "identical inputs must fit identical bits");
    }

    #[test]
    fn degenerate_inputs_do_not_panic() {
        let empty = fit_ridge(&[], &[], 1e-3);
        assert!(empty.weights.is_empty());
        // constant column: std clamps to 1, fit still finite
        let xs = vec![vec![1.0, 5.0], vec![1.0, 5.0], vec![1.0, 5.0]];
        let fit = fit_ridge(&xs, &[1.0, 2.0, 3.0], 1e-3);
        assert!(fit.predict(&[1.0, 5.0]).is_finite());
    }
}
