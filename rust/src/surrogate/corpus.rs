//! Deterministic labeled training corpus: generated kernels × random
//! designs, labeled by the exact analytic model.
//!
//! Everything downstream of [`TrainConfig::seed`] is a pure function of
//! it: kernel shapes come from `GenConfig::sampled` under per-kernel
//! derived seeds, designs are drawn with the same seeded enumeration
//! idiom as the `random` engine, and labels are
//! `ln(1 + model::evaluate(..).total_cycles)` — so two trainings from
//! one seed are bit-identical (the fuzz gate's property (a)).

use super::features::{phi, PHI_DIM};
use crate::frontend::generate::{generate, GenConfig};
use crate::hls::Device;
use crate::ir::LoopId;
use crate::poly::Analysis;
use crate::pragma::{space, Design, Space};
use crate::util::rng::Rng;
use std::collections::BTreeSet;

/// Corpus and fit knobs for [`train`](super::train) — the CLI `train`
/// subcommand exposes each of these.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Master seed: kernels, designs, and therefore the fitted weights
    /// are all pure functions of it.
    pub seed: u64,
    /// Generated kernels in the corpus.
    pub kernels: usize,
    /// Random designs drawn per kernel (the pragma-free baseline design
    /// is always added on top).
    pub designs: usize,
    /// Ridge regularization strength λ.
    pub lambda: f64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            seed: 0xd5e0_0001,
            kernels: 12,
            designs: 48,
            lambda: 1e-3,
        }
    }
}

impl TrainConfig {
    /// The micro corpus the `surrogate` engine self-trains on when no
    /// artifact is supplied (small enough for test suites, still enough
    /// samples to pin the dominant latency feature).
    pub fn micro() -> TrainConfig {
        TrainConfig {
            kernels: 5,
            designs: 16,
            ..TrainConfig::default()
        }
    }
}

/// A labeled feature matrix (row-major) ready for the ridge fit.
#[derive(Clone, Debug)]
pub struct Corpus {
    /// Pooled feature vectors, one row per labeled design.
    pub xs: Vec<Vec<f64>>,
    /// Targets: `ln(1 + exact total_cycles)`.
    pub ys: Vec<f64>,
    /// Kernels that contributed samples.
    pub n_kernels: usize,
    /// Designs dropped because their kernel overflowed the feature ABI.
    pub skipped: u32,
}

/// Sample the labeled corpus for `cfg` (deterministic in `cfg.seed`).
pub fn sample_corpus(cfg: &TrainConfig) -> Corpus {
    let dev = Device::u200();
    let root = Rng::new(cfg.seed);
    let mut xs: Vec<Vec<f64>> = Vec::new();
    let mut ys: Vec<f64> = Vec::new();
    let mut skipped = 0u32;
    for ki in 0..cfg.kernels.max(1) {
        let gseed = root.derive(&format!("corpus-kernel/{ki}")).next_u64();
        let k = generate(&GenConfig::sampled(gseed));
        let a = Analysis::new(&k);
        let sp = Space::new(&k, &a);
        let mut rng = root.derive(&format!("corpus-designs/{ki}"));

        // the pragma-free baseline anchors every kernel's label range
        let mut designs: Vec<Design> = vec![Design::empty(&k)];
        let mut seen: BTreeSet<String> = designs.iter().map(Design::fingerprint).collect();
        let mut draws = 0usize;
        while designs.len() < cfg.designs + 1 && draws < cfg.designs.saturating_mul(20) + 1 {
            draws += 1;
            let pcfg =
                &sp.pipeline_configs[rng.range(0, sp.pipeline_configs.len() as u64) as usize];
            let drawn: Vec<u64> = (0..k.n_loops())
                .map(|i| {
                    let menu = sp.ufs(LoopId(i as u32), &a, dev.max_array_partition);
                    if menu.is_empty() {
                        1
                    } else {
                        menu[rng.range(0, menu.len() as u64) as usize]
                    }
                })
                .collect();
            let d = space::materialize(&k, &a, pcfg, &|l: LoopId| drawn[l.0 as usize], &|_| 1);
            if seen.insert(d.fingerprint()) {
                designs.push(d);
            }
        }

        for d in &designs {
            match phi(&k, &a, &dev, d) {
                Some(x) => {
                    debug_assert_eq!(x.len(), PHI_DIM);
                    xs.push(x.to_vec());
                    ys.push((1.0 + crate::model::evaluate(&k, &a, &dev, d).total_cycles).ln());
                }
                None => skipped += 1,
            }
        }
    }
    Corpus {
        xs,
        ys,
        n_kernels: cfg.kernels.max(1),
        skipped,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_seed_deterministic() {
        let cfg = TrainConfig {
            kernels: 2,
            designs: 6,
            ..TrainConfig::default()
        };
        let c1 = sample_corpus(&cfg);
        let c2 = sample_corpus(&cfg);
        assert_eq!(c1.xs, c2.xs);
        assert_eq!(c1.ys, c2.ys);
        assert!(!c1.xs.is_empty());
        assert!(c1.ys.iter().all(|y| y.is_finite()));
    }

    #[test]
    fn different_seeds_sample_different_corpora() {
        let base = TrainConfig {
            kernels: 2,
            designs: 6,
            ..TrainConfig::default()
        };
        let other = TrainConfig {
            seed: base.seed + 1,
            ..base.clone()
        };
        let c1 = sample_corpus(&base);
        let c2 = sample_corpus(&other);
        assert_ne!(c1.ys, c2.ys);
    }
}
