//! Learned latency surrogate — a dependency-free closed-form ridge
//! regressor over pooled [`crate::model::DesignFeatures`] aggregates,
//! trained on a deterministic corpus of generated kernels × random
//! designs labeled by the exact analytic model, and an [`Engine`]
//! (`surrogate`) that uses it to *rank* each DSE ladder rung's NLP
//! candidates and synthesize only the predicted-best fraction.
//!
//! The AutoHLS/LIFT observation (PAPERS.md) is that a cheap learned
//! predictor ranks configurations orders of magnitude faster than exact
//! evaluation — *as long as winners are re-verified*. This module keeps
//! that contract structural rather than statistical:
//!
//! * Training is bit-reproducible: corpus, featurization, and the
//!   normal-equation solve are all deterministic functions of
//!   [`TrainConfig::seed`] (property (a) of
//!   `tests/property_surrogate.rs`).
//! * Prediction only ever *prunes* candidates before synthesis. The
//!   engine runs the exact NLP ladder (`dse::nlpdse`) through its
//!   crate-internal rung-filter hook, so every design it does explore is
//!   scored by the same solver/oracle path as `nlpdse` — and with
//!   `verify_fraction = 1.0` the ladder is bit-identical to the exact
//!   engine by construction (property (d)).
//! * The reported incumbent is re-verified post-hoc with the exact
//!   [`crate::model::CompiledModel`] score and the admissible
//!   [`crate::model::BoundModel::lower_bound`]; the outcome carries
//!   both, so a raw prediction can never masquerade as a result
//!   (property (c)).
//! * The model persists as a versioned JSON artifact ([`SurrogateModel`],
//!   via `util::json` — no serde); its content hash keys the serve
//!   daemon's cache fingerprint so a retrained artifact can never replay
//!   a stale exploration.
//!
//! CLI: `nlp-dse train --model-file surrogate.json` then
//! `nlp-dse dse --engine surrogate --model-file surrogate.json`.
//! See DESIGN.md §15 and the GUIDE.md walkthrough.
//!
//! [`Engine`]: crate::engine::Engine

pub mod corpus;
pub mod engine;
pub mod features;
pub mod model;
pub mod ridge;

pub use corpus::{sample_corpus, Corpus, TrainConfig};
pub use engine::{SurrogateConfig, SurrogateEngine, SurrogateOutcome};
pub use features::{phi, pool, PHI_DIM};
pub use model::{train, SurrogateModel, TrainOutcome, ARTIFACT_VERSION};
pub use ridge::{fit_ridge, RidgeFit};

/// Spearman rank correlation between two equal-length samples, with
/// average ranks on ties (the differential-fuzz gate's metric: the
/// surrogate is judged on *ordering* designs, not on absolute error).
/// Returns 0.0 for degenerate inputs (fewer than two points or a
/// constant side).
pub fn spearman(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "spearman: length mismatch");
    let n = a.len();
    if n < 2 {
        return 0.0;
    }
    let ra = average_ranks(a);
    let rb = average_ranks(b);
    // Pearson correlation of the rank vectors (exact under ties)
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let (ma, mb) = (mean(&ra), mean(&rb));
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for i in 0..n {
        let (da, db) = (ra[i] - ma, rb[i] - mb);
        cov += da * db;
        va += da * da;
        vb += db * db;
    }
    if va <= 0.0 || vb <= 0.0 {
        return 0.0;
    }
    cov / (va.sqrt() * vb.sqrt())
}

/// Ranks (1-based) with tied values assigned their average rank.
fn average_ranks(v: &[f64]) -> Vec<f64> {
    let n = v.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&i, &j| v[i].partial_cmp(&v[j]).unwrap_or(std::cmp::Ordering::Equal));
    let mut ranks = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && v[idx[j + 1]] == v[idx[i]] {
            j += 1;
        }
        // positions i..=j share value v[idx[i]]: average of ranks i+1..=j+1
        let avg = (i + 1 + j + 1) as f64 / 2.0;
        for &k in &idx[i..=j] {
            ranks[k] = avg;
        }
        i = j + 1;
    }
    ranks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spearman_perfect_and_inverse() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let up = [10.0, 20.0, 30.0, 40.0];
        let down = [40.0, 30.0, 20.0, 10.0];
        assert!((spearman(&a, &up) - 1.0).abs() < 1e-12);
        assert!((spearman(&a, &down) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_is_monotone_invariant() {
        // rank correlation must ignore any monotone transform
        let a = [1.0, 5.0, 2.0, 9.0, 3.0];
        let exp: Vec<f64> = a.iter().map(|x| x.exp()).collect();
        assert!((spearman(&a, &exp) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_handles_ties_and_degenerates() {
        let a = [1.0, 1.0, 2.0, 2.0];
        let b = [1.0, 1.0, 2.0, 2.0];
        assert!((spearman(&a, &b) - 1.0).abs() < 1e-12);
        assert_eq!(spearman(&[1.0], &[2.0]), 0.0);
        assert_eq!(spearman(&[1.0, 1.0], &[1.0, 2.0]), 0.0, "constant side");
    }
}
