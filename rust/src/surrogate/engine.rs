//! The `surrogate` [`Engine`]: the exact NLP ladder with a learned rank
//! cut in front of synthesis, and exact re-verification behind it.
//!
//! Per ladder rung, the NLP solver still produces its lower-bound-sorted
//! candidate wave; the surrogate predicts each candidate's latency and
//! only the predicted-best [`SurrogateConfig::verify_fraction`] of the
//! wave reaches synthesis (the rest are recorded as pruned steps).
//! Everything that *is* explored goes through the identical
//! solver/oracle path as the `nlpdse` engine — the cut is a keep-mask
//! handed to `dse::nlpdse`'s crate-internal rung filter, not a parallel
//! reimplementation — so `verify_fraction = 1.0` reproduces the exact
//! ladder bit-for-bit. The reported incumbent is then re-scored with the
//! exact compiled model and floored by the admissible bound model, so
//! the outcome's headline numbers are never raw predictions.

use super::corpus::TrainConfig;
use super::model::{train, SurrogateModel};
use crate::dse::nlpdse::run_ladder_filtered;
use crate::dse::DseConfig;
use crate::engine::{Engine, EngineDetail, ExploreCtx, Exploration};
use crate::model::sym::{BoundModel, PartialDesign};
use crate::pragma::Design;
use std::cell::Cell;
use std::sync::Arc;

/// Surrogate-engine parameters.
#[derive(Clone, Debug)]
pub struct SurrogateConfig {
    /// Pre-loaded artifact (CLI `--model-file`, serve `model_file`).
    /// `None`: the engine self-trains on [`SurrogateConfig::train`] at
    /// explore time — deterministic, so bare registry use still works.
    pub model: Option<SurrogateModel>,
    /// Fraction of each solver wave to synthesize, picked by predicted
    /// latency (clamped to `[0, 1]`; `1.0` disables the cut and is
    /// bit-identical to the `nlpdse` ladder).
    pub verify_fraction: f64,
    /// Floor on kept candidates per wave, so a tiny fraction can never
    /// silence a rung entirely.
    pub min_keep: usize,
    /// Self-training corpus knobs used when `model` is `None`.
    pub train: TrainConfig,
}

impl Default for SurrogateConfig {
    fn default() -> Self {
        SurrogateConfig {
            model: None,
            verify_fraction: 0.5,
            min_keep: 1,
            train: TrainConfig::micro(),
        }
    }
}

/// What one surrogate exploration produced, wrapping the ladder outcome
/// with model provenance and the exact re-verification of the best.
#[derive(Clone, Debug)]
pub struct SurrogateOutcome {
    /// The underlying (filtered) ladder record.
    pub outcome: crate::dse::DseOutcome,
    /// Content hash of the artifact that ranked the candidates — the
    /// serve cache-fingerprint ingredient.
    pub model_hash: u64,
    /// Training seed of that artifact (provenance).
    pub model_seed: u64,
    /// The rank cut actually applied (post-clamp).
    pub verify_fraction: f64,
    /// Candidates the rank cut kept from synthesis.
    pub rank_skipped: u32,
    /// Candidates kept unranked because their kernel overflowed the
    /// feature ABI (explored exactly instead).
    pub predict_failures: u32,
    /// Exact compiled-model score of the reported best design.
    pub exact_cycles: Option<f64>,
    /// Exact compiled-model feasibility of the reported best design.
    pub exact_feasible: bool,
    /// Admissible bound-model floor for the reported best design
    /// (infinite when no design was found).
    pub exact_lower_bound: f64,
}

/// The learned-ranking engine (registry name `surrogate`).
pub struct SurrogateEngine {
    /// Model + rank-cut parameters.
    pub cfg: SurrogateConfig,
    /// The underlying ladder's parameters (shared with `nlpdse`).
    pub dse: DseConfig,
}

impl SurrogateEngine {
    /// Engine over explicit surrogate and ladder parameters.
    pub fn new(cfg: SurrogateConfig, dse: DseConfig) -> SurrogateEngine {
        SurrogateEngine { cfg, dse }
    }
}

impl Default for SurrogateEngine {
    fn default() -> Self {
        SurrogateEngine::new(SurrogateConfig::default(), DseConfig::default())
    }
}

impl Engine for SurrogateEngine {
    fn name(&self) -> &str {
        "surrogate"
    }

    fn explore(&self, ctx: &ExploreCtx<'_>) -> Exploration {
        let (k, a, dev) = (ctx.kernel, ctx.analysis, ctx.device);
        let model = match &self.cfg.model {
            Some(m) => m.clone(),
            None => train(&self.cfg.train).model,
        };
        let model_hash = model.content_hash();
        let bound = match ctx.bound {
            Some(bm) => Arc::new(bm.clone()),
            None => Arc::new(BoundModel::build(k, a, dev)),
        };
        let compiled = Arc::new(bound.compile());

        let frac = self.cfg.verify_fraction.clamp(0.0, 1.0);
        let min_keep = self.cfg.min_keep.max(1);
        let rank_skipped = Cell::new(0u32);
        let predict_failures = Cell::new(0u32);
        let filter = |cands: &[(Design, f64)]| -> Vec<bool> {
            let n = cands.len();
            if frac >= 1.0 || n == 0 {
                return vec![true; n];
            }
            let mut keep = vec![false; n];
            let mut scored: Vec<(usize, f64)> = Vec::new();
            for (i, (d, _)) in cands.iter().enumerate() {
                match model.predict(k, a, dev, d) {
                    Some(p) => scored.push((i, p)),
                    None => {
                        // unrankable: fall back to exact exploration
                        predict_failures.set(predict_failures.get() + 1);
                        keep[i] = true;
                    }
                }
            }
            // predicted-best first; ties resolve to the solver's own
            // lower-bound-ascending order, keeping the cut deterministic
            scored.sort_by(|x, y| {
                x.1.partial_cmp(&y.1)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(x.0.cmp(&y.0))
            });
            let keep_n = ((frac * n as f64).ceil() as usize).max(min_keep).min(scored.len());
            for &(i, _) in scored.iter().take(keep_n) {
                keep[i] = true;
            }
            rank_skipped.set(rank_skipped.get() + (scored.len() - keep_n) as u32);
            keep
        };

        let out = run_ladder_filtered(
            k,
            a,
            dev,
            &self.dse,
            ctx.evaluator,
            bound.clone(),
            compiled.clone(),
            &[],
            Some(&filter),
        );

        // exact re-verification: the reported best is scored by the
        // compiled model and floored by the admissible bound, never
        // left as a prediction
        let exact = out.best.as_ref().map(|(d, _)| {
            let mut scratch = compiled.scratch();
            let r = compiled.evaluate(d, &mut scratch);
            let lb = bound.lower_bound(&PartialDesign::from_design(d));
            (r, lb)
        });
        let so = SurrogateOutcome {
            outcome: out,
            model_hash,
            model_seed: model.seed,
            verify_fraction: frac,
            rank_skipped: rank_skipped.get(),
            predict_failures: predict_failures.get(),
            exact_cycles: exact.as_ref().map(|(r, _)| r.total_cycles),
            exact_feasible: exact.as_ref().map(|(r, _)| r.feasible).unwrap_or(false),
            exact_lower_bound: exact.as_ref().map(|(_, lb)| *lb).unwrap_or(f64::INFINITY),
        };
        so.into()
    }
}

impl From<SurrogateOutcome> for Exploration {
    fn from(o: SurrogateOutcome) -> Exploration {
        // normalize from the filtered ladder; rank cuts already appear
        // as pruned steps in the trace, so the counters need no patching
        let mut e: Exploration = o.outcome.clone().into();
        e.engine = "surrogate".into();
        e.detail = EngineDetail::Surrogate(Box::new(o));
        e
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{self, Size};
    use crate::dse::run_nlp_dse;
    use crate::hls::Device;
    use crate::ir::DType;
    use crate::nlp::RustFeatureEvaluator;
    use crate::poly::Analysis;

    fn explore(frac: f64) -> Exploration {
        let k = benchmarks::build("gemm", Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let ctx = ExploreCtx {
            kernel: &k,
            analysis: &a,
            device: &dev,
            evaluator: &RustFeatureEvaluator,
            bound: None,
        };
        let cfg = SurrogateConfig { verify_fraction: frac, ..SurrogateConfig::default() };
        SurrogateEngine::new(cfg, DseConfig::default()).explore(&ctx)
    }

    #[test]
    fn verify_fraction_one_matches_exact_ladder_bitwise() {
        let k = benchmarks::build("gemm", Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let exact = run_nlp_dse(&k, &a, &dev, &DseConfig::default(), &RustFeatureEvaluator);
        let sur = explore(1.0);
        let so = sur.as_surrogate().unwrap();
        assert_eq!(so.rank_skipped, 0);
        assert_eq!(exact.best_gflops, sur.best_gflops);
        assert_eq!(exact.trace.len(), so.outcome.trace.len());
        for (s1, s2) in exact.trace.iter().zip(&so.outcome.trace) {
            assert_eq!(s1.fingerprint, s2.fingerprint, "step {}", s1.step);
            assert_eq!(s1.measured, s2.measured, "step {}", s1.step);
        }
    }

    #[test]
    fn reported_best_is_exactly_scored_and_feasible() {
        let out = explore(0.4);
        assert!(out.best.is_some());
        let so = out.as_surrogate().unwrap();
        let exact = so.exact_cycles.unwrap();
        assert!(so.exact_feasible, "best must re-verify feasible");
        assert!(exact.is_finite() && exact > 0.0);
        assert!(
            so.exact_lower_bound <= exact * 1.0001,
            "bound {} must floor exact {}",
            so.exact_lower_bound,
            exact
        );
        assert_eq!(out.engine, "surrogate");
    }

    #[test]
    fn rank_cut_keeps_the_outcome_contract() {
        let cut = explore(0.3);
        let so = cut.as_surrogate().unwrap();
        assert!(cut.best.is_some(), "min_keep keeps every wave alive");
        assert!(so.exact_feasible, "cut run's best must still re-verify");
        // every rank-skipped candidate surfaces as a pruned trace step
        assert!(cut.pruned >= so.rank_skipped, "{} < {}", cut.pruned, so.rank_skipped);
        assert!((so.verify_fraction - 0.3).abs() < 1e-12);
    }
}
