//! Pooled per-design feature vector φ for the ridge surrogate.
//!
//! The ABI encoding (`model::features`) is a variable-population block of
//! up to 16 units × 8 loop rows; a linear regressor needs a fixed dense
//! vector. φ pools the block into [`PHI_DIM`] log-scaled aggregates. The
//! strongest feature is the ABI formula's own latency
//! (`eval_features` — a proven lower bound within [0.2, 1.02]× of the
//! exact model on the benchmark suite), so the ridge fit mostly learns a
//! per-shape correction on top of an already-monotone signal; the
//! remaining aggregates let it separate designs the bound ties.

use crate::hls::Device;
use crate::ir::Kernel;
use crate::model::{encode_design, eval_features, Abi, DesignFeatures};
use crate::poly::Analysis;
use crate::pragma::Design;

/// Dimension of the pooled feature vector (bias included).
pub const PHI_DIM: usize = 14;

/// `ln(1 + max(x, 0))` — the corpus's latency/footprint magnitudes span
/// many decades, so every aggregate is log-compressed.
#[inline]
fn ln1p(x: f64) -> f64 {
    (1.0 + x.max(0.0)).ln()
}

/// Pool one encoded design into the dense φ vector.
pub fn pool(f: &DesignFeatures) -> [f64; PHI_DIM] {
    let (lat_hat, dsp_hat) = eval_features(f);
    let mut units_valid = 0.0f64;
    let mut sum_ln_tc = 0.0f64;
    let mut sum_ln_uf = 0.0f64;
    let mut n_above_par = 0.0f64;
    let mut n_above_seq = 0.0f64;
    let mut n_under_red = 0.0f64;
    let mut sum_ln_ii = 0.0f64;
    let mut sum_ln_ramp = 0.0f64;
    let mut sum_w = 0.0f64;
    let mut sum_ln_mcu = 0.0f64;
    for u in 0..Abi::UNITS {
        let unit = &f.units[u * Abi::G..(u + 1) * Abi::G];
        if unit[7] == 0.0 {
            continue;
        }
        units_valid += 1.0;
        sum_ln_ii += ln1p(unit[2]);
        sum_ln_ramp += ln1p(unit[2] * (unit[3] / unit[4].max(1.0) - 1.0).max(0.0));
        sum_w += unit[6];
        let mut mcu = 1.0f64;
        for l in 0..Abi::LOOPS {
            let row =
                &f.loops[(u * Abi::LOOPS + l) * Abi::F..(u * Abi::LOOPS + l + 1) * Abi::F];
            if row[5] == 0.0 {
                continue;
            }
            sum_ln_tc += ln1p(row[0]);
            sum_ln_uf += ln1p(row[1].max(1.0));
            n_above_par += row[2];
            n_above_seq += row[3];
            n_under_red += row[4];
            mcu *= row[1].max(1.0);
        }
        sum_ln_mcu += ln1p(mcu);
    }
    let x_lat = ln1p(lat_hat);
    [
        1.0, // bias
        x_lat,
        ln1p(dsp_hat),
        units_valid,
        sum_ln_tc,
        sum_ln_uf,
        n_above_par,
        n_above_seq,
        n_under_red,
        sum_ln_ii,
        sum_ln_ramp,
        sum_w,
        sum_ln_mcu,
        x_lat * x_lat, // curvature of the bound-to-exact gap
    ]
}

/// Encode + pool one design. `None` when the kernel overflows the ABI
/// (more units/loops than the encoding carries) — callers treat such
/// candidates as unrankable and fall back to exact exploration.
pub fn phi(k: &Kernel, a: &Analysis, dev: &Device, d: &Design) -> Option<[f64; PHI_DIM]> {
    encode_design(k, a, dev, d).map(|f| pool(&f))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{self, Size};
    use crate::ir::{DType, LoopId};

    #[test]
    fn phi_is_finite_and_pragma_sensitive() {
        let k = benchmarks::build("gemm", Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let d0 = Design::empty(&k);
        let mut d1 = Design::empty(&k);
        d1.get_mut(LoopId(0)).uf = 4;
        let p0 = phi(&k, &a, &dev, &d0).unwrap();
        let p1 = phi(&k, &a, &dev, &d1).unwrap();
        assert!(p0.iter().all(|x| x.is_finite()));
        assert!(p1.iter().all(|x| x.is_finite()));
        assert_ne!(p0, p1, "unrolling must move the feature vector");
        assert_eq!(p0[0], 1.0, "bias slot");
    }

    #[test]
    fn latency_feature_tracks_the_bound() {
        // the dominant feature is the ABI bound itself: a 4x-unrolled
        // pipeline must not report a *larger* ln-latency feature than
        // the pragma-free design
        let k = benchmarks::build("gemm", Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let d0 = Design::empty(&k);
        let mut d1 = Design::empty(&k);
        d1.get_mut(LoopId(3)).pipeline = true;
        d1.get_mut(LoopId(3)).uf = 4;
        let p0 = phi(&k, &a, &dev, &d0).unwrap();
        let p1 = phi(&k, &a, &dev, &d1).unwrap();
        assert!(p1[1] <= p0[1] + 1e-9, "phi_lat {} vs {}", p1[1], p0[1]);
    }
}
