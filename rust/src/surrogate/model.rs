//! The versioned surrogate artifact: fitted weights + standardization,
//! persisted as JSON via `util::json` (no serde), plus the training
//! entry point.
//!
//! The artifact's *content hash* ([`SurrogateModel::content_hash`]) is a
//! stable FNV-1a over its canonical compact JSON rendering — key-sorted
//! and shortest-round-trip float formatting, so the hash is identical
//! whether computed before `save` or after `load`. The serve daemon
//! mixes it into the DSE cache fingerprint, which is what makes a
//! retrained model structurally unable to replay a stale exploration.

use super::corpus::{sample_corpus, TrainConfig};
use super::features::{phi, PHI_DIM};
use super::ridge::{fit_ridge, RidgeFit};
use super::spearman;
use crate::hls::Device;
use crate::ir::Kernel;
use crate::poly::Analysis;
use crate::pragma::Design;
use crate::util::json::Json;
use crate::util::rng::hash64;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Artifact schema version. Bumped whenever the feature pooling or the
/// JSON layout changes; `from_json` rejects mismatches instead of
/// silently mis-predicting.
pub const ARTIFACT_VERSION: u64 = 1;

/// A trained, persistable latency surrogate.
#[derive(Clone, Debug, PartialEq)]
pub struct SurrogateModel {
    /// Schema version ([`ARTIFACT_VERSION`] at save time).
    pub version: u64,
    /// Master training seed (provenance; reproduces the artifact).
    pub seed: u64,
    /// Ridge regularization the fit used.
    pub lambda: f64,
    /// Standardization + weights over the pooled φ features.
    pub fit: RidgeFit,
    /// Labeled samples the fit saw (training split).
    pub n_samples: u64,
    /// Kernels in the corpus.
    pub n_kernels: u64,
}

impl SurrogateModel {
    /// Predicted `ln(1 + total_cycles)` for one design; `None` when the
    /// kernel overflows the feature ABI (callers fall back to exact
    /// exploration for such candidates).
    pub fn predict(&self, k: &Kernel, a: &Analysis, dev: &Device, d: &Design) -> Option<f64> {
        phi(k, a, dev, d).map(|x| self.fit.predict(&x))
    }

    /// The artifact as a JSON tree (canonical: key-sorted objects).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("kind", "nlp-dse-surrogate-ridge")
            .set("version", self.version)
            .set("seed", self.seed)
            .set("lambda", self.lambda)
            .set("dim", self.fit.weights.len())
            .set("weights", self.fit.weights.clone())
            .set("mean", self.fit.mean.clone())
            .set("std", self.fit.std.clone())
            .set("n_samples", self.n_samples)
            .set("n_kernels", self.n_kernels);
        j
    }

    /// Rebuild from a parsed artifact, rejecting wrong kinds, schema
    /// versions, and feature dimensions.
    pub fn from_json(j: &Json) -> Result<SurrogateModel> {
        let kind = j.get("kind").and_then(Json::as_str).unwrap_or("");
        if kind != "nlp-dse-surrogate-ridge" {
            bail!("not a surrogate artifact (kind `{kind}`)");
        }
        let version = j
            .get("version")
            .and_then(Json::as_u64)
            .context("surrogate artifact: missing `version`")?;
        if version != ARTIFACT_VERSION {
            bail!(
                "surrogate artifact version {version} unsupported (this build reads {ARTIFACT_VERSION}); retrain with `nlp-dse train`"
            );
        }
        let floats = |key: &str| -> Result<Vec<f64>> {
            j.get(key)
                .and_then(Json::as_arr)
                .with_context(|| format!("surrogate artifact: missing `{key}`"))?
                .iter()
                .map(|v| v.as_f64().with_context(|| format!("`{key}`: non-numeric entry")))
                .collect()
        };
        let weights = floats("weights")?;
        let mean = floats("mean")?;
        let std = floats("std")?;
        let dim = j.get("dim").and_then(Json::as_u64).unwrap_or(0) as usize;
        if dim != PHI_DIM
            || weights.len() != PHI_DIM
            || mean.len() != PHI_DIM
            || std.len() != PHI_DIM
        {
            bail!(
                "surrogate artifact feature dim {dim} != {PHI_DIM} (trained against a different feature set); retrain with `nlp-dse train`"
            );
        }
        if std.iter().any(|s| !s.is_finite() || *s <= 0.0)
            || weights.iter().chain(&mean).any(|x| !x.is_finite())
        {
            bail!("surrogate artifact: non-finite or non-positive fit parameters");
        }
        Ok(SurrogateModel {
            version,
            seed: j.get("seed").and_then(Json::as_u64).unwrap_or(0),
            lambda: j.get("lambda").and_then(Json::as_f64).unwrap_or(0.0),
            fit: RidgeFit { weights, mean, std },
            n_samples: j.get("n_samples").and_then(Json::as_u64).unwrap_or(0),
            n_kernels: j.get("n_kernels").and_then(Json::as_u64).unwrap_or(0),
        })
    }

    /// Stable content hash of the canonical compact rendering — the
    /// serve fingerprint ingredient. Identical before save and after
    /// load (the round trip is exact: shortest-representation floats).
    pub fn content_hash(&self) -> u64 {
        hash64(&self.to_json().to_line())
    }

    /// Write the artifact (pretty JSON + trailing newline) to `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut text = self.to_json().to_string_pretty();
        text.push('\n');
        std::fs::write(path, text)
            .with_context(|| format!("writing surrogate artifact {}", path.display()))
    }

    /// Read an artifact back (schema-checked).
    pub fn load(path: &Path) -> Result<SurrogateModel> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading surrogate artifact {}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing surrogate artifact {}: {e}", path.display()))?;
        SurrogateModel::from_json(&j)
    }
}

/// What [`train`] produced, with the held-out quality number the CLI
/// prints and the fuzz gate asserts against its committed floor.
#[derive(Clone, Debug)]
pub struct TrainOutcome {
    /// The fitted artifact.
    pub model: SurrogateModel,
    /// Training-split samples.
    pub n_train: usize,
    /// Held-out samples (every 5th corpus row).
    pub n_holdout: usize,
    /// Designs dropped at featurization (ABI overflow).
    pub skipped: u32,
    /// Spearman rank correlation between predicted and exact ln-latency
    /// on the held-out split (1.0 when the split is degenerate).
    pub holdout_spearman: f64,
}

/// Train a surrogate on the seeded corpus: sample, split (every 5th row
/// held out), fit the ridge on the rest, score the holdout by Spearman
/// rank correlation. Deterministic bit-for-bit in `cfg.seed`.
pub fn train(cfg: &TrainConfig) -> TrainOutcome {
    let corpus = sample_corpus(cfg);
    let mut train_x: Vec<Vec<f64>> = Vec::new();
    let mut train_y: Vec<f64> = Vec::new();
    let mut hold_x: Vec<Vec<f64>> = Vec::new();
    let mut hold_y: Vec<f64> = Vec::new();
    for (i, (x, &y)) in corpus.xs.iter().zip(&corpus.ys).enumerate() {
        if i % 5 == 4 {
            hold_x.push(x.clone());
            hold_y.push(y);
        } else {
            train_x.push(x.clone());
            train_y.push(y);
        }
    }
    let fit = fit_ridge(&train_x, &train_y, cfg.lambda);
    let holdout_spearman = if hold_y.len() >= 2 {
        let preds: Vec<f64> = hold_x.iter().map(|x| fit.predict(x)).collect();
        spearman(&preds, &hold_y)
    } else {
        1.0
    };
    TrainOutcome {
        model: SurrogateModel {
            version: ARTIFACT_VERSION,
            seed: cfg.seed,
            lambda: cfg.lambda,
            fit,
            n_samples: train_x.len() as u64,
            n_kernels: corpus.n_kernels as u64,
        },
        n_train: train_x.len(),
        n_holdout: hold_y.len(),
        skipped: corpus.skipped,
        holdout_spearman,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn micro() -> TrainConfig {
        TrainConfig {
            kernels: 3,
            designs: 10,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn training_is_bit_reproducible() {
        let t1 = train(&micro());
        let t2 = train(&micro());
        assert_eq!(t1.model, t2.model);
        assert_eq!(t1.model.content_hash(), t2.model.content_hash());
    }

    #[test]
    fn artifact_round_trips_and_hash_is_stable() {
        let t = train(&micro());
        let dir = std::env::temp_dir().join("nlp_dse_surrogate_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("artifact_roundtrip.json");
        t.model.save(&path).unwrap();
        let back = SurrogateModel::load(&path).unwrap();
        assert_eq!(back, t.model);
        assert_eq!(back.content_hash(), t.model.content_hash());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn different_seeds_hash_differently() {
        let t1 = train(&micro());
        let t2 = train(&TrainConfig {
            seed: micro().seed + 1,
            ..micro()
        });
        assert_ne!(t1.model.content_hash(), t2.model.content_hash());
    }

    #[test]
    fn from_json_rejects_foreign_and_stale_artifacts() {
        let t = train(&micro());
        let mut wrong_kind = t.model.to_json();
        wrong_kind.set("kind", "something-else");
        assert!(SurrogateModel::from_json(&wrong_kind).is_err());
        let mut wrong_version = t.model.to_json();
        wrong_version.set("version", ARTIFACT_VERSION + 1);
        let err = format!("{:#}", SurrogateModel::from_json(&wrong_version).unwrap_err());
        assert!(err.contains("retrain"), "{err}");
        let mut wrong_dim = t.model.to_json();
        wrong_dim.set("dim", 3u64);
        assert!(SurrogateModel::from_json(&wrong_dim).is_err());
    }

    #[test]
    fn holdout_rank_correlation_is_strong() {
        // the dominant feature is an admissible bound within [0.2, 1.02]x
        // of the exact score, so even the micro corpus must rank well
        let t = train(&micro());
        assert!(
            t.holdout_spearman > 0.7,
            "holdout spearman {}",
            t.holdout_spearman
        );
    }
}
