//! The evaluated benchmark suite: the 24 PolyBench/C 4.2.1 kernels of
//! Table 5 plus the CNN kernel, expressed in the affine IR at the paper's
//! Small / Medium / Large problem sizes (Table 8).
//!
//! `ludcmp`, `deriche`, `nussinov` are excluded (negative strides),
//! `cholesky`/`correlation` (sqrt) and `fdtd-2d` (Merlin bug) likewise —
//! matching Section 7.1's exclusions.

mod cnn;
mod linalg;
mod linalg_tri;
pub mod sizes;
mod stencil;

use crate::ir::{DType, Kernel};

pub use cnn::kernel_cnn;
pub use linalg::{
    kernel_2mm, kernel_3mm, kernel_atax, kernel_bicg, kernel_doitgen, kernel_gemm,
    kernel_gemver, kernel_gesummv, kernel_mvt,
};
pub use linalg_tri::{
    kernel_covariance, kernel_durbin, kernel_gramschmidt, kernel_lu, kernel_symm,
    kernel_syr2k, kernel_syrk, kernel_trisolv, kernel_trmm,
};
pub use sizes::{build, Size};
pub use stencil::{
    kernel_floyd_warshall, kernel_heat_3d, kernel_jacobi_1d, kernel_jacobi_2d,
    kernel_seidel_2d,
};

/// Resolve a kernel *spec*: a registered benchmark name (honouring
/// `size`/`dtype`) or a path to a `.knl` file (which carries its own
/// dtype and problem size — `size`/`dtype` are ignored).
///
/// This is the one kernel-by-name entry point the CLI, the campaign
/// coordinator, and the `Explorer` facade all route through; unknown
/// specs produce a clean error instead of the old `panic!` paths.
///
/// # Examples
///
/// ```
/// use nlp_dse::benchmarks::{lookup, Size};
/// use nlp_dse::ir::DType;
///
/// let k = lookup("gemm", Size::Small, DType::F32)?;
/// assert_eq!(k.name, "gemm");
/// assert_eq!(k.n_loops(), 4);
/// assert!(lookup("not-a-kernel", Size::Small, DType::F32).is_err());
/// # Ok::<(), anyhow::Error>(())
/// ```
pub fn lookup(spec: &str, size: Size, dtype: DType) -> anyhow::Result<Kernel> {
    if let Some(k) = build(spec, size, dtype) {
        return Ok(k);
    }
    // a `.knl` suffix always means "parse as a file" (so a missing file
    // reports the read error, not "unknown kernel"); anything else only
    // dispatches to the parser when it names an actual file — a typo'd
    // kernel name colliding with a directory must keep the clean
    // unknown-kernel guidance below
    if spec.ends_with(".knl") || std::path::Path::new(spec).is_file() {
        return crate::frontend::parse_file(spec);
    }
    anyhow::bail!(
        "unknown kernel `{spec}` — not a registered benchmark (known: {}) and not a .knl \
         file; try `--kernel-file <path.knl>` or generate one with `gen`",
        ALL.join(", ")
    )
}

/// All benchmark names, in Table 5 order.
pub const ALL: [&str; 24] = [
    "covariance",
    "2mm",
    "3mm",
    "atax",
    "bicg",
    "cnn",
    "doitgen",
    "durbin",
    "gemm",
    "gemver",
    "gesummv",
    "gramschmidt",
    "lu",
    "mvt",
    "symm",
    "syr2k",
    "syrk",
    "trisolv",
    "trmm",
    "floyd-warshall",
    "heat-3d",
    "jacobi-1d",
    "jacobi-2d",
    "seidel-2d",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_resolves_registry_names_and_knl_files() {
        let k = lookup("gemm", Size::Small, DType::F32).unwrap();
        assert_eq!(k.name, "gemm");
        // a .knl file path resolves through the frontend parser
        let gen = crate::frontend::generate(&crate::frontend::GenConfig::with_seed(11));
        let path = std::env::temp_dir().join("nlp_dse_lookup_test.knl");
        std::fs::write(&path, crate::frontend::pretty::print(&gen)).unwrap();
        let k2 = lookup(path.to_str().unwrap(), Size::Small, DType::F32).unwrap();
        assert_eq!(gen.structural_diff(&k2), None);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn lookup_unknown_kernel_is_a_clean_error() {
        let err = lookup("definitely-not-a-kernel", Size::Small, DType::F32).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown kernel `definitely-not-a-kernel`"), "{msg}");
        assert!(msg.contains("--kernel-file"), "{msg}");
        assert!(msg.contains("`gen`"), "{msg}");
        // a missing .knl path errors with the file context, not "unknown"
        let err = lookup("/nope/missing.knl", Size::Small, DType::F32).unwrap_err();
        assert!(format!("{err:#}").contains("reading kernel file"), "{err:#}");
    }

    #[test]
    fn all_kernels_build_at_all_sizes() {
        for name in ALL {
            for size in [Size::Small, Size::Medium, Size::Large] {
                if name == "cnn" && size != Size::Medium {
                    continue; // cnn has a single problem size (Sec 7.1)
                }
                let k = build(name, size, DType::F32)
                    .unwrap_or_else(|| panic!("{name} missing at {size:?}"));
                assert!(k.n_loops() > 0, "{name}");
                assert!(k.n_stmts() > 0, "{name}");
                // analyses must not panic
                let a = crate::poly::Analysis::new(&k);
                assert!(a.total_flops > 0.0, "{name} has no flops");
            }
        }
    }

    #[test]
    fn loop_counts_match_table5() {
        use crate::ir::DType::F32;
        // NL column of Table 5
        let cases: &[(&str, usize)] = &[
            ("covariance", 7),
            ("2mm", 6),
            ("3mm", 9),
            ("atax", 4),
            ("bicg", 3),
            ("cnn", 6),
            ("doitgen", 5),
            ("durbin", 4),
            ("gemm", 4),
            ("gemver", 7),
            ("gesummv", 2),
            ("lu", 5),
            ("mvt", 4),
            ("symm", 3),
            ("syr2k", 4),
            ("syrk", 4),
            ("trisolv", 2),
            ("trmm", 3),
            ("floyd-warshall", 3),
            ("heat-3d", 7),
            ("jacobi-1d", 3),
            ("jacobi-2d", 5),
            ("seidel-2d", 3),
        ];
        for &(name, nl) in cases {
            let k = build(name, Size::Medium, F32).unwrap();
            assert_eq!(k.n_loops(), nl, "{name} loop count");
        }
    }
}
