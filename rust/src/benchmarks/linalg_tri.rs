//! Triangular / recurrence-heavy linear-algebra kernels: covariance,
//! durbin, gramschmidt, lu, symm, syr2k, syrk, trisolv, trmm.
//!
//! These exercise non-constant trip counts (triangular loops), serializing
//! outer loops, and scalar recurrences — the cases where the paper's
//! `TC_min/TC_max/TC_avg` machinery and Eq 8 dependence caps matter.
//!
//! Scalars involved in recurrences (`nrm`, `sum`, `alpha`, `beta`, `temp2`)
//! are modeled as 1-element `Temp` arrays so the dependence analysis sees
//! them; square roots (gramschmidt's `R[k][k] = sqrt(nrm)`) are modeled as a
//! division (same latency class on Vitis).

use crate::ir::{ArrayDir, DType, Kernel, KernelBuilder, OpKind};

/// Covariance matrix of `data` (N samples × M variables).
pub fn kernel_covariance(m: u64, n: u64, dtype: DType) -> Kernel {
    let mut kb = KernelBuilder::new("covariance", dtype);
    let data = kb.array("data", &[n, m], ArrayDir::InOut);
    let mean = kb.array("mean", &[m], ArrayDir::Temp);
    let cov = kb.array("cov", &[m, m], ArrayDir::Out);

    kb.for_const("j0", 0, m as i64, |kb, j0| {
        kb.stmt("S0", vec![kb.at(mean, &[kb.v(j0)])], vec![], &[]);
        kb.for_const("i0", 0, n as i64, |kb, i0| {
            kb.stmt(
                "S1",
                vec![kb.at(mean, &[kb.v(j0)])],
                vec![kb.at(mean, &[kb.v(j0)]), kb.at(data, &[kb.v(i0), kb.v(j0)])],
                &[(OpKind::Add, 1)],
            );
        });
        kb.stmt(
            "S2",
            vec![kb.at(mean, &[kb.v(j0)])],
            vec![kb.at(mean, &[kb.v(j0)])],
            &[(OpKind::Div, 1)],
        );
    });
    kb.for_const("i1", 0, n as i64, |kb, i1| {
        kb.for_const("j1", 0, m as i64, |kb, j1| {
            kb.stmt(
                "S3",
                vec![kb.at(data, &[kb.v(i1), kb.v(j1)])],
                vec![kb.at(data, &[kb.v(i1), kb.v(j1)]), kb.at(mean, &[kb.v(j1)])],
                &[(OpKind::Sub, 1)],
            );
        });
    });
    kb.for_const("i2", 0, m as i64, |kb, i2| {
        // for j2 in [i2, M)
        kb.for_expr("j2", kb.v(i2), kb.c(m as i64), |kb, j2| {
            kb.stmt("S4", vec![kb.at(cov, &[kb.v(i2), kb.v(j2)])], vec![], &[]);
            kb.for_const("k2", 0, n as i64, |kb, k2| {
                kb.stmt(
                    "S5",
                    vec![kb.at(cov, &[kb.v(i2), kb.v(j2)])],
                    vec![
                        kb.at(cov, &[kb.v(i2), kb.v(j2)]),
                        kb.at(data, &[kb.v(k2), kb.v(i2)]),
                        kb.at(data, &[kb.v(k2), kb.v(j2)]),
                    ],
                    &[(OpKind::Mul, 1), (OpKind::Add, 1)],
                );
            });
            kb.stmt(
                "S6",
                vec![kb.at(cov, &[kb.v(i2), kb.v(j2)])],
                vec![kb.at(cov, &[kb.v(i2), kb.v(j2)])],
                &[(OpKind::Div, 1)],
            );
            kb.stmt(
                "S7",
                vec![kb.at(cov, &[kb.v(j2), kb.v(i2)])],
                vec![kb.at(cov, &[kb.v(i2), kb.v(j2)])],
                &[],
            );
        });
    });
    kb.finish()
}

/// Durbin's algorithm for Toeplitz systems (fully serial outer loop).
pub fn kernel_durbin(n: u64, dtype: DType) -> Kernel {
    let mut kb = KernelBuilder::new("durbin", dtype);
    let r = kb.array("r", &[n], ArrayDir::In);
    let y = kb.array("y", &[n], ArrayDir::Out);
    let z = kb.array("z", &[n], ArrayDir::Temp);
    let alpha = kb.array("alpha", &[1], ArrayDir::Temp);
    let beta = kb.array("beta", &[1], ArrayDir::Temp);
    let sum = kb.array("sum", &[1], ArrayDir::Temp);

    kb.for_const("k", 1, n as i64, |kb, k| {
        // beta = (1 - alpha*alpha) * beta
        kb.stmt(
            "S0",
            vec![kb.at(beta, &[kb.c(0)])],
            vec![kb.at(alpha, &[kb.c(0)]), kb.at(beta, &[kb.c(0)])],
            &[(OpKind::Mul, 2), (OpKind::Sub, 1)],
        );
        kb.stmt("S1", vec![kb.at(sum, &[kb.c(0)])], vec![], &[]);
        kb.for_expr("i0", kb.c(0), kb.v(k), |kb, i0| {
            // sum += r[k-i-1] * y[i]
            let idx = kb.v(k).sub(&kb.v(i0)).plus_const(-1);
            kb.stmt(
                "S2",
                vec![kb.at(sum, &[kb.c(0)])],
                vec![
                    kb.at(sum, &[kb.c(0)]),
                    kb.at(r, &[idx]),
                    kb.at(y, &[kb.v(i0)]),
                ],
                &[(OpKind::Mul, 1), (OpKind::Add, 1)],
            );
        });
        // alpha = -(r[k] + sum) / beta
        kb.stmt(
            "S3",
            vec![kb.at(alpha, &[kb.c(0)])],
            vec![
                kb.at(r, &[kb.v(k)]),
                kb.at(sum, &[kb.c(0)]),
                kb.at(beta, &[kb.c(0)]),
            ],
            &[(OpKind::Add, 1), (OpKind::Div, 1)],
        );
        kb.for_expr("i1", kb.c(0), kb.v(k), |kb, i1| {
            // z[i] = y[i] + alpha * y[k-i-1]
            let idx = kb.v(k).sub(&kb.v(i1)).plus_const(-1);
            kb.stmt(
                "S4",
                vec![kb.at(z, &[kb.v(i1)])],
                vec![
                    kb.at(y, &[kb.v(i1)]),
                    kb.at(alpha, &[kb.c(0)]),
                    kb.at(y, &[idx]),
                ],
                &[(OpKind::Mul, 1), (OpKind::Add, 1)],
            );
        });
        kb.for_expr("i2", kb.c(0), kb.v(k), |kb, i2| {
            kb.stmt(
                "S5",
                vec![kb.at(y, &[kb.v(i2)])],
                vec![kb.at(z, &[kb.v(i2)])],
                &[],
            );
        });
        kb.stmt(
            "S6",
            vec![kb.at(y, &[kb.v(k)])],
            vec![kb.at(alpha, &[kb.c(0)])],
            &[],
        );
    });
    kb.finish()
}

/// Modified Gram-Schmidt QR decomposition.
pub fn kernel_gramschmidt(m: u64, n: u64, dtype: DType) -> Kernel {
    let mut kb = KernelBuilder::new("gramschmidt", dtype);
    let a = kb.array("A", &[m, n], ArrayDir::InOut);
    let r = kb.array("R", &[n, n], ArrayDir::Out);
    let q = kb.array("Q", &[m, n], ArrayDir::Out);
    let nrm = kb.array("nrm", &[1], ArrayDir::Temp);

    kb.for_const("k", 0, n as i64, |kb, k| {
        kb.stmt("S0", vec![kb.at(nrm, &[kb.c(0)])], vec![], &[]);
        kb.for_const("i0", 0, m as i64, |kb, i0| {
            // nrm += A[i][k] * A[i][k]
            kb.stmt(
                "S1",
                vec![kb.at(nrm, &[kb.c(0)])],
                vec![kb.at(nrm, &[kb.c(0)]), kb.at(a, &[kb.v(i0), kb.v(k)])],
                &[(OpKind::Mul, 1), (OpKind::Add, 1)],
            );
        });
        // R[k][k] = sqrt(nrm) — modeled as a Div-class op
        kb.stmt(
            "S2",
            vec![kb.at(r, &[kb.v(k), kb.v(k)])],
            vec![kb.at(nrm, &[kb.c(0)])],
            &[(OpKind::Div, 1)],
        );
        kb.for_const("i1", 0, m as i64, |kb, i1| {
            // Q[i][k] = A[i][k] / R[k][k]
            kb.stmt(
                "S3",
                vec![kb.at(q, &[kb.v(i1), kb.v(k)])],
                vec![kb.at(a, &[kb.v(i1), kb.v(k)]), kb.at(r, &[kb.v(k), kb.v(k)])],
                &[(OpKind::Div, 1)],
            );
        });
        kb.for_expr("j", kb.vp(k, 1), kb.c(n as i64), |kb, j| {
            kb.stmt("S4", vec![kb.at(r, &[kb.v(k), kb.v(j)])], vec![], &[]);
            kb.for_const("i2", 0, m as i64, |kb, i2| {
                // R[k][j] += Q[i][k] * A[i][j]
                kb.stmt(
                    "S5",
                    vec![kb.at(r, &[kb.v(k), kb.v(j)])],
                    vec![
                        kb.at(r, &[kb.v(k), kb.v(j)]),
                        kb.at(q, &[kb.v(i2), kb.v(k)]),
                        kb.at(a, &[kb.v(i2), kb.v(j)]),
                    ],
                    &[(OpKind::Mul, 1), (OpKind::Add, 1)],
                );
            });
            kb.for_const("i3", 0, m as i64, |kb, i3| {
                // A[i][j] -= Q[i][k] * R[k][j]
                kb.stmt(
                    "S6",
                    vec![kb.at(a, &[kb.v(i3), kb.v(j)])],
                    vec![
                        kb.at(a, &[kb.v(i3), kb.v(j)]),
                        kb.at(q, &[kb.v(i3), kb.v(k)]),
                        kb.at(r, &[kb.v(k), kb.v(j)]),
                    ],
                    &[(OpKind::Mul, 1), (OpKind::Sub, 1)],
                );
            });
        });
    });
    kb.finish()
}

/// LU decomposition (in-place, no pivoting).
pub fn kernel_lu(n: u64, dtype: DType) -> Kernel {
    let mut kb = KernelBuilder::new("lu", dtype);
    let a = kb.array("A", &[n, n], ArrayDir::InOut);

    kb.for_const("i", 0, n as i64, |kb, i| {
        kb.for_expr("j0", kb.c(0), kb.v(i), |kb, j0| {
            kb.for_expr("k0", kb.c(0), kb.v(j0), |kb, k0| {
                // A[i][j] -= A[i][k] * A[k][j]
                kb.stmt(
                    "S0",
                    vec![kb.at(a, &[kb.v(i), kb.v(j0)])],
                    vec![
                        kb.at(a, &[kb.v(i), kb.v(j0)]),
                        kb.at(a, &[kb.v(i), kb.v(k0)]),
                        kb.at(a, &[kb.v(k0), kb.v(j0)]),
                    ],
                    &[(OpKind::Mul, 1), (OpKind::Sub, 1)],
                );
            });
            // A[i][j] /= A[j][j]
            kb.stmt(
                "S1",
                vec![kb.at(a, &[kb.v(i), kb.v(j0)])],
                vec![kb.at(a, &[kb.v(i), kb.v(j0)]), kb.at(a, &[kb.v(j0), kb.v(j0)])],
                &[(OpKind::Div, 1)],
            );
        });
        kb.for_expr("j1", kb.v(i), kb.c(n as i64), |kb, j1| {
            kb.for_expr("k1", kb.c(0), kb.v(i), |kb, k1| {
                kb.stmt(
                    "S2",
                    vec![kb.at(a, &[kb.v(i), kb.v(j1)])],
                    vec![
                        kb.at(a, &[kb.v(i), kb.v(j1)]),
                        kb.at(a, &[kb.v(i), kb.v(k1)]),
                        kb.at(a, &[kb.v(k1), kb.v(j1)]),
                    ],
                    &[(OpKind::Mul, 1), (OpKind::Sub, 1)],
                );
            });
        });
    });
    kb.finish()
}

/// Symmetric matrix-matrix multiply `C = alpha*A*B + beta*C`, A symmetric.
pub fn kernel_symm(m: u64, n: u64, dtype: DType) -> Kernel {
    let mut kb = KernelBuilder::new("symm", dtype);
    let c = kb.array("C", &[m, n], ArrayDir::InOut);
    let a = kb.array("A", &[m, m], ArrayDir::In);
    let b = kb.array("B", &[m, n], ArrayDir::In);
    let temp2 = kb.array("temp2", &[1], ArrayDir::Temp);

    kb.for_const("i", 0, m as i64, |kb, i| {
        kb.for_const("j", 0, n as i64, |kb, j| {
            kb.stmt("S0", vec![kb.at(temp2, &[kb.c(0)])], vec![], &[]);
            kb.for_expr("k", kb.c(0), kb.v(i), |kb, k| {
                // C[k][j] += alpha * B[i][j] * A[i][k]
                kb.stmt(
                    "S1",
                    vec![kb.at(c, &[kb.v(k), kb.v(j)])],
                    vec![
                        kb.at(c, &[kb.v(k), kb.v(j)]),
                        kb.at(b, &[kb.v(i), kb.v(j)]),
                        kb.at(a, &[kb.v(i), kb.v(k)]),
                    ],
                    &[(OpKind::Mul, 2), (OpKind::Add, 1)],
                );
                // temp2 += B[k][j] * A[i][k]
                kb.stmt(
                    "S2",
                    vec![kb.at(temp2, &[kb.c(0)])],
                    vec![
                        kb.at(temp2, &[kb.c(0)]),
                        kb.at(b, &[kb.v(k), kb.v(j)]),
                        kb.at(a, &[kb.v(i), kb.v(k)]),
                    ],
                    &[(OpKind::Mul, 1), (OpKind::Add, 1)],
                );
            });
            // C[i][j] = beta*C[i][j] + alpha*B[i][j]*A[i][i] + alpha*temp2
            kb.stmt_with_chain(
                "S3",
                vec![kb.at(c, &[kb.v(i), kb.v(j)])],
                vec![
                    kb.at(c, &[kb.v(i), kb.v(j)]),
                    kb.at(b, &[kb.v(i), kb.v(j)]),
                    kb.at(a, &[kb.v(i), kb.v(i)]),
                    kb.at(temp2, &[kb.c(0)]),
                ],
                &[(OpKind::Mul, 4), (OpKind::Add, 2)],
                vec![OpKind::Mul, OpKind::Mul, OpKind::Add, OpKind::Add],
            );
        });
    });
    kb.finish()
}

/// Symmetric rank-2k update (triangular output).
pub fn kernel_syr2k(n: u64, m: u64, dtype: DType) -> Kernel {
    let mut kb = KernelBuilder::new("syr2k", dtype);
    let c = kb.array("C", &[n, n], ArrayDir::InOut);
    let a = kb.array("A", &[n, m], ArrayDir::In);
    let b = kb.array("B", &[n, m], ArrayDir::In);

    kb.for_const("i", 0, n as i64, |kb, i| {
        kb.for_expr("j0", kb.c(0), kb.vp(i, 1), |kb, j0| {
            kb.stmt(
                "S0",
                vec![kb.at(c, &[kb.v(i), kb.v(j0)])],
                vec![kb.at(c, &[kb.v(i), kb.v(j0)])],
                &[(OpKind::Mul, 1)],
            );
        });
        kb.for_const("k", 0, m as i64, |kb, k| {
            kb.for_expr("j1", kb.c(0), kb.vp(i, 1), |kb, j1| {
                // C[i][j] += A[j][k]*alpha*B[i][k] + B[j][k]*alpha*A[i][k]
                kb.stmt_with_chain(
                    "S1",
                    vec![kb.at(c, &[kb.v(i), kb.v(j1)])],
                    vec![
                        kb.at(c, &[kb.v(i), kb.v(j1)]),
                        kb.at(a, &[kb.v(j1), kb.v(k)]),
                        kb.at(b, &[kb.v(i), kb.v(k)]),
                        kb.at(b, &[kb.v(j1), kb.v(k)]),
                        kb.at(a, &[kb.v(i), kb.v(k)]),
                    ],
                    &[(OpKind::Mul, 4), (OpKind::Add, 2)],
                    vec![OpKind::Mul, OpKind::Mul, OpKind::Add, OpKind::Add],
                );
            });
        });
    });
    kb.finish()
}

/// Symmetric rank-k update.
pub fn kernel_syrk(n: u64, m: u64, dtype: DType) -> Kernel {
    let mut kb = KernelBuilder::new("syrk", dtype);
    let c = kb.array("C", &[n, n], ArrayDir::InOut);
    let a = kb.array("A", &[n, m], ArrayDir::In);

    kb.for_const("i", 0, n as i64, |kb, i| {
        kb.for_expr("j0", kb.c(0), kb.vp(i, 1), |kb, j0| {
            kb.stmt(
                "S0",
                vec![kb.at(c, &[kb.v(i), kb.v(j0)])],
                vec![kb.at(c, &[kb.v(i), kb.v(j0)])],
                &[(OpKind::Mul, 1)],
            );
        });
        kb.for_const("k", 0, m as i64, |kb, k| {
            kb.for_expr("j1", kb.c(0), kb.vp(i, 1), |kb, j1| {
                // C[i][j] += alpha * A[i][k] * A[j][k]
                kb.stmt(
                    "S1",
                    vec![kb.at(c, &[kb.v(i), kb.v(j1)])],
                    vec![
                        kb.at(c, &[kb.v(i), kb.v(j1)]),
                        kb.at(a, &[kb.v(i), kb.v(k)]),
                        kb.at(a, &[kb.v(j1), kb.v(k)]),
                    ],
                    &[(OpKind::Mul, 2), (OpKind::Add, 1)],
                );
            });
        });
    });
    kb.finish()
}

/// Forward substitution for a lower-triangular system.
pub fn kernel_trisolv(n: u64, dtype: DType) -> Kernel {
    let mut kb = KernelBuilder::new("trisolv", dtype);
    let l = kb.array("L", &[n, n], ArrayDir::In);
    let x = kb.array("x", &[n], ArrayDir::Out);
    let b = kb.array("b", &[n], ArrayDir::In);

    kb.for_const("i", 0, n as i64, |kb, i| {
        kb.stmt(
            "S0",
            vec![kb.at(x, &[kb.v(i)])],
            vec![kb.at(b, &[kb.v(i)])],
            &[],
        );
        kb.for_expr("j", kb.c(0), kb.v(i), |kb, j| {
            // x[i] -= L[i][j] * x[j]
            kb.stmt(
                "S1",
                vec![kb.at(x, &[kb.v(i)])],
                vec![
                    kb.at(x, &[kb.v(i)]),
                    kb.at(l, &[kb.v(i), kb.v(j)]),
                    kb.at(x, &[kb.v(j)]),
                ],
                &[(OpKind::Mul, 1), (OpKind::Sub, 1)],
            );
        });
        // x[i] /= L[i][i]
        kb.stmt(
            "S2",
            vec![kb.at(x, &[kb.v(i)])],
            vec![kb.at(x, &[kb.v(i)]), kb.at(l, &[kb.v(i), kb.v(i)])],
            &[(OpKind::Div, 1)],
        );
    });
    kb.finish()
}

/// Triangular matrix multiply `B = alpha * A^T * B`, A unit lower.
pub fn kernel_trmm(m: u64, n: u64, dtype: DType) -> Kernel {
    let mut kb = KernelBuilder::new("trmm", dtype);
    let a = kb.array("A", &[m, m], ArrayDir::In);
    let b = kb.array("B", &[m, n], ArrayDir::InOut);

    kb.for_const("i", 0, m as i64, |kb, i| {
        kb.for_const("j", 0, n as i64, |kb, j| {
            kb.for_expr("k", kb.vp(i, 1), kb.c(m as i64), |kb, k| {
                // B[i][j] += A[k][i] * B[k][j]
                kb.stmt(
                    "S0",
                    vec![kb.at(b, &[kb.v(i), kb.v(j)])],
                    vec![
                        kb.at(b, &[kb.v(i), kb.v(j)]),
                        kb.at(a, &[kb.v(k), kb.v(i)]),
                        kb.at(b, &[kb.v(k), kb.v(j)]),
                    ],
                    &[(OpKind::Mul, 1), (OpKind::Add, 1)],
                );
            });
            // B[i][j] = alpha * B[i][j]
            kb.stmt(
                "S1",
                vec![kb.at(b, &[kb.v(i), kb.v(j)])],
                vec![kb.at(b, &[kb.v(i), kb.v(j)])],
                &[(OpKind::Mul, 1)],
            );
        });
    });
    kb.finish()
}
