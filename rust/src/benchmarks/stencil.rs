//! Stencil and graph kernels: jacobi-1d, jacobi-2d, heat-3d, seidel-2d,
//! floyd-warshall.
//!
//! These exercise the dependence machinery hardest: time loops carrying
//! cross-statement dependences (jacobi/heat), fully-serial Gauss-Seidel
//! sweeps, and floyd-warshall's `k`-propagation pattern.

use crate::ir::{ArrayDir, DType, Kernel, KernelBuilder, OpKind};

/// 1-D 3-point Jacobi, two arrays ping-ponged per time step.
pub fn kernel_jacobi_1d(tsteps: u64, n: u64, dtype: DType) -> Kernel {
    let mut kb = KernelBuilder::new("jacobi-1d", dtype);
    let a = kb.array("A", &[n], ArrayDir::InOut);
    let b = kb.array("B", &[n], ArrayDir::InOut);

    kb.for_const("t", 0, tsteps as i64, |kb, _t| {
        kb.for_const("i0", 1, n as i64 - 1, |kb, i0| {
            // B[i] = 0.33333 * (A[i-1] + A[i] + A[i+1])
            kb.stmt_with_chain(
                "S0",
                vec![kb.at(b, &[kb.v(i0)])],
                vec![
                    kb.at(a, &[kb.vp(i0, -1)]),
                    kb.at(a, &[kb.v(i0)]),
                    kb.at(a, &[kb.vp(i0, 1)]),
                ],
                &[(OpKind::Add, 2), (OpKind::Mul, 1)],
                vec![OpKind::Add, OpKind::Add, OpKind::Mul],
            );
        });
        kb.for_const("i1", 1, n as i64 - 1, |kb, i1| {
            kb.stmt_with_chain(
                "S1",
                vec![kb.at(a, &[kb.v(i1)])],
                vec![
                    kb.at(b, &[kb.vp(i1, -1)]),
                    kb.at(b, &[kb.v(i1)]),
                    kb.at(b, &[kb.vp(i1, 1)]),
                ],
                &[(OpKind::Add, 2), (OpKind::Mul, 1)],
                vec![OpKind::Add, OpKind::Add, OpKind::Mul],
            );
        });
    });
    kb.finish()
}

/// 2-D 5-point Jacobi.
pub fn kernel_jacobi_2d(tsteps: u64, n: u64, dtype: DType) -> Kernel {
    let mut kb = KernelBuilder::new("jacobi-2d", dtype);
    let a = kb.array("A", &[n, n], ArrayDir::InOut);
    let b = kb.array("B", &[n, n], ArrayDir::InOut);

    let five_point = |kb: &mut KernelBuilder,
                      name: &str,
                      dst: crate::ir::ArrayId,
                      src: crate::ir::ArrayId,
                      i: crate::ir::LoopId,
                      j: crate::ir::LoopId| {
        kb.stmt_with_chain(
            name,
            vec![kb.at(dst, &[kb.v(i), kb.v(j)])],
            vec![
                kb.at(src, &[kb.v(i), kb.v(j)]),
                kb.at(src, &[kb.v(i), kb.vp(j, -1)]),
                kb.at(src, &[kb.v(i), kb.vp(j, 1)]),
                kb.at(src, &[kb.vp(i, 1), kb.v(j)]),
                kb.at(src, &[kb.vp(i, -1), kb.v(j)]),
            ],
            &[(OpKind::Add, 4), (OpKind::Mul, 1)],
            vec![OpKind::Add, OpKind::Add, OpKind::Mul],
        );
    };

    kb.for_const("t", 0, tsteps as i64, |kb, _t| {
        kb.for_const("i0", 1, n as i64 - 1, |kb, i0| {
            kb.for_const("j0", 1, n as i64 - 1, |kb, j0| {
                five_point(kb, "S0", b, a, i0, j0);
            });
        });
        kb.for_const("i1", 1, n as i64 - 1, |kb, i1| {
            kb.for_const("j1", 1, n as i64 - 1, |kb, j1| {
                five_point(kb, "S1", a, b, i1, j1);
            });
        });
    });
    kb.finish()
}

/// 3-D 7-point heat equation.
pub fn kernel_heat_3d(tsteps: u64, n: u64, dtype: DType) -> Kernel {
    let mut kb = KernelBuilder::new("heat-3d", dtype);
    let a = kb.array("A", &[n, n, n], ArrayDir::InOut);
    let b = kb.array("B", &[n, n, n], ArrayDir::InOut);

    let seven_point = |kb: &mut KernelBuilder,
                       name: &str,
                       dst: crate::ir::ArrayId,
                       src: crate::ir::ArrayId,
                       i: crate::ir::LoopId,
                       j: crate::ir::LoopId,
                       l: crate::ir::LoopId| {
        // dst = 0.125*(src[i+1]-2src+src[i-1]) + ... (3 axes) + src
        kb.stmt_with_chain(
            name,
            vec![kb.at(dst, &[kb.v(i), kb.v(j), kb.v(l)])],
            vec![
                kb.at(src, &[kb.vp(i, 1), kb.v(j), kb.v(l)]),
                kb.at(src, &[kb.v(i), kb.v(j), kb.v(l)]),
                kb.at(src, &[kb.vp(i, -1), kb.v(j), kb.v(l)]),
                kb.at(src, &[kb.v(i), kb.vp(j, 1), kb.v(l)]),
                kb.at(src, &[kb.v(i), kb.vp(j, -1), kb.v(l)]),
                kb.at(src, &[kb.v(i), kb.v(j), kb.vp(l, 1)]),
                kb.at(src, &[kb.v(i), kb.v(j), kb.vp(l, -1)]),
            ],
            &[(OpKind::Mul, 6), (OpKind::Add, 6), (OpKind::Sub, 3)],
            vec![OpKind::Mul, OpKind::Sub, OpKind::Mul, OpKind::Add, OpKind::Add],
        );
    };

    kb.for_const("t", 0, tsteps as i64, |kb, _t| {
        kb.for_const("i0", 1, n as i64 - 1, |kb, i0| {
            kb.for_const("j0", 1, n as i64 - 1, |kb, j0| {
                kb.for_const("k0", 1, n as i64 - 1, |kb, k0| {
                    seven_point(kb, "S0", b, a, i0, j0, k0);
                });
            });
        });
        kb.for_const("i1", 1, n as i64 - 1, |kb, i1| {
            kb.for_const("j1", 1, n as i64 - 1, |kb, j1| {
                kb.for_const("k1", 1, n as i64 - 1, |kb, k1| {
                    seven_point(kb, "S1", a, b, i1, j1, k1);
                });
            });
        });
    });
    kb.finish()
}

/// Gauss-Seidel 9-point sweep (fully order-dependent).
pub fn kernel_seidel_2d(tsteps: u64, n: u64, dtype: DType) -> Kernel {
    let mut kb = KernelBuilder::new("seidel-2d", dtype);
    let a = kb.array("A", &[n, n], ArrayDir::InOut);

    kb.for_const("t", 0, tsteps as i64, |kb, _t| {
        kb.for_const("i", 1, n as i64 - 1, |kb, i| {
            kb.for_const("j", 1, n as i64 - 1, |kb, j| {
                kb.stmt_with_chain(
                    "S0",
                    vec![kb.at(a, &[kb.v(i), kb.v(j)])],
                    vec![
                        kb.at(a, &[kb.vp(i, -1), kb.vp(j, -1)]),
                        kb.at(a, &[kb.vp(i, -1), kb.v(j)]),
                        kb.at(a, &[kb.vp(i, -1), kb.vp(j, 1)]),
                        kb.at(a, &[kb.v(i), kb.vp(j, -1)]),
                        kb.at(a, &[kb.v(i), kb.v(j)]),
                        kb.at(a, &[kb.v(i), kb.vp(j, 1)]),
                        kb.at(a, &[kb.vp(i, 1), kb.vp(j, -1)]),
                        kb.at(a, &[kb.vp(i, 1), kb.v(j)]),
                        kb.at(a, &[kb.vp(i, 1), kb.vp(j, 1)]),
                    ],
                    &[(OpKind::Add, 8), (OpKind::Div, 1)],
                    vec![
                        OpKind::Add,
                        OpKind::Add,
                        OpKind::Add,
                        OpKind::Add,
                        OpKind::Div,
                    ],
                );
            });
        });
    });
    kb.finish()
}

/// All-pairs shortest paths; `min` modeled as an add-compare (1 flop + the
/// comparator folds into the select, not a DSP op).
pub fn kernel_floyd_warshall(n: u64, dtype: DType) -> Kernel {
    let mut kb = KernelBuilder::new("floyd-warshall", dtype);
    let path = kb.array("path", &[n, n], ArrayDir::InOut);

    kb.for_const("k", 0, n as i64, |kb, k| {
        kb.for_const("i", 0, n as i64, |kb, i| {
            kb.for_const("j", 0, n as i64, |kb, j| {
                // path[i][j] = min(path[i][j], path[i][k] + path[k][j])
                kb.stmt_with_chain(
                    "S0",
                    vec![kb.at(path, &[kb.v(i), kb.v(j)])],
                    vec![
                        kb.at(path, &[kb.v(i), kb.v(j)]),
                        kb.at(path, &[kb.v(i), kb.v(k)]),
                        kb.at(path, &[kb.v(k), kb.v(j)]),
                    ],
                    &[(OpKind::Add, 2)],
                    vec![OpKind::Add, OpKind::Add],
                );
            });
        });
    });
    kb.finish()
}
