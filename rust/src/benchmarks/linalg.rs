//! Rectangular linear-algebra kernels: 2mm, 3mm, gemm, atax, bicg, mvt,
//! gemver, gesummv, doitgen.
//!
//! Each follows the PolyBench/C 4.2.1 reference source, with scalar
//! constants (`alpha`, `beta`) folded into the op multisets (they live in
//! registers, not arrays, and do not create dependences).

use crate::ir::{ArrayDir, DType, Kernel, KernelBuilder, OpKind};

/// `D = alpha*A*B*C + beta*D` (Listing 1).
pub fn kernel_2mm(ni: u64, nj: u64, nk: u64, nl: u64, dtype: DType) -> Kernel {
    let mut kb = KernelBuilder::new("2mm", dtype);
    let tmp = kb.array("tmp", &[ni, nj], ArrayDir::Temp);
    let a = kb.array("A", &[ni, nk], ArrayDir::In);
    let b = kb.array("B", &[nk, nj], ArrayDir::In);
    let c = kb.array("C", &[nj, nl], ArrayDir::In);
    let d = kb.array("D", &[ni, nl], ArrayDir::InOut);

    kb.for_const("i1", 0, ni as i64, |kb, i1| {
        kb.for_const("j1", 0, nj as i64, |kb, j1| {
            kb.stmt("S0", vec![kb.at(tmp, &[kb.v(i1), kb.v(j1)])], vec![], &[]);
            kb.for_const("k1", 0, nk as i64, |kb, k1| {
                // tmp[i1][j1] += alpha * A[i1][k1] * B[k1][j1]
                kb.stmt(
                    "S1",
                    vec![kb.at(tmp, &[kb.v(i1), kb.v(j1)])],
                    vec![
                        kb.at(tmp, &[kb.v(i1), kb.v(j1)]),
                        kb.at(a, &[kb.v(i1), kb.v(k1)]),
                        kb.at(b, &[kb.v(k1), kb.v(j1)]),
                    ],
                    &[(OpKind::Mul, 2), (OpKind::Add, 1)],
                );
            });
        });
    });
    kb.for_const("i2", 0, ni as i64, |kb, i2| {
        kb.for_const("j2", 0, nl as i64, |kb, j2| {
            // D[i2][j2] *= beta
            kb.stmt(
                "S2",
                vec![kb.at(d, &[kb.v(i2), kb.v(j2)])],
                vec![kb.at(d, &[kb.v(i2), kb.v(j2)])],
                &[(OpKind::Mul, 1)],
            );
            kb.for_const("k2", 0, nj as i64, |kb, k2| {
                // D[i2][j2] += tmp[i2][k2] * C[k2][j2]
                kb.stmt(
                    "S3",
                    vec![kb.at(d, &[kb.v(i2), kb.v(j2)])],
                    vec![
                        kb.at(d, &[kb.v(i2), kb.v(j2)]),
                        kb.at(tmp, &[kb.v(i2), kb.v(k2)]),
                        kb.at(c, &[kb.v(k2), kb.v(j2)]),
                    ],
                    &[(OpKind::Mul, 1), (OpKind::Add, 1)],
                );
            });
        });
    });
    kb.finish()
}

/// `G = (A*B) * (C*D)`.
pub fn kernel_3mm(ni: u64, nj: u64, nk: u64, nl: u64, nm: u64, dtype: DType) -> Kernel {
    let mut kb = KernelBuilder::new("3mm", dtype);
    let e = kb.array("E", &[ni, nj], ArrayDir::Temp);
    let a = kb.array("A", &[ni, nk], ArrayDir::In);
    let b = kb.array("B", &[nk, nj], ArrayDir::In);
    let f = kb.array("F", &[nj, nl], ArrayDir::Temp);
    let c = kb.array("C", &[nj, nm], ArrayDir::In);
    let d = kb.array("D", &[nm, nl], ArrayDir::In);
    let g = kb.array("G", &[ni, nl], ArrayDir::Out);

    let mm = |kb: &mut KernelBuilder,
              tag: u32,
              out: crate::ir::ArrayId,
              x: crate::ir::ArrayId,
              y: crate::ir::ArrayId,
              n0: u64,
              n1: u64,
              n2: u64| {
        kb.for_const(&format!("i{tag}"), 0, n0 as i64, |kb, i| {
            kb.for_const(&format!("j{tag}"), 0, n1 as i64, |kb, j| {
                kb.stmt(
                    &format!("S{}", tag * 2),
                    vec![kb.at(out, &[kb.v(i), kb.v(j)])],
                    vec![],
                    &[],
                );
                kb.for_const(&format!("k{tag}"), 0, n2 as i64, |kb, k| {
                    kb.stmt(
                        &format!("S{}", tag * 2 + 1),
                        vec![kb.at(out, &[kb.v(i), kb.v(j)])],
                        vec![
                            kb.at(out, &[kb.v(i), kb.v(j)]),
                            kb.at(x, &[kb.v(i), kb.v(k)]),
                            kb.at(y, &[kb.v(k), kb.v(j)]),
                        ],
                        &[(OpKind::Mul, 1), (OpKind::Add, 1)],
                    );
                });
            });
        });
    };
    mm(&mut kb, 0, e, a, b, ni, nj, nk);
    mm(&mut kb, 1, f, c, d, nj, nl, nm);
    mm(&mut kb, 2, g, e, f, ni, nl, nj);
    kb.finish()
}

/// `C = alpha*A*B + beta*C`.
pub fn kernel_gemm(ni: u64, nj: u64, nk: u64, dtype: DType) -> Kernel {
    let mut kb = KernelBuilder::new("gemm", dtype);
    let c = kb.array("C", &[ni, nj], ArrayDir::InOut);
    let a = kb.array("A", &[ni, nk], ArrayDir::In);
    let b = kb.array("B", &[nk, nj], ArrayDir::In);
    // PolyBench 4.2.1 structure: the beta-scaling j-loop is a sibling of
    // the k(j) accumulation nest → 4 loops (NL=4 in Table 5).
    kb.for_const("i", 0, ni as i64, |kb, i| {
        kb.for_const("j0", 0, nj as i64, |kb, j0| {
            kb.stmt(
                "S0",
                vec![kb.at(c, &[kb.v(i), kb.v(j0)])],
                vec![kb.at(c, &[kb.v(i), kb.v(j0)])],
                &[(OpKind::Mul, 1)],
            );
        });
        kb.for_const("k", 0, nk as i64, |kb, k| {
            kb.for_const("j1", 0, nj as i64, |kb, j1| {
                kb.stmt(
                    "S1",
                    vec![kb.at(c, &[kb.v(i), kb.v(j1)])],
                    vec![
                        kb.at(c, &[kb.v(i), kb.v(j1)]),
                        kb.at(a, &[kb.v(i), kb.v(k)]),
                        kb.at(b, &[kb.v(k), kb.v(j1)]),
                    ],
                    &[(OpKind::Mul, 2), (OpKind::Add, 1)],
                );
            });
        });
    });
    kb.finish()
}

/// `y = A^T (A x)` (Listing 10).
pub fn kernel_atax(m: u64, n: u64, dtype: DType) -> Kernel {
    let mut kb = KernelBuilder::new("atax", dtype);
    let a = kb.array("A", &[m, n], ArrayDir::In);
    let x = kb.array("x", &[n], ArrayDir::In);
    let y = kb.array("y", &[n], ArrayDir::Out);
    let tmp = kb.array("tmp", &[m], ArrayDir::Temp);

    kb.for_const("i0", 0, n as i64, |kb, i0| {
        kb.stmt("S0", vec![kb.at(y, &[kb.v(i0)])], vec![], &[]);
    });
    kb.for_const("i1", 0, m as i64, |kb, i1| {
        kb.stmt("S1", vec![kb.at(tmp, &[kb.v(i1)])], vec![], &[]);
        kb.for_const("j1", 0, n as i64, |kb, j1| {
            kb.stmt(
                "S2",
                vec![kb.at(tmp, &[kb.v(i1)])],
                vec![
                    kb.at(tmp, &[kb.v(i1)]),
                    kb.at(a, &[kb.v(i1), kb.v(j1)]),
                    kb.at(x, &[kb.v(j1)]),
                ],
                &[(OpKind::Mul, 1), (OpKind::Add, 1)],
            );
        });
        kb.for_const("j2", 0, n as i64, |kb, j2| {
            kb.stmt(
                "S3",
                vec![kb.at(y, &[kb.v(j2)])],
                vec![
                    kb.at(y, &[kb.v(j2)]),
                    kb.at(a, &[kb.v(i1), kb.v(j2)]),
                    kb.at(tmp, &[kb.v(i1)]),
                ],
                &[(OpKind::Mul, 1), (OpKind::Add, 1)],
            );
        });
    });
    kb.finish()
}

/// `s = r^T A ; q = A p` (Listing 5).
pub fn kernel_bicg(n: u64, m: u64, dtype: DType) -> Kernel {
    let mut kb = KernelBuilder::new("bicg", dtype);
    let a = kb.array("A", &[n, m], ArrayDir::In);
    let s = kb.array("s", &[m], ArrayDir::Out);
    let q = kb.array("q", &[n], ArrayDir::Out);
    let p = kb.array("p", &[m], ArrayDir::In);
    let r = kb.array("r", &[n], ArrayDir::In);

    kb.for_const("i0", 0, m as i64, |kb, i0| {
        kb.stmt("S0", vec![kb.at(s, &[kb.v(i0)])], vec![], &[]);
    });
    kb.for_const("i1", 0, n as i64, |kb, i1| {
        kb.stmt("S1", vec![kb.at(q, &[kb.v(i1)])], vec![], &[]);
        kb.for_const("j1", 0, m as i64, |kb, j1| {
            kb.stmt(
                "S2",
                vec![kb.at(s, &[kb.v(j1)])],
                vec![
                    kb.at(s, &[kb.v(j1)]),
                    kb.at(r, &[kb.v(i1)]),
                    kb.at(a, &[kb.v(i1), kb.v(j1)]),
                ],
                &[(OpKind::Mul, 1), (OpKind::Add, 1)],
            );
            kb.stmt(
                "S3",
                vec![kb.at(q, &[kb.v(i1)])],
                vec![
                    kb.at(q, &[kb.v(i1)]),
                    kb.at(a, &[kb.v(i1), kb.v(j1)]),
                    kb.at(p, &[kb.v(j1)]),
                ],
                &[(OpKind::Mul, 1), (OpKind::Add, 1)],
            );
        });
    });
    kb.finish()
}

/// `x1 = x1 + A y1 ; x2 = x2 + A^T y2`.
pub fn kernel_mvt(n: u64, dtype: DType) -> Kernel {
    let mut kb = KernelBuilder::new("mvt", dtype);
    let x1 = kb.array("x1", &[n], ArrayDir::InOut);
    let x2 = kb.array("x2", &[n], ArrayDir::InOut);
    let y1 = kb.array("y1", &[n], ArrayDir::In);
    let y2 = kb.array("y2", &[n], ArrayDir::In);
    let a = kb.array("A", &[n, n], ArrayDir::In);

    kb.for_const("i1", 0, n as i64, |kb, i1| {
        kb.for_const("j1", 0, n as i64, |kb, j1| {
            kb.stmt(
                "S0",
                vec![kb.at(x1, &[kb.v(i1)])],
                vec![
                    kb.at(x1, &[kb.v(i1)]),
                    kb.at(a, &[kb.v(i1), kb.v(j1)]),
                    kb.at(y1, &[kb.v(j1)]),
                ],
                &[(OpKind::Mul, 1), (OpKind::Add, 1)],
            );
        });
    });
    kb.for_const("i2", 0, n as i64, |kb, i2| {
        kb.for_const("j2", 0, n as i64, |kb, j2| {
            kb.stmt(
                "S1",
                vec![kb.at(x2, &[kb.v(i2)])],
                vec![
                    kb.at(x2, &[kb.v(i2)]),
                    kb.at(a, &[kb.v(j2), kb.v(i2)]),
                    kb.at(y2, &[kb.v(j2)]),
                ],
                &[(OpKind::Mul, 1), (OpKind::Add, 1)],
            );
        });
    });
    kb.finish()
}

/// BLAS gemver: rank-2 update + two matrix-vector products.
pub fn kernel_gemver(n: u64, dtype: DType) -> Kernel {
    let mut kb = KernelBuilder::new("gemver", dtype);
    let a = kb.array("A", &[n, n], ArrayDir::InOut);
    let u1 = kb.array("u1", &[n], ArrayDir::In);
    let v1 = kb.array("v1", &[n], ArrayDir::In);
    let u2 = kb.array("u2", &[n], ArrayDir::In);
    let v2 = kb.array("v2", &[n], ArrayDir::In);
    let x = kb.array("x", &[n], ArrayDir::Temp);
    let y = kb.array("y", &[n], ArrayDir::In);
    let z = kb.array("z", &[n], ArrayDir::In);
    let w = kb.array("w", &[n], ArrayDir::Out);

    kb.for_const("i1", 0, n as i64, |kb, i1| {
        kb.for_const("j1", 0, n as i64, |kb, j1| {
            // A[i][j] += u1[i]*v1[j] + u2[i]*v2[j]
            kb.stmt_with_chain(
                "S0",
                vec![kb.at(a, &[kb.v(i1), kb.v(j1)])],
                vec![
                    kb.at(a, &[kb.v(i1), kb.v(j1)]),
                    kb.at(u1, &[kb.v(i1)]),
                    kb.at(v1, &[kb.v(j1)]),
                    kb.at(u2, &[kb.v(i1)]),
                    kb.at(v2, &[kb.v(j1)]),
                ],
                &[(OpKind::Mul, 2), (OpKind::Add, 2)],
                vec![OpKind::Mul, OpKind::Add, OpKind::Add],
            );
        });
    });
    kb.for_const("i2", 0, n as i64, |kb, i2| {
        kb.for_const("j2", 0, n as i64, |kb, j2| {
            // x[i] += beta * A[j][i] * y[j]
            kb.stmt(
                "S1",
                vec![kb.at(x, &[kb.v(i2)])],
                vec![
                    kb.at(x, &[kb.v(i2)]),
                    kb.at(a, &[kb.v(j2), kb.v(i2)]),
                    kb.at(y, &[kb.v(j2)]),
                ],
                &[(OpKind::Mul, 2), (OpKind::Add, 1)],
            );
        });
    });
    kb.for_const("i3", 0, n as i64, |kb, i3| {
        kb.stmt(
            "S2",
            vec![kb.at(x, &[kb.v(i3)])],
            vec![kb.at(x, &[kb.v(i3)]), kb.at(z, &[kb.v(i3)])],
            &[(OpKind::Add, 1)],
        );
    });
    kb.for_const("i4", 0, n as i64, |kb, i4| {
        kb.for_const("j4", 0, n as i64, |kb, j4| {
            // w[i] += alpha * A[i][j] * x[j]
            kb.stmt(
                "S3",
                vec![kb.at(w, &[kb.v(i4)])],
                vec![
                    kb.at(w, &[kb.v(i4)]),
                    kb.at(a, &[kb.v(i4), kb.v(j4)]),
                    kb.at(x, &[kb.v(j4)]),
                ],
                &[(OpKind::Mul, 2), (OpKind::Add, 1)],
            );
        });
    });
    kb.finish()
}

/// `y = alpha*A*x + beta*B*x`.
pub fn kernel_gesummv(n: u64, dtype: DType) -> Kernel {
    let mut kb = KernelBuilder::new("gesummv", dtype);
    let a = kb.array("A", &[n, n], ArrayDir::In);
    let b = kb.array("B", &[n, n], ArrayDir::In);
    let x = kb.array("x", &[n], ArrayDir::In);
    let y = kb.array("y", &[n], ArrayDir::Out);
    let tmp = kb.array("tmp", &[n], ArrayDir::Temp);

    kb.for_const("i", 0, n as i64, |kb, i| {
        kb.stmt("S0", vec![kb.at(tmp, &[kb.v(i)])], vec![], &[]);
        kb.stmt("S1", vec![kb.at(y, &[kb.v(i)])], vec![], &[]);
        kb.for_const("j", 0, n as i64, |kb, j| {
            kb.stmt(
                "S2",
                vec![kb.at(tmp, &[kb.v(i)])],
                vec![
                    kb.at(tmp, &[kb.v(i)]),
                    kb.at(a, &[kb.v(i), kb.v(j)]),
                    kb.at(x, &[kb.v(j)]),
                ],
                &[(OpKind::Mul, 1), (OpKind::Add, 1)],
            );
            kb.stmt(
                "S3",
                vec![kb.at(y, &[kb.v(i)])],
                vec![
                    kb.at(y, &[kb.v(i)]),
                    kb.at(b, &[kb.v(i), kb.v(j)]),
                    kb.at(x, &[kb.v(j)]),
                ],
                &[(OpKind::Mul, 1), (OpKind::Add, 1)],
            );
        });
        // y[i] = alpha*tmp[i] + beta*y[i]
        kb.stmt_with_chain(
            "S4",
            vec![kb.at(y, &[kb.v(i)])],
            vec![kb.at(tmp, &[kb.v(i)]), kb.at(y, &[kb.v(i)])],
            &[(OpKind::Mul, 2), (OpKind::Add, 1)],
            vec![OpKind::Mul, OpKind::Add],
        );
    });
    kb.finish()
}

/// `A[r][q][p] = Σ_s A[r][q][s] * C4[s][p]` (multi-resolution analysis).
pub fn kernel_doitgen(nr: u64, nq: u64, np: u64, dtype: DType) -> Kernel {
    let mut kb = KernelBuilder::new("doitgen", dtype);
    let a = kb.array("A", &[nr, nq, np], ArrayDir::InOut);
    let c4 = kb.array("C4", &[np, np], ArrayDir::In);
    let sum = kb.array("sum", &[np], ArrayDir::Temp);

    kb.for_const("r", 0, nr as i64, |kb, r| {
        kb.for_const("q", 0, nq as i64, |kb, q| {
            kb.for_const("p", 0, np as i64, |kb, p| {
                kb.stmt("S0", vec![kb.at(sum, &[kb.v(p)])], vec![], &[]);
                kb.for_const("s", 0, np as i64, |kb, s| {
                    kb.stmt(
                        "S1",
                        vec![kb.at(sum, &[kb.v(p)])],
                        vec![
                            kb.at(sum, &[kb.v(p)]),
                            kb.at(a, &[kb.v(r), kb.v(q), kb.v(s)]),
                            kb.at(c4, &[kb.v(s), kb.v(p)]),
                        ],
                        &[(OpKind::Mul, 1), (OpKind::Add, 1)],
                    );
                });
            });
            kb.for_const("p2", 0, np as i64, |kb, p2| {
                kb.stmt(
                    "S2",
                    vec![kb.at(a, &[kb.v(r), kb.v(q), kb.v(p2)])],
                    vec![kb.at(sum, &[kb.v(p2)])],
                    &[],
                );
            });
        });
    });
    kb.finish()
}
