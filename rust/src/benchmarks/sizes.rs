//! Problem sizes (Table 8) and the kernel registry.

use super::*;
use crate::ir::{DType, Kernel};

/// Problem-size class of Table 8.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Size {
    /// Table 8 `S` (HARP-comparison scale).
    Small,
    /// Table 8 `M` (the paper's main scale).
    Medium,
    /// Table 8 `L`.
    Large,
}

impl Size {
    /// One-letter tag (`S`/`M`/`L`) used in filenames and tables.
    pub fn tag(self) -> &'static str {
        match self {
            Size::Small => "S",
            Size::Medium => "M",
            Size::Large => "L",
        }
    }
    /// Parse a size spec (`s`/`small`/`m`/… case-insensitive).
    pub fn parse(s: &str) -> Option<Size> {
        match s.to_ascii_lowercase().as_str() {
            "s" | "small" => Some(Size::Small),
            "m" | "medium" => Some(Size::Medium),
            "l" | "large" => Some(Size::Large),
            _ => None,
        }
    }
}

/// Build a benchmark kernel by name + size (Table 8 values).
pub fn build(name: &str, size: Size, dtype: DType) -> Option<Kernel> {
    use Size::*;
    let k = match name {
        "2mm" => match size {
            Large => kernel_2mm(800, 900, 1100, 1200, dtype),
            Medium => kernel_2mm(180, 190, 210, 220, dtype),
            Small => kernel_2mm(40, 50, 70, 80, dtype),
        },
        "3mm" => match size {
            Large => kernel_3mm(800, 900, 1000, 1100, 1200, dtype),
            Medium => kernel_3mm(180, 190, 200, 210, 220, dtype),
            Small => kernel_3mm(40, 50, 60, 70, 80, dtype),
        },
        "atax" => match size {
            Large => kernel_atax(1900, 2100, dtype),
            Medium => kernel_atax(390, 410, dtype),
            Small => kernel_atax(116, 124, dtype),
        },
        "bicg" => match size {
            Large => kernel_bicg(2100, 1900, dtype),
            Medium => kernel_bicg(410, 390, dtype),
            Small => kernel_bicg(124, 116, dtype),
        },
        "covariance" => match size {
            Large => kernel_covariance(1200, 1400, dtype),
            Medium => kernel_covariance(240, 260, dtype),
            Small => kernel_covariance(80, 100, dtype),
        },
        "cnn" => kernel_cnn(256, 256, 5, 5, 224, 224, dtype),
        "doitgen" => match size {
            Large => kernel_doitgen(150, 140, 160, dtype),
            Medium => kernel_doitgen(50, 40, 60, dtype),
            Small => kernel_doitgen(25, 20, 30, dtype),
        },
        "durbin" => match size {
            Large => kernel_durbin(2000, dtype),
            Medium => kernel_durbin(400, dtype),
            Small => kernel_durbin(120, dtype),
        },
        "floyd-warshall" => match size {
            Large => kernel_floyd_warshall(2800, dtype),
            Medium => kernel_floyd_warshall(500, dtype),
            Small => kernel_floyd_warshall(180, dtype),
        },
        "gemm" => match size {
            Large => kernel_gemm(1000, 1100, 1200, dtype),
            Medium => kernel_gemm(200, 220, 240, dtype),
            Small => kernel_gemm(60, 70, 80, dtype),
        },
        "gemver" => match size {
            Large => kernel_gemver(2000, dtype),
            Medium => kernel_gemver(400, dtype),
            Small => kernel_gemver(120, dtype),
        },
        "gesummv" => match size {
            Large => kernel_gesummv(1300, dtype),
            Medium => kernel_gesummv(250, dtype),
            Small => kernel_gesummv(90, dtype),
        },
        "gramschmidt" => match size {
            Large => kernel_gramschmidt(1000, 1200, dtype),
            Medium => kernel_gramschmidt(200, 240, dtype),
            Small => kernel_gramschmidt(60, 80, dtype),
        },
        "heat-3d" => match size {
            Large => kernel_heat_3d(500, 120, dtype),
            Medium => kernel_heat_3d(100, 40, dtype),
            Small => kernel_heat_3d(40, 20, dtype),
        },
        "jacobi-1d" => match size {
            Large => kernel_jacobi_1d(500, 2000, dtype),
            Medium => kernel_jacobi_1d(100, 400, dtype),
            Small => kernel_jacobi_1d(40, 120, dtype),
        },
        "jacobi-2d" => match size {
            Large => kernel_jacobi_2d(500, 1300, dtype),
            Medium => kernel_jacobi_2d(100, 250, dtype),
            Small => kernel_jacobi_2d(40, 90, dtype),
        },
        "lu" => match size {
            Large => kernel_lu(2000, dtype),
            Medium => kernel_lu(400, dtype),
            Small => kernel_lu(120, dtype),
        },
        "mvt" => match size {
            Large => kernel_mvt(2000, dtype),
            Medium => kernel_mvt(400, dtype),
            Small => kernel_mvt(120, dtype),
        },
        "seidel-2d" => match size {
            Large => kernel_seidel_2d(500, 2000, dtype),
            Medium => kernel_seidel_2d(100, 400, dtype),
            Small => kernel_seidel_2d(40, 120, dtype),
        },
        "symm" => match size {
            Large => kernel_symm(1000, 1200, dtype),
            Medium => kernel_symm(200, 240, dtype),
            Small => kernel_symm(60, 80, dtype),
        },
        "syr2k" => match size {
            Large => kernel_syr2k(1200, 1000, dtype),
            Medium => kernel_syr2k(240, 200, dtype),
            Small => kernel_syr2k(80, 60, dtype),
        },
        "syrk" => match size {
            Large => kernel_syrk(1200, 1000, dtype),
            Medium => kernel_syrk(240, 200, dtype),
            Small => kernel_syrk(80, 60, dtype),
        },
        "trisolv" => match size {
            Large => kernel_trisolv(2000, dtype),
            Medium => kernel_trisolv(400, dtype),
            Small => kernel_trisolv(120, dtype),
        },
        "trmm" => match size {
            Large => kernel_trmm(1000, 1200, dtype),
            Medium => kernel_trmm(200, 240, dtype),
            Small => kernel_trmm(60, 80, dtype),
        },
        _ => return None,
    };
    Some(k)
}
