//! Convolutional layer kernel (Section 7.1: J,I = 256, P,Q = 5,
//! H,W = 224) — the one non-PolyBench benchmark of Table 5.

use crate::ir::{ArrayDir, DType, Kernel, KernelBuilder, OpKind};

/// Direct convolution: `out[j][h][w] += in[i][h+p][w+q] * W[j][i][p][q]`.
pub fn kernel_cnn(j_out: u64, i_in: u64, p: u64, q: u64, h: u64, w: u64, dtype: DType) -> Kernel {
    let mut kb = KernelBuilder::new("cnn", dtype);
    let input = kb.array("in", &[i_in, h + p - 1, w + q - 1], ArrayDir::In);
    let weight = kb.array("weight", &[j_out, i_in, p, q], ArrayDir::In);
    let out = kb.array("out", &[j_out, h, w], ArrayDir::Out);

    kb.for_const("j", 0, j_out as i64, |kb, j| {
        kb.for_const("h", 0, h as i64, |kb, hh| {
            kb.for_const("w", 0, w as i64, |kb, ww| {
                kb.stmt(
                    "S0",
                    vec![kb.at(out, &[kb.v(j), kb.v(hh), kb.v(ww)])],
                    vec![],
                    &[],
                );
                kb.for_const("i", 0, i_in as i64, |kb, i| {
                    kb.for_const("p", 0, p as i64, |kb, pp| {
                        kb.for_const("q", 0, q as i64, |kb, qq| {
                            kb.stmt(
                                "S1",
                                vec![kb.at(out, &[kb.v(j), kb.v(hh), kb.v(ww)])],
                                vec![
                                    kb.at(out, &[kb.v(j), kb.v(hh), kb.v(ww)]),
                                    kb.at(
                                        input,
                                        &[kb.v(i), kb.sum(&kb.v(hh), &kb.v(pp)), kb.sum(&kb.v(ww), &kb.v(qq))],
                                    ),
                                    kb.at(weight, &[kb.v(j), kb.v(i), kb.v(pp), kb.v(qq)]),
                                ],
                                &[(OpKind::Mul, 1), (OpKind::Add, 1)],
                            );
                        });
                    });
                });
            });
        });
    });
    kb.finish()
}
