//! AutoDSE (FPGA'21) reimplementation — the general-purpose, model-free
//! baseline of Tables 1–5.
//!
//! Reproduced behaviours (Sections 2.2–2.3):
//!
//! * treats Merlin/Vitis as a **black box**: candidate moves are generated
//!   without dependence knowledge, so illegal parallelizations are only
//!   discovered when Merlin refuses them (the `ER` column);
//! * **bottleneck-driven**: each round targets the loop nest with the
//!   highest measured latency share;
//! * **incremental**: starts pragma-free and grows factors, favouring
//!   powers of two for innermost unrolls;
//! * **over-parallelization**: workers also probe pipelining outer loops
//!   (implying full unrolling underneath), producing HLS timeouts (`DT`);
//! * 4 search partitions × 2 threads (8 parallel synthesis slots), 180-min
//!   HLS timeout, ~600-min DSE budget "not always respected" — the current
//!   wave always completes.

use crate::dse::SimClock;
use crate::hls::{Device, HlsOracle, HlsReport, SynthOptions};
use crate::ir::{Kernel, LoopId};
use crate::model;
use crate::poly::Analysis;
use crate::pragma::{Design, LoopPragma};
use crate::util::divisors;
use crate::util::rng::{hash64, Rng};
use std::collections::BTreeSet;

/// AutoDSE campaign parameters (Section 7.2's setup).
#[derive(Clone, Debug)]
pub struct AutoDseConfig {
    /// Parallel synthesis workers (paper: 4 kernels x 2 threads).
    pub workers: usize,
    /// Per-synthesis HLS timeout, minutes.
    pub hls_timeout_min: f64,
    /// Overall exploration budget, minutes.
    pub dse_budget_min: f64,
    /// Candidate moves evaluated per round (one per worker-thread).
    pub wave: usize,
}

impl Default for AutoDseConfig {
    fn default() -> Self {
        AutoDseConfig {
            workers: 8,
            hls_timeout_min: 180.0,
            dse_budget_min: 1200.0,
            wave: 8,
        }
    }
}

/// What one AutoDSE run produced (feeds Tables 1/3/5).
#[derive(Clone, Debug)]
pub struct AutoDseOutcome {
    /// Kernel the exploration ran on.
    pub kernel: String,
    /// Best valid design and its measured latency, cycles.
    pub best: Option<(Design, f64)>,
    /// Best measured throughput.
    pub best_gflops: f64,
    /// DSP utilization % of the best design.
    pub best_dsp_pct: f64,
    /// Simulated exploration wall time, minutes.
    pub dse_minutes: f64,
    /// DE: total designs sent to Merlin/HLS.
    pub designs_explored: u32,
    /// Synthesized to completion.
    pub designs_synthesized: u32,
    /// DT: HLS timeouts.
    pub designs_timeout: u32,
    /// ER: early-rejected by Merlin.
    pub early_rejected: u32,
}

/// One candidate move: a design plus a human-readable tag.
struct Move {
    design: Design,
    #[allow(dead_code)]
    tag: String,
}

/// Run AutoDSE on one kernel.
pub fn run_autodse(
    k: &Kernel,
    a: &Analysis,
    dev: &Device,
    cfg: &AutoDseConfig,
) -> AutoDseOutcome {
    let oracle = HlsOracle {
        device: dev.clone(),
        options: SynthOptions {
            hls_timeout_min: cfg.hls_timeout_min,
        },
    };
    let mut clock = SimClock::new(cfg.workers);
    let mut rng = Rng::new(hash64(&format!("autodse/{}/{}", k.name, k.dtype.name())));
    let mut seen: BTreeSet<String> = BTreeSet::new();

    let mut current = Design::empty(k);
    let mut best: Option<(Design, f64)> = None;
    let mut best_report: Option<HlsReport> = None;
    let mut de = 0u32;
    let mut synthd = 0u32;
    let mut dt = 0u32;
    let mut er = 0u32;
    let mut min_lat = f64::INFINITY;

    // initial pragma-free evaluation
    let rep0 = oracle.synth(k, a, &current);
    clock.submit(rep0.synth_minutes);
    de += 1;
    synthd += 1;
    seen.insert(current.fingerprint());
    if rep0.valid {
        min_lat = rep0.cycles;
        best = Some((current.clone(), rep0.cycles));
        best_report = Some(rep0);
    }

    let mut stale_rounds = 0;
    while clock.makespan() < cfg.dse_budget_min && stale_rounds < 10 {
        // ---- bottleneck selection ----------------------------------------
        let nb = model::nest_latencies(k, a, dev, &current);
        let mut nest_order: Vec<usize> = (0..nb.per_nest.len()).collect();
        nest_order.sort_by(|&x, &y| nb.per_nest[y].partial_cmp(&nb.per_nest[x]).unwrap());

        // ---- move generation (black-box: no dependence filtering) --------
        // bottleneck-first: only the hottest nest is mutated; other nests
        // are visited only once it yields nothing new — the paper's "mainly
        // optimize a single loop body" failure mode
        let mut moves: Vec<Move> = Vec::new();
        for &ni in &nest_order {
            let root = k.nest_roots()[ni];
            gen_moves(k, a, &current, root, &mut rng, &mut moves);
            moves.retain(|m| !seen.contains(&m.design.fingerprint()));
            if !moves.is_empty() {
                break;
            }
        }
        if moves.is_empty() {
            // diversification: random perturbations of the incumbent —
            // AutoDSE keeps consuming its budget rather than stopping
            // (the paper's DSE timeout is "not always respected")
            for _ in 0..50 {
                if moves.len() >= cfg.wave {
                    break;
                }
                let li = rng.range(0, k.n_loops() as u64) as usize;
                let tc = &a.tcs[li];
                if !tc.is_constant() || tc.max <= 1 {
                    continue;
                }
                let mut d = current.clone();
                if rng.chance(0.5) {
                    let divs = divisors(tc.max);
                    d.pragmas[li].uf = *rng.choose(&divs);
                } else {
                    d.pragmas[li].pipeline = !d.pragmas[li].pipeline;
                }
                if !seen.contains(&d.fingerprint()) {
                    moves.push(Move {
                        design: d,
                        tag: format!("diversify L{li}"),
                    });
                }
            }
        }
        for m in &moves {
            seen.insert(m.design.fingerprint());
        }
        moves.truncate(cfg.wave);
        if moves.is_empty() {
            stale_rounds += 1;
            continue;
        }

        // ---- evaluate the wave --------------------------------------------
        let mut improved = false;
        for m in &moves {
            let rep = oracle.synth(k, a, &m.design);
            clock.submit(rep.synth_minutes);
            de += 1;
            if rep.early_reject {
                er += 1;
                continue;
            }
            if rep.timeout {
                dt += 1;
                continue;
            }
            if !rep.pragmas_applied {
                // AutoDSE prunes designs where Merlin did not apply the
                // pragmas as requested (Section 2.3 "Exploration of the
                // space")
                er += 1;
                continue;
            }
            synthd += 1;
            if rep.valid && rep.cycles < min_lat {
                min_lat = rep.cycles;
                best = Some((m.design.clone(), rep.cycles));
                best_report = Some(rep);
                current = m.design.clone();
                improved = true;
            }
        }
        if !improved {
            stale_rounds += 1;
        } else {
            stale_rounds = 0;
        }
    }

    let best_gflops = best
        .as_ref()
        .map(|(_, c)| a.gflops(*c, dev.freq_hz))
        .unwrap_or(0.0);
    let best_dsp_pct = best_report
        .map(|r| r.dsp as f64 / dev.dsp_total as f64 * 100.0)
        .unwrap_or(0.0);
    AutoDseOutcome {
        kernel: k.name.clone(),
        best,
        best_gflops,
        best_dsp_pct,
        dse_minutes: clock.makespan(),
        designs_explored: de,
        designs_synthesized: synthd,
        designs_timeout: dt,
        early_rejected: er,
    }
}

/// Generate incremental moves on one nest — mirrors the published search
/// operators: grow innermost unrolls (powers of two first), toggle
/// pipelining at every level (including outer loops), coarse factors on
/// outer loops, all **without** consulting the dependence analysis.
fn gen_moves(
    k: &Kernel,
    a: &Analysis,
    current: &Design,
    root: LoopId,
    rng: &mut Rng,
    moves: &mut Vec<Move>,
) {
    let loops = k.nest_loops(root);
    for &l in loops.iter().rev() {
        let tc = a.tc(l);
        if !tc.is_constant() || tc.max <= 1 {
            continue;
        }
        let cur = current.get(l);
        // next unroll factors: powers of two among divisors first, then the
        // remaining divisors ("it favors the unroll factors to the power of
        // two ... does not try the other unroll factors first")
        let divs = divisors(tc.max);
        let mut pow2: Vec<u64> = divs
            .iter()
            .copied()
            .filter(|d| d.is_power_of_two() && *d > cur.uf)
            .collect();
        // strong pow2 preference (Section 2.3): non-pow2 divisors are only
        // sampled occasionally, which starves kernels whose trip counts
        // have few pow2 divisors (2mm's 180/190/210/220)
        if rng.chance(0.25) {
            let mut rest: Vec<u64> = divs
                .iter()
                .copied()
                .filter(|d| !d.is_power_of_two() && *d > cur.uf)
                .collect();
            rng.shuffle(&mut rest);
            pow2.extend(rest.into_iter().take(1));
        }
        for uf in pow2.into_iter().take(3) {
            let d = current.clone().with(
                l,
                LoopPragma {
                    uf,
                    tile: cur.tile,
                    pipeline: cur.pipeline,
                },
            );
            moves.push(Move {
                design: d,
                tag: format!("uf {l}={uf}"),
            });
        }
        // pipeline toggle (outer-loop pipelining is the over-parallelization
        // failure mode: everything under gets fully unrolled)
        if !cur.pipeline {
            let mut d = current.clone();
            d.get_mut(l).pipeline = true;
            if !k.loop_meta(l).innermost {
                // pipelining l fully unrolls below (black-box request)
                for &u in &loops {
                    if k.is_under(u, l) {
                        let utc = a.tc(u);
                        if utc.is_constant() {
                            d.get_mut(u).uf = utc.max.max(1);
                        }
                    }
                }
            }
            moves.push(Move {
                design: d,
                tag: format!("pipe {l}"),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{self, Size};
    use crate::ir::DType;

    fn run(name: &str, size: Size) -> AutoDseOutcome {
        let k = benchmarks::build(name, size, DType::F32).unwrap();
        let a = Analysis::new(&k);
        run_autodse(&k, &a, &Device::u200(), &AutoDseConfig::default())
    }

    #[test]
    fn improves_over_original() {
        let k = benchmarks::build("gemm", Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let oracle = HlsOracle::new(dev.clone());
        let orig = oracle.synth(&k, &a, &Design::empty(&k)).gflops(&a, &dev);
        let out = run("gemm", Size::Small);
        assert!(out.best_gflops > orig, "{} !> {orig}", out.best_gflops);
    }

    #[test]
    fn produces_early_rejects_and_explores_many() {
        let out = run("atax", Size::Medium);
        assert!(out.designs_explored > 20, "DE {}", out.designs_explored);
        assert!(out.early_rejected > 0, "ER {}", out.early_rejected);
    }

    #[test]
    fn deterministic() {
        let a1 = run("bicg", Size::Small);
        let a2 = run("bicg", Size::Small);
        assert_eq!(a1.designs_explored, a2.designs_explored);
        assert_eq!(a1.best_gflops, a2.best_gflops);
        assert_eq!(a1.dse_minutes, a2.dse_minutes);
    }

    #[test]
    fn spends_substantial_dse_time() {
        let out = run("2mm", Size::Medium);
        assert!(
            out.dse_minutes > 100.0,
            "AutoDSE should burn serious budget, got {}",
            out.dse_minutes
        );
    }
}
