//! Comparison baselines re-implemented from their publications:
//!
//! * [`autodse`] — AutoDSE (Sohrabizadeh et al., FPGA'21): model-free,
//!   bottleneck-driven incremental exploration treating the compiler as a
//!   black box (Sections 2.2–2.3 describe the behaviours reproduced here).
//! * [`harp`] — HARP (Sohrabizadeh et al., ICCAD'23): surrogate-guided
//!   near-exhaustive search (~75k configs/hour) with top-10 synthesis
//!   (Section 7.2.2 / 7.4).

pub mod autodse;
pub mod harp;

pub use autodse::{run_autodse, AutoDseConfig, AutoDseOutcome};
pub use harp::{run_harp, HarpConfig, HarpOutcome};
