//! HARP (ICCAD'23) reimplementation — the learned-surrogate baseline of
//! Table 9 / Fig 4.
//!
//! HARP trains a GNN on (pragma configuration → HLS report) pairs and
//! sweeps the space with millisecond-class predictions, then synthesizes
//! the top-10 candidates. What the comparison in Section 7.4 exercises is
//! the *shape* of that pipeline:
//!
//! * near-exhaustive bottom-up traversal (~75k configurations/hour);
//! * a fast surrogate with realistic error (HARP is "trained with precise
//!   knowledge of the kernel and problem size", so its error is modest but
//!   not zero — we model a deterministic ±35% multiplicative field over
//!   the design space, seeded per kernel);
//! * top-10 synthesis with the usual 3-hour timeout; best valid wins.

use crate::dse::SimClock;
use crate::hls::{Device, HlsOracle, HlsReport, SynthOptions};
use crate::ir::{Kernel, LoopId};
use crate::model;
use crate::poly::Analysis;
use crate::pragma::{space, Design, Space};
use crate::util::rng::{hash64, Rng};
use std::collections::BTreeSet;

/// HARP campaign parameters (Section 7.4's setup).
#[derive(Clone, Debug)]
pub struct HarpConfig {
    /// Surrogate sweep budget (Section 7.2.2: one hour).
    pub sweep_minutes: f64,
    /// Configurations the surrogate can score in the budget.
    pub sweep_configs: u64,
    /// Surrogate-ranked designs sent to real synthesis.
    pub top_k: usize,
    /// Parallel synthesis workers.
    pub workers: usize,
    /// Per-synthesis HLS timeout, minutes.
    pub hls_timeout_min: f64,
}

impl Default for HarpConfig {
    fn default() -> Self {
        HarpConfig {
            sweep_minutes: 60.0,
            sweep_configs: 75_000,
            top_k: 10,
            workers: 8,
            hls_timeout_min: 180.0,
        }
    }
}

/// What one HARP run produced (feeds Table 9 / Fig 4).
#[derive(Clone, Debug)]
pub struct HarpOutcome {
    /// Kernel the exploration ran on.
    pub kernel: String,
    /// Best valid design and its measured latency, cycles.
    pub best: Option<(Design, f64)>,
    /// Best measured throughput.
    pub best_gflops: f64,
    /// Simulated exploration wall time, minutes.
    pub dse_minutes: f64,
    /// Configurations scored by the surrogate sweep.
    pub configs_scored: u64,
    /// Designs sent through real synthesis.
    pub designs_synthesized: u32,
    /// Synthesis timeouts among them.
    pub designs_timeout: u32,
}

/// The surrogate: model latency modulated by a deterministic per-design
/// error field (mimicking a well-fine-tuned GNN's residuals).
fn surrogate(k: &Kernel, a: &Analysis, dev: &Device, d: &Design) -> f64 {
    let base = model::evaluate(k, a, dev, d).total_cycles;
    let h = hash64(&format!("harp-err/{}/{}", k.name, d.fingerprint()));
    let err = 0.75 + (h % 10_000) as f64 / 10_000.0 * 0.7; // 0.75 .. 1.45
    base * err
}

/// Run HARP on one kernel.
pub fn run_harp(k: &Kernel, a: &Analysis, dev: &Device, cfg: &HarpConfig) -> HarpOutcome {
    let oracle = HlsOracle {
        device: dev.clone(),
        options: SynthOptions {
            hls_timeout_min: cfg.hls_timeout_min,
        },
    };
    let mut rng = Rng::new(hash64(&format!("harp/{}/{}", k.name, k.dtype.name())));
    let space = Space::new(k, a);
    let mut seen: BTreeSet<String> = BTreeSet::new();

    // ---- surrogate sweep ---------------------------------------------------
    // bottom-up traversal: sample pipeline configs × UF assignments with a
    // bias toward growing factors (HARP walks the space incrementally)
    let mut scored: Vec<(Design, f64)> = Vec::new();
    let mut configs_scored = 0u64;
    let budget = cfg.sweep_configs;
    while configs_scored < budget {
        let cfg_idx = rng.range(0, space.pipeline_configs.len() as u64) as usize;
        let pcfg = &space.pipeline_configs[cfg_idx];
        // random UF assignment, pow2-biased, growing magnitudes over time
        let progress = configs_scored as f64 / budget as f64;
        let drawn: Vec<u64> = (0..k.n_loops())
            .map(|i| {
                let menu = space.ufs(LoopId(i as u32), a, dev.max_array_partition);
                if menu.len() <= 1 {
                    return 1;
                }
                // early sweep: small factors; late sweep: large
                let hi = (((menu.len() as f64) * (0.3 + 0.7 * progress)).ceil() as u64)
                    .clamp(1, menu.len() as u64);
                menu[rng.range(0, hi) as usize]
            })
            .collect();
        let d = space::materialize(k, a, pcfg, &|l: LoopId| drawn[l.0 as usize], &|_| 1);
        configs_scored += 1;
        if !seen.insert(d.fingerprint()) {
            continue;
        }
        // HARP's classifier drops clearly-invalid points (it is trained on
        // this very kernel, so it has learned which pragmas Merlin refuses
        // — Section 7.4); screen with the same legality predicate
        let part = d.max_partitioning(k);
        if part > dev.max_array_partition {
            continue;
        }
        if crate::merlin::apply(k, a, dev, &d).early_reject {
            continue;
        }
        let s = surrogate(k, a, dev, &d);
        scored.push((d, s));
        // keep the candidate list bounded
        if scored.len() > 4 * cfg.top_k {
            scored.sort_by(|x, y| x.1.partial_cmp(&y.1).unwrap());
            scored.truncate(2 * cfg.top_k);
        }
    }
    scored.sort_by(|x, y| x.1.partial_cmp(&y.1).unwrap());
    scored.truncate(cfg.top_k);

    // ---- synthesize the top-k ----------------------------------------------
    let mut clock = SimClock::new(cfg.workers);
    clock.serial(cfg.sweep_minutes);
    let mut best: Option<(Design, f64)> = None;
    let mut best_rep: Option<HlsReport> = None;
    let mut synthd = 0;
    let mut dt = 0;
    for (d, _) in &scored {
        let rep = oracle.synth(k, a, d);
        clock.submit(rep.synth_minutes);
        synthd += 1;
        if rep.timeout {
            dt += 1;
            continue;
        }
        if rep.valid && best.as_ref().map(|b| rep.cycles < b.1).unwrap_or(true) {
            best = Some((d.clone(), rep.cycles));
            best_rep = Some(rep);
        }
    }
    let _ = best_rep;

    let best_gflops = best
        .as_ref()
        .map(|(_, c)| a.gflops(*c, dev.freq_hz))
        .unwrap_or(0.0);
    HarpOutcome {
        kernel: k.name.clone(),
        best,
        best_gflops,
        dse_minutes: clock.makespan(),
        configs_scored,
        designs_synthesized: synthd,
        designs_timeout: dt,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{self, Size};
    use crate::ir::DType;

    fn run(name: &str, size: Size, dtype: DType) -> HarpOutcome {
        let k = benchmarks::build(name, size, dtype).unwrap();
        let a = Analysis::new(&k);
        let cfg = HarpConfig {
            sweep_configs: 5_000, // keep unit tests fast
            ..HarpConfig::default()
        };
        run_harp(&k, &a, &Device::u200(), &cfg)
    }

    #[test]
    fn finds_valid_design() {
        let out = run("gemm", Size::Small, DType::F64);
        assert!(out.best.is_some());
        assert!(out.best_gflops > 0.0);
        assert!(out.designs_synthesized <= 10);
        assert!(out.dse_minutes >= 60.0);
    }

    #[test]
    fn deterministic() {
        let o1 = run("bicg", Size::Small, DType::F64);
        let o2 = run("bicg", Size::Small, DType::F64);
        assert_eq!(o1.best_gflops, o2.best_gflops);
        assert_eq!(o1.configs_scored, o2.configs_scored);
    }

    #[test]
    fn improves_over_original() {
        let k = benchmarks::build("mvt", Size::Small, DType::F64).unwrap();
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let orig = HlsOracle::new(dev.clone())
            .synth(&k, &a, &Design::empty(&k))
            .gflops(&a, &dev);
        let out = run("mvt", Size::Small, DType::F64);
        assert!(out.best_gflops > orig, "{} !> {orig}", out.best_gflops);
    }
}
