//! Kernel frontend: the textual `.knl` loop-nest DSL and the seeded
//! random-kernel generator.
//!
//! The paper claims generality over *regular loop-based programs*, yet
//! a fixed 25-kernel corpus can only ever exercise a fixed slice of the
//! model/NLP/DSE stack. This module opens the input side:
//!
//! * [`parse_kernel`] / [`parse_file`] — a textual DSL (lexer +
//!   recursive-descent parser, zero new dependencies) covering affine
//!   bounds, typed arrays with transfer directions, and statements with
//!   affine accesses + op multisets, lowering through
//!   [`crate::ir::KernelBuilder`] into a finalized [`Kernel`] with
//!   precise source-span diagnostics ([`ParseError`]);
//! * [`pretty::print`] — the inverse emitter; `parse(print(k)) ≡ k`
//!   structurally for the whole benchmark corpus
//!   (`tests/frontend_roundtrip.rs`), so the DSL provably spans the
//!   kernels the paper evaluates;
//! * [`generate`] — a seeded always-regular random-kernel generator
//!   (depth/width/nest/array knobs, [`GenConfig`]) that turns the three
//!   redundant evaluators and the jobs=1/jobs=N solver paths into
//!   mutual oracles over *unbounded* inputs
//!   (`tests/property_frontend_fuzz.rs`, `nlp-dse gen`).
//!
//! Grammar and invariants: DESIGN.md §9.

pub mod ast;
pub mod diag;
pub mod generate;
pub mod lexer;
pub mod parser;
pub mod pretty;

pub use diag::{ParseError, Span};
pub use generate::{generate, GenConfig};
pub use parser::parse_kernel;

use crate::ir::Kernel;
use anyhow::Context;

/// Parse a `.knl` file from disk. Diagnostics carry the file path.
///
/// Parse failures convert the [`ParseError`] into the `anyhow` chain
/// **by value** (never `Debug`-formatted), so the rendered line/column
/// header and caret-underlined source snippet survive verbatim all the
/// way to the CLI error surface — `cli::tests` asserts the snippet on
/// the `--kernel-file` paths.
pub fn parse_file(path: &str) -> anyhow::Result<Kernel> {
    let src = std::fs::read_to_string(path)
        .with_context(|| format!("reading kernel file `{path}`"))?;
    parse_kernel(&src, path)
        .map_err(anyhow::Error::from)
        .with_context(|| format!("parsing kernel file `{path}`"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_file_reports_missing_path() {
        let err = parse_file("/definitely/not/here.knl").unwrap_err();
        assert!(format!("{err:#}").contains("reading kernel file"));
    }

    #[test]
    fn parse_file_preserves_the_caret_snippet_through_anyhow() {
        let path = std::env::temp_dir().join("nlp_dse_frontend_diag_test.knl");
        std::fs::write(
            &path,
            "kernel \"bad\" f32\narray a[4] out\nfor i in 0 .. 4 {\n  stmt s writes a[zz];\n}\n",
        )
        .unwrap();
        let err = parse_file(path.to_str().unwrap()).unwrap_err();
        let msg = format!("{err:#}");
        // the context names the file AND the rendered diagnostic keeps
        // its line/column header + caret underline
        assert!(msg.contains("parsing kernel file"), "{msg}");
        assert!(msg.contains(":4:"), "{msg}");
        assert!(msg.contains("stmt s writes a[zz];"), "{msg}");
        assert!(msg.contains('^'), "{msg}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn parse_file_roundtrips_via_disk() {
        let k = generate(&GenConfig::with_seed(3));
        let path = std::env::temp_dir().join("nlp_dse_frontend_test.knl");
        std::fs::write(&path, pretty::print(&k)).unwrap();
        let k2 = parse_file(path.to_str().unwrap()).unwrap();
        assert_eq!(k.structural_diff(&k2), None);
        let _ = std::fs::remove_file(&path);
    }
}
