//! Recursive-descent parser for the `.knl` loop-nest DSL, plus the
//! lowering pass that resolves names and drives [`KernelBuilder`].
//!
//! Grammar (see DESIGN.md §9 for the full EBNF):
//!
//! ```text
//! kernel  := "kernel" (STRING | IDENT) ("f32" | "f64") item*
//! item    := "array" IDENT ("[" INT "]")+ dir
//!          | loop
//! dir     := "in" | "out" | "inout" | "temp"
//! loop    := "for" IDENT "in" affine ".." affine "{" (loop | stmt)* "}"
//! stmt    := "stmt" IDENT ["writes" accs] ["reads" accs]
//!            ["ops" opcounts] ["chain" opchain] ";"
//! accs    := access ("," access)*
//! access  := IDENT ("[" affine "]")+
//! opcounts:= opcount ("," opcount)*      opcount := [INT "*"] op
//! opchain := op ("," op)*                op      := "add"|"sub"|"mul"|"div"
//! affine  := ["+"|"-"] term (("+"|"-") term)*
//! term    := INT | IDENT | INT "*" IDENT
//! ```
//!
//! Keywords are contextual (the CNN kernel has arrays named `in` and
//! `out`); every parse or lowering failure is a [`ParseError`] carrying
//! the offending source span.
//!
//! Lowering enforces the semantic rules the rest of the stack assumes:
//! iterators and loop bounds resolve only against *enclosing* loops, a
//! loop may not shadow an enclosing loop's name (iterator references
//! would become ambiguous and pretty-print → parse would not round-trip),
//! loop bodies are non-empty, constant bounds are non-degenerate, every
//! statement writes at least one access, and access arity matches the
//! array declaration.

use super::ast::{AccessAst, AffAst, ArrayAst, KernelAst, LoopAst, NodeAst, StmtAst};
use super::diag::{ParseError, Span};
use super::lexer::{lex, Tok, Token};
use crate::ir::{Access, AffineExpr, ArrayDir, ArrayId, DType, Kernel, KernelBuilder, LoopId, OpKind};

/// Ceiling on one statement's **total** per-iteration op count (summed
/// over the `ops` entries): counts expand into per-op chain vectors
/// downstream ([`crate::ir::Stmt::default_chain`]), so untrusted `.knl`
/// input must not amplify a few bytes into huge allocations — neither
/// via one literal nor by repeating entries. Far above any real kernel
/// (the corpus maximum is 3 ops per statement).
pub const MAX_OP_COUNT: u64 = 4096;

/// Magnitude cap on affine literals (constants and coefficients). With
/// at most [`MAX_AFFINE_TERMS`] terms per expression and iterator value
/// ranges capped at `MAX_RANGE` (checked per loop during lowering),
/// every interval computation the frontend and the downstream analyses
/// perform stays below `64 · 2^22 · 2^31 < 2^63` — untrusted input
/// cannot overflow the unchecked `i64` arithmetic in
/// [`crate::ir::AffineExpr`], by induction over the loop nest. Far
/// above any real kernel (the corpus maximum literal is 2800).
pub const MAX_AFFINE: u64 = 1 << 16;
/// Terms per affine expression (see [`MAX_AFFINE`]).
pub const MAX_AFFINE_TERMS: usize = 64;
/// Iterator value-range magnitude bound (see [`MAX_AFFINE`]).
const MAX_RANGE: i64 = 1 << 31;
/// Element-count cap per array: `Array::elements` multiplies the dims
/// in unchecked `u64`, so the declared product must be checked here.
const MAX_ELEMENTS: u64 = 1 << 40;
/// Loop-nest depth cap (bounds the lowering recursion and the range
/// induction above).
const MAX_DEPTH: usize = 64;

/// Parse (and lower) one `.knl` kernel. `origin` labels diagnostics
/// (usually the file path).
pub fn parse_kernel(src: &str, origin: &str) -> Result<Kernel, ParseError> {
    let ast = parse_ast(src, origin)?;
    lower(&ast, src, origin)
}

/// Parse to the surface AST without lowering (tests, tooling).
pub(super) fn parse_ast(src: &str, origin: &str) -> Result<KernelAst, ParseError> {
    let toks = lex(src, origin)?;
    Parser {
        src,
        origin,
        toks,
        pos: 0,
        depth: 0,
    }
    .kernel()
}

struct Parser<'s> {
    src: &'s str,
    origin: &'s str,
    toks: Vec<Token>,
    pos: usize,
    /// Current `for` nesting depth — capped at [`MAX_DEPTH`] *during
    /// parsing* (the lowering check alone would come after the parser
    /// already recursed arbitrarily deep on hostile input).
    depth: usize,
}

impl<'s> Parser<'s> {
    fn peek(&self) -> &Token {
        &self.toks[self.pos]
    }

    fn advance(&mut self) -> Token {
        let t = self.toks[self.pos].clone();
        if self.pos + 1 < self.toks.len() {
            self.pos += 1;
        }
        t
    }

    fn err<T>(&self, span: Span, msg: impl Into<String>) -> Result<T, ParseError> {
        Err(ParseError::new(self.src, self.origin, span, msg))
    }

    fn eat(&mut self, tok: &Tok) -> bool {
        if &self.peek().tok == tok {
            self.advance();
            true
        } else {
            false
        }
    }

    fn eat_kw(&mut self, word: &str) -> bool {
        if matches!(&self.peek().tok, Tok::Ident(w) if w == word) {
            self.advance();
            true
        } else {
            false
        }
    }

    fn expect(&mut self, tok: Tok, what: &str) -> Result<Span, ParseError> {
        if self.peek().tok == tok {
            Ok(self.advance().span)
        } else {
            let found = self.peek().tok.describe();
            self.err(
                self.peek().span,
                format!("expected {} ({what}), found {found}", tok.describe()),
            )
        }
    }

    fn expect_ident(&mut self, what: &str) -> Result<(String, Span), ParseError> {
        match self.peek().tok.clone() {
            Tok::Ident(s) => Ok((s, self.advance().span)),
            other => self.err(
                self.peek().span,
                format!("expected {what}, found {}", other.describe()),
            ),
        }
    }

    // --- grammar productions --------------------------------------------

    fn kernel(&mut self) -> Result<KernelAst, ParseError> {
        if !self.eat_kw("kernel") {
            return self.err(
                self.peek().span,
                "a .knl file starts with `kernel \"name\" f32|f64`",
            );
        }
        let name = match self.peek().tok.clone() {
            Tok::Str(s) => {
                self.advance();
                s
            }
            Tok::Ident(s) => {
                self.advance();
                s
            }
            other => {
                return self.err(
                    self.peek().span,
                    format!("expected kernel name, found {}", other.describe()),
                )
            }
        };
        let (dt, dspan) = self.expect_ident("scalar dtype `f32` or `f64`")?;
        let Some(dtype) = DType::from_name(&dt) else {
            return self.err(dspan, format!("unknown dtype `{dt}` (want f32 or f64)"));
        };
        let mut arrays = Vec::new();
        let mut roots = Vec::new();
        loop {
            if self.eat_kw("array") {
                arrays.push(self.array()?);
            } else if self.eat_kw("for") {
                roots.push(self.loop_()?);
            } else if self.peek().tok == Tok::Eof {
                break;
            } else {
                let found = self.peek().tok.describe();
                return self.err(
                    self.peek().span,
                    format!("expected `array` or `for` at top level, found {found}"),
                );
            }
        }
        Ok(KernelAst {
            name,
            dtype,
            arrays,
            roots,
        })
    }

    fn array(&mut self) -> Result<ArrayAst, ParseError> {
        let (name, span) = self.expect_ident("array name")?;
        let mut dims = Vec::new();
        while self.eat(&Tok::LBrack) {
            match self.peek().tok.clone() {
                Tok::Int(n) => {
                    let s = self.advance().span;
                    if n == 0 {
                        return self.err(s, format!("array `{name}` has a zero-extent dimension"));
                    }
                    dims.push(n);
                }
                other => {
                    return self.err(
                        self.peek().span,
                        format!("expected dimension extent, found {}", other.describe()),
                    )
                }
            }
            self.expect(Tok::RBrack, "closing the dimension")?;
        }
        if dims.is_empty() {
            return self.err(span, format!("array `{name}` needs at least one `[extent]`"));
        }
        // Array::elements multiplies dims unchecked; cap the product
        let elements = dims
            .iter()
            .try_fold(1u64, |acc, &d| acc.checked_mul(d))
            .filter(|&e| e <= MAX_ELEMENTS);
        if elements.is_none() {
            return self.err(
                span,
                format!("array `{name}` is too large (more than 2^40 elements)"),
            );
        }
        let (dw, dirspan) = self.expect_ident("array direction `in|out|inout|temp`")?;
        let Some(dir) = ArrayDir::from_word(&dw) else {
            return self.err(
                dirspan,
                format!("unknown array direction `{dw}` (want in, out, inout, or temp)"),
            );
        };
        Ok(ArrayAst {
            name,
            dims,
            dir,
            span,
        })
    }

    fn loop_(&mut self) -> Result<LoopAst, ParseError> {
        let (name, span) = self.expect_ident("loop iterator name")?;
        if self.depth >= MAX_DEPTH {
            return self.err(
                span,
                format!("loops nested deeper than the supported {MAX_DEPTH} levels"),
            );
        }
        self.depth += 1;
        let result = self.loop_body(name, span);
        self.depth -= 1;
        result
    }

    fn loop_body(&mut self, name: String, span: Span) -> Result<LoopAst, ParseError> {
        if !self.eat_kw("in") {
            let found = self.peek().tok.describe();
            return self.err(
                self.peek().span,
                format!("expected `in` after loop iterator `{name}`, found {found}"),
            );
        }
        let lb = self.affine()?;
        self.expect(Tok::DotDot, "separating the loop bounds")?;
        let ub = self.affine()?;
        self.expect(Tok::LBrace, "opening the loop body")?;
        let mut body = Vec::new();
        loop {
            if self.eat(&Tok::RBrace) {
                break;
            }
            if self.eat_kw("for") {
                body.push(NodeAst::Loop(self.loop_()?));
            } else if self.eat_kw("stmt") {
                body.push(NodeAst::Stmt(self.stmt()?));
            } else {
                let found = self.peek().tok.describe();
                return self.err(
                    self.peek().span,
                    format!("expected `for`, `stmt`, or `}}` in loop body, found {found}"),
                );
            }
        }
        Ok(LoopAst {
            name,
            lb,
            ub,
            body,
            span,
        })
    }

    fn stmt(&mut self) -> Result<StmtAst, ParseError> {
        let (name, span) = self.expect_ident("statement name")?;
        let mut writes: Option<Vec<AccessAst>> = None;
        let mut reads: Option<Vec<AccessAst>> = None;
        let mut ops: Option<Vec<(OpKind, u32)>> = None;
        let mut chain: Option<Vec<OpKind>> = None;
        loop {
            if self.eat(&Tok::Semi) {
                break;
            }
            let cspan = self.peek().span;
            if self.eat_kw("writes") {
                if writes.replace(self.access_list()?).is_some() {
                    return self.err(cspan, format!("duplicate `writes` clause in `{name}`"));
                }
            } else if self.eat_kw("reads") {
                if reads.replace(self.access_list()?).is_some() {
                    return self.err(cspan, format!("duplicate `reads` clause in `{name}`"));
                }
            } else if self.eat_kw("ops") {
                if ops.replace(self.op_counts()?).is_some() {
                    return self.err(cspan, format!("duplicate `ops` clause in `{name}`"));
                }
            } else if self.eat_kw("chain") {
                if chain.replace(self.op_chain()?).is_some() {
                    return self.err(cspan, format!("duplicate `chain` clause in `{name}`"));
                }
            } else {
                let found = self.peek().tok.describe();
                return self.err(
                    self.peek().span,
                    format!(
                        "expected `writes`, `reads`, `ops`, `chain`, or `;` in `{name}`, \
                         found {found}"
                    ),
                );
            }
        }
        Ok(StmtAst {
            name,
            writes: writes.unwrap_or_default(),
            reads: reads.unwrap_or_default(),
            ops: ops.unwrap_or_default(),
            chain,
            span,
        })
    }

    fn access_list(&mut self) -> Result<Vec<AccessAst>, ParseError> {
        let mut out = Vec::new();
        loop {
            out.push(self.access()?);
            if !self.eat(&Tok::Comma) {
                break;
            }
        }
        Ok(out)
    }

    fn access(&mut self) -> Result<AccessAst, ParseError> {
        let (array, span) = self.expect_ident("array name")?;
        if self.peek().tok != Tok::LBrack {
            let found = self.peek().tok.describe();
            return self.err(
                self.peek().span,
                format!("expected `[` after `{array}` (every access is subscripted), found {found}"),
            );
        }
        let mut indices = Vec::new();
        while self.eat(&Tok::LBrack) {
            indices.push(self.affine()?);
            self.expect(Tok::RBrack, "closing the subscript")?;
        }
        Ok(AccessAst {
            array,
            indices,
            span,
        })
    }

    fn op_word(&mut self) -> Result<OpKind, ParseError> {
        let (w, span) = self.expect_ident("op `add|sub|mul|div`")?;
        OpKind::from_word(&w)
            .ok_or_else(|| {
                ParseError::new(
                    self.src,
                    self.origin,
                    span,
                    format!("unknown op `{w}` (want add, sub, mul, or div)"),
                )
            })
    }

    fn op_counts(&mut self) -> Result<Vec<(OpKind, u32)>, ParseError> {
        let mut out = Vec::new();
        let mut total: u64 = 0;
        loop {
            let espan = self.peek().span;
            let n = match self.peek().tok.clone() {
                Tok::Int(n) => {
                    self.advance();
                    self.expect(Tok::Star, "op counts are written `N*op`")?;
                    n
                }
                _ => 1,
            };
            // the chain default expands counts into a per-op Vec, so
            // untrusted counts must stay allocation-sane — in total, not
            // just per literal (repetition must not defeat the cap)
            total = total.saturating_add(n);
            if total > MAX_OP_COUNT {
                return self.err(
                    espan,
                    format!(
                        "statement op multiset expands to {total}+ ops \
                         (max {MAX_OP_COUNT} total)"
                    ),
                );
            }
            out.push((self.op_word()?, n as u32));
            if !self.eat(&Tok::Comma) {
                break;
            }
        }
        Ok(out)
    }

    fn op_chain(&mut self) -> Result<Vec<OpKind>, ParseError> {
        let mut out = vec![self.op_word()?];
        while self.eat(&Tok::Comma) {
            out.push(self.op_word()?);
        }
        Ok(out)
    }

    fn affine(&mut self) -> Result<AffAst, ParseError> {
        let start = self.peek().span;
        let mut terms = Vec::new();
        let mut sign: i64 = 1;
        if self.eat(&Tok::Minus) {
            sign = -1;
        } else {
            self.eat(&Tok::Plus);
        }
        loop {
            terms.push(self.affine_term(sign)?);
            if self.eat(&Tok::Plus) {
                sign = 1;
            } else if self.eat(&Tok::Minus) {
                sign = -1;
            } else {
                break;
            }
        }
        let end = terms.last().map(|t: &super::ast::AffTermAst| t.span).unwrap_or(start);
        let span = start.to(end);
        if terms.len() > MAX_AFFINE_TERMS {
            return self.err(
                span,
                format!(
                    "affine expression has {} terms (max {MAX_AFFINE_TERMS})",
                    terms.len()
                ),
            );
        }
        Ok(AffAst { terms, span })
    }

    fn affine_term(&mut self, sign: i64) -> Result<super::ast::AffTermAst, ParseError> {
        use super::ast::AffTermAst;
        match self.peek().tok.clone() {
            Tok::Int(n) => {
                let span = self.advance().span;
                if n > MAX_AFFINE {
                    return self.err(
                        span,
                        format!("affine literal {n} exceeds the supported magnitude ({MAX_AFFINE})"),
                    );
                }
                if self.eat(&Tok::Star) {
                    let (it, ispan) = self.expect_ident("iterator after `*`")?;
                    Ok(AffTermAst {
                        coeff: sign * n as i64,
                        iter: Some(it),
                        span: span.to(ispan),
                    })
                } else {
                    Ok(AffTermAst {
                        coeff: sign * n as i64,
                        iter: None,
                        span,
                    })
                }
            }
            Tok::Ident(it) => {
                let span = self.advance().span;
                Ok(AffTermAst {
                    coeff: sign,
                    iter: Some(it),
                    span,
                })
            }
            other => self.err(
                self.peek().span,
                format!(
                    "expected an integer or iterator in affine expression, found {}",
                    other.describe()
                ),
            ),
        }
    }
}

// --- lowering -----------------------------------------------------------

/// Lower a surface AST into a finalized [`Kernel`] through
/// [`KernelBuilder`], performing every semantic check with span-anchored
/// diagnostics. The random-kernel generator feeds its ASTs through this
/// same path, so generated kernels satisfy the same rules by
/// construction.
pub(super) fn lower(ast: &KernelAst, src: &str, origin: &str) -> Result<Kernel, ParseError> {
    let mut kb = KernelBuilder::new(&ast.name, ast.dtype);
    let mut ctx = Lower {
        src,
        origin,
        arrays: Vec::new(),
        scope: Vec::new(),
    };
    for a in &ast.arrays {
        if ctx.arrays.iter().any(|(n, ..)| n == &a.name) {
            return ctx.err(a.span, format!("array `{}` is declared twice", a.name));
        }
        let id = kb.array(&a.name, &a.dims, a.dir);
        ctx.arrays.push((a.name.clone(), id, a.dims.clone()));
    }
    if ast.roots.is_empty() {
        return ctx.err(
            Span::default(),
            format!("kernel `{}` has no loops (nothing to explore)", ast.name),
        );
    }
    for l in &ast.roots {
        ctx.lower_loop(&mut kb, l)?;
    }
    Ok(kb.finish())
}

struct Lower<'s> {
    src: &'s str,
    origin: &'s str,
    /// `(name, id, dims)` in declaration order.
    arrays: Vec<(String, ArrayId, Vec<u64>)>,
    /// Enclosing loops, outermost first, with each iterator's inclusive
    /// value range (exact for affine bounds, computed outside-in the way
    /// `poly::tripcount` does).
    scope: Vec<(String, LoopId, (i64, i64))>,
}

impl<'s> Lower<'s> {
    fn err<T>(&self, span: Span, msg: impl Into<String>) -> Result<T, ParseError> {
        Err(ParseError::new(self.src, self.origin, span, msg))
    }

    /// Inclusive value range of enclosing iterator `l`.
    fn range_of(&self, l: LoopId) -> (i64, i64) {
        self.scope
            .iter()
            .find(|(_, id, _)| *id == l)
            .map(|(_, _, r)| *r)
            .expect("resolved iterator must be in scope")
    }

    fn lower_loop(&mut self, kb: &mut KernelBuilder, la: &LoopAst) -> Result<(), ParseError> {
        if self.scope.iter().any(|(n, ..)| n == &la.name) {
            return self.err(
                la.span,
                format!(
                    "loop `{}` shadows an enclosing loop of the same name \
                     (iterator references would be ambiguous)",
                    la.name
                ),
            );
        }
        let lb = self.resolve(&la.lb)?;
        let ub = self.resolve(&la.ub)?;
        if lb.is_constant() && ub.is_constant() && ub.constant <= lb.constant {
            return self.err(
                la.span,
                format!(
                    "loop `{}` is empty: bounds [{}, {}) contain no iterations",
                    la.name, lb.constant, ub.constant
                ),
            );
        }
        if la.body.is_empty() {
            return self.err(la.span, format!("loop `{}` has an empty body", la.name));
        }
        if self.scope.len() >= MAX_DEPTH {
            return self.err(
                la.span,
                format!("loops nested deeper than the supported {MAX_DEPTH} levels"),
            );
        }
        // iterator value range [lb_lo, ub_hi - 1], exact for affine
        // bounds over the enclosing box (extremes at corners). The
        // magnitude check keeps the range induction of [`MAX_AFFINE`]
        // going: every enclosing range is known ≤ MAX_RANGE here, so
        // this level's bounds() could not have overflowed.
        let rng = |l: LoopId| self.range_of(l);
        let (lb_lo, _) = lb.bounds(&rng);
        let (_, ub_hi) = ub.bounds(&rng);
        if lb_lo.abs() > MAX_RANGE || ub_hi.abs() > MAX_RANGE {
            return self.err(
                la.span,
                format!(
                    "bounds of loop `{}` reach magnitude {} (max {MAX_RANGE})",
                    la.name,
                    lb_lo.abs().max(ub_hi.abs())
                ),
            );
        }
        let range = (lb_lo, (ub_hi - 1).max(lb_lo));
        let mut result = Ok(());
        kb.for_expr(&la.name, lb, ub, |kb, id| {
            self.scope.push((la.name.clone(), id, range));
            for node in &la.body {
                result = match node {
                    NodeAst::Loop(l) => self.lower_loop(kb, l),
                    NodeAst::Stmt(s) => self.lower_stmt(kb, s),
                };
                if result.is_err() {
                    break;
                }
            }
            self.scope.pop();
        });
        result
    }

    fn lower_stmt(&mut self, kb: &mut KernelBuilder, sa: &StmtAst) -> Result<(), ParseError> {
        if sa.writes.is_empty() {
            return self.err(
                sa.span,
                format!(
                    "statement `{}` writes nothing (every statement needs a `writes` clause)",
                    sa.name
                ),
            );
        }
        let writes = sa
            .writes
            .iter()
            .map(|a| self.lower_access(a))
            .collect::<Result<Vec<_>, _>>()?;
        let reads = sa
            .reads
            .iter()
            .map(|a| self.lower_access(a))
            .collect::<Result<Vec<_>, _>>()?;
        match &sa.chain {
            None => kb.stmt(&sa.name, writes, reads, &sa.ops),
            Some(c) => kb.stmt_with_chain(&sa.name, writes, reads, &sa.ops, c.clone()),
        };
        Ok(())
    }

    fn lower_access(&self, aa: &AccessAst) -> Result<Access, ParseError> {
        let Some((_, id, dims)) = self.arrays.iter().find(|(n, ..)| n == &aa.array) else {
            let declared: Vec<&str> = self.arrays.iter().map(|(n, ..)| n.as_str()).collect();
            return self.err(
                aa.span,
                format!(
                    "unknown array `{}` (declared: {})",
                    aa.array,
                    if declared.is_empty() {
                        "none".to_string()
                    } else {
                        declared.join(", ")
                    }
                ),
            );
        };
        if aa.indices.len() != dims.len() {
            return self.err(
                aa.span,
                format!(
                    "access to `{}` has {} subscripts but the array has {} dimensions",
                    aa.array,
                    aa.indices.len(),
                    dims.len()
                ),
            );
        }
        let indices = aa
            .indices
            .iter()
            .map(|e| self.resolve(e))
            .collect::<Result<Vec<_>, _>>()?;
        // bounds check where the box range is *exact*: constant and
        // single-iterator subscripts (extremes at iterator endpoints).
        // Multi-iterator subscripts (cnn's `h + p`, durbin's `k - i - 1`)
        // are skipped — their box corners over-approximate correlated
        // iterators, and `poly::footprint` clamps to the extent anyway.
        for (d, (expr, idx_ast)) in indices.iter().zip(&aa.indices).enumerate() {
            if expr.terms.len() > 1 {
                continue;
            }
            let (lo, hi) = expr.bounds(&|l| self.range_of(l));
            if lo < 0 || hi >= dims[d] as i64 {
                return self.err(
                    idx_ast.span,
                    format!(
                        "subscript {d} of `{}` spans [{lo}, {hi}] but the dimension \
                         has extent {}",
                        aa.array, dims[d]
                    ),
                );
            }
        }
        Ok(Access::new(*id, indices))
    }

    fn resolve(&self, e: &AffAst) -> Result<AffineExpr, ParseError> {
        let mut out = AffineExpr::constant(0);
        for t in &e.terms {
            match &t.iter {
                None => out.constant += t.coeff,
                Some(name) => {
                    // innermost-first: lexical scoping (shadowing is
                    // rejected at loop entry, so this is unambiguous)
                    let Some((_, id, _)) = self.scope.iter().rev().find(|(n, ..)| n == name)
                    else {
                        let in_scope: Vec<&str> =
                            self.scope.iter().map(|(n, ..)| n.as_str()).collect();
                        return self.err(
                            t.span,
                            format!(
                                "unknown iterator `{name}` (in scope: {})",
                                if in_scope.is_empty() {
                                    "none".to_string()
                                } else {
                                    in_scope.join(", ")
                                }
                            ),
                        );
                    };
                    out.add_term(*id, t.coeff);
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GEMM_ISH: &str = r#"
# a gemm-shaped kernel
kernel "mini-gemm" f32

array C[8][8] inout
array A[8][8] in
array B[8][8] in

for i in 0 .. 8 {
  for j0 in 0 .. 8 {
    stmt S0 writes C[i][j0] reads C[i][j0] ops mul;
  }
  for k in 0 .. 8 {
    for j1 in 0 .. 8 {
      stmt S1 writes C[i][j1] reads C[i][j1], A[i][k], B[k][j1] ops 2*mul, add;
    }
  }
}
"#;

    #[test]
    fn parses_gemm_shape() {
        let k = parse_kernel(GEMM_ISH, "<test>").unwrap();
        assert_eq!(k.name, "mini-gemm");
        assert_eq!(k.n_loops(), 4);
        assert_eq!(k.n_stmts(), 2);
        assert_eq!(k.arrays.len(), 3);
        assert_eq!(k.summary_ast(), "Loop_i(Loop_j0(S0), Loop_k(Loop_j1(S1)))");
        assert_eq!(k.stmt(crate::ir::StmtId(1)).flops(), 3);
        // default chain = expanded op multiset
        assert_eq!(
            k.stmt(crate::ir::StmtId(1)).chain,
            vec![OpKind::Mul, OpKind::Mul, OpKind::Add]
        );
    }

    #[test]
    fn triangular_and_offset_bounds() {
        let src = r#"
kernel tri f64
array a[16][16] inout
for i in 0 .. 16 {
  for j in i + 1 .. 16 {
    stmt s writes a[i][j] reads a[j][i] ops add chain add;
  }
}
"#;
        let k = parse_kernel(src, "<test>").unwrap();
        let (lb, ub) = k.loop_bounds(LoopId(1));
        assert_eq!(lb, &AffineExpr::var(LoopId(0)).plus_const(1));
        assert!(ub.is_constant());
        assert_eq!(k.dtype, DType::F64);
    }

    #[test]
    fn scalar_accumulator_and_negative_offsets() {
        let src = r#"
kernel acc f32
array s[1] inout
array y[64] in
for i in 2 .. 32 {
  stmt s0 writes s[0] reads s[0], y[i - 2], y[2*i - 4] ops add, add;
}
"#;
        let k = parse_kernel(src, "<test>").unwrap();
        let st = k.stmt(crate::ir::StmtId(0));
        assert_eq!(st.reads[1].indices[0], AffineExpr::var(LoopId(0)).plus_const(-2));
        assert_eq!(
            st.reads[2].indices[0],
            AffineExpr::var_scaled(LoopId(0), 2).plus_const(-4)
        );
    }

    fn expect_err(src: &str, needle: &str) -> ParseError {
        let e = parse_kernel(src, "bad.knl").unwrap_err();
        assert!(
            e.msg.contains(needle),
            "error `{}` does not mention `{needle}`",
            e.msg
        );
        e
    }

    #[test]
    fn diagnostics_carry_spans() {
        let e = expect_err(
            "kernel k f32\narray a[4] in\nfor i in 0 .. 4 {\n  stmt s writes a[j];\n}\n",
            "unknown iterator `j`",
        );
        assert_eq!((e.line, e.col), (4, 19));
        let shown = format!("{e}");
        assert!(shown.contains("bad.knl:4:19"), "{shown}");
        assert!(shown.contains("stmt s writes a[j];"), "{shown}");
        assert!(shown.contains("in scope: i"), "{shown}");
    }

    #[test]
    fn semantic_rejections() {
        expect_err("array a[4] in", "starts with `kernel");
        expect_err("kernel k f16", "unknown dtype `f16`");
        expect_err(
            "kernel k f32\narray a[4] in\narray a[4] out\nfor i in 0 .. 4 { stmt s writes a[i]; }",
            "declared twice",
        );
        expect_err("kernel k f32\narray a[4] in", "has no loops");
        expect_err(
            "kernel k f32\narray a[4] out\nfor i in 0 .. 4 { }",
            "empty body",
        );
        expect_err(
            "kernel k f32\narray a[4] out\nfor i in 4 .. 4 { stmt s writes a[i]; }",
            "contain no iterations",
        );
        expect_err(
            "kernel k f32\narray a[4] out\nfor i in 0 .. 4 { for i in 0 .. 2 { stmt s writes a[i]; } }",
            "shadows an enclosing loop",
        );
        expect_err(
            "kernel k f32\narray a[4] out\nfor i in 0 .. 4 { stmt s reads a[i]; }",
            "writes nothing",
        );
        expect_err(
            "kernel k f32\narray a[4][4] out\nfor i in 0 .. 4 { stmt s writes a[i]; }",
            "1 subscripts but the array has 2",
        );
        expect_err(
            "kernel k f32\narray a[4] out\nfor i in 0 .. 4 { stmt s writes b[i]; }",
            "unknown array `b`",
        );
        expect_err(
            "kernel k f32\narray a[4] out\nfor i in 0 .. 4 { stmt s writes a[i] ops 2*xor; }",
            "unknown op `xor`",
        );
        expect_err(
            "kernel k f32\narray a[4] out\nfor i in 0 .. 4 { stmt s writes a[i] ops 4294967295*mul; }",
            "expands to 4294967295+ ops",
        );
        // repetition must not defeat the expansion cap either
        expect_err(
            "kernel k f32\narray a[4] out\nfor i in 0 .. 4 { stmt s writes a[i] ops 4096*mul, 4096*mul; }",
            "max 4096 total",
        );
        expect_err(
            "kernel k f32\narray a[4] out\nfor i in 0 .. i { stmt s writes a[i]; }",
            "unknown iterator `i`",
        );
        expect_err(
            "kernel k f32\narray a[0] out\nfor i in 0 .. 4 { stmt s writes a[i]; }",
            "zero-extent",
        );
        expect_err(
            "kernel k f32\narray a[4] out\nfor i in 0 .. 4 { stmt s writes a[i] writes a[i]; }",
            "duplicate `writes`",
        );
        // untrusted-input magnitude caps (overflow hardening)
        expect_err(
            "kernel k f32\narray a[4] out\nfor i in 0 .. 4 { stmt s writes a[i + 9223372036854775807]; }",
            "exceeds the supported magnitude",
        );
        expect_err(
            "kernel k f32\narray a[1099511627776][1099511627776] out\nfor i in 0 .. 4 { stmt s writes a[0][0]; }",
            "too large (more than 2^40 elements)",
        );
        // exact (constant / single-iterator) out-of-bounds subscripts
        expect_err(
            "kernel k f32\narray a[4] out\nfor i in 0 .. 64 { stmt s writes a[i]; }",
            "subscript 0 of `a` spans [0, 63] but the dimension has extent 4",
        );
        expect_err(
            "kernel k f32\narray a[4] out\nfor i in 0 .. 4 { stmt s writes a[i - 1]; }",
            "spans [-1, 2]",
        );
        expect_err(
            "kernel k f32\narray a[4] out\nfor i in 0 .. 4 { stmt s writes a[4]; }",
            "spans [4, 4]",
        );
    }

    #[test]
    fn parse_depth_is_capped_before_recursing() {
        // hostile nesting must produce a ParseError, not a stack overflow
        let mut src = String::from("kernel k f32\narray a[4] out\n");
        for i in 0..70 {
            src.push_str(&format!("for x{i} in 0 .. 2 {{\n"));
        }
        let e = parse_kernel(&src, "<t>").unwrap_err();
        assert!(e.msg.contains("nested deeper"), "{}", e.msg);
    }

    #[test]
    fn correlated_multi_iterator_subscripts_are_not_box_rejected() {
        // durbin's `r[k - i - 1]` is in-bounds for the true (coupled)
        // ranges but its box corners go negative — must stay accepted
        let src = r#"
kernel mini-durbin f32
array r[16] in
array s[1] inout
for k in 1 .. 16 {
  for i in 0 .. k {
    stmt s2 writes s[0] reads s[0], r[k - i - 1] ops mul, add;
  }
}
"#;
        let k = parse_kernel(src, "<t>").unwrap();
        assert_eq!(k.n_loops(), 2);
    }

    #[test]
    fn ops_order_and_grouping_preserved() {
        let src = "kernel k f32\narray a[4] out\nfor i in 0 .. 4 {\n  stmt s writes a[i] ops 2*mul, add, mul;\n}\n";
        let k = parse_kernel(src, "<t>").unwrap();
        assert_eq!(
            k.stmt(crate::ir::StmtId(0)).ops,
            vec![(OpKind::Mul, 2), (OpKind::Add, 1), (OpKind::Mul, 1)]
        );
    }
}
