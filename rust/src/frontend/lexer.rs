//! Tokenizer for the `.knl` loop-nest DSL.
//!
//! The token set is deliberately tiny: identifiers, unsigned integer
//! literals, quoted strings (kernel names may contain `-`), and the
//! punctuation of the grammar. Keywords (`kernel`, `array`, `for`, `in`,
//! `stmt`, …) are **contextual** — the lexer emits them as plain
//! identifiers and the parser matches on the spelling where the grammar
//! expects a keyword, so arrays named `in` or `out` (the CNN kernel has
//! both) never collide with the syntax.

use super::diag::{ParseError, Span};

/// Token kinds of the `.knl` lexer. Keywords are contextual: the
/// lexer only ever emits `Ident` for words.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Tok {
    /// Identifier (or contextual keyword).
    Ident(String),
    /// Unsigned integer literal.
    Int(u64),
    /// Double-quoted string (kernel names).
    Str(String),
    /// `[`
    LBrack,
    /// `]`
    RBrack,
    /// `{`
    LBrace,
    /// `}`
    RBrace,
    /// `,`
    Comma,
    /// `;`
    Semi,
    /// `+`
    Plus,
    /// `-`
    Minus,
    /// `*`
    Star,
    /// `..`
    DotDot,
    /// End of input.
    Eof,
}

impl Tok {
    /// Short human name for "expected X, found Y" messages.
    pub fn describe(&self) -> String {
        match self {
            Tok::Ident(s) => format!("`{s}`"),
            Tok::Int(n) => format!("`{n}`"),
            Tok::Str(s) => format!("\"{s}\""),
            Tok::LBrack => "`[`".into(),
            Tok::RBrack => "`]`".into(),
            Tok::LBrace => "`{`".into(),
            Tok::RBrace => "`}`".into(),
            Tok::Comma => "`,`".into(),
            Tok::Semi => "`;`".into(),
            Tok::Plus => "`+`".into(),
            Tok::Minus => "`-`".into(),
            Tok::Star => "`*`".into(),
            Tok::DotDot => "`..`".into(),
            Tok::Eof => "end of input".into(),
        }
    }
}

/// One token with its source span.
#[derive(Clone, Debug)]
pub struct Token {
    /// The token kind/payload.
    pub tok: Tok,
    /// Where it came from (caret diagnostics).
    pub span: Span,
}

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_'
}

fn is_ident_cont(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Tokenize `src`. `#` starts a comment running to end of line.
pub fn lex(src: &str, origin: &str) -> Result<Vec<Token>, ParseError> {
    let b = src.as_bytes();
    let mut out = Vec::new();
    let mut i = 0usize;
    let mut push = |tok: Tok, off: usize, len: usize, out: &mut Vec<Token>| {
        out.push(Token {
            tok,
            span: Span::new(off, len),
        });
    };
    while i < b.len() {
        let c = b[i];
        match c {
            b' ' | b'\t' | b'\r' | b'\n' => i += 1,
            b'#' => {
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
            }
            b'[' | b']' | b'{' | b'}' | b',' | b';' | b'+' | b'-' | b'*' => {
                let tok = match c {
                    b'[' => Tok::LBrack,
                    b']' => Tok::RBrack,
                    b'{' => Tok::LBrace,
                    b'}' => Tok::RBrace,
                    b',' => Tok::Comma,
                    b';' => Tok::Semi,
                    b'+' => Tok::Plus,
                    b'-' => Tok::Minus,
                    _ => Tok::Star,
                };
                push(tok, i, 1, &mut out);
                i += 1;
            }
            b'.' => {
                if i + 1 < b.len() && b[i + 1] == b'.' {
                    push(Tok::DotDot, i, 2, &mut out);
                    i += 2;
                } else {
                    return Err(ParseError::new(
                        src,
                        origin,
                        Span::new(i, 1),
                        "stray `.` (ranges are written `lo .. hi`)",
                    ));
                }
            }
            b'"' => {
                let start = i;
                i += 1;
                while i < b.len() && b[i] != b'"' && b[i] != b'\n' {
                    i += 1;
                }
                if i >= b.len() || b[i] != b'"' {
                    return Err(ParseError::new(
                        src,
                        origin,
                        Span::new(start, i - start),
                        "unterminated string literal",
                    ));
                }
                let s = &src[start + 1..i];
                i += 1;
                push(Tok::Str(s.to_string()), start, i - start, &mut out);
            }
            b'0'..=b'9' => {
                let start = i;
                while i < b.len() && b[i].is_ascii_digit() {
                    i += 1;
                }
                let text = &src[start..i];
                let n: u64 = text.parse().map_err(|_| {
                    ParseError::new(
                        src,
                        origin,
                        Span::new(start, i - start),
                        format!("integer literal `{text}` overflows u64"),
                    )
                })?;
                push(Tok::Int(n), start, i - start, &mut out);
            }
            c if is_ident_start(c) => {
                let start = i;
                while i < b.len() && is_ident_cont(b[i]) {
                    i += 1;
                }
                push(Tok::Ident(src[start..i].to_string()), start, i - start, &mut out);
            }
            other => {
                return Err(ParseError::new(
                    src,
                    origin,
                    Span::new(i, 1),
                    format!("unexpected character `{}`", other as char),
                ));
            }
        }
    }
    push(Tok::Eof, src.len(), 0, &mut out);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<Tok> {
        lex(src, "<test>").unwrap().into_iter().map(|t| t.tok).collect()
    }

    #[test]
    fn basic_stream() {
        let toks = kinds("for i in 0 .. 64 { stmt s0 writes a[i]; }");
        assert_eq!(toks[0], Tok::Ident("for".into()));
        assert_eq!(toks[2], Tok::Ident("in".into()));
        assert_eq!(toks[3], Tok::Int(0));
        assert_eq!(toks[4], Tok::DotDot);
        assert_eq!(toks[5], Tok::Int(64));
        assert_eq!(toks[6], Tok::LBrace);
        assert_eq!(*toks.last().unwrap(), Tok::Eof);
    }

    #[test]
    fn comments_and_strings() {
        let toks = kinds("# header\nkernel \"jacobi-1d\" f32 # tail\n");
        assert_eq!(
            toks,
            vec![
                Tok::Ident("kernel".into()),
                Tok::Str("jacobi-1d".into()),
                Tok::Ident("f32".into()),
                Tok::Eof
            ]
        );
    }

    #[test]
    fn spans_point_at_tokens() {
        let toks = lex("ab 12", "<test>").unwrap();
        assert_eq!(toks[0].span, Span::new(0, 2));
        assert_eq!(toks[1].span, Span::new(3, 2));
    }

    #[test]
    fn affine_punctuation() {
        let toks = kinds("2*i - j + 1");
        assert_eq!(
            toks,
            vec![
                Tok::Int(2),
                Tok::Star,
                Tok::Ident("i".into()),
                Tok::Minus,
                Tok::Ident("j".into()),
                Tok::Plus,
                Tok::Int(1),
                Tok::Eof
            ]
        );
    }

    #[test]
    fn rejects_stray_dot_and_bad_char() {
        assert!(lex("a . b", "<t>").unwrap_err().msg.contains("stray"));
        assert!(lex("a @ b", "<t>").unwrap_err().msg.contains("unexpected character"));
        assert!(lex("\"open", "<t>").unwrap_err().msg.contains("unterminated"));
    }
}
