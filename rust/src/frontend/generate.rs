//! Seeded random-kernel generator: unbounded scenario diversity for the
//! differential fuzz suites (`tests/property_frontend_fuzz.rs`), corpus
//! emission (`nlp-dse gen`), and ad-hoc stress kernels.
//!
//! The generator emits the parser's surface AST and lowers it through
//! the exact same semantic checks as textual input, so every generated
//! kernel is **by construction regular** — inside the paper's restricted
//! program class and inside the DSL's expressible set:
//!
//! * unit-stride loops with affine bounds: constant `[0, E)` or
//!   triangular against an enclosing iterator (`[0, i)` / `[i+1, E)`);
//! * every array access is affine with indices of the form `iter`,
//!   `iter + c`, or a constant, always within the array's extents
//!   (all arrays share one extent `B` ≥ every loop extent, and offsets
//!   are only drawn when they provably fit);
//! * statement op multisets/chains drawn from the four scalar op kinds;
//! * array directions are derived from actual use (read-only → `in`,
//!   write-only → `out`, both → `inout`/`temp`), so transfer analysis
//!   sees a consistent story.
//!
//! Determinism: `(seed, knobs)` fully determine the kernel — identical
//! calls reproduce identical kernels bit-for-bit (splitmix64, stable
//! across platforms), which is what lets failing fuzz cases be replayed
//! from the seed alone.

use super::ast::{AccessAst, AffAst, ArrayAst, KernelAst, LoopAst, NodeAst, StmtAst};
use super::diag::Span;
use super::parser;
use crate::ir::{ArrayDir, DType, Kernel, OpKind, Stmt};
use crate::util::rng::Rng;

/// Generator knobs. All counts are *maxima* — each kernel draws its
/// actual shape uniformly under them.
#[derive(Clone, Debug)]
pub struct GenConfig {
    /// PRNG seed; every knob draw derives from it (bit-exact replay).
    pub seed: u64,
    /// Max loop-nest depth (≥ 1).
    pub depth: usize,
    /// Max statements per innermost loop (≥ 1).
    pub width: usize,
    /// Max top-level loop nests (≥ 1).
    pub nests: usize,
    /// Soft cap on distinct arrays (≥ 1): reuse is forced once reached,
    /// except when a statement needs an arity no existing array has.
    pub arrays: usize,
    /// Loop extents are drawn from a divisor-rich menu capped here.
    pub max_trip: u64,
    /// Probability that an eligible inner loop gets triangular bounds.
    pub triangular: f64,
    /// Scalar element type of the generated kernel.
    pub dtype: DType,
}

impl Default for GenConfig {
    fn default() -> GenConfig {
        GenConfig {
            seed: 0,
            depth: 3,
            width: 2,
            nests: 2,
            arrays: 4,
            max_trip: 64,
            triangular: 0.25,
            dtype: DType::F32,
        }
    }
}

impl GenConfig {
    /// Default knobs under an explicit seed.
    pub fn with_seed(seed: u64) -> GenConfig {
        GenConfig {
            seed,
            ..GenConfig::default()
        }
    }

    /// Derive the knobs themselves from the seed — one `u64` reproduces
    /// the whole scenario (what the fuzz suites log for replay).
    pub fn sampled(seed: u64) -> GenConfig {
        let mut r = Rng::new(seed).derive("gen-knobs");
        GenConfig {
            seed,
            depth: 1 + r.range(0, 3) as usize,
            width: 1 + r.range(0, 2) as usize,
            nests: 1 + r.range(0, 2) as usize,
            arrays: 2 + r.range(0, 4) as usize,
            max_trip: *r.choose(&[8, 12, 16, 24, 32, 48, 64]),
            triangular: if r.chance(0.5) { 0.35 } else { 0.0 },
            dtype: if r.chance(0.2) { DType::F64 } else { DType::F32 },
        }
    }
}

/// Generate one always-regular kernel from the config.
pub fn generate(cfg: &GenConfig) -> Kernel {
    let menu: Vec<u64> = [2u64, 3, 4, 6, 8, 10, 12, 16, 20, 24, 32, 40, 48, 64, 96, 128, 192, 256]
        .into_iter()
        .filter(|&e| e <= cfg.max_trip.max(2))
        .collect();
    let menu = if menu.is_empty() { vec![2] } else { menu };
    let b = *menu.last().unwrap();
    let mut g = Gen {
        cfg,
        rng: Rng::new(cfg.seed).derive("frontend-gen"),
        menu,
        b,
        arrays: Vec::new(),
        loop_ctr: 0,
        stmt_ctr: 0,
    };
    let n_nests = 1 + g.rng.range(0, cfg.nests.max(1) as u64) as usize;
    let mut roots = Vec::new();
    let mut scope = Vec::new();
    for _ in 0..n_nests {
        let depth = 1 + g.rng.range(0, cfg.depth.max(1) as u64) as usize;
        roots.push(g.gen_loop(depth, &mut scope));
    }
    // split the generator: direction draws need the rng while reading
    // the accumulated array specs
    let mut rng = g.rng;
    let b = g.b;
    let arrays = g
        .arrays
        .iter()
        .map(|a| ArrayAst {
            name: a.name.clone(),
            dims: vec![b; a.arity],
            dir: a.dir(&mut rng),
            span: Span::default(),
        })
        .collect();
    let ast = KernelAst {
        name: format!("gen-{:016x}", cfg.seed),
        dtype: cfg.dtype,
        arrays,
        roots,
    };
    parser::lower(&ast, "", "<generated>").unwrap_or_else(|e| {
        panic!(
            "generator produced an invalid kernel (seed {:#x}): {e}",
            cfg.seed
        )
    })
}

struct ArrSpec {
    name: String,
    arity: usize,
    read: bool,
    written: bool,
}

impl ArrSpec {
    fn dir(&self, rng: &mut Rng) -> ArrayDir {
        match (self.read, self.written) {
            (true, false) => ArrayDir::In,
            (false, true) => ArrayDir::Out,
            // an accumulator both produced and consumed here is
            // occasionally a pure intermediate
            (true, true) if rng.chance(0.3) => ArrayDir::Temp,
            _ => ArrayDir::InOut,
        }
    }
}

/// One enclosing loop during generation: its name and an exclusive
/// upper bound on the iterator's value (`values ∈ [0, hint)`), the
/// invariant that keeps every access inside the shared extent `B`.
struct ScopeLoop {
    name: String,
    hint: u64,
}

struct Gen<'c> {
    cfg: &'c GenConfig,
    rng: Rng,
    menu: Vec<u64>,
    /// Shared array extent: every dimension of every array, ≥ every
    /// loop extent, so any iterator indexes any dimension safely.
    b: u64,
    arrays: Vec<ArrSpec>,
    loop_ctr: usize,
    stmt_ctr: usize,
}

impl<'c> Gen<'c> {
    fn gen_loop(&mut self, depth_left: usize, scope: &mut Vec<ScopeLoop>) -> LoopAst {
        let name = format!("l{}", self.loop_ctr);
        self.loop_ctr += 1;
        // triangular bounds need an enclosing iterator with ≥ 2 values
        let tri: Vec<usize> = (0..scope.len()).filter(|&i| scope[i].hint >= 2).collect();
        let (lb, ub, hint) = if !tri.is_empty() && self.rng.chance(self.cfg.triangular) {
            let o = &scope[*self.rng.choose(&tri)];
            if self.rng.chance(0.5) {
                // [0, outer) — lu/covariance style
                (AffAst::constant(0), AffAst::var(&o.name), o.hint)
            } else {
                // [outer + 1, E) — trmm/symm style
                (
                    AffAst::var_plus(&o.name, 1),
                    AffAst::constant(o.hint as i64),
                    o.hint,
                )
            }
        } else {
            let e = *self.rng.choose(&self.menu);
            (AffAst::constant(0), AffAst::constant(e as i64), e)
        };
        scope.push(ScopeLoop {
            name: name.clone(),
            hint,
        });
        let mut body = Vec::new();
        if depth_left <= 1 {
            let n = 1 + self.rng.range(0, self.cfg.width.max(1) as u64) as usize;
            for _ in 0..n {
                body.push(NodeAst::Stmt(self.gen_stmt(scope, false)));
            }
        } else {
            // optional init statement before the inner nest (gemm's
            // `C *= beta` / 2mm's zero-fill shape)
            if self.rng.chance(0.3) {
                body.push(NodeAst::Stmt(self.gen_stmt(scope, true)));
            }
            let children = if self.rng.chance(0.25) { 2 } else { 1 };
            for _ in 0..children {
                let d = 1 + self.rng.range(0, depth_left as u64 - 1) as usize;
                body.push(NodeAst::Loop(self.gen_loop(d, scope)));
            }
            if self.rng.chance(0.15) {
                body.push(NodeAst::Stmt(self.gen_stmt(scope, false)));
            }
        }
        scope.pop();
        LoopAst {
            name,
            lb,
            ub,
            body,
            span: Span::default(),
        }
    }

    fn gen_stmt(&mut self, scope: &[ScopeLoop], init: bool) -> StmtAst {
        let name = format!("s{}", self.stmt_ctr);
        self.stmt_ctr += 1;
        let depth = scope.len();
        // reduction: the write ignores the innermost iterator and reads
        // itself, making the innermost loop a tree-reducible recurrence
        let reduction = !init && self.rng.chance(0.45);
        let avail: Vec<usize> = if reduction { (0..depth.saturating_sub(1)).collect() } else { (0..depth).collect() };
        let write_idx = self.pick_indices(scope, &avail, false);
        let w_arr = self.pick_array(write_idx.len());
        self.arrays[w_arr].written = true;
        let write = AccessAst {
            array: self.arrays[w_arr].name.clone(),
            indices: write_idx,
            span: Span::default(),
        };
        let mut reads = Vec::new();
        let mut ops = Vec::new();
        if !init {
            if reduction {
                self.arrays[w_arr].read = true;
                reads.push(write.clone());
            }
            let all: Vec<usize> = (0..depth).collect();
            let n_sources = 1 + self.rng.range(0, 2) as usize;
            for _ in 0..n_sources {
                let idx = self.pick_indices(scope, &all, true);
                let arr = self.pick_array(idx.len());
                self.arrays[arr].read = true;
                reads.push(AccessAst {
                    array: self.arrays[arr].name.clone(),
                    indices: idx,
                    span: Span::default(),
                });
            }
            let n_entries = 1 + self.rng.range(0, 3) as usize;
            for _ in 0..n_entries {
                // Add/Mul-heavy mix, Div rare — matching the corpus
                let op = match self.rng.range(0, 10) {
                    0 => OpKind::Div,
                    1 | 2 => OpKind::Sub,
                    3..=6 => OpKind::Mul,
                    _ => OpKind::Add,
                };
                let c = 1 + self.rng.range(0, 2) as u32;
                ops.push((op, c));
            }
        }
        // occasionally a shorter explicit chain (internal parallelism à
        // la `(a*b) + (c*d)`)
        let chain = if !ops.is_empty() && self.rng.chance(0.15) {
            let full = Stmt::default_chain(&ops);
            let len = 1 + self.rng.range(0, full.len() as u64) as usize;
            let cut = full[..len].to_vec();
            if cut == full {
                None
            } else {
                Some(cut)
            }
        } else {
            None
        };
        StmtAst {
            name,
            writes: vec![write],
            reads,
            ops,
            chain,
            span: Span::default(),
        }
    }

    /// Index expressions over a subset of `avail` enclosing iterators,
    /// outermost-first; empty `avail` degenerates to a scalar `[0]`
    /// access (the `s += ...` accumulator shape). Offsets (`iter + c`)
    /// are only drawn when `c` provably fits inside the shared extent.
    fn pick_indices(&mut self, scope: &[ScopeLoop], avail: &[usize], offsets: bool) -> Vec<AffAst> {
        if avail.is_empty() {
            return vec![AffAst::constant(0)];
        }
        let max_arity = avail.len().min(3);
        let mut arity = 1;
        if max_arity > 1 && self.rng.chance(0.6) {
            arity += 1;
        }
        if max_arity > 2 && self.rng.chance(0.3) {
            arity += 1;
        }
        let mut picks = avail.to_vec();
        self.rng.shuffle(&mut picks);
        picks.truncate(arity);
        picks.sort_unstable();
        picks
            .into_iter()
            .map(|i| {
                let l = &scope[i];
                let room = self.b.saturating_sub(l.hint).min(2);
                if offsets && room > 0 && self.rng.chance(0.25) {
                    AffAst::var_plus(&l.name, (1 + self.rng.range(0, room)) as i64)
                } else {
                    AffAst::var(&l.name)
                }
            })
            .collect()
    }

    /// Reuse an existing array of the wanted arity, or mint a new one
    /// while under the (soft) array-count cap.
    fn pick_array(&mut self, arity: usize) -> usize {
        let candidates: Vec<usize> = (0..self.arrays.len())
            .filter(|&i| self.arrays[i].arity == arity)
            .collect();
        let full = self.arrays.len() >= self.cfg.arrays.max(1);
        if !candidates.is_empty() && (full || self.rng.chance(0.55)) {
            return *self.rng.choose(&candidates);
        }
        let id = self.arrays.len();
        self.arrays.push(ArrSpec {
            name: format!("a{id}"),
            arity,
            read: false,
            written: false,
        });
        id
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::{parse_kernel, pretty};
    use crate::poly::Analysis;

    #[test]
    fn deterministic_per_seed() {
        let cfg = GenConfig::with_seed(42);
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.structural_diff(&b), None);
        assert_eq!(pretty::print(&a), pretty::print(&b));
    }

    #[test]
    fn seeds_diversify() {
        let texts: std::collections::BTreeSet<String> = (0..8)
            .map(|s| pretty::print(&generate(&GenConfig::sampled(s))))
            .collect();
        assert!(texts.len() >= 7, "only {} distinct kernels from 8 seeds", texts.len());
    }

    #[test]
    fn generated_kernels_are_regular_and_roundtrip() {
        for seed in 0..24 {
            let cfg = GenConfig::sampled(seed);
            let k = generate(&cfg);
            assert!(k.n_loops() >= 1, "seed {seed}");
            assert!(k.n_stmts() >= 1, "seed {seed}");
            // analyses must hold on every generated kernel
            let a = Analysis::new(&k);
            assert!(a.total_flops >= 0.0);
            for (i, tc) in a.tcs.iter().enumerate() {
                assert!(
                    tc.max <= cfg.max_trip.max(2),
                    "seed {seed}: loop {i} TC {} above max_trip {}",
                    tc.max,
                    cfg.max_trip
                );
            }
            // round-trip through the textual form
            let text = pretty::print(&k);
            let k2 = parse_kernel(&text, "<gen>").unwrap_or_else(|e| {
                panic!("seed {seed}: generated kernel failed to reparse:\n{e}\n{text}")
            });
            assert_eq!(k.structural_diff(&k2), None, "seed {seed}");
        }
    }

    #[test]
    fn knobs_bound_the_shape() {
        let cfg = GenConfig {
            seed: 7,
            depth: 2,
            width: 1,
            nests: 1,
            arrays: 3,
            max_trip: 8,
            triangular: 0.0,
            dtype: DType::F32,
        };
        for seed in 0..16 {
            let k = generate(&GenConfig { seed, ..cfg.clone() });
            assert!(k.loops.iter().all(|m| m.depth < 2), "depth bound");
            assert_eq!(k.nest_roots().len(), 1, "nest bound");
            let a = Analysis::new(&k);
            assert!(a.tcs.iter().all(|t| t.max <= 8), "trip bound");
        }
    }
}
