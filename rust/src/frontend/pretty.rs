//! `Kernel` → `.knl` text. The emitter is the inverse of the parser:
//! for every kernel whose array/loop/statement names are identifiers
//! (`[A-Za-z_][A-Za-z0-9_]*` — all in-repo builders and the generator
//! comply; [`print`] asserts it rather than silently emitting text that
//! lexes differently), `parse_kernel(print(k))` is structurally
//! identical to `k` ([`Kernel::structural_diff`] returns `None`) — the
//! round-trip invariant proven over the whole benchmark corpus in
//! `tests/frontend_roundtrip.rs`. The kernel name itself is quoted, so
//! it only needs to avoid `"` and newlines.

use crate::ir::{Access, AffineExpr, Kernel, Node, Stmt};

fn ident_ok(s: &str) -> bool {
    let b = s.as_bytes();
    !b.is_empty()
        && (b[0].is_ascii_alphabetic() || b[0] == b'_')
        && b.iter().all(|&c| c.is_ascii_alphanumeric() || c == b'_')
}

/// Render a kernel as `.knl` source text.
///
/// Panics when a name cannot survive the trip back through the lexer
/// (loud beats silently corrupting the interchange text).
pub fn print(k: &Kernel) -> String {
    assert!(
        !k.name.contains('"') && !k.name.contains('\n'),
        "kernel name {:?} cannot be quoted in .knl",
        k.name
    );
    let mut out = format!(
        "# {} — {} loops, {} statements ({})\n",
        k.name,
        k.n_loops(),
        k.n_stmts(),
        k.summary_ast()
    );
    out.push_str(&format!("kernel \"{}\" {}\n\n", k.name, k.dtype.name()));
    for a in &k.arrays {
        assert!(ident_ok(&a.name), "array name {:?} is not a .knl identifier", a.name);
        let dims: String = a.dims.iter().map(|d| format!("[{d}]")).collect();
        out.push_str(&format!("array {}{dims} {}\n", a.name, a.dir.word()));
    }
    for root in &k.roots {
        out.push('\n');
        print_node(k, root, 0, &mut out);
    }
    out
}

fn indent(depth: usize, out: &mut String) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn print_node(k: &Kernel, n: &Node, depth: usize, out: &mut String) {
    match n {
        Node::Loop(l) => {
            assert!(ident_ok(&l.name), "loop name {:?} is not a .knl identifier", l.name);
            indent(depth, out);
            out.push_str(&format!(
                "for {} in {} .. {} {{\n",
                l.name,
                affine(k, &l.lb),
                affine(k, &l.ub)
            ));
            for c in &l.body {
                print_node(k, c, depth + 1, out);
            }
            indent(depth, out);
            out.push_str("}\n");
        }
        Node::Stmt(s) => {
            assert!(ident_ok(&s.name), "stmt name {:?} is not a .knl identifier", s.name);
            indent(depth, out);
            out.push_str(&format!("stmt {}", s.name));
            if !s.writes.is_empty() {
                out.push_str(" writes ");
                out.push_str(&access_list(k, &s.writes));
            }
            if !s.reads.is_empty() {
                out.push_str(" reads ");
                out.push_str(&access_list(k, &s.reads));
            }
            if !s.ops.is_empty() {
                out.push_str(" ops ");
                let entries: Vec<String> = s
                    .ops
                    .iter()
                    .map(|&(o, c)| {
                        if c == 1 {
                            o.word().to_string()
                        } else {
                            format!("{c}*{}", o.word())
                        }
                    })
                    .collect();
                out.push_str(&entries.join(", "));
            }
            // the chain clause is elided when it is the default expansion
            if s.chain != Stmt::default_chain(&s.ops) {
                out.push_str(" chain ");
                let words: Vec<&str> = s.chain.iter().map(|o| o.word()).collect();
                out.push_str(&words.join(", "));
            }
            out.push_str(";\n");
        }
    }
}

fn access_list(k: &Kernel, accs: &[Access]) -> String {
    let rendered: Vec<String> = accs
        .iter()
        .map(|a| {
            let idx: String = a
                .indices
                .iter()
                .map(|e| format!("[{}]", affine(k, e)))
                .collect();
            format!("{}{idx}", k.array(a.array).name)
        })
        .collect();
    rendered.join(", ")
}

/// Affine expression with loop *names* (the IR `Display` uses raw
/// `L<id>` labels). Same sign/spacing conventions as the parser accepts.
fn affine(k: &Kernel, e: &AffineExpr) -> String {
    let mut out = String::new();
    let mut first = true;
    for &(l, c) in &e.terms {
        let name = k.loop_name(l);
        if first {
            if c == 1 {
                out.push_str(name);
            } else if c == -1 {
                out.push_str(&format!("-{name}"));
            } else {
                out.push_str(&format!("{c}*{name}"));
            }
            first = false;
        } else if c == 1 {
            out.push_str(&format!(" + {name}"));
        } else if c == -1 {
            out.push_str(&format!(" - {name}"));
        } else if c > 0 {
            out.push_str(&format!(" + {c}*{name}"));
        } else {
            out.push_str(&format!(" - {}*{name}", -c));
        }
    }
    if first {
        out.push_str(&format!("{}", e.constant));
    } else if e.constant > 0 {
        out.push_str(&format!(" + {}", e.constant));
    } else if e.constant < 0 {
        out.push_str(&format!(" - {}", -e.constant));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks;
    use crate::frontend::parse_kernel;
    use crate::ir::DType;

    #[test]
    fn gemm_prints_and_reparses() {
        let k = benchmarks::kernel_gemm(60, 70, 80, DType::F32);
        let text = print(&k);
        assert!(text.contains("kernel \"gemm\" f32"), "{text}");
        assert!(text.contains("array C[60][70] inout"), "{text}");
        assert!(text.contains("for i in 0 .. 60 {"), "{text}");
        let k2 = parse_kernel(&text, "<pretty>").unwrap();
        assert_eq!(k.structural_diff(&k2), None);
    }

    #[test]
    fn triangular_bounds_print_with_names() {
        let k = benchmarks::kernel_lu(120, DType::F32);
        let text = print(&k);
        // lu's j0 loop runs [0, i); k0 runs [0, j0); j1 runs [i+1, n)
        assert!(text.contains("for j0 in 0 .. i {"), "{text}");
        let k2 = parse_kernel(&text, "<pretty>").unwrap();
        assert_eq!(k.structural_diff(&k2), None);
    }

    #[test]
    #[should_panic(expected = "not a .knl identifier")]
    fn non_identifier_names_are_rejected_loudly() {
        use crate::ir::{ArrayDir, KernelBuilder, OpKind};
        let mut kb = KernelBuilder::new("bad", DType::F32);
        let a = kb.array("my array", &[4], ArrayDir::Out);
        kb.for_const("i", 0, 4, |kb, i| {
            kb.stmt("s", vec![kb.at(a, &[kb.v(i)])], vec![], &[(OpKind::Add, 1)]);
        });
        print(&kb.finish());
    }

    #[test]
    fn printing_is_stable_under_roundtrip() {
        let k = benchmarks::kernel_2mm(40, 50, 70, 80, DType::F32);
        let t1 = print(&k);
        let t2 = print(&parse_kernel(&t1, "<pretty>").unwrap());
        assert_eq!(t1, t2);
    }
}
