//! Surface AST of the `.knl` DSL — what the parser produces and the
//! random-kernel generator constructs directly (both lower through the
//! same semantic checks in [`super::parser::lower`], so generated
//! kernels are by construction inside the DSL's expressible class).
//!
//! Names are unresolved strings here; lowering resolves iterator names
//! against the enclosing-loop scope and array names against the
//! declaration list, reporting failures against each node's [`Span`].

use super::diag::Span;
use crate::ir::{ArrayDir, DType, OpKind};

#[derive(Clone, Debug)]
pub struct KernelAst {
    pub name: String,
    pub dtype: DType,
    pub arrays: Vec<ArrayAst>,
    pub roots: Vec<LoopAst>,
}

#[derive(Clone, Debug)]
pub struct ArrayAst {
    pub name: String,
    pub dims: Vec<u64>,
    pub dir: ArrayDir,
    pub span: Span,
}

#[derive(Clone, Debug)]
pub enum NodeAst {
    Loop(LoopAst),
    Stmt(StmtAst),
}

#[derive(Clone, Debug)]
pub struct LoopAst {
    pub name: String,
    pub lb: AffAst,
    pub ub: AffAst,
    pub body: Vec<NodeAst>,
    pub span: Span,
}

#[derive(Clone, Debug)]
pub struct StmtAst {
    pub name: String,
    pub writes: Vec<AccessAst>,
    pub reads: Vec<AccessAst>,
    /// `(op, count)` entries, order- and grouping-preserving (the IR
    /// compares `ops` vectors exactly).
    pub ops: Vec<(OpKind, u32)>,
    /// Explicit internal op chain; `None` = the default all-sequential
    /// expansion of `ops`.
    pub chain: Option<Vec<OpKind>>,
    pub span: Span,
}

#[derive(Clone, Debug)]
pub struct AccessAst {
    pub array: String,
    pub indices: Vec<AffAst>,
    pub span: Span,
}

/// An affine expression as written: a signed sum of terms.
#[derive(Clone, Debug, Default)]
pub struct AffAst {
    pub terms: Vec<AffTermAst>,
    pub span: Span,
}

/// One affine term: `coeff * iter`, or a constant when `iter` is `None`.
#[derive(Clone, Debug)]
pub struct AffTermAst {
    pub coeff: i64,
    pub iter: Option<String>,
    pub span: Span,
}

impl AffAst {
    pub fn constant(c: i64) -> AffAst {
        AffAst {
            terms: vec![AffTermAst {
                coeff: c,
                iter: None,
                span: Span::default(),
            }],
            span: Span::default(),
        }
    }

    pub fn var(name: &str) -> AffAst {
        AffAst {
            terms: vec![AffTermAst {
                coeff: 1,
                iter: Some(name.to_string()),
                span: Span::default(),
            }],
            span: Span::default(),
        }
    }

    /// `name + c` (the generator's stencil-offset form).
    pub fn var_plus(name: &str, c: i64) -> AffAst {
        let mut e = AffAst::var(name);
        if c != 0 {
            e.terms.push(AffTermAst {
                coeff: c,
                iter: None,
                span: Span::default(),
            });
        }
        e
    }
}
