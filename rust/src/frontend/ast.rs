//! Surface AST of the `.knl` DSL — what the parser produces and the
//! random-kernel generator constructs directly (both lower through the
//! same semantic checks in `parser::lower`, so generated
//! kernels are by construction inside the DSL's expressible class).
//!
//! Names are unresolved strings here; lowering resolves iterator names
//! against the enclosing-loop scope and array names against the
//! declaration list, reporting failures against each node's [`Span`].

use super::diag::Span;
use crate::ir::{ArrayDir, DType, OpKind};

/// A parsed (un-lowered) kernel: the parser's output, the generator's interchange form.
#[derive(Clone, Debug)]
pub struct KernelAst {
    /// Kernel name (quoted string or identifier in the source).
    pub name: String,
    /// Scalar element type.
    pub dtype: DType,
    /// Array declarations, in source order.
    pub arrays: Vec<ArrayAst>,
    /// Top-level loop nests, in source order.
    pub roots: Vec<LoopAst>,
}

/// One `array name[d0][d1] dir` declaration.
#[derive(Clone, Debug)]
pub struct ArrayAst {
    /// Array identifier.
    pub name: String,
    /// Constant extents, outermost first.
    pub dims: Vec<u64>,
    /// Transfer direction keyword.
    pub dir: ArrayDir,
    /// Source span of the declaration.
    pub span: Span,
}

/// One loop-body item.
#[derive(Clone, Debug)]
pub enum NodeAst {
    /// A nested loop.
    Loop(LoopAst),
    /// A statement.
    Stmt(StmtAst),
}

/// One `for it in lb .. ub { ... }` loop.
#[derive(Clone, Debug)]
pub struct LoopAst {
    /// Iterator identifier.
    pub name: String,
    /// Lower bound (inclusive).
    pub lb: AffAst,
    /// Upper bound (exclusive).
    pub ub: AffAst,
    /// Loops and statements in source order (non-empty after lowering checks).
    pub body: Vec<NodeAst>,
    /// Source span of the loop header.
    pub span: Span,
}

/// One `stmt name writes ... reads ... ops ...;` statement.
#[derive(Clone, Debug)]
pub struct StmtAst {
    /// Statement identifier.
    pub name: String,
    /// Written accesses (at least one required by lowering).
    pub writes: Vec<AccessAst>,
    /// Read accesses.
    pub reads: Vec<AccessAst>,
    /// `(op, count)` entries, order- and grouping-preserving (the IR
    /// compares `ops` vectors exactly).
    pub ops: Vec<(OpKind, u32)>,
    /// Explicit internal op chain; `None` = the default all-sequential
    /// expansion of `ops`.
    pub chain: Option<Vec<OpKind>>,
    /// Source span of the statement.
    pub span: Span,
}

/// One `array[aff]...[aff]` access.
#[derive(Clone, Debug)]
pub struct AccessAst {
    /// Array identifier (resolved during lowering).
    pub array: String,
    /// One affine index per dimension.
    pub indices: Vec<AffAst>,
    /// Source span of the access.
    pub span: Span,
}

/// An affine expression as written: a signed sum of terms.
#[derive(Clone, Debug, Default)]
pub struct AffAst {
    /// Signed terms, in source order.
    pub terms: Vec<AffTermAst>,
    /// Source span of the expression.
    pub span: Span,
}

/// One affine term: `coeff * iter`, or a constant when `iter` is `None`.
#[derive(Clone, Debug)]
pub struct AffTermAst {
    /// Signed coefficient (the sign carries `+`/`-`).
    pub coeff: i64,
    /// Iterator name; `None` for a constant term.
    pub iter: Option<String>,
    /// Source span of the term.
    pub span: Span,
}

impl AffAst {
    /// The constant expression `c`.
    pub fn constant(c: i64) -> AffAst {
        AffAst {
            terms: vec![AffTermAst {
                coeff: c,
                iter: None,
                span: Span::default(),
            }],
            span: Span::default(),
        }
    }

    /// The single-iterator expression `name`.
    pub fn var(name: &str) -> AffAst {
        AffAst {
            terms: vec![AffTermAst {
                coeff: 1,
                iter: Some(name.to_string()),
                span: Span::default(),
            }],
            span: Span::default(),
        }
    }

    /// `name + c` (the generator's stencil-offset form).
    pub fn var_plus(name: &str, c: i64) -> AffAst {
        let mut e = AffAst::var(name);
        if c != 0 {
            e.terms.push(AffTermAst {
                coeff: c,
                iter: None,
                span: Span::default(),
            });
        }
        e
    }
}
