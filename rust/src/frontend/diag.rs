//! Source-span diagnostics for the `.knl` frontend.
//!
//! Every token and AST node carries a byte-offset [`Span`]; when the
//! lexer, parser, or lowering rejects an input, the [`ParseError`] is
//! rendered against the original source with a line/column header and a
//! caret underline — the diagnostic style users of rustc/clang expect:
//!
//! ```text
//! error: unknown iterator `k2` (in scope: i, j)
//!   --> gemm.knl:12:20
//!    |
//! 12 |   stmt S1 writes C[i][k2] reads A[i][k2];
//!    |                       ^^
//! ```

/// A half-open byte range into the source text.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Span {
    /// Byte offset of the span start.
    pub off: u32,
    /// Span length in bytes.
    pub len: u32,
}

impl Span {
    /// Span from byte offset + length.
    pub fn new(off: usize, len: usize) -> Span {
        Span {
            off: off as u32,
            len: len as u32,
        }
    }

    /// The span covering both `self` and `other`.
    pub fn to(self, other: Span) -> Span {
        let off = self.off.min(other.off);
        let end = (self.off + self.len).max(other.off + other.len);
        Span {
            off,
            len: end - off,
        }
    }
}

/// A frontend error: one message anchored to one source span, rendered
/// eagerly (the error outlives the source text it points into).
#[derive(Debug)]
pub struct ParseError {
    /// One-line description (no source context).
    pub msg: String,
    /// Origin label (file path, `<generated>`, `<inline>`).
    pub origin: String,
    /// 1-based source line of the span start.
    pub line: u32,
    /// 1-based source column of the span start.
    pub col: u32,
    rendered: String,
}

impl ParseError {
    /// Render a diagnostic for `span` in `src` eagerly (the error outlives the source).
    pub fn new(src: &str, origin: &str, span: Span, msg: impl Into<String>) -> ParseError {
        let msg = msg.into();
        let (line, col, text) = locate(src, span.off as usize);
        let mut rendered = format!("error: {msg}\n  --> {origin}:{line}:{col}\n");
        // snippet + caret underline (skip when the span points past a
        // source we don't have, e.g. generator-internal lowering)
        if !src.is_empty() {
            let gutter = line.to_string();
            let pad = " ".repeat(gutter.len());
            let avail = (text.len() + 1).saturating_sub(col as usize).max(1);
            let carets = "^".repeat((span.len as usize).clamp(1, avail));
            rendered.push_str(&format!("{pad} |\n{gutter} | {text}\n{pad} | "));
            rendered.push_str(&" ".repeat(col as usize - 1));
            rendered.push_str(&carets);
            rendered.push('\n');
        }
        ParseError {
            msg,
            origin: origin.to_string(),
            line,
            col,
            rendered,
        }
    }
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.rendered.trim_end())
    }
}

impl std::error::Error for ParseError {}

/// Map a byte offset to (1-based line, 1-based column, line text).
fn locate(src: &str, off: usize) -> (u32, u32, String) {
    let off = off.min(src.len());
    let mut line = 1u32;
    let mut line_start = 0usize;
    for (i, b) in src.bytes().enumerate() {
        if i >= off {
            break;
        }
        if b == b'\n' {
            line += 1;
            line_start = i + 1;
        }
    }
    let text: String = src[line_start..].lines().next().unwrap_or("").to_string();
    let col = (off - line_start) as u32 + 1;
    (line, col, text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn locates_line_and_column() {
        let src = "kernel \"x\" f32\narray a[4] in\nfor i in 0 .. 4 {\n";
        let e = ParseError::new(src, "x.knl", Span::new(21, 3), "boom");
        assert_eq!(e.line, 2);
        assert_eq!(e.col, 7);
        let s = format!("{e}");
        assert!(s.contains("error: boom"), "{s}");
        assert!(s.contains("x.knl:2:7"), "{s}");
        assert!(s.contains("array a[4] in"), "{s}");
        assert!(s.contains('^'), "{s}");
    }

    #[test]
    fn span_union() {
        let a = Span::new(4, 2);
        let b = Span::new(10, 3);
        assert_eq!(a.to(b), Span::new(4, 9));
        assert_eq!(b.to(a), Span::new(4, 9));
    }

    #[test]
    fn tolerates_offset_past_end() {
        let e = ParseError::new("ab", "x", Span::new(99, 1), "eof");
        assert_eq!(e.line, 1);
        assert!(format!("{e}").contains("error: eof"));
    }
}
