//! The specialized global NLP solver (the repo's stand-in for BARON).
//!
//! Structure exploited: for a fixed pipeline configuration the objective
//! decomposes per loop nest (sum- or max-combined per dependences), the
//! only cross-nest couplings being array partitioning (Eq 13, a monotone
//! per-dimension max) and the DSP budget (Eq 11, max over nests in the
//! optimistic model ⇒ separable). The solver therefore:
//!
//! 1. prunes whole pipeline configurations by **interval propagation over
//!    the symbolic bound model** (`BoundModel::lower_bound` on the
//!    config's partial design) before any candidate is generated;
//! 2. enumerates per-nest candidate UF assignments over the divisor
//!    lattice (Eqs 1/6/8/9/15 enforced during generation);
//! 3. scores candidates in bulk — through the XLA batch evaluator when one
//!    is plugged in (`BatchEvaluator`), else the Rust feature evaluator or
//!    the compiled symbolic tape ([`SymbolicEvaluator`]);
//! 4. branch-and-bounds across nests with an admissible bound (scores are
//!    themselves lower bounds) and monotone partitioning pruning;
//! 5. verifies leaves with the shared constraint set + compiled objective
//!    before accepting an incumbent.
//!
//! The accounting distinguishes relaxation-bound prunes
//! (`pruned_bound` / `pruned_relaxation`) from constraint-infeasible
//! rejections (`infeasible`), which earlier versions conflated (leaf
//! rejections were simply invisible).
//!
//! Anytime behaviour: on budget exhaustion the best incumbent is returned
//! with `optimal = false`, plus the proven lower bound — exactly what
//! Algorithm 1 consumes for pruning.

use super::formulation::NlpProblem;
use crate::ir::LoopId;
use crate::model;
use crate::model::sym::PartialDesign;
use crate::pragma::{space, Design, PipelineConfig};
use std::time::Instant;

/// Bulk lower-bound scoring interface. `runtime::XlaEvaluator` implements
/// this over the AOT artifact; [`RustFeatureEvaluator`] is the in-process
/// fallback with identical semantics.
pub trait BatchEvaluator {
    /// Returns `(latency_lb, dsp)` per design.
    fn eval_batch(&self, problem: &NlpProblem, designs: &[Design]) -> Vec<(f64, f64)>;
}

/// Fallback evaluator: the Rust reference implementation of the feature
/// formula (same ABI semantics as the XLA artifact).
pub struct RustFeatureEvaluator;

impl BatchEvaluator for RustFeatureEvaluator {
    fn eval_batch(&self, p: &NlpProblem, designs: &[Design]) -> Vec<(f64, f64)> {
        designs
            .iter()
            .map(|d| {
                match model::encode_design(p.kernel, p.analysis, p.device, d) {
                    Some(f) => model::eval_features(&f),
                    None => {
                        let r = model::evaluate(p.kernel, p.analysis, p.device, d);
                        (r.total_cycles, r.dsp)
                    }
                }
            })
            .collect()
    }
}

/// Batch evaluator backed by the problem's compiled symbolic bound model:
/// exact model scores (not the feature under-approximation) at flattened
/// tape speed, with zero per-design allocation.
pub struct SymbolicEvaluator;

impl BatchEvaluator for SymbolicEvaluator {
    fn eval_batch(&self, p: &NlpProblem, designs: &[Design]) -> Vec<(f64, f64)> {
        p.compiled
            .evaluate_batch(designs)
            .into_iter()
            .map(|r| (r.total_cycles, r.dsp))
            .collect()
    }
}

#[derive(Clone, Debug, Default)]
pub struct SolverStats {
    pub nodes: u64,
    pub leaves: u64,
    /// Branch-and-bound nodes cut by the admissible candidate bound.
    pub pruned_bound: u64,
    /// Whole pipeline configurations cut by symbolic interval relaxation
    /// before candidate generation.
    pub pruned_relaxation: u64,
    pub pruned_partition: u64,
    /// Nodes rejected by the constraint check (infeasible leaves and
    /// configurations with no legal candidate) — reported separately from
    /// the relaxation prunes they used to be conflated with.
    pub infeasible: u64,
    pub candidates_scored: u64,
    pub configs: u64,
}

#[derive(Clone, Debug)]
pub struct SolveResult {
    /// Best feasible designs found, ascending objective (≤ `topk`).
    pub designs: Vec<(Design, f64)>,
    /// Proven lower bound on the optimum over the sub-space.
    pub lower_bound: f64,
    /// Whether the search completed within budget.
    pub optimal: bool,
    pub solve_time_s: f64,
    pub stats: SolverStats,
}

impl SolveResult {
    /// Best feasible design found, if any. `None` means every candidate
    /// was cut — consult [`Self::pruned_by_relaxation`] vs
    /// [`Self::infeasible_nodes`] to see whether bounds or constraints
    /// emptied the search.
    pub fn best(&self) -> Option<&(Design, f64)> {
        self.designs.first()
    }

    /// Nodes cut by relaxation bounds (admissible b&b bound + symbolic
    /// interval config prunes).
    pub fn pruned_by_relaxation(&self) -> u64 {
        self.stats.pruned_bound + self.stats.pruned_relaxation
    }

    /// Nodes rejected as constraint-infeasible.
    pub fn infeasible_nodes(&self) -> u64 {
        self.stats.infeasible
    }
}

/// Per-nest candidate: the free-loop UF assignment and its additive
/// latency contribution + partitioning/DSP signature.
struct Cand {
    ufs: Vec<(LoopId, u64)>,
    lat: f64,
    /// product of coarse (above-pipe, non-innermost) factors — the
    /// realization-risk tie-break key
    risk: f64,
    /// per (array, dim) UF maxima contributed by this nest
    part: Vec<((u32, usize), u64)>,
}

/// Solve one NLP instance.
pub fn solve(
    problem: &NlpProblem,
    timeout_s: f64,
    topk: usize,
    evaluator: &dyn BatchEvaluator,
) -> SolveResult {
    let t0 = Instant::now();
    let mut stats = SolverStats::default();
    let k = problem.kernel;
    let cap = problem.partition_cap();
    let nests = k.nest_roots();

    let mut best: Vec<(Design, f64, f64)> = Vec::new();
    let mut proven_lb = f64::INFINITY;
    let mut optimal = true;

    // baseline per-nest latencies for the empty design (score extraction)
    let empty = Design::empty(k);
    let base = model::nest_latencies(k, problem.analysis, problem.device, &empty);

    // per-nest candidate sets depend only on the pipeline choice *within*
    // that nest — cache them across the cross-product of configs (§Perf
    // iteration 3: 3mm has 64 configs but only 12 distinct nest options)
    let mut cand_cache: std::collections::BTreeMap<(u32, Vec<u32>), std::rc::Rc<Vec<Cand>>> =
        Default::default();

    for cfg in problem.space.pipeline_configs.clone() {
        stats.configs += 1;
        if t0.elapsed().as_secs_f64() > timeout_s {
            optimal = false;
            break;
        }

        // ---- symbolic interval relaxation over the whole config ------------
        // With the pipeline fixed and the structural Eq 9/15 assignments
        // applied, every UF left free is relaxed to its interval hull; if
        // even that optimistic completion cannot enter the top-k (compared
        // against the *k-th* incumbent, so runners-up are never lost), the
        // config is pruned before any candidate is generated.
        if best.len() >= topk {
            let incumbent = best.last().map(|b| b.1).unwrap_or(f64::INFINITY);
            let partial = config_partial(problem, &cfg);
            let iv_lb = problem.bound.lower_bound(&partial);
            if iv_lb > incumbent * (1.0 + 1e-9) {
                stats.pruned_relaxation += 1;
                continue;
            }
        }

        // ---- per-nest candidate generation (cached) ------------------------
        let mut per_nest: Vec<std::rc::Rc<Vec<Cand>>> = Vec::new();
        let mut infeasible_cfg = false;
        for (ni, &root) in nests.iter().enumerate() {
            let nest_loops = k.nest_loops(root);
            let mut local: Vec<u32> = cfg
                .pipelined
                .iter()
                .filter(|l| nest_loops.contains(l))
                .map(|l| l.0)
                .collect();
            local.sort_unstable();
            let key = (root.0, local);
            let cands = cand_cache
                .entry(key)
                .or_insert_with(|| {
                    std::rc::Rc::new(nest_candidates(
                        problem, &cfg, root, cap, evaluator, &base, ni, &mut stats,
                    ))
                })
                .clone();
            if cands.is_empty() {
                infeasible_cfg = true;
                break;
            }
            per_nest.push(cands);
        }
        if infeasible_cfg {
            stats.infeasible += 1;
            continue;
        }

        // config-level relaxation bound: combine per-nest minima
        let min_lats: Vec<f64> = per_nest
            .iter()
            .map(|c| c.iter().map(|x| x.lat).fold(f64::INFINITY, f64::min))
            .collect();
        let cfg_lb = combine(&min_lats, base.sum_combine) + base.comm;
        proven_lb = proven_lb.min(cfg_lb);
        // compare against the *k-th* incumbent (not the #1): a config whose
        // optimum lies between best[0] and best[k-1] still owes the caller
        // a runner-up. Strict comparison with tolerance: configs that
        // *tie* may still win the risk tie-break on the work-floor plateau
        // (Theorem 4.4).
        let incumbent = best.last().map(|b| b.1).unwrap_or(f64::INFINITY);
        if cfg_lb > incumbent * (1.0 + 1e-9) && best.len() >= topk {
            continue; // config cannot enter the top-k
        }

        // ---- branch and bound across nests --------------------------------
        let per_nest: Vec<&[Cand]> = per_nest.iter().map(|r| r.as_slice()).collect();
        let mut chosen: Vec<usize> = vec![0; per_nest.len()];
        // bounds plateau tie-exploration; once the incumbent list is full
        // of risk-free ties nothing better exists (§Perf iteration 2)
        let mut leaf_budget: i64 = if best.len() >= topk
            && best.iter().all(|b| b.2 <= 1.0 + 1e-9)
        {
            0
        } else {
            1_500
        };
        bb(
            problem,
            &cfg,
            &per_nest,
            &min_lats,
            base.sum_combine,
            base.comm,
            0,
            &mut chosen,
            &mut Vec::new(),
            &mut best,
            topk,
            t0,
            timeout_s,
            &mut optimal,
            &mut stats,
            &mut leaf_budget,
        );
    }

    best.sort_by(|a, b| {
        let rel = (a.1 - b.1).abs() / a.1.abs().max(1.0);
        if rel < 1e-9 {
            a.2.partial_cmp(&b.2).unwrap()
        } else {
            a.1.partial_cmp(&b.1).unwrap()
        }
    });
    best.truncate(topk);
    if let Some(b) = best.first() {
        // the optimum can't be below the proven relaxation, nor above the
        // incumbent
        proven_lb = proven_lb.min(b.1);
    }
    SolveResult {
        designs: best.into_iter().map(|(d, o, _)| (d, o)).collect(),
        lower_bound: proven_lb,
        optimal,
        solve_time_s: t0.elapsed().as_secs_f64(),
        stats,
    }
}

fn combine(lats: &[f64], sum: bool) -> f64 {
    if sum {
        lats.iter().sum()
    } else {
        lats.iter().cloned().fold(0.0, f64::max)
    }
}

/// The partial design describing one pipeline configuration's sub-space:
/// `pip` fixed per the config, the structurally forced UFs assigned
/// (Eq 15 full unroll under the pipe, Eq 9 / Theorem 4.11 / Merlin bans
/// above it — mirroring `nest_candidates`' menu rules), every other UF
/// left free for interval relaxation, capped by the partitioning rung.
fn config_partial(problem: &NlpProblem, cfg: &PipelineConfig) -> PartialDesign {
    let k = problem.kernel;
    let a = problem.analysis;
    let mut p = PartialDesign::free(k.n_loops()).with_uf_cap(problem.partition_cap());
    for i in 0..k.n_loops() {
        let l = LoopId(i as u32);
        p.assign_pipeline(l, cfg.pipelined.contains(&l));
        p.assign_tile(l, 1); // the solver explores tile = 1 (caching is Merlin-auto)
        let info = &a.deps.per_loop[i];
        let tc = &a.tcs[i];
        let pipelined_here = cfg.pipelined.contains(&l);
        let under_pipe = cfg.pipelined.iter().any(|&pp| k.is_under(l, pp));
        if pipelined_here {
            continue; // UF free (space menu)
        }
        if under_pipe {
            if info.reduction {
                // tree-unroll factor stays free
            } else if info.serializing {
                p.assign_uf(l, 1);
            } else if tc.is_constant() {
                p.assign_uf(l, tc.max.max(1)); // Eq 15
            } else {
                p.assign_uf(l, 1);
            }
        } else {
            // above the pipeline
            if problem.fine_grained_only
                || info.reduction
                || info.serializing
                || problem.coarse_banned.contains(&l.0)
            {
                p.assign_uf(l, 1);
            }
        }
    }
    p
}

/// Generate + score candidates for one nest under one pipeline config.
#[allow(clippy::too_many_arguments)]
fn nest_candidates(
    problem: &NlpProblem,
    cfg: &PipelineConfig,
    root: LoopId,
    cap: u64,
    evaluator: &dyn BatchEvaluator,
    base: &model::NestBreakdown,
    nest_idx: usize,
    stats: &mut SolverStats,
) -> Vec<Cand> {
    let k = problem.kernel;
    let a = problem.analysis;
    let nest_loops = k.nest_loops(root);

    // free loops and their UF menus
    let mut free: Vec<(LoopId, Vec<u64>)> = Vec::new();
    for &l in &nest_loops {
        let info = a.deps.loop_info(l);
        let tc = a.tc(l);
        let pipelined_here = cfg.pipelined.contains(&l);
        let under_pipe = cfg.pipelined.iter().any(|&p| k.is_under(l, p));
        let above_pipe = !pipelined_here && !under_pipe;
        if !tc.is_constant() {
            continue; // not unrollable
        }
        let menu: Vec<u64> = if pipelined_here {
            problem.space.ufs(l, a, cap)
        } else if under_pipe {
            if info.reduction {
                // tree-reduction unroll factor is free (Section 5.4's
                // TC/uf × log2(uf) term)
                problem.space.ufs(l, a, cap)
            } else {
                continue; // parallel under pipe: forced full (Eq 15)
            }
        } else if above_pipe {
            if problem.fine_grained_only
                || info.reduction
                || info.serializing
                || problem.coarse_banned.contains(&l.0)
            {
                continue; // Eq 9, coarse-grain illegal (Theorem 4.11), or
                          // Merlin already refused this loop in this run
            }
            problem.space.ufs(l, a, cap)
        } else {
            continue;
        };
        if menu.len() > 1 {
            free.push((l, menu));
        }
    }

    // cartesian product (bounded: divisor sets are small)
    let mut assignments: Vec<Vec<(LoopId, u64)>> = vec![vec![]];
    for (l, menu) in &free {
        let mut next = Vec::with_capacity(assignments.len() * menu.len());
        for base_a in &assignments {
            for &u in menu {
                let mut v = base_a.clone();
                v.push((*l, u));
                next.push(v);
            }
        }
        assignments = next;
        if assignments.len() > 200_000 {
            break; // runaway product guard; menus stay partial but valid
        }
    }

    // materialize candidate designs (only this nest assigned) + prefilter
    // by per-nest partitioning
    let mut designs: Vec<Design> = Vec::new();
    let mut metas: Vec<(Vec<(LoopId, u64)>, Vec<((u32, usize), u64)>)> = Vec::new();
    for asg in assignments {
        let d = space::materialize(
            k,
            a,
            &PipelineConfig {
                pipelined: cfg
                    .pipelined
                    .iter()
                    .copied()
                    .filter(|&p| nest_loops.contains(&p))
                    .collect(),
            },
            &|l| {
                asg.iter()
                    .find(|(al, _)| *al == l)
                    .map(|&(_, u)| u)
                    .unwrap_or(1)
            },
            &|_| 1,
        );
        // restrict materialization to this nest: zero out other nests
        let mut d2 = Design::empty(k);
        for &l in &nest_loops {
            d2.pragmas[l.0 as usize] = d.pragmas[l.0 as usize];
        }
        // per-nest partitioning signature + cap check
        let mut part: std::collections::BTreeMap<(u32, usize), u64> = Default::default();
        let mut ok = true;
        for arr in &k.arrays {
            let p = d2.partitioning(k, arr.id);
            if p > cap {
                ok = false;
                break;
            }
            for s in k.stmts() {
                for (acc, _) in k.stmt_accesses(s.id) {
                    if acc.array != arr.id {
                        continue;
                    }
                    for (dim, idx) in acc.indices.iter().enumerate() {
                        for l in idx.loops() {
                            let uf = d2.get(l).uf;
                            if uf > 1 {
                                let e = part.entry((arr.id.0, dim)).or_insert(1);
                                *e = (*e).max(uf);
                            }
                        }
                    }
                }
            }
        }
        if !ok {
            continue;
        }
        designs.push(d2);
        metas.push((asg, part.into_iter().collect()));
    }
    if designs.is_empty() {
        return vec![];
    }

    // bulk score (lower bounds) — XLA artifact when plugged in
    let scores = evaluator.eval_batch(problem, &designs);
    stats.candidates_scored += designs.len() as u64;

    // extract additive per-nest latency from the total score:
    // total = Σ_m≠n base[m] + lat_n + comm   (sum-combine)
    let others: f64 = if base.sum_combine {
        base.per_nest
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != nest_idx)
            .map(|(_, &x)| x)
            .sum()
    } else {
        0.0
    };

    let mut out: Vec<Cand> = designs
        .into_iter()
        .zip(metas)
        .zip(scores)
        .filter_map(|((d, (ufs, part)), (score, dsp))| {
            // per-nest DSP prefilter (Eq 11 is max-over-nests separable)
            if dsp > problem.device.dsp_total as f64 {
                return None;
            }
            let lat = if base.sum_combine {
                (score - base.comm - others).max(0.0)
            } else {
                // max-combine: recompute the nest latency precisely
                model::nest_latencies(k, a, problem.device, &d).per_nest[nest_idx]
            };
            let risk: f64 = ufs
                .iter()
                .map(|&(l, u)| {
                    let meta = k.loop_meta(l);
                    let under = cfg.pipelined.iter().any(|&p| k.is_under(l, p));
                    let at = cfg.pipelined.contains(&l);
                    if u > 1 && !meta.innermost && !at && !under {
                        u as f64
                    } else {
                        1.0
                    }
                })
                .product();
            Some(Cand { ufs, lat, risk, part })
        })
        .collect();
    // ascending latency; equal-latency candidates ordered by realization
    // risk so plateau ties are found low-risk-first (§Perf iteration 4)
    out.sort_by(|x, y| {
        x.lat
            .partial_cmp(&y.lat)
            .unwrap()
            .then(x.risk.partial_cmp(&y.risk).unwrap())
    });
    // keep a deep-but-bounded front (ascending latency)
    out.truncate(4096);
    out
}

/// Recursive branch-and-bound across nests.
#[allow(clippy::too_many_arguments)]
fn bb(
    problem: &NlpProblem,
    cfg: &PipelineConfig,
    per_nest: &[&[Cand]],
    min_lats: &[f64],
    sum_combine: bool,
    comm: f64,
    depth: usize,
    chosen: &mut Vec<usize>,
    part_stack: &mut Vec<((u32, usize), u64)>,
    best: &mut Vec<(Design, f64, f64)>,
    topk: usize,
    t0: Instant,
    timeout_s: f64,
    optimal: &mut bool,
    stats: &mut SolverStats,
    leaf_budget: &mut i64,
) {
    if t0.elapsed().as_secs_f64() > timeout_s {
        *optimal = false;
        return;
    }
    stats.nodes += 1;
    // anytime node budget per solve (BARON-style): beyond it, return the
    // incumbent and report non-optimality — Table 7's timeout behaviour
    if stats.nodes > 1_500_000 {
        *optimal = false;
        return;
    }
    let incumbent = if best.len() >= topk {
        best.last().map(|b| b.1).unwrap_or(f64::INFINITY)
    } else {
        f64::INFINITY
    };

    if depth == per_nest.len() {
        stats.leaves += 1;
        *leaf_budget -= 1;
        // materialize the full design and verify precisely
        let d = leaf_design(problem, cfg, per_nest, chosen);
        let Some(obj) = problem.check_objective(&d) else {
            stats.infeasible += 1;
            return;
        };
        // the Theorem 4.4 work floor creates objective plateaus; among
        // equal-latency solutions prefer the one with the least *risky*
        // parallelism: coarse-grained factors above the pipeline are the
        // pragmas Merlin most often refuses (Section 7.5), while fine
        // under-pipe unrolls apply reliably — lexicographic
        // (objective, Π coarse-UF) ordering
        let par: f64 = d
            .pragmas
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let l = crate::ir::LoopId(i as u32);
                let coarse = !problem.kernel.loop_meta(l).innermost
                    && !p.pipeline
                    && problem.kernel.loop_meta(l).children.len()
                        + usize::from(!problem.kernel.loop_meta(l).innermost)
                        > 0
                    && d.pipeline_above(problem.kernel, l) != Some(l)
                    && !d
                        .pipelined()
                        .any(|pl| problem.kernel.is_under(l, pl));
                if coarse {
                    p.uf.max(1) as f64
                } else {
                    1.0
                }
            })
            .product();
        if obj < incumbent * (1.0 + 1e-9) {
            if !best.iter().any(|(bd, ..)| *bd == d) {
                best.push((d, obj, par));
                best.sort_by(|a, b| {
                    let rel = (a.1 - b.1).abs() / a.1.abs().max(1.0);
                    if rel < 1e-9 {
                        a.2.partial_cmp(&b.2).unwrap()
                    } else {
                        a.1.partial_cmp(&b.1).unwrap()
                    }
                });
                best.truncate(topk);
            }
        }
        return;
    }

    for (ci, cand) in per_nest[depth].iter().enumerate() {
        // admissible bound: chosen lats + this cand + per-nest minima below
        let mut lats: Vec<f64> = (0..depth)
            .map(|i| per_nest[i][chosen[i]].lat)
            .collect();
        lats.push(cand.lat);
        lats.extend(min_lats.iter().skip(depth + 1));
        let bound = combine(&lats, sum_combine) + comm;
        // while leaf budget remains, ties with the incumbent are explored
        // (risk tie-break on the plateau); afterwards only strict
        // improvements descend
        let cutoff = if *leaf_budget > 0 {
            incumbent * (1.0 + 1e-9)
        } else {
            incumbent
        };
        if bound > cutoff || (bound >= incumbent && *leaf_budget <= 0) {
            stats.pruned_bound += 1;
            break; // candidates sorted ascending → all following worse
        }
        // monotone partitioning pruning: merge the candidate's per-
        // (array, dim) UF maxima into the stack view and check every
        // touched array's cross-dimension product (Eq 13)
        let cap = problem.partition_cap();
        let mut violated = false;
        if !part_stack.is_empty() && !cand.part.is_empty() {
            let mut merged: std::collections::BTreeMap<(u32, usize), u64> = Default::default();
            for &(key, uf) in part_stack.iter() {
                let e = merged.entry(key).or_insert(1);
                *e = (*e).max(uf);
            }
            for &((arr, dim), uf) in &cand.part {
                let e = merged.entry((arr, dim)).or_insert(1);
                *e = (*e).max(uf);
            }
            let mut per_arr: std::collections::BTreeMap<u32, u64> = Default::default();
            for (&(arr, _dim), &uf) in &merged {
                let e = per_arr.entry(arr).or_insert(1);
                *e = e.saturating_mul(uf);
            }
            if per_arr.values().any(|&p| p > cap) {
                violated = true;
            }
        }
        if violated {
            stats.pruned_partition += 1;
            continue;
        }
        chosen[depth] = ci;
        let pushed = cand.part.len();
        part_stack.extend(cand.part.iter().copied());
        bb(
            problem, cfg, per_nest, min_lats, sum_combine, comm, depth + 1, chosen, part_stack,
            best, topk, t0, timeout_s, optimal, stats, leaf_budget,
        );
        part_stack.truncate(part_stack.len() - pushed);
        if t0.elapsed().as_secs_f64() > timeout_s {
            *optimal = false;
            return;
        }
    }
}

/// Build the full design from the chosen per-nest candidates.
fn leaf_design(
    problem: &NlpProblem,
    cfg: &PipelineConfig,
    per_nest: &[&[Cand]],
    chosen: &[usize],
) -> Design {
    let k = problem.kernel;
    let a = problem.analysis;
    let mut ufs: std::collections::BTreeMap<LoopId, u64> = Default::default();
    for (ni, cands) in per_nest.iter().enumerate() {
        for &(l, u) in &cands[chosen[ni]].ufs {
            ufs.insert(l, u);
        }
    }
    space::materialize(
        k,
        a,
        cfg,
        &|l| ufs.get(&l).copied().unwrap_or(1),
        &|_| 1,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{self, Size};
    use crate::hls::Device;
    use crate::ir::DType;
    use crate::poly::Analysis;

    fn solve_kernel(name: &str, size: Size, cap: u64, fine: bool) -> (SolveResult, f64) {
        let k = benchmarks::build(name, size, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let p = NlpProblem::new(&k, &a, &dev, cap, fine);
        let empty_obj = p.objective(&Design::empty(&k));
        let r = solve(&p, 30.0, 4, &RustFeatureEvaluator);
        (r, empty_obj)
    }

    #[test]
    fn solver_finds_feasible_better_than_empty() {
        for name in ["gemm", "bicg", "atax", "mvt"] {
            let (r, empty_obj) = solve_kernel(name, Size::Small, 512, false);
            let (d, obj) = r.best().expect(name).clone();
            assert!(obj < empty_obj * 0.5, "{name}: {obj} vs empty {empty_obj}");
            assert!(d.pipelined().count() >= 1 || d.pragmas.iter().any(|p| p.uf > 1));
            assert!(r.lower_bound <= obj + 1.0);
        }
    }

    #[test]
    fn solver_matches_bruteforce_on_tiny_space() {
        // small gemm with tight partition cap → tiny space; brute-force the
        // same space definition and compare optima
        let k = benchmarks::kernel_gemm(8, 8, 8, DType::F32);
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let p = NlpProblem::new(&k, &a, &dev, 64, false);
        let r = solve(&p, 30.0, 1, &RustFeatureEvaluator);
        let best = r.best().unwrap().1;

        // brute force over the full valid space
        let space = crate::pragma::Space::new(&k, &a);
        let mut bf = f64::INFINITY;
        for cfg in &space.pipeline_configs {
            let free: Vec<LoopId> = k
                .nest_loops(k.nest_roots()[0])
                .into_iter()
                .collect();
            // enumerate UF assignments over all loops crudely
            let menus: Vec<Vec<u64>> = free
                .iter()
                .map(|&l| space.ufs(l, &a, 64))
                .collect();
            let mut idx = vec![0usize; menus.len()];
            loop {
                let d = crate::pragma::space::materialize(
                    &k,
                    &a,
                    cfg,
                    &|l| {
                        free.iter()
                            .position(|&x| x == l)
                            .map(|i| menus[i][idx[i]])
                            .unwrap_or(1)
                    },
                    &|_| 1,
                );
                if p.check(&d).is_empty() {
                    bf = bf.min(p.objective(&d));
                }
                // odometer
                let mut c = 0;
                loop {
                    if c == menus.len() {
                        break;
                    }
                    idx[c] += 1;
                    if idx[c] < menus[c].len() {
                        break;
                    }
                    idx[c] = 0;
                    c += 1;
                }
                if c == menus.len() {
                    break;
                }
            }
        }
        assert!(
            (best - bf).abs() / bf < 1e-9,
            "solver {best} vs brute force {bf}"
        );
    }

    #[test]
    fn fine_grained_mode_restricts_coarse() {
        let (r, _) = solve_kernel("gemm", Size::Small, 512, true);
        let (d, _) = r.best().unwrap();
        // Eq 9: loops above the pipeline must have UF = 1
        let k = benchmarks::build("gemm", Size::Small, DType::F32).unwrap();
        for lp in d.pipelined() {
            let mut cur = k.loop_meta(lp).parent;
            while let Some(l) = cur {
                assert_eq!(d.get(l).uf, 1, "coarse UF above pipeline in fine mode");
                cur = k.loop_meta(l).parent;
            }
        }
    }

    #[test]
    fn partition_ladder_monotone() {
        // smaller cap → can't be faster
        let (r512, _) = solve_kernel("gemm", Size::Small, 512, false);
        let (r8, _) = solve_kernel("gemm", Size::Small, 8, false);
        let b512 = r512.best().unwrap().1;
        let b8 = r8.best().unwrap().1;
        assert!(b512 <= b8 * 1.0001, "cap 512 {b512} vs cap 8 {b8}");
    }

    #[test]
    fn solutions_respect_all_constraints() {
        for name in ["2mm", "gesummv", "doitgen"] {
            let k = benchmarks::build(name, Size::Small, DType::F32).unwrap();
            let a = Analysis::new(&k);
            let dev = Device::u200();
            let p = NlpProblem::new(&k, &a, &dev, 256, false);
            let r = solve(&p, 30.0, 4, &RustFeatureEvaluator);
            for (d, _) in &r.designs {
                assert!(p.check(d).is_empty(), "{name}: infeasible result");
            }
        }
    }

    #[test]
    fn symbolic_evaluator_matches_rust_evaluator_best() {
        // exact-model scoring may reorder candidate fronts, but the leaf
        // verification is the same compiled objective, so the optimum on a
        // small exhaustive space must agree
        let k = benchmarks::kernel_gemm(8, 8, 8, DType::F32);
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let p = NlpProblem::new(&k, &a, &dev, 64, false);
        let r1 = solve(&p, 30.0, 1, &RustFeatureEvaluator);
        let r2 = solve(&p, 30.0, 1, &SymbolicEvaluator);
        let (b1, b2) = (r1.best().unwrap().1, r2.best().unwrap().1);
        assert!(
            (b1 - b2).abs() / b1.max(1.0) < 1e-9,
            "rust {b1} vs symbolic {b2}"
        );
    }

    #[test]
    fn stats_separate_relaxation_prunes_from_infeasible() {
        // a tight partition cap forces the b&b to cut something
        let k = benchmarks::build("2mm", Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let p = NlpProblem::new(&k, &a, &dev, 8, false);
        let r = solve(&p, 30.0, 2, &RustFeatureEvaluator);
        assert!(r.best().is_some());
        assert!(
            r.pruned_by_relaxation() + r.stats.pruned_partition + r.stats.infeasible > 0,
            "{:?}",
            r.stats
        );
    }

    #[test]
    fn config_partial_bound_admissible_for_solver_designs() {
        // guards the hand-mirrored Eq 9/15 rules in `config_partial`
        // against drift from `space::materialize`/`nest_candidates`: for
        // every design the real solver returns, the interval bound of its
        // pipeline config's partial design must not exceed the design's
        // own objective — if `config_partial` ever pins a pragma the
        // candidate space actually leaves free (or vice versa), this
        // inequality is the first thing to break
        for (name, fine) in [
            ("gemm", false),
            ("gemm", true),
            ("2mm", false),
            ("seidel-2d", false),
        ] {
            let k = benchmarks::build(name, Size::Small, DType::F32).unwrap();
            let a = Analysis::new(&k);
            let dev = Device::u200();
            let p = NlpProblem::new(&k, &a, &dev, 512, fine);
            let r = solve(&p, 30.0, 4, &RustFeatureEvaluator);
            for (d, obj) in &r.designs {
                let cfg = PipelineConfig {
                    pipelined: d.pipelined().collect(),
                };
                let partial = config_partial(&p, &cfg);
                let lb = p.bound.lower_bound(&partial);
                assert!(
                    lb <= obj * (1.0 + 1e-9),
                    "{name} fine={fine}: config bound {lb} beats returned design {obj} ({})",
                    d.fingerprint()
                );
            }
        }
    }

    #[test]
    fn infeasible_counter_fires_when_no_design_is_legal() {
        // zero DSP budget: every candidate/leaf violates Eq 11, so the
        // search must come back empty with the rejections accounted as
        // infeasible — not silently dropped, not counted as bound prunes
        let k = benchmarks::build("gemm", Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let mut dev = Device::u200();
        dev.dsp_total = 0;
        let p = NlpProblem::new(&k, &a, &dev, u64::MAX, false);
        let r = solve(&p, 30.0, 2, &RustFeatureEvaluator);
        assert!(r.best().is_none());
        assert!(r.infeasible_nodes() > 0, "{:?}", r.stats);
        assert_eq!(r.stats.pruned_relaxation, 0, "{:?}", r.stats);
    }

    #[test]
    fn timeout_returns_anytime_result() {
        let k = benchmarks::build("3mm", Size::Medium, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let p = NlpProblem::new(&k, &a, &dev, u64::MAX, false);
        let r = solve(&p, 0.000001, 1, &RustFeatureEvaluator);
        assert!(!r.optimal);
        assert!(r.lower_bound.is_finite() || r.designs.is_empty());
    }
}
