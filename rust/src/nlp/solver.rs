//! The specialized global NLP solver (the repo's stand-in for BARON) —
//! multi-threaded end to end.
//!
//! Structure exploited: for a fixed pipeline configuration the objective
//! decomposes per loop nest (sum- or max-combined per dependences), the
//! only cross-nest couplings being array partitioning (Eq 13, a monotone
//! per-dimension max) and the DSP budget (Eq 11, max over nests in the
//! optimistic model ⇒ separable). The solver therefore:
//!
//! 1. prunes whole pipeline configurations by **interval propagation over
//!    the symbolic bound model** (`BoundModel::lower_bound` on the
//!    config's partial design) before any candidate is generated;
//! 2. enumerates per-nest candidate UF assignments over the divisor
//!    lattice with an **odometer** (Eqs 1/6/8/9/15 enforced during
//!    generation; the runaway-product guard truncates after a fixed
//!    number of complete assignments and records it in
//!    [`SolverStats::truncated_menus`]);
//! 3. scores candidates in bulk — through the XLA batch evaluator when one
//!    is plugged in (`BatchEvaluator`), else the Rust feature evaluator or
//!    the compiled symbolic tape ([`SymbolicEvaluator`]);
//! 4. branch-and-bounds across nests with an admissible bound (scores are
//!    themselves lower bounds) and monotone partitioning pruning;
//! 5. verifies leaves with the shared constraint set + compiled objective
//!    before accepting an incumbent.
//!
//! ## Parallel work sharing ([`solve_jobs`])
//!
//! Pipeline configurations are **embarrassingly parallel**: a scoped
//! worker team processes them with **work stealing**. Before any worker
//! starts, every configuration's interval-relaxation bound is computed in
//! one batched laned sweep (`BoundModel::lower_bound_batch`) and the
//! configs are sorted **bound-ascending**; the sorted order is dealt
//! round-robin in `STEAL_CHUNK`-sized chunks into per-worker deques.
//! Each worker pops from its own deque's front (best bounds first — good
//! incumbents land early, so the cross-worker guard starts cutting
//! sooner); a worker whose deque runs dry steals the *back* half of the
//! first non-empty victim deque, so stragglers stuck on a menu-bomb
//! config no longer strand the rest of their chunk (the old single
//! `fetch_add` counter had no such recourse: work was claimed one config
//! at a time, but a skewed config still serialized everything dealt
//! behind it on the same counter — here the remaining configs just get
//! stolen). Per-nest candidate menus are shared across workers through a
//! sharded concurrent map (the menu depends only on
//! `(nest root, local pipeline choice)`), and a lock-free shared
//! incumbent — the k-th best objective as atomic f64 bits — lets every
//! worker skip whole configurations that provably cannot enter the final
//! top-k.
//!
//! ## Determinism
//!
//! `solve_jobs(.., jobs = N)` is **bit-identical** to
//! `solve_jobs(.., jobs = 1)` for every `N` (property-tested over all 24
//! kernels + CNN in `tests/property_solver_parallel.rs`). The
//! construction:
//!
//! * the branch-and-bound inside one configuration is a *pure function*
//!   of that configuration — it prunes only against its own local
//!   incumbents and a fixed per-config tie budget, never against shared
//!   state. This deliberately forgoes the old solver's cross-config
//!   node-level incumbent pruning (the price of parity); the cost is
//!   bounded because candidates are sorted ascending — the first leaf of
//!   a config is already near its optimum, so local pruning converges
//!   immediately — and hopeless configs are skipped wholesale by the
//!   guard before any node is expanded, leaving at most `LEAF_BUDGET`
//!   extra tie leaves per surviving config;
//! * the shared incumbent guard is consulted at **configuration
//!   granularity** only, and only for cuts that are *sound with
//!   tolerance*: a configuration is skipped iff its lower bound is
//!   strictly worse (beyond 1e-9 relative) than k already-found designs,
//!   which proves none of its designs can rank in the final top-k —
//!   so the skip can never change the reduction below, it only saves
//!   work. (Consulting the guard *inside* the b&b would be sound for the
//!   result set too, but would make the per-config tie-budget countdown
//!   depend on thread timing — that is exactly the nondeterminism the
//!   config-granularity rule avoids.)
//! * the final reduction is a **deterministic merge**: all per-config
//!   top-k lists are pooled, ranked by the total order
//!   `(objective, realization risk, pragma vector)`, deduplicated, and
//!   truncated — invariant under any work interleaving. This is also why
//!   work stealing and bound-ascending dispatch are free to reorder and
//!   re-partition the configs arbitrarily: *which worker* runs a config,
//!   and *when*, never reaches the reduction;
//! * the proven lower bound is the minimum over *all* configurations of
//!   the interval-relaxation bound (precomputed for every config before
//!   dispatch, so it covers skipped configs too), capped by the best
//!   objective — again interleaving-invariant.
//!
//! `SolverStats` are merged commutatively (field-wise sums), so totals
//! are reproducible for a fixed explored/skipped partition; with
//! `jobs > 1` the partition itself may shift with guard timing, so node
//! and prune *counts* (unlike results) are not guaranteed identical to
//! the serial run. [`SolverStats::steals`] and
//! [`SolverStats::queue_idle_s`] expose the stealing machinery itself;
//! both are identically zero for `jobs = 1`.
//!
//! ## Front extraction ([`solve_front`])
//!
//! The same engine also extracts **epsilon-dominance Pareto fronts**
//! over `(latency, DSP, on-chip bytes, LUT)` for system-level
//! multi-kernel allocation: in front mode the guard never engages (every
//! pipeline configuration is processed — `stats.configs` is exact), the
//! merged pool keeps the *union* of per-config top-`max_points` lists,
//! and the final reduction is the order-invariant epsilon-grid archive
//! of [`super::front`]. Membership in the front is a pure function of
//! that union, so `jobs = N` remains bit-identical to `jobs = 1` by the
//! same argument as the top-k reduction.
//!
//! Anytime behaviour: on budget exhaustion (wall clock, or a config
//! blowing the per-config node cap) the best incumbent is returned with
//! `optimal = false`, plus the proven lower bound — exactly what
//! Algorithm 1 consumes for pruning. These anytime escapes are the one
//! documented exception to the bit-parity guarantee: a truncated search
//! is honest about it (`optimal = false`), and only then may results —
//! or, for a node-capped config that another interleaving guard-skips,
//! just the flag (pessimistically false, identical designs) — depend on
//! interleaving.

use super::formulation::NlpProblem;
use super::front::{FrontConfig, FrontPoint};
use crate::ir::{Kernel, LoopId};
use crate::model;
use crate::model::sym::{EvalScratch, PartialDesign, SoaScratch};
use crate::pragma::{space, Design, PipelineConfig};
use std::collections::hash_map::{DefaultHasher, Entry};
use std::collections::{HashMap, HashSet, VecDeque};
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Relative tolerance for objective ties (the Theorem 4.4 work-floor
/// plateau).
const EPS: f64 = 1e-9;
/// Per-config tie-exploration budget (leaves). Fixed per configuration so
/// the within-config search is a pure function of the configuration.
const LEAF_BUDGET: i64 = 1_500;
/// Per-config node budget (BARON-style anytime cap).
const NODE_CAP: u64 = 1_500_000;
/// Runaway-product guard: complete assignments enumerated per nest menu.
const MAX_MENU_ASSIGNMENTS: usize = 200_000;
/// Sharded concurrent nest-menu cache width (power of two).
const CACHE_SHARDS: usize = 16;
/// Work-stealing deal granularity: the bound-ascending config order is
/// dealt round-robin into per-worker deques this many configs at a time.
/// Small on purpose — the initial deal only has to keep early guard
/// updates spread across workers; load balance comes from stealing, not
/// from clairvoyant chunking.
const STEAL_CHUNK: usize = 2;

/// Bulk lower-bound scoring interface. `runtime::XlaEvaluator` implements
/// this over the AOT artifact; [`RustFeatureEvaluator`] is the in-process
/// fallback with identical semantics. `Send + Sync` so one evaluator can
/// serve the whole scoped worker team.
pub trait BatchEvaluator: Send + Sync {
    /// Returns `(latency_lb, dsp)` per design.
    fn eval_batch(&self, problem: &NlpProblem, designs: &[Design]) -> Vec<(f64, f64)>;

    /// [`eval_batch`](Self::eval_batch) through a caller-owned SoA lane
    /// scratch. Evaluators with a batched kernel (the
    /// [`SymbolicEvaluator`]) override this to score allocation-free
    /// through per-worker lane buffers; the default ignores the scratch
    /// and must return exactly what `eval_batch` returns.
    fn eval_batch_in(
        &self,
        problem: &NlpProblem,
        designs: &[Design],
        _lanes: &mut SoaScratch,
    ) -> Vec<(f64, f64)> {
        self.eval_batch(problem, designs)
    }
}

/// Fallback evaluator: the Rust reference implementation of the feature
/// formula (same ABI semantics as the XLA artifact).
pub struct RustFeatureEvaluator;

impl BatchEvaluator for RustFeatureEvaluator {
    fn eval_batch(&self, p: &NlpProblem, designs: &[Design]) -> Vec<(f64, f64)> {
        designs
            .iter()
            .map(|d| {
                match model::encode_design(p.kernel, p.analysis, p.device, d) {
                    Some(f) => model::eval_features(&f),
                    None => {
                        let r = model::evaluate(p.kernel, p.analysis, p.device, d);
                        (r.total_cycles, r.dsp)
                    }
                }
            })
            .collect()
    }
}

/// Batch evaluator backed by the problem's compiled symbolic bound model:
/// exact model scores (not the feature under-approximation) at flattened
/// tape speed, with zero per-design allocation.
pub struct SymbolicEvaluator;

impl BatchEvaluator for SymbolicEvaluator {
    fn eval_batch(&self, p: &NlpProblem, designs: &[Design]) -> Vec<(f64, f64)> {
        // SoA lane kernel: bit-identical scores to the scalar tape at a
        // fraction of the per-design dispatch cost
        p.compiled
            .evaluate_batch_soa(designs)
            .into_iter()
            .map(|r| (r.total_cycles, r.dsp))
            .collect()
    }

    fn eval_batch_in(
        &self,
        p: &NlpProblem,
        designs: &[Design],
        lanes: &mut SoaScratch,
    ) -> Vec<(f64, f64)> {
        let mut out = Vec::new();
        p.compiled.evaluate_batch_soa_in(designs, lanes, &mut out);
        out.into_iter().map(|r| (r.total_cycles, r.dsp)).collect()
    }
}

/// Default worker count for [`solve_jobs`]: every core the host exposes.
/// Deliberately distinct from `coordinator::num_threads` (which caps the
/// campaign pool at 16 and falls back to 4): a single solve should take
/// the whole machine, and the serial fallback is the exact `jobs = 1`
/// path.
pub fn default_jobs() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Search counters, merged field-wise across workers.
#[derive(Clone, Debug, Default)]
pub struct SolverStats {
    /// Branch-and-bound nodes expanded.
    pub nodes: u64,
    /// Complete assignments reached.
    pub leaves: u64,
    /// Branch-and-bound nodes cut by the admissible candidate bound.
    pub pruned_bound: u64,
    /// Whole pipeline configurations cut by symbolic interval relaxation
    /// (or the per-nest-minima config bound) against the shared incumbent
    /// guard, before any branch-and-bound.
    pub pruned_relaxation: u64,
    /// Candidates cut by the monotone partitioning screen.
    pub pruned_partition: u64,
    /// Nodes rejected by the constraint check (infeasible leaves and
    /// configurations with no legal candidate) — reported separately from
    /// the relaxation prunes they used to be conflated with.
    pub infeasible: u64,
    /// Designs scored through the batch evaluator.
    pub candidates_scored: u64,
    /// Pipeline configurations processed.
    pub configs: u64,
    /// Nest menus truncated by the runaway-product guard: the odometer
    /// stopped after `MAX_MENU_ASSIGNMENTS` complete assignments, so the
    /// menu is a deterministic lexicographic prefix of the full product
    /// (visible here instead of silently asymmetric, as the old
    /// mid-extension break was).
    pub truncated_menus: u64,
    /// Successful work-stealing grabs: a worker found its own deque empty
    /// and took the back half of a victim's. Always 0 for `jobs = 1`
    /// (the serial path never consults other queues); with `jobs > 1` the
    /// count depends on thread timing, like the other partition-sensitive
    /// counters.
    pub steals: u64,
    /// Seconds workers spent with an empty local deque hunting for work
    /// (scanning victims, successful or not). Wall-clock measurement:
    /// reported for bench/diagnostic use, never compared for determinism.
    /// Always 0.0 for `jobs = 1`.
    pub queue_idle_s: f64,
}

impl SolverStats {
    /// Commutative merge (field-wise sums) — the per-worker stats
    /// reduction.
    pub fn merge(&mut self, o: &SolverStats) {
        self.nodes += o.nodes;
        self.leaves += o.leaves;
        self.pruned_bound += o.pruned_bound;
        self.pruned_relaxation += o.pruned_relaxation;
        self.pruned_partition += o.pruned_partition;
        self.infeasible += o.infeasible;
        self.candidates_scored += o.candidates_scored;
        self.configs += o.configs;
        self.truncated_menus += o.truncated_menus;
        self.steals += o.steals;
        self.queue_idle_s += o.queue_idle_s;
    }
}

/// Outcome of one (sub-space) NLP solve.
#[derive(Clone, Debug)]
pub struct SolveResult {
    /// Best feasible designs found, ascending `(objective, risk, pragmas)`
    /// (≤ `topk`).
    pub designs: Vec<(Design, f64)>,
    /// Proven lower bound on the optimum over the sub-space.
    pub lower_bound: f64,
    /// Whether the search completed within budget.
    pub optimal: bool,
    /// Wall-clock of the solve, seconds.
    pub solve_time_s: f64,
    /// Summed per-worker busy time (seconds actually spent processing
    /// configurations — excludes queue-idle threads). Equals
    /// `solve_time_s` for `jobs = 1`; the simulated DSE clock charges
    /// this, not wall × jobs, so idle workers don't inflate the bill.
    pub cpu_time_s: f64,
    /// Worker threads the solve ran with (1 = serial path).
    pub jobs: usize,
    /// Merged search counters.
    pub stats: SolverStats,
}

impl SolveResult {
    /// Best feasible design found, if any. `None` means every candidate
    /// was cut — consult [`Self::pruned_by_relaxation`] vs
    /// [`Self::infeasible_nodes`] to see whether bounds or constraints
    /// emptied the search.
    pub fn best(&self) -> Option<&(Design, f64)> {
        self.designs.first()
    }

    /// Nodes cut by relaxation bounds (admissible b&b bound + symbolic
    /// interval config prunes).
    pub fn pruned_by_relaxation(&self) -> u64 {
        self.stats.pruned_bound + self.stats.pruned_relaxation
    }

    /// Nodes rejected as constraint-infeasible.
    pub fn infeasible_nodes(&self) -> u64 {
        self.stats.infeasible
    }
}

/// Outcome of one epsilon-dominance front extraction ([`solve_front`]).
#[derive(Clone, Debug)]
pub struct FrontResult {
    /// The reduced front, in canonical `(latency, risk, pragmas)` order
    /// (≤ `FrontConfig::max_points`, mutually epsilon-non-dominated).
    pub points: Vec<FrontPoint>,
    /// Proven lower bound on the latency optimum (identical construction
    /// to [`SolveResult::lower_bound`]).
    pub lower_bound: f64,
    /// Whether the search completed within budget.
    pub optimal: bool,
    /// Wall-clock of the solve, seconds.
    pub solve_time_s: f64,
    /// Summed per-worker busy seconds (see [`SolveResult::cpu_time_s`]).
    pub cpu_time_s: f64,
    /// Worker threads the solve ran with.
    pub jobs: usize,
    /// Merged search counters. With the guard disabled,
    /// `stats.configs` equals the full pipeline-configuration count.
    pub stats: SolverStats,
}

/// Per-nest candidate: the free-loop UF assignment and its additive
/// latency contribution + partitioning/DSP signature.
struct Cand {
    ufs: Vec<(LoopId, u64)>,
    lat: f64,
    /// product of coarse (above-pipe, non-innermost) factors — the
    /// realization-risk tie-break key
    risk: f64,
    /// per (array, dim) UF maxima contributed by this nest
    part: Vec<((u32, usize), u64)>,
}

/// One accepted leaf: design + exact objective + realization risk.
#[derive(Clone, Debug)]
struct Incumbent {
    design: Design,
    obj: f64,
    risk: f64,
}

/// The deterministic total order of the final reduction: objective, then
/// realization risk, then the pragma vector itself (`Design: Ord`) so two
/// distinct designs never compare equal.
///
/// Objectives compare *exactly* (the old 1e-9 relative-tolerance
/// comparator was non-transitive and cannot drive a deterministic
/// merge). The Theorem 4.4 plateau still resolves by risk: designs on
/// the work floor share the design-independent floor term bit-for-bit,
/// so true plateau ties are exact f64 ties and fall through to the risk
/// key; only sub-ulp *near*-ties now order by raw objective instead.
///
/// `total_cmp`, not `partial_cmp(..).unwrap()`: a NaN objective or risk
/// (a degenerate device spec, a broken plug-in evaluator) must *rank
/// last* — IEEE-754 totalOrder places positive NaN above `+inf` — never
/// panic a worker mid-merge while it holds the incumbent lock.
fn rank_cmp(a: &Incumbent, b: &Incumbent) -> std::cmp::Ordering {
    a.obj
        .total_cmp(&b.obj)
        .then_with(|| a.risk.total_cmp(&b.risk))
        .then_with(|| a.design.cmp(&b.design))
}

/// Realization risk of a complete design — the rank tie-break key. The
/// Theorem 4.4 work floor creates objective plateaus; among equal-latency
/// solutions the reduction prefers the least *risky* parallelism:
/// coarse-grained factors above the pipeline are the pragmas Merlin most
/// often refuses (Section 7.5), while fine under-pipe unrolls apply
/// reliably — hence the lexicographic (objective, Π coarse-UF) ordering.
/// A pure function of (kernel, design): search leaves and warm-start
/// seeds compute identical keys, so [`solve_jobs_seeded`]'s reduction
/// ranks a seeded design exactly like a search-found copy of it.
pub fn design_risk(k: &Kernel, d: &Design) -> f64 {
    d.pragmas
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let l = LoopId(i as u32);
            let coarse = !k.loop_meta(l).innermost
                && !p.pipeline
                && k.loop_meta(l).children.len() + usize::from(!k.loop_meta(l).innermost) > 0
                && d.pipeline_above(k, l) != Some(l)
                && !d.pipelined().any(|pl| k.is_under(l, pl));
            if coarse {
                p.uf.max(1) as f64
            } else {
                1.0
            }
        })
        .product()
}

/// Deterministic 64-bit design key (leaf dedup without structural scans).
/// `DefaultHasher::new()` is documented to hash identically across
/// instances and processes, so the key — and any collision — is the same
/// on every run and thread.
fn design_key(d: &Design) -> u64 {
    let mut h = DefaultHasher::new();
    d.hash(&mut h);
    h.finish()
}

/// Recover a mutex guard even when another worker panicked while holding
/// the lock. Sound for every mutex in this module: the queues hold plain
/// `u32` config indices (any prefix of a poisoned update is a valid work
/// set — at worst a config is processed that the panicking worker had
/// claimed), and the incumbent vector is re-canonicalized (sort + dedup)
/// on every merge, so a partially-appended pool is repaired by the next
/// merge. The panic itself is not swallowed: `solve_jobs` re-raises the
/// *first* worker panic after every worker has been joined.
fn lock_recover<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Monotone-min shared f64 stored as bits; lock-free CAS loop. Carries
/// the cross-worker incumbent guard (since PR 8 the lower-bound
/// reduction no longer lives here — it is the precomputed `iv_lb_all`
/// minimum from the batched dispatch sweep).
struct AtomicF64Min(AtomicU64);

impl AtomicF64Min {
    fn new(v: f64) -> AtomicF64Min {
        AtomicF64Min(AtomicU64::new(v.to_bits()))
    }
    fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
    fn fetch_min(&self, v: f64) {
        let mut cur = self.0.load(Ordering::Relaxed);
        while v < f64::from_bits(cur) {
            match self.0.compare_exchange_weak(
                cur,
                v.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(c) => cur = c,
            }
        }
    }
}

/// One cached nest menu plus its generation accounting (charged to the
/// worker that built it, exactly once).
struct CandSet {
    cands: Vec<Cand>,
    scored: u64,
    truncated: bool,
}

/// Menu-cache key: `(nest root, sorted pipeline choice local to the
/// nest)` — everything the menu depends on besides the fixed problem.
type CandKey = (u32, Vec<u32>);
type CandShard = Mutex<HashMap<CandKey, Arc<CandSet>>>;

/// Sharded concurrent map `(nest root, local pipeline choice) → menu`, so
/// distinct configurations (and distinct workers) share per-nest menus
/// without a global lock.
struct CandCache {
    shards: Vec<CandShard>,
}

impl CandCache {
    fn new() -> CandCache {
        CandCache {
            shards: (0..CACHE_SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
        }
    }

    fn shard(&self, key: &CandKey) -> &CandShard {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) & (CACHE_SHARDS - 1)]
    }

    /// Returns the cached menu, building it outside the shard lock on a
    /// miss. The bool is true iff this call inserted (the builder charges
    /// generation stats exactly once; a lost race discards the duplicate).
    fn get_or_build(
        &self,
        key: CandKey,
        build: impl FnOnce() -> CandSet,
    ) -> (Arc<CandSet>, bool) {
        if let Some(v) = lock_recover(self.shard(&key)).get(&key) {
            return (v.clone(), false);
        }
        let built = Arc::new(build());
        let mut g = lock_recover(self.shard(&key));
        match g.entry(key) {
            Entry::Occupied(e) => (e.get().clone(), false),
            Entry::Vacant(e) => {
                e.insert(built.clone());
                (built, true)
            }
        }
    }
}

/// Everything the worker team shares. `&Shared` crosses threads, so every
/// field is `Sync` (atomics, mutexes, shared references into the
/// `Send + Sync` problem/model).
struct Shared<'a> {
    problem: &'a NlpProblem<'a>,
    configs: &'a [PipelineConfig],
    evaluator: &'a dyn BatchEvaluator,
    nests: Vec<LoopId>,
    base: model::NestBreakdown,
    cap: u64,
    topk: usize,
    t0: Instant,
    timeout_s: f64,
    /// Per-worker config deques (the work-stealing queues). Dealt from
    /// the bound-ascending order before any worker starts; no producer
    /// exists after that, so "every deque empty" means the search is
    /// drained. Plain mutexed `VecDeque`s: steals are rare (a worker only
    /// locks a victim when its own deque is dry) and config granularity
    /// is coarse, so a lock-free deque would buy nothing here.
    queues: Vec<Mutex<VecDeque<u32>>>,
    /// Interval-relaxation bound per config index, precomputed for *all*
    /// configs in one laned batch sweep before dispatch.
    iv_lbs: Vec<f64>,
    /// `min(iv_lbs)` — the deterministic part of the proven lower bound.
    iv_lb_all: f64,
    /// k-th best objective over the merged global top-k (+inf until full).
    guard: AtomicF64Min,
    optimal: AtomicBool,
    /// Merged global top-k, kept in `rank_cmp` order, deduped, ≤ topk.
    best: Mutex<Vec<Incumbent>>,
    cache: CandCache,
    /// Front-extraction mode: never truncate the merged pool, never
    /// tighten the guard (see [`solve_front`]).
    keep_all: bool,
}

/// Per-worker reusable buffers: after the first configuration warms the
/// capacities, branch-and-bound nodes allocate nothing (leaves write into
/// the reused `leaf` design and clone it only on acceptance).
struct WorkerScratch {
    eval: EvalScratch,
    /// Lane buffer backing the SoA batch scoring path.
    soa: SoaScratch,
    chosen: Vec<usize>,
    part_stack: Vec<((u32, usize), u64)>,
    merged: Vec<((u32, usize), u64)>,
    seen: HashSet<u64>,
    leaf: Design,
    cfg_nodes: u64,
    timed_out: bool,
}

impl WorkerScratch {
    fn new(problem: &NlpProblem) -> WorkerScratch {
        WorkerScratch {
            eval: problem.scratch(),
            soa: problem.soa_scratch(),
            chosen: Vec::new(),
            part_stack: Vec::new(),
            merged: Vec::new(),
            seen: HashSet::new(),
            leaf: Design::empty(problem.kernel),
            cfg_nodes: 0,
            timed_out: false,
        }
    }

    fn reset_config(&mut self, n_nests: usize) {
        self.chosen.clear();
        self.chosen.resize(n_nests, 0);
        self.part_stack.clear();
        self.seen.clear();
        self.cfg_nodes = 0;
    }
}

/// Solve one NLP instance serially (the `jobs = 1` path of
/// [`solve_jobs`], with no thread spawns, queues, or lock contention).
pub fn solve(
    problem: &NlpProblem,
    timeout_s: f64,
    topk: usize,
    evaluator: &dyn BatchEvaluator,
) -> SolveResult {
    solve_jobs(problem, timeout_s, topk, evaluator, 1)
}

/// Solve one NLP instance with a team of `jobs` workers draining the
/// pipeline-configuration queue. Results are bit-identical for every
/// `jobs` value (see the module docs for the determinism construction);
/// `jobs = 1` runs entirely on the caller thread.
pub fn solve_jobs(
    problem: &NlpProblem,
    timeout_s: f64,
    topk: usize,
    evaluator: &dyn BatchEvaluator,
    jobs: usize,
) -> SolveResult {
    solve_jobs_seeded(problem, timeout_s, topk, evaluator, jobs, &[])
}

/// [`solve_jobs`] warm-started from candidate incumbent designs (the
/// serve daemon's fingerprint cache passes the previous solve's top-k
/// when only sizes/precision changed).
///
/// Soundness: every seed is **re-verified against this problem** with
/// the same single-tape feasibility + objective check a search leaf
/// passes; infeasible seeds are dropped, feasible ones enter the
/// incumbent reduction as ordinary incumbents (identical objective,
/// risk, and dedup keys to a search-found copy of the same design). The
/// incumbent guard only engages once `topk` incumbents exist — exactly
/// as in a cold solve — so seeding can prune work but never prunes a
/// design that would have ranked in a cold top-k. A completed seeded
/// solve therefore returns the cold result, except that a seed the
/// restricted candidate menus cannot reach (e.g. carried over from a
/// different partition rung) may *improve* the top-k; timed-out anytime
/// results keep the same caveats as the unseeded path.
pub fn solve_jobs_seeded(
    problem: &NlpProblem,
    timeout_s: f64,
    topk: usize,
    evaluator: &dyn BatchEvaluator,
    jobs: usize,
    seeds: &[Design],
) -> SolveResult {
    let core = solve_core(problem, timeout_s, topk, evaluator, jobs, seeds, false);
    SolveResult {
        designs: core
            .incumbents
            .into_iter()
            .map(|i| (i.design, i.obj))
            .collect(),
        lower_bound: core.lower_bound,
        optimal: core.optimal,
        solve_time_s: core.solve_time_s,
        cpu_time_s: core.cpu_time_s,
        jobs: core.jobs,
        stats: core.stats,
    }
}

/// What the worker team produced, before the caller-specific packaging
/// (top-k [`SolveResult`] vs Pareto-front [`FrontResult`]).
struct CoreOutcome {
    incumbents: Vec<Incumbent>,
    lower_bound: f64,
    optimal: bool,
    solve_time_s: f64,
    cpu_time_s: f64,
    jobs: usize,
    stats: SolverStats,
}

/// The shared solve engine. `keep_all = false` is the classic top-k
/// reduction; `keep_all = true` disables the incumbent guard and the
/// merge truncation so the pooled incumbent set is exactly the union of
/// the per-config top-`topk` lists — the deterministic raw material for
/// epsilon-dominance front extraction ([`solve_front`]).
fn solve_core(
    problem: &NlpProblem,
    timeout_s: f64,
    topk: usize,
    evaluator: &dyn BatchEvaluator,
    jobs: usize,
    seeds: &[Design],
    keep_all: bool,
) -> CoreOutcome {
    let t0 = Instant::now();
    let jobs = jobs.max(1);
    let k = problem.kernel;

    // re-verify the seeds into genuine incumbents before any worker runs
    let mut seeded: Vec<Incumbent> = Vec::new();
    let mut seed_keys: HashSet<u64> = HashSet::new();
    for d in seeds {
        if d.pragmas.len() != k.n_loops() || !seed_keys.insert(design_key(d)) {
            continue; // foreign-shape or duplicate seed
        }
        if let Some(obj) = problem.check_objective(d) {
            seeded.push(Incumbent {
                design: d.clone(),
                obj,
                risk: design_risk(k, d),
            });
        }
    }
    seeded.sort_by(rank_cmp);
    seeded.truncate(topk);
    // front mode never engages the guard: every config must contribute
    // its full local top-k to the pooled reduction
    let seed_guard = if !keep_all && seeded.len() >= topk {
        seeded.last().map(|i| i.obj).unwrap_or(f64::INFINITY)
    } else {
        f64::INFINITY
    };

    // baseline per-nest latencies for the empty design (score extraction)
    let empty = Design::empty(k);
    let base = model::nest_latencies(k, problem.analysis, problem.device, &empty);

    // ---- bound-ascending work-stealing dispatch -------------------------
    // Every config's interval-relaxation bound is computed up front in one
    // laned batch sweep (8 configs per tape pass); besides feeding the
    // per-config guard checks, the full vector gives the deterministic
    // lower-bound reduction over *all* configs — including ones a timeout
    // later leaves unclaimed. The configs are then sorted by
    // (bound, index) — total_cmp so NaN-free ordering is total, index so
    // the order is unique — and dealt round-robin into per-worker deques:
    // fronts hold the most promising configs, so every worker's first
    // claims tighten the guard fastest.
    let configs: &[PipelineConfig] = &problem.space.pipeline_configs;
    let partials: Vec<PartialDesign> = configs
        .iter()
        .map(|cfg| config_partial(problem, cfg))
        .collect();
    let iv_lbs = problem.bound.lower_bound_batch(&partials);
    drop(partials);
    let iv_lb_all = iv_lbs.iter().copied().fold(f64::INFINITY, f64::min);
    let mut order: Vec<u32> = (0..configs.len() as u32).collect();
    order.sort_by(|&x, &y| {
        iv_lbs[x as usize]
            .total_cmp(&iv_lbs[y as usize])
            .then(x.cmp(&y))
    });
    let queues: Vec<Mutex<VecDeque<u32>>> = (0..jobs)
        .map(|_| Mutex::new(VecDeque::with_capacity(configs.len() / jobs + STEAL_CHUNK)))
        .collect();
    for (i, chunk) in order.chunks(STEAL_CHUNK).enumerate() {
        lock_recover(&queues[i % jobs]).extend(chunk.iter().copied());
    }

    let sh = Shared {
        problem,
        configs,
        evaluator,
        nests: k.nest_roots(),
        base,
        cap: problem.partition_cap(),
        topk,
        t0,
        timeout_s,
        queues,
        iv_lbs,
        iv_lb_all,
        guard: AtomicF64Min::new(seed_guard),
        optimal: AtomicBool::new(true),
        best: Mutex::new(seeded),
        cache: CandCache::new(),
        keep_all,
    };

    let mut stats = SolverStats::default();
    let mut cpu_time_s = 0.0f64;
    if jobs == 1 {
        cpu_time_s = worker(&sh, 0, &mut stats);
    } else {
        // Join every worker and only then re-raise the *first* panic:
        // the old `.expect("solver worker panicked")` aborted the join
        // loop on the first failed handle, leaking a PoisonError cascade
        // (every stealer that touched a queue the panicking worker had
        // poisoned would panic in turn, and the caller saw whichever
        // payload the join order happened to surface). The recovering
        // locks keep the surviving workers draining cleanly; the original
        // payload — not a PoisonError wrapper — reaches the caller.
        let mut first_panic: Option<Box<dyn std::any::Any + Send>> = None;
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..jobs)
                .map(|id| {
                    let sh = &sh;
                    scope.spawn(move || {
                        let mut st = SolverStats::default();
                        let busy = worker(sh, id, &mut st);
                        (st, busy)
                    })
                })
                .collect();
            for h in handles {
                match h.join() {
                    Ok((st, busy)) => {
                        stats.merge(&st);
                        cpu_time_s += busy;
                    }
                    Err(payload) => {
                        if first_panic.is_none() {
                            first_panic = Some(payload);
                        }
                    }
                }
            }
        });
        if let Some(payload) = first_panic {
            std::panic::resume_unwind(payload);
        }
    }

    let best = sh.best.into_inner().unwrap_or_else(|p| p.into_inner());
    let mut proven_lb = sh.iv_lb_all;
    if let Some(b) = best.first() {
        // the optimum can't be below the proven relaxation, nor above the
        // incumbent
        proven_lb = proven_lb.min(b.obj);
    }
    CoreOutcome {
        incumbents: best,
        lower_bound: proven_lb,
        optimal: sh.optimal.load(Ordering::Relaxed),
        solve_time_s: t0.elapsed().as_secs_f64(),
        cpu_time_s,
        jobs,
        stats,
    }
}

/// Extract an **epsilon-dominance Pareto front** over
/// `(latency, DSP, on-chip bytes, LUT)` instead of a scalar top-k.
///
/// The search machinery is [`solve_jobs`]'s, run in `keep_all` mode: the
/// incumbent guard stays at `+inf` (no config is ever guard-skipped, so
/// `stats.configs` counts every pipeline configuration), each config
/// contributes its local top-`max_points` incumbents, and the pooled set
/// is exactly the union of the per-config results — a pure function of
/// the problem, independent of worker interleaving. The final reduction
/// ranks the pool by the canonical total order, evaluates each design's
/// resource vector once with the analytical model, and applies the
/// order-invariant epsilon-grid reduction of [`front`](super::front) —
/// so `jobs = N` is bit-identical to `jobs = 1`, the same construction
/// (and the same property-test discipline) as the top-k path.
pub fn solve_front(
    problem: &NlpProblem,
    timeout_s: f64,
    fc: &FrontConfig,
    evaluator: &dyn BatchEvaluator,
    jobs: usize,
) -> FrontResult {
    let core = solve_core(
        problem,
        timeout_s,
        fc.max_points.max(1),
        evaluator,
        jobs,
        &[],
        true,
    );
    // one exact model evaluation per pooled incumbent: the objective is
    // the solver's verified latency; the resource axes come from the
    // analytical model (Eq 11/12 + the LUT mirror of Eq 11)
    let points: Vec<FrontPoint> = core
        .incumbents
        .into_iter()
        .map(|inc| {
            let r = model::evaluate(
                problem.kernel,
                problem.analysis,
                problem.device,
                &inc.design,
            );
            FrontPoint {
                design: inc.design,
                latency: inc.obj,
                risk: inc.risk,
                dsp: r.dsp,
                onchip_bytes: r.onchip_bytes,
                lut: r.lut,
            }
        })
        .collect();
    let points = super::front::reduce(points, fc);
    FrontResult {
        points,
        lower_bound: core.lower_bound,
        optimal: core.optimal,
        solve_time_s: core.solve_time_s,
        cpu_time_s: core.cpu_time_s,
        jobs: core.jobs,
        stats: core.stats,
    }
}

/// One worker: drain the local deque, steal when it runs dry, until no
/// queue holds work or the time budget is empty. Returns the seconds this
/// worker spent busy on configurations (the honest per-worker CPU bill).
fn worker(sh: &Shared, id: usize, stats: &mut SolverStats) -> f64 {
    let mut ws = WorkerScratch::new(sh.problem);
    let mut busy = 0.0f64;
    loop {
        // claim first, then check the clock: drained queues are a
        // *completed* search even if the deadline passed while the last
        // config finished — only flag non-optimality when work remains
        let Some(ci) = next_config(sh, id, stats) else {
            return busy;
        };
        if sh.t0.elapsed().as_secs_f64() > sh.timeout_s {
            sh.optimal.store(false, Ordering::Relaxed);
            return busy;
        }
        stats.configs += 1;
        let t = Instant::now();
        run_config(sh, &mut ws, ci as usize, stats);
        busy += t.elapsed().as_secs_f64();
        if ws.timed_out {
            return busy;
        }
    }
}

/// Claim the next config for worker `id`: pop the local deque's front
/// (best remaining bound), else steal the **back** half of the first
/// non-empty victim — the victim keeps its better-bounded front, the
/// thief inherits the tail it was never going to reach soon. `None` only
/// when every deque is empty; with no producers after the initial deal
/// that means the search is drained. (Benign race, documented: a stolen
/// chunk is invisible to *other* scanners while the thief re-queues it,
/// so a third worker may retire one scan early — work is never lost, the
/// thief itself processes everything it took.)
fn next_config(sh: &Shared, id: usize, stats: &mut SolverStats) -> Option<u32> {
    if let Some(ci) = lock_recover(&sh.queues[id]).pop_front() {
        return Some(ci);
    }
    let n = sh.queues.len();
    if n == 1 {
        return None; // serial path: no victims, no idle accounting
    }
    let t = Instant::now();
    let mut found = None;
    for off in 1..n {
        let victim = (id + off) % n;
        let mut stolen = {
            let mut q = lock_recover(&sh.queues[victim]);
            if q.is_empty() {
                continue;
            }
            let keep = q.len() / 2; // steal-half, rounding the extra to us
            q.split_off(keep)
        };
        let ci = stolen.pop_front().expect("stole from non-empty deque");
        if !stolen.is_empty() {
            lock_recover(&sh.queues[id]).append(&mut stolen);
        }
        stats.steals += 1;
        found = Some(ci);
        break;
    }
    stats.queue_idle_s += t.elapsed().as_secs_f64();
    found
}

/// Process one pipeline configuration: sound config-level skips against
/// the shared guard, per-nest candidate menus, then a purely local
/// branch-and-bound whose top-k merges into the global reduction.
fn run_config(sh: &Shared, ws: &mut WorkerScratch, ci: usize, stats: &mut SolverStats) {
    let problem = sh.problem;
    let k = problem.kernel;
    let cfg = &sh.configs[ci];

    // ---- symbolic interval relaxation over the whole config ------------
    // Precomputed for every config in the dispatch sweep (the minimum
    // over all of them is the deterministic part of the proven lower
    // bound). With the pipeline fixed and the structural Eq 9/15
    // assignments applied, every UF left free is relaxed to its interval
    // hull; if even that optimistic completion cannot enter the top-k
    // (compared against the *k-th* global incumbent with tolerance, so
    // runners-up and ties are never lost), the whole config is skipped
    // before any candidate exists.
    let iv_lb = sh.iv_lbs[ci];
    if iv_lb > sh.guard.get() * (1.0 + EPS) {
        stats.pruned_relaxation += 1;
        return;
    }

    // ---- per-nest candidate generation (shared sharded cache) ----------
    let mut per_nest: Vec<Arc<CandSet>> = Vec::with_capacity(sh.nests.len());
    for (ni, &root) in sh.nests.iter().enumerate() {
        let nest_loops = k.nest_loops(root);
        let mut local: Vec<u32> = cfg
            .pipelined
            .iter()
            .filter(|l| nest_loops.contains(l))
            .map(|l| l.0)
            .collect();
        local.sort_unstable();
        let key = (root.0, local);
        let (set, inserted) = sh.cache.get_or_build(key, || {
            nest_candidates(problem, cfg, root, sh.cap, sh.evaluator, &sh.base, ni, &mut ws.soa)
        });
        if inserted {
            stats.candidates_scored += set.scored;
            if set.truncated {
                stats.truncated_menus += 1;
            }
        }
        if set.cands.is_empty() {
            stats.infeasible += 1;
            return;
        }
        per_nest.push(set);
    }

    // config-level relaxation bound: combine per-nest minima into suffix
    // bounds (candidates are sorted ascending, so the minimum is first)
    let n = per_nest.len();
    let mut suffix = vec![0.0f64; n + 1];
    for i in (0..n).rev() {
        let m = per_nest[i].cands[0].lat;
        suffix[i] = combine2(m, suffix[i + 1], sh.base.sum_combine);
    }
    // compare against the *k-th* global incumbent (not the #1): a config
    // whose optimum lies between best[0] and best[k-1] still owes the
    // caller a runner-up; ties survive the tolerance and lose (or win) the
    // deterministic merge instead.
    let cfg_lb = suffix[0] + sh.base.comm;
    if cfg_lb > sh.guard.get() * (1.0 + EPS) {
        stats.pruned_relaxation += 1;
        return;
    }

    // ---- branch and bound across nests (pure per-config function) ------
    let per_nest: Vec<&[Cand]> = per_nest.iter().map(|s| s.cands.as_slice()).collect();
    ws.reset_config(n);
    let mut local: Vec<Incumbent> = Vec::with_capacity(sh.topk + 1);
    let mut leaf_budget: i64 = LEAF_BUDGET;
    bb(
        sh,
        ws,
        cfg,
        &per_nest,
        &suffix,
        0,
        0.0,
        &mut local,
        stats,
        &mut leaf_budget,
    );
    if !local.is_empty() {
        merge_into_global(sh, local);
    }
}

/// Merge one config's local top-k into the global reduction: pool, rank
/// by the deterministic total order, dedup, truncate, refresh the guard.
///
/// In front-extraction mode (`keep_all`) the pool is never truncated and
/// the guard is never tightened: every per-config top-k survives to the
/// final epsilon-dominance reduction, whose membership must be a pure
/// function of the union of per-config results — any truncation or
/// guard-driven skip here would make it depend on merge order.
fn merge_into_global(sh: &Shared, mut local: Vec<Incumbent>) {
    let mut g = lock_recover(&sh.best);
    g.append(&mut local);
    g.sort_by(rank_cmp);
    g.dedup_by(|a, b| a.design == b.design);
    if sh.keep_all {
        return;
    }
    g.truncate(sh.topk);
    if g.len() >= sh.topk {
        if let Some(last) = g.last() {
            sh.guard.fetch_min(last.obj);
        }
    }
}

#[inline]
fn combine2(a: f64, b: f64, sum: bool) -> f64 {
    if sum {
        a + b
    } else {
        a.max(b)
    }
}

/// The partial design describing one pipeline configuration's sub-space:
/// `pip` fixed per the config, the structurally forced UFs assigned
/// (Eq 15 full unroll under the pipe, Eq 9 / Theorem 4.11 / Merlin bans
/// above it — mirroring `nest_candidates`' menu rules), every other UF
/// left free for interval relaxation, capped by the partitioning rung.
fn config_partial(problem: &NlpProblem, cfg: &PipelineConfig) -> PartialDesign {
    let k = problem.kernel;
    let a = problem.analysis;
    let mut p = PartialDesign::free(k.n_loops()).with_uf_cap(problem.partition_cap());
    for i in 0..k.n_loops() {
        let l = LoopId(i as u32);
        p.assign_pipeline(l, cfg.pipelined.contains(&l));
        p.assign_tile(l, 1); // the solver explores tile = 1 (caching is Merlin-auto)
        let info = &a.deps.per_loop[i];
        let tc = &a.tcs[i];
        let pipelined_here = cfg.pipelined.contains(&l);
        let under_pipe = cfg.pipelined.iter().any(|&pp| k.is_under(l, pp));
        if pipelined_here {
            continue; // UF free (space menu)
        }
        if under_pipe {
            if info.reduction {
                // tree-unroll factor stays free
            } else if info.serializing {
                p.assign_uf(l, 1);
            } else if tc.is_constant() {
                p.assign_uf(l, tc.max.max(1)); // Eq 15
            } else {
                p.assign_uf(l, 1);
            }
        } else {
            // above the pipeline
            if problem.fine_grained_only
                || info.reduction
                || info.serializing
                || problem.coarse_banned.contains(&l.0)
            {
                p.assign_uf(l, 1);
            }
        }
    }
    p
}

/// Generate + score candidates for one nest under one pipeline config.
/// Pure (no shared state): the result is cached by
/// `(nest root, local pipeline choice)` in the sharded menu cache.
#[allow(clippy::too_many_arguments)]
fn nest_candidates(
    problem: &NlpProblem,
    cfg: &PipelineConfig,
    root: LoopId,
    cap: u64,
    evaluator: &dyn BatchEvaluator,
    base: &model::NestBreakdown,
    nest_idx: usize,
    lanes: &mut SoaScratch,
) -> CandSet {
    let k = problem.kernel;
    let a = problem.analysis;
    let nest_loops = k.nest_loops(root);

    // free loops and their UF menus
    let mut free: Vec<(LoopId, Vec<u64>)> = Vec::new();
    for &l in &nest_loops {
        let info = a.deps.loop_info(l);
        let tc = a.tc(l);
        let pipelined_here = cfg.pipelined.contains(&l);
        let under_pipe = cfg.pipelined.iter().any(|&p| k.is_under(l, p));
        let above_pipe = !pipelined_here && !under_pipe;
        if !tc.is_constant() {
            continue; // not unrollable
        }
        let menu: Vec<u64> = if pipelined_here {
            problem.space.ufs(l, a, cap)
        } else if under_pipe {
            if info.reduction {
                // tree-reduction unroll factor is free (Section 5.4's
                // TC/uf × log2(uf) term)
                problem.space.ufs(l, a, cap)
            } else {
                continue; // parallel under pipe: forced full (Eq 15)
            }
        } else if above_pipe {
            if problem.fine_grained_only
                || info.reduction
                || info.serializing
                || problem.coarse_banned.contains(&l.0)
            {
                continue; // Eq 9, coarse-grain illegal (Theorem 4.11), or
                          // Merlin already refused this loop in this run
            }
            problem.space.ufs(l, a, cap)
        } else {
            continue;
        };
        if menu.len() > 1 {
            free.push((l, menu));
        }
    }

    // cartesian product via an odometer over menu indices (last menu
    // varies fastest), capped at a fixed number of *complete* assignments
    // — the menu stays a deterministic lexicographic prefix instead of the
    // old mid-extension break that truncated the last loop asymmetrically
    let nest_cfg = PipelineConfig {
        pipelined: cfg
            .pipelined
            .iter()
            .copied()
            .filter(|&p| nest_loops.contains(&p))
            .collect(),
    };
    let mut designs: Vec<Design> = Vec::new();
    let mut metas: Vec<(Vec<(LoopId, u64)>, Vec<((u32, usize), u64)>)> = Vec::new();
    let mut idx = vec![0usize; free.len()];
    let mut enumerated = 0usize;
    let mut truncated = false;
    loop {
        enumerated += 1;
        let asg: Vec<(LoopId, u64)> = free
            .iter()
            .zip(idx.iter())
            .map(|((l, menu), &i)| (*l, menu[i]))
            .collect();
        // materialize the candidate (only this nest assigned) + prefilter
        // by per-nest partitioning
        let d = space::materialize(
            k,
            a,
            &nest_cfg,
            &|l| {
                asg.iter()
                    .find(|(al, _)| *al == l)
                    .map(|&(_, u)| u)
                    .unwrap_or(1)
            },
            &|_| 1,
        );
        // restrict materialization to this nest: zero out other nests
        let mut d2 = Design::empty(k);
        for &l in &nest_loops {
            d2.pragmas[l.0 as usize] = d.pragmas[l.0 as usize];
        }
        // per-nest partitioning signature + cap check
        let mut part: std::collections::BTreeMap<(u32, usize), u64> = Default::default();
        let mut ok = true;
        for arr in &k.arrays {
            let p = d2.partitioning(k, arr.id);
            if p > cap {
                ok = false;
                break;
            }
            for s in k.stmts() {
                for (acc, _) in k.stmt_accesses(s.id) {
                    if acc.array != arr.id {
                        continue;
                    }
                    for (dim, idx) in acc.indices.iter().enumerate() {
                        for l in idx.loops() {
                            let uf = d2.get(l).uf;
                            if uf > 1 {
                                let e = part.entry((arr.id.0, dim)).or_insert(1);
                                *e = (*e).max(uf);
                            }
                        }
                    }
                }
            }
        }
        if ok {
            designs.push(d2);
            metas.push((asg, part.into_iter().collect()));
        }
        if enumerated >= MAX_MENU_ASSIGNMENTS {
            // truncated iff combinations remain beyond this prefix
            truncated = idx
                .iter()
                .zip(free.iter())
                .any(|(&i, (_, menu))| i + 1 < menu.len());
            break;
        }
        // advance the odometer (last index fastest, matching the old
        // product order so stable ties sort identically)
        let mut advanced = false;
        for c in (0..free.len()).rev() {
            idx[c] += 1;
            if idx[c] < free[c].1.len() {
                advanced = true;
                break;
            }
            idx[c] = 0;
        }
        if !advanced {
            break;
        }
    }
    if designs.is_empty() {
        return CandSet {
            cands: vec![],
            scored: 0,
            truncated,
        };
    }

    // bulk score (lower bounds) — XLA artifact when plugged in; the
    // symbolic evaluator flushes through the worker's SoA lane buffer at
    // lane-width granularity
    let scores = evaluator.eval_batch_in(problem, &designs, lanes);
    let scored = designs.len() as u64;

    // extract additive per-nest latency from the total score:
    // total = Σ_m≠n base[m] + lat_n + comm   (sum-combine)
    let others: f64 = if base.sum_combine {
        base.per_nest
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != nest_idx)
            .map(|(_, &x)| x)
            .sum()
    } else {
        0.0
    };

    let mut out: Vec<Cand> = designs
        .into_iter()
        .zip(metas)
        .zip(scores)
        .filter_map(|((d, (ufs, part)), (score, dsp))| {
            // per-nest DSP prefilter (Eq 11 is max-over-nests separable)
            if dsp > problem.device.dsp_total as f64 {
                return None;
            }
            let lat = if base.sum_combine {
                (score - base.comm - others).max(0.0)
            } else {
                // max-combine: recompute the nest latency precisely
                model::nest_latencies(k, a, problem.device, &d).per_nest[nest_idx]
            };
            let risk: f64 = ufs
                .iter()
                .map(|&(l, u)| {
                    let meta = k.loop_meta(l);
                    let under = cfg.pipelined.iter().any(|&p| k.is_under(l, p));
                    let at = cfg.pipelined.contains(&l);
                    if u > 1 && !meta.innermost && !at && !under {
                        u as f64
                    } else {
                        1.0
                    }
                })
                .product();
            Some(Cand {
                ufs,
                lat,
                risk,
                part,
            })
        })
        .collect();
    // ascending latency; equal-latency candidates ordered by realization
    // risk so plateau ties are found low-risk-first (§Perf iteration 4).
    // total_cmp: a NaN score (broken plug-in evaluator, degenerate
    // device) sorts *after* every finite latency — the candidate is
    // explored last and rejected by the leaf verification — instead of
    // panicking the worker that built the menu.
    out.sort_by(|x, y| x.lat.total_cmp(&y.lat).then(x.risk.total_cmp(&y.risk)));
    // keep a deep-but-bounded front (ascending latency)
    out.truncate(4096);
    CandSet {
        cands: out,
        scored,
        truncated,
    }
}

/// Recursive branch-and-bound across nests. Zero allocations per node:
/// the admissible bound is a running prefix value + the precomputed
/// suffix-minima array, partition merging reuses worker scratch buffers,
/// and leaves materialize into a reused design (cloned only on
/// acceptance). Pure per configuration: prunes only against the local
/// incumbent list and the fixed tie budget.
#[allow(clippy::too_many_arguments)]
fn bb(
    sh: &Shared,
    ws: &mut WorkerScratch,
    cfg: &PipelineConfig,
    per_nest: &[&[Cand]],
    suffix: &[f64],
    depth: usize,
    prefix: f64,
    local: &mut Vec<Incumbent>,
    stats: &mut SolverStats,
    leaf_budget: &mut i64,
) {
    stats.nodes += 1;
    ws.cfg_nodes += 1;
    // anytime node budget per configuration (BARON-style): beyond it,
    // return the incumbent and report non-optimality — Table 7's timeout
    // behaviour. Per-config so *which* configs can blow it is a pure
    // property of the config; like the wall clock, this is an anytime
    // escape: a capped config that one interleaving guard-skips makes the
    // flag pessimistically false in the other (the design set is still
    // identical — every design of a skippable config loses the merge).
    if ws.cfg_nodes > NODE_CAP {
        sh.optimal.store(false, Ordering::Relaxed);
        return;
    }
    // throttled wall-clock check (syscall every 256 nodes, plus leaves)
    if (ws.cfg_nodes & 255) == 0 && sh.t0.elapsed().as_secs_f64() > sh.timeout_s {
        sh.optimal.store(false, Ordering::Relaxed);
        ws.timed_out = true;
        return;
    }

    if depth == per_nest.len() {
        leaf(sh, ws, cfg, per_nest, local, stats, leaf_budget);
        return;
    }

    let sum = sh.base.sum_combine;
    for (ci, cand) in per_nest[depth].iter().enumerate() {
        let local_kth = if local.len() >= sh.topk {
            local.last().map(|b| b.obj).unwrap_or(f64::INFINITY)
        } else {
            f64::INFINITY
        };
        // admissible bound: chosen prefix + this cand + per-nest minima
        // below (precomputed suffix) — no per-node vector
        let p2 = combine2(prefix, cand.lat, sum);
        let bound = combine2(p2, suffix[depth + 1], sum) + sh.base.comm;
        // while leaf budget remains, ties with the incumbent are explored
        // (risk tie-break on the plateau); afterwards only strict
        // improvements descend
        let cutoff = if *leaf_budget > 0 {
            local_kth * (1.0 + EPS)
        } else {
            local_kth
        };
        if bound > cutoff || (bound >= local_kth && *leaf_budget <= 0) {
            stats.pruned_bound += 1;
            break; // candidates sorted ascending → all following worse
        }
        // monotone partitioning pruning: merge the candidate's per-
        // (array, dim) UF maxima into the stack view and check every
        // touched array's cross-dimension product (Eq 13) — in reused
        // scratch, no maps
        if !ws.part_stack.is_empty() && !cand.part.is_empty() && part_violated(ws, cand, sh.cap) {
            stats.pruned_partition += 1;
            continue;
        }
        ws.chosen[depth] = ci;
        let pushed = cand.part.len();
        ws.part_stack.extend_from_slice(&cand.part);
        bb(
            sh,
            ws,
            cfg,
            per_nest,
            suffix,
            depth + 1,
            p2,
            local,
            stats,
            leaf_budget,
        );
        let keep = ws.part_stack.len() - pushed;
        ws.part_stack.truncate(keep);
        if ws.timed_out {
            return;
        }
    }
}

/// Eq 13 check over `part_stack ∪ cand.part` using the reused merge
/// buffer: sort by (array, dim), fold per-dimension maxima into per-array
/// products, compare against the cap.
fn part_violated(ws: &mut WorkerScratch, cand: &Cand, cap: u64) -> bool {
    ws.merged.clear();
    ws.merged.extend_from_slice(&ws.part_stack);
    ws.merged.extend_from_slice(&cand.part);
    ws.merged.sort_unstable();
    let m = &ws.merged;
    let mut i = 0;
    while i < m.len() {
        let arr = m[i].0 .0;
        let mut prod: u64 = 1;
        while i < m.len() && m[i].0 .0 == arr {
            let dim = m[i].0 .1;
            let mut dmax = 1u64;
            while i < m.len() && m[i].0 == (arr, dim) {
                dmax = dmax.max(m[i].1);
                i += 1;
            }
            prod = prod.saturating_mul(dmax);
        }
        if prod > cap {
            return true;
        }
    }
    false
}

/// Verify one leaf: materialize the full design into the reused buffer,
/// run the single-tape feasibility + objective check, and binary-insert
/// an accepted incumbent into the local top-k (fingerprint-set dedup, no
/// structural scans, no re-sort).
fn leaf(
    sh: &Shared,
    ws: &mut WorkerScratch,
    cfg: &PipelineConfig,
    per_nest: &[&[Cand]],
    local: &mut Vec<Incumbent>,
    stats: &mut SolverStats,
    leaf_budget: &mut i64,
) {
    stats.leaves += 1;
    *leaf_budget -= 1;
    let problem = sh.problem;
    let k = problem.kernel;

    // materialize the full design from the chosen per-nest candidates
    // (linear scan over the chosen UF lists; no map)
    let chosen = &ws.chosen;
    let uf_of = |l: LoopId| -> u64 {
        for (ni, cands) in per_nest.iter().enumerate() {
            for &(al, u) in &cands[chosen[ni]].ufs {
                if al == l {
                    return u;
                }
            }
        }
        1
    };
    space::materialize_into(k, problem.analysis, cfg, &uf_of, &|_| 1, &mut ws.leaf);

    // verify precisely with a single tape evaluation
    let Some(obj) = problem.check_objective_in(&mut ws.eval, &ws.leaf) else {
        stats.infeasible += 1;
        return;
    };

    // exact rejection: the rank order compares objectives exactly, so a
    // leaf strictly above the k-th would binary-insert at position k and
    // be truncated right back out — skip the clone/insert entirely. Exact
    // ties still enter (the risk / pragma-vector keys may rank them in).
    // No tolerance needed here: obj and the stored incumbents come from
    // the same tape, so plateau ties are bit-equal.
    let local_kth = if local.len() >= sh.topk {
        local.last().map(|b| b.obj).unwrap_or(f64::INFINITY)
    } else {
        f64::INFINITY
    };
    if obj > local_kth {
        return;
    }

    let risk = design_risk(k, &ws.leaf);

    // fingerprint-set dedup (a rejected duplicate would re-rank
    // identically; the deterministic 64-bit key replaces the old
    // structural equality scan over the whole incumbent list)
    if !ws.seen.insert(design_key(&ws.leaf)) {
        return;
    }
    let inc = Incumbent {
        design: ws.leaf.clone(),
        obj,
        risk,
    };
    let pos = local.partition_point(|x| rank_cmp(x, &inc) == std::cmp::Ordering::Less);
    local.insert(pos, inc);
    local.truncate(sh.topk);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{self, Size};
    use crate::hls::Device;
    use crate::ir::DType;
    use crate::poly::Analysis;

    fn solve_kernel(name: &str, size: Size, cap: u64, fine: bool) -> (SolveResult, f64) {
        let k = benchmarks::build(name, size, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let p = NlpProblem::new(&k, &a, &dev, cap, fine);
        let empty_obj = p.objective(&Design::empty(&k));
        let r = solve(&p, 30.0, 4, &RustFeatureEvaluator);
        (r, empty_obj)
    }

    #[test]
    fn solver_finds_feasible_better_than_empty() {
        for name in ["gemm", "bicg", "atax", "mvt"] {
            let (r, empty_obj) = solve_kernel(name, Size::Small, 512, false);
            let (d, obj) = r.best().expect(name).clone();
            assert!(obj < empty_obj * 0.5, "{name}: {obj} vs empty {empty_obj}");
            assert!(d.pipelined().count() >= 1 || d.pragmas.iter().any(|p| p.uf > 1));
            assert!(r.lower_bound <= obj + 1.0);
        }
    }

    #[test]
    fn solver_matches_bruteforce_on_tiny_space() {
        // small gemm with tight partition cap → tiny space; brute-force the
        // same space definition and compare optima
        let k = benchmarks::kernel_gemm(8, 8, 8, DType::F32);
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let p = NlpProblem::new(&k, &a, &dev, 64, false);
        let r = solve(&p, 30.0, 1, &RustFeatureEvaluator);
        let best = r.best().unwrap().1;

        // brute force over the full valid space
        let space = crate::pragma::Space::new(&k, &a);
        let mut bf = f64::INFINITY;
        for cfg in &space.pipeline_configs {
            let free: Vec<LoopId> = k
                .nest_loops(k.nest_roots()[0])
                .into_iter()
                .collect();
            // enumerate UF assignments over all loops crudely
            let menus: Vec<Vec<u64>> = free
                .iter()
                .map(|&l| space.ufs(l, &a, 64))
                .collect();
            let mut idx = vec![0usize; menus.len()];
            loop {
                let d = crate::pragma::space::materialize(
                    &k,
                    &a,
                    cfg,
                    &|l| {
                        free.iter()
                            .position(|&x| x == l)
                            .map(|i| menus[i][idx[i]])
                            .unwrap_or(1)
                    },
                    &|_| 1,
                );
                if p.check(&d).is_empty() {
                    bf = bf.min(p.objective(&d));
                }
                // odometer
                let mut c = 0;
                loop {
                    if c == menus.len() {
                        break;
                    }
                    idx[c] += 1;
                    if idx[c] < menus[c].len() {
                        break;
                    }
                    idx[c] = 0;
                    c += 1;
                }
                if c == menus.len() {
                    break;
                }
            }
        }
        assert!(
            (best - bf).abs() / bf < 1e-9,
            "solver {best} vs brute force {bf}"
        );
    }

    #[test]
    fn fine_grained_mode_restricts_coarse() {
        let (r, _) = solve_kernel("gemm", Size::Small, 512, true);
        let (d, _) = r.best().unwrap();
        // Eq 9: loops above the pipeline must have UF = 1
        let k = benchmarks::build("gemm", Size::Small, DType::F32).unwrap();
        for lp in d.pipelined() {
            let mut cur = k.loop_meta(lp).parent;
            while let Some(l) = cur {
                assert_eq!(d.get(l).uf, 1, "coarse UF above pipeline in fine mode");
                cur = k.loop_meta(l).parent;
            }
        }
    }

    #[test]
    fn partition_ladder_monotone() {
        // smaller cap → can't be faster
        let (r512, _) = solve_kernel("gemm", Size::Small, 512, false);
        let (r8, _) = solve_kernel("gemm", Size::Small, 8, false);
        let b512 = r512.best().unwrap().1;
        let b8 = r8.best().unwrap().1;
        assert!(b512 <= b8 * 1.0001, "cap 512 {b512} vs cap 8 {b8}");
    }

    #[test]
    fn solutions_respect_all_constraints() {
        for name in ["2mm", "gesummv", "doitgen"] {
            let k = benchmarks::build(name, Size::Small, DType::F32).unwrap();
            let a = Analysis::new(&k);
            let dev = Device::u200();
            let p = NlpProblem::new(&k, &a, &dev, 256, false);
            let r = solve(&p, 30.0, 4, &RustFeatureEvaluator);
            for (d, _) in &r.designs {
                assert!(p.check(d).is_empty(), "{name}: infeasible result");
            }
        }
    }

    #[test]
    fn symbolic_evaluator_matches_rust_evaluator_best() {
        // exact-model scoring may reorder candidate fronts, but the leaf
        // verification is the same compiled objective, so the optimum on a
        // small exhaustive space must agree
        let k = benchmarks::kernel_gemm(8, 8, 8, DType::F32);
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let p = NlpProblem::new(&k, &a, &dev, 64, false);
        let r1 = solve(&p, 30.0, 1, &RustFeatureEvaluator);
        let r2 = solve(&p, 30.0, 1, &SymbolicEvaluator);
        let (b1, b2) = (r1.best().unwrap().1, r2.best().unwrap().1);
        assert!(
            (b1 - b2).abs() / b1.max(1.0) < 1e-9,
            "rust {b1} vs symbolic {b2}"
        );
    }

    #[test]
    fn stats_separate_relaxation_prunes_from_infeasible() {
        // a tight partition cap forces the b&b to cut something
        let k = benchmarks::build("2mm", Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let p = NlpProblem::new(&k, &a, &dev, 8, false);
        let r = solve(&p, 30.0, 2, &RustFeatureEvaluator);
        assert!(r.best().is_some());
        assert!(
            r.pruned_by_relaxation() + r.stats.pruned_partition + r.stats.infeasible > 0,
            "{:?}",
            r.stats
        );
    }

    #[test]
    fn config_partial_bound_admissible_for_solver_designs() {
        // guards the hand-mirrored Eq 9/15 rules in `config_partial`
        // against drift from `space::materialize`/`nest_candidates`: for
        // every design the real solver returns, the interval bound of its
        // pipeline config's partial design must not exceed the design's
        // own objective — if `config_partial` ever pins a pragma the
        // candidate space actually leaves free (or vice versa), this
        // inequality is the first thing to break
        for (name, fine) in [
            ("gemm", false),
            ("gemm", true),
            ("2mm", false),
            ("seidel-2d", false),
        ] {
            let k = benchmarks::build(name, Size::Small, DType::F32).unwrap();
            let a = Analysis::new(&k);
            let dev = Device::u200();
            let p = NlpProblem::new(&k, &a, &dev, 512, fine);
            let r = solve(&p, 30.0, 4, &RustFeatureEvaluator);
            for (d, obj) in &r.designs {
                let cfg = PipelineConfig {
                    pipelined: d.pipelined().collect(),
                };
                let partial = config_partial(&p, &cfg);
                let lb = p.bound.lower_bound(&partial);
                assert!(
                    lb <= obj * (1.0 + 1e-9),
                    "{name} fine={fine}: config bound {lb} beats returned design {obj} ({})",
                    d.fingerprint()
                );
            }
        }
    }

    #[test]
    fn infeasible_counter_fires_when_no_design_is_legal() {
        // zero DSP budget: every candidate/leaf violates Eq 11, so the
        // search must come back empty with the rejections accounted as
        // infeasible — not silently dropped, not counted as bound prunes
        let k = benchmarks::build("gemm", Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let mut dev = Device::u200();
        dev.dsp_total = 0;
        let p = NlpProblem::new(&k, &a, &dev, u64::MAX, false);
        let r = solve(&p, 30.0, 2, &RustFeatureEvaluator);
        assert!(r.best().is_none());
        assert!(r.infeasible_nodes() > 0, "{:?}", r.stats);
        assert_eq!(r.stats.pruned_relaxation, 0, "{:?}", r.stats);
    }

    #[test]
    fn timeout_returns_anytime_result() {
        let k = benchmarks::build("3mm", Size::Medium, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let p = NlpProblem::new(&k, &a, &dev, u64::MAX, false);
        let r = solve(&p, 0.000001, 1, &RustFeatureEvaluator);
        assert!(!r.optimal);
        assert!(r.lower_bound.is_finite() || r.designs.is_empty());
    }

    #[test]
    fn parallel_matches_serial_on_gemm() {
        // the exhaustive 24-kernel parity property lives in
        // tests/property_solver_parallel.rs; this is the in-module smoke
        let k = benchmarks::build("gemm", Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let p = NlpProblem::new(&k, &a, &dev, 512, false);
        let serial = solve_jobs(&p, 60.0, 4, &RustFeatureEvaluator, 1);
        let par = solve_jobs(&p, 60.0, 4, &RustFeatureEvaluator, 4);
        assert_eq!(serial.optimal, par.optimal);
        assert_eq!(serial.lower_bound.to_bits(), par.lower_bound.to_bits());
        assert_eq!(serial.designs.len(), par.designs.len());
        for ((d1, o1), (d2, o2)) in serial.designs.iter().zip(&par.designs) {
            assert_eq!(d1, d2);
            assert_eq!(o1.to_bits(), o2.to_bits());
        }
        assert_eq!(par.jobs, 4);
    }

    #[test]
    fn seeded_solve_matches_cold_solve_when_complete() {
        let k = benchmarks::build("gemm", Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let p = NlpProblem::new(&k, &a, &dev, 512, false);
        let cold = solve_jobs(&p, 30.0, 3, &RustFeatureEvaluator, 1);
        assert!(cold.optimal);
        // seeding with the cold optimum (the warm-cache scenario) must
        // return the identical design set — the seeds are already in the
        // search space, so they can only prune, never change the answer
        let seeds: Vec<Design> = cold.designs.iter().map(|(d, _)| d.clone()).collect();
        let warm = solve_jobs_seeded(&p, 30.0, 3, &RustFeatureEvaluator, 1, &seeds);
        assert_eq!(cold.designs.len(), warm.designs.len());
        for ((d1, o1), (d2, o2)) in cold.designs.iter().zip(&warm.designs) {
            assert_eq!(d1, d2);
            assert_eq!(o1.to_bits(), o2.to_bits());
        }
        // an infeasible or foreign-shape seed is dropped, not propagated
        let mut bad = Design::empty(&k);
        bad.get_mut(LoopId(0)).uf = 7; // 60 % 7 != 0 → infeasible
        let alien = Design { pragmas: vec![] };
        let r = solve_jobs_seeded(&p, 30.0, 3, &RustFeatureEvaluator, 1, &[bad.clone(), alien]);
        assert!(!r.designs.iter().any(|(d, _)| *d == bad));
        assert_eq!(r.designs.len(), cold.designs.len());
    }

    #[test]
    fn seeded_solve_can_only_improve_the_incumbent_set() {
        // seeds from a *different* rung (larger cap) stay in the result
        // when feasible here — the documented "may improve" escape
        let k = benchmarks::build("2mm", Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let p8 = NlpProblem::new(&k, &a, &dev, 8, false);
        let cold8 = solve_jobs(&p8, 30.0, 2, &RustFeatureEvaluator, 1);
        let p512 = NlpProblem::new(&k, &a, &dev, 512, false);
        let best512 = solve_jobs(&p512, 30.0, 2, &RustFeatureEvaluator, 1);
        let seeds: Vec<Design> = best512.designs.iter().map(|(d, _)| d.clone()).collect();
        let warm8 = solve_jobs_seeded(&p8, 30.0, 2, &RustFeatureEvaluator, 1, &seeds);
        let cold_best = cold8.best().unwrap().1;
        let warm_best = warm8.best().unwrap().1;
        assert!(warm_best <= cold_best, "warm {warm_best} vs cold {cold_best}");
    }

    #[test]
    fn design_risk_counts_coarse_factors_only() {
        let k = benchmarks::build("gemm", Size::Small, DType::F32).unwrap();
        let empty = Design::empty(&k);
        assert_eq!(design_risk(&k, &empty), 1.0);
        // a coarse UF on the outer loop multiplies the risk…
        let mut coarse = Design::empty(&k);
        coarse.get_mut(LoopId(0)).uf = 4;
        assert_eq!(design_risk(&k, &coarse), 4.0);
        // …while the same factor under a pipeline is risk-free
        let mut fine = Design::empty(&k);
        fine.get_mut(LoopId(0)).pipeline = true;
        fine.get_mut(LoopId(1)).uf = 4;
        assert_eq!(design_risk(&k, &fine), 1.0);
    }

    #[test]
    fn rank_cmp_ranks_nan_last_instead_of_panicking() {
        use std::cmp::Ordering::Less;
        let k = benchmarks::kernel_gemm(8, 8, 8, DType::F32);
        let inc = |obj: f64, risk: f64| Incumbent {
            design: Design::empty(&k),
            obj,
            risk,
        };
        // IEEE-754 totalOrder: positive NaN (what arithmetic produces)
        // sits above +inf, so a NaN objective loses to *everything*
        assert_eq!(rank_cmp(&inc(10.0, 1.0), &inc(f64::NAN, 1.0)), Less);
        assert_eq!(
            rank_cmp(&inc(f64::INFINITY, 1.0), &inc(f64::NAN, 1.0)),
            Less,
            "NaN must rank even after +inf"
        );
        // a NaN risk falls through the same way
        assert_eq!(rank_cmp(&inc(10.0, 1.0), &inc(10.0, f64::NAN)), Less);
        // and a pool containing NaNs sorts (no panic) finite-first
        let mut pool = vec![inc(f64::NAN, 1.0), inc(10.0, 1.0), inc(f64::INFINITY, 1.0)];
        pool.sort_by(rank_cmp);
        assert_eq!(pool[0].obj, 10.0);
        assert!(pool[2].obj.is_nan());
    }

    #[test]
    fn front_mode_is_exhaustive_and_parallel_identical() {
        let k = benchmarks::build("gemm", Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let p = NlpProblem::new(&k, &a, &dev, 64, false);
        let fc = FrontConfig {
            epsilon: 0.05,
            max_points: 8,
        };
        let f1 = solve_front(&p, 30.0, &fc, &RustFeatureEvaluator, 1);
        // guard disabled → every pipeline configuration is processed
        assert_eq!(f1.stats.configs as usize, p.space.pipeline_configs.len());
        assert!(!f1.points.is_empty() && f1.points.len() <= fc.max_points);
        let f4 = solve_front(&p, 30.0, &fc, &RustFeatureEvaluator, 4);
        assert_eq!(f1.points.len(), f4.points.len());
        for (x, y) in f1.points.iter().zip(&f4.points) {
            assert_eq!(x.design, y.design);
            assert_eq!(x.latency.to_bits(), y.latency.to_bits());
            assert_eq!(x.dsp.to_bits(), y.dsp.to_bits());
            assert_eq!(x.lut.to_bits(), y.lut.to_bits());
        }
    }

    #[test]
    fn atomic_f64_min_is_monotone() {
        let a = AtomicF64Min::new(f64::INFINITY);
        assert!(a.get().is_infinite());
        a.fetch_min(10.0);
        assert_eq!(a.get(), 10.0);
        a.fetch_min(20.0);
        assert_eq!(a.get(), 10.0, "min must not regress");
        a.fetch_min(5.0);
        assert_eq!(a.get(), 5.0);
    }

    #[test]
    fn rank_order_is_total_and_deterministic() {
        let k = benchmarks::kernel_gemm(8, 8, 8, DType::F32);
        let d1 = Design::empty(&k);
        let mut d2 = Design::empty(&k);
        d2.get_mut(LoopId(0)).uf = 2;
        let a = Incumbent {
            design: d1.clone(),
            obj: 10.0,
            risk: 1.0,
        };
        let b = Incumbent {
            design: d2,
            obj: 10.0,
            risk: 1.0,
        };
        // equal objective and risk: the pragma vector breaks the tie, and
        // consistently so in both directions
        assert_eq!(rank_cmp(&a, &b), rank_cmp(&b, &a).reverse());
        assert_ne!(rank_cmp(&a, &b), std::cmp::Ordering::Equal);
        let c = Incumbent {
            design: d1,
            obj: 9.0,
            risk: 5.0,
        };
        assert_eq!(rank_cmp(&c, &a), std::cmp::Ordering::Less, "objective first");
    }
}
