//! The Non-Linear Program of Section 5 and its solver.
//!
//! * [`formulation`] — a thin view over the shared symbolic bound model
//!   (`model::sym::BoundModel`): the constraint set (Eqs 1–15) and the
//!   Section 5.4 objective are the model's first-class `Constraint` /
//!   expression values, evaluated through the compiled tape.
//! * [`solver`] — the specialized global optimizer standing in for AMPL +
//!   BARON: per-pipeline-configuration enumeration over the divisor
//!   lattice with branch-and-bound across loop nests, admissible
//!   latency bounds, monotone constraint propagation (partitioning/DSP),
//!   and a deterministic time budget. Pipeline configurations are dealt
//!   bound-ascending into per-worker deques and drained by a scoped
//!   work-stealing team ([`solve_jobs`]), with a deterministic reduction
//!   making `jobs = N` bit-identical to `jobs = 1`. On timeout it
//!   returns the best incumbent plus a valid lower bound, exactly as
//!   BARON's anytime behaviour (Table 7).
//! * [`front`] — epsilon-dominance Pareto-front reduction over
//!   `(latency, DSP, on-chip bytes, LUT)`: the merge-order-invariant
//!   grid archive behind [`solve_front`], which runs the same
//!   branch-and-bound in exhaustive mode (guard disabled) and reduces
//!   every incumbent to a deterministic front.

pub mod formulation;
pub mod front;
pub mod solver;

pub use formulation::{NlpProblem, Violation};
pub use front::{FrontConfig, FrontPoint};
pub use solver::{
    default_jobs, design_risk, solve, solve_front, solve_jobs, solve_jobs_seeded, BatchEvaluator,
    FrontResult, RustFeatureEvaluator, SolveResult, SolverStats, SymbolicEvaluator,
};
