//! The Non-Linear Program of Section 5 and its solver.
//!
//! * [`formulation`] — a thin view over the shared symbolic bound model
//!   (`model::sym::BoundModel`): the constraint set (Eqs 1–15) and the
//!   Section 5.4 objective are the model's first-class `Constraint` /
//!   expression values, evaluated through the compiled tape.
//! * [`solver`] — the specialized global optimizer standing in for AMPL +
//!   BARON: per-pipeline-configuration enumeration over the divisor
//!   lattice with branch-and-bound across loop nests, admissible
//!   latency bounds, monotone constraint propagation (partitioning/DSP),
//!   and a deterministic time budget. Pipeline configurations are dealt
//!   bound-ascending into per-worker deques and drained by a scoped
//!   work-stealing team ([`solve_jobs`]), with a deterministic reduction
//!   making `jobs = N` bit-identical to `jobs = 1`. On timeout it
//!   returns the best incumbent plus a valid lower bound, exactly as
//!   BARON's anytime behaviour (Table 7).

pub mod formulation;
pub mod solver;

pub use formulation::{NlpProblem, Violation};
pub use solver::{
    default_jobs, design_risk, solve, solve_jobs, solve_jobs_seeded, BatchEvaluator,
    RustFeatureEvaluator, SolveResult, SolverStats, SymbolicEvaluator,
};
