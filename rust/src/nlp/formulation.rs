//! NLP formulation: variables, constants and constraints (Section 5) —
//! now a **thin view over the shared symbolic bound model**
//! (`model::sym::BoundModel`).
//!
//! Variables (per loop `l`): `loop_l_UF`, `loop_l_tile`, `loop_l_pip`
//! (cache booleans are resolved automatically by Merlin in our pipeline).
//! Constants (from `poly::Analysis`): trip counts, II ingredients,
//! iteration latencies, DSP per op, dependence distances.
//!
//! The constraint set, numbered as in the paper:
//!
//! | Eq | Meaning | Where enforced |
//! |----|---------|----------------|
//! | 1  | `1 ≤ UF_l ≤ TC_l` | candidate generation + `BoundModel` domains |
//! | 2  | `1 ≤ tile_l ≤ TC_l` | candidate generation + `BoundModel` domains |
//! | 3  | `pip_l ∈ {0,1}` | `PipelineConfig` |
//! | 4  | cache booleans | Merlin-auto |
//! | 5  | ≤ 1 pipelined loop per statement | antichain enumeration |
//! | 6  | `TC_l mod UF_l == 0` | `Constraint::Divides` (shared) |
//! | 7  | `TC_l mod tile_l == 0` | divisor sets |
//! | 8  | `UF_l ≤ d_l` when the carried distance `d_l > 1` | `Constraint::Distance` (shared) |
//! | 9  | fine-grained mode: `UF = 1` above the pipeline | candidate generation |
//! | 10 | `Π UF ≤ MAX_PARTITIONING` per statement | `Constraint::Partitioning` (shared) |
//! | 11 | optimistic DSP ≤ available | `Constraint::Dsp` (shared) |
//! | 12 | cached footprints ≤ on-chip memory | `Constraint::OnChip` (shared) |
//! | 13 | per-array cross-dim partitioning ≤ cap | `Constraint::Partitioning` (shared) |
//! | 14 | cache only above the pipeline | Merlin-auto |
//! | 15 | full unroll under the pipeline | `space::materialize` |
//!
//! "Shared" rows are [`model::sym::Constraint`] values built once per
//! kernel; [`NlpProblem::check`] walks them and the objective is the
//! compiled symbolic tape — the same objects the solver's interval
//! relaxation and the DSE's partial-configuration pruning consume. The
//! pre-IR hand-written path survives as [`NlpProblem::check_legacy`] /
//! [`NlpProblem::objective_reference`], the executable reference the
//! model/NLP parity property test compares against.

use crate::hls::Device;
use crate::ir::Kernel;
use crate::model::{self, sym};
use crate::poly::Analysis;
use crate::pragma::{Design, Space};
use std::sync::{Arc, Mutex};

pub use crate::model::sym::Violation;

/// One NLP instance: a kernel + the sub-space restrictions Algorithm 1
/// sweeps (max array partitioning, parallelism mode), viewing the shared
/// [`sym::BoundModel`] for its objective and constraints.
///
/// `Send + Sync`: the parallel solver shares one `&NlpProblem` across its
/// whole worker team, so the model handles are `Arc` (one symbolic build
/// serves every thread) and the convenience scratch sits behind a mutex —
/// the solver's hot paths bypass it entirely with the `*_in` methods and
/// per-worker [`sym::EvalScratch`] buffers (see [`NlpProblem::scratch`]).
pub struct NlpProblem<'k> {
    /// The kernel under optimization.
    pub kernel: &'k Kernel,
    /// Its exact polyhedral analysis.
    pub analysis: &'k Analysis,
    /// The target device model.
    pub device: &'k Device,
    /// Enumerated design space (UF menus, pipeline configs).
    pub space: Space<'k>,
    /// `MAX_PARTITIONING` for this DSE step (`u64::MAX` = ∞ rung).
    pub max_partitioning: u64,
    /// Eq 9: restrict to fine-grained parallelism (UF = 1 above pipeline).
    pub fine_grained_only: bool,
    /// Loops whose coarse-grained replication Merlin refused in an earlier
    /// synthesis of this DSE run (Section 7.5: the DSE detects pragmas not
    /// applied and restricts the subspace accordingly).
    pub coarse_banned: std::collections::BTreeSet<u32>,
    /// The shared symbolic bound model (objective + Eqs 1–15). `Arc`: the
    /// model depends only on (kernel, device), so callers that sweep
    /// sub-space restrictions (the DSE ladder) — and the solver's worker
    /// threads — share one build.
    pub bound: Arc<sym::BoundModel>,
    /// Its flattened batch evaluator (the leaf/scoring hot path).
    pub compiled: Arc<sym::CompiledModel>,
    /// Convenience-path scratch (the `check`/`objective` methods).
    /// Uncontended in serial use; worker threads use their own scratch.
    scratch: Mutex<sym::EvalScratch>,
}

impl<'k> NlpProblem<'k> {
    /// Build the problem (and its symbolic model) for one sub-space.
    pub fn new(
        kernel: &'k Kernel,
        analysis: &'k Analysis,
        device: &'k Device,
        max_partitioning: u64,
        fine_grained_only: bool,
    ) -> NlpProblem<'k> {
        let bound = Arc::new(sym::BoundModel::build(kernel, analysis, device));
        let compiled = Arc::new(bound.compile());
        NlpProblem::with_model(
            kernel,
            analysis,
            device,
            max_partitioning,
            fine_grained_only,
            bound,
            compiled,
        )
    }

    /// Build a problem around an already-built (shared) bound model —
    /// what `run_nlp_dse` uses so the ladder's 22 sub-space instances
    /// reuse one symbolic build.
    pub fn with_model(
        kernel: &'k Kernel,
        analysis: &'k Analysis,
        device: &'k Device,
        max_partitioning: u64,
        fine_grained_only: bool,
        bound: Arc<sym::BoundModel>,
        compiled: Arc<sym::CompiledModel>,
    ) -> NlpProblem<'k> {
        let scratch = Mutex::new(compiled.scratch());
        NlpProblem {
            kernel,
            analysis,
            device,
            space: Space::new(kernel, analysis),
            max_partitioning,
            fine_grained_only,
            coarse_banned: Default::default(),
            bound,
            compiled,
            scratch,
        }
    }

    /// Effective partitioning cap: min(device limit, DSE rung).
    pub fn partition_cap(&self) -> u64 {
        self.device.max_array_partition.min(self.max_partitioning)
    }

    /// A fresh tape scratch sized for this problem's compiled model —
    /// one per solver worker, so the hot paths below never touch the
    /// shared convenience mutex.
    pub fn scratch(&self) -> sym::EvalScratch {
        self.compiled.scratch()
    }

    /// A fresh structure-of-arrays lane scratch for this problem's
    /// compiled model — one per solver worker, backing the batched
    /// (`evaluate_batch_soa_in`) leaf-scoring path.
    pub fn soa_scratch(&self) -> sym::SoaScratch {
        self.compiled.soa_scratch()
    }

    /// Check every formulation constraint on a complete design; returns the
    /// list of violations (empty = feasible point of the NLP), produced by
    /// the shared [`sym::Constraint`] objects.
    pub fn check(&self, d: &Design) -> Vec<Violation> {
        let mut s = self.scratch.lock().unwrap();
        self.bound
            .check(&self.compiled, &mut s, d, self.partition_cap())
    }

    /// The Section 5.4 objective: the latency lower bound of the design,
    /// from the compiled symbolic tape.
    pub fn objective(&self, d: &Design) -> f64 {
        let mut s = self.scratch.lock().unwrap();
        self.compiled.evaluate(d, &mut s).total_cycles
    }

    /// [`Self::objective`] into a caller-owned scratch (no lock).
    pub fn objective_in(&self, s: &mut sym::EvalScratch, d: &Design) -> f64 {
        self.compiled.evaluate(d, s).total_cycles
    }

    /// Combined feasibility + objective with a single tape evaluation —
    /// the solver's leaf hot path (convenience form; workers use
    /// [`Self::check_objective_in`]). Returns `None` when any constraint
    /// is violated.
    pub fn check_objective(&self, d: &Design) -> Option<f64> {
        let mut s = self.scratch.lock().unwrap();
        self.bound
            .check_objective(&self.compiled, &mut s, d, self.partition_cap())
    }

    /// [`Self::check_objective`] into a caller-owned scratch — the
    /// per-worker leaf hot path of the parallel solver (no lock, no
    /// allocation once the scratch is warm).
    pub fn check_objective_in(&self, s: &mut sym::EvalScratch, d: &Design) -> Option<f64> {
        self.bound
            .check_objective(&self.compiled, s, d, self.partition_cap())
    }

    // --- pre-IR reference implementations ---------------------------------
    // Kept verbatim from before the symbolic IR: the parity property test
    // (`tests/property_model_sym.rs`) asserts `check == check_legacy` and
    // `objective == objective_reference` on every kernel.

    /// The hand-written constraint walk the shared constraints replaced.
    pub fn check_legacy(&self, d: &Design) -> Vec<Violation> {
        let mut out = Vec::new();
        let k = self.kernel;

        // Eq 6 + Eq 8 per loop
        for (i, p) in d.pragmas.iter().enumerate() {
            let tc = &self.analysis.tcs[i];
            if p.uf > 1 {
                if !tc.is_constant() || tc.max % p.uf != 0 {
                    out.push(Violation::Divisibility(i as u32, p.uf, tc.max));
                }
                let info = &self.analysis.deps.per_loop[i];
                if let Some(dd) = info.min_distance {
                    if dd > 1 && p.uf > dd {
                        out.push(Violation::Dependence(i as u32, p.uf, dd));
                    }
                }
            }
        }

        // Eq 10/13 partitioning per array
        let cap = self.partition_cap();
        for arr in &k.arrays {
            let part = d.partitioning(k, arr.id);
            if part > cap {
                out.push(Violation::Partitioning(arr.name.clone(), part, cap));
            }
        }

        // Eq 11 + Eq 12 via the recursive model
        let r = model::evaluate(k, self.analysis, self.device, d);
        if r.dsp > self.device.dsp_total as f64 {
            out.push(Violation::Dsp(r.dsp as u64, self.device.dsp_total));
        }
        if r.onchip_bytes > self.device.onchip_bytes as f64 {
            out.push(Violation::OnChip(
                r.onchip_bytes as u64,
                self.device.onchip_bytes,
            ));
        }
        out
    }

    /// The objective via the recursive reference model.
    pub fn objective_reference(&self, d: &Design) -> f64 {
        model::evaluate(self.kernel, self.analysis, self.device, d).total_cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{self, Size};
    use crate::ir::{DType, LoopId};

    fn problem<'a>(k: &'a Kernel, a: &'a Analysis, dev: &'a Device) -> NlpProblem<'a> {
        NlpProblem::new(k, a, dev, u64::MAX, false)
    }

    #[test]
    fn problem_is_send_and_sync() {
        // the parallel solver shares `&NlpProblem` across its worker team
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<NlpProblem<'static>>();
    }

    #[test]
    fn explicit_scratch_paths_match_convenience_paths() {
        let k = benchmarks::build("gemm", Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let p = problem(&k, &a, &dev);
        let mut s = p.scratch();
        let mut d = Design::empty(&k);
        d.get_mut(LoopId(3)).uf = 10;
        assert_eq!(p.objective(&d).to_bits(), p.objective_in(&mut s, &d).to_bits());
        assert_eq!(p.check_objective(&d), p.check_objective_in(&mut s, &d));
    }

    #[test]
    fn empty_design_feasible() {
        let k = benchmarks::build("gemm", Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let p = problem(&k, &a, &dev);
        assert!(p.check(&Design::empty(&k)).is_empty());
    }

    #[test]
    fn non_divisor_uf_flagged() {
        let k = benchmarks::build("gemm", Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let p = problem(&k, &a, &dev);
        let mut d = Design::empty(&k);
        d.get_mut(LoopId(0)).uf = 7; // 60 % 7 != 0
        let v = p.check(&d);
        assert!(v
            .iter()
            .any(|v| matches!(v, Violation::Divisibility(0, 7, 60))));
    }

    #[test]
    fn partition_cap_flagged() {
        let k = benchmarks::build("gemm", Size::Medium, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let mut p = problem(&k, &a, &dev);
        p.max_partitioning = 8;
        let mut d = Design::empty(&k);
        d.get_mut(LoopId(3)).uf = 20; // j1 → partitioning 20 > 8
        let v = p.check(&d);
        assert!(v.iter().any(|v| matches!(v, Violation::Partitioning(..))));
    }

    #[test]
    fn dsp_violation_flagged() {
        let k = benchmarks::build("gemm", Size::Medium, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let p = problem(&k, &a, &dev);
        let mut d = Design::empty(&k);
        // 200×220 replication of a 3-dsp statement vastly exceeds 6840
        d.get_mut(LoopId(0)).uf = 200;
        d.get_mut(LoopId(3)).uf = 220;
        let v = p.check(&d);
        assert!(v.iter().any(|v| matches!(v, Violation::Dsp(..))));
    }

    #[test]
    fn shared_constraints_match_legacy_walk() {
        // spot check of the parity invariant (the exhaustive version lives
        // in tests/property_model_sym.rs)
        let k = benchmarks::build("2mm", Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let mut p = problem(&k, &a, &dev);
        p.max_partitioning = 16;
        for uf in [1u64, 2, 7, 30, 180] {
            let mut d = Design::empty(&k);
            d.get_mut(LoopId(0)).uf = uf;
            assert_eq!(p.check(&d), p.check_legacy(&d), "uf={uf}");
            let o = p.objective(&d);
            let r = p.objective_reference(&d);
            assert!((o - r).abs() / r.max(1.0) < 1e-9, "uf={uf}: {o} vs {r}");
        }
    }
}
