//! Epsilon-dominance Pareto-front reduction over
//! `(latency, DSP, on-chip bytes, LUT)` — the order-invariant archive
//! behind [`solve_front`](super::solve_front).
//!
//! ## The grid archive
//!
//! With `epsilon > 0` every point is mapped to a **grid box**: coordinate
//! `i` becomes `floor(ln(1 + v_i) / ln(1 + epsilon))`, so each box spans
//! one multiplicative `(1 + epsilon)` band per axis (the classic
//! epsilon-Pareto archive of Laumanns et al., also what MailoHLS-style
//! multi-objective HLS explorers keep). Per occupied box exactly one
//! representative survives — the **canonical minimum** under the total
//! order `(latency, risk, resources, pragma vector)` — and a box is kept
//! iff no other occupied box dominates it coordinate-wise. With
//! `epsilon = 0` the boxes degenerate to the raw metric vectors and the
//! filter is plain Pareto dominance.
//!
//! ## Merge-order invariance
//!
//! [`archive`] is a *pure function of the input set*: sorting to the
//! canonical order first makes the per-box representative the set-wide
//! minimum (min is associative/commutative), and dominance between
//! *boxes* is transitive, so dropping a dominated box can never shield a
//! third box its dominator would not also dominate. Hence
//! `archive(archive(A) ∪ B) == archive(A ∪ B)` bit-for-bit — per-config
//! fronts can be merged in any order, in any partition, and the result
//! is the archive of the union. `tests/integration_system.rs` proves
//! this over seeded random point sets; the truncation to
//! [`FrontConfig::max_points`] is applied exactly once, at the very end
//! ([`reduce`]), because truncation is *not* merge-invariant.

use crate::pragma::Design;

/// Knobs of one front extraction.
#[derive(Clone, Copy, Debug)]
pub struct FrontConfig {
    /// Relative epsilon-dominance band per objective axis (`0.0` = exact
    /// Pareto dominance; `0.02` collapses points within 2 % per axis).
    pub epsilon: f64,
    /// Hard cap on returned front points (canonical-order prefix,
    /// applied once after the archive reduction).
    pub max_points: usize,
}

impl Default for FrontConfig {
    fn default() -> FrontConfig {
        FrontConfig {
            epsilon: 0.02,
            max_points: 16,
        }
    }
}

/// One point on a kernel's latency-vs-resources front.
#[derive(Clone, Debug)]
pub struct FrontPoint {
    /// The pragma design realizing this trade-off.
    pub design: Design,
    /// Verified latency objective, cycles (the solver's exact tape).
    pub latency: f64,
    /// Realization risk (the solver's coarse-UF tie-break key).
    pub risk: f64,
    /// Optimistic DSP usage (Eq 11).
    pub dsp: f64,
    /// On-chip bytes for cached arrays (Eq 12) — the BRAM/URAM axis.
    pub onchip_bytes: f64,
    /// Estimated LUT usage (the Eq 11 mirror over LUT op costs).
    pub lut: f64,
}

impl FrontPoint {
    /// The four objective axes, in fixed order
    /// `(latency, dsp, onchip_bytes, lut)`.
    pub fn metrics(&self) -> [f64; 4] {
        [self.latency, self.dsp, self.onchip_bytes, self.lut]
    }
}

/// The canonical total order of front points: latency, then risk, then
/// the resource axes, then the pragma vector — `total_cmp` throughout,
/// so NaN metrics order last instead of panicking, and two points
/// compare `Equal` only when bit-identical in every key.
pub fn canonical_cmp(a: &FrontPoint, b: &FrontPoint) -> std::cmp::Ordering {
    a.latency
        .total_cmp(&b.latency)
        .then_with(|| a.risk.total_cmp(&b.risk))
        .then_with(|| a.dsp.total_cmp(&b.dsp))
        .then_with(|| a.onchip_bytes.total_cmp(&b.onchip_bytes))
        .then_with(|| a.lut.total_cmp(&b.lut))
        .then_with(|| a.design.cmp(&b.design))
}

/// Grid-box coordinates of one point. `epsilon > 0`: logarithmic band
/// index per axis; `epsilon <= 0`: the raw f64 bit pattern (monotone for
/// the non-negative finite metrics the model produces), i.e. exact
/// dominance. Non-finite metrics map to `u64::MAX` so a NaN/inf axis is
/// dominated by every finite value instead of miscomparing.
fn box_coords(p: &FrontPoint, epsilon: f64) -> [u64; 4] {
    let mut out = [0u64; 4];
    for (o, v) in out.iter_mut().zip(p.metrics()) {
        *o = if !v.is_finite() {
            u64::MAX
        } else if epsilon > 0.0 {
            ((1.0 + v.max(0.0)).ln() / (1.0 + epsilon).ln()).floor() as u64
        } else {
            v.max(0.0).to_bits()
        };
    }
    out
}

/// `a` dominates `b`: every coordinate ≤, at least one <.
fn dominates(a: &[u64; 4], b: &[u64; 4]) -> bool {
    a != b && a.iter().zip(b).all(|(x, y)| x <= y)
}

/// The pure epsilon-grid archive: canonical-min representative per
/// occupied grid box, then box-wise dominance filtering, returned in
/// canonical order. **No truncation** — this is the merge-invariant
/// operation (`archive(archive(A) ∪ B) == archive(A ∪ B)`); see the
/// module docs for the argument and [`reduce`] for the final cap.
pub fn archive(mut points: Vec<FrontPoint>, epsilon: f64) -> Vec<FrontPoint> {
    points.sort_by(canonical_cmp);
    // first point per box in canonical order == set-wide canonical min
    let mut boxes: std::collections::BTreeMap<[u64; 4], FrontPoint> = Default::default();
    for p in points {
        boxes.entry(box_coords(&p, epsilon)).or_insert(p);
    }
    let keys: Vec<[u64; 4]> = boxes.keys().copied().collect();
    let mut out: Vec<FrontPoint> = boxes
        .into_iter()
        .filter(|(k, _)| !keys.iter().any(|k2| dominates(k2, k)))
        .map(|(_, p)| p)
        .collect();
    out.sort_by(canonical_cmp);
    out
}

/// [`archive`] + the final `max_points` truncation (canonical-order
/// prefix). This is what one complete front extraction returns; callers
/// that merge partial fronts must merge **un-truncated** archives and
/// call this exactly once on the union.
pub fn reduce(points: Vec<FrontPoint>, fc: &FrontConfig) -> Vec<FrontPoint> {
    let mut out = archive(points, fc.epsilon);
    out.truncate(fc.max_points.max(1));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks;
    use crate::ir::DType;
    use crate::util::rng::Rng;

    fn pt(k: &crate::ir::Kernel, m: [f64; 4]) -> FrontPoint {
        FrontPoint {
            design: Design::empty(k),
            latency: m[0],
            risk: 1.0,
            dsp: m[1],
            onchip_bytes: m[2],
            lut: m[3],
        }
    }

    #[test]
    fn exact_dominance_filters_dominated_points() {
        let k = benchmarks::kernel_gemm(8, 8, 8, DType::F32);
        let a = pt(&k, [100.0, 10.0, 10.0, 10.0]);
        let b = pt(&k, [200.0, 10.0, 10.0, 10.0]); // dominated by a
        let c = pt(&k, [50.0, 20.0, 10.0, 10.0]); // trade-off vs a
        let f = archive(vec![b, a, c], 0.0);
        assert_eq!(f.len(), 2);
        assert_eq!(f[0].latency, 50.0);
        assert_eq!(f[1].latency, 100.0);
    }

    #[test]
    fn epsilon_band_collapses_near_duplicates() {
        let k = benchmarks::kernel_gemm(8, 8, 8, DType::F32);
        // 1 % apart on every axis: one box at eps = 5 %, two at eps = 0
        let a = pt(&k, [100.0, 10.0, 10.0, 10.0]);
        let b = pt(&k, [101.0, 10.1, 10.1, 10.1]);
        assert_eq!(archive(vec![a.clone(), b.clone()], 0.0).len(), 2);
        let f = archive(vec![b, a], 0.05);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].latency, 100.0, "canonical-min representative");
    }

    #[test]
    fn nan_metrics_lose_to_every_finite_point() {
        let k = benchmarks::kernel_gemm(8, 8, 8, DType::F32);
        let good = pt(&k, [100.0, 10.0, 10.0, 10.0]);
        let nan = pt(&k, [f64::NAN, 5.0, 5.0, 5.0]);
        let f = archive(vec![nan, good], 0.02);
        // the NaN axis maps to u64::MAX: strictly dominated, filtered out
        assert_eq!(f.len(), 1);
        assert!(f[0].latency.is_finite());
    }

    #[test]
    fn archive_merge_is_order_invariant_on_random_sets() {
        let k = benchmarks::kernel_gemm(8, 8, 8, DType::F32);
        let mut rng = Rng::new(0xF0E1);
        for case in 0..50u64 {
            let n = 3 + (rng.next_u64() % 40) as usize;
            let eps = [0.0, 0.02, 0.1][(case % 3) as usize];
            let points: Vec<FrontPoint> = (0..n)
                .map(|_| {
                    let m = |r: &mut Rng| 1.0 + (r.next_u64() % 100_000) as f64;
                    pt(&k, [m(&mut rng), m(&mut rng), m(&mut rng), m(&mut rng)])
                })
                .collect();
            let whole = archive(points.clone(), eps);
            // any partition: archive the parts, merge, archive again
            let cut = (rng.next_u64() as usize) % (n + 1);
            let (a, b) = points.split_at(cut);
            let mut merged = archive(a.to_vec(), eps);
            merged.extend(b.to_vec());
            let merged = archive(merged, eps);
            assert_eq!(whole.len(), merged.len(), "case {case}");
            for (x, y) in whole.iter().zip(&merged) {
                assert_eq!(x.latency.to_bits(), y.latency.to_bits(), "case {case}");
                assert_eq!(x.dsp.to_bits(), y.dsp.to_bits(), "case {case}");
                assert_eq!(
                    x.onchip_bytes.to_bits(),
                    y.onchip_bytes.to_bits(),
                    "case {case}"
                );
                assert_eq!(x.lut.to_bits(), y.lut.to_bits(), "case {case}");
                assert_eq!(x.design, y.design, "case {case}");
            }
        }
    }

    #[test]
    fn reduce_caps_the_front_after_the_archive() {
        let k = benchmarks::kernel_gemm(8, 8, 8, DType::F32);
        // an antichain: descending latency vs ascending dsp
        let points: Vec<FrontPoint> = (0..20)
            .map(|i| pt(&k, [1000.0 - i as f64 * 10.0, 10.0 + i as f64, 1.0, 1.0]))
            .collect();
        let fc = FrontConfig {
            epsilon: 0.0,
            max_points: 5,
        };
        let f = reduce(points, &fc);
        assert_eq!(f.len(), 5);
        // canonical prefix: the five lowest latencies
        assert!(f.windows(2).all(|w| w[0].latency <= w[1].latency));
        assert_eq!(f[0].latency, 810.0);
    }
}
