//! The normalized exploration outcome every [`Engine`](super::Engine)
//! produces, plus the conversions from the three legacy outcome types.
//!
//! Normalization keeps the coordinator, CLI, and JSON dumps engine-
//! agnostic; the engine-specific record survives in [`EngineDetail`] so
//! the paper's table/figure generators keep their full fidelity.

use crate::baselines::{AutoDseOutcome, HarpOutcome};
use crate::dse::{DseOutcome, StepRecord};
use crate::ir::Kernel;
use crate::pragma::Design;
use crate::surrogate::SurrogateOutcome;
use crate::transform::TransformOutcome;

/// What happened to one explored candidate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepStatus {
    /// Synthesized to completion with a valid report.
    Synthesized,
    /// Synthesized but the toolchain produced an unusable design.
    Invalid,
    /// HLS synthesis hit its wall-clock timeout.
    Timeout,
    /// Skipped before synthesis (lower-bound pruning, legality screen).
    Pruned,
    /// Identical configuration already synthesized; result reused.
    Dedup,
}

impl StepStatus {
    /// Short status tag rendered in trace listings.
    pub fn tag(self) -> &'static str {
        match self {
            StepStatus::Synthesized => "ok",
            StepStatus::Invalid => "invalid",
            StepStatus::Timeout => "timeout",
            StepStatus::Pruned => "pruned",
            StepStatus::Dedup => "dedup",
        }
    }
}

/// One normalized exploration step (engine-agnostic trace entry).
#[derive(Clone, Debug)]
pub struct ExplorationStep {
    /// 1-based exploration step index.
    pub step: u32,
    /// Model/solver lower bound for this candidate, if the engine has one.
    pub lower_bound: Option<f64>,
    /// Measured HLS latency in cycles (valid designs only).
    pub measured: Option<f64>,
    /// Measured throughput (0 when not synthesized/valid).
    pub gflops: f64,
    /// What happened to the candidate.
    pub status: StepStatus,
}

/// Engine-specific detail preserved through normalization.
#[derive(Clone, Debug)]
pub enum EngineDetail {
    /// The full NLP-DSE record.
    NlpDse(DseOutcome),
    /// The full AutoDSE record.
    AutoDse(AutoDseOutcome),
    /// The full HARP record.
    Harp(HarpOutcome),
    /// The full `(variant × pragma)` transform-DSE record (boxed — it
    /// carries the winning kernel and its whole trace).
    Transform(Box<TransformOutcome>),
    /// The learned-surrogate record (boxed — it wraps a whole ladder
    /// trace plus model provenance and the exact re-verification).
    Surrogate(Box<SurrogateOutcome>),
    /// Engines with no legacy record (e.g. `random`, third-party).
    Generic,
}

/// The single normalized outcome of a design-space exploration.
#[derive(Clone, Debug)]
pub struct Exploration {
    /// Registry name of the engine that produced this outcome.
    pub engine: String,
    /// Kernel the exploration ran on.
    pub kernel: String,
    /// Best valid design and its measured latency in cycles.
    pub best: Option<(Design, f64)>,
    /// Best measured throughput.
    pub best_gflops: f64,
    /// Throughput of the first synthesizable design (0 when unknown —
    /// only lower-bound-ordered engines report it meaningfully).
    pub first_synth_gflops: f64,
    /// DSP utilization % of the best design (0 when unknown).
    pub best_dsp_pct: f64,
    /// Proven latency floor across the explored space, cycles (engines
    /// without a bounding model report `None`).
    pub lower_bound: Option<f64>,
    /// Simulated DSE wall time, minutes.
    pub wall_minutes: f64,
    /// Designs sent to Merlin/HLS synthesis (the tables' DE column).
    pub synth_calls: u32,
    /// Synthesis timeouts (DT column).
    pub synth_timeouts: u32,
    /// Candidates skipped before synthesis (pruning / legality screen).
    pub pruned: u32,
    /// Candidates rejected by the toolchain (ER column / invalid).
    pub rejected: u32,
    /// Normalized step trace (may be empty for black-box engines).
    pub trace: Vec<ExplorationStep>,
    /// Engine-specific record preserved through normalization.
    pub detail: EngineDetail,
}

impl Exploration {
    /// The legacy NLP-DSE record, when this outcome is one.
    pub fn as_nlpdse(&self) -> Option<&DseOutcome> {
        match &self.detail {
            EngineDetail::NlpDse(o) => Some(o),
            _ => None,
        }
    }

    /// The legacy AutoDSE record, when this outcome is one.
    pub fn as_autodse(&self) -> Option<&AutoDseOutcome> {
        match &self.detail {
            EngineDetail::AutoDse(o) => Some(o),
            _ => None,
        }
    }

    /// The legacy HARP record, when this outcome is one.
    pub fn as_harp(&self) -> Option<&HarpOutcome> {
        match &self.detail {
            EngineDetail::Harp(o) => Some(o),
            _ => None,
        }
    }

    /// The `(variant × pragma)` transform-DSE record, when this outcome
    /// is one.
    pub fn as_transform(&self) -> Option<&TransformOutcome> {
        match &self.detail {
            EngineDetail::Transform(o) => Some(o),
            _ => None,
        }
    }

    /// The learned-surrogate record, when this outcome is one.
    pub fn as_surrogate(&self) -> Option<&SurrogateOutcome> {
        match &self.detail {
            EngineDetail::Surrogate(o) => Some(o),
            _ => None,
        }
    }

    /// Engine-agnostic one-screen summary.
    pub fn summary(&self) -> String {
        let mut out = format!(
            "engine `{}` on {}:\n  best GF/s: {:.2}   wall: {:.0} min\n  \
             synthesized: {}   timeouts: {}   pruned: {}   rejected: {}\n",
            self.engine,
            self.kernel,
            self.best_gflops,
            self.wall_minutes,
            self.synth_calls,
            self.synth_timeouts,
            self.pruned,
            self.rejected
        );
        if self.first_synth_gflops > 0.0 {
            out.push_str(&format!(
                "  first synthesizable GF/s: {:.2}\n",
                self.first_synth_gflops
            ));
        }
        if let Some(lb) = self.lower_bound {
            out.push_str(&format!("  proven latency floor: {lb:.0} cycles\n"));
        }
        out
    }

    /// Summary + normalized trace + the best pragma configuration.
    /// `k` must be the kernel this exploration ran on.
    pub fn render(&self, k: &Kernel) -> String {
        let mut out = self.summary();
        if !self.trace.is_empty() {
            out.push_str("\ntrace:\n");
            for s in &self.trace {
                out.push_str(&format!(
                    "  step {:>3}  lb={:>14}  measured={:>14}  gfs={:>8.2}  {}\n",
                    s.step,
                    fmt_opt(s.lower_bound),
                    fmt_opt(s.measured),
                    s.gflops,
                    s.status.tag()
                ));
            }
        }
        if let Some((d, cycles)) = &self.best {
            out.push_str(&format!("\nbest design ({cycles:.0} cycles):\n"));
            out.push_str(&d.render(k));
        }
        out
    }
}

fn fmt_opt(x: Option<f64>) -> String {
    match x {
        Some(v) => format!("{v:.0}"),
        None => "-".into(),
    }
}

fn step_from_record(s: &StepRecord) -> ExplorationStep {
    let status = if s.dedup {
        StepStatus::Dedup
    } else if s.pruned {
        StepStatus::Pruned
    } else if s.timeout {
        StepStatus::Timeout
    } else if s.valid {
        StepStatus::Synthesized
    } else {
        StepStatus::Invalid
    };
    ExplorationStep {
        step: s.step,
        lower_bound: if s.lower_bound.is_finite() {
            Some(s.lower_bound)
        } else {
            None
        },
        measured: s.measured,
        gflops: s.gflops,
        status,
    }
}

impl From<DseOutcome> for Exploration {
    fn from(o: DseOutcome) -> Exploration {
        let trace: Vec<ExplorationStep> = o.trace.iter().map(step_from_record).collect();
        let floor = o
            .trace
            .iter()
            .map(|s| s.lower_bound)
            .filter(|lb| lb.is_finite())
            .fold(f64::INFINITY, f64::min);
        let pruned = o.trace.iter().filter(|s| s.pruned).count() as u32;
        let rejected = trace
            .iter()
            .filter(|s| s.status == StepStatus::Invalid)
            .count() as u32;
        Exploration {
            engine: "nlpdse".into(),
            kernel: o.kernel.clone(),
            best: o.best.clone(),
            best_gflops: o.best_gflops,
            first_synth_gflops: o.first_synth_gflops,
            best_dsp_pct: o.best_dsp_pct,
            lower_bound: if floor.is_finite() { Some(floor) } else { None },
            wall_minutes: o.dse_minutes,
            synth_calls: o.designs_explored,
            synth_timeouts: o.designs_timeout,
            pruned,
            rejected,
            trace,
            detail: EngineDetail::NlpDse(o),
        }
    }
}

impl From<AutoDseOutcome> for Exploration {
    fn from(o: AutoDseOutcome) -> Exploration {
        Exploration {
            engine: "autodse".into(),
            kernel: o.kernel.clone(),
            best: o.best.clone(),
            best_gflops: o.best_gflops,
            first_synth_gflops: 0.0,
            best_dsp_pct: o.best_dsp_pct,
            lower_bound: None,
            wall_minutes: o.dse_minutes,
            synth_calls: o.designs_explored,
            synth_timeouts: o.designs_timeout,
            pruned: 0,
            rejected: o.early_rejected,
            trace: Vec::new(),
            detail: EngineDetail::AutoDse(o),
        }
    }
}

impl From<TransformOutcome> for Exploration {
    fn from(o: TransformOutcome) -> Exploration {
        // normalize from the winning variant's ladder; variant-level
        // prunes fold into the engine-agnostic `pruned` counter
        let mut e: Exploration = o.outcome.clone().into();
        e.engine = "transform".into();
        e.kernel = o.kernel.clone();
        e.pruned += o.pruned;
        e.detail = EngineDetail::Transform(Box::new(o));
        e
    }
}

impl From<HarpOutcome> for Exploration {
    fn from(o: HarpOutcome) -> Exploration {
        Exploration {
            engine: "harp".into(),
            kernel: o.kernel.clone(),
            best: o.best.clone(),
            best_gflops: o.best_gflops,
            first_synth_gflops: 0.0,
            best_dsp_pct: 0.0,
            lower_bound: None,
            wall_minutes: o.dse_minutes,
            synth_calls: o.designs_synthesized,
            synth_timeouts: o.designs_timeout,
            pruned: 0,
            rejected: 0,
            trace: Vec::new(),
            detail: EngineDetail::Harp(o),
        }
    }
}
