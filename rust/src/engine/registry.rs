//! Name-keyed engine registry.
//!
//! The CLI (`--engine`), the campaign coordinator, and the [`Explorer`]
//! facade all resolve engines here, so adding an engine is one
//! `register` call — no dispatch site anywhere else changes. Factories
//! are plain `fn` pointers taking the shared [`EngineTuning`] bundle;
//! each reads only the field it cares about.
//!
//! [`Explorer`]: super::Explorer

use super::{AutoDseEngine, Engine, EngineTuning, HarpEngine, NlpDseEngine, RandomSearchEngine};
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Engine constructor: builds a boxed engine from the campaign tuning.
pub type EngineFactory = fn(&EngineTuning) -> Box<dyn Engine>;

/// Name-keyed engine factory table (CLI/coordinator/Explorer dispatch).
#[derive(Clone, Default)]
pub struct Registry {
    factories: BTreeMap<String, EngineFactory>,
}

impl Registry {
    /// An empty registry (for fully custom engine sets).
    pub fn empty() -> Registry {
        Registry::default()
    }

    /// The five in-repo engines: `nlpdse`, `autodse`, `harp`, `random`,
    /// `surrogate`.
    pub fn builtin() -> Registry {
        let mut r = Registry::empty();
        r.register("nlpdse", |t| Box::new(NlpDseEngine::new(t.dse.clone())));
        r.register("autodse", |t| Box::new(AutoDseEngine::new(t.autodse.clone())));
        r.register("harp", |t| Box::new(HarpEngine::new(t.harp.clone())));
        r.register("random", |t| Box::new(RandomSearchEngine::new(t.random.clone())));
        r.register("surrogate", |t| {
            Box::new(crate::surrogate::SurrogateEngine::new(t.surrogate.clone(), t.dse.clone()))
        });
        r
    }

    /// Register (or replace) an engine factory under `name`.
    pub fn register(&mut self, name: &str, factory: EngineFactory) {
        self.factories.insert(name.to_string(), factory);
    }

    /// Whether `name` is registered.
    pub fn contains(&self, name: &str) -> bool {
        self.factories.contains_key(name)
    }

    /// Registered names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.factories.keys().cloned().collect()
    }

    /// Instantiate the engine registered under `name`.
    pub fn create(&self, name: &str, tuning: &EngineTuning) -> Result<Box<dyn Engine>> {
        match self.factories.get(name) {
            Some(f) => Ok(f(tuning)),
            None => bail!(
                "unknown engine `{name}` (registered: {})",
                self.names().join(", ")
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_registers_all_five_engines() {
        let r = Registry::builtin();
        assert_eq!(r.names(), vec!["autodse", "harp", "nlpdse", "random", "surrogate"]);
        for n in ["nlpdse", "autodse", "harp", "random", "surrogate"] {
            assert!(r.contains(n), "{n}");
            let e = r.create(n, &EngineTuning::default()).unwrap();
            assert_eq!(e.name(), n);
        }
    }

    #[test]
    fn unknown_engine_is_a_clean_error() {
        let r = Registry::builtin();
        let err = r
            .create("simulated-annealing", &EngineTuning::default())
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown engine `simulated-annealing`"), "{msg}");
        // the error names the valid choices
        assert!(msg.contains("nlpdse") && msg.contains("random"), "{msg}");
        assert!(msg.contains("surrogate"), "new engines must appear in the listing: {msg}");
    }

    #[test]
    fn third_party_registration_and_replacement() {
        let mut r = Registry::builtin();
        fn f(t: &EngineTuning) -> Box<dyn Engine> {
            Box::new(RandomSearchEngine::new(t.random.clone()))
        }
        r.register("my-search", f);
        assert!(r.contains("my-search"));
        assert!(r.create("my-search", &EngineTuning::default()).is_ok());
        // replacement under an existing key wins
        r.register("nlpdse", f);
        let e = r.create("nlpdse", &EngineTuning::default()).unwrap();
        assert_eq!(e.name(), "random");
    }
}
