//! The three published explorers wrapped as [`Engine`] implementations.
//!
//! Each wrapper owns its legacy config and delegates to the existing
//! free function; the outcome is normalized through the `From`
//! conversions in [`super::outcome`]. Research code that wants the raw
//! outcome types can keep calling `dse::run_nlp_dse` /
//! `baselines::run_autodse` / `baselines::run_harp` directly.

use super::{Engine, ExploreCtx, Exploration};
use crate::baselines::{run_autodse, run_harp, AutoDseConfig, HarpConfig};
use crate::dse::{run_nlp_dse, run_nlp_dse_with_bound, DseConfig};

/// The paper's NLP-driven DSE (Algorithm 1).
pub struct NlpDseEngine {
    /// Algorithm 1 parameters this engine runs with.
    pub cfg: DseConfig,
}

impl NlpDseEngine {
    /// Engine over explicit NLP-DSE parameters.
    pub fn new(cfg: DseConfig) -> NlpDseEngine {
        NlpDseEngine { cfg }
    }
}

impl Default for NlpDseEngine {
    fn default() -> Self {
        NlpDseEngine::new(DseConfig::default())
    }
}

impl Engine for NlpDseEngine {
    fn name(&self) -> &str {
        "nlpdse"
    }

    fn explore(&self, ctx: &ExploreCtx<'_>) -> Exploration {
        match ctx.bound {
            // reuse the scheduler/session's symbolic bound model
            Some(bm) => run_nlp_dse_with_bound(
                ctx.kernel,
                ctx.analysis,
                ctx.device,
                &self.cfg,
                ctx.evaluator,
                bm,
            )
            .into(),
            None => {
                run_nlp_dse(ctx.kernel, ctx.analysis, ctx.device, &self.cfg, ctx.evaluator)
                    .into()
            }
        }
    }
}

/// AutoDSE (FPGA'21): model-free bottleneck-driven baseline. Treats the
/// toolchain as a black box, so it ignores `ctx.evaluator`.
pub struct AutoDseEngine {
    /// AutoDSE parameters this engine runs with.
    pub cfg: AutoDseConfig,
}

impl AutoDseEngine {
    /// Engine over explicit AutoDSE parameters.
    pub fn new(cfg: AutoDseConfig) -> AutoDseEngine {
        AutoDseEngine { cfg }
    }
}

impl Default for AutoDseEngine {
    fn default() -> Self {
        AutoDseEngine::new(AutoDseConfig::default())
    }
}

impl Engine for AutoDseEngine {
    fn name(&self) -> &str {
        "autodse"
    }

    fn explore(&self, ctx: &ExploreCtx<'_>) -> Exploration {
        run_autodse(ctx.kernel, ctx.analysis, ctx.device, &self.cfg).into()
    }

    fn uses_evaluator(&self) -> bool {
        false
    }
}

/// HARP (ICCAD'23): surrogate-guided near-exhaustive sweep with top-k
/// synthesis. Uses its own learned surrogate, not `ctx.evaluator`.
pub struct HarpEngine {
    /// HARP parameters this engine runs with.
    pub cfg: HarpConfig,
}

impl HarpEngine {
    /// Engine over explicit HARP parameters.
    pub fn new(cfg: HarpConfig) -> HarpEngine {
        HarpEngine { cfg }
    }
}

impl Default for HarpEngine {
    fn default() -> Self {
        HarpEngine::new(HarpConfig::default())
    }
}

impl Engine for HarpEngine {
    fn name(&self) -> &str {
        "harp"
    }

    fn explore(&self, ctx: &ExploreCtx<'_>) -> Exploration {
        run_harp(ctx.kernel, ctx.analysis, ctx.device, &self.cfg).into()
    }

    fn uses_evaluator(&self) -> bool {
        false
    }
}
