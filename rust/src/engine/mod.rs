//! The unified exploration-engine subsystem.
//!
//! The paper's core architectural claim (Sections 6–7) is that NLP-DSE,
//! AutoDSE, and HARP are interchangeable *explorers* over the same
//! kernel / analysis / oracle substrate. This module makes that claim a
//! first-class API:
//!
//! * [`Engine`] — the object-safe strategy trait. An engine receives an
//!   [`ExploreCtx`] (kernel, analysis, device, batch evaluator) and
//!   returns a normalized [`Exploration`] outcome.
//! * [`Exploration`] — the single outcome type every engine produces:
//!   best design + measured latency, throughput, proven lower bound,
//!   synthesis-call accounting, wall time, and a normalized step trace.
//!   The legacy `DseOutcome` / `AutoDseOutcome` / `HarpOutcome` types
//!   convert into it (and remain reachable through
//!   [`Exploration::as_nlpdse`] and friends for the report generators).
//! * [`Registry`] — a name-keyed engine registry. The CLI, coordinator,
//!   and examples dispatch by name; new engines register a factory and
//!   need **zero** edits anywhere else ([`RandomSearchEngine`] is the
//!   in-repo proof).
//! * [`Explorer`] — the builder-style session facade and the crate's
//!   front door:
//!
//! ```no_run
//! use nlp_dse::benchmarks::Size;
//! use nlp_dse::engine::{Evaluator, Explorer};
//!
//! # fn main() -> anyhow::Result<()> {
//! let outcome = Explorer::kernel("gemm", Size::Medium)?
//!     .device(nlp_dse::hls::Device::u200())
//!     .evaluator(Evaluator::auto())
//!     .engine("nlpdse")?
//!     .run()?;
//! println!("best: {:.2} GF/s in {:.0} simulated minutes",
//!          outcome.best_gflops, outcome.wall_minutes);
//! # Ok(())
//! # }
//! ```
//!
//! The low-level modules (`dse`, `baselines`, `nlp`, `hls`, …) stay
//! public as the escape hatch for research code that needs to hold the
//! substrate pieces directly.

pub mod builtin;
pub mod explorer;
pub mod outcome;
pub mod random;
pub mod registry;

pub use builtin::{AutoDseEngine, HarpEngine, NlpDseEngine};
pub use explorer::{Evaluator, Explorer};
pub use outcome::{EngineDetail, Exploration, ExplorationStep, StepStatus};
pub use random::{RandomConfig, RandomSearchEngine};
pub use registry::{EngineFactory, Registry};

use crate::baselines::{AutoDseConfig, HarpConfig};
use crate::dse::DseConfig;
use crate::hls::Device;
use crate::ir::Kernel;
use crate::model::sym::BoundModel;
use crate::nlp::BatchEvaluator;
use crate::poly::Analysis;
use crate::surrogate::SurrogateConfig;

/// Everything an engine may consume: the substrate the session facade
/// (or the coordinator) owns on the engine's behalf.
pub struct ExploreCtx<'a> {
    /// The kernel under exploration.
    pub kernel: &'a Kernel,
    /// Its exact polyhedral analysis.
    pub analysis: &'a Analysis,
    /// The target device model.
    pub device: &'a Device,
    /// Bulk lower-bound evaluator (Rust reference or the AOT XLA
    /// artifact) behind the `dyn BatchEvaluator` boundary. Engines that
    /// treat the toolchain as a black box (AutoDSE, HARP) ignore it.
    pub evaluator: &'a dyn BatchEvaluator,
    /// The kernel's symbolic bound model (built once per session/job):
    /// `bound.lower_bound(&PartialDesign)` lets any engine prune whole
    /// subspaces by achievable latency before enumerating them.
    /// Schedulers may pass `None` for black-box engines (AutoDSE, HARP,
    /// random) to skip the build; model-driven engines fall back to
    /// building their own when absent.
    pub bound: Option<&'a BoundModel>,
}

/// A design-space exploration strategy. Object-safe: the coordinator
/// schedules `Box<dyn Engine>` jobs across its thread pool.
pub trait Engine: Send + Sync {
    /// Stable engine name (what the registry keys on and the tables
    /// print).
    fn name(&self) -> &str;
    /// Explore the design space of `ctx.kernel` and report the outcome.
    fn explore(&self, ctx: &ExploreCtx<'_>) -> Exploration;
    /// Whether this engine reads `ctx.evaluator`. Black-box engines
    /// return `false` so schedulers skip loading the (costly) XLA
    /// artifact for their jobs.
    fn uses_evaluator(&self) -> bool {
        true
    }
}

/// Per-engine campaign parameters, bundled so registry factories stay
/// uniform (`fn(&EngineTuning) -> Box<dyn Engine>`). Each factory reads
/// only its own field; third-party engines are free to ignore it.
#[derive(Clone, Debug, Default)]
pub struct EngineTuning {
    /// NLP-DSE (Algorithm 1) parameters.
    pub dse: DseConfig,
    /// AutoDSE baseline parameters.
    pub autodse: AutoDseConfig,
    /// HARP baseline parameters.
    pub harp: HarpConfig,
    /// Random-search baseline parameters.
    pub random: RandomConfig,
    /// Learned-surrogate engine parameters (the `surrogate` engine also
    /// reads `dse` for its underlying ladder).
    pub surrogate: SurrogateConfig,
}
