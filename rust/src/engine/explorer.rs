//! The builder-style exploration session facade — the crate's front
//! door.
//!
//! `Explorer` owns everything an engine needs (kernel construction,
//! exact polyhedral analysis, device model, Rust-vs-XLA evaluator
//! selection behind the `dyn BatchEvaluator` boundary) so call sites
//! stop copy-pasting the kernel-build → `Analysis::new` →
//! evaluator-selection → oracle-setup boilerplate the CLI, coordinator,
//! and examples used to repeat.

use super::registry::EngineFactory;
use super::{Engine, EngineTuning, ExploreCtx, Exploration, Registry};
use crate::baselines::{AutoDseConfig, HarpConfig};
use crate::benchmarks::{self, Size};
use crate::dse::DseConfig;
use crate::engine::RandomConfig;
use crate::hls::Device;
use crate::ir::{DType, Kernel};
use crate::model::sym::{BoundModel, CompiledModel, PartialDesign};
use crate::nlp::{
    self, BatchEvaluator, NlpProblem, RustFeatureEvaluator, SolveResult, SymbolicEvaluator,
};
use crate::poly::Analysis;
use crate::runtime::{default_artifact_dir, XlaEvaluator};
use crate::surrogate::SurrogateConfig;
use anyhow::{bail, Result};
use std::sync::Arc;

/// Batch-evaluator selection policy, resolved once per `run`.
#[derive(Clone)]
pub enum Evaluator {
    /// Use the AOT XLA artifact when it loads, else the Rust reference.
    Auto,
    /// Always the in-process Rust reference evaluator.
    Rust,
    /// The compiled symbolic bound model (`model::sym`): exact model
    /// scores from the flattened allocation-free tape.
    Sym,
    /// Require the AOT XLA artifact; `run` fails if it cannot load.
    Xla,
    /// Caller-supplied evaluator (e.g. an instrumented one). `Arc`
    /// (`BatchEvaluator` is `Send + Sync`): the parallel NLP solver
    /// shares it across its worker team.
    Custom(Arc<dyn BatchEvaluator>),
}

impl Evaluator {
    /// XLA artifact when it loads, Rust reference otherwise.
    pub fn auto() -> Evaluator {
        Evaluator::Auto
    }
    /// Always the in-process Rust reference evaluator.
    pub fn rust() -> Evaluator {
        Evaluator::Rust
    }
    /// Always the compiled symbolic bound-model tape.
    pub fn sym() -> Evaluator {
        Evaluator::Sym
    }
    /// Require the AOT XLA artifact (fail instead of falling back).
    pub fn xla() -> Evaluator {
        Evaluator::Xla
    }
    /// A caller-supplied evaluator (shared across solver workers).
    pub fn custom(e: Arc<dyn BatchEvaluator>) -> Evaluator {
        Evaluator::Custom(e)
    }
}

impl std::fmt::Debug for Evaluator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Evaluator::Auto => "Auto",
            Evaluator::Rust => "Rust",
            Evaluator::Sym => "Sym",
            Evaluator::Xla => "Xla",
            Evaluator::Custom(_) => "Custom(..)",
        })
    }
}

enum EngineChoice {
    Named(String),
    Custom(Box<dyn Engine>),
}

/// An [`Evaluator`] policy resolved to a concrete evaluator for one run
/// (owns the loaded XLA artifact when the policy selected one).
enum ResolvedEvaluator {
    Rust(RustFeatureEvaluator),
    Sym(SymbolicEvaluator),
    Xla(XlaEvaluator),
    Shared(Arc<dyn BatchEvaluator>),
}

impl ResolvedEvaluator {
    fn as_dyn(&self) -> &dyn BatchEvaluator {
        match self {
            ResolvedEvaluator::Rust(e) => e,
            ResolvedEvaluator::Sym(e) => e,
            ResolvedEvaluator::Xla(e) => e,
            ResolvedEvaluator::Shared(e) => e.as_ref(),
        }
    }
}

/// One exploration session over one kernel. Build with
/// [`Explorer::kernel`] (PolyBench registry) or [`Explorer::custom`]
/// (bring-your-own [`Kernel`]), chain the setters, then [`run`].
///
/// # Examples
///
/// Explore a registry kernel and emit its best design as annotated C:
///
/// ```no_run
/// use nlp_dse::benchmarks::Size;
/// use nlp_dse::codegen::EmitConfig;
/// use nlp_dse::engine::{Evaluator, Explorer};
///
/// # fn main() -> anyhow::Result<()> {
/// let explorer = Explorer::kernel("gemm", Size::Medium)?
///     .evaluator(Evaluator::rust())
///     .jobs(1)
///     .engine("nlpdse")?;
/// let outcome = explorer.run()?;
/// println!("{}", outcome.summary());
/// if let Some(code) = explorer.emit_best(&outcome, &EmitConfig::merlin()) {
///     std::fs::write("gemm_annotated.c", code)?;
/// }
/// # Ok(())
/// # }
/// ```
///
/// [`run`]: Explorer::run
pub struct Explorer {
    kernel: Kernel,
    analysis: Analysis,
    device: Device,
    /// Lazily built on first use (black-box engines never pay for it);
    /// `Arc` so a warm cache (the serve daemon) can share one build
    /// across sessions over structurally identical kernels.
    bound: std::cell::OnceCell<Arc<BoundModel>>,
    /// The bound model's flattened tape, same lifecycle.
    compiled: std::cell::OnceCell<Arc<CompiledModel>>,
    evaluator: Evaluator,
    tuning: EngineTuning,
    registry: Registry,
    choice: EngineChoice,
}

impl Explorer {
    /// Session over a registered benchmark kernel at f32 precision.
    pub fn kernel(name: &str, size: Size) -> Result<Explorer> {
        Explorer::kernel_dtype(name, size, DType::F32)
    }

    /// Session over a kernel spec at chosen precision: a registered
    /// benchmark name or a `.knl` file path (which carries its own dtype
    /// and size — see [`benchmarks::lookup`]).
    pub fn kernel_dtype(name: &str, size: Size, dtype: DType) -> Result<Explorer> {
        Ok(Explorer::custom(benchmarks::lookup(name, size, dtype)?))
    }

    /// Session over a kernel parsed from a `.knl` file.
    pub fn kernel_file(path: &str) -> Result<Explorer> {
        Ok(Explorer::custom(crate::frontend::parse_file(path)?))
    }

    /// Session over a freshly generated random kernel (see
    /// [`crate::frontend::generate`]) — every engine and evaluator runs
    /// on generated kernels exactly as on the benchmark corpus.
    pub fn generated(cfg: &crate::frontend::GenConfig) -> Explorer {
        Explorer::custom(crate::frontend::generate(cfg))
    }

    /// Session over a user-built kernel (see `ir::KernelBuilder`).
    pub fn custom(kernel: Kernel) -> Explorer {
        let analysis = Analysis::new(&kernel);
        Explorer {
            kernel,
            analysis,
            device: Device::u200(),
            bound: std::cell::OnceCell::new(),
            compiled: std::cell::OnceCell::new(),
            evaluator: Evaluator::Auto,
            tuning: EngineTuning::default(),
            registry: Registry::builtin(),
            choice: EngineChoice::Named("nlpdse".into()),
        }
    }

    /// Target device (default: Alveo U200 @ 250 MHz). Invalidates any
    /// lazily built bound model (op costs and budgets are
    /// device-dependent).
    pub fn device(mut self, dev: Device) -> Explorer {
        self.device = dev;
        self.bound = std::cell::OnceCell::new();
        self.compiled = std::cell::OnceCell::new();
        self
    }

    /// Seed the session's bound model + compiled tape from a previous
    /// build instead of rebuilding — the serve daemon's model-cache
    /// hook. The caller asserts the pair was built for a structurally
    /// identical kernel (equal exact [`crate::serve::Fingerprint`]) on
    /// the same device; nothing here re-checks.
    pub fn with_shared_model(
        mut self,
        bound: Arc<BoundModel>,
        compiled: Arc<CompiledModel>,
    ) -> Explorer {
        self.bound = std::cell::OnceCell::from(bound);
        self.compiled = std::cell::OnceCell::from(compiled);
        self
    }

    /// Evaluator selection policy (default: [`Evaluator::Auto`]).
    pub fn evaluator(mut self, ev: Evaluator) -> Explorer {
        self.evaluator = ev;
        self
    }

    /// Replace the whole per-engine tuning bundle.
    pub fn tuning(mut self, t: EngineTuning) -> Explorer {
        self.tuning = t;
        self
    }

    /// Set the NLP-DSE (Algorithm 1) parameters.
    pub fn dse_config(mut self, c: DseConfig) -> Explorer {
        self.tuning.dse = c;
        self
    }

    /// NLP-solver worker threads (the CLI's `--jobs`). `1` is the exact
    /// serial path; for searches that complete within budget, any value
    /// returns bit-identical results (the solver's deterministic
    /// reduction), so this only trades wall clock.
    pub fn jobs(mut self, n: usize) -> Explorer {
        self.tuning.dse.jobs = n.max(1);
        self
    }

    /// Set the AutoDSE baseline parameters.
    pub fn autodse_config(mut self, c: AutoDseConfig) -> Explorer {
        self.tuning.autodse = c;
        self
    }

    /// Set the HARP baseline parameters.
    pub fn harp_config(mut self, c: HarpConfig) -> Explorer {
        self.tuning.harp = c;
        self
    }

    /// Set the random-search baseline parameters.
    pub fn random_config(mut self, c: RandomConfig) -> Explorer {
        self.tuning.random = c;
        self
    }

    /// Set the learned-surrogate engine parameters (the `surrogate`
    /// engine reads the NLP ladder settings from [`Explorer::dse_config`]).
    pub fn surrogate_config(mut self, c: SurrogateConfig) -> Explorer {
        self.tuning.surrogate = c;
        self
    }

    /// Register an additional engine factory for this session.
    pub fn register(mut self, name: &str, factory: EngineFactory) -> Explorer {
        self.registry.register(name, factory);
        self
    }

    /// Select the engine to run by registry name (default: `nlpdse`).
    /// Fails fast on unknown names.
    pub fn engine(mut self, name: &str) -> Result<Explorer> {
        if !self.registry.contains(name) {
            bail!(
                "unknown engine `{name}` (registered: {})",
                self.registry.names().join(", ")
            );
        }
        self.choice = EngineChoice::Named(name.to_string());
        Ok(self)
    }

    /// Run a caller-built engine instead of a registered one.
    pub fn with_engine(mut self, e: Box<dyn Engine>) -> Explorer {
        self.choice = EngineChoice::Custom(e);
        self
    }

    // --- escape hatches into the owned substrate ------------------------

    /// The session's kernel.
    pub fn kernel_ref(&self) -> &Kernel {
        &self.kernel
    }

    /// The session's exact polyhedral analysis.
    pub fn analysis(&self) -> &Analysis {
        &self.analysis
    }

    /// The session's target device.
    pub fn device_ref(&self) -> &Device {
        &self.device
    }

    /// The session's symbolic bound model (one per kernel × device,
    /// built on first use).
    pub fn bound_model(&self) -> &BoundModel {
        self.bound
            .get_or_init(|| Arc::new(BoundModel::build(&self.kernel, &self.analysis, &self.device)))
            .as_ref()
    }

    /// The bound model as a shareable handle (what a warm cache stores).
    pub fn bound_model_arc(&self) -> Arc<BoundModel> {
        self.bound_model();
        self.bound.get().expect("just initialized").clone()
    }

    /// The bound model's compiled tape as a shareable handle, built on
    /// first use (or seeded via [`Explorer::with_shared_model`]).
    pub fn compiled_model_arc(&self) -> Arc<CompiledModel> {
        self.compiled
            .get_or_init(|| Arc::new(self.bound_model().compile()))
            .clone()
    }

    /// Achievable-latency lower bound of a (possibly partial) pragma
    /// configuration — no completion of `partial` can beat this many
    /// cycles on this session's kernel/device.
    pub fn lower_bound(&self, partial: &PartialDesign) -> f64 {
        self.bound_model().lower_bound(partial)
    }

    /// The session's per-engine tuning bundle.
    pub fn tuning_ref(&self) -> &EngineTuning {
        &self.tuning
    }

    /// Lower `design` on this session's kernel to pragma-annotated HLS
    /// C text (see [`crate::codegen`]). Works for any design — solved,
    /// hand-built, or partial-free — and honours the session's device.
    pub fn emit(&self, design: &crate::pragma::Design, cfg: &crate::codegen::EmitConfig) -> String {
        crate::codegen::emit(&self.kernel, &self.analysis, &self.device, design, cfg)
    }

    /// Emit the best design of an [`Exploration`] produced by this
    /// session (any engine), or `None` when the engine found no valid
    /// design.
    pub fn emit_best(
        &self,
        outcome: &Exploration,
        cfg: &crate::codegen::EmitConfig,
    ) -> Option<String> {
        outcome.best.as_ref().map(|(d, _)| self.emit(d, cfg))
    }

    /// Names of all engines this session can run.
    pub fn engine_names(&self) -> Vec<String> {
        self.registry.names()
    }

    // --- execution ------------------------------------------------------

    /// Run the selected engine over this session's kernel.
    pub fn run(&self) -> Result<Exploration> {
        match &self.choice {
            EngineChoice::Custom(e) => self.run_with(e.as_ref()),
            EngineChoice::Named(n) => {
                let e = self.registry.create(n, &self.tuning)?;
                self.run_with(e.as_ref())
            }
        }
    }

    /// Run a specific registered engine, ignoring the selected one —
    /// convenient for sweeping every engine over one session.
    pub fn run_engine(&self, name: &str) -> Result<Exploration> {
        let e = self.registry.create(name, &self.tuning)?;
        self.run_with(e.as_ref())
    }

    /// Solve the Section 5 NLP over this session's kernel with the
    /// session's evaluator and `jobs` setting: sub-space cap `cap`
    /// (`u64::MAX` = unrestricted), Eq 9 restriction `fine`, `topk`
    /// designs, `timeout_s` budget. Reuses a shared bound model when
    /// [`Explorer::with_shared_model`] seeded one.
    pub fn solve(&self, cap: u64, fine: bool, topk: usize, timeout_s: f64) -> Result<SolveResult> {
        self.solve_seeded(cap, fine, topk, timeout_s, &[])
    }

    /// [`Explorer::solve`] warm-started from `seeds` — cached incumbent
    /// designs from a previous solve of a same-shaped kernel (the serve
    /// daemon's warm path). Seeds are re-verified against *this*
    /// problem before use, so stale or alien seeds are dropped, never
    /// trusted (see [`nlp::solve_jobs_seeded`]).
    pub fn solve_seeded(
        &self,
        cap: u64,
        fine: bool,
        topk: usize,
        timeout_s: f64,
        seeds: &[crate::pragma::Design],
    ) -> Result<SolveResult> {
        let problem = NlpProblem::with_model(
            &self.kernel,
            &self.analysis,
            &self.device,
            cap,
            fine,
            self.bound_model_arc(),
            self.compiled_model_arc(),
        );
        let resolved = self.resolve_evaluator()?;
        let jobs = self.tuning.dse.jobs.max(1);
        Ok(nlp::solve_jobs_seeded(
            &problem,
            timeout_s,
            topk,
            resolved.as_dyn(),
            jobs,
            seeds,
        ))
    }

    fn resolve_evaluator(&self) -> Result<ResolvedEvaluator> {
        Ok(match &self.evaluator {
            Evaluator::Rust => ResolvedEvaluator::Rust(RustFeatureEvaluator),
            Evaluator::Sym => ResolvedEvaluator::Sym(SymbolicEvaluator),
            Evaluator::Auto => match XlaEvaluator::load(&default_artifact_dir()) {
                Ok(e) => ResolvedEvaluator::Xla(e),
                Err(_) => ResolvedEvaluator::Rust(RustFeatureEvaluator),
            },
            Evaluator::Xla => ResolvedEvaluator::Xla(XlaEvaluator::load(&default_artifact_dir())?),
            Evaluator::Custom(shared) => ResolvedEvaluator::Shared(shared.clone()),
        })
    }

    fn run_with(&self, engine: &dyn Engine) -> Result<Exploration> {
        let resolved = self.resolve_evaluator()?;
        let evaluator = resolved.as_dyn();
        // model-driven engines get the (lazily built) bound model;
        // black-box engines never trigger the build — same policy as the
        // coordinator's job scheduler
        let ctx = ExploreCtx {
            kernel: &self.kernel,
            analysis: &self.analysis,
            device: &self.device,
            evaluator,
            bound: engine.uses_evaluator().then(|| self.bound_model()),
        };
        Ok(engine.explore(&ctx))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_kernel_and_engine_fail_fast() {
        let err = Explorer::kernel("does-not-exist", Size::Small).unwrap_err();
        assert!(format!("{err:#}").contains("unknown kernel"));
        let err = Explorer::kernel("gemm", Size::Small)
            .unwrap()
            .engine("does-not-exist")
            .unwrap_err();
        assert!(format!("{err:#}").contains("unknown engine"));
    }

    #[test]
    fn facade_accepts_generated_and_file_kernels() {
        let cfg = crate::frontend::GenConfig {
            max_trip: 8,
            depth: 2,
            ..crate::frontend::GenConfig::with_seed(5)
        };
        let ex = Explorer::generated(&cfg)
            .evaluator(Evaluator::rust())
            .run()
            .unwrap();
        assert_eq!(ex.engine, "nlpdse");
        assert!(ex.best.is_some());
        // the same kernel via a .knl file gives the same exploration
        let k = crate::frontend::generate(&cfg);
        let path = std::env::temp_dir().join("nlp_dse_explorer_test.knl");
        std::fs::write(&path, crate::frontend::pretty::print(&k)).unwrap();
        let ex2 = Explorer::kernel_file(path.to_str().unwrap())
            .unwrap()
            .evaluator(Evaluator::rust())
            .run()
            .unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(ex.best_gflops, ex2.best_gflops);
        assert_eq!(ex.synth_calls, ex2.synth_calls);
    }

    #[test]
    fn facade_runs_default_engine() {
        let ex = Explorer::kernel("atax", Size::Small)
            .unwrap()
            .evaluator(Evaluator::rust())
            .run()
            .unwrap();
        assert_eq!(ex.engine, "nlpdse");
        assert!(ex.best.is_some());
        assert!(ex.best_gflops > 0.0);
    }

    #[test]
    fn facade_exposes_partial_config_bounds() {
        let ex = Explorer::kernel("gemm", Size::Small).unwrap();
        let k = ex.kernel_ref();
        let free = PartialDesign::free(k.n_loops());
        let lb_free = ex.lower_bound(&free);
        assert!(lb_free.is_finite() && lb_free > 0.0);
        // pinning the whole design to "no pragmas" can only raise the bound
        let empty = PartialDesign::from_design(&crate::pragma::Design::empty(k));
        let lb_empty = ex.lower_bound(&empty);
        assert!(lb_empty >= lb_free, "{lb_empty} < {lb_free}");
        // ... and for a complete design the bound is the exact model value
        let exact = crate::model::evaluate(
            k,
            ex.analysis(),
            ex.device_ref(),
            &crate::pragma::Design::empty(k),
        );
        let rel = (lb_empty - exact.total_cycles).abs() / exact.total_cycles;
        assert!(rel < 1e-9, "{lb_empty} vs {}", exact.total_cycles);
    }

    #[test]
    fn sym_evaluator_runs_default_engine() {
        let ex = Explorer::kernel("atax", Size::Small)
            .unwrap()
            .evaluator(Evaluator::sym())
            .run()
            .unwrap();
        assert_eq!(ex.engine, "nlpdse");
        assert!(ex.best.is_some());
        assert!(ex.best_gflops > 0.0);
    }

    #[test]
    fn jobs_knob_changes_wall_clock_only() {
        let r1 = Explorer::kernel("atax", Size::Small)
            .unwrap()
            .evaluator(Evaluator::rust())
            .jobs(1)
            .run()
            .unwrap();
        let r4 = Explorer::kernel("atax", Size::Small)
            .unwrap()
            .evaluator(Evaluator::rust())
            .jobs(4)
            .run()
            .unwrap();
        assert_eq!(r1.best_gflops, r4.best_gflops);
        assert_eq!(r1.synth_calls, r4.synth_calls);
    }

    #[test]
    fn emit_best_produces_lintable_c_for_any_engine() {
        let explorer = Explorer::kernel("bicg", Size::Small)
            .unwrap()
            .evaluator(Evaluator::rust());
        for engine in ["nlpdse", "random"] {
            let outcome = explorer.run_engine(engine).unwrap();
            let code = explorer
                .emit_best(&outcome, &crate::codegen::EmitConfig::merlin())
                .unwrap_or_else(|| panic!("{engine}: no best design"));
            crate::codegen::lint(explorer.kernel_ref(), &code)
                .unwrap_or_else(|e| panic!("{engine}: {e}\n{code}"));
            // the emitted design is the outcome's best, verbatim
            let (d, _) = outcome.best.as_ref().unwrap();
            assert!(code.contains(&format!("design: {}", d.fingerprint())), "{engine}");
        }
    }

    #[test]
    fn shared_model_and_seeded_solve_match_the_fresh_path() {
        let ex1 = Explorer::kernel("gemm", Size::Small)
            .unwrap()
            .evaluator(Evaluator::rust())
            .jobs(1);
        let r1 = ex1.solve(16, false, 3, 30.0).unwrap();
        assert!(r1.optimal && !r1.designs.is_empty());
        // a second session seeded with the first one's model + incumbents
        // (the serve daemon's warm path) must reproduce the result bit
        // for bit
        let seeds: Vec<_> = r1.designs.iter().map(|(d, _)| d.clone()).collect();
        let ex2 = Explorer::kernel("gemm", Size::Small)
            .unwrap()
            .evaluator(Evaluator::rust())
            .jobs(1)
            .with_shared_model(ex1.bound_model_arc(), ex1.compiled_model_arc());
        let r2 = ex2.solve_seeded(16, false, 3, 30.0, &seeds).unwrap();
        assert_eq!(r1.designs, r2.designs);
        assert_eq!(r1.lower_bound, r2.lower_bound);
    }

    #[test]
    fn facade_matches_low_level_path() {
        // the facade must be sugar, not semantics: identical outcome to
        // calling the engine over a hand-built context
        let explorer = Explorer::kernel("bicg", Size::Small)
            .unwrap()
            .evaluator(Evaluator::rust());
        let hi = explorer.run().unwrap();
        let lo = crate::dse::run_nlp_dse(
            explorer.kernel_ref(),
            explorer.analysis(),
            explorer.device_ref(),
            &crate::dse::DseConfig::default(),
            &RustFeatureEvaluator,
        );
        assert_eq!(hi.best_gflops, lo.best_gflops);
        assert_eq!(hi.synth_calls, lo.designs_explored);
        // the simulated clock folds in *measured* NLP-solve seconds, so
        // two runs agree only up to solver wall-clock jitter (the synth
        // schedule itself is deterministic minutes)
        assert!(
            (hi.wall_minutes - lo.dse_minutes).abs() < 0.5,
            "{} vs {}",
            hi.wall_minutes,
            lo.dse_minutes
        );
    }
}
