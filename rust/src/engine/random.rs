//! Uniform random search — the smallest useful baseline engine, and the
//! registry's proof that a new engine needs zero CLI/coordinator edits:
//! it is reachable from `nlp-dse dse --engine random`, campaign scopes,
//! and the `Explorer` facade purely through its registry entry.
//!
//! Strategy: synthesize the pragma-free design (guaranteed-valid
//! baseline), then draw uniformly random pipeline-configuration × unroll
//! assignments from the enumerated space, screen them with the same
//! legality predicate HARP's classifier learns, and synthesize up to the
//! budget. Deterministic per kernel via the seeded in-repo PRNG.

use super::{Engine, EngineDetail, ExploreCtx, Exploration, ExplorationStep, StepStatus};
use crate::dse::SimClock;
use crate::hls::{Device, HlsOracle, SynthOptions};
use crate::ir::{Kernel, LoopId};
use crate::poly::Analysis;
use crate::pragma::{space, Design, Space};
use crate::util::rng::{hash64, Rng};
use std::collections::BTreeSet;

/// Random-search baseline parameters.
#[derive(Clone, Debug)]
pub struct RandomConfig {
    /// Candidate draws before giving up (screened, deduplicated).
    pub samples: u64,
    /// Designs actually sent to synthesis (including the baseline).
    pub synth_budget: u32,
    /// Parallel synthesis workers for the simulated clock.
    pub workers: usize,
    /// Per-synthesis HLS timeout, minutes.
    pub hls_timeout_min: f64,
}

impl Default for RandomConfig {
    fn default() -> Self {
        RandomConfig {
            samples: 4_000,
            synth_budget: 48,
            workers: 8,
            hls_timeout_min: 180.0,
        }
    }
}

/// Uniform random search over legal designs — the registry's proof
/// that new engines need zero dispatch edits.
pub struct RandomSearchEngine {
    /// Sampling and synthesis budgets.
    pub cfg: RandomConfig,
}

impl RandomSearchEngine {
    /// Engine over explicit random-search parameters.
    pub fn new(cfg: RandomConfig) -> RandomSearchEngine {
        RandomSearchEngine { cfg }
    }
}

impl Default for RandomSearchEngine {
    fn default() -> Self {
        RandomSearchEngine::new(RandomConfig::default())
    }
}

/// Mutable search state threaded through every synthesis call.
struct SearchState {
    clock: SimClock,
    best: Option<(Design, f64)>,
    best_dsp: u64,
    min_lat: f64,
    first_synth_gflops: f64,
    synth_calls: u32,
    synth_timeouts: u32,
    pruned: u32,
    rejected: u32,
    trace: Vec<ExplorationStep>,
}

impl SearchState {
    fn synth(&mut self, oracle: &HlsOracle, k: &Kernel, a: &Analysis, dev: &Device, d: &Design) {
        let rep = oracle.synth(k, a, d);
        self.clock.submit(rep.synth_minutes);
        self.synth_calls += 1;
        let status = if rep.timeout {
            self.synth_timeouts += 1;
            StepStatus::Timeout
        } else if rep.valid {
            StepStatus::Synthesized
        } else {
            self.rejected += 1;
            StepStatus::Invalid
        };
        let gfs = rep.gflops(a, dev);
        if rep.valid && self.first_synth_gflops == 0.0 {
            self.first_synth_gflops = gfs;
        }
        if rep.valid && rep.cycles < self.min_lat {
            self.min_lat = rep.cycles;
            self.best = Some((d.clone(), rep.cycles));
            self.best_dsp = rep.dsp;
        }
        self.trace.push(ExplorationStep {
            step: self.synth_calls,
            lower_bound: None,
            measured: if rep.valid { Some(rep.cycles) } else { None },
            gflops: gfs,
            status,
        });
    }
}

impl Engine for RandomSearchEngine {
    fn name(&self) -> &str {
        "random"
    }

    fn uses_evaluator(&self) -> bool {
        false
    }

    fn explore(&self, ctx: &ExploreCtx<'_>) -> Exploration {
        let (k, a, dev) = (ctx.kernel, ctx.analysis, ctx.device);
        let oracle = HlsOracle {
            device: dev.clone(),
            options: SynthOptions {
                hls_timeout_min: self.cfg.hls_timeout_min,
            },
        };
        let space = Space::new(k, a);
        let mut rng = Rng::new(hash64(&format!("random/{}/{}", k.name, k.dtype.name())));
        let mut seen: BTreeSet<String> = BTreeSet::new();
        let mut st = SearchState {
            clock: SimClock::new(self.cfg.workers),
            best: None,
            best_dsp: 0,
            min_lat: f64::INFINITY,
            first_synth_gflops: 0.0,
            synth_calls: 0,
            synth_timeouts: 0,
            pruned: 0,
            rejected: 0,
            trace: Vec::new(),
        };

        // baseline: the pragma-free design is always valid, so random
        // search never returns empty-handed
        let empty = Design::empty(k);
        seen.insert(empty.fingerprint());
        st.synth(&oracle, k, a, dev, &empty);

        for _ in 0..self.cfg.samples {
            if st.synth_calls >= self.cfg.synth_budget {
                break;
            }
            let pcfg =
                &space.pipeline_configs[rng.range(0, space.pipeline_configs.len() as u64) as usize];
            let drawn: Vec<u64> = (0..k.n_loops())
                .map(|i| {
                    let menu = space.ufs(LoopId(i as u32), a, dev.max_array_partition);
                    if menu.is_empty() {
                        1
                    } else {
                        menu[rng.range(0, menu.len() as u64) as usize]
                    }
                })
                .collect();
            let d = space::materialize(k, a, pcfg, &|l: LoopId| drawn[l.0 as usize], &|_| 1);
            if !seen.insert(d.fingerprint()) {
                continue;
            }
            // the same legality screen HARP applies before scoring
            if d.max_partitioning(k) > dev.max_array_partition
                || crate::merlin::apply(k, a, dev, &d).early_reject
            {
                st.pruned += 1;
                continue;
            }
            st.synth(&oracle, k, a, dev, &d);
        }

        let best_gflops = st
            .best
            .as_ref()
            .map(|(_, c)| a.gflops(*c, dev.freq_hz))
            .unwrap_or(0.0);
        let best_dsp_pct = if st.best.is_some() {
            st.best_dsp as f64 / dev.dsp_total as f64 * 100.0
        } else {
            0.0
        };
        Exploration {
            engine: "random".into(),
            kernel: k.name.clone(),
            best: st.best,
            best_gflops,
            first_synth_gflops: st.first_synth_gflops,
            best_dsp_pct,
            lower_bound: None,
            wall_minutes: st.clock.makespan(),
            synth_calls: st.synth_calls,
            synth_timeouts: st.synth_timeouts,
            pruned: st.pruned,
            rejected: st.rejected,
            trace: st.trace,
            detail: EngineDetail::Generic,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{self, Size};
    use crate::ir::DType;
    use crate::nlp::RustFeatureEvaluator;

    fn run(name: &str) -> Exploration {
        let k = benchmarks::build(name, Size::Small, DType::F32).unwrap();
        let a = Analysis::new(&k);
        let dev = Device::u200();
        let ctx = ExploreCtx {
            kernel: &k,
            analysis: &a,
            device: &dev,
            evaluator: &RustFeatureEvaluator,
            bound: None,
        };
        RandomSearchEngine::new(RandomConfig {
            samples: 1_000,
            synth_budget: 16,
            ..RandomConfig::default()
        })
        .explore(&ctx)
    }

    #[test]
    fn always_finds_a_valid_design() {
        let out = run("gemm");
        assert!(out.best.is_some());
        assert!(out.best_gflops > 0.0);
        assert!(out.synth_calls >= 1);
        assert!(out.wall_minutes > 0.0);
        assert_eq!(out.engine, "random");
    }

    #[test]
    fn deterministic() {
        let o1 = run("bicg");
        let o2 = run("bicg");
        assert_eq!(o1.best_gflops, o2.best_gflops);
        assert_eq!(o1.synth_calls, o2.synth_calls);
        assert_eq!(o1.trace.len(), o2.trace.len());
    }

    #[test]
    fn respects_synth_budget() {
        let out = run("atax");
        assert!(out.synth_calls <= 16, "budget exceeded: {}", out.synth_calls);
    }
}
