//! Tiny argv parser: `command --key value --flag` forms.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Parsed argv: one command, `--key value` options, `--flag`s.
pub struct Args {
    command: String,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: Vec<(String, String)>,
}

impl Args {
    /// Parse raw argv tokens (no escaping; values may not start with `--`).
    pub fn parse(argv: &[&str]) -> Result<Args> {
        let mut command = String::new();
        let mut opts = BTreeMap::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let tok = argv[i];
            if let Some(key) = tok.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    opts.insert(key.to_string(), argv[i + 1].to_string());
                    i += 2;
                } else {
                    flags.push(key.to_string());
                    i += 1;
                }
            } else if command.is_empty() {
                command = tok.to_string();
                i += 1;
            } else {
                bail!("unexpected positional argument `{tok}`");
            }
        }
        Ok(Args {
            command,
            opts,
            flags,
            consumed: Vec::new(),
        })
    }

    /// The leading positional command (empty when none).
    pub fn command(&self) -> &str {
        &self.command
    }

    /// Take an option value (consumed once; `put_back` restores it).
    pub fn opt(&mut self, key: &str) -> Option<String> {
        if let Some(v) = self.opts.remove(key) {
            self.consumed.push((key.to_string(), v.clone()));
            Some(v)
        } else {
            None
        }
    }

    /// Restore a previously consumed option so a later reader sees it.
    pub fn put_back(&mut self, key: &str) {
        if let Some(pos) = self.consumed.iter().position(|(k, _)| k == key) {
            let (k, v) = self.consumed.remove(pos);
            self.opts.insert(k, v);
        }
    }

    /// Whether the bare flag `--key` was given.
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_mixed() {
        let mut a = Args::parse(&["table", "--id", "5", "--tsv", "--scope", "quick"]).unwrap();
        assert_eq!(a.command(), "table");
        assert_eq!(a.opt("id").as_deref(), Some("5"));
        assert!(a.flag("tsv"));
        assert_eq!(a.opt("scope").as_deref(), Some("quick"));
        assert_eq!(a.opt("id"), None, "consumed");
    }

    #[test]
    fn put_back_restores() {
        let mut a = Args::parse(&["space", "--kernel", "2mm"]).unwrap();
        assert_eq!(a.opt("kernel").as_deref(), Some("2mm"));
        a.put_back("kernel");
        assert_eq!(a.opt("kernel").as_deref(), Some("2mm"));
    }

    #[test]
    fn rejects_stray_positional() {
        assert!(Args::parse(&["dse", "oops"]).is_err());
    }
}
