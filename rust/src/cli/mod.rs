//! Command-line interface (hand-rolled; clap is unavailable offline).
//!
//! ```text
//! nlp-dse table --id 5 [--scope quick|paper] [--xla] [--tsv] [--out FILE]
//! nlp-dse figure --id 2|3|4|5|6 [--scope ...] [--kernel K --size M]
//! nlp-dse dse --kernel 2mm --size M [--engine NAME] [--xla|--sym] [--prune-bound] [--jobs N]
//!             [--transform [--max-variants N] [--max-depth D] [--max-perm-loops P]]
//!             [--model-file m.json] [--verify-fraction F]   (engine `surrogate` only)
//! nlp-dse train --model-file m.json [--seed S] [--kernels N] [--designs N] [--lambda L]
//! nlp-dse solve --kernel gemm --size S [--cap 512] [--fine] [--xla|--sym] [--jobs N]
//! nlp-dse system --kernels gemm,bicg [--size S] [--epsilon 0.02] [--max-points 16]
//!                [--cap 512] [--device u200] [--tsv]
//! nlp-dse bound gemm [--size S] [--assign i=4,k=8] [--pipeline j1] [--cap 512]
//! nlp-dse emit gemm [--design-from solve|dse|empty] [--assign i=4] [--pipeline k]
//!                   [--dialect merlin|vitis] [--realized] [--out gemm.c]
//! nlp-dse space --kernel 2mm --size M
//! nlp-dse gen [--seed S] [--count N] [--out-dir DIR] [--sampled] [--depth/--width/...]
//! nlp-dse campaign [--scope quick|paper|harp] [--engines a,b] [--json FILE] [--xla] [--jobs N]
//!                  [--emit-dir DIR]
//! nlp-dse serve [--addr HOST:PORT] [--cache-entries K] [--threads N] [--jobs N]
//! ```
//!
//! Everywhere a kernel is named, the spec is either a registered
//! benchmark (`--kernel 2mm`) or a `.knl` file (`--kernel-file p.knl`,
//! or a path given to `--kernel`) — resolution goes through
//! [`benchmarks::lookup`], and `gen` emits seeded random `.knl` corpora
//! for the other commands to consume.
//!
//! The `dse` command dispatches through the engine [`Registry`] — any
//! registered engine name works, with no per-engine code here. With
//! `--transform` it instead runs the `(variant × pragma)` search of
//! [`crate::transform`]: legality-certified interchange / distribution /
//! fusion variants are enumerated and the NLP ladder runs per variant,
//! pruning variants whose bound-model floor already loses to the
//! incumbent; `emit --design-from dse --transform` lowers the winning
//! variant. The
//! `bound` command goes through the `Explorer` facade's symbolic bound
//! model: it prints the achievable-latency lower bound of a (possibly
//! partial) pragma configuration.
//!
//! `--jobs N` sets the NLP solver's worker-team size (default: every
//! host core; `1` = the exact serial path). For searches that complete
//! within budget, results are bit-identical for every value — the knob
//! trades wall clock only (a timed-out anytime result may legitimately
//! differ, as the solver docs spell out).

pub mod args;

use crate::benchmarks::{self, Size};
use crate::coordinator::{self, engine_names, CampaignConfig, CampaignResult};
use crate::engine::{Evaluator, Exploration, Explorer, Registry};
use crate::frontend;
use crate::hls::Device;
use crate::ir::DType;
use crate::nlp::{self, BatchEvaluator, NlpProblem, RustFeatureEvaluator};
use crate::poly::Analysis;
use crate::pragma::Space;
use crate::report;
use crate::runtime::{default_artifact_dir, XlaEvaluator};
use anyhow::{anyhow, bail, Result};
use args::Args;

/// Binary entry point: parse `std::env::args` and run.
pub fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    run(&argv.iter().map(|s| s.as_str()).collect::<Vec<_>>())
}

/// Run one CLI invocation against explicit argv (testable entry point).
pub fn run(argv: &[&str]) -> Result<()> {
    // `bound <kernel>` / `emit <kernel>` sugar: the kernel may be given
    // positionally
    let rewritten: Vec<&str>;
    let argv = if matches!(argv.first().copied(), Some("bound") | Some("emit"))
        && argv.get(1).is_some_and(|a| !a.starts_with("--"))
    {
        rewritten = std::iter::once(argv[0])
            .chain(std::iter::once("--kernel"))
            .chain(argv[1..].iter().copied())
            .collect();
        &rewritten[..]
    } else {
        argv
    };
    let mut args = Args::parse(argv)?;
    let out = match args.command() {
        "table" => cmd_table(&mut args)?,
        "figure" => cmd_figure(&mut args)?,
        "dse" => cmd_dse(&mut args)?,
        "train" => cmd_train(&mut args)?,
        "solve" => cmd_solve(&mut args)?,
        "system" => cmd_system(&mut args)?,
        "bound" => cmd_bound(&mut args)?,
        "emit" => cmd_emit(&mut args)?,
        "space" => cmd_space(&mut args)?,
        "gen" => cmd_gen(&mut args)?,
        "campaign" => cmd_campaign(&mut args)?,
        "serve" => cmd_serve(&mut args)?,
        "engines" => cmd_engines(),
        "help" | "" => help(),
        other => bail!("unknown command `{other}` (try `help`)"),
    };
    match args.opt("out") {
        Some(path) => {
            std::fs::write(&path, &out)?;
            println!("wrote {path}");
        }
        None => println!("{out}"),
    }
    Ok(())
}

fn help() -> String {
    format!(
        "NLP-DSE — automatic HLS pragma insertion via non-linear programming\n\
         \n\
         commands:\n\
           table    --id 1|2|3|5|6|7|8|9 [--scope quick|paper] [--xla] [--tsv]\n\
           figure   --id 2|3|4|5|6 [--scope quick|paper] [--kernel K --size S]\n\
           dse      --kernel K --size S|M|L [--engine {engines}] [--xla|--sym] [--prune-bound]\n\
                    [--transform [--max-variants N] [--max-depth D] [--max-perm-loops P]]\n\
                    (--transform: legality-checked interchange/distribution/fusion\n\
                     variants × pragma search, bound-pruned per variant)\n\
                    [--model-file m.json] [--verify-fraction F] (engine `surrogate`:\n\
                     rank-cut each solver wave by the trained artifact's prediction,\n\
                     re-verify the reported best with the exact model)\n\
           train    --model-file FILE [--seed S] [--kernels N] [--designs N] [--lambda L]\n\
                    (fit the latency surrogate on a seeded generated corpus and save\n\
                     the versioned JSON artifact for dse/serve --engine surrogate)\n\
           solve    --kernel K --size S [--cap N] [--fine] [--xla|--sym]\n\
           system   --kernels k1,k2,... [--size S] [--epsilon E] [--max-points N]\n\
                    [--cap N] [--device u200] [--tsv]\n\
                    (per-kernel epsilon-dominance Pareto fronts over latency/DSP/\n\
                     BRAM/LUT, then branch-and-bound budget allocation maximizing\n\
                     system GF/s under the shared device budget)\n\
           bound    K [--size S] [--assign loop=uf,...] [--pipeline loop,...] [--cap N]\n\
                    (achievable-latency lower bound of a partial pragma configuration)\n\
           emit     K [--size S] [--design-from solve|dse|empty | --assign loop=uf,...\n\
                    --pipeline loop,... --tile loop=t,...] [--dialect merlin|vitis]\n\
                    [--realized] [--cap N] [--fine] [--engine E] [--out FILE]\n\
                    (pragma-annotated HLS C; --realized shows what Merlin accepts;\n\
                     --design-from dse --transform lowers the winning variant)\n\
           space    --kernel K --size S\n\
           gen      [--seed S] [--count N] [--out-dir DIR] [--sampled]\n\
                    [--depth D --width W --nests K --arrays A --max-trip T]\n\
                    (emit seeded random .knl kernels; single kernel prints to stdout)\n\
           campaign [--scope quick|paper|harp] [--engines a,b,c] [--json FILE] [--xla]\n\
                    [--emit-dir DIR [--dialect merlin|vitis] [--realized]]\n\
           serve    [--addr HOST:PORT] [--cache-entries K] [--threads N]\n\
                    (line-JSON DSE daemon with a fingerprint-keyed warm cache;\n\
                     ops: solve|dse|system|bound|emit|gen|stats|shutdown — see GUIDE.md)\n\
           engines  (list the registered exploration engines)\n\
         \n\
         common flags: --out FILE  --threads N  --jobs N  --dtype f32|f64\n\
         (--jobs: NLP-solver worker threads; default = all cores, 1 = serial;\n\
          completed searches are bit-identical for every value)\n\
         kernel specs: --kernel takes a benchmark name or a .knl path;\n\
         --kernel-file PATH forces file parsing (see `gen`)\n",
        engines = Registry::builtin().names().join("|")
    )
}

fn cmd_engines() -> String {
    let mut out = String::from("registered exploration engines:\n");
    for n in Registry::builtin().names() {
        out.push_str(&format!("  {n}\n"));
    }
    out
}

fn scope_campaign(
    args: &mut Args,
    engines: Vec<String>,
) -> Result<(CampaignConfig, CampaignResult)> {
    let scope = args.opt("scope").unwrap_or_else(|| "quick".into());
    let mut cfg = match scope.as_str() {
        "paper" => CampaignConfig::paper_autodse(),
        "harp" => CampaignConfig::paper_harp(),
        "quick" => {
            let mut c = CampaignConfig::quick();
            // quick scope still exercises the motivation trio for tables 1-3
            c.kernels = vec![
                ("2mm".into(), Size::Medium),
                ("gemm".into(), Size::Medium),
                ("gramschmidt".into(), Size::Large),
                ("bicg".into(), Size::Medium),
                ("atax".into(), Size::Medium),
            ];
            c
        }
        other => bail!("unknown scope `{other}`"),
    };
    cfg.engines = engines;
    if let Some(t) = args.opt("threads") {
        cfg.threads = t.parse()?;
    }
    // campaign constructors pin the solver to 1 job per pool thread (the
    // pool already saturates the host); `--jobs` opts into nesting
    // through the config knob — the scope's tuning stays untouched
    cfg.solver_jobs = parse_jobs(args)?;
    cfg.use_xla = args.flag("xla");
    eprintln!(
        "[campaign] scope={scope} kernels={} engines={} threads={} jobs={} xla={}",
        cfg.kernels.len(),
        cfg.engines.join(","),
        cfg.threads,
        cfg.effective_tuning().dse.jobs,
        cfg.use_xla
    );
    let result = coordinator::run_campaign(&cfg);
    Ok((cfg, result))
}

fn cmd_table(args: &mut Args) -> Result<String> {
    let id: u32 = args
        .opt("id")
        .ok_or_else(|| anyhow!("--id required"))?
        .parse()?;
    let tsv = args.flag("tsv");
    let table = match id {
        8 => report::table8(),
        9 => {
            let (_, r) = scope_campaign(args, engine_names(&["nlpdse", "harp"]))?;
            report::table9(&r)
        }
        7 | 6 => {
            let (_, r) = scope_campaign(args, engine_names(&["nlpdse"]))?;
            if id == 7 {
                report::table7(&r)
            } else {
                report::table6(&r)
            }
        }
        1 | 2 | 3 | 5 => {
            let (_, r) = scope_campaign(args, engine_names(&["nlpdse", "autodse"]))?;
            match id {
                1 => report::table1(&r),
                2 => report::table2(&r),
                3 => report::table3(&r),
                _ => report::table5(&r),
            }
        }
        other => bail!("no table {other} in the paper's evaluation"),
    };
    Ok(if tsv { table.to_tsv() } else { table.render() })
}

fn cmd_figure(args: &mut Args) -> Result<String> {
    let id: u32 = args
        .opt("id")
        .ok_or_else(|| anyhow!("--id required"))?
        .parse()?;
    Ok(match id {
        2 | 3 => {
            let (_, r) = scope_campaign(args, engine_names(&["nlpdse", "autodse"]))?;
            let size = if id == 2 { Size::Large } else { Size::Medium };
            report::figure2_3(&r, size)
        }
        4 => {
            let (_, r) = scope_campaign(args, engine_names(&["nlpdse", "harp"]))?;
            report::figure4(&r)
        }
        5 => {
            let (_, r) = scope_campaign(args, engine_names(&["nlpdse"]))?;
            report::figure5(&r)
        }
        6 => {
            let kernel = args.opt("kernel").unwrap_or_else(|| "2mm".into());
            let size = parse_size(args)?.unwrap_or(Size::Medium);
            let mut cfg = CampaignConfig::quick();
            cfg.kernels = vec![(kernel.clone(), size)];
            cfg.engines = engine_names(&["nlpdse"]);
            cfg.use_xla = args.flag("xla");
            let r = coordinator::run_campaign(&cfg);
            report::figure6(&r, &kernel, size)
        }
        other => bail!("no figure {other}"),
    })
}

fn parse_size(args: &mut Args) -> Result<Option<Size>> {
    match args.opt("size") {
        None => Ok(None),
        Some(s) => Size::parse(&s)
            .map(Some)
            .ok_or_else(|| anyhow!("bad --size {s} (S|M|L)")),
    }
}

/// `--dtype` as given (`None` when the flag is absent).
fn parse_dtype_opt(args: &mut Args) -> Result<Option<DType>> {
    match args.opt("dtype") {
        None => Ok(None),
        Some(v) => DType::from_name(&v)
            .map(Some)
            .ok_or_else(|| anyhow!("bad --dtype {v} (want f32 or f64)")),
    }
}

/// `--dtype`, defaulting to f32 (the paper's main precision).
fn parse_dtype(args: &mut Args) -> Result<DType> {
    Ok(parse_dtype_opt(args)?.unwrap_or(DType::F32))
}

/// `--jobs N` (≥ 1): NLP-solver worker threads. `None` = caller default.
fn parse_jobs(args: &mut Args) -> Result<Option<usize>> {
    match args.opt("jobs") {
        None => Ok(None),
        Some(s) => {
            let n: usize = s.parse()?;
            if n == 0 {
                bail!("--jobs must be >= 1 (1 = serial path)");
            }
            Ok(Some(n))
        }
    }
}

/// Kernel spec: `--kernel-file PATH` (always parsed as a `.knl` file,
/// never consulted against the registry — a file named like a benchmark
/// must not silently resolve to the benchmark) or `--kernel NAME`
/// (registry name or `.knl` path — [`benchmarks::lookup`] resolves both).
enum KernelSpec {
    File(String),
    Name(String),
}

impl KernelSpec {
    fn kernel(&self, size: Size, dtype: DType) -> Result<crate::ir::Kernel> {
        match self {
            KernelSpec::File(p) => frontend::parse_file(p),
            KernelSpec::Name(n) => benchmarks::lookup(n, size, dtype),
        }
    }
}

fn kernel_spec(args: &mut Args) -> Result<KernelSpec> {
    if let Some(p) = args.opt("kernel-file") {
        return Ok(KernelSpec::File(p));
    }
    if let Some(n) = args.opt("kernel") {
        return Ok(KernelSpec::Name(n));
    }
    Err(anyhow!("--kernel <name> or --kernel-file <path.knl> required"))
}

fn build_kernel(args: &mut Args) -> Result<(crate::ir::Kernel, Analysis, Device)> {
    let spec = kernel_spec(args)?;
    let size = parse_size(args)?.unwrap_or(Size::Medium);
    let dtype = parse_dtype(args)?;
    let k = spec.kernel(size, dtype)?;
    let a = Analysis::new(&k);
    Ok((k, a, Device::u200()))
}

fn make_evaluator(args: &mut Args) -> Box<dyn BatchEvaluator> {
    if args.flag("xla") {
        match XlaEvaluator::load(&default_artifact_dir()) {
            Ok(e) => {
                eprintln!("[xla] artifact loaded (batch={})", e.batch);
                return Box::new(e);
            }
            Err(e) => eprintln!("[xla] unavailable ({e:#}); falling back"),
        }
    }
    if args.flag("sym") {
        eprintln!("[sym] using the compiled symbolic bound-model evaluator");
        return Box::new(nlp::SymbolicEvaluator);
    }
    Box::new(RustFeatureEvaluator)
}

/// `dse` goes through the `Explorer` facade: any registered engine name
/// dispatches, and the output is the engine-agnostic exploration render.
fn cmd_dse(args: &mut Args) -> Result<String> {
    if args.flag("transform") {
        return cmd_dse_transform(args);
    }
    let engine = args.opt("engine").unwrap_or_else(|| "nlpdse".into());
    let surrogate_cfg = parse_surrogate_config(args, &engine)?;
    let spec = kernel_spec(args)?;
    let size = parse_size(args)?.unwrap_or(Size::Medium);
    let dtype = parse_dtype(args)?;
    // make_evaluator reports artifact load / fallback on stderr
    let evaluator = Evaluator::custom(std::sync::Arc::from(make_evaluator(args)));
    let dse_cfg = crate::dse::DseConfig {
        prune_bound: args.flag("prune-bound"),
        jobs: parse_jobs(args)?.unwrap_or_else(nlp::default_jobs),
        ..Default::default()
    };
    let explorer = Explorer::custom(spec.kernel(size, dtype)?)
        .evaluator(evaluator)
        .dse_config(dse_cfg)
        .surrogate_config(surrogate_cfg)
        .engine(&engine)?;
    let outcome = explorer.run()?;
    Ok(outcome.render(explorer.kernel_ref()))
}

/// `--model-file` / `--verify-fraction`: the `surrogate` engine's knobs.
/// The artifact is loaded (and schema-checked) here — the engine itself
/// is infallible — and both flags reject other engines instead of being
/// silently ignored.
fn parse_surrogate_config(
    args: &mut Args,
    engine: &str,
) -> Result<crate::surrogate::SurrogateConfig> {
    let mut cfg = crate::surrogate::SurrogateConfig::default();
    let model_file = args.opt("model-file");
    let verify_fraction = args.opt("verify-fraction");
    if engine != "surrogate" && (model_file.is_some() || verify_fraction.is_some()) {
        bail!("--model-file/--verify-fraction apply to --engine surrogate only");
    }
    if let Some(p) = model_file {
        cfg.model = Some(crate::surrogate::SurrogateModel::load(std::path::Path::new(&p))?);
    }
    if let Some(v) = verify_fraction {
        let f: f64 = v.parse()?;
        if !(0.0..=1.0).contains(&f) {
            bail!("--verify-fraction must be in [0, 1] (1.0 = the exact ladder)");
        }
        cfg.verify_fraction = f;
    }
    Ok(cfg)
}

/// `train`: fit the latency surrogate on a seeded generated corpus and
/// persist it as a versioned JSON artifact — the input to
/// `dse --engine surrogate --model-file` and the serve daemon's
/// `model_file` request field. (`--model-file` is the artifact
/// destination; `--out`, as everywhere, captures this summary.)
fn cmd_train(args: &mut Args) -> Result<String> {
    let path = args.opt("model-file").ok_or_else(|| {
        anyhow!("--model-file <path.json> required (the artifact destination)")
    })?;
    let mut cfg = crate::surrogate::TrainConfig::default();
    if let Some(v) = args.opt("seed") {
        cfg.seed = v.parse()?;
    }
    if let Some(v) = args.opt("kernels") {
        cfg.kernels = v.parse()?;
        if cfg.kernels == 0 {
            bail!("--kernels must be >= 1");
        }
    }
    if let Some(v) = args.opt("designs") {
        cfg.designs = v.parse()?;
        if cfg.designs == 0 {
            bail!("--designs must be >= 1");
        }
    }
    if let Some(v) = args.opt("lambda") {
        cfg.lambda = v.parse()?;
        if !cfg.lambda.is_finite() || cfg.lambda <= 0.0 {
            bail!("--lambda must be a positive number");
        }
    }
    let t = crate::surrogate::train(&cfg);
    t.model.save(std::path::Path::new(&path))?;
    Ok(format!(
        "surrogate trained: seed {} — {} kernels, {} train + {} holdout samples ({} skipped)\n\
         holdout spearman: {:.4}\n\
         artifact: {path} (version {}, hash {:016x})\n",
        cfg.seed,
        t.model.n_kernels,
        t.n_train,
        t.n_holdout,
        t.skipped,
        t.holdout_spearman,
        t.model.version,
        t.model.content_hash()
    ))
}

/// `--max-variants/--max-depth/--max-perm-loops` over the defaults.
fn parse_transform_config(args: &mut Args) -> Result<crate::transform::TransformConfig> {
    let mut t = crate::transform::TransformConfig::default();
    if let Some(v) = args.opt("max-variants") {
        t.max_variants = v.parse()?;
        if t.max_variants == 0 {
            bail!("--max-variants must be at least 1 (the original)");
        }
    }
    if let Some(v) = args.opt("max-depth") {
        t.max_depth = v.parse()?;
    }
    if let Some(v) = args.opt("max-perm-loops") {
        t.max_perm_loops = v.parse()?;
    }
    Ok(t)
}

/// `dse --transform`: the `(variant × pragma)` search — enumerate
/// legality-certified loop-transformation variants, run the NLP ladder
/// per variant with lower-bound variant pruning, report the winner.
fn cmd_dse_transform(args: &mut Args) -> Result<String> {
    let spec = kernel_spec(args)?;
    let size = parse_size(args)?.unwrap_or(Size::Medium);
    let dtype = parse_dtype(args)?;
    let k = spec.kernel(size, dtype)?;
    let evaluator = make_evaluator(args);
    let dse_cfg = crate::dse::DseConfig {
        prune_bound: args.flag("prune-bound"),
        jobs: parse_jobs(args)?.unwrap_or_else(nlp::default_jobs),
        ..Default::default()
    };
    let tcfg = parse_transform_config(args)?;
    let dev = Device::u200();
    let o = crate::transform::run_transform_dse(&k, &dev, &dse_cfg, &tcfg, evaluator.as_ref());

    let mut out = format!(
        "(variant × pragma) DSE on {} [{}]: {} variant(s) enumerated, {} pruned by bound\n\n",
        o.kernel,
        o.config.describe(),
        o.records.len(),
        o.pruned
    );
    for r in &o.records {
        let chain = if r.trace.is_empty() {
            "(original)".to_string()
        } else {
            r.trace.join(" ; ")
        };
        let fate = if r.pruned {
            "pruned".to_string()
        } else {
            match r.cycles {
                Some(c) => format!("{c:.0} cycles"),
                None => "no valid design".to_string(),
            }
        };
        let mark = if r.index == o.winner { " <- winner" } else { "" };
        out.push_str(&format!(
            "  v{:<2} lb={:>12.0}  {fate:<16} {chain}{mark}\n",
            r.index, r.lower_bound
        ));
    }
    let winner_kernel = o.variant.kernel.clone();
    match &o.winning_trace()[..] {
        [] => out.push_str("\nwinner: the untransformed original\n\n"),
        steps => out.push_str(&format!("\nwinner trace: {}\n\n", steps.join(" ; "))),
    }
    out.push_str(&Exploration::from(o).render(&winner_kernel));
    Ok(out)
}

/// `bound`: achievable-latency lower bound of a (possibly partial) pragma
/// configuration, through the `Explorer` facade's symbolic bound model.
fn cmd_bound(args: &mut Args) -> Result<String> {
    let spec = kernel_spec(args)
        .map_err(|_| anyhow!("--kernel or --kernel-file required (or `bound <kernel>`)"))?;
    let size = parse_size(args)?.unwrap_or(Size::Medium);
    let dtype = parse_dtype(args)?;
    // --jobs is accepted (and validated) on every solver-adjacent command
    // for CLI uniformity, but the bound itself is a single interval
    // evaluation — there is nothing to parallelize here
    let _ = parse_jobs(args)?;
    let ex = Explorer::custom(spec.kernel(size, dtype)?);
    let k = ex.kernel_ref();
    let resolve = |tok: &str| resolve_loop(k, tok);

    let mut partial = crate::model::sym::PartialDesign::free(k.n_loops());
    if let Some(cap) = args.opt("cap") {
        partial = partial.with_uf_cap(cap.parse()?);
    }
    if let Some(assigns) = args.opt("assign") {
        for pair in assigns.split(',').filter(|s| !s.is_empty()) {
            let (lhs, rhs) = pair
                .split_once('=')
                .ok_or_else(|| anyhow!("bad --assign entry `{pair}` (want loop=uf)"))?;
            partial.assign_uf(resolve(lhs.trim())?, rhs.trim().parse()?);
        }
    }
    if let Some(pipes) = args.opt("pipeline") {
        for tok in pipes.split(',').filter(|s| !s.is_empty()) {
            partial.assign_pipeline(resolve(tok.trim())?, true);
        }
    }

    let lb = ex.lower_bound(&partial);
    let a = ex.analysis();
    let dev = ex.device_ref();
    let mut out = format!(
        "symbolic bound model on {} ({} loops, {} free pragma slots):\n",
        k.name,
        k.n_loops(),
        partial.free_slots()
    );
    for i in 0..k.n_loops() {
        let l = crate::ir::LoopId(i as u32);
        out.push_str(&format!(
            "  L{i} {:<8} UF={}  pipeline={}\n",
            k.loop_name(l),
            partial.uf[i]
                .map(|v| v.to_string())
                .unwrap_or_else(|| "free".into()),
            partial.pipeline[i]
                .map(|v| v.to_string())
                .unwrap_or_else(|| "free".into()),
        ));
    }
    out.push_str(&format!(
        "\nachievable-latency lower bound: {:.0} cycles ({:.2} GF/s ceiling)\n",
        lb,
        a.gflops(lb, dev.freq_hz)
    ));
    out.push_str(
        "no completion of this partial configuration can beat the bound \
         (Theorem B.21 admissibility)\n",
    );
    Ok(out)
}

/// Resolve a loop token against a kernel: loop name, `L<i>`, or the
/// bare index (shared by `bound` and `emit`).
fn resolve_loop(k: &crate::ir::Kernel, tok: &str) -> Result<crate::ir::LoopId> {
    for i in 0..k.n_loops() {
        let l = crate::ir::LoopId(i as u32);
        if k.loop_name(l) == tok || format!("L{i}") == tok || i.to_string() == tok {
            return Ok(l);
        }
    }
    bail!(
        "unknown loop `{tok}` (loops: {})",
        (0..k.n_loops())
            .map(|i| k.loop_name(crate::ir::LoopId(i as u32)).to_string())
            .collect::<Vec<_>>()
            .join(", ")
    )
}

/// `--dialect` (default: merlin, the paper's flow).
fn parse_dialect(args: &mut Args) -> Result<crate::codegen::Dialect> {
    match args.opt("dialect") {
        None => Ok(crate::codegen::Dialect::Merlin),
        Some(s) => crate::codegen::Dialect::parse(&s)
            .ok_or_else(|| anyhow!("bad --dialect {s} (want merlin or vitis)")),
    }
}

/// `emit`: lower a kernel + pragma design to annotated HLS C — the
/// paper's end-to-end deliverable. The design comes from the NLP solver
/// (`--design-from solve`, the default), a full DSE engine run
/// (`--design-from dse [--engine E]`), the pragma-free baseline
/// (`--design-from empty`), or explicit `--assign`/`--pipeline`/`--tile`
/// settings. `--realized` emits what simulated Merlin actually applies.
fn cmd_emit(args: &mut Args) -> Result<String> {
    let spec = kernel_spec(args)
        .map_err(|_| anyhow!("--kernel or --kernel-file required (or `emit <kernel>`)"))?;
    let size = parse_size(args)?.unwrap_or(Size::Medium);
    let dtype = parse_dtype(args)?;
    let dialect = parse_dialect(args)?;
    let realized = args.flag("realized");
    let mut k = spec.kernel(size, dtype)?;
    let mut a = Analysis::new(&k);
    let dev = Device::u200();

    let assigns = args.opt("assign");
    let tiles = args.opt("tile");
    let pipes = args.opt("pipeline");
    let manual = assigns.is_some() || tiles.is_some() || pipes.is_some();
    let from = args.opt("design-from");
    if manual && from.is_some() {
        bail!("--design-from conflicts with --assign/--pipeline/--tile (pick one design source)");
    }

    let design = if manual {
        let mut d = crate::pragma::Design::empty(&k);
        if let Some(list) = assigns {
            for pair in list.split(',').filter(|s| !s.is_empty()) {
                let (lhs, rhs) = pair
                    .split_once('=')
                    .ok_or_else(|| anyhow!("bad --assign entry `{pair}` (want loop=uf)"))?;
                d.get_mut(resolve_loop(&k, lhs.trim())?).uf = rhs.trim().parse()?;
            }
        }
        if let Some(list) = tiles {
            for pair in list.split(',').filter(|s| !s.is_empty()) {
                let (lhs, rhs) = pair
                    .split_once('=')
                    .ok_or_else(|| anyhow!("bad --tile entry `{pair}` (want loop=factor)"))?;
                d.get_mut(resolve_loop(&k, lhs.trim())?).tile = rhs.trim().parse()?;
            }
        }
        if let Some(list) = pipes {
            for tok in list.split(',').filter(|s| !s.is_empty()) {
                d.get_mut(resolve_loop(&k, tok.trim())?).pipeline = true;
            }
        }
        d
    } else {
        match from.as_deref().unwrap_or("solve") {
            "empty" => crate::pragma::Design::empty(&k),
            "solve" => {
                let cap = args
                    .opt("cap")
                    .map(|s| s.parse::<u64>())
                    .transpose()?
                    .unwrap_or(u64::MAX);
                let fine = args.flag("fine");
                let jobs = parse_jobs(args)?.unwrap_or_else(nlp::default_jobs);
                let eval = make_evaluator(args);
                let p = NlpProblem::new(&k, &a, &dev, cap, fine);
                let r = nlp::solve_jobs(&p, 30.0, 1, eval.as_ref(), jobs);
                r.best().map(|(d, _)| d.clone()).ok_or_else(|| {
                    anyhow!(
                        "solver found no feasible design for `{}` (try a larger --cap)",
                        k.name
                    )
                })?
            }
            "dse" if args.flag("transform") => {
                // (variant × pragma): lower the *winning variant* — the
                // transformed kernel is a plain ir::Kernel, so codegen
                // runs unchanged once k and its analysis are swapped
                let dse_cfg = crate::dse::DseConfig {
                    jobs: parse_jobs(args)?.unwrap_or_else(nlp::default_jobs),
                    ..Default::default()
                };
                let tcfg = parse_transform_config(args)?;
                let eval = make_evaluator(args);
                let o = crate::transform::run_transform_dse(&k, &dev, &dse_cfg, &tcfg, eval.as_ref());
                let d = o.outcome.best.clone().map(|(d, _)| d).ok_or_else(|| {
                    anyhow!("transform DSE found no valid design for `{}`", k.name)
                })?;
                k = o.variant.kernel;
                a = Analysis::new(&k);
                d
            }
            "dse" => {
                let engine = args.opt("engine").unwrap_or_else(|| "nlpdse".into());
                let evaluator =
                    Evaluator::custom(std::sync::Arc::from(make_evaluator(args)));
                let dse_cfg = crate::dse::DseConfig {
                    jobs: parse_jobs(args)?.unwrap_or_else(nlp::default_jobs),
                    ..Default::default()
                };
                let outcome = Explorer::custom(k.clone())
                    .evaluator(evaluator)
                    .dse_config(dse_cfg)
                    .engine(&engine)?
                    .run()?;
                outcome.best.map(|(d, _)| d).ok_or_else(|| {
                    anyhow!("engine `{engine}` found no valid design for `{}`", k.name)
                })?
            }
            other => bail!(
                "bad --design-from `{other}` (want solve|dse|empty, \
                 or use --assign/--pipeline/--tile)"
            ),
        }
    };

    Ok(crate::codegen::emit(
        &k,
        &a,
        &dev,
        &design,
        &crate::codegen::EmitConfig { dialect, realized },
    ))
}

fn cmd_solve(args: &mut Args) -> Result<String> {
    let cap = args
        .opt("cap")
        .map(|s| s.parse::<u64>())
        .transpose()?
        .unwrap_or(u64::MAX);
    let fine = args.flag("fine");
    let jobs = parse_jobs(args)?.unwrap_or_else(nlp::default_jobs);
    let (k, a, dev) = build_kernel(args)?;
    let eval = make_evaluator(args);
    let p = NlpProblem::new(&k, &a, &dev, cap, fine);
    let r = nlp::solve_jobs(&p, 30.0, 3, eval.as_ref(), jobs);
    let mut out = format!(
        "NLP solve on {} (cap={}, fine={fine}, jobs={}):\n  proven lower bound: {:.0} cycles\n  \
         optimal: {}   solve time: {:.3}s   nodes: {}   scored: {}\n  \
         pruned by relaxation: {} (b&b {} + interval {})   infeasible: {}   \
         partition-pruned: {}   truncated menus: {}\n  \
         steals: {}   queue idle: {:.3}s\n",
        k.name,
        if cap == u64::MAX {
            "inf".into()
        } else {
            cap.to_string()
        },
        r.jobs,
        r.lower_bound,
        r.optimal,
        r.solve_time_s,
        r.stats.nodes,
        r.stats.candidates_scored,
        r.pruned_by_relaxation(),
        r.stats.pruned_bound,
        r.stats.pruned_relaxation,
        r.stats.infeasible,
        r.stats.pruned_partition,
        r.stats.truncated_menus,
        r.stats.steals,
        r.stats.queue_idle_s
    );
    for (i, (d, obj)) in r.designs.iter().enumerate() {
        out.push_str(&format!(
            "\n#{} objective {:.0} cycles ({:.2} GF/s bound):\n{}",
            i + 1,
            obj,
            a.gflops(*obj, dev.freq_hz),
            d.render(&k)
        ));
    }
    Ok(out)
}

/// `system`: multi-kernel system-level DSE — one epsilon-dominance
/// Pareto front per kernel ([`crate::nlp::solve_front`]), then the
/// branch-and-bound budget allocation of [`crate::system`] picking one
/// front point per kernel maximizing total GF/s under the shared
/// device DSP/BRAM/LUT budget.
fn cmd_system(args: &mut Args) -> Result<String> {
    let list = args
        .opt("kernels")
        .ok_or_else(|| anyhow!("--kernels k1,k2,... required (benchmark names or .knl paths)"))?;
    let size = parse_size(args)?.unwrap_or(Size::Medium);
    let dtype = parse_dtype(args)?;
    let device = match args.opt("device").as_deref().unwrap_or("u200") {
        "u200" | "xilinx-u200" => Device::u200(),
        other => bail!("unknown --device `{other}` (only `u200` is modeled)"),
    };
    let cap = args
        .opt("cap")
        .map(|s| s.parse::<u64>())
        .transpose()?
        .unwrap_or(u64::MAX);
    let epsilon: f64 = args
        .opt("epsilon")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(0.02);
    if !(0.0..1.0).contains(&epsilon) {
        bail!("--epsilon must be in [0, 1)");
    }
    let max_points: usize = args
        .opt("max-points")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(16);
    if max_points == 0 {
        bail!("--max-points must be >= 1");
    }
    let jobs = parse_jobs(args)?.unwrap_or_else(nlp::default_jobs);
    let tsv = args.flag("tsv");
    let eval = make_evaluator(args);
    let mut kernels = Vec::new();
    for spec in list.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        // path-looking specs parse as .knl files, everything else goes
        // through the benchmark registry (same rule as --kernel)
        let k = if spec.contains('/') || spec.ends_with(".knl") {
            frontend::parse_file(spec)?
        } else {
            benchmarks::lookup(spec, size, dtype)?
        };
        kernels.push((k.name.clone(), k));
    }
    if kernels.is_empty() {
        bail!("--kernels list is empty");
    }
    let cfg = crate::system::SystemConfig {
        front: nlp::FrontConfig { epsilon, max_points },
        cap,
        timeout_s: 30.0,
        jobs,
    };
    let out = crate::system::solve_system(&kernels, &device, &cfg, eval.as_ref());
    let fronts = report::system_fronts(&out);
    let alloc = report::system_allocation(&out, &device);
    if tsv {
        return Ok(format!("{}\n{}", fronts.to_tsv(), alloc.to_tsv()));
    }
    let verdict = match &out.alloc.best {
        Some(b) => format!(
            "system allocation: {:.2} GF/s total — dsp {:.0}/{}  onchip {:.0}/{} B  \
             lut {:.0}/{}  ({} b&b nodes, {:.3}s solve)",
            b.gflops,
            b.dsp,
            device.dsp_total,
            b.onchip_bytes,
            device.onchip_bytes,
            b.lut,
            device.lut_total,
            out.alloc.nodes,
            out.solve_time_s
        ),
        None => format!(
            "system allocation: infeasible — no choice of one front point per kernel \
             fits the {} budget ({} b&b nodes)",
            device.name, out.alloc.nodes
        ),
    };
    Ok(format!("{}\n\n{}\n\n{verdict}", fronts.render(), alloc.render()))
}

fn cmd_space(args: &mut Args) -> Result<String> {
    if args.opt("kernel").is_none() && args.opt("kernel-file").is_none() {
        let mut out = String::from("available kernels:\n");
        for n in benchmarks::ALL {
            out.push_str(&format!("  {n}\n"));
        }
        out.push_str("(or any .knl file — see `gen` and --kernel-file)\n");
        return Ok(out);
    }
    args.put_back("kernel");
    args.put_back("kernel-file");
    let (k, a, _dev) = build_kernel(args)?;
    let s = Space::new(&k, &a);
    let mut out = format!(
        "{} — {} loops, {} statements, {} dependences\n\
         space size (valid designs): {}\n\
         pipeline configurations: {}\n\
         summary AST: {}\n",
        k.name,
        k.n_loops(),
        k.n_stmts(),
        a.deps.nd(),
        crate::util::sci(s.size()),
        s.pipeline_configs.len(),
        k.summary_ast()
    );
    for (i, tc) in a.tcs.iter().enumerate() {
        let info = &a.deps.per_loop[i];
        out.push_str(&format!(
            "  L{i} {:<6} TC {}..{} (avg {:.1})  {}{}{}  UF options: {}\n",
            k.loop_name(crate::ir::LoopId(i as u32)),
            tc.min,
            tc.max,
            tc.avg,
            if info.reduction { "reduction " } else { "" },
            if info.serializing { "serializing " } else { "" },
            if info.parallel() { "parallel" } else { "" },
            s.uf_candidates[i].len()
        ));
    }
    Ok(out)
}

/// `gen`: emit seeded random `.knl` kernels — one to stdout, or a
/// corpus under `--out-dir` with one file per seed. Seeds are logged in
/// the summary so any kernel can be regenerated exactly.
fn cmd_gen(args: &mut Args) -> Result<String> {
    let seed: u64 = args.opt("seed").map(|s| s.parse()).transpose()?.unwrap_or(0);
    let count: usize = args
        .opt("count")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(1);
    if count == 0 {
        bail!("--count must be >= 1");
    }
    // the summary advertises seeds `seed..=last` as the replay handle —
    // they must exist, not wrap
    let last = seed
        .checked_add(count as u64 - 1)
        .ok_or_else(|| anyhow!("--seed {seed} + --count {count} overflows the seed range"))?;
    // --sampled derives the knobs from each seed (max scenario
    // diversity, one-u64 replay); explicitly passed knob flags apply on
    // top in either mode, so a `--max-trip 8` cap is never silently lost
    let sampled = args.flag("sampled");
    let depth: Option<usize> = args.opt("depth").map(|v| v.parse()).transpose()?;
    let width: Option<usize> = args.opt("width").map(|v| v.parse()).transpose()?;
    let nests: Option<usize> = args.opt("nests").map(|v| v.parse()).transpose()?;
    let arrays: Option<usize> = args.opt("arrays").map(|v| v.parse()).transpose()?;
    let max_trip: Option<u64> = args.opt("max-trip").map(|v| v.parse()).transpose()?;
    let dtype = parse_dtype_opt(args)?;
    let out_dir = args.opt("out-dir");
    if count > 1 && out_dir.is_none() {
        bail!("--count {count} needs --out-dir <dir> (a corpus is one file per seed)");
    }
    let mut summary = String::new();
    for i in 0..count {
        let s = seed + i as u64;
        let mut cfg = if sampled {
            frontend::GenConfig::sampled(s)
        } else {
            frontend::GenConfig::with_seed(s)
        };
        if let Some(v) = depth {
            cfg.depth = v;
        }
        if let Some(v) = width {
            cfg.width = v;
        }
        if let Some(v) = nests {
            cfg.nests = v;
        }
        if let Some(v) = arrays {
            cfg.arrays = v;
        }
        if let Some(v) = max_trip {
            cfg.max_trip = v;
        }
        if let Some(v) = dtype {
            cfg.dtype = v;
        }
        let k = frontend::generate(&cfg);
        let text = frontend::pretty::print(&k);
        match &out_dir {
            None => return Ok(text),
            Some(dir) => {
                std::fs::create_dir_all(dir)?;
                let path = format!("{dir}/{}.knl", k.name);
                std::fs::write(&path, &text)?;
                summary.push_str(&format!(
                    "seed {s:>6}  {}  ({} loops, {} stmts) -> {path}\n",
                    k.name,
                    k.n_loops(),
                    k.n_stmts()
                ));
            }
        }
    }
    let mut knobs: Vec<String> = Vec::new();
    if let Some(v) = depth {
        knobs.push(format!("depth<={v}"));
    }
    if let Some(v) = width {
        knobs.push(format!("width<={v}"));
    }
    if let Some(v) = nests {
        knobs.push(format!("nests<={v}"));
    }
    if let Some(v) = arrays {
        knobs.push(format!("arrays~{v}"));
    }
    if let Some(v) = max_trip {
        knobs.push(format!("max-trip {v}"));
    }
    if let Some(v) = dtype {
        knobs.push(v.name().to_string());
    }
    summary.push_str(&format!(
        "generated {count} kernel(s), seeds {seed}..={last} ({}{})\n",
        if sampled {
            "knobs sampled per seed"
        } else {
            "default knobs"
        },
        if knobs.is_empty() {
            String::new()
        } else {
            format!("; pinned: {}", knobs.join(" "))
        }
    ));
    Ok(summary)
}

fn cmd_campaign(args: &mut Args) -> Result<String> {
    let engines = match args.opt("engines") {
        Some(list) => {
            let names: Vec<String> = list.split(',').map(|s| s.trim().to_string()).collect();
            let reg = Registry::builtin();
            for n in &names {
                if !reg.contains(n) {
                    bail!(
                        "unknown engine `{n}` (registered: {})",
                        reg.names().join(", ")
                    );
                }
            }
            names
        }
        None => engine_names(&["nlpdse", "autodse", "harp"]),
    };
    let emit_dir = args.opt("emit-dir");
    let emit_cfg = crate::codegen::EmitConfig {
        dialect: parse_dialect(args)?,
        realized: args.flag("realized"),
    };
    let (cfg, r) = scope_campaign(args, engines)?;
    // best-design artifacts: one annotated C file per (row, engine),
    // indexed by a report table so campaigns link code, not just numbers
    let emit_note = match emit_dir {
        None => String::new(),
        Some(dir) => {
            let rows = emit_campaign(&r, cfg.dtype, &dir, &emit_cfg)?;
            format!("\n{}", report::emitted_index(&rows).render())
        }
    };
    let json = campaign_json(&r);
    if let Some(path) = args.opt("json") {
        std::fs::write(&path, json.to_string_pretty())?;
        return Ok(format!(
            "campaign complete: {} rows -> {path}{emit_note}",
            r.rows.len()
        ));
    }
    Ok(format!("{}{emit_note}", json.to_string_pretty()))
}

/// Write one pragma-annotated C file per (campaign row, engine) best
/// design into `dir` and return the index rows for
/// [`report::emitted_index`]. Rows whose kernel no longer resolves are
/// skipped with a report, like every other campaign-robustness path.
fn emit_campaign(
    r: &CampaignResult,
    dtype: DType,
    dir: &str,
    cfg: &crate::codegen::EmitConfig,
) -> Result<Vec<report::EmittedRow>> {
    std::fs::create_dir_all(dir)?;
    let mut out = Vec::new();
    for row in &r.rows {
        let k = match benchmarks::lookup(&row.name, row.size, dtype) {
            Ok(k) => k,
            Err(err) => {
                eprintln!("[campaign] emit skipped for `{}`: {err:#}", row.name);
                continue;
            }
        };
        let a = Analysis::new(&k);
        let dev = Device::u200();
        for e in &row.explorations {
            let Some((d, _)) = &e.best else { continue };
            let code = crate::codegen::emit(&k, &a, &dev, d, cfg);
            let safe: String = row
                .name
                .chars()
                .map(|c| if c == '/' || c == '\\' { '_' } else { c })
                .collect();
            let path = format!(
                "{dir}/{safe}-{}-{}.{}.c",
                row.size.tag(),
                e.engine,
                cfg.dialect.name()
            );
            std::fs::write(&path, &code)?;
            out.push(report::EmittedRow {
                kernel: row.name.clone(),
                size: row.size.tag().to_string(),
                engine: e.engine.clone(),
                gflops: e.best_gflops,
                path,
            });
        }
    }
    Ok(out)
}

/// `serve`: the DSE-as-a-service daemon of [`crate::serve`]. Binds
/// `--addr` (default `127.0.0.1:4517`; port `0` picks an ephemeral one)
/// and blocks until a `shutdown` op or SIGTERM/SIGINT, then drains
/// in-flight requests and returns. `--threads` bounds concurrent
/// requests (default: the campaign pool width); `--jobs` sets the NLP
/// solver's worker team *per request* (default 1 — the request pool
/// already saturates the host, exactly like campaigns; individual
/// requests may still override with a `"jobs"` field).
fn cmd_serve(args: &mut Args) -> Result<String> {
    let addr = args.opt("addr").unwrap_or_else(|| "127.0.0.1:4517".into());
    let cache_entries: usize = args
        .opt("cache-entries")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(64);
    let jobs = parse_jobs(args)?.unwrap_or(1);
    let threads: usize = match args.opt("threads") {
        Some(t) => t.parse()?,
        None => coordinator::num_threads(),
    };
    crate::serve::install_signal_handlers();
    let h = crate::serve::spawn(&addr, crate::serve::ServeConfig { jobs, cache_entries }, threads)?;
    let bound = h.addr();
    eprintln!(
        "[serve] listening on {bound} (threads={threads} jobs={jobs} cache-entries={cache_entries})\n\
         [serve] line-JSON ops: solve|dse|system|bound|emit|gen|stats|shutdown — e.g.\n\
         [serve]   printf '%s\\n' '{{\"op\":\"solve\",\"kernel\":\"gemm\",\"size\":\"S\"}}' | nc {} {}\n\
         [serve] ^C (or the `shutdown` op) stops the daemon cleanly",
        bound.ip(),
        bound.port()
    );
    let state = h.state().clone();
    h.join();
    // parting observability: issue one in-process `stats` op against the
    // drained daemon and render it as a table
    let mut last = String::new();
    let _ = crate::serve::handle_line(&state, r#"{"op":"stats"}"#, &mut |l: &str| {
        last = l.to_string();
    });
    let stats = crate::util::json::Json::parse(&last)
        .ok()
        .and_then(|j| j.get("data").cloned())
        .map(|d| format!("\n\n{}", report::serve_stats(&d).render()))
        .unwrap_or_default();
    Ok(format!("serve: daemon on {bound} shut down cleanly{stats}"))
}

/// JSON dump of a campaign (for plotting / external analysis). One
/// object per engine under `engines`, keyed by registry name — new
/// engines appear automatically with the normalized fields.
pub fn campaign_json(r: &CampaignResult) -> crate::util::json::Json {
    use crate::util::json::Json;
    let mut arr = Json::Arr(vec![]);
    for row in &r.rows {
        let mut o = Json::obj();
        o.set("kernel", row.name.as_str())
            .set("size", row.size.tag())
            .set("nl", row.nl)
            .set("nd", row.nd)
            .set("space", row.space_size)
            .set("footprint_bytes", row.footprint_bytes)
            .set("original_gflops", row.original_gflops);
        let mut engines = Json::obj();
        for e in &row.explorations {
            let mut j = Json::obj();
            j.set("gflops", e.best_gflops)
                .set("minutes", e.wall_minutes)
                .set("synth_calls", e.synth_calls)
                .set("timeouts", e.synth_timeouts)
                .set("pruned", e.pruned)
                .set("rejected", e.rejected);
            if e.first_synth_gflops > 0.0 {
                j.set("first_synth_gflops", e.first_synth_gflops);
            }
            if let Some(lb) = e.lower_bound {
                j.set("lower_bound_cycles", lb);
            }
            if let Some(n) = e.as_nlpdse() {
                j.set("steps_to_best", n.steps_to_best)
                    .set("steps_to_terminate", n.steps_to_terminate);
            }
            if let Some(h) = e.as_harp() {
                j.set("configs_scored", h.configs_scored);
            }
            engines.set(e.engine.as_str(), j);
        }
        o.set("engines", engines);
        arr.push(o);
    }
    arr
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_kernel_surfaces_the_clean_lookup_error() {
        for argv in [
            &["dse", "--kernel", "definitely-not-a-kernel"][..],
            &["solve", "--kernel", "definitely-not-a-kernel", "--cap", "16"][..],
            &["bound", "definitely-not-a-kernel"][..],
            &["space", "--kernel", "definitely-not-a-kernel"][..],
        ] {
            let err = run(argv).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains("unknown kernel"), "{argv:?}: {msg}");
            assert!(msg.contains("--kernel-file"), "{argv:?}: {msg}");
            assert!(msg.contains("`gen`"), "{argv:?}: {msg}");
        }
    }

    #[test]
    fn kernel_file_never_falls_back_to_the_registry() {
        // a --kernel-file named like a benchmark must be parsed as a
        // file (here: reported missing), never resolved to the benchmark
        let err = run(&["solve", "--kernel-file", "gemm", "--cap", "16"]).unwrap_err();
        assert!(format!("{err:#}").contains("reading kernel file"), "{err:#}");
    }

    #[test]
    fn missing_kernel_flag_is_reported() {
        let err = run(&["solve"]).unwrap_err();
        assert!(format!("{err:#}").contains("--kernel <name> or --kernel-file"));
    }

    #[test]
    fn gen_then_solve_via_kernel_file() {
        let dir = std::env::temp_dir().join("nlp_dse_cli_gen_test");
        let dir_s = dir.to_str().unwrap().to_string();
        let _ = std::fs::remove_dir_all(&dir);
        run(&["gen", "--seed", "9", "--count", "3", "--max-trip", "8", "--out-dir", &dir_s])
            .unwrap();
        let mut files: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().path())
            .collect();
        files.sort();
        assert_eq!(files.len(), 3, "{files:?}");
        let knl = files[0].to_str().unwrap();
        // the emitted corpus drives every kernel-consuming command
        run(&["solve", "--kernel-file", knl, "--cap", "16", "--jobs", "1"]).unwrap();
        run(&["space", "--kernel-file", knl]).unwrap();
        run(&["bound", "--kernel-file", knl]).unwrap();
        // and a path passed to --kernel resolves identically
        run(&["space", "--kernel", knl]).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dse_transform_reports_variants_and_winner() {
        let out = std::env::temp_dir().join("nlp_dse_cli_transform_test.txt");
        let out_s = out.to_str().unwrap().to_string();
        run(&[
            "dse", "--kernel", "mvt", "--size", "S", "--transform", "--max-variants", "2",
            "--jobs", "1", "--out", &out_s,
        ])
        .unwrap();
        let text = std::fs::read_to_string(&out).unwrap();
        assert!(text.contains("(variant × pragma) DSE on mvt"), "{text}");
        assert!(text.contains("(original)"), "{text}");
        assert!(text.contains("winner"), "{text}");
        assert!(text.contains("engine `transform`"), "{text}");
        let _ = std::fs::remove_file(&out);
    }

    #[test]
    fn emit_transform_lowers_the_winning_variant() {
        let out = std::env::temp_dir().join("nlp_dse_cli_transform_emit_test.c");
        let out_s = out.to_str().unwrap().to_string();
        run(&[
            "emit", "mvt", "--size", "S", "--design-from", "dse", "--transform",
            "--max-variants", "2", "--jobs", "1", "--out", &out_s,
        ])
        .unwrap();
        let c = std::fs::read_to_string(&out).unwrap();
        assert!(c.contains("#pragma"), "{c}");
        assert!(c.contains("void kernel_mvt("), "{c}");
        let _ = std::fs::remove_file(&out);
    }

    #[test]
    fn kernel_file_parse_errors_keep_the_caret_snippet() {
        // the rendered ParseError diagnostic (line/col header + caret
        // underline) must survive the anyhow chain on every
        // --kernel-file command path
        let path = std::env::temp_dir().join("nlp_dse_cli_diag_test.knl");
        let path_s = path.to_str().unwrap().to_string();
        std::fs::write(
            &path,
            "kernel \"bad\" f32\narray a[4] out\nfor i in 0 .. 4 {\n  stmt s writes a[zz];\n}\n",
        )
        .unwrap();
        for argv in [
            &["solve", "--kernel-file", &path_s, "--cap", "16"][..],
            &["emit", "--kernel-file", &path_s][..],
            &["space", "--kernel-file", &path_s][..],
        ] {
            let err = run(argv).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains("parsing kernel file"), "{argv:?}: {msg}");
            assert!(msg.contains(":4:"), "{argv:?}: {msg}");
            assert!(msg.contains("stmt s writes a[zz];"), "{argv:?}: {msg}");
            assert!(msg.contains('^'), "{argv:?}: {msg}");
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn emit_manual_design_writes_lintable_c() {
        let out = std::env::temp_dir().join("nlp_dse_cli_emit_manual.c");
        let out_s = out.to_str().unwrap().to_string();
        for dialect in ["merlin", "vitis"] {
            run(&[
                "emit", "gemm", "--size", "S", "--assign", "k=8", "--pipeline", "j1", "--tile",
                "i=2", "--dialect", dialect, "--out", &out_s,
            ])
            .unwrap();
            let code = std::fs::read_to_string(&out).unwrap();
            let k = benchmarks::lookup("gemm", Size::Small, DType::F32).unwrap();
            crate::codegen::lint(&k, &code).unwrap_or_else(|e| panic!("{dialect}: {e}\n{code}"));
            assert!(code.contains("void kernel_gemm("), "{code}");
        }
        // realized mode also lints (and reports the merlin outcome)
        run(&[
            "emit", "gemm", "--size", "S", "--assign", "k=8", "--realized", "--out", &out_s,
        ])
        .unwrap();
        let code = std::fs::read_to_string(&out).unwrap();
        assert!(code.contains("mode: realized"), "{code}");
        let _ = std::fs::remove_file(&out);
    }

    #[test]
    fn emit_design_sources_are_mutually_exclusive() {
        let err = run(&["emit", "gemm", "--design-from", "solve", "--assign", "i=2"]).unwrap_err();
        assert!(format!("{err:#}").contains("conflicts"), "{err:#}");
        let err = run(&["emit", "gemm", "--design-from", "nope"]).unwrap_err();
        assert!(format!("{err:#}").contains("bad --design-from"), "{err:#}");
    }

    #[test]
    fn emit_via_solve_and_dse_covers_kernels_end_to_end() {
        let dir = std::env::temp_dir().join("nlp_dse_cli_emit_solve");
        std::fs::create_dir_all(&dir).unwrap();
        // the acceptance flow: `emit K --design-from solve --dialect merlin`
        for name in ["gemm", "bicg", "atax"] {
            let out = dir.join(format!("{name}.c"));
            let out_s = out.to_str().unwrap().to_string();
            run(&[
                "emit", name, "--size", "S", "--design-from", "solve", "--cap", "16", "--jobs",
                "1", "--dialect", "merlin", "--out", &out_s,
            ])
            .unwrap();
            let code = std::fs::read_to_string(&out).unwrap();
            let k = benchmarks::lookup(name, Size::Small, DType::F32).unwrap();
            crate::codegen::lint(&k, &code).unwrap_or_else(|e| panic!("{name}: {e}\n{code}"));
            assert!(code.contains("#pragma ACCEL"), "{name}: {code}");
        }
        // a DSE engine's best design is emittable the same way
        let out = dir.join("mvt-dse.c");
        let out_s = out.to_str().unwrap().to_string();
        run(&[
            "emit", "mvt", "--size", "S", "--design-from", "dse", "--jobs", "1", "--out", &out_s,
        ])
        .unwrap();
        let k = benchmarks::lookup("mvt", Size::Small, DType::F32).unwrap();
        let code = std::fs::read_to_string(&out).unwrap();
        crate::codegen::lint(&k, &code).unwrap_or_else(|e| panic!("mvt/dse: {e}\n{code}"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn campaign_emit_dir_writes_indexed_artifacts() {
        let dir = std::env::temp_dir().join("nlp_dse_cli_emit_campaign");
        let dir_s = dir.to_str().unwrap().to_string();
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = CampaignConfig::quick();
        cfg.engines = engine_names(&["nlpdse", "random"]);
        let row = coordinator::run_one(&cfg, "gemm", Size::Small).unwrap();
        let r = CampaignResult { rows: vec![row] };
        let rows =
            emit_campaign(&r, DType::F32, &dir_s, &crate::codegen::EmitConfig::merlin()).unwrap();
        // one artifact per engine with a valid best design
        assert_eq!(rows.len(), 2, "{rows:?}");
        let k = benchmarks::lookup("gemm", Size::Small, DType::F32).unwrap();
        for er in &rows {
            let code = std::fs::read_to_string(&er.path).unwrap();
            crate::codegen::lint(&k, &code).unwrap_or_else(|e| panic!("{}: {e}", er.engine));
        }
        let index = report::emitted_index(&rows).render();
        assert!(index.contains("nlpdse"), "{index}");
        assert!(index.contains(&rows[0].path), "{index}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn train_then_surrogate_dse_via_model_file() {
        let dir = std::env::temp_dir().join("nlp_dse_cli_train_test");
        std::fs::create_dir_all(&dir).unwrap();
        let model = dir.join("m.json");
        let model_s = model.to_str().unwrap().to_string();
        let sum = dir.join("train.txt");
        let sum_s = sum.to_str().unwrap().to_string();
        run(&[
            "train", "--model-file", &model_s, "--kernels", "2", "--designs", "6", "--out",
            &sum_s,
        ])
        .unwrap();
        let text = std::fs::read_to_string(&sum).unwrap();
        assert!(text.contains("holdout spearman"), "{text}");
        assert!(text.contains("hash"), "{text}");
        // the artifact drives a surrogate DSE end to end
        let out = dir.join("dse.txt");
        let out_s = out.to_str().unwrap().to_string();
        run(&[
            "dse", "--kernel", "gemm", "--size", "S", "--engine", "surrogate", "--model-file",
            &model_s, "--verify-fraction", "0.5", "--jobs", "1", "--out", &out_s,
        ])
        .unwrap();
        let text = std::fs::read_to_string(&out).unwrap();
        assert!(text.contains("engine `surrogate`"), "{text}");
        assert!(text.contains("best design"), "{text}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn surrogate_flags_reject_other_engines_and_bad_values() {
        let err =
            run(&["dse", "--kernel", "gemm", "--size", "S", "--verify-fraction", "0.5"])
                .unwrap_err();
        assert!(format!("{err:#}").contains("surrogate"), "{err:#}");
        let err = run(&[
            "dse", "--kernel", "gemm", "--size", "S", "--engine", "surrogate",
            "--verify-fraction", "1.5",
        ])
        .unwrap_err();
        assert!(format!("{err:#}").contains("[0, 1]"), "{err:#}");
        let err = run(&["train"]).unwrap_err();
        assert!(format!("{err:#}").contains("--model-file"), "{err:#}");
    }

    #[test]
    fn dse_unknown_engine_error_lists_surrogate() {
        let err = run(&["dse", "--kernel", "gemm", "--engine", "nope"]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown engine"), "{msg}");
        assert!(msg.contains("surrogate"), "{msg}");
    }

    #[test]
    fn gen_without_out_dir_prints_knl_text() {
        // count 1 prints; count > 1 requires a directory
        run(&["gen", "--seed", "3", "--max-trip", "8"]).unwrap();
        let err = run(&["gen", "--count", "2"]).unwrap_err();
        assert!(format!("{err:#}").contains("--out-dir"));
    }
}
