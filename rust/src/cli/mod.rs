//! Command-line interface (hand-rolled; clap is unavailable offline).
//!
//! ```text
//! nlp-dse table --id 5 [--scope quick|paper] [--xla] [--tsv] [--out FILE]
//! nlp-dse figure --id 2|3|4|5|6 [--scope ...] [--kernel K --size M]
//! nlp-dse dse --kernel 2mm --size M [--engine nlpdse|autodse|harp] [--xla]
//! nlp-dse solve --kernel gemm --size S [--cap 512] [--fine] [--xla]
//! nlp-dse space --kernel 2mm --size M
//! nlp-dse campaign [--scope quick|paper|harp] [--json FILE] [--xla]
//! ```

pub mod args;

use crate::benchmarks::{self, Size};
use crate::coordinator::{self, CampaignConfig, CampaignResult, Engines};
use crate::dse::DseConfig;
use crate::hls::Device;
use crate::ir::DType;
use crate::nlp::{self, BatchEvaluator, NlpProblem, RustFeatureEvaluator};
use crate::poly::Analysis;
use crate::pragma::Space;
use crate::report;
use crate::runtime::{default_artifact_dir, XlaEvaluator};
use anyhow::{anyhow, bail, Result};
use args::Args;

pub fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    run(&argv.iter().map(|s| s.as_str()).collect::<Vec<_>>())
}

pub fn run(argv: &[&str]) -> Result<()> {
    let mut args = Args::parse(argv)?;
    let out = match args.command() {
        "table" => cmd_table(&mut args)?,
        "figure" => cmd_figure(&mut args)?,
        "dse" => cmd_dse(&mut args)?,
        "solve" => cmd_solve(&mut args)?,
        "space" => cmd_space(&mut args)?,
        "campaign" => cmd_campaign(&mut args)?,
        "help" | "" => help(),
        other => bail!("unknown command `{other}` (try `help`)"),
    };
    match args.opt("out") {
        Some(path) => {
            std::fs::write(&path, &out)?;
            println!("wrote {path}");
        }
        None => println!("{out}"),
    }
    Ok(())
}

fn help() -> String {
    "NLP-DSE — automatic HLS pragma insertion via non-linear programming\n\
     \n\
     commands:\n\
       table    --id 1|2|3|5|6|7|8|9 [--scope quick|paper] [--xla] [--tsv]\n\
       figure   --id 2|3|4|5|6 [--scope quick|paper] [--kernel K --size S]\n\
       dse      --kernel K --size S|M|L [--engine nlpdse|autodse|harp] [--xla]\n\
       solve    --kernel K --size S [--cap N] [--fine] [--xla]\n\
       space    --kernel K --size S\n\
       campaign [--scope quick|paper|harp] [--json FILE] [--xla]\n\
     \n\
     common flags: --out FILE  --threads N  --dtype f32|f64\n"
        .to_string()
}

fn scope_campaign(args: &mut Args, engines: Engines) -> Result<CampaignResult> {
    let scope = args.opt("scope").unwrap_or_else(|| "quick".into());
    let mut cfg = match scope.as_str() {
        "paper" => CampaignConfig::paper_autodse(),
        "harp" => CampaignConfig::paper_harp(),
        "quick" => {
            let mut c = CampaignConfig::quick();
            // quick scope still exercises the motivation trio for tables 1-3
            c.kernels = vec![
                ("2mm".into(), Size::Medium),
                ("gemm".into(), Size::Medium),
                ("gramschmidt".into(), Size::Large),
                ("bicg".into(), Size::Medium),
                ("atax".into(), Size::Medium),
            ];
            c
        }
        other => bail!("unknown scope `{other}`"),
    };
    cfg.engines = engines;
    if let Some(t) = args.opt("threads") {
        cfg.threads = t.parse()?;
    }
    cfg.use_xla = args.flag("xla");
    eprintln!(
        "[campaign] scope={scope} kernels={} threads={} xla={}",
        cfg.kernels.len(),
        cfg.threads,
        cfg.use_xla
    );
    Ok(coordinator::run_campaign(&cfg))
}

fn cmd_table(args: &mut Args) -> Result<String> {
    let id: u32 = args
        .opt("id")
        .ok_or_else(|| anyhow!("--id required"))?
        .parse()?;
    let tsv = args.flag("tsv");
    let table = match id {
        8 => report::table8(),
        9 => {
            let r = scope_campaign(
                args,
                Engines {
                    nlpdse: true,
                    autodse: false,
                    harp: true,
                },
            )?;
            report::table9(&r)
        }
        7 | 6 => {
            let r = scope_campaign(args, Engines::nlp_only())?;
            if id == 7 {
                report::table7(&r)
            } else {
                report::table6(&r)
            }
        }
        1 | 2 | 3 | 5 => {
            let r = scope_campaign(
                args,
                Engines {
                    nlpdse: true,
                    autodse: true,
                    harp: false,
                },
            )?;
            match id {
                1 => report::table1(&r),
                2 => report::table2(&r),
                3 => report::table3(&r),
                _ => report::table5(&r),
            }
        }
        other => bail!("no table {other} in the paper's evaluation"),
    };
    Ok(if tsv { table.to_tsv() } else { table.render() })
}

fn cmd_figure(args: &mut Args) -> Result<String> {
    let id: u32 = args
        .opt("id")
        .ok_or_else(|| anyhow!("--id required"))?
        .parse()?;
    Ok(match id {
        2 | 3 => {
            let r = scope_campaign(
                args,
                Engines {
                    nlpdse: true,
                    autodse: true,
                    harp: false,
                },
            )?;
            let size = if id == 2 { Size::Large } else { Size::Medium };
            report::figure2_3(&r, size)
        }
        4 => {
            let r = scope_campaign(
                args,
                Engines {
                    nlpdse: true,
                    autodse: false,
                    harp: true,
                },
            )?;
            report::figure4(&r)
        }
        5 => {
            let r = scope_campaign(args, Engines::nlp_only())?;
            report::figure5(&r)
        }
        6 => {
            let kernel = args.opt("kernel").unwrap_or_else(|| "2mm".into());
            let size = parse_size(args)?.unwrap_or(Size::Medium);
            let mut cfg = CampaignConfig::quick();
            cfg.kernels = vec![(kernel.clone(), size)];
            cfg.engines = Engines::nlp_only();
            cfg.use_xla = args.flag("xla");
            let r = coordinator::run_campaign(&cfg);
            report::figure6(&r, &kernel, size)
        }
        other => bail!("no figure {other}"),
    })
}

fn parse_size(args: &mut Args) -> Result<Option<Size>> {
    match args.opt("size") {
        None => Ok(None),
        Some(s) => Size::parse(&s)
            .map(Some)
            .ok_or_else(|| anyhow!("bad --size {s} (S|M|L)")),
    }
}

fn parse_dtype(args: &mut Args) -> DType {
    match args.opt("dtype").as_deref() {
        Some("f64") => DType::F64,
        _ => DType::F32,
    }
}

fn build_kernel(args: &mut Args) -> Result<(crate::ir::Kernel, Analysis, Device)> {
    let name = args
        .opt("kernel")
        .ok_or_else(|| anyhow!("--kernel required"))?;
    let size = parse_size(args)?.unwrap_or(Size::Medium);
    let dtype = parse_dtype(args);
    let k = benchmarks::build(&name, size, dtype)
        .ok_or_else(|| anyhow!("unknown kernel `{name}` (see `space` for the list)"))?;
    let a = Analysis::new(&k);
    Ok((k, a, Device::u200()))
}

fn make_evaluator(args: &mut Args) -> Box<dyn BatchEvaluator> {
    if args.flag("xla") {
        match XlaEvaluator::load(&default_artifact_dir()) {
            Ok(e) => {
                eprintln!("[xla] artifact loaded (batch={})", e.batch);
                return Box::new(e);
            }
            Err(e) => eprintln!("[xla] unavailable ({e:#}); using rust evaluator"),
        }
    }
    Box::new(RustFeatureEvaluator)
}

fn cmd_dse(args: &mut Args) -> Result<String> {
    let engine = args.opt("engine").unwrap_or_else(|| "nlpdse".into());
    let (k, a, dev) = build_kernel(args)?;
    let mut out = String::new();
    match engine.as_str() {
        "nlpdse" => {
            let eval = make_evaluator(args);
            let o = crate::dse::run_nlp_dse(&k, &a, &dev, &DseConfig::default(), eval.as_ref());
            out.push_str(&format!(
                "NLP-DSE on {} ({:?}):\n  best GF/s: {:.2}   first-synth GF/s: {:.2}\n  \
                 DSE time: {:.0} min   explored: {}   timeouts: {}\n  \
                 steps to best: {}   steps to terminate: {}\n\ntrace:\n",
                k.name,
                k.dtype,
                o.best_gflops,
                o.first_synth_gflops,
                o.dse_minutes,
                o.designs_explored,
                o.designs_timeout,
                o.steps_to_best,
                o.steps_to_terminate
            ));
            for s in &o.trace {
                out.push_str(&format!(
                    "  step {:>2} cap={:<8} fine={:<5} lb={:>14.0} gfs={:>8.2} {}\n",
                    s.step,
                    if s.cap == u64::MAX {
                        "inf".into()
                    } else {
                        s.cap.to_string()
                    },
                    s.fine_only,
                    s.lower_bound,
                    s.gflops,
                    if s.dedup {
                        "dedup"
                    } else if s.pruned {
                        "pruned"
                    } else if s.timeout {
                        "timeout"
                    } else if s.valid {
                        "ok"
                    } else {
                        "invalid"
                    }
                ));
            }
            if let Some((d, _)) = &o.best {
                out.push_str("\nbest pragma configuration:\n");
                out.push_str(&d.render(&k));
            }
        }
        "autodse" => {
            let o = crate::baselines::run_autodse(&k, &a, &dev, &Default::default());
            out.push_str(&format!(
                "AutoDSE on {}:\n  best GF/s: {:.2}\n  DSE time: {:.0} min\n  \
                 explored: {} (synth {} / timeout {} / early-reject {})\n",
                k.name,
                o.best_gflops,
                o.dse_minutes,
                o.designs_explored,
                o.designs_synthesized,
                o.designs_timeout,
                o.early_rejected
            ));
        }
        "harp" => {
            let o = crate::baselines::run_harp(&k, &a, &dev, &Default::default());
            out.push_str(&format!(
                "HARP on {}:\n  best GF/s: {:.2}\n  DSE time: {:.0} min\n  \
                 surrogate configs: {}   synthesized: {}\n",
                k.name, o.best_gflops, o.dse_minutes, o.configs_scored, o.designs_synthesized
            ));
        }
        other => bail!("unknown engine `{other}`"),
    }
    Ok(out)
}

fn cmd_solve(args: &mut Args) -> Result<String> {
    let cap = args
        .opt("cap")
        .map(|s| s.parse::<u64>())
        .transpose()?
        .unwrap_or(u64::MAX);
    let fine = args.flag("fine");
    let (k, a, dev) = build_kernel(args)?;
    let eval = make_evaluator(args);
    let p = NlpProblem::new(&k, &a, &dev, cap, fine);
    let r = nlp::solve(&p, 30.0, 3, eval.as_ref());
    let mut out = format!(
        "NLP solve on {} (cap={}, fine={fine}):\n  proven lower bound: {:.0} cycles\n  \
         optimal: {}   solve time: {:.3}s   nodes: {}   scored: {}\n",
        k.name,
        if cap == u64::MAX {
            "inf".into()
        } else {
            cap.to_string()
        },
        r.lower_bound,
        r.optimal,
        r.solve_time_s,
        r.stats.nodes,
        r.stats.candidates_scored
    );
    for (i, (d, obj)) in r.designs.iter().enumerate() {
        out.push_str(&format!(
            "\n#{} objective {:.0} cycles ({:.2} GF/s bound):\n{}",
            i + 1,
            obj,
            a.gflops(*obj, dev.freq_hz),
            d.render(&k)
        ));
    }
    Ok(out)
}

fn cmd_space(args: &mut Args) -> Result<String> {
    if args.opt("kernel").is_none() {
        let mut out = String::from("available kernels:\n");
        for n in benchmarks::ALL {
            out.push_str(&format!("  {n}\n"));
        }
        return Ok(out);
    }
    args.put_back("kernel");
    let (k, a, _dev) = build_kernel(args)?;
    let s = Space::new(&k, &a);
    let mut out = format!(
        "{} — {} loops, {} statements, {} dependences\n\
         space size (valid designs): {}\n\
         pipeline configurations: {}\n\
         summary AST: {}\n",
        k.name,
        k.n_loops(),
        k.n_stmts(),
        a.deps.nd(),
        crate::util::sci(s.size()),
        s.pipeline_configs.len(),
        k.summary_ast()
    );
    for (i, tc) in a.tcs.iter().enumerate() {
        let info = &a.deps.per_loop[i];
        out.push_str(&format!(
            "  L{i} {:<6} TC {}..{} (avg {:.1})  {}{}{}  UF options: {}\n",
            k.loop_name(crate::ir::LoopId(i as u32)),
            tc.min,
            tc.max,
            tc.avg,
            if info.reduction { "reduction " } else { "" },
            if info.serializing { "serializing " } else { "" },
            if info.parallel() { "parallel" } else { "" },
            s.uf_candidates[i].len()
        ));
    }
    Ok(out)
}

fn cmd_campaign(args: &mut Args) -> Result<String> {
    let r = scope_campaign(args, Engines::all())?;
    let json = campaign_json(&r);
    if let Some(path) = args.opt("json") {
        std::fs::write(&path, json.to_string_pretty())?;
        return Ok(format!("campaign complete: {} rows -> {path}", r.rows.len()));
    }
    Ok(json.to_string_pretty())
}

/// JSON dump of a campaign (for plotting / external analysis).
pub fn campaign_json(r: &CampaignResult) -> crate::util::json::Json {
    use crate::util::json::Json;
    let mut arr = Json::Arr(vec![]);
    for row in &r.rows {
        let mut o = Json::obj();
        o.set("kernel", row.name.as_str())
            .set("size", row.size.tag())
            .set("nl", row.nl)
            .set("nd", row.nd)
            .set("space", row.space_size)
            .set("footprint_bytes", row.footprint_bytes)
            .set("original_gflops", row.original_gflops);
        if let Some(n) = &row.nlpdse {
            let mut j = Json::obj();
            j.set("gflops", n.best_gflops)
                .set("first_synth_gflops", n.first_synth_gflops)
                .set("minutes", n.dse_minutes)
                .set("explored", n.designs_explored)
                .set("timeouts", n.designs_timeout)
                .set("steps_to_best", n.steps_to_best)
                .set("steps_to_terminate", n.steps_to_terminate);
            o.set("nlpdse", j);
        }
        if let Some(a) = &row.autodse {
            let mut j = Json::obj();
            j.set("gflops", a.best_gflops)
                .set("minutes", a.dse_minutes)
                .set("explored", a.designs_explored)
                .set("timeouts", a.designs_timeout)
                .set("early_rejected", a.early_rejected);
            o.set("autodse", j);
        }
        if let Some(h) = &row.harp {
            let mut j = Json::obj();
            j.set("gflops", h.best_gflops)
                .set("minutes", h.dse_minutes)
                .set("configs_scored", h.configs_scored);
            o.set("harp", j);
        }
        arr.push(o);
    }
    arr
}
