//! Simulated AMD/Xilinx Merlin source-to-source compiler.
//!
//! Merlin takes the pragma-annotated kernel and *realizes* it: it may
//! refuse pragmas its analyses cannot prove safe/profitable, it decides the
//! actual array partitioning, and it generates the off-chip↔on-chip
//! transfers. The paper's evaluation hinges on these behaviours:
//!
//! * Section 7.5: "about half of the designs have at least one pragma not
//!   applied"; "Merlin is more restrictive for coarse-grained
//!   parallelization, in many cases these pragmas are not applied",
//!   especially for kernels without an outermost reduction loop (2mm, 3mm,
//!   gemver, …);
//! * "certain cases where the partitioning is not done correctly which
//!   does not allow a pipeline with II=1 when it is theoretically
//!   possible";
//! * "Merlin transforms the size of the arrays according to the program's
//!   unroll factors and in certain cases does not allow transfers with a
//!   bitwidth of 512 bits"; and the mvt case where an array is transferred
//!   twice;
//! * rarely, Vitis auto-applies `loop_flatten`, the one documented case
//!   where the measured latency undercuts the lower bound (Fig 5, red).
//!
//! All decisions are **deterministic**: they hash the (kernel, loop,
//! pragma) triple, so identical designs always realize identically — a
//! requirement for reproducible DSE traces.

use crate::hls::Device;
use crate::ir::{ArrayId, Kernel, LoopId};
use crate::poly::Analysis;
use crate::pragma::Design;
use crate::util::rng::hash64;

/// Why a pragma was not applied.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Reject {
    /// Coarse-grained `parallel` refused by Merlin's conservative analysis.
    CoarseGrained(LoopId),
    /// `parallel` refused because the implied array partitioning is not
    /// realizable.
    Partitioning(LoopId),
    /// The whole design is refused (AutoDSE's "early reject" bucket):
    /// Merlin's analysis fails outright, e.g. a `parallel` factor on a
    /// dependence-carrying loop.
    EarlyReject,
}

/// Realized memory-transfer plan for one array.
#[derive(Clone, Debug)]
pub struct Transfer {
    /// The transferred array.
    pub array: ArrayId,
    /// How many times the array crosses the off-chip boundary.
    pub times: u32,
    /// Achieved packing width in bits (≤ device max burst).
    pub bits: u64,
    /// Total transfer cycles for this array.
    pub cycles: f64,
}

/// The outcome of running Merlin on a pragma configuration.
#[derive(Clone, Debug)]
pub struct MerlinOutcome {
    /// The design Merlin actually implements (refused pragmas reset).
    pub realized: Design,
    /// Every pragma refusal, in decision order.
    pub rejects: Vec<Reject>,
    /// Achieved II multiplier (≥ 1) from imperfect partitioning.
    pub ii_penalty: f64,
    /// Realized off-chip transfer plan, per array.
    pub transfers: Vec<Transfer>,
    /// Total realized communication cycles (transfers serialize per nest
    /// group — pessimistic vs the Theorem 4.14 bound).
    pub comm_cycles: f64,
    /// Vitis auto-applied `loop_flatten` (lower-bound exception, Fig 5).
    pub flattened: bool,
    /// Design refused outright.
    pub early_reject: bool,
}

impl MerlinOutcome {
    /// True when every requested pragma was applied as given (Fig 5b's
    /// filter).
    pub fn pragmas_applied(&self, requested: &Design) -> bool {
        !self.early_reject && self.realized == *requested
    }
}

/// Deterministic per-decision coin: hash of kernel + decision key.
fn coin(k: &Kernel, key: &str, p_percent: u64) -> bool {
    hash64(&format!("{}/{}/{}", k.name, k.dtype.name(), key)) % 100 < p_percent
}

/// Run (simulated) Merlin on a design.
pub fn apply(k: &Kernel, a: &Analysis, dev: &Device, d: &Design) -> MerlinOutcome {
    let mut realized = d.clone();
    let mut rejects = Vec::new();
    let mut early_reject = false;

    // ---- early rejection: pragmas Merlin cannot analyze at all ------------
    // parallel factor on a serializing loop (distance-capped recurrences
    // excepted when UF ≤ distance — Eq 8 designs are analyzable)
    for (i, p) in d.pragmas.iter().enumerate() {
        if p.uf <= 1 {
            continue;
        }
        let info = &a.deps.per_loop[i];
        let dist_ok = info.min_distance.map(|dd| p.uf <= dd.max(1)).unwrap_or(true);
        if info.serializing && !dist_ok {
            early_reject = true;
            rejects.push(Reject::EarlyReject);
            break;
        }
        // coarse-grained replication of a reduction loop is impossible —
        // the paper's AtAx example: AutoDSE "attempts coarse-grained
        // parallelization on Loop 1 with all divisors, which is impossible
        // due to dependencies" → Merlin prunes these designs
        let meta = k.loop_meta(LoopId(i as u32));
        if info.reduction && !meta.innermost && !meta.children.is_empty() {
            early_reject = true;
            rejects.push(Reject::EarlyReject);
            break;
        }
        // non-divisor or non-constant TC unrolls are likewise refused
        let tc = &a.tcs[i];
        if !tc.is_constant() || (tc.max > 0 && tc.max % p.uf != 0) {
            early_reject = true;
            rejects.push(Reject::EarlyReject);
            break;
        }
    }

    // ---- coarse-grained parallel decisions ---------------------------------
    // a `parallel` on a loop whose body still contains loops (after the
    // under-pipeline full-unroll) is coarse-grained: Merlin frequently
    // refuses these (Section 7.5), more often for kernels without an outer
    // reduction loop.
    if !early_reject {
        let has_outer_reduction = k
            .nest_roots()
            .iter()
            .any(|&r| a.deps.loop_info(r).reduction);
        for (i, p) in d.pragmas.iter().enumerate() {
            if p.uf <= 1 || p.pipeline {
                continue;
            }
            let l = LoopId(i as u32);
            let meta = k.loop_meta(l);
            let is_coarse = !meta.innermost
                && d.pipeline_above(k, l) != Some(l)
                && !meta
                    .children
                    .is_empty();
            // only "above pipeline" replication counts as coarse
            let under_pipe = d
                .pipelined()
                .any(|pl| k.is_under(l, pl));
            if is_coarse && !under_pipe {
                // acceptance rate: 30% for kernels without outer reduction,
                // 60% with (the reduction forces Merlin's restructuring
                // path, which handles replication better). The decision is
                // **per loop**, not per factor: Merlin either can prove the
                // restructuring for that loop or it cannot — retrying with
                // a different factor does not change the analysis outcome.
                let accept = if has_outer_reduction { 60 } else { 30 };
                if !coin(k, &format!("coarse/{i}"), accept) {
                    realized.pragmas[i].uf = 1;
                    rejects.push(Reject::CoarseGrained(l));
                }
            }
        }
    }

    // ---- fine-grained partitioning feasibility ------------------------------
    // large partitioning factors sometimes fail to yield II=1 pipelines
    let mut ii_penalty = 1.0f64;
    if !early_reject {
        for arr in &k.arrays {
            let part = realized.partitioning(k, arr.id);
            if part > dev.max_array_partition {
                // Vitis hard limit: the unroll is refused, not the design
                // (Merlin falls back to a smaller factor on the innermost)
                for (i, p) in d.pragmas.iter().enumerate() {
                    if p.uf > 1 {
                        realized.pragmas[i].uf = 1;
                    }
                }
                rejects.push(Reject::Partitioning(LoopId(0)));
                break;
            }
            if part > 256 && coin(k, &format!("part/{}/{part}", arr.name), 40) {
                // partitioning realized imperfectly → achieved II grows
                ii_penalty = ii_penalty.max(2.0 + ((part as f64).log2() - 8.0).max(0.0) * 0.5);
            }
        }
    }

    // ---- memory transfers ---------------------------------------------------
    let (transfers, comm_cycles) = plan_transfers(k, a, dev, &realized);

    // ---- auto loop_flatten (the documented LB exception) --------------------
    // occurs for perfectly-nested pipelines at a middle loop
    let flattened = !early_reject
        && d.pipelined().any(|lp| {
            let meta = k.loop_meta(lp);
            meta.depth > 0 && !meta.innermost
        })
        && coin(k, "flatten", 4);

    MerlinOutcome {
        realized,
        rejects,
        ii_penalty,
        transfers,
        comm_cycles,
        flattened,
        early_reject,
    }
}

/// Realize the off-chip transfer plan. Pessimistic vs the model:
/// * arrays used by several nests with a large footprint are re-transferred
///   per use (no cross-nest reuse — the paper's mvt observation);
/// * packing degrades below 512 bits when the partitioning interacts badly
///   with the transfer layout;
/// * transfers within one nest group serialize (sum), groups serialize too.
fn plan_transfers(
    k: &Kernel,
    a: &Analysis,
    dev: &Device,
    d: &Design,
) -> (Vec<Transfer>, f64) {
    let mut out = Vec::new();
    let mut total = 0f64;
    for arr in &k.arrays {
        let fp = arr.footprint_bytes(k.dtype);
        if fp == 0 {
            continue;
        }
        // nests touching this array
        let mut nests_using = std::collections::BTreeSet::new();
        for s in k.stmts() {
            for (acc, _) in k.stmt_accesses(s.id) {
                if acc.array == arr.id {
                    if let Some(root) = k.stmt_meta(s.id).nest.first() {
                        nests_using.insert(k.loop_meta(*root).nest_root);
                    }
                }
            }
        }
        let crossings =
            arr.dir.is_live_in() as u32 + arr.dir.is_live_out() as u32;
        if crossings == 0 {
            continue; // pure temp kept on-chip when it fits
        }
        // re-transfer per nest when the footprint strains on-chip capacity
        let mut times = crossings;
        if nests_using.len() > 1 && fp as f64 > dev.onchip_bytes as f64 / 4.0 {
            times += (nests_using.len() as u32 - 1) * arr.dir.is_live_in() as u32;
        }
        // packing degradation
        let part = d.partitioning(k, arr.id);
        let mut bits = dev.max_burst_bits;
        if part > 64 && coin(k, &format!("pack/{}/{part}", arr.name), 35) {
            bits = dev.max_burst_bits / 2;
        }
        if part > 512 {
            bits = bits.min(dev.max_burst_bits / 4);
        }
        let cycles = times as f64 * fp as f64 / (bits as f64 / 8.0);
        total += cycles;
        out.push(Transfer {
            array: arr.id,
            times,
            bits,
            cycles,
        });
    }
    let _ = a;
    (out, total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{self, Size};
    use crate::ir::DType;
    use crate::pragma::LoopPragma;

    fn setup(name: &str) -> (Kernel, Analysis, Device) {
        let k = benchmarks::build(name, Size::Medium, DType::F32).unwrap();
        let a = Analysis::new(&k);
        (k, a, Device::u200())
    }

    #[test]
    fn empty_design_passes_through() {
        let (k, a, dev) = setup("gemm");
        let d = Design::empty(&k);
        let m = apply(&k, &a, &dev, &d);
        assert!(!m.early_reject);
        assert!(m.pragmas_applied(&d));
        assert_eq!(m.ii_penalty, 1.0);
        assert!(m.comm_cycles > 0.0);
    }

    #[test]
    fn serializing_unroll_early_rejected() {
        let (k, a, dev) = setup("seidel-2d");
        let mut d = Design::empty(&k);
        d.get_mut(crate::ir::LoopId(1)).uf = 2; // i carries the sweep order
        let m = apply(&k, &a, &dev, &d);
        assert!(m.early_reject);
    }

    #[test]
    fn non_divisor_rejected() {
        let (k, a, dev) = setup("gemm");
        let mut d = Design::empty(&k);
        d.get_mut(crate::ir::LoopId(0)).uf = 7; // 200 % 7 != 0
        let m = apply(&k, &a, &dev, &d);
        assert!(m.early_reject);
    }

    #[test]
    fn deterministic_outcomes() {
        let (k, a, dev) = setup("2mm");
        let mut d = Design::empty(&k);
        d.get_mut(crate::ir::LoopId(0)).uf = 3;
        d.get_mut(crate::ir::LoopId(1)).pipeline = true;
        let m1 = apply(&k, &a, &dev, &d);
        let m2 = apply(&k, &a, &dev, &d);
        assert_eq!(m1.realized, m2.realized);
        assert_eq!(m1.comm_cycles, m2.comm_cycles);
    }

    #[test]
    fn coarse_grain_sometimes_refused() {
        // across many coarse configurations, a substantial fraction must be
        // refused (Section 7.5) — statistically over the suite
        let mut refused = 0;
        let mut total = 0;
        for name in ["2mm", "3mm", "gemver", "gemm", "doitgen"] {
            let (k, a, dev) = setup(name);
            for i in 0..k.n_loops() {
                let meta = k.loop_meta(crate::ir::LoopId(i as u32));
                if meta.innermost || meta.children.is_empty() {
                    continue;
                }
                let tc = a.tcs[i].clone();
                if !tc.is_constant() {
                    continue;
                }
                for uf in crate::util::divisors(tc.max).into_iter().skip(1).take(4) {
                    let mut d = Design::empty(&k);
                    d.pragmas[i] = LoopPragma {
                        uf,
                        tile: 1,
                        pipeline: false,
                    };
                    let m = apply(&k, &a, &dev, &d);
                    if m.early_reject {
                        continue;
                    }
                    total += 1;
                    if !m.pragmas_applied(&d) {
                        refused += 1;
                    }
                }
            }
        }
        assert!(total > 10);
        let rate = refused as f64 / total as f64;
        assert!(
            (0.2..=0.9).contains(&rate),
            "coarse refusal rate {rate} ({refused}/{total})"
        );
    }

    #[test]
    fn transfer_plan_covers_live_arrays() {
        let (k, a, dev) = setup("bicg");
        let d = Design::empty(&k);
        let m = apply(&k, &a, &dev, &d);
        // A, p, r inputs; s, q outputs → 5 transfers
        assert_eq!(m.transfers.len(), 5);
        // realized comm must be ≥ the optimistic model bound
        let model = crate::model::evaluate(&k, &a, &dev, &d);
        assert!(m.comm_cycles >= model.comm_cycles);
    }

    #[test]
    fn realized_comm_always_at_least_model_bound() {
        for name in ["gemm", "2mm", "mvt", "gesummv", "jacobi-2d"] {
            let (k, a, dev) = setup(name);
            let d = Design::empty(&k);
            let m = apply(&k, &a, &dev, &d);
            let model = crate::model::evaluate(&k, &a, &dev, &d);
            assert!(
                m.comm_cycles >= model.comm_cycles * 0.999,
                "{name}: merlin {} < model {}",
                m.comm_cycles,
                model.comm_cycles
            );
        }
    }
}
