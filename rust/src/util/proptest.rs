//! Mini property-based testing driver (the proptest crate is unavailable
//! offline). Generates `cases` random inputs from a caller-supplied
//! generator and checks a property; on failure reports the case index and
//! seed so the exact input can be regenerated.
//!
//! No shrinking — generators are kept small and structured enough that raw
//! failing cases are readable (they are printed via `Debug`).

use super::rng::Rng;

/// Mini property-test harness: N seeded cases per property.
pub struct Prop {
    /// Cases to run.
    pub cases: u32,
    /// Base seed (case i derives from it).
    pub seed: u64,
}

impl Default for Prop {
    fn default() -> Self {
        // PROPTEST_CASES mirrors the proptest crate's env knob.
        let cases = std::env::var("PROPTEST_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(64);
        Prop { cases, seed: 0x5eed }
    }
}

impl Prop {
    /// Harness running `cases` cases from the default seed.
    pub fn new(cases: u32) -> Prop {
        Prop {
            cases,
            ..Prop::default()
        }
    }

    /// Run `prop` on `cases` inputs drawn from `gen`. Panics (with seed and
    /// input `Debug`) on the first failing case.
    pub fn check<T: std::fmt::Debug>(
        &self,
        name: &str,
        mut gen: impl FnMut(&mut Rng) -> T,
        mut prop: impl FnMut(&T) -> Result<(), String>,
    ) {
        for i in 0..self.cases {
            let mut rng = Rng::new(self.seed.wrapping_add(i as u64).wrapping_mul(0x9e37));
            let input = gen(&mut rng);
            if let Err(msg) = prop(&input) {
                panic!(
                    "property `{name}` failed at case {i} (seed={}):\n  input: {input:?}\n  {msg}",
                    self.seed
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        Prop::new(32).check(
            "add-commutes",
            |r| (r.range(0, 1000), r.range(0, 1000)),
            |&(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn reports_failures() {
        Prop::new(4).check("always-fails", |r| r.next_u64(), |_| Err("no".into()));
    }
}
