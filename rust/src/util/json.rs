//! Minimal JSON value tree + emitter + parser (serde is unavailable
//! offline).
//!
//! Construction, pretty- and compact printing with stable key order,
//! string escaping, and — since the `serve` daemon speaks line-delimited
//! JSON both ways — a small recursive-descent parser ([`Json::parse`])
//! with typed accessors. The parser accepts standard JSON (RFC 8259):
//! it is not streaming (the serve protocol frames one value per line)
//! and rejects trailing garbage.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value (offline stand-in for serde_json).
///
/// Integers and floats are distinct variants: an integer lexeme parses
/// to [`Json::Int`] and round-trips losslessly over the full `i64`
/// range, so a client correlation `id` such as a 64-bit snowflake is
/// echoed bit-exactly instead of being squeezed through an `f64`
/// (which silently rounds above 2⁵³). Integers outside `i64` fall back
/// to [`Json::Num`] with `f64` precision.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Integer number (lossless over the `i64` range).
    Int(i64),
    /// Floating-point number (also integers outside the `i64` range).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (key-sorted for stable output).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// An empty object.
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Set `key` on an object (panics on non-objects).
    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val.into());
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    /// Append to an array (panics on non-arrays).
    pub fn push(&mut self, val: impl Into<Json>) -> &mut Self {
        if let Json::Arr(v) = self {
            v.push(val.into());
        } else {
            panic!("Json::push on non-array");
        }
        self
    }

    /// Render with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out
    }

    /// Render without any whitespace — one line, the serve protocol's
    /// wire framing (newline-delimited JSON requires the value itself to
    /// contain no raw newlines; string escaping already guarantees that).
    pub fn to_line(&self) -> String {
        let mut out = String::new();
        self.write_compact(&mut out);
        out
    }

    fn write_compact(&self, out: &mut String) {
        match self {
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write_compact(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out, 0);
                    out.push(':');
                    v.write_compact(out);
                }
                out.push('}');
            }
            // scalars render identically in both modes
            other => other.write(out, 0),
        }
    }

    // --- accessors (the serve protocol's request-field reads) -----------

    /// Object field lookup (`None` on non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The string payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric payload, if this is a number (`Int` widens to `f64`,
    /// lossily above 2⁵³ — use [`Json::as_u64`] for exact integers).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(i) => Some(*i as f64),
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The numeric payload as an unsigned integer (rejects fractions and
    /// negatives rather than silently truncating a request field).
    /// `Int` values are exact; whole `Num` floats are accepted only
    /// below 2⁵³ where `f64` is still exact.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Int(i) if *i >= 0 => Some(*i as u64),
            Json::Num(x) if x.fract() == 0.0 && *x >= 0.0 && *x < 9e15 => Some(*x as u64),
            _ => None,
        }
    }

    /// The boolean payload, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The element list, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    // --- parser ---------------------------------------------------------

    /// Parse one JSON value from `src`. The whole input must be consumed
    /// (modulo surrounding whitespace) — trailing garbage is an error,
    /// so a mangled protocol line can't half-parse silently.
    pub fn parse(src: &str) -> Result<Json, String> {
        let b = src.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(b, &mut pos)?;
        skip_ws(b, &mut pos);
        if pos != b.len() {
            return Err(format!("trailing characters at byte {pos}"));
        }
        Ok(v)
    }

    fn write(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        let pad1 = "  ".repeat(indent + 1);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    out.push_str(&pad1);
                    x.write(out, indent + 1);
                    if i + 1 < v.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    out.push_str(&pad1);
                    Json::Str(k.clone()).write(out, indent + 1);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<(), String> {
    if *pos < b.len() && b[*pos] == c {
        *pos += 1;
        Ok(())
    } else {
        Err(format!(
            "expected `{}` at byte {} (found {})",
            c as char,
            *pos,
            b.get(*pos).map(|&c| (c as char).to_string()).unwrap_or_else(|| "end of input".into())
        ))
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err("unexpected end of input".into()),
        Some(b'{') => parse_obj(b, pos),
        Some(b'[') => parse_arr(b, pos),
        Some(b'"') => Ok(Json::Str(parse_string(b, pos)?)),
        Some(b't') => parse_lit(b, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_lit(b, pos, "false", Json::Bool(false)),
        Some(b'n') => parse_lit(b, pos, "null", Json::Null),
        Some(_) => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, word: &str, v: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(word.as_bytes()) {
        *pos += word.len();
        Ok(v)
    } else {
        Err(format!("bad literal at byte {} (expected `{word}`)", *pos))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    while *pos < b.len() && matches!(b[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-') {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos]).map_err(|_| "non-utf8 number".to_string())?;
    // integer lexemes stay exact (Int) — crucial for echoed correlation
    // ids above 2^53; only i64 overflow falls back to f64
    if !text.bytes().any(|c| matches!(c, b'.' | b'e' | b'E')) {
        if let Ok(i) = text.parse::<i64>() {
            return Ok(Json::Int(i));
        }
    }
    text.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| format!("bad number `{text}` at byte {start}"))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    expect(b, pos, b'"')?;
    let mut out = String::new();
    loop {
        match b.get(*pos) {
            None => return Err("unterminated string".into()),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let hex = b
                            .get(*pos + 1..*pos + 5)
                            .and_then(|h| std::str::from_utf8(h).ok())
                            .ok_or_else(|| "truncated \\u escape".to_string())?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| format!("bad \\u escape `{hex}`"))?;
                        // surrogate pairs are not reassembled (the emitter
                        // never writes them; BMP codepoints cover the
                        // protocol's diagnostics); lone surrogates map to
                        // the replacement character instead of erroring
                        out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    other => {
                        return Err(format!("bad escape `\\{:?}`", other.map(|&c| c as char)))
                    }
                }
                *pos += 1;
            }
            Some(_) => {
                // consume one UTF-8 scalar (multi-byte sequences pass
                // through unmodified — the source is a &str, so they are
                // valid)
                let s = std::str::from_utf8(&b[*pos..]).map_err(|_| "non-utf8".to_string())?;
                let c = s.chars().next().unwrap();
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    expect(b, pos, b'[')?;
    let mut v = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(v));
    }
    loop {
        v.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => {
                *pos += 1;
            }
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(v));
            }
            _ => return Err(format!("expected `,` or `]` at byte {}", *pos)),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    expect(b, pos, b'{')?;
    let mut m = BTreeMap::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(m));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        expect(b, pos, b':')?;
        let val = parse_value(b, pos)?;
        m.insert(key, val);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => {
                *pos += 1;
            }
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(m));
            }
            _ => return Err(format!("expected `,` or `}}` at byte {}", *pos)),
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        i64::try_from(x).map(Json::Int).unwrap_or(Json::Num(x as f64))
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Int(x as i64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        i64::try_from(x).map(Json::Int).unwrap_or(Json::Num(x as f64))
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Int(x)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_shapes() {
        let mut o = Json::obj();
        o.set("name", "2mm").set("gfs", 117.48).set("ok", true);
        let mut arr = Json::Arr(vec![]);
        arr.push(1u64).push(2u64);
        o.set("steps", arr);
        let s = o.to_string_pretty();
        assert!(s.contains("\"name\": \"2mm\""));
        assert!(s.contains("117.48"));
        assert!(s.contains("\"ok\": true"));
    }

    #[test]
    fn escapes() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.to_string_pretty(), "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string_pretty(), "42");
        assert_eq!(Json::Num(0.5).to_string_pretty(), "0.5");
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(Json::Num(f64::INFINITY).to_string_pretty(), "null");
    }

    #[test]
    fn parse_roundtrips_pretty_and_compact() {
        let mut o = Json::obj();
        o.set("op", "solve")
            .set("kernel", "2mm")
            .set("cap", 512u64)
            .set("fine", false)
            .set("t", 1.5);
        let mut arr = Json::Arr(vec![]);
        arr.push(1u64).push(Json::Null).push("x");
        o.set("steps", arr);
        for text in [o.to_string_pretty(), o.to_line()] {
            assert_eq!(Json::parse(&text).unwrap(), o, "{text}");
        }
        assert!(!o.to_line().contains('\n'), "line framing must stay one line");
    }

    #[test]
    fn parse_accessors() {
        let j = Json::parse(r#"{"a": 3, "b": "x", "c": true, "d": [1, 2], "e": -2.5}"#).unwrap();
        assert_eq!(j.get("a").and_then(Json::as_u64), Some(3));
        assert_eq!(j.get("b").and_then(Json::as_str), Some("x"));
        assert_eq!(j.get("c").and_then(Json::as_bool), Some(true));
        assert_eq!(j.get("d").and_then(Json::as_arr).map(<[Json]>::len), Some(2));
        assert_eq!(j.get("e").and_then(Json::as_f64), Some(-2.5));
        assert_eq!(j.get("e").and_then(Json::as_u64), None, "negative is not u64");
        assert_eq!(j.get("missing"), None);
    }

    #[test]
    fn large_integer_ids_round_trip_losslessly() {
        // a snowflake-style correlation id above 2^53: an f64 round-trip
        // would corrupt it, Int must not
        let id: i64 = 9_007_199_254_740_993; // 2^53 + 1
        let line = format!(r#"{{"id":{id}}}"#);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("id"), Some(&Json::Int(id)));
        assert_eq!(j.get("id").and_then(Json::as_u64), Some(id as u64));
        assert_eq!(j.to_line(), line, "echoed id must be bit-exact");
        // i64 extremes survive; fractional lexemes still parse as floats
        for extreme in [i64::MAX, i64::MIN] {
            let rt = Json::parse(&Json::Int(extreme).to_line()).unwrap();
            assert_eq!(rt, Json::Int(extreme));
        }
        assert_eq!(Json::parse("3.0").unwrap(), Json::Num(3.0));
        assert_eq!(Json::from(3u64), Json::Int(3));
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let j = Json::parse(r#""a\"b\\c\ndAé""#).unwrap();
        assert_eq!(j.as_str(), Some("a\"b\\c\ndAé"));
        // escaping survives a full round-trip (the caret diagnostics the
        // serve error payloads carry are multi-line strings)
        let s = Json::Str("line1\nline2 | ^^\n".into());
        assert_eq!(Json::parse(&s.to_line()).unwrap(), s);
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in ["", "{", "[1,", "{\"a\" 1}", "tru", "1 2", "{\"a\":1} x", "\"unterminated"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }
}
