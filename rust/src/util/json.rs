//! Minimal JSON value tree + emitter (serde is unavailable offline).
//!
//! Only what the result dumps and report tooling need: construction,
//! pretty-printing with stable key order, and string escaping. No parser —
//! nothing in the pipeline reads JSON back (artifacts are HLO text).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value (offline stand-in for serde_json).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Number.
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (key-sorted for stable output).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// An empty object.
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Set `key` on an object (panics on non-objects).
    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val.into());
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    /// Append to an array (panics on non-arrays).
    pub fn push(&mut self, val: impl Into<Json>) -> &mut Self {
        if let Json::Arr(v) = self {
            v.push(val.into());
        } else {
            panic!("Json::push on non-array");
        }
        self
    }

    /// Render with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        let pad1 = "  ".repeat(indent + 1);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    out.push_str(&pad1);
                    x.write(out, indent + 1);
                    if i + 1 < v.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    out.push_str(&pad1);
                    Json::Str(k.clone()).write(out, indent + 1);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_shapes() {
        let mut o = Json::obj();
        o.set("name", "2mm").set("gfs", 117.48).set("ok", true);
        let mut arr = Json::Arr(vec![]);
        arr.push(1u64).push(2u64);
        o.set("steps", arr);
        let s = o.to_string_pretty();
        assert!(s.contains("\"name\": \"2mm\""));
        assert!(s.contains("117.48"));
        assert!(s.contains("\"ok\": true"));
    }

    #[test]
    fn escapes() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.to_string_pretty(), "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string_pretty(), "42");
        assert_eq!(Json::Num(0.5).to_string_pretty(), "0.5");
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(Json::Num(f64::INFINITY).to_string_pretty(), "null");
    }
}
