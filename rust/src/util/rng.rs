//! Deterministic PRNG (splitmix64 core) used everywhere randomness is
//! needed: HLS-oracle perturbations, HARP surrogate noise, property tests.
//!
//! Determinism is load-bearing: identical seeds must reproduce identical DSE
//! traces (tested in `rust/tests/integration_dse.rs`).

/// Splitmix64 generator. Small state, passes BigCrush for our purposes,
/// and — critically — stable across platforms and runs.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// PRNG seeded with `seed` (splitmix64 stream).
    pub fn new(seed: u64) -> Self {
        Rng {
            state: seed ^ 0x9e37_79b9_7f4a_7c15,
        }
    }

    /// Derive a child generator from a string key (e.g. kernel + design
    /// fingerprint), independent of draw order on the parent.
    pub fn derive(&self, key: &str) -> Rng {
        let mut h = self.state;
        for b in key.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100_0000_01b3); // FNV-ish mix
        }
        Rng::new(h)
    }

    /// Next raw 64-bit draw.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi)`. Panics if `lo >= hi`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range");
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform float in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Pick a uniformly random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.range(0, xs.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, (i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

/// Stable 64-bit hash of a string (FNV-1a), for seeding per-design oracles.
pub fn hash64(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.range(3, 10);
            assert!((3..10).contains(&x));
        }
    }

    #[test]
    fn derive_is_order_independent() {
        let r = Rng::new(5);
        let mut c1 = r.derive("2mm/design-17");
        let mut r2 = Rng::new(5);
        let _ = r2.next_u64(); // consuming parent draws must not matter
        let mut c2 = r.derive("2mm/design-17");
        assert_eq!(c1.next_u64(), c2.next_u64());
        let _ = r2;
    }

    #[test]
    fn hash64_stable() {
        assert_eq!(hash64("gemm"), hash64("gemm"));
        assert_ne!(hash64("gemm"), hash64("gemv"));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
