//! Aggregate statistics used by the report tables (average / geomean rows,
//! Table 7 solver-time summaries, Fig 5 accuracy series).

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Geometric mean over strictly-positive entries; non-positive entries are
/// skipped (the paper's geomean rows are over ratios > 0).
pub fn geomean(xs: &[f64]) -> f64 {
    let logs: Vec<f64> = xs.iter().filter(|&&x| x > 0.0).map(|x| x.ln()).collect();
    if logs.is_empty() {
        0.0
    } else {
        (logs.iter().sum::<f64>() / logs.len() as f64).exp()
    }
}

/// p-th percentile (0..=100) by nearest-rank on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[idx.min(v.len() - 1)]
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_geomean() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        let g = geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
        // non-positive skipped
        let g2 = geomean(&[0.0, 4.0]);
        assert!((g2 - 4.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn stddev_basic() {
        assert_eq!(stddev(&[2.0, 2.0, 2.0]), 0.0);
        assert!(stddev(&[1.0, 3.0]) > 0.0);
    }
}
