//! Fixed-width text table renderer producing the paper-style rows printed
//! by `report::tables` and the `nlp-dse table` CLI subcommand.

/// Fixed-width text table with a title row (byte-stable output).
pub struct TextTable {
    /// Table title, printed above the header.
    pub title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    aligns: Vec<Align>,
}

/// Per-column cell alignment.
#[derive(Clone, Copy, PartialEq)]
pub enum Align {
    /// Left-aligned.
    Left,
    /// Right-aligned.
    Right,
}

impl TextTable {
    /// Table with a title and header row.
    pub fn new(title: &str, headers: &[&str]) -> TextTable {
        TextTable {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            aligns: headers
                .iter()
                .enumerate()
                .map(|(i, _)| if i == 0 { Align::Left } else { Align::Right })
                .collect(),
        }
    }

    /// Set the alignment of column `col`.
    pub fn align(&mut self, col: usize, a: Align) -> &mut Self {
        self.aligns[col] = a;
        self
    }

    /// Append a row (cell count should match the header).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Insert a horizontal separator (rendered as a dashed row).
    pub fn sep(&mut self) -> &mut Self {
        self.rows.push(vec!["--".to_string(); self.headers.len()]);
        self
    }

    /// Render the padded text table.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let total: usize = widths.iter().sum::<usize>() + 3 * (ncol - 1);
        let mut out = String::new();
        out.push_str(&format!("{}\n", self.title));
        out.push_str(&"=".repeat(total.max(self.title.len())));
        out.push('\n');
        let fmt_row = |cells: &[String], widths: &[usize], aligns: &[Align]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str(" | ");
                }
                if aligns[i] == Align::Left {
                    line.push_str(&format!("{:<w$}", c, w = widths[i]));
                } else {
                    line.push_str(&format!("{:>w$}", c, w = widths[i]));
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths, &self.aligns));
        out.push('\n');
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for r in &self.rows {
            if r.iter().all(|c| c == "--") {
                out.push_str(&"-".repeat(total));
            } else {
                out.push_str(&fmt_row(r, &widths, &self.aligns));
            }
            out.push('\n');
        }
        out
    }

    /// Tab-separated form for machine consumption / plotting.
    pub fn to_tsv(&self) -> String {
        let mut out = self.headers.join("\t");
        out.push('\n');
        for r in &self.rows {
            if r.iter().all(|c| c == "--") {
                continue;
            }
            out.push_str(&r.join("\t"));
            out.push('\n');
        }
        out
    }
}

/// Format helpers shared by report tables.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}
/// Format with 1 decimal.
pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}
/// Format as a rounded integer.
pub fn i0(x: f64) -> String {
    format!("{}", x.round() as i64)
}
/// Format as a `x N.N` ratio.
pub fn ratio(x: f64) -> String {
    format!("{x:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = TextTable::new("Table X", &["Kernel", "GF/s"]);
        t.row(vec!["2mm".into(), "117.48".into()]);
        t.row(vec!["gramschmidt".into(), "2.34".into()]);
        let s = t.render();
        assert!(s.contains("Table X"));
        assert!(s.contains("2mm"));
        let lines: Vec<&str> = s.lines().collect();
        // data rows equal width
        assert_eq!(lines[4].len(), lines[5].len());
    }

    #[test]
    fn tsv_skips_separators() {
        let mut t = TextTable::new("t", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]).sep().row(vec!["3".into(), "4".into()]);
        let tsv = t.to_tsv();
        assert_eq!(tsv.lines().count(), 3);
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut t = TextTable::new("t", &["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
