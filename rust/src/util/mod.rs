//! In-repo substrates for the offline build environment.
//!
//! The published system used commodity crates for randomness, serialization
//! and benchmarking; none are available offline here, so each is implemented
//! as a small, tested module:
//!
//! * [`rng`] — deterministic splitmix64/xoshiro PRNG (seeded DSE traces).
//! * [`json`] — minimal JSON value tree + emitter for result dumps.
//! * [`bench`] — criterion-style micro-benchmark harness used by
//!   `rust/benches/*` (`harness = false`).
//! * [`proptest`] — mini property-based testing driver (random cases +
//!   first-failure reporting with the generating seed).
//! * [`stats`] — mean / geomean / percentile helpers used by the report
//!   tables.
//! * [`table`] — fixed-width text table renderer for paper-style output.

pub mod bench;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod table;

/// Read a `usize` knob from the environment (`FUZZ_KERNELS=500`-style);
/// unset or unparsable values fall back to `default`. Used by the
/// generative property suites and the bench smoke harness.
pub fn env_usize(name: &str, default: usize) -> usize {
    parse_usize_or(std::env::var(name).ok(), default)
}

/// The pure half of [`env_usize`] (testable without mutating the
/// process environment, which is UB-prone in threaded test binaries).
fn parse_usize_or(value: Option<String>, default: usize) -> usize {
    value.and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// Integer ceiling division for positive operands.
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// `ceil(log2(x))` for `x >= 1`; returns 0 for `x == 1`.
#[inline]
pub fn ceil_log2(x: u64) -> u32 {
    debug_assert!(x >= 1);
    if x <= 1 {
        0
    } else {
        64 - ((x - 1).leading_zeros() as u32)
    }
}

/// All positive divisors of `n`, ascending. `divisors(0)` is empty.
pub fn divisors(n: u64) -> Vec<u64> {
    if n == 0 {
        return vec![];
    }
    let mut small = Vec::new();
    let mut large = Vec::new();
    let mut d = 1u64;
    while d * d <= n {
        if n % d == 0 {
            small.push(d);
            if d != n / d {
                large.push(n / d);
            }
        }
        d += 1;
    }
    large.reverse();
    small.extend(large);
    small
}

/// Format a cycle/design count compactly (`1.37e10` style), matching the
/// paper's space-size columns.
pub fn sci(x: f64) -> String {
    if x == 0.0 {
        return "0".to_string();
    }
    let exp = x.abs().log10().floor() as i32;
    if (-3..4).contains(&exp) {
        if x.fract() == 0.0 && x.abs() < 1e6 {
            format!("{}", x as i64)
        } else {
            format!("{x:.2}")
        }
    } else {
        let mant = x / 10f64.powi(exp);
        format!("{mant:.2}e{exp}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn divisors_basic() {
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(divisors(190), vec![1, 2, 5, 10, 19, 38, 95, 190]);
        assert_eq!(divisors(0), Vec::<u64>::new());
    }

    #[test]
    fn divisors_count_matches_paper_kernels() {
        // Sanity anchors used by space-size computations.
        assert_eq!(divisors(180).len(), 18);
        assert_eq!(divisors(210).len(), 16);
        assert_eq!(divisors(220).len(), 12);
    }

    #[test]
    fn env_usize_defaults_and_parses() {
        assert_eq!(env_usize("NLP_DSE_SURELY_UNSET_KNOB", 7), 7);
        assert_eq!(parse_usize_or(Some("42".into()), 7), 42);
        assert_eq!(parse_usize_or(Some("not-a-number".into()), 7), 7);
        assert_eq!(parse_usize_or(None, 7), 7);
    }

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(1, 1), 1);
    }

    #[test]
    fn ceil_log2_basic() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(8), 3);
        assert_eq!(ceil_log2(9), 4);
        assert_eq!(ceil_log2(1024), 10);
    }

    #[test]
    fn sci_format() {
        assert_eq!(sci(1.37e10), "1.37e10");
        assert_eq!(sci(0.0), "0");
        assert_eq!(sci(12.0), "12");
    }
}
