//! Criterion-style micro-benchmark harness for `harness = false` bench
//! targets (criterion itself is not available offline).
//!
//! Usage in `rust/benches/*.rs`:
//! ```ignore
//! let mut b = Bench::new("nlp_batch_eval");
//! b.bench("rust_eval/B=512", || { /* work */ });
//! b.finish();
//! ```
//!
//! Each benchmark is warmed up, then timed over adaptive iterations until a
//! target measurement time is reached; reports mean / p50 / p95 per
//! iteration plus throughput when `set_items` was used.

use std::time::{Duration, Instant};

/// One bench suite: named cases, adaptive iteration, printed stats.
pub struct Bench {
    suite: String,
    target: Duration,
    results: Vec<BenchResult>,
}

/// Measured statistics of one case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Case name.
    pub name: String,
    /// Total timed iterations.
    pub iters: u64,
    /// Mean per-iteration time, ns.
    pub mean_ns: f64,
    /// Median per-iteration time, ns.
    pub p50_ns: f64,
    /// 95th-percentile per-iteration time, ns.
    pub p95_ns: f64,
    /// Items per iteration when throughput was requested.
    pub items_per_iter: Option<f64>,
}

impl Bench {
    /// Start a suite (target ms/case from `BENCH_MS`, default 300).
    pub fn new(suite: &str) -> Bench {
        let target_ms: u64 = std::env::var("BENCH_MS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(300);
        println!("== bench suite: {suite} (target {target_ms} ms/case)");
        Bench {
            suite: suite.to_string(),
            target: Duration::from_millis(target_ms),
            results: Vec::new(),
        }
    }

    /// Time `f`, which should perform one unit of work per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) -> &mut Self {
        self.bench_items(name, None, f)
    }

    /// Time `f` and report throughput as `items / iteration-time`.
    pub fn bench_with_items<F: FnMut()>(&mut self, name: &str, items: f64, f: F) -> &mut Self {
        self.bench_items(name, Some(items), f)
    }

    fn bench_items<F: FnMut()>(&mut self, name: &str, items: Option<f64>, mut f: F) -> &mut Self {
        // Warm-up: a few calls, also sizes a batch so each sample >= ~50 us.
        let t0 = Instant::now();
        f();
        let first = t0.elapsed();
        let mut batch = 1u64;
        if first < Duration::from_micros(50) {
            batch = (Duration::from_micros(50).as_nanos() / first.as_nanos().max(1)) as u64 + 1;
        }

        let mut samples: Vec<f64> = Vec::new();
        let mut total = Duration::ZERO;
        let mut iters = 0u64;
        while total < self.target && samples.len() < 2000 {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            let dt = t.elapsed();
            samples.push(dt.as_nanos() as f64 / batch as f64);
            total += dt;
            iters += batch;
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let p50 = samples[samples.len() / 2];
        let p95 = samples[(samples.len() as f64 * 0.95) as usize % samples.len()];

        let thr = items.map(|n| n / (mean / 1e9));
        let thr_str = thr
            .map(|t| format!("  thr={}/s", crate::util::sci(t)))
            .unwrap_or_default();
        println!(
            "  {:<44} mean={:>12}  p50={:>12}  p95={:>12}  iters={}{}",
            name,
            fmt_ns(mean),
            fmt_ns(p50),
            fmt_ns(p95),
            iters,
            thr_str
        );
        self.results.push(BenchResult {
            name: name.to_string(),
            iters,
            mean_ns: mean,
            p50_ns: p50,
            p95_ns: p95,
            items_per_iter: items,
        });
        self
    }

    /// All measured cases so far.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Print the suite footer.
    pub fn finish(&self) {
        println!("== bench suite {} done ({} cases)", self.suite, self.results.len());
    }
}

/// Prevent the optimizer from eliding a computed value (ptr read fence).
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66
    std::hint::black_box(x)
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        std::env::set_var("BENCH_MS", "10");
        let mut b = Bench::new("selftest");
        let mut acc = 0u64;
        b.bench("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert_eq!(b.results().len(), 1);
        assert!(b.results()[0].mean_ns > 0.0);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12e3).ends_with("us"));
        assert!(fmt_ns(12e6).ends_with("ms"));
        assert!(fmt_ns(12e9).ends_with('s'));
    }
}
