//! # NLP-DSE — Automatic Hardware Pragma Insertion in HLS via Non-Linear Programming
//!
//! Reproduction of Pouget, Pouchet & Cong (TODAES 2024, DOI 10.1145/3711847).
//!
//! ## Front door: the `Explorer` facade
//!
//! Most tasks are one chained call through [`engine::Explorer`], which
//! owns kernel construction, exact analysis, Rust-vs-XLA evaluator
//! selection, and oracle setup, and runs any engine registered in the
//! name-keyed [`engine::Registry`] (`nlpdse`, `autodse`, `harp`,
//! `random`, `surrogate`, or your own):
//!
//! ```no_run
//! use nlp_dse::benchmarks::Size;
//! use nlp_dse::engine::{Evaluator, Explorer};
//!
//! # fn main() -> anyhow::Result<()> {
//! let outcome = Explorer::kernel("gemm", Size::Medium)?
//!     .evaluator(Evaluator::auto())
//!     .engine("nlpdse")?
//!     .run()?;
//! println!("{}", outcome.summary());
//! # Ok(())
//! # }
//! ```
//!
//! Every engine returns the same normalized [`engine::Exploration`]
//! outcome, which is what the campaign coordinator aggregates and the
//! report generators consume.
//!
//! ## Escape hatch: the substrate modules
//!
//! The library remains organized as the paper's system plus every
//! substrate it depends on (all built in-repo — see `DESIGN.md` §2 for
//! the substitution table), and all of it stays public for research
//! code that needs the pieces directly:
//!
//! * [`ir`] — affine loop-nest intermediate representation for the input
//!   kernels (the paper consumes PolyBench/C through PolyOpt-HLS; we consume
//!   the same programs expressed directly in this IR).
//! * [`frontend`] — the textual `.knl` loop-nest DSL (parser with
//!   source-span diagnostics + pretty-printer, round-trip-proven over the
//!   whole corpus) and the seeded always-regular random-kernel generator
//!   behind `nlp-dse gen` and the differential fuzz suites.
//! * [`poly`] — exact static analysis: trip counts (incl. triangular loops),
//!   data-dependence analysis with distance vectors, reduction detection,
//!   array footprints and live-in/live-out sets.
//! * [`benchmarks`] — the evaluated kernels (24 PolyBench kernels + CNN) at
//!   the paper's Small/Medium/Large problem sizes (Table 8).
//! * [`pragma`] — Merlin pragma configurations (`parallel`, `pipeline`,
//!   `tile`, `cache`) as per-loop property vectors, plus design-space
//!   enumeration and counting.
//! * [`model`] — the analytical latency + resource **lower bound** of
//!   Section 4 / Appendix B. Its front door is the symbolic bound-model IR
//!   [`model::sym`]: one [`model::sym::BoundModel`] per kernel carries the
//!   latency objective and the Eqs 1–15 constraints as first-class values
//!   and serves all three consumers — the compiled allocation-free batch
//!   evaluator on the DSE hot path, the NLP lowering, and
//!   partial-configuration interval bounds for subspace pruning. The
//!   executable reference recursion ([`model::evaluate`]) and the dense
//!   feature encoding for the AOT XLA evaluator remain alongside.
//! * [`nlp`] — the non-linear program of Section 5 as a thin view over the
//!   shared bound model (shared `Constraint` objects produce the
//!   `Violation`s; the objective is the compiled symbolic tape) and a
//!   specialized global solver standing in for BARON (branch-and-bound
//!   over the divisor lattice with symbolic interval relaxation bounds
//!   and timeouts).
//! * [`merlin`] — simulated AMD/Xilinx Merlin source-to-source compiler:
//!   decides whether each requested pragma is actually applied and realizes
//!   code transformations + memory transfers.
//! * [`hls`] — simulated Vitis HLS + device model (Alveo U200 @ 250 MHz):
//!   the measurement oracle returning post-synthesis latency, DSP/BRAM
//!   usage, achieved II, and synthesis wall-time.
//! * [`dse`] — NLP-DSE itself (Algorithm 1): array-partitioning ladder ×
//!   parallelism mode, lower-bound pruning, early termination.
//! * [`transform`] — legality-checked pre-pragma loop transformations
//!   (interchange / distribution / fusion), each admitted by a
//!   machine-checkable certificate over the `poly::deps`
//!   direction-vector analysis, plus the bounded variant enumerator and
//!   the `(variant × pragma)` DSE mode (`dse --transform`).
//! * [`codegen`] — the exit path: lowers a kernel + solved pragma
//!   [`pragma::Design`] to compilable, pragma-annotated HLS C in two
//!   dialects (Merlin `#pragma ACCEL`, raw Vitis `#pragma HLS`), with a
//!   *realized* mode that emits what simulated Merlin actually accepted
//!   next to what was requested.
//! * [`baselines`] — AutoDSE (bottleneck-driven) and HARP (surrogate-guided)
//!   reimplementations used as comparison points.
//! * [`surrogate`] — the learned-ranking engine: a dependency-free
//!   closed-form ridge regressor over pooled [`model::DesignFeatures`]
//!   (deterministic seeded training on a `gen`-kernel corpus labeled by
//!   [`model::evaluate`], persisted as a versioned JSON artifact) that
//!   rank-cuts each NLP ladder wave before synthesis; every reported
//!   incumbent is re-scored by the exact compiled model and floored by
//!   the admissible bound, never left as a prediction.
//! * [`engine`] — the unified exploration API: the object-safe
//!   [`engine::Engine`] trait, the normalized [`engine::Exploration`]
//!   outcome, the engine [`engine::Registry`], and the
//!   [`engine::Explorer`] session facade.
//! * [`runtime`] — PJRT CPU client wrapper loading `artifacts/*.hlo.txt`
//!   for bulk lower-bound evaluation (python never runs at DSE time);
//!   built as a stub unless the `xla` cargo feature is enabled.
//! * [`system`] — system-level multi-kernel DSE: per-kernel
//!   epsilon-dominance Pareto fronts ([`nlp::solve_front`]) feeding a
//!   branch-and-bound budget allocator that picks one front point per
//!   kernel maximizing total throughput under the shared device
//!   DSP/BRAM/LUT budget (brute-force cross-checked on small instances).
//! * [`coordinator`] — thread-pool campaign orchestration: one
//!   `Box<dyn Engine>` job per (kernel, engine) pair.
//! * [`serve`] — DSE-as-a-service: a line-JSON TCP daemon
//!   (`nlp-dse serve`) with a structural-fingerprint-keyed warm cache —
//!   bit-identical replay of completed solves, bound-model reuse, and
//!   warm-started resubmissions.
//! * [`report`] — regenerates every table and figure of the evaluation.
//! * [`util`] — in-repo substrates for the offline environment: PRNG,
//!   JSON/TSV emitters, bench harness, mini property-testing helper.

#![warn(missing_docs)]

pub mod util;
pub mod ir;
pub mod frontend;
pub mod poly;
pub mod benchmarks;
pub mod pragma;
pub mod hls;
pub mod model;
pub mod nlp;
pub mod merlin;
pub mod dse;
pub mod transform;
pub mod codegen;
pub mod system;
pub mod baselines;
pub mod surrogate;
pub mod engine;
pub mod runtime;
pub mod coordinator;
pub mod serve;
pub mod report;
pub mod cli;

pub use codegen::{Dialect, EmitConfig};
pub use engine::{Engine, Evaluator, Exploration, ExploreCtx, Explorer, Registry};
pub use ir::{ArrayId, Kernel, LoopId, StmtId};
pub use model::{BoundModel, ModelResult, PartialDesign};
pub use pragma::Design;
