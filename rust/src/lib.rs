//! # NLP-DSE — Automatic Hardware Pragma Insertion in HLS via Non-Linear Programming
//!
//! Reproduction of Pouget, Pouchet & Cong (TODAES 2024, DOI 10.1145/3711847).
//!
//! The library is organized as the paper's system plus every substrate it
//! depends on (all built in-repo — see `DESIGN.md` §2 for the substitution
//! table):
//!
//! * [`ir`] — affine loop-nest intermediate representation for the input
//!   kernels (the paper consumes PolyBench/C through PolyOpt-HLS; we consume
//!   the same programs expressed directly in this IR).
//! * [`poly`] — exact static analysis: trip counts (incl. triangular loops),
//!   data-dependence analysis with distance vectors, reduction detection,
//!   array footprints and live-in/live-out sets.
//! * [`benchmarks`] — the evaluated kernels (24 PolyBench kernels + CNN) at
//!   the paper's Small/Medium/Large problem sizes (Table 8).
//! * [`pragma`] — Merlin pragma configurations (`parallel`, `pipeline`,
//!   `tile`, `cache`) as per-loop property vectors, plus design-space
//!   enumeration and counting.
//! * [`model`] — the analytical latency + resource **lower bound** of
//!   Section 4 / Appendix B, and the dense feature encoding consumed by the
//!   AOT-compiled XLA evaluator.
//! * [`nlp`] — the non-linear program of Section 5 (variables, constraints
//!   Eqs 1–15, objective) and a specialized global solver standing in for
//!   BARON (branch-and-bound over the divisor lattice with relaxation
//!   bounds and timeouts).
//! * [`merlin`] — simulated AMD/Xilinx Merlin source-to-source compiler:
//!   decides whether each requested pragma is actually applied and realizes
//!   code transformations + memory transfers.
//! * [`hls`] — simulated Vitis HLS + device model (Alveo U200 @ 250 MHz):
//!   the measurement oracle returning post-synthesis latency, DSP/BRAM
//!   usage, achieved II, and synthesis wall-time.
//! * [`dse`] — NLP-DSE itself (Algorithm 1): array-partitioning ladder ×
//!   parallelism mode, lower-bound pruning, early termination.
//! * [`baselines`] — AutoDSE (bottleneck-driven) and HARP (surrogate-guided)
//!   reimplementations used as comparison points.
//! * [`runtime`] — PJRT CPU client wrapper loading `artifacts/*.hlo.txt`
//!   for bulk lower-bound evaluation (python never runs at DSE time).
//! * [`coordinator`] — thread-pool campaign orchestration across kernels.
//! * [`report`] — regenerates every table and figure of the evaluation.
//! * [`util`] — in-repo substrates for the offline environment: PRNG,
//!   JSON/TSV emitters, bench harness, mini property-testing helper.

pub mod util;
pub mod ir;
pub mod poly;
pub mod benchmarks;
pub mod pragma;
pub mod hls;
pub mod model;
pub mod nlp;
pub mod merlin;
pub mod dse;
pub mod baselines;
pub mod runtime;
pub mod coordinator;
pub mod report;
pub mod cli;

pub use ir::{ArrayId, Kernel, LoopId, StmtId};
pub use model::ModelResult;
pub use pragma::Design;
