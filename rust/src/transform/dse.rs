//! The `(variant × pragma)` design-space exploration.
//!
//! Enumerate legal variants ([`enumerate`]), then run the NLP ladder
//! (Algorithm 1) per variant — cheapest first: each variant's
//! [`BoundModel`] free-design lower bound is computed before any solve,
//! and a variant whose bound already meets or exceeds the incumbent's
//! measured cycles is pruned wholesale, ladder unrun. The untransformed
//! original is always variant 0 and is never pruned, so the search
//! cannot return a worse objective than the no-transform baseline; a
//! transformed variant replaces the incumbent only on strictly better
//! cycles (ties keep the earlier, shorter-trace winner).

use crate::dse::{run_nlp_dse_with_bound_seeded, DseConfig, DseOutcome};
use crate::hls::Device;
use crate::ir::Kernel;
use crate::model::{BoundModel, PartialDesign};
use crate::nlp::BatchEvaluator;
use crate::poly::Analysis;
use crate::pragma::Design;

use super::{enumerate, TransformConfig, Variant};

/// What happened to one enumerated variant.
#[derive(Clone, Debug)]
pub struct VariantRecord {
    /// Index in enumeration order (0 = original).
    pub index: usize,
    /// Rendered rewrite chain (empty for the original).
    pub trace: Vec<String>,
    /// Free-design objective lower bound (cycles) of this variant.
    pub lower_bound: f64,
    /// True when the bound met the incumbent and the ladder was skipped.
    pub pruned: bool,
    /// Best measured cycles, when the ladder ran and synthesized
    /// anything.
    pub cycles: Option<f64>,
    /// Best GF/s, when the ladder ran.
    pub gflops: Option<f64>,
}

/// What one `(variant × pragma)` search produced.
#[derive(Clone, Debug)]
pub struct TransformOutcome {
    /// Kernel name.
    pub kernel: String,
    /// Enumeration bounds used.
    pub config: TransformConfig,
    /// Per-variant fates, in enumeration order.
    pub records: Vec<VariantRecord>,
    /// Index of the winning variant.
    pub winner: usize,
    /// The winning variant itself — `emit` lowers `variant.kernel`
    /// with zero codegen changes.
    pub variant: Variant,
    /// The winning variant's ladder outcome.
    pub outcome: DseOutcome,
    /// Variants pruned by their lower bound.
    pub pruned: u32,
}

impl TransformOutcome {
    /// The winning rewrite chain (empty when the original won).
    pub fn winning_trace(&self) -> Vec<String> {
        self.variant.trace_strings()
    }
}

/// Run the `(variant × pragma)` DSE on `k`.
pub fn run_transform_dse(
    k: &Kernel,
    dev: &Device,
    cfg: &DseConfig,
    tcfg: &TransformConfig,
    evaluator: &dyn BatchEvaluator,
) -> TransformOutcome {
    run_transform_dse_seeded(k, dev, cfg, tcfg, evaluator, &[])
}

/// [`run_transform_dse`] warm-started from cached incumbent designs —
/// the serve daemon's transform-aware warm seeding: the original
/// kernel's cached top-k seeds *every* variant's ladder. Seeds carry
/// over transformation boundaries safely because each variant's solver
/// re-verifies them against its own model (a variant whose loop
/// permutation or rung cap makes a seed infeasible just drops it), so
/// the search can never end up worse than a cold run, and the same
/// seeds always reproduce the same outcome bit-for-bit. A verified
/// seed the rung's menu cannot reach may *improve* the top-k relative
/// to a cold run — which is why seeded results must never be admitted
/// to replay caches.
pub fn run_transform_dse_seeded(
    k: &Kernel,
    dev: &Device,
    cfg: &DseConfig,
    tcfg: &TransformConfig,
    evaluator: &dyn BatchEvaluator,
    seeds: &[Design],
) -> TransformOutcome {
    let variants = enumerate(k, tcfg);
    let mut records = Vec::with_capacity(variants.len());
    let mut incumbent = f64::INFINITY;
    let mut winner = 0usize;
    let mut best: Option<(Variant, DseOutcome)> = None;
    let mut pruned = 0u32;

    for (i, v) in variants.iter().enumerate() {
        let a = Analysis::new(&v.kernel);
        let bound = BoundModel::build(&v.kernel, &a, dev);
        let lb = bound.lower_bound(&PartialDesign::free(v.kernel.n_loops()));
        // variant 0 (the original) always runs: it seeds the incumbent
        // and guarantees the never-worse-than-baseline property
        if i > 0 && lb >= incumbent {
            pruned += 1;
            records.push(VariantRecord {
                index: i,
                trace: v.trace_strings(),
                lower_bound: lb,
                pruned: true,
                cycles: None,
                gflops: None,
            });
            continue;
        }
        let outcome =
            run_nlp_dse_with_bound_seeded(&v.kernel, &a, dev, cfg, evaluator, &bound, seeds);
        let cycles = outcome.best.as_ref().map(|(_, c)| *c);
        records.push(VariantRecord {
            index: i,
            trace: v.trace_strings(),
            lower_bound: lb,
            pruned: false,
            cycles,
            gflops: Some(outcome.best_gflops),
        });
        let better = match (cycles, best.is_some()) {
            (Some(c), _) => c < incumbent,
            // keep the original as placeholder winner even if its
            // ladder synthesized nothing
            (None, false) => true,
            (None, true) => false,
        };
        if better {
            if let Some(c) = cycles {
                incumbent = c;
            }
            winner = i;
            best = Some((v.clone(), outcome));
        }
    }

    let (variant, outcome) = best.expect("variant 0 always runs");
    TransformOutcome {
        kernel: k.name.clone(),
        config: tcfg.clone(),
        records,
        winner,
        variant,
        outcome,
        pruned,
    }
}
