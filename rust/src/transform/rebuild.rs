//! Kernel reconstruction after a structural rewrite.
//!
//! `ir::Kernel` invariants — dense pre-order `LoopId`/`StmtId`s, every
//! `AffineExpr` term naming an enclosing loop — are creation-order
//! facts that any tree surgery breaks. [`rebuild`] restores them:
//! ids are renumbered in pre-order and every affine reference (loop
//! bounds, access indices) is remapped through a *scoped* binding
//! stack. Scoping matters because rewrites may duplicate a source loop
//! id across sibling subtrees (distribution clones the split loop), so
//! a flat old→new map would be ambiguous; the innermost binding wins,
//! exactly like iterator name resolution in the `.knl` parser.

use crate::ir::{Access, AffineExpr, Array, DType, Kernel, Loop, LoopId, Node, Stmt, StmtId};

/// Rebuild a finalized kernel from a (possibly rearranged) node tree.
pub fn rebuild(name: &str, dtype: DType, arrays: Vec<Array>, roots: &[Node]) -> Kernel {
    let mut next_loop = 0u32;
    let mut next_stmt = 0u32;
    let mut scope: Vec<(LoopId, LoopId)> = Vec::new();
    let new_roots: Vec<Node> = roots
        .iter()
        .map(|n| walk(n, &mut next_loop, &mut next_stmt, &mut scope))
        .collect();
    Kernel::finalize(name, dtype, arrays, new_roots)
}

fn walk(
    node: &Node,
    next_loop: &mut u32,
    next_stmt: &mut u32,
    scope: &mut Vec<(LoopId, LoopId)>,
) -> Node {
    match node {
        Node::Loop(l) => {
            let id = LoopId(*next_loop);
            *next_loop += 1;
            // bounds reference enclosing loops only — resolve them
            // before binding this loop's own id
            let lb = remap(&l.lb, scope);
            let ub = remap(&l.ub, scope);
            scope.push((l.id, id));
            let body = l
                .body
                .iter()
                .map(|n| walk(n, next_loop, next_stmt, scope))
                .collect();
            scope.pop();
            Node::Loop(Loop {
                id,
                name: l.name.clone(),
                lb,
                ub,
                body,
            })
        }
        Node::Stmt(s) => {
            let id = StmtId(*next_stmt);
            *next_stmt += 1;
            Node::Stmt(Stmt {
                id,
                name: s.name.clone(),
                writes: s.writes.iter().map(|a| remap_access(a, scope)).collect(),
                reads: s.reads.iter().map(|a| remap_access(a, scope)).collect(),
                ops: s.ops.clone(),
                chain: s.chain.clone(),
            })
        }
    }
}

fn remap(e: &AffineExpr, scope: &[(LoopId, LoopId)]) -> AffineExpr {
    let mut out = AffineExpr::constant(e.constant);
    for &(l, c) in &e.terms {
        let new = scope
            .iter()
            .rev()
            .find(|(old, _)| *old == l)
            .map(|&(_, n)| n)
            .unwrap_or_else(|| panic!("unbound loop reference {l:?} during rebuild"));
        out.add_term(new, c);
    }
    out
}

fn remap_access(a: &Access, scope: &[(LoopId, LoopId)]) -> Access {
    Access::new(a.array, a.indices.iter().map(|e| remap(e, scope)).collect())
}

/// Substitute every affine reference to loop `from` with `to` in a
/// subtree (fusion folds the second loop's iterator onto the first's
/// before rebuilding).
pub fn substitute(node: &Node, from: LoopId, to: LoopId) -> Node {
    let sub_expr = |e: &AffineExpr| -> AffineExpr {
        let mut out = AffineExpr::constant(e.constant);
        for &(l, c) in &e.terms {
            out.add_term(if l == from { to } else { l }, c);
        }
        out
    };
    match node {
        Node::Loop(l) => Node::Loop(Loop {
            id: l.id,
            name: l.name.clone(),
            lb: sub_expr(&l.lb),
            ub: sub_expr(&l.ub),
            body: l.body.iter().map(|n| substitute(n, from, to)).collect(),
        }),
        Node::Stmt(s) => Node::Stmt(Stmt {
            id: s.id,
            name: s.name.clone(),
            writes: s
                .writes
                .iter()
                .map(|a| Access::new(a.array, a.indices.iter().map(&sub_expr).collect()))
                .collect(),
            reads: s
                .reads
                .iter()
                .map(|a| Access::new(a.array, a.indices.iter().map(&sub_expr).collect()))
                .collect(),
            ops: s.ops.clone(),
            chain: s.chain.clone(),
        }),
    }
}

/// The `Loop` node for `id` anywhere under `nodes`, if present.
pub fn find_loop(nodes: &[Node], id: LoopId) -> Option<&Loop> {
    for n in nodes {
        if let Node::Loop(l) = n {
            if l.id == id {
                return Some(l);
            }
            if let Some(found) = find_loop(&l.body, id) {
                return Some(found);
            }
        }
    }
    None
}

/// Replace the `Loop` node for `id` anywhere under `nodes` with the
/// given replacement nodes (splicing them in place). Returns the new
/// forest and whether a replacement happened.
pub fn splice(nodes: &[Node], id: LoopId, replacement: &[Node]) -> (Vec<Node>, bool) {
    let mut out = Vec::with_capacity(nodes.len());
    let mut hit = false;
    for n in nodes {
        match n {
            Node::Loop(l) if l.id == id && !hit => {
                out.extend(replacement.iter().cloned());
                hit = true;
            }
            Node::Loop(l) => {
                let (body, inner_hit) = if hit {
                    (l.body.clone(), false)
                } else {
                    splice(&l.body, id, replacement)
                };
                hit |= inner_hit;
                out.push(Node::Loop(Loop {
                    id: l.id,
                    name: l.name.clone(),
                    lb: l.lb.clone(),
                    ub: l.ub.clone(),
                    body,
                }));
            }
            Node::Stmt(s) => out.push(Node::Stmt(s.clone())),
        }
    }
    (out, hit)
}

/// All statement ids under a node, in pre-order.
pub fn stmts_under(node: &Node) -> Vec<StmtId> {
    let mut out = Vec::new();
    collect_stmts(node, &mut out);
    out
}

fn collect_stmts(node: &Node, out: &mut Vec<StmtId>) {
    match node {
        Node::Loop(l) => {
            for n in &l.body {
                collect_stmts(n, out);
            }
        }
        Node::Stmt(s) => out.push(s.id),
    }
}
