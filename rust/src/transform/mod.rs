//! Pre-pragma, legality-checked loop transformations and the
//! `(variant × pragma)` DSE mode (ISSUE 7).
//!
//! The paper optimizes pragmas on a *fixed* loop nest; the FPGA'25
//! follow-up ("A Unified Framework for Automated Code Transformation
//! and Pragma Insertion", PAPERS.md) lets the same NLP model choose
//! among *transformed variants* of the nest. This module supplies that
//! variant space:
//!
//! * [`Rewrite`] — the three structural rewrites: loop **interchange**
//!   (permute a perfect nest), loop **distribution** (split one loop's
//!   body into two sibling copies), loop **fusion** (merge adjacent
//!   compatible sibling nests);
//! * every application is admitted by a [`LegalityCert`] derived from
//!   the [`poly::deps`](crate::poly::deps) direction/distance vectors
//!   ([`DirVector`](crate::poly::deps::DirVector)) — and every
//!   certificate is *machine-checkable*: [`legality::verify_trace`]
//!   replays a variant's rewrite chain from the original kernel,
//!   re-derives each certificate, and structurally diffs the result;
//! * [`enumerate`](enumerate::enumerate) — a bounded, deterministic,
//!   breadth-first [`Variant`] enumerator deduplicated by exact
//!   structural fingerprint;
//! * [`dse`](mod@dse) — the `(variant × pragma)` search: the NLP ladder
//!   (Algorithm 1) per variant, with
//!   [`BoundModel::lower_bound`](crate::model::BoundModel::lower_bound)
//!   pruning whole variants whose free-design bound already exceeds the
//!   incumbent. The untransformed original always runs first, so the
//!   mode never returns a worse objective than the no-transform
//!   baseline.
//!
//! Variants are plain [`ir::Kernel`](crate::ir::Kernel)s — dense
//! pre-order ids restored by [`rebuild`](rebuild::rebuild) — so pragma
//! spaces, evaluators, codegen, and the `.knl` round trip all apply
//! unchanged.

pub mod distribute;
pub mod dse;
pub mod enumerate;
pub mod fuse;
pub mod interchange;
pub mod legality;
pub mod rebuild;

pub use dse::{run_transform_dse, run_transform_dse_seeded, TransformOutcome, VariantRecord};
pub use enumerate::{enumerate, TransformConfig};
pub use legality::{verify_rewrite, verify_trace, LegalityCert};

use crate::ir::{Kernel, LoopId};
use crate::poly::deps::DepAnalysis;

/// One structural rewrite, expressed over the loop ids of the kernel it
/// is applied to (ids are renumbered by the application itself, so a
/// chain of rewrites names each step's ids, not the original's).
#[derive(Clone, Debug, PartialEq)]
pub enum Rewrite {
    /// Reorder the perfect-nest chain rooted at the top-level loop
    /// `root` into `perm` (the full chain, new outermost first).
    Interchange {
        /// Nest root (must be top-level and perfect).
        root: LoopId,
        /// The permuted chain, new outermost first.
        perm: Vec<LoopId>,
    },
    /// Split loop `at`'s body after its first `split` nodes into two
    /// sibling copies of the loop.
    Distribute {
        /// The loop being distributed.
        at: LoopId,
        /// Number of leading body nodes kept in the first copy.
        split: usize,
    },
    /// Merge adjacent sibling loop `second` into `first` (identical
    /// bounds; `second`'s body is appended to `first`'s).
    Fuse {
        /// The surviving loop.
        first: LoopId,
        /// The loop fused away.
        second: LoopId,
    },
}

impl Rewrite {
    /// Human-readable rendering against the pre-rewrite kernel.
    pub fn describe(&self, k: &Kernel) -> String {
        match self {
            Rewrite::Interchange { root, perm } => {
                let names: Vec<&str> = perm.iter().map(|&l| k.loop_name(l)).collect();
                format!("interchange {} -> ({})", k.loop_name(*root), names.join(","))
            }
            Rewrite::Distribute { at, split } => {
                format!("distribute {} @ {}", k.loop_name(*at), split)
            }
            Rewrite::Fuse { first, second } => {
                format!("fuse {} + {}", k.loop_name(*first), k.loop_name(*second))
            }
        }
    }
}

/// A rewrite together with the certificate that admitted it and its
/// rendering against the kernel it was applied to.
#[derive(Clone, Debug)]
pub struct AppliedRewrite {
    /// The rewrite, over pre-rewrite loop ids.
    pub rewrite: Rewrite,
    /// `rewrite.describe(..)` at application time.
    pub desc: String,
    /// The dependence facts that admitted it.
    pub cert: LegalityCert,
}

/// A transformed kernel plus the rewrite chain that produced it.
#[derive(Clone, Debug)]
pub struct Variant {
    /// The transformed kernel (a plain, finalized `ir::Kernel`).
    pub kernel: Kernel,
    /// Rewrites applied, in order, from the original kernel.
    pub trace: Vec<AppliedRewrite>,
}

impl Variant {
    /// The untransformed original.
    pub fn original(k: &Kernel) -> Variant {
        Variant {
            kernel: k.clone(),
            trace: Vec::new(),
        }
    }
    /// No rewrites applied.
    pub fn is_original(&self) -> bool {
        self.trace.is_empty()
    }
    /// The rendered rewrite chain (empty for the original).
    pub fn trace_strings(&self) -> Vec<String> {
        self.trace.iter().map(|a| a.desc.clone()).collect()
    }
}

/// Apply one rewrite: certify legality against `k`'s dependence
/// direction vectors, then rebuild. `Err(reason)` when the rewrite is
/// structurally inapplicable or refused by the legality rule.
pub fn apply(k: &Kernel, rw: &Rewrite) -> Result<(Kernel, LegalityCert), String> {
    apply_with(k, &crate::poly::deps::analyze(k), rw)
}

/// [`apply`] over a caller-owned dependence analysis of `k` (the
/// enumerator analyzes each frontier kernel once and tries every
/// candidate against it).
pub fn apply_with(
    k: &Kernel,
    da: &DepAnalysis,
    rw: &Rewrite,
) -> Result<(Kernel, LegalityCert), String> {
    match rw {
        Rewrite::Interchange { root, perm } => interchange::apply(k, da, *root, perm),
        Rewrite::Distribute { at, split } => distribute::apply(k, da, *at, *split),
        Rewrite::Fuse { first, second } => fuse::apply(k, *first, *second),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{ArrayDir, DType, KernelBuilder, OpKind, StmtId};
    use crate::serve::fingerprint::fingerprint;

    /// A perfect 3-nest matmul: `for i { for j { for k {
    /// C[i][j] += A[i][k] * B[k][j] } } }` (the PolyBench `gemm`
    /// registry kernel is deliberately imperfect — beta-scaling sibling
    /// nest — so interchange tests build their own).
    fn mm() -> Kernel {
        let mut kb = KernelBuilder::new("mm", DType::F32);
        let c = kb.array("C", &[16, 18], ArrayDir::InOut);
        let a = kb.array("A", &[16, 20], ArrayDir::In);
        let b = kb.array("B", &[20, 18], ArrayDir::In);
        kb.for_const("i", 0, 16, |kb, i| {
            kb.for_const("j", 0, 18, |kb, j| {
                kb.for_const("k", 0, 20, |kb, kk| {
                    kb.stmt(
                        "S0",
                        vec![kb.at(c, &[kb.v(i), kb.v(j)])],
                        vec![
                            kb.at(c, &[kb.v(i), kb.v(j)]),
                            kb.at(a, &[kb.v(i), kb.v(kk)]),
                            kb.at(b, &[kb.v(kk), kb.v(j)]),
                        ],
                        &[(OpKind::Mul, 2), (OpKind::Add, 1)],
                    );
                });
            });
        });
        kb.finish()
    }

    #[test]
    fn mm_interchange_kji_is_legal_and_certified() {
        let k = mm();
        let rw = Rewrite::Interchange {
            root: LoopId(0),
            perm: vec![LoopId(2), LoopId(1), LoopId(0)],
        };
        let (k2, cert) = apply(&k, &rw).expect("mm admits any permutation");
        assert_eq!(cert.rule, interchange::RULE);
        assert!(!cert.checked.is_empty(), "the += self-RAW must be examined");
        // ids renumber pre-order: the new outermost loop is k
        assert_eq!(k2.loop_name(LoopId(0)), "k");
        assert_eq!(k2.loop_name(LoopId(2)), "i");
        assert_eq!(k2.n_loops(), k.n_loops());
        assert_eq!(k2.n_stmts(), k.n_stmts());
        assert!(k2.structural_diff(&k).is_some(), "the nest actually moved");
        // the certificate re-derives bit-for-bit
        legality::verify_rewrite(&k, &rw, &cert).expect("certificate verifies");
    }

    #[test]
    fn interchange_refuses_reversed_vector() {
        // a[i+1][j] = a[i][j+1]: self-RAW distance (1, -1) — swapping
        // i and j would lead with -1
        let mut kb = KernelBuilder::new("skew", DType::F32);
        let a = kb.array("a", &[70, 70], ArrayDir::InOut);
        kb.for_const("i", 0, 63, |kb, i| {
            kb.for_const("j", 0, 63, |kb, j| {
                kb.stmt(
                    "S0",
                    vec![kb.at(a, &[kb.vp(i, 1), kb.v(j)])],
                    vec![kb.at(a, &[kb.v(i), kb.vp(j, 1)])],
                    &[(OpKind::Add, 1)],
                );
            });
        });
        let k = kb.finish();
        let rw = Rewrite::Interchange {
            root: LoopId(0),
            perm: vec![LoopId(1), LoopId(0)],
        };
        let err = apply(&k, &rw).expect_err("(1,-1) must refuse interchange");
        assert!(err.contains("reversed"), "got: {err}");
    }

    #[test]
    fn interchange_rejects_identity_and_partial_permutations() {
        let k = mm();
        let id = Rewrite::Interchange {
            root: LoopId(0),
            perm: vec![LoopId(0), LoopId(1), LoopId(2)],
        };
        assert!(apply(&k, &id).is_err());
        let partial = Rewrite::Interchange {
            root: LoopId(0),
            perm: vec![LoopId(1), LoopId(0)],
        };
        assert!(apply(&k, &partial).is_err());
    }

    #[test]
    fn triangular_bound_blocks_structural_interchange() {
        // for i { for j in i.. } — j's lower bound names i, so (j, i)
        // is structurally inapplicable whatever the dependences say
        let mut kb = KernelBuilder::new("tri", DType::F32);
        let a = kb.array("a", &[64, 64], ArrayDir::Out);
        kb.for_const("i", 0, 64, |kb, i| {
            kb.for_expr("j", kb.v(i), kb.c(64), |kb, j| {
                kb.stmt(
                    "S0",
                    vec![kb.at(a, &[kb.v(i), kb.v(j)])],
                    vec![],
                    &[(OpKind::Add, 1)],
                );
            });
        });
        let k = kb.finish();
        let rw = Rewrite::Interchange {
            root: LoopId(0),
            perm: vec![LoopId(1), LoopId(0)],
        };
        let err = apply(&k, &rw).expect_err("triangular bound must refuse");
        assert!(err.contains("bound"), "got: {err}");
    }

    /// `for i { b[i] = a[i]; c[i] = b[i-1] }`: the crossing RAW is
    /// carried at i but flows first-copy→second-copy — distributable.
    #[test]
    fn distribute_producer_consumer_is_legal() {
        let mut kb = KernelBuilder::new("pc", DType::F32);
        let a = kb.array("a", &[64], ArrayDir::In);
        let b = kb.array("b", &[64], ArrayDir::InOut);
        let c = kb.array("c", &[64], ArrayDir::Out);
        kb.for_const("i", 1, 64, |kb, i| {
            kb.stmt(
                "S0",
                vec![kb.at(b, &[kb.v(i)])],
                vec![kb.at(a, &[kb.v(i)])],
                &[(OpKind::Add, 1)],
            );
            kb.stmt(
                "S1",
                vec![kb.at(c, &[kb.v(i)])],
                vec![kb.at(b, &[kb.vp(i, -1)])],
                &[(OpKind::Add, 1)],
            );
        });
        let k = kb.finish();
        let rw = Rewrite::Distribute {
            at: LoopId(0),
            split: 1,
        };
        let (k2, cert) = apply(&k, &rw).expect("forward crossing distributes");
        assert_eq!(cert.rule, distribute::RULE);
        assert_eq!(k2.nest_roots().len(), 2, "two sibling copies");
        assert_eq!(k2.n_stmts(), 2);
        legality::verify_rewrite(&k, &rw, &cert).expect("certificate verifies");
    }

    /// `for i { a2[i] = c[i-1]; c[i] = a1[i] }`: the RAW source sits in
    /// the second group — distribution would read c before writing it.
    #[test]
    fn distribute_refuses_backward_crossing() {
        let mut kb = KernelBuilder::new("bw", DType::F32);
        let a1 = kb.array("a1", &[64], ArrayDir::In);
        let a2 = kb.array("a2", &[64], ArrayDir::Out);
        let c = kb.array("c", &[64], ArrayDir::InOut);
        kb.for_const("i", 1, 64, |kb, i| {
            kb.stmt(
                "S0",
                vec![kb.at(a2, &[kb.v(i)])],
                vec![kb.at(c, &[kb.vp(i, -1)])],
                &[(OpKind::Add, 1)],
            );
            kb.stmt(
                "S1",
                vec![kb.at(c, &[kb.v(i)])],
                vec![kb.at(a1, &[kb.v(i)])],
                &[(OpKind::Add, 1)],
            );
        });
        let k = kb.finish();
        let rw = Rewrite::Distribute {
            at: LoopId(0),
            split: 1,
        };
        let err = apply(&k, &rw).expect_err("backward carried crossing must refuse");
        assert!(err.contains("second-copy"), "got: {err}");
    }

    #[test]
    fn fuse_same_iteration_producer_consumer() {
        let mut kb = KernelBuilder::new("fu", DType::F32);
        let a = kb.array("a", &[64], ArrayDir::In);
        let b = kb.array("b", &[64], ArrayDir::InOut);
        let c = kb.array("c", &[64], ArrayDir::Out);
        kb.for_const("i", 0, 64, |kb, i| {
            kb.stmt(
                "S0",
                vec![kb.at(b, &[kb.v(i)])],
                vec![kb.at(a, &[kb.v(i)])],
                &[(OpKind::Add, 1)],
            );
        });
        kb.for_const("i2", 0, 64, |kb, i2| {
            kb.stmt(
                "S1",
                vec![kb.at(c, &[kb.v(i2)])],
                vec![kb.at(b, &[kb.v(i2)])],
                &[(OpKind::Mul, 1)],
            );
        });
        let k = kb.finish();
        let rw = Rewrite::Fuse {
            first: LoopId(0),
            second: LoopId(1),
        };
        let (k2, cert) = apply(&k, &rw).expect("distance-0 RAW fuses");
        assert_eq!(cert.rule, fuse::RULE);
        assert_eq!(cert.checked.len(), 1, "exactly the b RAW pair");
        assert_eq!(k2.nest_roots().len(), 1);
        assert_eq!(k2.loop_meta(LoopId(0)).stmts.len(), 2);
        // S1's access now names the surviving iterator
        let s1 = k2.stmt(StmtId(1));
        assert_eq!(s1.reads[0].indices[0].terms, vec![(LoopId(0), 1)]);
        legality::verify_rewrite(&k, &rw, &cert).expect("certificate verifies");
    }

    #[test]
    fn fuse_refuses_read_ahead_across_nests() {
        // second nest reads b[i+1]: fused iteration i would consume it
        // before the (former first-nest) iteration i+1 produces it
        let mut kb = KernelBuilder::new("fx", DType::F32);
        let a = kb.array("a", &[66], ArrayDir::In);
        let b = kb.array("b", &[66], ArrayDir::InOut);
        let c = kb.array("c", &[66], ArrayDir::Out);
        kb.for_const("i", 0, 64, |kb, i| {
            kb.stmt(
                "S0",
                vec![kb.at(b, &[kb.v(i)])],
                vec![kb.at(a, &[kb.v(i)])],
                &[(OpKind::Add, 1)],
            );
        });
        kb.for_const("i2", 0, 64, |kb, i2| {
            kb.stmt(
                "S1",
                vec![kb.at(c, &[kb.v(i2)])],
                vec![kb.at(b, &[kb.vp(i2, 1)])],
                &[(OpKind::Mul, 1)],
            );
        });
        let k = kb.finish();
        let rw = Rewrite::Fuse {
            first: LoopId(0),
            second: LoopId(1),
        };
        let err = apply(&k, &rw).expect_err("negative fused distance must refuse");
        assert!(err.contains("reverses"), "got: {err}");
    }

    #[test]
    fn fuse_requires_adjacent_identical_bounds() {
        let mut kb = KernelBuilder::new("fb", DType::F32);
        let a = kb.array("a", &[64], ArrayDir::Out);
        let b = kb.array("b", &[64], ArrayDir::Out);
        kb.for_const("i", 0, 64, |kb, i| {
            kb.stmt("S0", vec![kb.at(a, &[kb.v(i)])], vec![], &[(OpKind::Add, 1)]);
        });
        kb.for_const("j", 0, 32, |kb, j| {
            kb.stmt("S1", vec![kb.at(b, &[kb.v(j)])], vec![], &[(OpKind::Add, 1)]);
        });
        let k = kb.finish();
        let rw = Rewrite::Fuse {
            first: LoopId(0),
            second: LoopId(1),
        };
        let err = apply(&k, &rw).expect_err("bounds differ");
        assert!(err.contains("bounds"), "got: {err}");
    }

    #[test]
    fn enumerate_mm_reaches_all_six_orders_deterministically() {
        let k = mm();
        let cfg = TransformConfig::default();
        let vs = enumerate(&k, &cfg);
        // mm's vectors admit every permutation: 3! orders, one variant
        // each, dedup folds depth-2 chains back onto depth-1 results
        assert_eq!(vs.len(), 6);
        assert!(vs[0].is_original());
        let mut fps: Vec<u64> = vs.iter().map(|v| fingerprint(&v.kernel).exact).collect();
        fps.sort();
        fps.dedup();
        assert_eq!(fps.len(), 6, "fingerprints are pairwise distinct");
        for v in &vs {
            legality::verify_trace(&k, v).expect("every trace replays");
        }
        // bit-for-bit reproducible
        let again = enumerate(&k, &cfg);
        assert_eq!(vs.len(), again.len());
        for (a, b) in vs.iter().zip(&again) {
            assert_eq!(a.trace_strings(), b.trace_strings());
            assert!(a.kernel.structural_diff(&b.kernel).is_none());
        }
    }

    #[test]
    fn enumerate_respects_caps() {
        let k = mm();
        let cfg = TransformConfig {
            max_variants: 3,
            max_depth: 1,
            max_perm_loops: 4,
        };
        let vs = enumerate(&k, &cfg);
        assert_eq!(vs.len(), 3);
        // chains never exceed the depth cap
        assert!(vs.iter().all(|v| v.trace.len() <= 1));
        // a perm cap below the nest width disables interchange entirely
        let none = enumerate(
            &k,
            &TransformConfig {
                max_variants: 24,
                max_depth: 2,
                max_perm_loops: 2,
            },
        );
        assert_eq!(none.len(), 1, "only the original remains");
    }

    #[test]
    fn certificate_tampering_is_detected() {
        let k = mm();
        let rw = Rewrite::Interchange {
            root: LoopId(0),
            perm: vec![LoopId(1), LoopId(0), LoopId(2)],
        };
        let (_, mut cert) = apply(&k, &rw).expect("legal");
        cert.checked.pop();
        let err = legality::verify_rewrite(&k, &rw, &cert).expect_err("tampered cert");
        assert!(err.contains("mismatch"), "got: {err}");
    }
}
