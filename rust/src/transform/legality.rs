//! Legality certificates and their verification.
//!
//! Every admitted rewrite carries a [`LegalityCert`]: the rule name and
//! the exact direction/distance vectors the rule examined. The
//! certificate is *machine-checkable*: [`verify_rewrite`] re-derives it
//! from scratch against the pre-rewrite kernel and compares, and
//! [`verify_trace`] replays a whole variant chain from the original
//! kernel, verifying every step and structurally diffing the final
//! kernel against the variant's. The property suites run both over the
//! PolyBench registry and the generated corpus.
//!
//! The per-rewrite criteria (DESIGN.md §12):
//!
//! * **interchange** — for every dependence vector touching the
//!   permuted band, the leading non-`=` component in the *permuted*
//!   order must stay forward: a positive constant distance or a proven-
//!   positive (`<`) component. An `Any` (`*`) component is admitted
//!   only when the permutation preserves the relative order of all the
//!   vector's non-`=` components (then the permuted vector is
//!   order-equivalent to the original, which is lexicographically
//!   non-negative by construction).
//! * **distribution** — a dependence crossing the cut is legal when an
//!   enclosing loop above the split carries it with a proven-positive
//!   distance (distribution never reorders across enclosing
//!   iterations), or — with all `=` components above — when its source
//!   lies in the textually first group (the first copy running wholly
//!   early only over-satisfies first→second flows). A source in the
//!   second group with no positive outer carrier is broken by the
//!   split; `Any` above the split refuses conservatively.
//! * **fusion** — per conflicting access pair across the two nests,
//!   the un-normalized fused-level distance (`iter_second = iter_first
//!   + d`) must satisfy `d >= 0` unless an outer constant level already
//!   orders the pair; `Any` anywhere refuses.

use crate::ir::Kernel;
use crate::poly::deps::{DirComp, DirVector};

use super::{Rewrite, Variant};

/// The dependence facts one rewrite's admission rested on.
#[derive(Clone, Debug, PartialEq)]
pub struct LegalityCert {
    /// The rule that admitted the rewrite.
    pub rule: &'static str,
    /// Direction vectors examined, exactly as the rule saw them (for
    /// fusion these are raw, un-normalized pair vectors).
    pub checked: Vec<DirVector>,
}

/// Re-derive the certificate of `rw` against `pre` and require it to
/// match `cert` bit-for-bit. `Err` when the rewrite no longer applies,
/// is no longer legal, or was admitted on different facts.
pub fn verify_rewrite(pre: &Kernel, rw: &Rewrite, cert: &LegalityCert) -> Result<Kernel, String> {
    let (next, fresh) = super::apply(pre, rw)?;
    if &fresh == cert {
        Ok(next)
    } else {
        Err(format!(
            "certificate mismatch for {rw:?}: recorded {} vector(s) under rule `{}`, \
             re-derivation yields {} under `{}`",
            cert.checked.len(),
            cert.rule,
            fresh.checked.len(),
            fresh.rule,
        ))
    }
}

/// Replay a variant's whole rewrite chain from `original`, verifying
/// each step's certificate, then structurally diff the replayed kernel
/// against the variant's.
pub fn verify_trace(original: &Kernel, v: &Variant) -> Result<(), String> {
    let mut k = original.clone();
    for (i, step) in v.trace.iter().enumerate() {
        k = verify_rewrite(&k, &step.rewrite, &step.cert)
            .map_err(|e| format!("step {} ({}): {e}", i + 1, step.desc))?;
    }
    match k.structural_diff(&v.kernel) {
        None => Ok(()),
        Some(d) => Err(format!("replayed kernel diverges from variant: {d}")),
    }
}

/// The interchange criterion for one vector under a permuted loop
/// order (outermost first). See the module docs.
pub(crate) fn permuted_vector_legal(v: &DirVector, order: &[crate::ir::LoopId]) -> bool {
    for &l in order {
        match v.component(l) {
            None => continue, // not part of this vector's shared nest
            Some(DirComp::Dist(0)) => continue,
            Some(DirComp::Dist(d)) if d > 0 => return true,
            Some(DirComp::Dist(_)) => return false, // negative would lead
            Some(DirComp::Pos) => return true,
            Some(DirComp::Any) => return relative_order_preserved(v, order),
        }
    }
    true // loop-independent under this order
}

/// Whether `order` keeps all of `v`'s non-`=` components in their
/// original relative order (sufficient for legality: the permuted
/// vector is then order-equivalent to the original).
fn relative_order_preserved(v: &DirVector, order: &[crate::ir::LoopId]) -> bool {
    let mut last: Option<usize> = None;
    for (l, c) in &v.entries {
        if c.is_eq() {
            continue;
        }
        let Some(pos) = order.iter().position(|x| x == l) else {
            return false; // a constrained loop left the band: refuse
        };
        if let Some(p) = last {
            if pos < p {
                return false;
            }
        }
        last = Some(pos);
    }
    true
}
