//! Loop interchange: permute the loops of a top-level perfect nest.
//!
//! Legal iff no dependence direction vector is reversed by the
//! permutation ([`legality::permuted_vector_legal`]). Structural
//! preconditions: the nest must be perfect (each level's body is a
//! single loop until a straight-line innermost body), the root must be
//! top-level, and no loop bound may reference a loop that the
//! permutation moves below it (triangular nests admit only
//! order-respecting permutations).

use crate::ir::{Kernel, Loop, LoopId, Node};
use crate::poly::deps::DepAnalysis;

use super::legality::{permuted_vector_legal, LegalityCert};
use super::rebuild::{find_loop, rebuild, splice};

/// The rule string recorded in interchange certificates.
pub const RULE: &str = "interchange: leading non-`=` component stays forward under permutation";

/// The perfect-nest chain rooted at `root` (outermost first), if the
/// nest is perfect: every non-innermost body is exactly one loop, the
/// innermost body is non-empty straight-line code.
pub fn perfect_chain(k: &Kernel, root: LoopId) -> Option<Vec<LoopId>> {
    let mut chain = Vec::new();
    let mut cur = find_loop(&k.roots, root)?;
    loop {
        chain.push(cur.id);
        if cur.body.iter().all(|n| matches!(n, Node::Stmt(_))) {
            return if cur.body.is_empty() { None } else { Some(chain) };
        }
        match cur.body.as_slice() {
            [Node::Loop(inner)] => cur = inner,
            _ => return None,
        }
    }
}

/// Certify and apply `perm` to the perfect nest rooted at `root`.
pub fn apply(
    k: &Kernel,
    da: &DepAnalysis,
    root: LoopId,
    perm: &[LoopId],
) -> Result<(Kernel, LegalityCert), String> {
    if k.loop_meta(root).parent.is_some() {
        return Err(format!("loop {} is not a nest root", k.loop_name(root)));
    }
    let chain =
        perfect_chain(k, root).ok_or_else(|| format!("{} is not a perfect nest", k.loop_name(root)))?;
    let mut sorted = perm.to_vec();
    sorted.sort();
    let mut chain_sorted = chain.clone();
    chain_sorted.sort();
    if sorted != chain_sorted {
        return Err("permutation does not cover the nest chain".into());
    }
    if perm == chain.as_slice() {
        return Err("identity permutation".into());
    }
    // structural precondition: every bound references only loops that
    // stay above it in the new order
    for (p, &l) in perm.iter().enumerate() {
        let (lb, ub) = k.loop_bounds(l);
        for dep in lb.loops().chain(ub.loops()) {
            if !perm[..p].contains(&dep) {
                return Err(format!(
                    "bound of {} references {}, which the permutation moves below it",
                    k.loop_name(l),
                    k.loop_name(dep)
                ));
            }
        }
    }
    // legality: every vector touching the band survives the reorder
    let mut checked = Vec::new();
    for v in &da.dir_vectors {
        if !v.entries.iter().any(|(l, _)| chain.contains(l)) {
            continue;
        }
        if !permuted_vector_legal(v, perm) {
            return Err(format!(
                "dependence {:?} {}→{} reversed by permutation",
                v.kind, v.src, v.dst
            ));
        }
        checked.push(v.clone());
    }
    let cert = LegalityCert {
        rule: RULE,
        checked,
    };

    // rebuild the nest in permuted order: each loop keeps its own
    // (id, name, bounds); the innermost statements move wholesale
    let innermost_body = find_loop(&k.roots, *chain.last().unwrap())
        .expect("chain tail exists")
        .body
        .clone();
    let mut nest: Option<Node> = None;
    for &l in perm.iter().rev() {
        let lp = find_loop(&k.roots, l).expect("chain loop exists");
        let body = match nest.take() {
            Some(inner) => vec![inner],
            None => innermost_body.clone(),
        };
        nest = Some(Node::Loop(Loop {
            id: lp.id,
            name: lp.name.clone(),
            lb: lp.lb.clone(),
            ub: lp.ub.clone(),
            body,
        }));
    }
    let (new_roots, hit) = splice(&k.roots, root, &[nest.expect("non-empty chain")]);
    debug_assert!(hit);
    Ok((
        rebuild(&k.name, k.dtype, k.arrays.clone(), &new_roots),
        cert,
    ))
}
