//! Loop distribution: split one loop's body into two sibling copies.
//!
//! `for i { A; B }` becomes `for i { A } for i { B }`. Within one
//! iteration of any enclosing loop, all of `A`'s iterations now run
//! before all of `B`'s. A crossing dependence whose source is in `A`
//! is therefore always still satisfied — running the whole first copy
//! early only over-satisfies it — while one whose source is in `B`
//! (which the original interleaving ordered before later `A`
//! iterations) is broken unless an enclosing loop above the split
//! carries it with a proven-positive distance (distribution never
//! reorders across enclosing iterations). An `Any` component above the
//! split refuses conservatively.

use crate::ir::{Kernel, Loop, LoopId, Node, StmtId};
use crate::poly::deps::{DepAnalysis, DirComp, DirVector};
use std::collections::BTreeSet;

use super::legality::LegalityCert;
use super::rebuild::{find_loop, rebuild, splice, stmts_under};

/// The rule string recorded in distribution certificates.
pub const RULE: &str = "distribute: every crossing dependence flows first-copy to second-copy \
                        or is carried above the split";

/// Whether one crossing vector survives distributing `at`.
/// `src_in_first`: the vector's source statement lies in the group kept
/// in the textually first copy.
fn crossing_legal(v: &DirVector, at: LoopId, src_in_first: bool) -> bool {
    for &(l, c) in &v.entries {
        if l == at {
            // all enclosing levels are `=`: the pair's order within this
            // enclosing iteration is decided by the copies' sequence
            return src_in_first;
        }
        match c {
            DirComp::Dist(0) => continue,
            DirComp::Dist(d) if d > 0 => return true, // outer loop enforces
            DirComp::Pos => return true,
            _ => return false, // Any / negative above: refuse
        }
    }
    src_in_first // `at` missing from the shared nest: conservative
}

/// Certify and apply: split loop `at`'s body after `split` nodes.
pub fn apply(
    k: &Kernel,
    da: &DepAnalysis,
    at: LoopId,
    split: usize,
) -> Result<(Kernel, LegalityCert), String> {
    let node = find_loop(&k.roots, at)
        .ok_or_else(|| format!("loop {} not found", at))?
        .clone();
    let m = node.body.len();
    if m < 2 || split == 0 || split >= m {
        return Err(format!(
            "split {split} outside (0, {m}) for loop {}",
            k.loop_name(at)
        ));
    }
    let a_stmts: BTreeSet<StmtId> = node.body[..split].iter().flat_map(stmts_under).collect();
    let b_stmts: BTreeSet<StmtId> = node.body[split..].iter().flat_map(stmts_under).collect();

    let mut checked = Vec::new();
    for v in &da.dir_vectors {
        let forward = a_stmts.contains(&v.src) && b_stmts.contains(&v.dst);
        let backward = b_stmts.contains(&v.src) && a_stmts.contains(&v.dst);
        if !forward && !backward {
            continue;
        }
        if !crossing_legal(v, at, forward) {
            return Err(format!(
                "dependence {:?} {}→{} flows second-copy→first across the cut at {}",
                v.kind,
                v.src,
                v.dst,
                k.loop_name(at)
            ));
        }
        checked.push(v.clone());
    }
    let cert = LegalityCert {
        rule: RULE,
        checked,
    };

    let halves = [
        Node::Loop(Loop {
            id: node.id,
            name: node.name.clone(),
            lb: node.lb.clone(),
            ub: node.ub.clone(),
            body: node.body[..split].to_vec(),
        }),
        Node::Loop(Loop {
            id: node.id,
            name: node.name.clone(),
            lb: node.lb.clone(),
            ub: node.ub.clone(),
            body: node.body[split..].to_vec(),
        }),
    ];
    let (new_roots, hit) = splice(&k.roots, at, &halves);
    debug_assert!(hit);
    Ok((
        rebuild(&k.name, k.dtype, k.arrays.clone(), &new_roots),
        cert,
    ))
}
