//! Bounded, deterministic variant enumeration.
//!
//! Breadth-first over rewrite chains: depth 1 applies every legal
//! candidate to the original, depth 2 to each depth-1 survivor, and so
//! on up to [`TransformConfig::max_depth`]. Candidates are generated in
//! a fixed order (interchanges by nest then lexicographic permutation,
//! distributions by loop id then split, fusions in pre-order position)
//! and duplicates are dropped by exact structural fingerprint, so for a
//! given kernel and config the variant list — indices, traces, and all
//! — is reproducible. A replayed `gen`-corpus failure therefore needs
//! only the corpus seed and this config to name its variant exactly.

use crate::ir::{Kernel, LoopId, Node};
use crate::serve::fingerprint;
use std::collections::BTreeSet;

use super::{apply_with, interchange, AppliedRewrite, Rewrite, Variant};

/// Deterministic enumeration bounds. All knobs are part of the serve
/// cache key space, so two daemons with different bounds never share
/// variant-space cache entries.
#[derive(Clone, Debug, PartialEq)]
pub struct TransformConfig {
    /// Total variants kept, original included.
    pub max_variants: usize,
    /// Longest rewrite chain explored.
    pub max_depth: usize,
    /// Widest perfect nest considered for interchange (permutation
    /// count is factorial in this).
    pub max_perm_loops: usize,
}

impl Default for TransformConfig {
    fn default() -> Self {
        TransformConfig {
            max_variants: 24,
            max_depth: 2,
            max_perm_loops: 4,
        }
    }
}

impl TransformConfig {
    /// Canonical rendering, mixed into serve fingerprints and printed
    /// in fuzz replay lines.
    pub fn describe(&self) -> String {
        format!(
            "variants={} depth={} perm={}",
            self.max_variants, self.max_depth, self.max_perm_loops
        )
    }
}

/// All candidate rewrites of `k`, in the fixed enumeration order.
/// Candidates are structural only — legality is decided by `apply_with`.
pub fn candidates(k: &Kernel, cfg: &TransformConfig) -> Vec<Rewrite> {
    let mut out = Vec::new();
    for root in k.nest_roots() {
        if let Some(chain) = interchange::perfect_chain(k, root) {
            if chain.len() >= 2 && chain.len() <= cfg.max_perm_loops {
                for idx in permutations(chain.len()) {
                    let perm: Vec<LoopId> = idx.iter().map(|&i| chain[i]).collect();
                    if perm != chain {
                        out.push(Rewrite::Interchange { root, perm });
                    }
                }
            }
        }
    }
    for lid in 0..k.n_loops() as u32 {
        let l = LoopId(lid);
        if let Some(node) = super::rebuild::find_loop(&k.roots, l) {
            for split in 1..node.body.len() {
                out.push(Rewrite::Distribute { at: l, split });
            }
        }
    }
    collect_fusions(&k.roots, &mut out);
    out
}

fn collect_fusions(nodes: &[Node], out: &mut Vec<Rewrite>) {
    for w in nodes.windows(2) {
        if let [Node::Loop(a), Node::Loop(b)] = w {
            if a.lb == b.lb && a.ub == b.ub {
                out.push(Rewrite::Fuse {
                    first: a.id,
                    second: b.id,
                });
            }
        }
    }
    for n in nodes {
        if let Node::Loop(l) = n {
            collect_fusions(&l.body, out);
        }
    }
}

/// All permutations of `0..n` in lexicographic order.
fn permutations(n: usize) -> Vec<Vec<usize>> {
    let mut out = Vec::new();
    let mut cur = Vec::with_capacity(n);
    let mut used = vec![false; n];
    perm_rec(n, &mut cur, &mut used, &mut out);
    out
}

fn perm_rec(n: usize, cur: &mut Vec<usize>, used: &mut [bool], out: &mut Vec<Vec<usize>>) {
    if cur.len() == n {
        out.push(cur.clone());
        return;
    }
    for i in 0..n {
        if used[i] {
            continue;
        }
        used[i] = true;
        cur.push(i);
        perm_rec(n, cur, used, out);
        cur.pop();
        used[i] = false;
    }
}

/// Enumerate legal variants of `k` breadth-first under `cfg`. The
/// original is always variant 0; every other entry carries a non-empty
/// certified trace. Structurally identical kernels (exact fingerprint)
/// are enumerated once, whichever chain reaches them first.
pub fn enumerate(k: &Kernel, cfg: &TransformConfig) -> Vec<Variant> {
    let mut seen: BTreeSet<u64> = BTreeSet::new();
    seen.insert(fingerprint(k).exact);
    let mut variants = vec![Variant::original(k)];
    let mut frontier: Vec<usize> = vec![0];
    for _depth in 0..cfg.max_depth {
        if variants.len() >= cfg.max_variants {
            break;
        }
        let mut next_frontier = Vec::new();
        for vi in frontier {
            let base = variants[vi].clone();
            let da = crate::poly::deps::analyze(&base.kernel);
            for rw in candidates(&base.kernel, cfg) {
                if variants.len() >= cfg.max_variants {
                    break;
                }
                let Ok((kernel, cert)) = apply_with(&base.kernel, &da, &rw) else {
                    continue;
                };
                if !seen.insert(fingerprint(&kernel).exact) {
                    continue;
                }
                let mut trace = base.trace.clone();
                trace.push(AppliedRewrite {
                    desc: rw.describe(&base.kernel),
                    rewrite: rw,
                    cert,
                });
                next_frontier.push(variants.len());
                variants.push(Variant { kernel, trace });
            }
        }
        if next_frontier.is_empty() {
            break;
        }
        frontier = next_frontier;
    }
    variants
}
